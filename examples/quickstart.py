"""Quickstart: the R-like GenOps API, lazy fusion, and out-of-core execution.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's programming model: write ordinary (R-flavoured) matrix
code; the engine fuses it into one streaming pass and runs it on either the
in-memory tier or the out-of-core tier with identical results.
"""
import numpy as np

from repro.core import fm

# --- build a "dataset": 2M x 16 tall-and-skinny matrix --------------------
n, p = 2_000_000, 16
X_host = np.random.default_rng(0).normal(size=(n, p)).astype(np.float32)

# In-memory tier (device = HBM analog)
X = fm.conv_R2FM(X_host)

# Lazy R-style expressions: nothing computes yet -----------------------------
Z = (X - 1.0) / 2.0                  # elementwise chain (sapply/mapply)
stats = fm.colSums(Z ** 2)           # aggregation sink
gram = fm.crossprod(Z)               # Gram sink (t(Z) %*% Z)
hist = fm.table_(fm.which_min_row(fm.abs_(Z)), p)  # argmin + groupby

print("virtual handles:", Z.m, stats.m, gram.m, sep="\n  ")

# ONE fused pass materializes every sink together -----------------------------
stats_m, gram_m, hist_m = fm.materialize(stats, gram, hist)
print("colSums(Z^2)[:4] =", fm.as_np(stats_m).ravel()[:4])
print("gram[0,:4]       =", fm.as_np(gram_m)[0, :4])
print("argmin histogram =", fm.as_np(hist_m).ravel())

# --- out-of-core tier: same code, host-resident matrix ----------------------
X_ooc = fm.conv_R2FM(X_host, host=True)        # "on SSD"
Z2 = (X_ooc - 1.0) / 2.0
stats2, gram2 = fm.materialize(fm.colSums(Z2 ** 2), fm.crossprod(Z2))
np.testing.assert_allclose(fm.as_np(stats2), fm.as_np(stats_m), rtol=1e-4)
np.testing.assert_allclose(fm.as_np(gram2), fm.as_np(gram_m), rtol=1e-4)
print("out-of-core result == in-memory result  ✓")

# --- paper algorithms, one line each ----------------------------------------
from repro.algorithms import summary, correlation, svd_tall

s = summary(X)
print("summary.mean[:4] =", s.mean[:4])
c = correlation(X)
print("corr diag ≈ 1:", np.allclose(np.diag(c), 1.0, atol=1e-5))
r = svd_tall(X, k=4)
print("top-4 singular values:", np.round(r.s, 1))
