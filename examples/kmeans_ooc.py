"""Out-of-core k-means at "billion-scale" proportions (scaled to CPU).

    PYTHONPATH=src python examples/kmeans_ooc.py            # host-RAM tier
    PYTHONPATH=src python examples/kmeans_ooc.py --disk     # real disk tier

The paper's MixGaussian-1B experiment in miniature: a mixture-of-Gaussians
dataset on the slow tier is clustered without ever materializing it on the
device tier.  Each Lloyd iteration is ONE fused streaming pass (distances →
argmin → groupby sinks), and the compiled plan is reused across iterations
(plan cache).

``--disk`` exercises the full FlashR external-memory workflow: the dataset
is written to the on-disk matrix format partition-by-partition (it never
exists whole in RAM), reopened by name through the registry as an
``MmapStore``, and streamed through the double-buffered prefetcher.  The
partition budget is shrunk (``--partition-mib``) so the matrix is ≥16
partitions long, then the resulting centroids are checked against an
in-memory run of the identical streaming schedule (bitwise-equal reduction
order ⇒ centroids match to float32 exactness).
"""
import argparse
import tempfile
import time

import numpy as np


def build_dataset(n: int, p: int, k: int, seed: int = 42):
    """Mixture-of-Gaussians generator: returns (means, row-chunk iterator)."""
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(k, p)) * 8
    labels = rng.integers(0, k, size=n)

    def chunks(chunk_rows: int = 1 << 16):
        for ofs in range(0, n, chunk_rows):
            lab = labels[ofs:ofs + chunk_rows]
            yield (means[lab]
                   + rng.normal(size=(lab.shape[0], p))).astype(np.float32)

    return means, chunks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--disk", action="store_true",
                    help="use the on-disk tier (MmapStore) instead of host RAM")
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--p", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--iters", type=int, default=15)
    ap.add_argument("--partition-mib", type=int, default=4,
                    help="I/O partition budget in --disk mode (MiB)")
    ap.add_argument("--data-dir", default=None,
                    help="registry data dir for --disk (default: a temp dir)")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the in-memory equivalence check in --disk mode")
    args = ap.parse_args(argv)

    from repro.core import fm
    from repro.algorithms import kmeans

    n, p, k = args.n, args.p, args.k
    nbytes = n * p * 4
    means, chunks = build_dataset(n, p, k)

    tmpdir = None  # auto-removed at exit when the user gave no --data-dir
    if args.disk:
        from repro import storage
        budget = args.partition_mib << 20
        data_dir = args.data_dir
        if data_dir is None:
            tmpdir = tempfile.TemporaryDirectory(prefix="fm-kmeans-")
            data_dir = tmpdir.name
        fm.set_conf(data_dir=data_dir, io_partition_bytes=budget)
        print(f"writing MixGaussian ({n}x{p}, {nbytes / 2**20:.0f} MiB = "
              f"{nbytes / budget:.0f}x the partition budget) to disk...")
        store = storage.create_matrix(storage.registry.matrix_path("mixgauss"),
                                      (n, p), np.float32)
        ofs = 0
        for chunk in chunks():
            store.write_rows(ofs, chunk)
            ofs += chunk.shape[0]
        store.flush()
        store.close()
        X = fm.get_dense_matrix("mixgauss")
        assert X.m.on_disk and isinstance(X.m.store, storage.MmapStore)
    else:
        print(f"sampling MixGaussian ({n}x{p}, {nbytes / 2**20:.0f} MiB) "
              "on the host-RAM tier...")
        X_host = np.empty((n, p), np.float32)
        ofs = 0
        for chunk in chunks():
            X_host[ofs:ofs + chunk.shape[0]] = chunk
            ofs += chunk.shape[0]
        X = fm.conv_R2FM(X_host, host=True)

    t0 = time.perf_counter()
    res = kmeans(X, k=k, max_iter=args.iters, seed=0)
    dt = time.perf_counter() - t0

    d = np.linalg.norm(res.centers[:, None] - means[None], axis=-1)
    print(f"done in {dt:.1f}s ({res.iters} iterations, "
          f"{nbytes * res.iters / dt / 2**30:.2f} GiB/s streamed)")
    print(f"wss = {res.wss:.3e}")
    print(f"recovered centers within {d.min(1).max():.3f} of truth "
          f"({(d.min(1) < 0.5).sum()}/{k} exact)")

    if args.disk and not args.no_check:
        # The acceptance check: the disk run must reproduce the in-memory
        # run.  mode='stream' walks the same partition schedule on the
        # device tier, so the reduction order — and hence the centroids —
        # must agree to float32 exactness.
        print("verifying against the in-memory run...")
        X_mem = fm.conv_R2FM(np.asarray(X.m.logical_data()))
        res_mem = kmeans(X_mem, k=k, max_iter=args.iters, seed=0, mode="stream")
        np.testing.assert_allclose(res.centers, res_mem.centers, atol=1e-5)
        print(f"in-memory centroids match (max diff "
              f"{np.abs(res.centers - res_mem.centers).max():.2e})")
    # Mixture recovery is a property of the synthetic data/seed, not the
    # storage tier — check it last so a local optimum at unusual --n/--k
    # can't mask the disk==memory acceptance result above.
    assert (d.min(1) < 1.0).all(), "failed to recover mixture centers"
    print("OK")
    return res


if __name__ == "__main__":
    main()
