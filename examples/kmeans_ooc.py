"""Out-of-core k-means at "billion-scale" proportions (scaled to CPU).

    PYTHONPATH=src python examples/kmeans_ooc.py

The paper's MixGaussian-1B experiment in miniature: a mixture-of-Gaussians
dataset that lives on the slow tier (host numpy = the SSD stand-in) is
clustered without ever materializing it on the device tier.  Each Lloyd
iteration is ONE fused streaming pass (distances → argmin → groupby sinks),
and the compiled plan is reused across iterations (plan cache).
"""
import time

import numpy as np

from repro.core import fm
from repro.algorithms import kmeans

rng = np.random.default_rng(42)
k, p = 10, 32
n = 1_000_000                       # paper: 1B rows; CPU example: 1M

print(f"sampling MixGaussian-{n/1e6:.0f}M ({n}x{p}, {n*p*4/2**20:.0f} MiB) "
      "on the out-of-core tier...")
means = rng.normal(size=(k, p)) * 8
X_host = np.empty((n, p), np.float32)
sizes = np.full(k, n // k)
sizes[: n % k] += 1
ofs = 0
for j in range(k):
    X_host[ofs:ofs + sizes[j]] = means[j] + rng.normal(size=(sizes[j], p))
    ofs += sizes[j]
rng.shuffle(X_host)

X = fm.conv_R2FM(X_host, host=True)          # stays on the slow tier

t0 = time.perf_counter()
res = kmeans(X, k=k, max_iter=15, seed=0)
dt = time.perf_counter() - t0

d = np.linalg.norm(res.centers[:, None] - means[None], axis=-1)
print(f"done in {dt:.1f}s ({res.iters} iterations, "
      f"{n * p * 4 * res.iters / dt / 2**30:.2f} GiB/s streamed)")
print(f"wss = {res.wss:.3e}")
print(f"recovered centers within {d.min(1).max():.3f} of truth "
      f"({(d.min(1) < 0.5).sum()}/{k} exact)")
assert (d.min(1) < 1.0).all(), "failed to recover mixture centers"
print("OK")
