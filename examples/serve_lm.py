"""Multi-tenant serving example: concurrent analytics over one SSD matrix.

    PYTHONPATH=src python examples/serve_lm.py

Three tenants submit independent requests against the same named
disk-resident matrix from their own threads.  The engine's admission
window coalesces them onto ONE streaming pass — the disk tier is read
once, and each tenant's ``fm.collect_stats()`` scope still reports its
own plan's share.
"""
import threading

import numpy as np

from repro.core import fm
from repro.core import materialize as mz

X_np = np.random.default_rng(0).normal(size=(50_000, 8)).astype(np.float32)
X = fm.load_dense_matrix(X_np, "served_features")  # SSD-analog tier

mz.reset_exec_stats()
with fm.serve(window_ms=200, max_window_requests=3) as engine:
    barrier = threading.Barrier(3)
    results = {}

    def tenant(name, output):
        with fm.collect_stats(name) as scope:
            barrier.wait()
            value = engine.submit(output).result(timeout=120)
        results[name] = (fm.as_np(value), scope.stats())

    threads = [
        threading.Thread(target=tenant, args=("means", fm.colMeans(X))),
        threading.Thread(target=tenant, args=("sds", fm.colSds(X))),
        threading.Thread(target=tenant, args=("gram", fm.crossprod(X))),
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

st = mz.exec_stats()
print(f"3 tenants -> streams={st['streams']} passes={st['passes']}")
assert st["streams"] == 1  # one shared scan of the disk tier

for name, (value, stats) in sorted(results.items()):
    print(f"  {name}: shape={np.asarray(value).shape} "
          f"streams={stats['streams']} bytes={stats['bytes_streamed']}")

np.testing.assert_allclose(results["means"][0].ravel(), X_np.mean(0),
                           rtol=1e-3, atol=1e-4)
print("parity with numpy: OK")
