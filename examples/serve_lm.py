"""Batched serving example across architecture families (deliverable b).

    PYTHONPATH=src python examples/serve_lm.py

Prefill + greedy decode on three different cache machineries:
  * dense GQA KV cache        (llama family)
  * SSM state + conv window   (mamba2 — O(1) memory per token)
  * hybrid shared-block KV    (zamba2)
"""
from repro.launch import serve

for arch in ("llama3.2-3b", "mamba2-1.3b", "zamba2-7b"):
    print(f"\n=== {arch} (reduced config) ===")
    serve.main(["--arch", arch, "--reduced", "--batch", "4",
                "--prompt-len", "32", "--gen", "12"])
