"""End-to-end LM training driver (deliverable b: the e2e example).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Trains a ~25M-parameter llama-family model (the reduced qwen2-0.5b config
widened back up to a CPU-tractable "real" size) for a few hundred steps on
the synthetic Zipf corpus, with checkpoints, resume, and the full sharded
train step — the same code path the 512-chip dry-run lowers.
"""
import argparse
import dataclasses
import sys

sys.argv = [sys.argv[0]]  # keep sub-arg parsing clean when run via -m

from repro.configs import get_config, reduced_for_smoke
from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args, _ = ap.parse_known_args()

    # a ~25M-param model: reduced family scaled up to be a real (if small) LM
    train.main([
        "--arch", "qwen2-0.5b", "--reduced",
        "--steps", str(args.steps),
        "--batch", "16", "--seq", "256",
        "--ckpt-dir", args.ckpt, "--ckpt-every", "100",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    main()
