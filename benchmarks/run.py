"""Benchmark runner: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig6,fig8] [--skip-slow]

Prints ``name,us_per_call,derived`` CSV rows (repo contract).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--skip-slow", action="store_true",
                    help="skip fig8 device-scaling subprocesses")
    args = ap.parse_args(argv)

    from . import (algorithms_bench, fusion_ablation, kernel_bench,
                   paper_figures, scaling, storage_bench)
    fns = (list(paper_figures.ALL) + list(kernel_bench.ALL)
           + list(fusion_ablation.ALL) + list(storage_bench.ALL)
           + list(algorithms_bench.ALL))
    if not args.skip_slow:
        fns += list(scaling.ALL)
    if args.only:
        keys = args.only.split(",")
        fns = [f for f in fns if any(k in f.__name__ for k in keys)]

    print("name,us_per_call,derived")
    failures = 0
    for fn in fns:
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},nan,ERROR:{type(e).__name__}:{e}",
                  file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
