"""CI perf-regression gate over the BENCH rows (ISSUE 5 satellite).

Runs a small fixed ``fusion_ablation`` + ``algorithms_bench`` grid
in-process, collects the machine-readable ``BENCH {json}`` rows, and
compares them against the committed ``benchmarks/baseline.json``:

* **counters must not drift** — ``passes``, ``passes_over_sources``,
  ``bytes_in``, ``epilogue_launches`` / ``epilogue_launches_per_materialize``,
  ``epilogue_nodes`` and the pallas ``kernels`` list are engine *evidence*
  (how many streaming passes a plan takes, whether the epilogue fused,
  which kernels dispatched); any change is a planner behavior change and
  fails the gate outright;
* **wall time may not regress by more than the gate percentage**
  (default 25%, ``BENCH_GATE_PCT``) after machine-speed normalization: the
  baseline stores a numpy-matmul calibration time, the current machine is
  re-calibrated, and thresholds scale by the speed ratio so a slower CI
  runner does not false-fail.  A per-row absolute slack
  (``BENCH_GATE_SLACK_US``, default 50 ms) keeps sub-millisecond rows out
  of the noise.

Usage:

    PYTHONPATH=src python benchmarks/check_regression.py            # gate
    PYTHONPATH=src python benchmarks/check_regression.py --update   # rebase

``--update`` rewrites baseline.json from the current run — commit the
result together with any intentional counter change (the diff shows the
reviewer exactly which evidence moved).
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import os
import sys
import time

import numpy as np

try:
    from . import algorithms_bench, fusion_ablation
except ImportError:  # direct `python benchmarks/check_regression.py`
    import algorithms_bench
    import fusion_ablation

from repro.launch import serve as serve_loadgen

BASELINE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "baseline.json")

#: The gated grid: small enough for a CI job, large enough to cover every
#: workload × mode × backend cell including the multi-pass scale plan.
#: iters are deliberately ≥3: the rows are milliseconds-scale, so a
#: median over too few samples turns one scheduler/GC hiccup into a
#: false wall-time failure.
FUSION_ARGS = ["--n", "40000", "--pallas-n", "5000", "--iters", "5",
               "--skip-nofuse"]
ALGO_ARGS = ["--n", "12000", "--pallas-n", "3000", "--iters", "3"]
#: The serving load generator (ISSUE 8): serial vs served arms over one
#: named disk matrix.  The arms run with mid-stream admission off and
#: one wave per admission window, so the gated counters are exact.
SERVE_ARGS = ["--n", "40000", "--p", "8", "--clients", "3", "--waves", "2",
              "--partition-kib", "64", "--name", "ci_serve_x"]

#: Engine-evidence fields compared EXACTLY (any drift fails the gate).
#: ``partition_steps`` is deterministic (n and io_partition_bytes are
#: fixed by the grid); the timing-derived telemetry the rows also carry
#: (stream_bandwidth_bytes_s, prefetch_wait_frac, p50/p99 latency) is
#: reported, not gated.  ``streams`` (ISSUE 7) is gated exactly: the
#: batched arm reading its group's sources in ONE streaming drive (vs k
#: serially) is a scheduler contract, not a timing artifact — as are the
#: serve rows' ``bytes_per_request``/``requests`` (ISSUE 8): the served
#: arm's bytes-per-request is serial's divided by the window's client
#: count, or window coalescing has regressed.  ``shards``/``shard_merges``
#: (ISSUE 9) gate the sharded-execution contract: one shard per mesh
#: data-axis device per streamed pass, one combine merge per shard
#: boundary (deterministic on the bench runner's single-device mesh).
COUNTER_KEYS = ("passes", "passes_over_sources", "bytes_in",
                "epilogue_launches", "epilogue_launches_per_materialize",
                "epilogue_nodes", "kernels", "partition_steps", "streams",
                "bytes_per_request", "requests", "shards", "shard_merges")

GATE_PCT = float(os.environ.get("BENCH_GATE_PCT", "25"))
#: Absolute per-row slack: most rows are single-digit milliseconds where
#: 25% is below OS-jitter level — the percentage gate is really for the
#: slow (hundreds of ms+) rows, and the counters catch behavioral drift
#: on the fast ones.
SLACK_US = float(os.environ.get("BENCH_GATE_SLACK_US", "100000"))


def calibrate() -> float:
    """Machine-speed probe: best-of-5 µs for a fixed numpy matmul.  Stored
    in the baseline so thresholds transfer across runner generations."""
    rng = np.random.default_rng(0)
    a = rng.normal(size=(512, 512))
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        float((a @ a).sum())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _row_key(rec: dict) -> str:
    parts = [str(rec.get(k)) for k in ("bench", "workload", "algo", "arm",
                                       "mode", "backend")
             if rec.get(k) is not None]
    return "/".join(parts)


def collect() -> dict:
    """Run the gated grid and return {row_key: BENCH record}."""
    from repro.core import matrix as matrix_mod
    old_io = matrix_mod.IO_PARTITION_BYTES
    buf = io.StringIO()
    try:
        with contextlib.redirect_stdout(buf):
            fusion_ablation.run(FUSION_ARGS)
            algorithms_bench.run(ALGO_ARGS)
            serve_loadgen.run(SERVE_ARGS)
    finally:
        matrix_mod.IO_PARTITION_BYTES = old_io
    rows = {}
    for line in buf.getvalue().splitlines():
        if not line.startswith("BENCH "):
            continue
        rec = json.loads(line[len("BENCH "):])
        rows[_row_key(rec)] = rec
    return rows


def _counters_equal(a, b) -> bool:
    if isinstance(a, float) or isinstance(b, float):
        return abs(float(a) - float(b)) <= 1e-6
    return a == b


def compare(current: dict, cal_us: float, baseline: dict) -> list:
    """Return a list of human-readable failure strings (empty = pass)."""
    failures = []
    base_rows = baseline["rows"]
    # Machine-speed normalization, floored at 1.0: a faster runner must
    # not shrink the budget below the recorded baseline.
    ratio = max(cal_us / max(baseline["calibration_us"], 1e-9), 1.0)
    for key, base in base_rows.items():
        cur = current.get(key)
        if cur is None:
            failures.append(f"{key}: row MISSING from current run")
            continue
        for ck in COUNTER_KEYS:
            if ck not in base:
                continue
            if ck not in cur or not _counters_equal(cur[ck], base[ck]):
                failures.append(
                    f"{key}: counter drift {ck}: baseline={base[ck]!r} "
                    f"current={cur.get(ck)!r}")
        budget = base["us_per_call"] * ratio * (1.0 + GATE_PCT / 100.0) \
            + SLACK_US
        if cur["us_per_call"] > budget:
            failures.append(
                f"{key}: wall-time regression {cur['us_per_call']:.0f}us > "
                f"budget {budget:.0f}us (baseline "
                f"{base['us_per_call']:.0f}us, speed ratio {ratio:.2f}, "
                f"gate {GATE_PCT:.0f}% + {SLACK_US:.0f}us slack)")
    for key in current:
        if key not in base_rows:
            failures.append(
                f"{key}: NEW row not in baseline — rerun with --update and "
                f"commit benchmarks/baseline.json")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="rewrite baseline.json from the current run")
    ap.add_argument("--baseline", default=BASELINE_PATH)
    args = ap.parse_args(argv)

    cal_us = calibrate()
    rows = collect()
    print(f"check_regression: {len(rows)} BENCH rows, "
          f"calibration {cal_us:.0f}us")
    if args.update:
        payload = {
            "calibration_us": round(cal_us, 1),
            "grid": {"fusion_ablation": FUSION_ARGS,
                     "algorithms_bench": ALGO_ARGS,
                     "serve_loadgen": SERVE_ARGS},
            "rows": rows,
        }
        with open(args.baseline, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"baseline written: {args.baseline}")
        return 0

    with open(args.baseline, encoding="utf-8") as fh:
        baseline = json.load(fh)
    grid = {"fusion_ablation": FUSION_ARGS, "algorithms_bench": ALGO_ARGS,
            "serve_loadgen": SERVE_ARGS}
    if baseline.get("grid") != grid:
        print("check_regression: grid definition changed — rerun with "
              "--update and commit the new baseline")
        return 1
    failures = compare(rows, cal_us, baseline)
    if failures:
        print(f"check_regression: FAIL ({len(failures)} finding(s))")
        for f in failures:
            print("  " + f)
        return 1
    print(f"check_regression: OK — {len(baseline['rows'])} rows within "
          f"{GATE_PCT:.0f}% of baseline, no counter drift")
    return 0


if __name__ == "__main__":
    sys.exit(main())
