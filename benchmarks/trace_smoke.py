"""Observability smoke for CI: export a real Chrome trace and explain a plan.

Runs the acceptance scenario — an out-of-core two-pass ``scale(X,
save='disk')`` over a disk-tier matrix — under ``fm.trace(...)``, writes the
Chrome-trace JSON (the bench job uploads it as an artifact), and validates
the span structure:

  * one ``materialize`` span, one ``pass`` span per scheduled pass;
  * per-pass ``partition`` spans with ``stage`` / ``prefetch_wait`` /
    ``device_step`` / ``combine`` activity;
  * the prefetcher's staging thread on its OWN track (thread_name metadata);
  * exactly ONE ``epilogue`` span per pass that schedules one.

Then prints ``fm.explain`` for the same program on both backends (the
explain smoke step).  Exits non-zero if the trace structure is wrong.

    PYTHONPATH=src python benchmarks/trace_smoke.py [--out trace.json]
"""
from __future__ import annotations

import argparse
import collections
import json
import sys
import tempfile


def run(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="trace.json",
                    help="Chrome-trace JSON output path")
    ap.add_argument("--n", type=int, default=16384)
    ap.add_argument("--p", type=int, default=8)
    args = ap.parse_args(argv)

    import numpy as np

    from repro.core import fm
    from repro.core import materialize as mz
    from repro.core import matrix as matrix_mod

    tmp = tempfile.mkdtemp(prefix="fm-trace-smoke-")
    # Small I/O partitions so the run streams several partitions per pass.
    old_io = matrix_mod.IO_PARTITION_BYTES
    fm.set_conf(data_dir=tmp, io_partition_bytes=128 * 1024)
    try:
        rng = np.random.default_rng(0)
        X = fm.load_dense_matrix(
            rng.normal(size=(args.n, args.p)).astype(np.float32), "smoke_x")
        Z = fm.scale(X, save="disk")
        with fm.trace(export=args.out):
            (Zm,) = fm.materialize(Z)
        st = mz.exec_stats()

        doc = json.load(open(args.out, encoding="utf-8"))
        spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
        counts = collections.Counter(e["name"] for e in spans)
        threads = {e["args"]["name"] for e in doc["traceEvents"]
                   if e.get("ph") == "M" and e["name"] == "thread_name"}
        n_passes = st["passes"]
        epi_passes = st["epilogue_launches"]

        failures = []
        if counts["materialize"] != 1:
            failures.append(f"materialize spans: {counts['materialize']}")
        if counts["pass"] != n_passes or n_passes < 2:
            failures.append(
                f"pass spans {counts['pass']} != passes {n_passes} (>=2)")
        if counts["partition"] != st["partition_steps"] \
                or counts["partition"] <= n_passes:
            failures.append(
                f"partition spans {counts['partition']} != partition_steps "
                f"{st['partition_steps']} (or no real streaming)")
        for required in ("stage", "prefetch_wait", "device_step", "combine"):
            if counts[required] == 0:
                failures.append(f"no {required!r} spans recorded")
        if counts["epilogue"] != epi_passes:
            failures.append(f"epilogue spans {counts['epilogue']} != "
                            f"epilogue launches {epi_passes}")
        if "fm-prefetch" not in threads:
            failures.append(f"no prefetch-thread track (threads={threads})")

        print(f"trace_smoke: {len(spans)} spans -> {args.out}")
        print(f"trace_smoke: span counts {dict(counts)}")
        print(f"trace_smoke: thread tracks {sorted(threads)}")
        print()
        plan = fm.scale(X)  # the same two-pass structure, freshly lazy
        print("=== fm.explain (xla) ===")
        print(fm.explain(plan))
        print()
        print("=== fm.explain (pallas) ===")
        print(fm.explain(fm.crossprod(plan), backend="pallas"))
        if failures:
            print("\ntrace_smoke: FAIL")
            for f in failures:
                print("  " + f)
            return 1
        print("\ntrace_smoke: OK")
        return 0
    finally:
        matrix_mod.IO_PARTITION_BYTES = old_io


if __name__ == "__main__":
    sys.exit(run())
