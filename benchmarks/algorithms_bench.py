"""Paper-scale algorithm grid (paper §IV, Fig. 8/10): the "out-of-core
tracks in-memory" experiment over the full suite.

    PYTHONPATH=src python benchmarks/algorithms_bench.py [--n N] [--p P]

Grid: algorithm (glm-logistic / pca / nmf / naive-bayes / kmeans)
      × mode (mem | stream | ooc-disk)
      × backend (xla | pallas),
plus the sparse track (ISSUE 10): glm-sparse — logistic regression on a
one-hot CSR/ELL design matrix — over the same mode × backend grid, with
the pallas cells gated on dispatching the spmm kernels.

Each cell prints TWO lines:

  * the repo-wide ``name,us_per_call,derived`` CSV row, and
  * a machine-readable ``BENCH {json}`` row with the timing plus the
    engine evidence: the iteration Plan's cost counters —
    ``passes`` (scheduled streaming passes: 1 for IRLS/NMF iterations, 2
    for pca's moment→centered-Gram plan) and ``passes_over_sources`` =
    bytes_in / bytes(sources), the proof that a one-pass iteration
    streams X exactly ONCE however many leaves reference it (staging
    dedupe) while the two-pass pca plan honestly reads it twice;
    ``epilogue_nodes`` / ``epilogue_launches_per_materialize`` = the
    post-sink math (the GLM Newton solve, the NB moment division) running
    as ONE on-device epilogue launch inside the same plan — and, for
    pallas cells, the kernels the engine dispatched to (the weighted-gram
    segment must show ``wgram``) with the max abs deviation from the xla
    backend.

On this CPU container the pallas backend runs the interpreter (expect
O(100×) slower rows — correctness evidence, not speed); on TPU the same
rows time Mosaic-compiled kernels.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

try:
    from .common import emit, time_call
except ImportError:  # direct `python benchmarks/algorithms_bench.py`
    from common import emit, time_call


def _make_data(n, p, k, rng):
    X = np.abs(rng.normal(size=(n, p))).astype(np.float32) + 0.1
    beta = rng.normal(size=p)
    pv = 1.0 / (1.0 + np.exp(-(X.astype(np.float64) @ beta / np.sqrt(p))))
    y_bin = (rng.uniform(size=n) < pv).astype(np.float32)
    y_cls = rng.integers(0, k, size=n).astype(np.float32)
    return X, y_bin, y_cls


def _tiered(fm, arr, mode, name):
    """Place an array on the tier a grid mode reads from."""
    if mode == "ooc-disk":
        return fm.load_dense_matrix(arr, name)
    return fm.conv_R2FM(arr)


def _exec_mode(mode):
    return {"mem": "whole", "stream": "stream", "ooc-disk": "auto"}[mode]


def _workloads(fm, k):
    """name -> (run(X, y_bin, y_cls, mode, backend) -> comparable np array,
                iteration_plan(X, y_bin, y_cls) or None)."""
    from repro.algorithms import glm, naive_bayes, nmf, pca
    from repro.algorithms.glm import glm_iteration_plan
    from repro.algorithms.kmeans import kmeans_iteration
    from repro.core.fusion import Plan

    def run_glm(X, yb, yc, mode, backend):
        r = glm(X, yb, family="logistic", max_iter=4, mode=mode,
                backend=backend)
        return r.beta

    def plan_glm(X, yb, yc):
        return glm_iteration_plan(X, yb, np.zeros(X.ncol), "logistic")

    def run_pca(X, yb, yc, mode, backend):
        return pca(X, k=min(4, X.ncol), mode=mode).sdev

    def plan_pca(X, yb, yc):
        # The covariance of the LAZILY centered matrix: a two-pass plan
        # (moment pass → sweep+Gram pass) — what pca() now materializes in
        # one call.
        return Plan([fm.crossprod(fm.scale(X, scale=False)).m])

    def run_nmf(X, yb, yc, mode, backend):
        return np.array([nmf(X, k=k, max_iter=3, seed=0, mode=mode,
                             backend=backend).objective])

    def plan_nmf(X, yb, yc):
        # Pass A of one multiplicative update: both contraction sinks.
        W = fm.conv_R2FM(np.abs(np.random.default_rng(0).normal(
            size=(X.nrow, k))).astype(np.float32))
        return Plan([fm.crossprod(W, X).m, fm.crossprod(W).m])

    def run_nb(X, yb, yc, mode, backend):
        m = naive_bayes(X, yc, k, mode=mode, backend=backend)
        return m.means

    def plan_nb(X, yb, yc):
        # The exact gaussian training DAG (grouped sinks + lazy per-class
        # moment epilogue), from the algorithm's own builder.
        from repro.algorithms.naive_bayes import nb_gaussian_outputs
        return Plan([o.m for o in nb_gaussian_outputs(X, yc, k)])

    def run_kmeans(X, yb, yc, mode, backend):
        C = np.abs(np.random.default_rng(0).normal(
            size=(k, X.ncol))).astype(np.float32)
        newC, _, wss, _ = kmeans_iteration(X, C, mode=mode)
        return newC

    def plan_kmeans(X, yb, yc):
        C = np.abs(np.random.default_rng(0).normal(
            size=(k, X.ncol))).astype(np.float32)
        D = fm.inner_prod(X, C.T, "squared_diff", "sum")
        labels = fm.which_min_row(D)
        return Plan([fm.rowsum(X, labels, k).m, fm.table_(labels, k).m,
                     fm.sum_(fm.rowMins(D)).m, labels.m])

    return {
        "glm-logistic": (run_glm, plan_glm),
        "pca": (run_pca, plan_pca),
        "nmf": (run_nmf, plan_nmf),
        "naive-bayes": (run_nb, plan_nb),
        "kmeans": (run_kmeans, plan_kmeans),
    }


def _sparse_glm_rows(fm, mz, args, on_tpu, rows):
    """The Criteo-shaped track: logistic regression on a one-hot sparse
    design matrix (ISSUE 10).  mem/stream cells read the in-RAM ELL tier,
    ooc-disk reads a CSR .fmat; counters prove the bytes streamed are
    nnz-proportional and (pallas) that the spmm kernels claimed the IRLS
    contractions."""
    import json as _json

    import numpy as np

    from repro.algorithms.glm import glm, glm_iteration_plan

    levels = (24, 16, 8)
    for backend in ("xla", "pallas"):
        n = args.n if (backend == "xla" or on_tpu) else args.pallas_n
        rng = np.random.default_rng(0)
        codes = [rng.integers(0, lv, n) for lv in levels]
        p = sum(levels)
        dense = np.zeros((n, p), np.float32)
        off = np.cumsum([0] + list(levels[:-1]))
        for c, o in zip(codes, off):
            dense[np.arange(n), c + o] = 1.0
        beta = rng.normal(0, 0.5, p)
        pv = 1.0 / (1.0 + np.exp(-(dense.astype(np.float64) @ beta)))
        yb_n = (rng.uniform(size=n) < pv).astype(np.float32)[:, None]
        oracle = None
        for mode in ("mem", "stream", "ooc-disk"):
            X = fm.one_hot(*[fm.as_factor(c, lv)
                             for c, lv in zip(codes, levels)])
            if mode == "ooc-disk":
                X = fm.persist(X, tier="disk",
                               name=f"bench_sparse_x_{backend}")
            yb = _tiered(fm, yb_n, mode, f"bench_sparse_yb_{backend}")
            mz.clear_plan_cache()
            fm.set_conf(backend=backend)
            exec_mode = _exec_mode(mode)

            def work():
                # ridge: a one-hot design is rank-deficient (each factor's
                # columns sum to the ones vector) — unridged Newton
                # diverges.
                return glm(X, yb, family="logistic", max_iter=4,
                           ridge=1e-3, mode=exec_mode,
                           backend=backend).beta

            mz.reset_exec_stats()
            res = np.asarray(work())
            st = mz.exec_stats()
            us = time_call(work, iters=args.iters)
            if oracle is None:
                fm.set_conf(backend="xla")
                oracle = np.asarray(
                    glm(fm.conv_R2FM(dense), yb, family="logistic",
                        max_iter=4, ridge=1e-3, mode="whole",
                        backend="xla").beta)
                fm.set_conf(backend=backend)
            plan = glm_iteration_plan(X, yb, np.zeros(p), "logistic")
            src_bytes = sum(m.nbytes() for _, m in plan.staged_sources())
            err = float(np.max(np.abs(res.astype(np.float64)
                                      - oracle.astype(np.float64))))
            record = {
                "bench": "algorithms",
                "algo": "glm-sparse", "mode": mode, "backend": backend,
                "n": n, "p": p, "us_per_call": round(us, 1),
                # nnz-proportionality evidence: bytes_in counts the CSR/
                # ELL payload, a small fraction of n·p dense bytes.
                "bytes_in": plan.bytes_in(),
                "passes": len(plan.passes),
                "passes_over_sources": round(
                    plan.bytes_in() / max(src_bytes, 1), 3),
                "epilogue_nodes": len(plan.epilogue_nodes),
                "epilogue_launches_per_materialize": round(
                    st["epilogue_launches"]
                    / max(st["materialize_calls"], 1), 3),
                "partition_steps": st["partition_steps"],
                "streams": st["streams"],
                "maxerr_vs_xla_mem": err,
            }
            if backend == "pallas":
                # The dispatch contract: the IRLS weighted-gram and
                # moment contractions must ride the spmm kernels.
                record["kernels"] = sorted(
                    {u.kernel
                     for u in plan.program("pallas").kernel_units})
            print("BENCH " + _json.dumps(record, sort_keys=True))
            rows.append(
                (f"algorithms/glm-sparse/{mode}/{backend}", us,
                 f"passes={record['passes_over_sources']};"
                 f"bytes_in={record['bytes_in']:.2e};"
                 f"maxerr={err:.2e}"))


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--p", type=int, default=16)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--pallas-n", type=int, default=8_000,
                    help="row count for interpret-mode pallas rows (CPU)")
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--partition-mib", type=int, default=4)
    args = ap.parse_args(argv)

    import jax

    from repro.core import fm
    from repro.core import materialize as mz
    from repro.observability import metrics as obs_metrics

    fm.set_conf(io_partition_bytes=args.partition_mib << 20)
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    try:
        for backend in ("xla", "pallas"):
            # Interpret-mode pallas on CPU is a correctness path, not a
            # speed path: shrink the matrix so grid sweeps finish quickly.
            n = args.n if (backend == "xla" or on_tpu) else args.pallas_n
            rng = np.random.default_rng(0)
            Xn, yb_n, yc_n = _make_data(n, args.p, args.k, rng)
            baseline = {}
            for mode in ("mem", "stream", "ooc-disk"):
                X = _tiered(fm, Xn, mode, f"bench_x_{backend}")
                yb = _tiered(fm, yb_n, mode, f"bench_yb_{backend}")
                yc = _tiered(fm, yc_n, mode, f"bench_yc_{backend}")
                for algo, (work, plan_fn) in _workloads(fm, args.k).items():
                    mz.clear_plan_cache()
                    # Route every materialize in the cell (including the
                    # algorithms without a backend parameter) through the
                    # engine-wide backend default.
                    fm.set_conf(backend=backend)
                    exec_mode = _exec_mode(mode)
                    mz.reset_exec_stats()
                    # Scoped I/O telemetry over the measured run: staging
                    # read bandwidth and the fraction of streaming time the
                    # compute thread spent blocked on the prefetch queue
                    # (0.0 for whole-mode cells — nothing streams).
                    with obs_metrics.collect() as obs_scope:
                        res = np.asarray(work(X, yb, yc, exec_mode, backend))
                    obs = obs_scope.stats()
                    st = mz.exec_stats()
                    us = time_call(
                        lambda: work(X, yb, yc, exec_mode, backend),
                        iters=args.iters)
                    plan = plan_fn(X, yb, yc)
                    src_bytes = sum(m.nbytes()
                                    for _, m in plan.staged_sources())
                    record = {
                        "bench": "algorithms",
                        "algo": algo, "mode": mode, "backend": backend,
                        "n": n, "p": args.p, "us_per_call": round(us, 1),
                        # The pass-count proof: one-pass iterations read
                        # each source matrix exactly once (staging dedupe,
                        # bytes_in == bytes(sources)); the two-pass pca
                        # plan honestly reports passes == 2 and
                        # passes_over_sources == 2.0.
                        "bytes_in": plan.bytes_in(),
                        "passes": len(plan.passes),
                        "passes_over_sources": round(
                            plan.bytes_in() / max(src_bytes, 1), 3),
                        "flops": plan.flop_count(),
                        # Epilogue-stage evidence: nodes the iteration plan
                        # evaluates after the merge (the GLM Newton solve,
                        # the NB moment division), and the launches the
                        # measured run actually performed — 1.0 per
                        # materialize = the whole post-sink chain ran as
                        # ONE on-device launch inside the same plan.
                        "epilogue_nodes": len(plan.epilogue_nodes),
                        "epilogue_launches_per_materialize": round(
                            st["epilogue_launches"]
                            / max(st["materialize_calls"], 1), 3),
                        # Two-level-partitioning evidence: how many
                        # I/O-level partition steps the measured run took
                        # (deterministic given n and io_partition_bytes —
                        # gated exactly by check_regression).
                        "partition_steps": st["partition_steps"],
                        # Stream-fusion evidence (ISSUE 7): streaming
                        # drives the measured run performed (0 for mem
                        # cells; with the iteration inspector each driver
                        # iteration is exactly one) and resident final
                        # partitions the next iteration consumed without
                        # a re-read.
                        "streams": st["streams"],
                        "prefetch_reuse_hits": st["prefetch_reuse_hits"],
                        # Measured I/O telemetry (timing-derived: reported,
                        # not gated): slow-tier staging bandwidth and
                        # prefetch-queue wait fraction of the run.
                        "stream_bandwidth_bytes_s": round(
                            obs["stream_bandwidth_bytes_s"], 1),
                        "prefetch_wait_frac": round(
                            obs["prefetch_wait_frac"], 4),
                    }
                    if mode == "mem":
                        # The cell every other mode/backend is judged
                        # against: the xla in-memory run on the SAME data.
                        if backend == "xla":
                            baseline[algo] = res
                        else:
                            fm.set_conf(backend="xla")
                            baseline[algo] = np.asarray(
                                work(X, yb, yc, exec_mode, "xla"))
                            fm.set_conf(backend=backend)
                    if backend == "pallas":
                        record["kernels"] = sorted(
                            {u.kernel
                             for u in plan.program("pallas").kernel_units})
                    err = float(np.max(np.abs(
                        res.astype(np.float64)
                        - baseline[algo].astype(np.float64))))
                    record["maxerr_vs_xla_mem"] = err
                    print("BENCH " + json.dumps(record, sort_keys=True))
                    rows.append(
                        (f"algorithms/{algo}/{mode}/{backend}", us,
                         f"passes={record['passes_over_sources']};"
                         f"bytes_in={record['bytes_in']:.2e};"
                         f"epilogue="
                         f"{record['epilogue_launches_per_materialize']};"
                         f"maxerr={err:.2e}"))
        _sparse_glm_rows(fm, mz, args, on_tpu, rows)
    finally:
        fm.set_conf(backend="auto")
    return emit(rows)


def algorithms_bench():
    """run.py entry: reduced size, restores engine config afterwards."""
    from repro.core import matrix as matrix_mod
    old = matrix_mod.IO_PARTITION_BYTES
    try:
        return run(["--n", "20000", "--pallas-n", "4000", "--iters", "1"])
    finally:
        matrix_mod.IO_PARTITION_BYTES = old


ALL = [algorithms_bench]


if __name__ == "__main__":
    run()
