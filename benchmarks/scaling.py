"""Fig 8: parallel scaling of the engine.

On this container, wall-clock SPMD scaling is NOT measurable: one XLA-CPU
"device" already multithreads across every physical core, so adding host
devices only adds partitioning overhead on a shared pool (measured: ~0.2x
"speedup" - reported honestly rather than massaged).  The paper's Fig 8
claim - work partitions evenly with no replication, sinks merge with one
reduction - is instead verified *structurally*: the same global GenOps
workload (crossprod + colSums over 200k x 64) is lowered and compiled for
1/2/4/8 devices and the loop-aware per-device FLOPs must fall as 1/N with
only O(p^2) reduction traffic.  On real hardware the identical lowering is
what executes, so per-device work proportional to 1/N IS linear scaling.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import emit

_WORKER = textwrap.dedent("""
    import os, sys, json
    n = int(sys.argv[1])
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.hlo_analysis import analyze

    mesh = jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    def work(x):
        z = jnp.abs(x * 2.0 - 1.0)
        return z.T @ z, z.sum(0)

    spec = jax.ShapeDtypeStruct((200_000, 64), jnp.float32)
    sh = NamedSharding(mesh, P("data", None))
    rep = NamedSharding(mesh, P())
    compiled = jax.jit(work, in_shardings=sh,
                       out_shardings=(rep, rep)).lower(spec).compile()
    la = analyze(compiled.as_text())
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    print(json.dumps({"n": n, "flops_per_dev": la["dot_flops"],
                      "coll_bytes": la["collective_bytes_total"],
                      "bytes_accessed": float(ca.get("bytes accessed", 0))}))
""")


def fig8_scaling():
    rows = []
    base = None
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH="src")
    for n in (1, 2, 4, 8):
        proc = subprocess.run([sys.executable, "-c", _WORKER, str(n)],
                              capture_output=True, text=True, env=env,
                              cwd=root, timeout=600)
        if proc.returncode != 0:
            rows.append((f"fig8/devices{n}", float("nan"),
                         f"error:{proc.stderr[-200:]}"))
            continue
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        if base is None:
            base = out["flops_per_dev"]
        eff = base / (n * out["flops_per_dev"]) if out["flops_per_dev"] else 0
        rows.append((f"fig8/devices{n}", out["flops_per_dev"],
                     f"parallel_efficiency={eff:.3f};"
                     f"coll_bytes={out['coll_bytes']:.2e}"))
    return emit(rows)


ALL = [fig8_scaling]
