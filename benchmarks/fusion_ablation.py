"""Fusion/backend ablation: the engine's two-level fusion knobs in a grid.

    PYTHONPATH=src python benchmarks/fusion_ablation.py [--n N] [--p P]

Four paper workloads — the six-statistic summary (apply→agg.col chains),
the Gram contraction (correlation/SVD hot loop), the colMeans/colSds
moment pair (sink + post-sink EPILOGUE math in one plan), and the
standardized Gram ``crossprod(scale(X))`` (the MULTI-PASS planner:
moment pass → sweep+Gram pass in one materialize) — are timed over
every combination of:

    fuse     on | off    off = materialize every DAG node separately (the
                         paper's "MLlib materializes aggregation separately"
                         strawman; out-of-core it roundtrips the host tier)
    mode     whole | ooc whole = device-resident single computation;
                         ooc = host-tier source streamed partition-by-
                         partition through the prefetcher
    backend  xla | pallas  the lowering layer (core/lowering.py): generic
                         trace vs kernels/ dispatch.  On this CPU container
                         the pallas backend runs the *interpreter* — the
                         timings are not meaningful on CPU (expect O(100×)
                         slowdown), the rows demonstrate the engine
                         dispatching to the kernels and the results
                         matching; on TPU the same rows time Mosaic.

Derived columns report the Plan cost counters (FLOPs, bytes in/out), the
EXECUTION counters for the measured cell — ``passes_over_sources`` (bytes
read / bytes of sources: 1.0 = each matrix streamed once) and
``epilogue_launches`` per materialize (1 for fused epilogue plans; the
nofuse arm shows the post-sink math exploding into separate tiny
executions instead) — and, for pallas rows, the kernels the engine
dispatched to plus the max abs deviation from the xla result — the
acceptance check that engine-level kernel lowering matches the generic
trace.

Rows follow the repo-wide ``name,us_per_call,derived`` contract; every
FUSED cell additionally prints a machine-readable ``BENCH {json}`` row
(wall time, ``passes``, ``passes_over_sources``, ``bytes_in``,
``epilogue_launches``, ``streams``, ``prefetch_reuse_hits``) — the grid
benchmarks/check_regression.py gates against the committed baseline in
CI.  A final batched-vs-serial arm (``batch3-*`` rows) runs the same
three requests as three solo materializes vs one ``fm.batch`` over
device / host-RAM / disk tiers: ``streams`` drops k× (gated exactly)
and the slow-tier rows show the wall-time win of the single scan.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

try:
    from .common import emit, pallas_dispatch_info, summary_outs, time_call
except ImportError:  # direct `python benchmarks/fusion_ablation.py`
    from common import emit, pallas_dispatch_info, summary_outs, time_call


def _moment_outs(fm, X):
    """colSums sinks + the /n and sqrt((Σx²−(Σx)²/n)/(n−1)) EPILOGUE
    chains — the post-sink lazy math the engine evaluates once after the
    partition-loop merge.  One definition feeds both the timed workload
    and the plan-counter evidence."""
    return (fm.colMeans(X), fm.colSds(X))


def _workloads(fm):
    return {
        "summary": lambda X, **kw: [
            fm.as_np(o) for o in fm.materialize(*summary_outs(fm, X), **kw)],
        "gram": lambda X, **kw: [
            fm.as_np(fm.materialize(fm.crossprod(X), **kw)[0])],
        "moments": lambda X, **kw: [
            fm.as_np(o)
            for o in fm.materialize(*_moment_outs(fm, X), **kw)],
        # The multi-pass tentpole: ONE materialize schedules the moment
        # pass and the sweep+Gram pass (exec passes == 2).
        "scale": lambda X, **kw: [
            fm.as_np(fm.materialize(fm.crossprod(fm.scale(X)), **kw)[0])],
    }


def _plan_counters(fm, outs):
    from repro.core.fusion import Plan
    plan = Plan([o.m for o in outs])
    src_bytes = max(1, sum(m.nbytes() for _, m in plan.staged_sources()))
    return plan, (f"flops={plan.flop_count():.2e};"
                  f"bytes_in={plan.bytes_in():.2e};"
                  f"bytes_out={plan.bytes_out():.2e};"
                  f"passes_over_sources={plan.bytes_in() / src_bytes:.3f}")


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--p", type=int, default=16)
    ap.add_argument("--pallas-n", type=int, default=20_000,
                    help="row count for interpret-mode pallas rows (CPU)")
    ap.add_argument("--partition-mib", type=int, default=4)
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--skip-nofuse", action="store_true",
                    help="fused cells only (the BENCH grid the CI "
                         "regression gate measures — the eager arm is an "
                         "ablation, not a gated surface)")
    args = ap.parse_args(argv)

    import jax

    from repro.core import fm
    from repro.core import materialize as mz
    from repro.observability import metrics as obs_metrics

    fm.set_conf(io_partition_bytes=args.partition_mib << 20)
    on_tpu = jax.default_backend() == "tpu"

    rng = np.random.default_rng(0)
    rows = []
    for backend in ("xla", "pallas"):
        # Interpret-mode pallas on CPU is a correctness path, not a speed
        # path: shrink the matrix so the grid sweep finishes quickly.
        n = args.n if (backend == "xla" or on_tpu) else args.pallas_n
        X_np = rng.normal(size=(n, args.p)).astype(np.float32)
        X_dev = fm.conv_R2FM(X_np)
        X_ram = fm.conv_R2FM(X_np, host=True)
        for wname, work in _workloads(fm).items():
            for mode, X in (("whole", X_dev), ("ooc", X_ram)):
                for fuse in ((True,) if args.skip_nofuse else (True, False)):
                    mz.clear_plan_cache()
                    kw = dict(mode=mode, fuse=fuse, backend=backend)
                    mz.reset_exec_stats()
                    res = work(X, **kw)
                    st = mz.exec_stats()
                    us = time_call(lambda: work(X, **kw), iters=args.iters)
                    # Execution evidence for ONE materialize of this cell:
                    # a fused epilogue plan launches exactly once; the
                    # nofuse arm materializes every post-sink node as its
                    # own tiny execution (partition_steps balloons).
                    derived = (f"epilogue_launches={st['epilogue_launches']};"
                               f"partition_steps={st['partition_steps']}")
                    if fuse:
                        outs = (summary_outs(fm, X) if wname == "summary"
                                else _moment_outs(fm, X)
                                if wname == "moments"
                                else (fm.crossprod(fm.scale(X)),)
                                if wname == "scale"
                                else (fm.crossprod(X),))
                        plan, counters = _plan_counters(fm, outs)
                        derived = counters + ";" + derived
                        src_bytes = max(1, sum(
                            m.nbytes() for _, m in plan.staged_sources()))
                        record = {
                            "bench": "fusion", "workload": wname,
                            "mode": mode, "backend": backend,
                            "n": n, "p": args.p,
                            "us_per_call": round(us, 1),
                            "bytes_in": plan.bytes_in(),
                            "passes": len(plan.passes),
                            "passes_over_sources": round(
                                plan.bytes_in() / src_bytes, 3),
                            "epilogue_launches": round(
                                st["epilogue_launches"]
                                / max(st["materialize_calls"], 1), 3),
                            # Stream-fusion evidence (ISSUE 7): streaming
                            # drives this cell's measured run performed
                            # (0 for whole-mode cells) and resident final
                            # partitions served without a re-read.
                            "streams": st["streams"],
                            "prefetch_reuse_hits":
                                st["prefetch_reuse_hits"],
                        }
                        if backend == "pallas":
                            # Acceptance check: engine-level kernel lowering
                            # matches the generic trace on the same data.
                            ref = work(X, mode=mode, fuse=True,
                                       backend="xla")
                            derived += ";" + pallas_dispatch_info(
                                plan, res, ref)
                            record["kernels"] = sorted(
                                {u.kernel for u in
                                 plan.program("pallas").kernel_units})
                        print("BENCH " + json.dumps(record, sort_keys=True))
                    rows.append(
                        (f"fusion/{wname}/{mode}/"
                         f"{'fuse' if fuse else 'nofuse'}/{backend}",
                         us, derived))

    # ------------------------------------------------------------------
    # Batched vs serial arm (cross-materialize stream fusion): the SAME
    # three independent requests — colMeans, colSds, crossprod — run as
    # three solo materializes (k streams over X) vs one ``fm.batch``
    # (k plans × 1 stream).  `streams` is the counter-gated proof; the
    # ooc / ooc-disk rows are the wall-time proof the one-scan schedule
    # wins where the source actually lives on a slow tier.
    X_np = rng.normal(size=(args.n, args.p)).astype(np.float32)
    batch_tiers = (
        ("whole", fm.conv_R2FM(X_np), "whole"),
        ("ooc", fm.conv_R2FM(X_np, host=True), "ooc"),
        ("ooc-disk", fm.load_dense_matrix(X_np, "ablation_batch_x"),
         "auto"),
    )
    for mode, X, exec_mode in batch_tiers:
        for arm in ("serial", "batched"):
            def work(X=X, exec_mode=exec_mode, arm=arm):
                reqs = (fm.colMeans(X), fm.colSds(X), fm.crossprod(X))
                if arm == "batched":
                    return [fm.as_np(r)
                            for r in fm.batch(*reqs, mode=exec_mode)]
                return [fm.as_np(fm.materialize(r, mode=exec_mode)[0])
                        for r in reqs]
            mz.clear_plan_cache()
            mz.reset_exec_stats()
            work()
            st = mz.exec_stats()
            streamed = int(obs_metrics.root_counter("bytes_streamed"))
            us = time_call(work, iters=args.iters)
            record = {
                "bench": "fusion", "workload": f"batch3-{arm}",
                "mode": mode, "backend": "xla",
                "n": args.n, "p": args.p,
                "us_per_call": round(us, 1),
                "streams": st["streams"],
                "passes": st["passes"],
                "prefetch_reuse_hits": st["prefetch_reuse_hits"],
                "bytes_streamed": streamed,
            }
            print("BENCH " + json.dumps(record, sort_keys=True))
            rows.append((f"fusion/batch3/{mode}/{arm}/xla", us,
                         f"streams={st['streams']};"
                         f"passes={st['passes']};"
                         f"bytes_streamed={streamed:.2e}"))

    # ------------------------------------------------------------------
    # Sharded arm (sharded multi-device execution): the standardized-Gram
    # multi-pass workload streamed single-device vs with the partition
    # loop split across the default host mesh (`materialize(mesh=...)`).
    # `shards` / `shard_merges` are the counter-gated proof: one shard
    # per mesh data-axis device per streamed pass, one combine merge per
    # shard boundary.  On the single-device CI bench runner the mesh has
    # one data shard, so the gated counters are deterministic; under
    # XLA_FLAGS=--xla_force_host_platform_device_count=8 the same rows
    # show the 8-way split.
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    shard_tiers = (
        ("ooc", fm.conv_R2FM(X_np, host=True)),
        ("ooc-disk", fm.load_dense_matrix(X_np, "ablation_shard_x")),
    )
    for mode, X in shard_tiers:
        for arm, kw in (("single", {}), ("sharded", {"mesh": mesh})):
            def work(X=X, kw=kw):
                return fm.as_np(
                    fm.materialize(fm.crossprod(fm.scale(X)),
                                   mode="stream", **kw)[0])
            mz.clear_plan_cache()
            mz.reset_exec_stats()
            work()
            st = mz.exec_stats()
            us = time_call(work, iters=args.iters)
            record = {
                "bench": "fusion", "workload": f"scale-{arm}",
                "mode": mode, "backend": "xla",
                "n": args.n, "p": args.p,
                "us_per_call": round(us, 1),
                "passes": st["passes"],
                "streams": st["streams"],
                "shards": st["shards"],
                "shard_merges": st["shard_merges"],
            }
            print("BENCH " + json.dumps(record, sort_keys=True))
            rows.append((f"fusion/scale-shard/{mode}/{arm}/xla", us,
                         f"shards={st['shards']};"
                         f"shard_merges={st['shard_merges']};"
                         f"streams={st['streams']}"))
    return emit(rows)


def fusion_ablation():
    """run.py entry: reduced size, restores engine config afterwards."""
    from repro.core import matrix as matrix_mod
    old = matrix_mod.IO_PARTITION_BYTES
    try:
        return run(["--n", "100000", "--pallas-n", "8000", "--iters", "2"])
    finally:
        matrix_mod.IO_PARTITION_BYTES = old


ALL = [fusion_ablation]


if __name__ == "__main__":
    run()
