"""Storage-tier benchmark: the paper's "out-of-core tracks in-memory" figure
against a real slow tier.

    PYTHONPATH=src python benchmarks/storage_bench.py [--n N] [--p P]

One fused analytics pass (Gram matrix + column sums — the correlation
workload, O(n·p²) FLOPs on O(n·p) bytes) is timed in every execution mode:

    whole            device-resident, one XLA computation
    stream           device-resident, explicit I/O-partition loop
    ooc-ram          host numpy source, streamed host→device
    ooc-ram-nopf     ... with the async prefetcher disabled
    ooc-disk         MmapStore source (the on-disk matrix format)
    ooc-disk-nopf    ... with the async prefetcher disabled

The ooc-disk vs ooc-disk-nopf pair is the paper's I/O/compute-overlap
ablation: prefetch-on stages partition i+1 (disk read + H2D copy) on a
background thread while partition i computes.  Interpretation caveat for
this CPU container: the matrix file usually sits in the page cache and the
XLA CPU "device" already saturates every core, so there is no I/O latency
to hide and the staging thread can only add contention — expect parity or
a small overhead here, and the actual win on a machine where the slow tier
has real latency (SSD cold reads, network storage) and the device computes
without stealing host cores.

Rows follow the repo-wide ``name,us_per_call,derived`` contract; derived is
the streamed bandwidth in GiB/s.
"""
import argparse
import sys
import tempfile

import numpy as np

try:
    from .common import emit, time_call
except ImportError:  # direct `python benchmarks/storage_bench.py` invocation
    from common import emit, time_call


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=400_000)
    ap.add_argument("--p", type=int, default=32)
    ap.add_argument("--partition-mib", type=int, default=4)
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args(argv)

    from repro.core import fm

    tmp = tempfile.TemporaryDirectory(prefix="fm-bench-")  # removed at exit
    fm.set_conf(data_dir=tmp.name, io_partition_bytes=args.partition_mib << 20)

    n, p = args.n, args.p
    nbytes = n * p * 4
    rng = np.random.default_rng(0)
    X_np = rng.normal(size=(n, p)).astype(np.float32)

    X_dev = fm.conv_R2FM(X_np)
    X_ram = fm.conv_R2FM(X_np, host=True)
    X_disk = fm.load_dense_matrix(X_np, "bench")
    print(f"# {n}x{p} f32 = {nbytes / 2**20:.0f} MiB, partition budget "
          f"{args.partition_mib} MiB", file=sys.stderr)

    def scan(X, **kw):
        G = fm.crossprod(X)
        s = fm.colSums(X)
        Gm, sm = fm.materialize(G, s, **kw)
        return fm.as_np(Gm)

    variants = [
        ("storage/whole", X_dev, {"mode": "whole"}, False),
        ("storage/stream", X_dev, {"mode": "stream"}, False),
        ("storage/ooc-ram", X_ram, {"mode": "ooc", "prefetch": True}, False),
        ("storage/ooc-ram-nopf", X_ram, {"mode": "ooc", "prefetch": False},
         False),
        ("storage/ooc-disk", X_disk, {"mode": "ooc", "prefetch": True}, False),
        ("storage/ooc-disk-nopf", X_disk, {"mode": "ooc", "prefetch": False},
         False),
        # Cold-read arms: direct_io drops each partition's pages after the
        # read (posix_fadvise DONTNEED), so every pass re-reads from the
        # device — the prefetch-overlap measurement the warm page cache
        # hides on this container.
        ("storage/ooc-disk-cold", X_disk,
         {"mode": "ooc", "prefetch": True}, True),
        ("storage/ooc-disk-cold-nopf", X_disk,
         {"mode": "ooc", "prefetch": False}, True),
    ]

    rows = []
    baseline = None
    for name, X, kw, direct_io in variants:
        fm.set_conf(direct_io=direct_io)
        try:
            us = time_call(scan, X, iters=args.iters, **kw)
        finally:
            fm.set_conf(direct_io=False)
        gibps = nbytes / (us * 1e-6) / 2**30
        rows.append((name, us, f"{gibps:.2f}GiB/s"))
        if name == "storage/whole":
            baseline = us
    emit(rows)
    disk_pf = next(us for nm, us, _ in rows if nm == "storage/ooc-disk")
    disk_np = next(us for nm, us, _ in rows if nm == "storage/ooc-disk-nopf")
    print(f"# ooc-disk is {disk_pf / baseline:.2f}x whole;"
          f" prefetch saves {(disk_np - disk_pf) / disk_np * 100:.0f}% "
          f"({disk_np:.0f}us -> {disk_pf:.0f}us)", file=sys.stderr)
    return rows


def storage_tiers():
    """run.py entry: a quick pass at reduced size.  Restores the engine
    config afterwards so later benchmarks keep the default partition
    budget."""
    from repro.core import matrix as matrix_mod
    from repro import storage
    old_budget = matrix_mod.IO_PARTITION_BYTES
    old_dir = storage.registry._CONF["data_dir"]
    try:
        return run(["--n", "200000", "--iters", "2"])
    finally:
        matrix_mod.IO_PARTITION_BYTES = old_budget
        storage.registry._CONF["data_dir"] = old_dir


ALL = [storage_tiers]


if __name__ == "__main__":
    run()
