"""Benchmark harness helpers: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (the repo-wide
contract) and returns them for benchmarks/run.py aggregation.
"""
from __future__ import annotations

import time

import jax
import numpy as np


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall time (µs) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows
