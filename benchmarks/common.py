"""Benchmark harness helpers: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (the repo-wide
contract) and returns them for benchmarks/run.py aggregation.
"""
from __future__ import annotations

import time

import jax
import numpy as np


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall time (µs) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


def summary_outs(fm, X):
    """The paper's six-statistic summary DAG — the shared apply→agg.col
    workload of kernel_bench.engine_dispatch and fusion_ablation."""
    return (fm.colSums(X), fm.colSums(fm.abs_(X)), fm.colSums(X ** 2),
            fm.colMins(X), fm.colMaxs(X), fm.agg_col(X, "count_nonzero"))


def pallas_dispatch_info(plan, results, reference) -> str:
    """Derived-column fragment naming the kernels the pallas backend
    dispatched to plus the max abs deviation from the reference results —
    the engine-level acceptance check both benchmarks report."""
    kernels = sorted({u.kernel for u in plan.program("pallas").kernel_units})
    err = max(float(np.abs(np.asarray(a, np.float64)
                           - np.asarray(b, np.float64)).max())
              for a, b in zip(results, reference))
    return f"kernels={'+'.join(kernels)};maxerr={err:.2e}"
