"""Pallas kernels vs their jnp oracles (XLA-fused) — wall time on CPU is
interpret-mode (not meaningful); what matters here is correctness parity
and the FLOP counts used by the roofline. On TPU the same harness times
Mosaic-compiled kernels.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from .common import emit, pallas_dispatch_info, summary_outs, time_call

RNG = np.random.default_rng(0)


def kernels():
    rows = []
    x = jnp.asarray(RNG.normal(size=(4096, 16)), jnp.float32)
    c = jnp.asarray(RNG.normal(size=(8, 16)), jnp.float32)

    t = time_call(lambda: ref.fused_summary_ref(x), iters=2)
    rows.append(("kern/fused_summary/xla_ref", t,
                 f"flops={4096*16*6:.2e}"))
    t = time_call(lambda: ref.gram_ref(x), iters=2)
    rows.append(("kern/gram/xla_ref", t, f"flops={2*4096*16*16:.2e}"))
    t = time_call(lambda: ref.kmeans_assign_ref(x, c), iters=2)
    rows.append(("kern/kmeans_assign/xla_ref", t,
                 f"flops={2*4096*16*8:.2e}"))
    q = jnp.asarray(RNG.normal(size=(4, 256, 64)), jnp.float32)
    t = time_call(lambda: ref.attention_ref(q, q, q), iters=2)
    rows.append(("kern/attention/xla_ref", t,
                 f"flops={4*4*256*256*64:.2e}"))
    # interpret-mode parity check (correctness, not speed)
    o = ops.gram(x, block_rows=512)
    err = float(jnp.abs(o - ref.gram_ref(x)).max())
    rows.append(("kern/gram/pallas_interpret_maxerr", err, "parity"))
    return emit(rows)


def engine_dispatch():
    """The same kernels reached THROUGH the engine (materialize → plan IR →
    lowering), not standalone calls: each row names the kernels the pallas
    backend dispatched to and the max abs deviation from the xla backend."""
    from repro.core import fm
    from repro.core.fusion import Plan

    rng = np.random.default_rng(0)
    A = rng.normal(size=(4096, 16)).astype(np.float32)
    X = fm.conv_R2FM(A)
    wv = fm.conv_R2FM(np.abs(rng.normal(size=4096)).astype(np.float32))
    C = rng.normal(size=(8, 16)).astype(np.float32)

    def lloyd_outs():
        D = fm.inner_prod(X, C.T, "squared_diff", "sum")
        labels = fm.which_min_row(D)
        return (fm.rowsum(X, labels, 8), fm.table_(labels, 8),
                fm.sum_(fm.rowMins(D)), labels)

    def wgram_outs():
        # The IRLS XᵀWX segment (algorithms/glm.py) — must show 'wgram'.
        return (fm.crossprod(fm.mapply_col(X, wv, "mul"), X),)

    rows = []
    for name, outs_fn in (("summary", lambda: summary_outs(fm, X)),
                          ("gram", lambda: (fm.crossprod(X),)),
                          ("wgram", wgram_outs),
                          ("kmeans", lloyd_outs)):
        plan = Plan([o.m for o in outs_fn()])
        t = time_call(lambda: fm.materialize(*outs_fn(), backend="pallas"),
                      iters=2)
        px = [fm.as_np(o) for o in fm.materialize(*outs_fn(),
                                                  backend="pallas")]
        xx = [fm.as_np(o) for o in fm.materialize(*outs_fn(), backend="xla")]
        rows.append((f"kern/engine/{name}/pallas", t,
                     pallas_dispatch_info(plan, px, xx)))
    return emit(rows)


ALL = [kernels, engine_dispatch]
