"""One benchmark per paper table/figure (deliverable d).

The paper's hardware (48-core NUMA + 24-SSD array) is absent, so each
figure is reproduced as the *relative* experiment it actually argues:

  Table IV  — measured FLOP/byte counters vs the analytic complexity table.
  Fig 6     — fused GenOps engine vs eager per-op materialization
              (the MLlib-style strawman) on the same algorithms.
  Fig 7     — single-thread FlashMatrix-in-JAX vs numpy (R's C/FORTRAN
              stand-in) per algorithm.
  Fig 8     — thread/device scaling (subprocess with N host devices).
  Fig 9     — out-of-core vs in-memory ratio as n_cols grows (random-65M
              scaled to CPU: 200k rows).
  Fig 10    — out-of-core vs in-memory ratio as k grows (kmeans/gmm).
  Fig 11    — memory-optimization ablation: eager / fused-unstreamed /
              fused-streamed (mem-alloc → mem-fuse → cache-fuse).
  Fig 12    — VUDF ablation: per-element python VUDF loop vs vectorized.

Each function returns [(name, us_per_call, derived), ...].
"""
from __future__ import annotations

import numpy as np

from repro.core import fm
from repro.algorithms import correlation, gmm, kmeans, summary, svd_tall
from repro.algorithms.kmeans import kmeans_iteration, _init_centers

from .common import emit, time_call

RNG = np.random.default_rng(0)
N_ROWS = 120_000        # "65M rows" scaled to CPU wall-clock budgets
N_COLS = 16


def _data(n=N_ROWS, p=N_COLS, host=False):
    X = RNG.normal(size=(n, p)).astype(np.float32)
    return X, fm.conv_R2FM(X, host=host)


def table4_complexity():
    """Measured plan counters vs Table IV complexity formulas."""
    from repro.core.fusion import Plan
    rows = []
    Xn, X = _data()
    n, p = Xn.shape
    k = 10
    cases = {
        "summary": ([fm.colSums(X), fm.colSums(X ** 2), fm.colMins(X)],
                    n * p),
        "correlation": ([fm.crossprod(X)], n * p * p),
        "kmeans_iter": (None, n * p * k),
    }
    for name, (outs, comp) in cases.items():
        if name == "kmeans_iter":
            C = _init_centers(X, k, 0)
            D = fm.inner_prod(X, C.T, "squared_diff", "sum")
            outs = [fm.rowsum(X, fm.which_min_row(D), k)]
        plan = Plan([o.m for o in outs])
        rows.append((f"table4/{name}/flops", plan.flop_count(),
                     f"analytic={comp:.3e};io={plan.bytes_in():.3e}"))
    return emit(rows)


def fig6_vs_unfused():
    """Fused engine vs eager per-op materialization (MLlib stand-in)."""
    rows = []
    Xn, X = _data()
    algos = {
        "summary": lambda fuse: summary(X, fuse=fuse),
        "correlation": lambda fuse: correlation(X, fuse=fuse),
        "svd": lambda fuse: svd_tall(X, k=8, fuse=fuse),
        "kmeans(3it)": lambda fuse: kmeans(X, k=8, max_iter=3, fuse=fuse),
        "gmm(2it)": lambda fuse: gmm(X, k=4, max_iter=2, fuse=fuse),
    }
    for name, f in algos.items():
        fused = time_call(f, True, warmup=1, iters=2)
        eager = time_call(f, False, warmup=1, iters=2)
        rows.append((f"fig6/{name}/fused", fused, f"speedup={eager/fused:.2f}x"))
        rows.append((f"fig6/{name}/eager", eager, "baseline"))
    return emit(rows)


def fig7_vs_numpy():
    """Single-thread engine vs numpy reference implementations."""
    rows = []
    Xn, X = _data()
    k = 8

    def np_summary():
        return (Xn.min(0), Xn.max(0), Xn.mean(0), np.abs(Xn).sum(0),
                (Xn ** 2).sum(0), (Xn != 0).sum(0), Xn.var(0))

    def np_corr():
        return np.corrcoef(Xn.T)

    def np_kmeans_iter(C):
        d = ((Xn[:, None] - C[None]) ** 2).sum(-1)
        lab = d.argmin(1)
        s = np.zeros_like(C)
        np.add.at(s, lab, Xn)
        return s

    C = _init_centers(X, k, 0)
    cases = {
        "summary": (lambda: summary(X), np_summary),
        "correlation": (lambda: correlation(X), np_corr),
        "svd": (lambda: svd_tall(X, k=8),
                lambda: np.linalg.svd(Xn, compute_uv=False)),
        "kmeans_iter": (lambda: kmeans_iteration(X, C), lambda: np_kmeans_iter(C)),
    }
    for name, (ours, ref) in cases.items():
        t_fm = time_call(ours, warmup=1, iters=2)
        t_np = time_call(ref, warmup=1, iters=2)
        rows.append((f"fig7/{name}/flashmatrix", t_fm,
                     f"vs_numpy={t_np/t_fm:.2f}x"))
        rows.append((f"fig7/{name}/numpy", t_np, "reference"))
    return emit(rows)


def fig9_feature_scaling():
    """OOC/IM ratio vs feature count (paper: approaches 1 as p grows)."""
    rows = []
    for p in (8, 32, 128):
        Xn = RNG.normal(size=(60_000, p)).astype(np.float32)
        Xd = fm.conv_R2FM(Xn)
        Xh = fm.conv_R2FM(Xn, host=True)
        t_im = time_call(lambda: correlation(Xd), warmup=1, iters=2)
        t_em = time_call(lambda: correlation(Xh), warmup=1, iters=2)
        rows.append((f"fig9/corr/p{p}/ooc", t_em, f"im_ratio={t_im/t_em:.3f}"))
    return emit(rows)


def fig10_cluster_scaling():
    """OOC/IM ratio vs cluster count."""
    rows = []
    Xn = RNG.normal(size=(60_000, 16)).astype(np.float32)
    Xd, Xh = fm.conv_R2FM(Xn), fm.conv_R2FM(Xn, host=True)
    for k in (2, 8, 32):
        C = _init_centers(Xd, k, 0)
        t_im = time_call(lambda: kmeans_iteration(Xd, C), warmup=1, iters=2)
        t_em = time_call(lambda: kmeans_iteration(Xh, C), warmup=1, iters=2)
        rows.append((f"fig10/kmeans/k{k}/ooc", t_em,
                     f"im_ratio={t_im/t_em:.3f}"))
    return emit(rows)


def fig11_memory_opts():
    """mem-alloc / mem-fuse / cache-fuse ablation on the OOC tier.

    eager+host-roundtrip (no fusion)  -> 'none'
    fused but partition-streamed with donation off -> 'mem-fuse'
    fused + streamed + donated buffers -> '+cache-fuse/recycle' (default)
    """
    rows = []
    Xn = RNG.normal(size=(80_000, 16)).astype(np.float32)
    Xh = fm.conv_R2FM(Xn, host=True)

    def run(fuse, donate):
        s = fm.colSums(fm.abs_(Xh * 2.0 - 1.0))
        g = fm.crossprod(Xh * 2.0 - 1.0)
        return fm.materialize(s, g, fuse=fuse, donate=donate)

    t_none = time_call(lambda: run(False, False), warmup=1, iters=2)
    t_fuse = time_call(lambda: run(True, False), warmup=1, iters=2)
    t_full = time_call(lambda: run(True, True), warmup=1, iters=2)
    rows.append(("fig11/none", t_none, "baseline"))
    rows.append(("fig11/mem-fuse", t_fuse, f"speedup={t_none/t_fuse:.2f}x"))
    rows.append(("fig11/cache-fuse+recycle", t_full,
                 f"speedup={t_none/t_full:.2f}x"))
    return emit(rows)


def fig12_vudf():
    """VUDF ablation: the paper's per-element function-call overhead,
    with a Python loop as the unvectorized extreme; vectorized VUDFs are the engine default."""
    rows = []
    Xn = RNG.normal(size=(20_000, 8)).astype(np.float32)
    X = fm.conv_R2FM(Xn)

    t_vec = time_call(lambda: fm.materialize(fm.colSums(X ** 2)), warmup=1,
                      iters=2)
    # per-element emulation (tiny sample, extrapolated)
    sample = Xn[:2000]

    def per_element():
        acc = np.zeros(sample.shape[1])
        sq = lambda v: v * v
        for i in range(sample.shape[0]):
            for j in range(sample.shape[1]):
                acc[j] += sq(sample[i, j])
        return acc

    t_elem = time_call(per_element, warmup=0, iters=1)
    t_elem_full = t_elem * (Xn.shape[0] / sample.shape[0])
    rows.append(("fig12/vudf-vectorized", t_vec,
                 f"speedup={t_elem_full/t_vec:.1f}x"))
    rows.append(("fig12/per-element(extrapolated)", t_elem_full, "baseline"))
    return emit(rows)


ALL = [table4_complexity, fig6_vs_unfused, fig7_vs_numpy,
       fig9_feature_scaling, fig10_cluster_scaling, fig11_memory_opts,
       fig12_vudf]
