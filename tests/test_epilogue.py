"""Plan EPILOGUE stage tests (ISSUE 4 tentpole).

Contract under test: post-sink lazy math — ``colSums(X)/n``,
``sqrt(ss/n − mean²)``, ``solve(XᵀWX, XᵀWz)`` — executes INSIDE the same
plan as the sinks it consumes: one streaming pass over the sources, one
on-device epilogue launch after the partial merge, one plan-cache entry,
identical results on every backend × mode cell.
"""
import numpy as np
import pytest

from helpers_cache import assert_activity, cache_activity
from repro.core import fm
from repro.core import materialize as mz
from repro.core.fusion import Plan

RNG = np.random.default_rng(3)


def _x(n=600, p=5):
    return (RNG.normal(size=(n, p)) * 2 + 0.5).astype(np.float32)


@pytest.fixture(autouse=True)
def _small_partitions():
    """Make streams multi-partition so the merge actually merges."""
    from repro.core import matrix as matrix_mod
    old = matrix_mod.IO_PARTITION_BYTES
    fm.set_conf(io_partition_bytes=4096)
    mz.clear_plan_cache()
    yield
    matrix_mod.IO_PARTITION_BYTES = old
    mz.clear_plan_cache()


# ---------------------------------------------------------------------------
# The regression the ISSUE names: a DAG whose ONLY output is a sink-consumer
# ---------------------------------------------------------------------------

def test_sink_consumer_only_output_materializes():
    """fm.materialize on a bare sink-consumer used to raise from the eager
    small-tier workaround path; it now routes through the epilogue."""
    a = _x()
    X = fm.conv_R2FM(a)
    (m,) = fm.materialize(fm.colSums(X) / float(X.nrow))
    np.testing.assert_allclose(fm.as_np(m).reshape(-1), a.mean(0), rtol=1e-5)


@pytest.mark.parametrize("mode", ["whole", "stream"])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_colmeans_colsds_one_plan_one_epilogue(mode, backend):
    """colMeans + colSds co-materialize: ONE pass over X, ONE epilogue
    launch, parity with numpy — the ISSUE acceptance counters."""
    a = _x()
    X = fm.conv_R2FM(a)
    mu, sd = fm.colMeans(X), fm.colSds(X)
    plan = Plan([mu.m, sd.m])
    # Static one-pass proof: bytes_in counts each physical source once.
    assert plan.bytes_in() == X.m.nbytes()
    assert [s.kind for s in plan.ir.segments].count("epilogue") == 1
    with cache_activity() as act:
        mu_m, sd_m = fm.materialize(mu, sd, mode=mode, backend=backend)
    assert_activity(act, misses=1, hits=0, epilogue_launches=1,
                    materialize_calls=1)
    np.testing.assert_allclose(fm.as_np(mu_m).reshape(-1), a.mean(0),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(fm.as_np(sd_m).reshape(-1),
                               a.std(0, ddof=1), rtol=1e-3)


def test_epilogue_rides_kernel_lowering():
    """The pallas backend still claims the sink chains below an epilogue
    (fused_apply_agg); the epilogue itself is never a kernel unit."""
    X = fm.conv_R2FM(_x())
    plan = Plan([fm.colMeans(X).m, fm.colSds(X).m])
    prog = plan.program("pallas")
    assert [u.kernel for u in prog.kernel_units] == ["fused_apply_agg"]
    assert prog.epilogue is not None


# ---------------------------------------------------------------------------
# The IRLS shape: sinks + epilogue solve in one plan
# ---------------------------------------------------------------------------

def test_glm_style_solve_in_plan():
    a = _x(800, 4)
    wv = np.abs(RNG.normal(size=(800,))).astype(np.float32) + 0.1
    zv = RNG.normal(size=(800, 1)).astype(np.float32)
    X, w, z = fm.conv_R2FM(a), fm.conv_R2FM(wv), fm.conv_R2FM(zv)
    XtWX = fm.crossprod(fm.mapply_col(X, w, "mul"), X)
    XtWz = fm.crossprod(X, w * z)
    beta = fm.solve(XtWX, XtWz)
    assert beta.is_virtual  # lazy: nothing computed yet
    plan = Plan([beta.m])
    assert plan.bytes_in() == X.m.nbytes() + w.m.nbytes() + z.m.nbytes()
    assert "wgram" in [u.kernel for u in plan.program("pallas").kernel_units]
    with cache_activity() as act:
        (b_m,) = fm.materialize(beta, mode="stream")
    assert_activity(act, epilogue_launches=1, materialize_calls=1)
    A = (a * wv[:, None]).T.astype(np.float64) @ a
    rhs = a.T.astype(np.float64) @ (wv[:, None] * zv)
    np.testing.assert_allclose(fm.as_np(b_m), np.linalg.solve(A, rhs),
                               rtol=1e-4, atol=1e-5)


def test_solve_inverse_and_physical_operands():
    """solve(A) with a virtual Gram sink → epilogue inverse; physical
    operands keep the eager float64 path (non-virtual result)."""
    a = _x(300, 4)
    X = fm.conv_R2FM(a)
    (inv_m,) = fm.materialize(fm.solve(fm.crossprod(X)))
    G = a.T.astype(np.float64) @ a
    np.testing.assert_allclose(fm.as_np(inv_m), np.linalg.inv(G),
                               rtol=1e-3, atol=1e-6)
    A = (G + 10 * np.eye(4)).astype(np.float32)
    eager = fm.solve(fm.conv_R2FM(A))
    assert not eager.is_virtual
    np.testing.assert_allclose(fm.as_np(eager), np.linalg.inv(A), rtol=1e-4)


def test_solve_rhs_shapes():
    """A (1, n) vector sink is accepted as a one-column RHS; a (k, n)
    matrix is NOT silently truncated to a vector (shape-corruption
    regression)."""
    a = _x(300, 4)
    X = fm.conv_R2FM(a)
    A = fm.crossprod(X)
    (x1,) = fm.materialize(fm.solve(A, fm.colSums(X)))  # (1, 4) sink RHS
    G = a.T.astype(np.float64) @ a
    np.testing.assert_allclose(
        fm.as_np(x1), np.linalg.solve(G, a.sum(0).reshape(-1, 1)),
        rtol=1e-3, atol=1e-5)
    with pytest.raises(ValueError, match="solve shape mismatch"):
        fm.solve(fm.crossprod(X), fm.conv_R2FM(_x(2, 4)) + 0.0)


def test_epilogue_evaluated_sink():
    """A sink whose operand is itself post-sink (sum(colMeans(X))) runs its
    identity→update→finalize quartet inside the epilogue."""
    a = _x()
    X = fm.conv_R2FM(a)
    tot = fm.sum_(fm.colMeans(X))
    plan = Plan([tot.m])
    assert [n.kind for n in plan.epilogue_nodes] == ["mapply", "agg"]
    assert plan.sinks and all(n.kind == "agg_col" for n in plan.sinks)
    assert abs(fm.as_scalar(tot) - a.mean(0).sum()) < 1e-4


def test_mean_and_scale_are_lazy():
    a = _x(400, 3)
    X = fm.conv_R2FM(a)
    m = fm.mean_(X)
    assert m.is_virtual
    assert abs(fm.as_scalar(m) - a.mean()) < 1e-5
    Z = fm.scale(X)
    assert Z.is_virtual  # moments materialized, the sweep itself is lazy
    (G,) = fm.materialize(fm.crossprod(Z))
    Zn = (a - a.mean(0)) / a.std(0, ddof=1)
    np.testing.assert_allclose(fm.as_np(G), Zn.T @ Zn, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Plan-cache correctness under the epilogue key
# ---------------------------------------------------------------------------

def test_cache_no_collision_with_and_without_epilogue():
    """The same sink requested bare vs feeding an epilogue must be two
    cache entries (an epilogue-less executable would silently drop the
    post-sink math); re-running each signature is a hit."""
    a = _x()
    X = fm.conv_R2FM(a)
    with cache_activity() as act:
        fm.materialize(fm.colSums(X))
        fm.materialize(fm.colSums(X) / float(X.nrow))
        fm.materialize(fm.colSums(X))
        (mu_m,) = fm.materialize(fm.colSums(X) / float(X.nrow))
    assert_activity(act, misses=2, hits=2, epilogue_launches=2)
    np.testing.assert_allclose(fm.as_np(mu_m).reshape(-1), a.mean(0),
                               rtol=1e-5)


def test_cache_no_collision_on_requested_epilogue_roots():
    """Which epilogue nodes are REQUESTED is part of the cache key.

    Regression: materialize([e, sum(e)]) vs materialize([sum(e)]) share the
    whole DAG structure; only the request set differs.  Before the fix the
    second borrowed the first's template (whose compiled epilogue returns
    BOTH roots) and positional result alignment handed ``sum(e)`` the value
    of ``e``."""
    a = _x()
    X = fm.conv_R2FM(a)

    def build():
        e = fm.sqrt(fm.abs_(fm.colSums(X ** 2) - fm.colSums(X) / 2.0))
        return e, fm.sum_(e)

    ref_e = np.sqrt(np.abs((a.astype(np.float64) ** 2).sum(0)
                           - a.astype(np.float64).sum(0) / 2.0))
    with cache_activity() as act:
        e1, s1 = build()
        e_m, s_m = fm.materialize(e1, s1)
        _, s2 = build()
        (solo_m,) = fm.materialize(s2)
    assert_activity(act, misses=2, hits=0)
    np.testing.assert_allclose(fm.as_np(e_m).reshape(-1), ref_e, rtol=1e-4)
    np.testing.assert_allclose(float(fm.as_scalar(s_m)), ref_e.sum(),
                               rtol=1e-4)
    np.testing.assert_allclose(float(fm.as_scalar(solo_m)), ref_e.sum(),
                               rtol=1e-4)


def test_cached_plan_reuse_with_epilogue_iteration():
    """IRLS-style loop: iteration N+1 (new Small beta) borrows the cached
    executable — including its epilogue — and produces correct results."""
    a = _x(500, 3)
    yv = RNG.normal(size=(500, 1)).astype(np.float32)
    X, y = fm.conv_R2FM(a), fm.conv_R2FM(yv)
    betas = []
    with cache_activity() as act:
        for it in range(3):
            shift = float(it)
            r = y - X @ np.full((3, 1), shift, np.float32)
            beta = fm.solve(fm.crossprod(X), fm.crossprod(X, r))
            (b_m,) = fm.materialize(beta, mode="stream")
            betas.append(fm.as_np(b_m))
    assert_activity(act, misses=1, hits=2, epilogue_launches=3)
    G = a.T.astype(np.float64) @ a
    for it, got in enumerate(betas):
        r = yv - a @ np.full((3, 1), float(it), np.float32)
        ref = np.linalg.solve(G, a.T.astype(np.float64) @ r)
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# ooc: merged sinks land on device before the epilogue runs
# ---------------------------------------------------------------------------

def test_ooc_epilogue_inputs_on_device(tmp_path, monkeypatch):
    """Disk-backed sources: the epilogue callable must receive device
    arrays only — no np.memmap/numpy leaks past the merge (the
    epilogue_host_inputs counter records any violation)."""
    from repro import storage
    monkeypatch.setitem(storage.registry._CONF, "data_dir", None)
    fm.set_conf(data_dir=str(tmp_path / "fmdata"))
    a = _x(700, 4)
    Xd = fm.load_dense_matrix(a, "epi_x")
    assert Xd.m.on_disk
    with cache_activity() as act:
        mu_m, sd_m = fm.materialize(fm.colMeans(Xd), fm.colSds(Xd))
    assert_activity(act, epilogue_launches=1, epilogue_host_inputs=0)
    assert act.partition_steps > 1  # genuinely multi-partition ooc
    np.testing.assert_allclose(fm.as_np(mu_m).reshape(-1), a.mean(0),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(fm.as_np(sd_m).reshape(-1),
                               a.std(0, ddof=1), rtol=1e-3)
    # The stored results themselves are device-resident (sink-like).
    assert not mu_m.m.on_host and not sd_m.m.on_host


def test_ooc_ridge_eye_is_epilogue_source(tmp_path, monkeypatch):
    """A small physical matrix consumed only by the epilogue (ridge eye) is
    handed whole to the callable — staged to device, never streamed."""
    from repro import storage
    monkeypatch.setitem(storage.registry._CONF, "data_dir", None)
    fm.set_conf(data_dir=str(tmp_path / "fmdata"))
    a = _x(512, 3)
    Xd = fm.load_dense_matrix(a, "epi_ridge_x")
    eye = fm.conv_R2FM(np.eye(3, dtype=np.float32), host=True)
    A = fm.crossprod(Xd) + eye
    plan = Plan([A.m])
    assert len(plan.epilogue_sources) == 1
    assert plan.bytes_in() == Xd.m.nbytes()  # eye not part of the stream
    with cache_activity() as act:
        (am,) = fm.materialize(A)
    assert_activity(act, epilogue_launches=1, epilogue_host_inputs=0)
    np.testing.assert_allclose(fm.as_np(am),
                               a.T.astype(np.float64) @ a + np.eye(3),
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# Guard rails
# ---------------------------------------------------------------------------

def test_epilogue_of_streaming_intermediate_rejected():
    """solve() of a row-local (streaming) intermediate needs a second pass:
    the plan refuses with an actionable message instead of mis-executing."""
    a = _x(8, 8)  # square so the row-local chain shares the long dim
    Z = fm.conv_R2FM(a) + 1.0
    bad = fm.solve(Z, np.ones((8, 1), np.float32))
    with pytest.raises(ValueError, match="streaming intermediate"):
        fm.materialize(bad)


def test_source_shared_by_loop_and_epilogue_rejected():
    from repro.core import genops
    from repro.core.dag import as_node, wrap

    leaf = wrap(as_node(fm.conv_R2FM(_x(4, 4)).m))
    sink = genops.agg_col(leaf.node, "sum")      # loop consumer
    inv = genops.solve(leaf.node)                # epilogue consumer
    with pytest.raises(ValueError, match="both the partition loop"):
        Plan([sink, inv])


def test_persisted_sink_as_cut_source_keeps_its_value():
    """Regression: a materialized sink reused as a SOURCE of a later plan
    must not re-register as that plan's sink — the executor would
    re-initialize it to its identity and clobber the persisted value with
    zeros (the eager-mode IRLS NaN bug)."""
    a = _x(400, 3)
    X = fm.conv_R2FM(a)
    s = fm.colSums(X)
    fm.materialize(s)
    v1 = fm.as_np(s).copy()
    plan = Plan([(s / 400.0).m])
    assert plan.sinks == []          # the persisted sink is a source here
    (mu_m,) = fm.materialize(s / 400.0)
    np.testing.assert_array_equal(fm.as_np(s), v1)  # value survived
    np.testing.assert_allclose(fm.as_np(mu_m).reshape(-1), a.mean(0),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# Eager (fuse=False) arm still works — the ablation baseline
# ---------------------------------------------------------------------------

def test_eager_mode_epilogue_parity():
    a = _x(300, 4)
    X = fm.conv_R2FM(a)
    with cache_activity() as act:
        (sd_m,) = fm.materialize(fm.colSds(X), fuse=False)
    np.testing.assert_allclose(fm.as_np(sd_m).reshape(-1),
                               a.std(0, ddof=1), rtol=1e-3)
    # unfused: every post-sink node materializes as its OWN tiny plan over
    # persisted cut points (no epilogue at all) — many separate executions
    # instead of one launch, exactly the contrast fusion_ablation measures.
    assert act.epilogue_launches == 0
    assert act.partition_steps >= 5
