"""Docs cannot rot: every fenced ```python block in README.md and docs/
executes, in order, sharing one namespace per document (ISSUE 3 satellite).

Conventions for doc authors:
  * ```python blocks are EXECUTED (cumulatively, top to bottom);
  * blocks whose first line contains ``doc-only`` are rendered but skipped
    (illustrative sketches that reference internals out of context);
  * non-python fences (```r, ```bash, ```text, …) are never executed.
"""
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]

DOCS = [
    ROOT / "README.md",
    ROOT / "docs" / "api.md",
    ROOT / "docs" / "lowering.md",
]

_FENCE = re.compile(r"^```python[ \t]*$(.*?)^```[ \t]*$",
                    re.MULTILINE | re.DOTALL)


def python_blocks(path: pathlib.Path):
    text = path.read_text()
    blocks = []
    for m in _FENCE.finditer(text):
        code = m.group(1).strip("\n")
        first = code.splitlines()[0] if code else ""
        if "doc-only" in first:
            continue
        line = text[:m.start()].count("\n") + 2  # 1-based, after the fence
        blocks.append((line, code))
    return blocks


def test_all_docs_exist_and_have_executable_examples():
    for path in DOCS:
        assert path.exists(), f"missing documentation file {path}"
    assert sum(len(python_blocks(p)) for p in DOCS) >= 8


@pytest.mark.parametrize("path", DOCS, ids=lambda p: p.name)
def test_doc_snippets_execute(path, tmp_path):
    """Execute the document's python blocks in one shared namespace, like a
    reader pasting them into a REPL top-to-bottom."""
    from repro.core import fm
    from repro import storage

    blocks = python_blocks(path)
    assert blocks, f"{path.name} has no executable python examples"
    old_dir = storage.registry._CONF["data_dir"]
    fm.set_conf(data_dir=str(tmp_path / "fm-docs"))
    ns: dict = {"__name__": f"doc_{path.stem}"}
    try:
        for line, code in blocks:
            try:
                exec(compile(code, f"{path.name}:{line}", "exec"), ns)
            except Exception as e:  # pragma: no cover - failure reporting
                pytest.fail(
                    f"{path.name} snippet at line {line} failed: "
                    f"{type(e).__name__}: {e}\n--- snippet ---\n{code}")
    finally:
        storage.registry._CONF["data_dir"] = old_dir
