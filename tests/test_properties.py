"""Hypothesis property tests on the engine's invariants.

The invariants FlashMatrix's design depends on:
  * fusion never changes results (fused == eager),
  * execution mode never changes results (whole == stream == ooc),
  * partition size never changes results (indexed reductions stay absolute),
  * groupby.row(sum) ≡ one-hot matmul,
  * dtype promotion is monotone on the lattice.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import dtypes, fm
from repro.core.matrix import io_partition_rows

SHAPE = st.tuples(st.integers(5, 200), st.integers(1, 8))


def arrays(draw, shape, dtype=np.float32):
    n, p = shape
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    return (rng.normal(size=(n, p)) * 2).astype(dtype)


@settings(max_examples=20, deadline=None)
@given(st.data(), SHAPE)
def test_fused_equals_eager(data, shape):
    Xn = arrays(data.draw, shape)
    X = fm.conv_R2FM(Xn)
    expr = fm.colSums(fm.abs_(X * 2.0 - 1.0))
    (a,) = fm.materialize(expr, fuse=True)
    expr2 = fm.colSums(fm.abs_(fm.conv_R2FM(Xn) * 2.0 - 1.0))
    (b,) = fm.materialize(expr2, fuse=False)
    np.testing.assert_allclose(fm.as_np(a), fm.as_np(b), rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.data(), SHAPE)
def test_mode_invariance(data, shape):
    Xn = arrays(data.draw, shape)
    ref = None
    for mode, host in (("whole", False), ("stream", False), ("auto", True)):
        X = fm.conv_R2FM(Xn, host=host)
        (g, w) = fm.materialize(fm.crossprod(X), fm.which_min_row(X), mode=mode)
        if ref is None:
            ref = (fm.as_np(g), fm.as_np(w))
        else:
            np.testing.assert_allclose(fm.as_np(g), ref[0], rtol=1e-3, atol=1e-3)
            np.testing.assert_array_equal(fm.as_np(w), ref[1])


@settings(max_examples=15, deadline=None)
@given(st.data(), st.integers(5, 300), st.integers(1, 6), st.integers(1, 5))
def test_groupby_equals_onehot_matmul(data, n, p, k):
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31)))
    Xn = rng.normal(size=(n, p)).astype(np.float32)
    lab = rng.integers(0, k, n).astype(np.int32)
    X = fm.conv_R2FM(Xn)
    (g,) = fm.materialize(fm.rowsum(X, fm.conv_R2FM(lab), k))
    onehot = np.eye(k, dtype=np.float64)[lab]
    np.testing.assert_allclose(fm.as_np(g), onehot.T @ Xn, rtol=1e-3, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(st.sampled_from(["bool", "int8", "int32", "bfloat16", "float32"]),
       st.sampled_from(["bool", "int8", "int32", "bfloat16", "float32"]))
def test_promotion_monotone(a, b):
    p = dtypes.promote(a, b)
    assert dtypes.rank(p) >= dtypes.rank(a)
    assert dtypes.rank(p) >= dtypes.rank(b)
    assert dtypes.promote(a, b) == dtypes.promote(b, a)
    assert dtypes.promote(a, a) == dtypes.canon(a)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4096), st.sampled_from(["float32", "int8", "bfloat16"]),
       st.integers(1, 8))
def test_partition_rows_power_of_two(ncol, dtype, n_live):
    rows = io_partition_rows(ncol, dtype, n_live)
    assert rows >= 8
    assert rows & (rows - 1) == 0  # paper: always 2^i


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_indexed_reduction_partition_invariance(data):
    """which.min over the long dim must be absolute regardless of partition
    count (offset threading)."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31)))
    Xn = rng.normal(size=(500, 3)).astype(np.float32)
    X = fm.conv_R2FM(Xn, host=True)   # ooc: many partitions
    (w,) = fm.materialize(fm.agg_col(X, "which.min"))
    np.testing.assert_array_equal(fm.as_np(w).ravel(), Xn.argmin(0))
