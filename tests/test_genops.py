"""GenOps vs numpy oracle: every operator × execution mode × storage tier."""
import numpy as np
import pytest

from repro.core import fm

RNG = np.random.default_rng(7)


def data(n=257, p=9, dtype=np.float32):
    return (RNG.normal(size=(n, p)) * 3).astype(dtype)


MODES = [("whole", False), ("stream", False), ("whole", True)]


def make(host):
    X = data()
    return X, fm.conv_R2FM(X, host=host)


@pytest.mark.parametrize("mode,host", MODES)
class TestElementwise:
    def test_sapply_chain(self, mode, host):
        Xn, X = make(host)
        out = fm.sqrt(fm.abs_(X * 2.0 + 1.0))
        (m,) = fm.materialize(out, mode=mode)
        np.testing.assert_allclose(fm.as_np(m), np.sqrt(np.abs(Xn * 2 + 1)),
                                   rtol=1e-5)

    def test_mapply_matrix(self, mode, host):
        Xn, X = make(host)
        Y = fm.conv_R2FM(Xn * 0.5 + 1, host=host)
        (m,) = fm.materialize(X * Y - Y, mode=mode)
        np.testing.assert_allclose(fm.as_np(m), Xn * (Xn * 0.5 + 1) - (Xn * 0.5 + 1),
                                   rtol=1e-4)

    def test_scalar_forms(self, mode, host):
        """bVUDF2 (vec∘scalar) and bVUDF3 (scalar∘vec)."""
        Xn, X = make(host)
        (a, b) = fm.materialize(X - 3.0, 3.0 - X, mode=mode)
        np.testing.assert_allclose(fm.as_np(a), Xn - 3.0, rtol=1e-6)
        np.testing.assert_allclose(fm.as_np(b), 3.0 - Xn, rtol=1e-6)

    def test_mapply_row_col(self, mode, host):
        Xn, X = make(host)
        row = RNG.normal(size=Xn.shape[1]).astype(np.float32)
        col = RNG.normal(size=Xn.shape[0]).astype(np.float32)
        (a, b) = fm.materialize(fm.mapply_row(X, row, "mul"),
                                fm.mapply_col(X, col, "add"), mode=mode)
        np.testing.assert_allclose(fm.as_np(a), Xn * row[None], rtol=1e-5)
        np.testing.assert_allclose(fm.as_np(b), Xn + col[:, None], rtol=1e-5)

    def test_pmin_pmax_ifelse0(self, mode, host):
        Xn, X = make(host)
        Y = fm.conv_R2FM(-Xn, host=host)
        (mn, mx) = fm.materialize(fm.pmin(X, Y), fm.pmax(X, Y), mode=mode)
        np.testing.assert_allclose(fm.as_np(mn), np.minimum(Xn, -Xn))
        np.testing.assert_allclose(fm.as_np(mx), np.maximum(Xn, -Xn))

    def test_cbind(self, mode, host):
        Xn, X = make(host)
        (m,) = fm.materialize(fm.cbind(X, X * 2.0), mode=mode)
        np.testing.assert_allclose(fm.as_np(m),
                                   np.concatenate([Xn, Xn * 2], 1), rtol=1e-6)


@pytest.mark.parametrize("mode,host", MODES)
class TestAggregation:
    def test_agg_full(self, mode, host):
        Xn, X = make(host)
        (s,) = fm.materialize(fm.sum_(X), mode=mode)
        np.testing.assert_allclose(fm.as_scalar(s), Xn.sum(), rtol=1e-4)

    def test_agg_col_variants(self, mode, host):
        Xn, X = make(host)
        outs = fm.materialize(fm.colSums(X), fm.colMins(X), fm.colMaxs(X),
                              fm.agg_col(X, "count_nonzero"), mode=mode)
        np.testing.assert_allclose(fm.as_np(outs[0]).ravel(), Xn.sum(0), rtol=1e-4)
        np.testing.assert_allclose(fm.as_np(outs[1]).ravel(), Xn.min(0))
        np.testing.assert_allclose(fm.as_np(outs[2]).ravel(), Xn.max(0))
        np.testing.assert_array_equal(fm.as_np(outs[3]).ravel(),
                                      (Xn != 0).sum(0))

    def test_agg_row(self, mode, host):
        Xn, X = make(host)
        (s,) = fm.materialize(fm.rowSums(X), mode=mode)
        np.testing.assert_allclose(fm.as_np(s).ravel(), Xn.sum(1), rtol=1e-4)

    def test_which_min_row_absolute_indices(self, mode, host):
        """Indexed reductions must stay absolute across partitions."""
        Xn, X = make(host)
        (w,) = fm.materialize(fm.which_min_row(X), mode=mode)
        np.testing.assert_array_equal(fm.as_np(w).ravel(), Xn.argmin(1))

    def test_logsumexp_streaming(self, mode, host):
        Xn, X = make(host)
        (l,) = fm.materialize(fm.agg_row(X, "logsumexp"), mode=mode)
        ref = np.log(np.exp(Xn - Xn.max(1, keepdims=True)).sum(1)) + Xn.max(1)
        np.testing.assert_allclose(fm.as_np(l).ravel(), ref, rtol=1e-5)

    def test_any_all(self, mode, host):
        Xn, X = make(host)
        (a, b) = fm.materialize(fm.any_(X > 10.0), fm.all_(X > -100.0), mode=mode)
        assert bool(fm.as_scalar(a)) == bool((Xn > 10).any())
        assert bool(fm.as_scalar(b)) == bool((Xn > -100).all())


@pytest.mark.parametrize("mode,host", MODES)
class TestInnerProdGroupBy:
    def test_crossprod(self, mode, host):
        Xn, X = make(host)
        (g,) = fm.materialize(fm.crossprod(X), mode=mode)
        np.testing.assert_allclose(fm.as_np(g), Xn.T @ Xn, rtol=1e-3)

    def test_crossprod_xy(self, mode, host):
        Xn, X = make(host)
        Yn = data()
        Y = fm.conv_R2FM(Yn, host=host)
        (g,) = fm.materialize(fm.crossprod(X, Y), mode=mode)
        np.testing.assert_allclose(fm.as_np(g), Xn.T @ Yn, rtol=1e-3)

    def test_tall_matmul(self, mode, host):
        Xn, X = make(host)
        W = RNG.normal(size=(Xn.shape[1], 4)).astype(np.float32)
        (m,) = fm.materialize(X @ W, mode=mode)
        np.testing.assert_allclose(fm.as_np(m), Xn @ W, rtol=1e-3)

    def test_semiring_distance(self, mode, host):
        Xn, X = make(host)
        C = RNG.normal(size=(Xn.shape[1], 5)).astype(np.float32)
        d = fm.inner_prod(X, C, "squared_diff", "sum")
        (m,) = fm.materialize(d, mode=mode)
        ref = ((Xn[:, :, None] - C[None]) ** 2).sum(1)
        np.testing.assert_allclose(fm.as_np(m), ref, rtol=1e-3)

    def test_groupby_row(self, mode, host):
        Xn, X = make(host)
        lab = RNG.integers(0, 6, Xn.shape[0])
        (g, c) = fm.materialize(
            fm.rowsum(X, fm.conv_R2FM(lab.astype(np.int32), host=host), 6),
            fm.table_(fm.conv_R2FM(lab.astype(np.int32), host=host), 6),
            mode=mode)
        ref = np.zeros((6, Xn.shape[1]), np.float64)
        np.add.at(ref, lab, Xn.astype(np.float64))
        np.testing.assert_allclose(fm.as_np(g), ref, rtol=1e-3)
        np.testing.assert_array_equal(fm.as_np(c).ravel(),
                                      np.bincount(lab, minlength=6))

    def test_groupby_col(self, mode, host):
        Xn, X = make(host)
        lab = RNG.integers(0, 3, Xn.shape[1]).astype(np.int32)
        (g,) = fm.materialize(fm.groupby_col(X, lab, "sum", 3), mode=mode)
        ref = np.zeros((Xn.shape[0], 3), np.float32)
        for j, k in enumerate(lab):
            ref[:, k] += Xn[:, j]
        np.testing.assert_allclose(fm.as_np(g), ref, rtol=1e-4)


class TestDtypesAndLazy:
    def test_lazy_cast_promotion(self):
        Xi = RNG.integers(0, 100, (64, 3)).astype(np.int32)
        X = fm.conv_R2FM(Xi)
        (m,) = fm.materialize(X * 1.5)
        assert fm.as_np(m).dtype == np.float32
        np.testing.assert_allclose(fm.as_np(m), Xi * 1.5)

    def test_division_promotes(self):
        Xi = RNG.integers(1, 100, (64, 3)).astype(np.int32)
        X = fm.conv_R2FM(Xi)
        (m,) = fm.materialize(X / 2)
        np.testing.assert_allclose(fm.as_np(m), Xi / 2)

    def test_comparison_dtype(self):
        Xn, X = make(False)
        (m,) = fm.materialize(X > 0.0)
        assert fm.as_np(m).dtype == np.bool_

    def test_missing_values_fig5(self):
        """The paper's Fig. 5 workload: std-dev with NA exclusion."""
        Xn = data()
        Xn[Xn > 2.0] = np.nan
        X = fm.conv_R2FM(Xn)
        na = fm.is_na(X)
        x0 = fm.ifelse0(X, na)
        x2 = fm.ifelse0(X ** 2, na)
        (sx, sx2, cnt) = fm.materialize(
            fm.sum_(x0), fm.sum_(x2),
            fm.agg(fm.sapply(na, "not"), "sum"))
        n = float(fm.as_scalar(cnt))
        mean = fm.as_scalar(sx) / n
        var = fm.as_scalar(sx2) / n - mean ** 2
        ref = np.nanstd(Xn)
        np.testing.assert_allclose(np.sqrt(var), ref, rtol=1e-3)

    def test_materialize_flag_reuse(self):
        Xn, X = make(False)
        Y = X * 2.0
        fm.persist(Y, tier="device")
        (s,) = fm.materialize(fm.colSums(Y))
        # Y is now cut: reusing it must not recompute from X
        assert Y.m.node.cached_store is not None
        (g,) = fm.materialize(fm.crossprod(Y))
        np.testing.assert_allclose(fm.as_np(g), (Xn * 2).T @ (Xn * 2), rtol=1e-3)

    def test_transpose_roundtrip(self):
        Xn, X = make(False)
        T = X.t()
        assert T.shape == (Xn.shape[1], Xn.shape[0])
        np.testing.assert_allclose(fm.as_np(T), Xn.T)


class TestRecycling:
    """R-style vector recycling across a matrix (FM._recycle): direction
    selection, the square-matrix ambiguity, and the error surface."""

    def test_length_ncol_recycles_per_row(self):
        Xn = data(40, 7)
        X = fm.conv_R2FM(Xn)
        v = fm.conv_R2FM(np.arange(7, dtype=np.float32))   # 7×1 vector
        (m,) = fm.materialize(X - v.T)                     # 1×7: per-row
        np.testing.assert_allclose(fm.as_np(m), Xn - np.arange(7)[None],
                                   rtol=1e-6)

    def test_length_nrow_recycles_per_column(self):
        Xn = data(40, 7)
        X = fm.conv_R2FM(Xn)
        v = fm.conv_R2FM(np.arange(40, dtype=np.float32))
        (m,) = fm.materialize(X - v)
        np.testing.assert_allclose(fm.as_np(m), Xn - np.arange(40)[:, None],
                                   rtol=1e-6)

    def test_square_matrix_prefers_column_major_pairing(self):
        """nrow == ncol is ambiguous; R's column-major recycling pairs
        vector element i with ROW i (mapply.col), which we follow."""
        Xn = data(6, 6)
        X = fm.conv_R2FM(Xn)
        v = np.arange(6, dtype=np.float32)
        (m,) = fm.materialize(X + fm.conv_R2FM(v))
        np.testing.assert_allclose(fm.as_np(m), Xn + v[:, None], rtol=1e-6)

    def test_wrong_length_vector_raises_with_both_options(self):
        X = fm.conv_R2FM(data(40, 7))
        bad = fm.conv_R2FM(np.ones(13, np.float32))
        with pytest.raises(ValueError) as ei:
            X + bad
        msg = str(ei.value)
        assert "length-13" in msg and "40" in msg and "7" in msg

    def test_matrix_operand_shape_mismatch_raises(self):
        X = fm.conv_R2FM(data(40, 7))
        Y = fm.conv_R2FM(data(20, 2))
        with pytest.raises(ValueError, match="shapes must match exactly"):
            X * Y

    def test_virtual_vector_recycles(self):
        """A recycled vector may itself be lazy (e.g. rowMeans output)."""
        Xn = data(50, 4)
        X = fm.conv_R2FM(Xn)
        (m,) = fm.materialize(X - fm.rowMeans(X))
        np.testing.assert_allclose(fm.as_np(m), Xn - Xn.mean(1, keepdims=True),
                                   rtol=1e-5)


class TestSmallTierVocabulary:
    """diag / solve / colMeans / colSds — the small-tier R vocabulary."""

    def test_diag_both_directions(self):
        A = data(5, 5)
        d = fm.as_np(fm.diag(fm.conv_R2FM(A))).reshape(-1)
        np.testing.assert_allclose(d, np.diag(A))
        D = fm.as_np(fm.diag(np.arange(3, dtype=np.float32)))
        np.testing.assert_allclose(D, np.diag(np.arange(3)))

    def test_solve(self):
        A = data(4, 4) + 10 * np.eye(4, dtype=np.float32)
        b = data(4, 1)
        x = fm.as_np(fm.solve(fm.conv_R2FM(A), fm.conv_R2FM(b)))
        np.testing.assert_allclose(A @ x, b, atol=1e-4)
        Ainv = fm.as_np(fm.solve(fm.conv_R2FM(A)))
        np.testing.assert_allclose(Ainv, np.linalg.inv(A), atol=1e-5)

    def test_col_moments(self):
        Xn = data(200, 6)
        X = fm.conv_R2FM(Xn)
        np.testing.assert_allclose(fm.as_np(fm.colMeans(X)).reshape(-1),
                                   Xn.mean(0), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(fm.as_np(fm.colSds(X)).reshape(-1),
                                   Xn.std(0, ddof=1), rtol=1e-3)

    def test_standardize_then_gram_pipeline(self):
        """The README quickstart: standardize lazily, Gram in one pass."""
        Xn = data(300, 5)
        X = fm.conv_R2FM(Xn)
        Z = (X - fm.colMeans(X)) / fm.colSds(X)
        (G,) = fm.materialize(fm.crossprod(Z))
        Zn = (Xn - Xn.mean(0)) / Xn.std(0, ddof=1)
        np.testing.assert_allclose(fm.as_np(G), Zn.T @ Zn, rtol=1e-3,
                                   atol=1e-3)
