"""Storage-tier tests: on-disk format, MmapStore, prefetcher, spill, registry.

The invariants the disk tier must hold:
  * header/body round-trip preserves shape/dtype/layout exactly,
  * MmapStore.block() == the in-memory slice (both layouts, any range),
  * the prefetcher delivers every partition, in order, and shuts down
    cleanly even when the consumer abandons the stream,
  * spill-to-disk outputs equal their in-memory counterparts (k-means,
    correlation — the paper's EM == IM contract),
  * the plan cache survives mode changes and evicts LRU.
"""
import pathlib

import numpy as np
import pytest

from repro.core import fm
from repro.core import materialize as mz
from repro.core.matrix import DenseStore, FMMatrix
from repro import storage


@pytest.fixture()
def data_dir(tmp_path, monkeypatch):
    """Point the registry at a fresh directory (and restore the old one)."""
    monkeypatch.setitem(storage.registry._CONF, "data_dir", None)
    fm.set_conf(data_dir=str(tmp_path / "fmdata"))
    return tmp_path / "fmdata"


def _arr(n=1000, p=7, seed=0):
    return (np.random.default_rng(seed).normal(size=(n, p)) * 3
            ).astype(np.float32)


# ---------------------------------------------------------------------------
# Format
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["row", "col"])
@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
def test_header_roundtrip(tmp_path, layout, dtype):
    A = _arr().astype(dtype)
    path = tmp_path / "a.fmat"
    written = storage.save_matrix(path, A, layout=layout)
    header = storage.read_header(path)
    assert header == written
    assert header.shape == A.shape
    assert header.dtype == np.dtype(dtype)
    assert header.layout == layout
    assert header.body_offset % 4096 == 0
    st = storage.open_matrix(path)
    np.testing.assert_array_equal(np.asarray(st.logical()), A)


def test_bad_magic_rejected(tmp_path):
    path = tmp_path / "junk.fmat"
    path.write_bytes(b"NOTAMATRIX" * 10)
    with pytest.raises(ValueError, match="magic"):
        storage.read_header(path)


def test_vector_becomes_one_column(tmp_path):
    v = np.arange(10, dtype=np.float32)
    storage.save_matrix(tmp_path / "v.fmat", v)
    st = storage.open_matrix(tmp_path / "v.fmat")
    assert st.header.shape == (10, 1)


# ---------------------------------------------------------------------------
# MmapStore
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["row", "col"])
def test_mmap_block_matches_memory(tmp_path, layout):
    A = _arr(500, 6)
    storage.save_matrix(tmp_path / "a.fmat", A, layout=layout)
    st = storage.open_matrix(tmp_path / "a.fmat")
    for start, stop in [(0, 500), (0, 1), (7, 130), (499, 500), (128, 256)]:
        np.testing.assert_array_equal(np.asarray(st.block(start, stop)),
                                      A[start:stop])
    assert st.nbytes() == A.nbytes
    assert st.on_host and st.on_disk


def test_mmap_transpose_zero_copy(tmp_path):
    A = _arr(64, 5)
    storage.save_matrix(tmp_path / "a.fmat", A)
    mat = FMMatrix(A.shape, A.dtype, store=storage.open_matrix(tmp_path / "a.fmat"))
    t = mat.transpose()
    assert t.shape == (5, 64)
    assert t.store.on_disk  # still the same file, no materialization
    np.testing.assert_array_equal(np.asarray(t.block(1, 3)), A.T[1:3])


def test_write_rows_roundtrip(tmp_path):
    A = _arr(200, 4)
    st = storage.create_matrix(tmp_path / "w.fmat", A.shape, A.dtype)
    for start in range(0, 200, 64):
        st.write_rows(start, A[start:start + 64])
    st.flush()
    reopened = storage.open_matrix(tmp_path / "w.fmat")
    np.testing.assert_array_equal(np.asarray(reopened.logical()), A)
    with pytest.raises(ValueError, match="read-only"):
        reopened.write_rows(0, A[:1])


def test_dense_store_col_layout_block():
    """Regression: col-layout block() must slice the stored buffer and
    transpose only the block (never the whole buffer)."""
    A = _arr(100, 3)
    st = DenseStore(np.ascontiguousarray(A.T), "col")
    np.testing.assert_array_equal(np.asarray(st.block(10, 20)), A[10:20])
    # the returned block is a view of the stored buffer, not of a full
    # transposed copy
    blk = st.block(10, 20)
    assert blk.base is st.data or blk.base is getattr(st.data, "base", None)


# ---------------------------------------------------------------------------
# Prefetcher
# ---------------------------------------------------------------------------

def test_prefetcher_ordering(tmp_path):
    A = _arr(1000, 5)
    B = _arr(1000, 3, seed=1)
    storage.save_matrix(tmp_path / "a.fmat", A)
    sa = storage.open_matrix(tmp_path / "a.fmat")
    sb = DenseStore(B)
    with storage.PartitionPrefetcher([(0, sa), (1, sb)], 128, 1000,
                                     stage_to_device=False) as pf:
        seen = []
        for start, stop, blocks in pf:
            seen.append((start, stop))
            np.testing.assert_array_equal(np.asarray(blocks[0]), A[start:stop])
            np.testing.assert_array_equal(np.asarray(blocks[1]), B[start:stop])
    expected = [(s, min(s + 128, 1000)) for s in range(0, 1000, 128)]
    assert seen == expected  # every partition, exactly once, in order


def test_prefetcher_shutdown_midstream(tmp_path):
    A = _arr(10_000, 4)
    storage.save_matrix(tmp_path / "a.fmat", A)
    st = storage.open_matrix(tmp_path / "a.fmat")
    pf = storage.PartitionPrefetcher([(0, st)], 64, 10_000,
                                     stage_to_device=False)
    for i, _ in enumerate(pf):
        if i == 2:
            break  # abandon with ~150 partitions outstanding
    pf.close()
    assert not pf.alive
    pf.close()  # idempotent


def test_prefetcher_error_propagates():
    class Exploding:
        def block(self, start, stop):
            raise OSError("bad sector")

    pf = storage.PartitionPrefetcher([(0, Exploding())], 8, 64)
    with pytest.raises(storage.PrefetchError, match="bad sector"):
        for _ in pf:
            pass
    pf.close()


# ---------------------------------------------------------------------------
# End-to-end: disk tier through the engine
# ---------------------------------------------------------------------------

def test_registry_roundtrip(data_dir):
    A = _arr()
    X = fm.load_dense_matrix(A, "mat_a")
    assert "mat_a" in storage.list_matrices()
    Y = fm.get_dense_matrix("mat_a")
    np.testing.assert_array_equal(fm.as_np(Y), A)
    with pytest.raises(KeyError):
        fm.get_dense_matrix("nope")


def test_conv_store_disk(data_dir):
    A = _arr()
    X = fm.conv_R2FM(A)
    Xd = fm.persist(X, tier="disk", name="spilled")
    assert Xd.m.on_disk
    np.testing.assert_array_equal(fm.as_np(Xd), A)
    np.testing.assert_array_equal(fm.as_np(fm.get_dense_matrix("spilled")), A)


def test_ingest_csv_and_binary(data_dir, tmp_path):
    A = _arr(300, 4)
    csv = tmp_path / "a.csv"
    np.savetxt(csv, A, delimiter=",", comments="", header="a,b,c,d")
    X = fm.load_dense_matrix(str(csv), "from_csv", skip_header=1,
                             chunk_rows=64)
    np.testing.assert_allclose(fm.as_np(X), A, rtol=1e-6)

    raw = tmp_path / "a.bin"
    A.tofile(raw)
    Y = fm.load_dense_matrix(str(raw), "from_bin", ncol=4, chunk_rows=100)
    np.testing.assert_array_equal(fm.as_np(Y), A)


@pytest.mark.parametrize("prefetch", [True, False])
def test_ooc_disk_equals_memory_correlation(data_dir, prefetch):
    from repro.algorithms import correlation
    A = _arr(5000, 6)
    Xd = fm.load_dense_matrix(A, "corr")
    Xm = fm.conv_R2FM(A)
    mz.clear_plan_cache()
    G = fm.crossprod(Xd)
    s = fm.colSums(Xd)
    Gm, sm = fm.materialize(G, s, prefetch=prefetch)
    G2, s2 = fm.materialize(fm.crossprod(Xm), fm.colSums(Xm), mode="stream")
    np.testing.assert_allclose(fm.as_np(Gm), fm.as_np(G2), rtol=1e-5)
    np.testing.assert_allclose(correlation(Xd), correlation(Xm),
                               rtol=1e-4, atol=1e-5)


def test_ooc_disk_equals_memory_kmeans(data_dir):
    from repro.algorithms import kmeans
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(3, 5)) * 10
    A = np.concatenate(
        [c + rng.normal(size=(400, 5)) for c in centers]).astype(np.float32)
    Xd = fm.load_dense_matrix(A, "km")
    Xm = fm.conv_R2FM(A)
    r_disk = kmeans(Xd, k=3, max_iter=10, seed=1)
    r_mem = kmeans(Xm, k=3, max_iter=10, seed=1, mode="stream")
    np.testing.assert_allclose(r_disk.centers, r_mem.centers, atol=1e-5)
    assert abs(r_disk.wss - r_mem.wss) <= 1e-4 * max(1.0, abs(r_mem.wss))


@pytest.mark.parametrize("layout", ["row", "col"])
def test_direct_io_reads_correct_and_cold(data_dir, layout):
    """direct_io=True (cache-bypass benchmarking) must not change results:
    blocks are materialized copies and the pages are dropped after the
    read (best-effort posix_fadvise DONTNEED)."""
    A = _arr(3000, 4)
    Xd = fm.load_dense_matrix(A, f"dio_{layout}", layout=layout)
    fm.set_conf(direct_io=True)
    try:
        blk = Xd.m.store.block(100, 200)
        assert isinstance(blk, np.ndarray) and not isinstance(blk, np.memmap)
        np.testing.assert_array_equal(blk, A[100:200])
        G, s = fm.materialize(fm.crossprod(Xd), fm.colSums(Xd))
        np.testing.assert_allclose(
            fm.as_np(G), A.T.astype(np.float64) @ A.astype(np.float64),
            rtol=1e-4)
        np.testing.assert_allclose(fm.as_np(s).reshape(-1), A.sum(0),
                                   rtol=1e-4)
    finally:
        fm.set_conf(direct_io=False)
    # normal mode again serves lazy views
    blk2 = Xd.m.store.block(0, 10)
    np.testing.assert_array_equal(np.asarray(blk2), A[:10])


def test_staging_dedupes_shared_matrix_reads(data_dir):
    """Regression (ROADMAP open item): a DAG referencing one physical
    matrix through k leaves (crossprod + two agg.col chains here) must read
    each partition from the store ONCE, not k times."""
    from repro.core.fusion import Plan
    A = _arr(20_000, 4)
    fm.set_conf(io_partition_bytes=1 << 18)  # force many partitions
    try:
        Xd = fm.load_dense_matrix(A, "dedupe")
        store = Xd.m.store
        reads = []
        orig_block = store.block
        store.block = lambda start, stop: (reads.append((start, stop)),
                                           orig_block(start, stop))[1]
        outs = (fm.crossprod(Xd), fm.colSums(Xd), fm.colSums(Xd ** 2))
        plan = Plan([o.m for o in outs])
        assert len(plan.sources) >= 3          # three leaves ...
        assert len(plan.source_groups) == 1    # ... one physical matrix
        Gm, sm, qm = fm.materialize(*outs, prefetch=False)
        n_partitions = -(-A.shape[0] // plan.partition_rows)
        assert len(reads) == n_partitions, \
            f"{len(reads)} reads for {n_partitions} partitions"
        np.testing.assert_allclose(
            fm.as_np(Gm), A.T.astype(np.float64) @ A, rtol=1e-4)
        np.testing.assert_allclose(fm.as_np(sm).reshape(-1), A.sum(0),
                                   rtol=1e-4)
        np.testing.assert_allclose(fm.as_np(qm).reshape(-1), (A * A).sum(0),
                                   rtol=1e-4)
    finally:
        fm.set_conf(io_partition_bytes=64 << 20)


def test_staging_alias_structure_in_plan_signature():
    """Two structurally identical cuts that alias sources differently (one
    matrix through two leaves vs two distinct matrices) must not share a
    compiled plan — the staged-block layout differs."""
    from repro.core.fusion import Plan
    A = _arr(256, 3)
    X = fm.conv_R2FM(A)
    Y = fm.conv_R2FM(A.copy())
    shared = Plan([fm.crossprod(X, X).m])
    distinct = Plan([fm.crossprod(X, Y).m])
    assert len(shared.source_groups) == 1
    assert len(distinct.source_groups) == 2
    assert shared.signature() != distinct.signature()
    (g1,) = fm.materialize(fm.crossprod(X, X))
    (g2,) = fm.materialize(fm.crossprod(X, Y))  # same sig shape, new aliases
    np.testing.assert_allclose(fm.as_np(g1), fm.as_np(g2), rtol=1e-5)


def test_spill_to_disk_output(data_dir):
    """save='disk' long-dimension outputs stream into an on-disk matrix and
    equal the in-memory result."""
    A = _arr(4000, 4)
    Xd = fm.load_dense_matrix(A, "base")
    Z = fm.abs_(Xd) * 2.0 - 1.0
    fm.persist(Z, tier="disk")
    (Zm,) = fm.materialize(Z)
    assert Zm.m.on_disk
    np.testing.assert_allclose(fm.as_np(Zm), np.abs(A) * 2.0 - 1.0, rtol=1e-6)

    # whole-mode spill of a device-resident computation
    W = fm.conv_R2FM(A)
    Z2 = fm.sqrt(fm.abs_(W))
    fm.persist(Z2, tier="disk")
    (Z2m,) = fm.materialize(Z2, mode="whole")
    assert Z2m.m.on_disk
    np.testing.assert_allclose(fm.as_np(Z2m), np.sqrt(np.abs(A)), rtol=1e-6)


def test_disk_source_disk_sink_pipeline(data_dir):
    """Full EM pipeline: disk in, disk out, nothing big in RAM."""
    A = _arr(3000, 3)
    Xd = fm.load_dense_matrix(A, "pipe_in")
    Z = (Xd - 1.0) / 2.0
    fm.persist(Z, tier="disk")
    (Zm,) = fm.materialize(Z)
    out = fm.persist(Zm, tier="disk", name="pipe_out")
    np.testing.assert_allclose(fm.as_np(fm.get_dense_matrix("pipe_out")),
                               (A - 1.0) / 2.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# Plan cache (satellite: keying + LRU)
# ---------------------------------------------------------------------------

def test_plan_cache_survives_mode_change(data_dir):
    """Reusing a cached plan under a different execution mode (retrace)
    must not skip sinks — regression for the stale cached_store bug."""
    A = _arr(2000, 4)
    mz.clear_plan_cache()
    Xd = fm.load_dense_matrix(A, "pc")
    (Gd,) = fm.materialize(fm.crossprod(Xd))          # ooc
    Xm = fm.conv_R2FM(A)
    (Gm,) = fm.materialize(fm.crossprod(Xm))          # whole, same signature
    expected = A.T.astype(np.float64) @ A.astype(np.float64)
    np.testing.assert_allclose(fm.as_np(Gd), expected, rtol=1e-4)
    np.testing.assert_allclose(fm.as_np(Gm), expected, rtol=1e-4)


def test_spill_to_disk_survives_plan_cache(data_dir):
    """Regression: a cache-hit save='disk' materialization must still spill
    (the first execution zeroes the cached template's save flags)."""
    A = _arr(2000, 3)
    mz.clear_plan_cache()
    for i in range(3):  # identical signature each round → cache hit on 2nd+
        Xd = fm.load_dense_matrix(A + i, f"sp{i}")
        Z = fm.abs_(Xd) * 2.0
        fm.persist(Z, tier="disk")
        (Zm,) = fm.materialize(Z)
        assert Zm.m.on_disk, f"round {i} lost the disk spill target"
        np.testing.assert_allclose(fm.as_np(Zm), np.abs(A + i) * 2.0,
                                   rtol=1e-6)


def test_partition_budget_change_misses_cache(data_dir):
    """Regression: fm.set_conf(io_partition_bytes=...) must not be ignored
    for already-cached signatures — partition size is part of the key."""
    from repro.core import matrix as matrix_mod
    old = matrix_mod.IO_PARTITION_BYTES
    mz.clear_plan_cache()
    try:
        A = _arr(100_000, 4)
        Xd = fm.load_dense_matrix(A, "budget")
        fm.materialize(fm.colSums(Xd))
        assert len(mz._PLANS) == 1
        fm.set_conf(io_partition_bytes=1 << 18)  # 256 KiB
        (s,) = fm.materialize(fm.colSums(fm.get_dense_matrix("budget")))
        assert len(mz._PLANS) == 2  # new partition size ⇒ new cache entry
        np.testing.assert_allclose(fm.as_np(s).reshape(-1), A.sum(0),
                                   rtol=1e-4)
    finally:
        matrix_mod.IO_PARTITION_BYTES = old
        mz.clear_plan_cache()


def test_plan_cache_hit_preserves_first_dag(data_dir):
    """Regression: borrowing a cached plan must not clobber the first
    caller's persisted cut points — a later structurally identical
    computation once overwrote them, silently corrupting downstream
    virtual matrices of the original DAG."""
    mz.clear_plan_cache()
    A = fm.conv_R2FM(np.full((64, 2), 2.0, np.float32))
    VA = A + 0.0
    fm.persist(VA, tier="device")       # persisted cut point
    VB = VA * 10.0                        # depends on VA's persisted value
    fm.materialize(VA)
    # structurally identical DAG over different data → cache hit
    VC = fm.conv_R2FM(np.full((64, 2), 5.0, np.float32)) + 0.0
    fm.persist(VC, tier="device")
    (VCm,) = fm.materialize(VC)
    np.testing.assert_allclose(fm.as_np(VCm), 5.0)
    (VBm,) = fm.materialize(VB)
    np.testing.assert_allclose(fm.as_np(VBm), 20.0)  # not 50.0


def test_plan_cache_lru_eviction():
    mz.clear_plan_cache()
    old_limit = mz.PLAN_CACHE_LIMIT
    mz.PLAN_CACHE_LIMIT = 2
    try:
        A = _arr(64, 3)
        X = fm.conv_R2FM(A)
        sigs = []
        for const in (1.0, 2.0, 3.0):  # Smalls don't change the signature
            for p in ((X + const), (X * const), fm.abs_(X + const)):
                fm.materialize(fm.colSums(p))
            assert len(mz._PLANS) <= 2  # evicts, never bypasses
    finally:
        mz.PLAN_CACHE_LIMIT = old_limit
        mz.clear_plan_cache()


def test_plan_cache_mesh_key_not_id(monkeypatch):
    """Cache keys must use mesh structure, not id(mesh) (which the GC can
    reissue to a different mesh object)."""
    import jax
    from jax.sharding import Mesh
    mz.clear_plan_cache()
    devs = np.array(jax.devices()[:1])
    m1 = Mesh(devs, ("data",))
    m2 = Mesh(devs, ("data",))
    assert mz._mesh_key(m1) == mz._mesh_key(m2)
    assert mz._mesh_key(m1) != mz._mesh_key(None)
    A = _arr(64, 3)
    X = fm.conv_R2FM(A)
    fm.materialize(fm.colSums(X * 2.0), mesh=m1)
    n_before = len(mz._PLANS)
    fm.materialize(fm.colSums(fm.conv_R2FM(A) * 2.0), mesh=m2)
    assert len(mz._PLANS) == n_before  # structurally equal mesh ⇒ cache hit


# ---------------------------------------------------------------------------
# Registry-owned temp-dir cleanup (ISSUE 9 satellite)
# ---------------------------------------------------------------------------

def test_registry_cleanup_removes_owned_dirs_only(tmp_path, monkeypatch):
    """Lazily-mkdtemp'd fm-data-* dirs are removed by cleanup() and
    forgotten; a user-configured data_dir is never touched."""
    reg = storage.registry
    monkeypatch.setitem(reg._CONF, "data_dir", None)
    saved_owned = list(reg._OWNED_DIRS)
    reg._OWNED_DIRS[:] = []
    try:
        lazy = reg.data_dir()            # lazy init -> registry-owned
        assert lazy.exists() and lazy.name.startswith("fm-data-")
        assert lazy in reg._OWNED_DIRS
        removed = storage.cleanup()
        assert lazy in removed
        assert not lazy.exists()
        assert reg._OWNED_DIRS == []
        assert reg._CONF["data_dir"] is None  # forgotten, re-inits fresh

        # User-supplied dirs are never owned, never removed.
        user = tmp_path / "user-data"
        fm.set_conf(data_dir=str(user))
        assert reg.data_dir() == user
        assert storage.cleanup() == []
        assert user.exists()
        assert reg._CONF["data_dir"] == user  # a user dir is not forgotten
    finally:
        reg._OWNED_DIRS[:] = saved_owned


def test_engine_close_release_storage(tmp_path, monkeypatch):
    """Engine.close(release_storage=True) routes to registry.cleanup()."""
    reg = storage.registry
    monkeypatch.setitem(reg._CONF, "data_dir", None)
    saved_owned = list(reg._OWNED_DIRS)
    reg._OWNED_DIRS[:] = []
    try:
        lazy = reg.data_dir()
        assert lazy.exists()
        eng = fm.serve(window_ms=1)
        eng.close(release_storage=True)
        assert not lazy.exists()
    finally:
        reg._OWNED_DIRS[:] = saved_owned


@pytest.mark.slow
def test_registry_cleanup_runs_at_interpreter_exit():
    """The atexit hook removes a lazily-created data dir when the process
    exits normally — repeated runs no longer accumulate fm-data-* litter."""
    import subprocess, sys, os
    code = (
        "import json\n"
        "from repro.storage import registry\n"
        "d = registry.data_dir()\n"
        "assert d.exists()\n"
        "print(json.dumps(str(d)))\n")
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, env=env,
                          timeout=120, cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-2000:]
    import json
    leaked = pathlib.Path(json.loads(proc.stdout.strip().splitlines()[-1]))
    assert not leaked.exists(), f"atexit cleanup left {leaked}"


# ---------------------------------------------------------------------------
# Prefetcher shutdown on interrupted streams (ISSUE 9 satellite)
# ---------------------------------------------------------------------------

def test_interrupted_stream_leaks_no_prefetcher_state(data_dir):
    """A staging fault mid-stream must tear the prefetch pipeline down
    completely: worker thread joined, queued staged partitions drained
    (not pinned on device), TLS residents cleared — thread count and
    pinned-partition census return to baseline."""
    import threading, time
    from helpers_cache import StagingFault
    from repro.core import matrix as matrix_mod

    old_budget = matrix_mod.IO_PARTITION_BYTES
    fm.set_conf(io_partition_bytes=4096)  # force a real multi-partition sweep
    try:
        A = _arr(4096, 4)
        X = fm.persist(fm.conv_R2FM(A), tier="disk")
        store = X.m.store
        orig_block, reads = store.block, {"n": 0}

        def flaky_block(start, stop):
            reads["n"] += 1
            if reads["n"] > 2:
                raise StagingFault("injected disk fault")
            return orig_block(start, stop)

        store.block = flaky_block  # instance attr shadows the method

        n_threads0 = threading.active_count()
        with pytest.raises((StagingFault, storage.PrefetchError)):
            fm.materialize(fm.colSums(X * X), mode="ooc", prefetch=True)

        deadline = time.time() + 10
        while time.time() < deadline and (
                storage.live_prefetchers()
                or threading.active_count() > n_threads0):
            time.sleep(0.02)
        assert storage.live_prefetchers() == [], "worker thread still alive"
        assert storage.staged_leaks() == [], "staged partitions pinned"
        assert threading.active_count() <= n_threads0
        assert mz._tls_residents() is None  # interrupted run pins nothing
    finally:
        matrix_mod.IO_PARTITION_BYTES = old_budget
        mz.clear_plan_cache()


def test_abandoned_prefetcher_close_drains_late_enqueue(data_dir):
    """close() must win the race against a worker parked in the bounded
    queue's put(): repeatedly abandon a stream mid-flight with a FULL
    queue and assert no staged block survives shutdown."""
    A = _arr(4096, 4)
    X = fm.persist(fm.conv_R2FM(A), tier="disk")
    pairs = [(0, X.m)]
    for _ in range(10):
        pf = storage.PartitionPrefetcher(pairs, 256, 4096, depth=1)
        it = iter(pf)
        next(it)          # worker now racing to refill the full queue
        pf.close()
        assert not pf.alive
        assert pf.queued == 0, "block enqueued after shutdown drain"
    assert storage.staged_leaks() == []
