"""Substrate tests: checkpointing, data pipeline, optimizer, fault runtime."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, DataIterator
from repro.optim import adam, compression, schedule
from repro.runtime import (StragglerMonitor, replan_mesh, rescale_grad_accum)


# -- checkpoint ---------------------------------------------------------------

def _tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = _tree()
    ck.save(3, tree, extra={"data": {"step": 3}}, blocking=True)
    out, step, extra = ck.restore(tree)
    assert step == 3 and extra["data"]["step"] == 3
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_atomic_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        ck.save(s, tree, blocking=True)
    steps = sorted(ck.all_steps())
    assert steps == [3, 4]          # gc kept the last two
    assert ck.latest_step() == 4
    # a stale .tmp dir must not be visible as a checkpoint
    (tmp_path / "step_0000000099.tmp").mkdir()
    assert ck.latest_step() == 4


def test_checkpoint_corruption_detected(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree(), blocking=True)
    d = next((pathlib.Path(tmp_path)).glob("step_*/leaf_00000.npy"))
    d.write_bytes(b"corrupt!" + d.read_bytes()[8:])
    with pytest.raises(IOError):
        ck.restore(_tree())


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _tree(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 5


def test_elastic_restore_resharded(tmp_path):
    """Save, then restore with explicit (new-mesh) shardings."""
    from repro.launch.mesh import mesh_axis_kwargs
    mesh = jax.make_mesh((1,), ("data",), **mesh_axis_kwargs(1))
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(tmp_path)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, tree, blocking=True)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out, _, _ = ck.restore(tree, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


# -- data pipeline --------------------------------------------------------------

def test_data_deterministic_and_resumable():
    cfg = DataConfig(seq_len=32, global_batch=4, vocab=1000, seed=5)
    it1 = DataIterator(cfg)
    batches = [next(it1) for _ in range(5)]
    # resume from step 3
    it2 = DataIterator(cfg)
    it2.load_state_dict({"step": 3, "seed": 5})
    b3 = next(it2)
    np.testing.assert_array_equal(np.asarray(batches[3]["tokens"]),
                                  np.asarray(b3["tokens"]))


def test_data_labels_shifted():
    cfg = DataConfig(seq_len=16, global_batch=2, vocab=100, seed=1)
    b = next(DataIterator(cfg))
    t, l = np.asarray(b["tokens"]), np.asarray(b["labels"])
    np.testing.assert_array_equal(t[:, 1:], l[:, :-1])


def test_data_multiprocess_disjoint():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=100, seed=1)
    a = DataIterator(cfg, process_index=0, process_count=2)._host_batch(0)
    b = DataIterator(cfg, process_index=1, process_count=2)._host_batch(0)
    assert not np.array_equal(a["tokens"], b["tokens"])


# -- optimizer -------------------------------------------------------------------

def test_adam_converges_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    cfg = adam.AdamConfig(lr=0.2, weight_decay=0.0, moment_dtype="float32",
                          grad_clip=0.0)
    state = adam.init(params, cfg)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, state, _ = adam.update(g, state, params, cfg)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_adam_bf16_moments_shapes():
    params = {"w": jnp.zeros((8, 8))}
    state = adam.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    assert adam.opt_state_axes({"w": "d_model|d_ff"})["m"]["w"] == "d_model|d_ff"


def test_grad_clip():
    params = {"x": jnp.asarray([1.0])}
    cfg = adam.AdamConfig(lr=0.0, grad_clip=1.0)
    state = adam.init(params, cfg)
    _, _, m = adam.update({"x": jnp.asarray([100.0])}, state, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(100.0)


def test_schedule_warmup_cosine():
    import numpy as np
    lr0 = float(schedule.warmup_cosine(jnp.asarray(0), warmup=10, total=100))
    lrw = float(schedule.warmup_cosine(jnp.asarray(10), warmup=10, total=100))
    lre = float(schedule.warmup_cosine(jnp.asarray(100), warmup=10, total=100))
    assert lr0 == 0.0 and lrw == pytest.approx(1.0) and lre == pytest.approx(0.1)


def test_compression_error_feedback_unbiased():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * 1e-3,
                          jnp.float32)}
    err = compression.init_error_state(g)
    total = np.zeros(64)
    for _ in range(50):
        payload, err = compression.compress_with_feedback(g, err)
        q, s = payload["w"]
        total += np.asarray(compression.dequantize(q, s))
    # error feedback: accumulated dequantized sum ~ accumulated true sum
    np.testing.assert_allclose(total / 50, np.asarray(g["w"]), rtol=0.05,
                               atol=1e-5)


# -- fault runtime ---------------------------------------------------------------

def test_straggler_monitor_flags():
    m = StragglerMonitor(threshold=2.0)
    for i in range(20):
        m.record(i, 0.1)
    assert m.record(20, 0.5) is True
    assert m.flagged


def test_replan_mesh_and_accum():
    mesh = replan_mesh(1, prefer_model=16)
    assert mesh.devices.size == 1
    assert rescale_grad_accum(4, old_data=16, new_data=8) == 8
    assert rescale_grad_accum(1, old_data=16, new_data=16) == 1
