"""The paper's five algorithms vs numpy/scipy-free references,
in-memory AND out-of-core (the central claim: identical results, one code
path, two tiers)."""
import numpy as np
import pytest

from repro.core import fm
from repro.algorithms import correlation, gmm, kmeans, summary, svd_tall

RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def X_np():
    return (RNG.normal(size=(3000, 10)) * 2 + 1).astype(np.float32)


@pytest.fixture(scope="module")
def blobs():
    centers = RNG.normal(size=(5, 8)) * 12
    pts = np.concatenate([c + RNG.normal(size=(400, 8)) for c in centers])
    return pts.astype(np.float32), centers


@pytest.mark.parametrize("host", [False, True])
def test_summary(X_np, host):
    s = summary(fm.conv_R2FM(X_np, host=host))
    np.testing.assert_allclose(s.mean, X_np.mean(0), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(s.var, X_np.var(0, ddof=1), rtol=1e-2)
    np.testing.assert_allclose(s.col_min, X_np.min(0))
    np.testing.assert_allclose(s.col_max, X_np.max(0))
    np.testing.assert_allclose(s.l1, np.abs(X_np).sum(0), rtol=1e-3)
    np.testing.assert_array_equal(s.nnz, (X_np != 0).sum(0))


@pytest.mark.parametrize("host", [False, True])
@pytest.mark.parametrize("two_pass", [False, True])
def test_correlation(X_np, host, two_pass):
    c = correlation(fm.conv_R2FM(X_np, host=host), two_pass=two_pass)
    np.testing.assert_allclose(c, np.corrcoef(X_np.T), rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("host", [False, True])
def test_svd(X_np, host):
    r = svd_tall(fm.conv_R2FM(X_np, host=host), k=6, compute_u=True)
    ref = np.linalg.svd(X_np.astype(np.float64), compute_uv=False)[:6]
    np.testing.assert_allclose(r.s, ref, rtol=1e-3)
    U = fm.as_np(r.U)
    np.testing.assert_allclose(U.T @ U, np.eye(6), atol=2e-2)
    # factorization consistency: X·V == U·diag(s) on the computed subspace
    np.testing.assert_allclose(X_np @ r.V, U @ np.diag(r.s),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("host", [False, True])
def test_kmeans_recovers_blobs(blobs, host):
    pts, centers = blobs
    res = kmeans(fm.conv_R2FM(pts, host=host), k=5, max_iter=30, seed=1)
    d = np.linalg.norm(res.centers[:, None] - centers[None], axis=-1)
    assert (d.min(1) < 1.0).all()
    assert res.wss < pts.shape[0] * 8 * 2.0  # ~within-cluster variance


@pytest.mark.parametrize("host", [False, True])
def test_gmm_loglik_monotone(blobs, host):
    pts, _ = blobs
    res = gmm(fm.conv_R2FM(pts, host=host), k=5, max_iter=6, seed=1)
    t = np.array(res.loglik_trace)
    assert (np.diff(t) > -1e-2 * np.abs(t[:-1])).all()
    np.testing.assert_allclose(res.weights.sum(), 1.0, rtol=1e-6)


def test_kmeans_matches_pallas_kernel(blobs):
    """The fused GenOps iteration and the Pallas kernel agree."""
    import jax.numpy as jnp
    from repro.algorithms.kmeans import kmeans_iteration, _init_centers
    from repro.kernels import ops
    pts, _ = blobs
    X = fm.conv_R2FM(pts)
    C = _init_centers(X, 5, 0)
    newC, counts, wss, _ = kmeans_iteration(X, C)
    lab_k, sums_k, cnt_k, wss_k = ops.kmeans_assign(jnp.asarray(pts),
                                                    jnp.asarray(C),
                                                    block_rows=256)
    np.testing.assert_allclose(np.asarray(cnt_k), counts)
    np.testing.assert_allclose(float(wss_k[0]), wss, rtol=1e-3)
    kernC = np.where(np.asarray(cnt_k)[:, None] > 0,
                     np.asarray(sums_k) / np.maximum(np.asarray(cnt_k)[:, None], 1),
                     C)
    np.testing.assert_allclose(kernC, newC, rtol=1e-3, atol=1e-3)
