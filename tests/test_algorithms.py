"""The paper's algorithm suite vs numpy references, in-memory AND
out-of-core (the central claim: identical results, one code path, all
tiers)."""
import numpy as np
import pytest

from repro.core import fm
from repro.algorithms import (correlation, glm, gmm, kmeans, naive_bayes,
                              nb_predict, nmf, pca, summary, svd_tall)
from repro.algorithms.glm import glm_iteration_plan

RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def X_np():
    return (RNG.normal(size=(3000, 10)) * 2 + 1).astype(np.float32)


@pytest.fixture(scope="module")
def blobs():
    centers = RNG.normal(size=(5, 8)) * 12
    pts = np.concatenate([c + RNG.normal(size=(400, 8)) for c in centers])
    return pts.astype(np.float32), centers


@pytest.mark.parametrize("host", [False, True])
def test_summary(X_np, host):
    s = summary(fm.conv_R2FM(X_np, host=host))
    np.testing.assert_allclose(s.mean, X_np.mean(0), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(s.var, X_np.var(0, ddof=1), rtol=1e-2)
    np.testing.assert_allclose(s.col_min, X_np.min(0))
    np.testing.assert_allclose(s.col_max, X_np.max(0))
    np.testing.assert_allclose(s.l1, np.abs(X_np).sum(0), rtol=1e-3)
    np.testing.assert_array_equal(s.nnz, (X_np != 0).sum(0))


@pytest.mark.parametrize("host", [False, True])
@pytest.mark.parametrize("two_pass", [False, True])
def test_correlation(X_np, host, two_pass):
    c = correlation(fm.conv_R2FM(X_np, host=host), two_pass=two_pass)
    np.testing.assert_allclose(c, np.corrcoef(X_np.T), rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("host", [False, True])
def test_svd(X_np, host):
    r = svd_tall(fm.conv_R2FM(X_np, host=host), k=6, compute_u=True)
    ref = np.linalg.svd(X_np.astype(np.float64), compute_uv=False)[:6]
    np.testing.assert_allclose(r.s, ref, rtol=1e-3)
    U = fm.as_np(r.U)
    np.testing.assert_allclose(U.T @ U, np.eye(6), atol=2e-2)
    # factorization consistency: X·V == U·diag(s) on the computed subspace
    np.testing.assert_allclose(X_np @ r.V, U @ np.diag(r.s),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("host", [False, True])
def test_kmeans_recovers_blobs(blobs, host):
    pts, centers = blobs
    res = kmeans(fm.conv_R2FM(pts, host=host), k=5, max_iter=30, seed=1)
    d = np.linalg.norm(res.centers[:, None] - centers[None], axis=-1)
    assert (d.min(1) < 1.0).all()
    assert res.wss < pts.shape[0] * 8 * 2.0  # ~within-cluster variance


@pytest.mark.parametrize("host", [False, True])
def test_gmm_loglik_monotone(blobs, host):
    pts, _ = blobs
    res = gmm(fm.conv_R2FM(pts, host=host), k=5, max_iter=6, seed=1)
    t = np.array(res.loglik_trace)
    assert (np.diff(t) > -1e-2 * np.abs(t[:-1])).all()
    np.testing.assert_allclose(res.weights.sum(), 1.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# GLM / IRLS
# ---------------------------------------------------------------------------

def _numpy_irls_logistic(X, y, max_iter=25, tol=1e-8, w_eps=1e-6):
    """Reference IRLS with the same weight floor as algorithms/glm.py."""
    beta = np.zeros(X.shape[1])
    Xf = X.astype(np.float64)
    prev = -np.inf
    for _ in range(max_iter):
        eta = Xf @ beta
        mu = 1.0 / (1.0 + np.exp(-eta))
        w = mu * (1 - mu) + w_eps
        z = eta + (y - mu) / w
        beta = np.linalg.solve(Xf.T @ (Xf * w[:, None]), Xf.T @ (w * z))
        ll = float(np.sum(y * eta - np.logaddexp(0.0, eta)))
        if np.isfinite(prev) and abs(ll - prev) <= tol * (abs(prev) + 1.0):
            break
        prev = ll
    return beta


@pytest.fixture(scope="module")
def logit_data():
    X = RNG.normal(size=(4000, 6)).astype(np.float32)
    true_beta = np.array([1.5, -2.0, 0.5, 0.0, 1.0, -0.5])
    pvec = 1.0 / (1.0 + np.exp(-(X.astype(np.float64) @ true_beta)))
    y = (RNG.uniform(size=4000) < pvec).astype(np.float32)
    return X, y


@pytest.mark.parametrize("host", [False, True])
def test_glm_logistic_matches_numpy_irls(logit_data, host):
    X, y = logit_data
    res = glm(fm.conv_R2FM(X, host=host), fm.conv_R2FM(y, host=host),
              family="logistic")
    ref = _numpy_irls_logistic(X, y)
    np.testing.assert_allclose(res.beta, ref, rtol=1e-5, atol=1e-6)
    assert res.converged
    t = np.array(res.loglik_trace)
    assert (np.diff(t) > -1e-6 * np.abs(t[:-1])).all()  # IRLS ascends


def test_glm_logistic_ooc_disk_one_pass_and_wgram_dispatch(
        logit_data, tmp_path, monkeypatch):
    """ISSUE 3 acceptance: logistic GLM on an ooc-DISK matrix matches the
    numpy IRLS reference within 1e-5; the iteration plan's cost counters
    prove one streaming pass over X per iteration; the weighted-gram
    segment lowers onto the pallas wgram kernel."""
    from repro import storage
    monkeypatch.setitem(storage.registry._CONF, "data_dir", None)
    fm.set_conf(data_dir=str(tmp_path / "fmdata"))
    X, y = logit_data
    Xd = fm.load_dense_matrix(X, "glm_x")
    yd = fm.load_dense_matrix(y, "glm_y")
    assert Xd.m.on_disk and yd.m.on_disk

    # Plan counters: ONE pass — bytes_in is exactly X + y (each staged once
    # per partition despite the many leaves referencing them).
    plan = glm_iteration_plan(Xd, yd, np.zeros(X.shape[1]), "logistic")
    assert len(plan.source_groups) == 2            # {X, y}, deduped
    assert plan.bytes_in() == Xd.m.nbytes() + yd.m.nbytes()

    # Engine dispatch: the XᵀWX segment is claimed by the wgram kernel.
    kernels = sorted(u.kernel
                     for u in plan.program("pallas").kernel_units)
    assert "wgram" in kernels, plan.program("pallas").describe()

    res = glm(Xd, yd, family="logistic")
    ref = _numpy_irls_logistic(X, y)
    np.testing.assert_allclose(res.beta, ref, rtol=1e-5, atol=1e-6)


def test_glm_gaussian_is_ols(logit_data):
    X, _ = logit_data
    true_beta = np.array([0.5, 1.0, -1.0, 2.0, 0.0, -0.3])
    y = (X.astype(np.float64) @ true_beta
         + 0.01 * RNG.normal(size=X.shape[0])).astype(np.float32)
    res = glm(fm.conv_R2FM(X), fm.conv_R2FM(y), family="gaussian")
    ref = np.linalg.lstsq(X.astype(np.float64), y.astype(np.float64),
                          rcond=None)[0]
    np.testing.assert_allclose(res.beta, ref, rtol=1e-4, atol=1e-5)
    assert res.iters == 1                      # constant weights: one step
    rss = float(((X.astype(np.float64) @ res.beta - y) ** 2).sum())
    # loglik = −RSS/2 via the quadratic expansion of f32 sinks: cancellation
    # (RSS ≈ 0.4 out of yᵀy ≈ 1e5) bounds the precision — diagnostic only.
    np.testing.assert_allclose(res.loglik, -0.5 * rss, atol=0.05)


def test_glm_poisson(logit_data):
    X, _ = logit_data
    true_beta = np.array([0.3, -0.2, 0.1, 0.4, 0.0, -0.1])
    lam = np.exp(X.astype(np.float64) @ true_beta)
    y = RNG.poisson(lam).astype(np.float32)
    res = glm(fm.conv_R2FM(X), fm.conv_R2FM(y), family="poisson")
    np.testing.assert_allclose(res.beta, true_beta, atol=0.1)
    assert res.converged


def test_glm_singular_raises(logit_data):
    """The on-device epilogue solve cannot raise like the old eager f64
    path — glm restores the diagnostic with a finite check on beta."""
    X, y = logit_data
    Xs = np.concatenate([X[:, :3], X[:, :1]], axis=1)  # duplicated column
    with pytest.raises(np.linalg.LinAlgError, match="ridge"):
        glm(fm.conv_R2FM(Xs), fm.conv_R2FM(y), family="logistic")


def test_glm_predict(logit_data):
    X, y = logit_data
    res = glm(fm.conv_R2FM(X), fm.conv_R2FM(y), family="logistic")
    from repro.algorithms import glm_predict
    (mu,) = fm.materialize(glm_predict(res, fm.conv_R2FM(X)))
    acc = ((fm.as_np(mu).reshape(-1) > 0.5) == (y > 0.5)).mean()
    assert acc > 0.8


# ---------------------------------------------------------------------------
# PCA
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("host", [False, True])
def test_pca_matches_numpy(X_np, host):
    r = pca(fm.conv_R2FM(X_np, host=host), k=4, compute_scores=True)
    Xc = X_np.astype(np.float64) - X_np.mean(0)
    ref_s = np.linalg.svd(Xc, compute_uv=False)[:4]
    np.testing.assert_allclose(r.sdev, ref_s / np.sqrt(X_np.shape[0] - 1),
                               rtol=1e-3)
    np.testing.assert_allclose(r.center, X_np.mean(0), rtol=1e-3, atol=1e-3)
    scores = fm.as_np(r.scores)
    # Scores equal the centered projection up to per-component sign.
    ref_scores = Xc @ r.rotation
    sign = np.sign((scores * ref_scores).sum(0))
    np.testing.assert_allclose(scores * sign, ref_scores * sign,
                               rtol=1e-2, atol=1e-2)


def test_pca_scaled_matches_correlation_eigs(X_np):
    r = pca(fm.conv_R2FM(X_np), k=10, scale=True)
    evals = np.sort(np.linalg.eigvalsh(np.corrcoef(X_np.T)))[::-1]
    np.testing.assert_allclose(np.sort(r.sdev ** 2)[::-1], evals,
                               rtol=2e-2, atol=1e-3)


# ---------------------------------------------------------------------------
# NMF
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("host", [False, True])
def test_nmf_reconstructs(host):
    W0 = np.abs(RNG.normal(size=(1500, 4))).astype(np.float32)
    H0 = np.abs(RNG.normal(size=(4, 9))).astype(np.float32)
    Xn = (W0 @ H0).astype(np.float32)
    res = nmf(fm.conv_R2FM(Xn, host=host), k=4, max_iter=60, seed=3)
    t = np.array(res.objective_trace)
    assert (np.diff(t) <= 1e-3 * np.maximum(np.abs(t[:-1]), 1.0)).all()
    rel = res.objective / float((Xn.astype(np.float64) ** 2).sum())
    assert rel < 0.01, f"relative reconstruction error {rel}"
    # objective trace is consistent with the actual factors
    recon = fm.as_np(res.W).astype(np.float64) @ res.H
    direct = float(((Xn - recon) ** 2).sum())
    # (trace logs the objective one W-update earlier, so allow slack)
    assert direct <= res.objective_trace[-1] * 1.5 + 1e-6


def test_nmf_disk_spill(tmp_path, monkeypatch):
    """save='disk': the tall factor streams write-through to the disk tier
    every iteration and the result matches the in-memory run."""
    from repro import storage
    monkeypatch.setitem(storage.registry._CONF, "data_dir", None)
    fm.set_conf(data_dir=str(tmp_path / "fmdata"))
    W0 = np.abs(RNG.normal(size=(1200, 3))).astype(np.float32)
    H0 = np.abs(RNG.normal(size=(3, 7))).astype(np.float32)
    Xn = (W0 @ H0).astype(np.float32)
    Xd = fm.load_dense_matrix(Xn, "nmf_x")
    r_disk = nmf(Xd, k=3, max_iter=15, seed=1, save="disk")
    assert r_disk.W.m.on_disk
    # Superseded spill files are reclaimed: only the live W remains.
    spills = list((tmp_path / "fmdata" / "spill").glob("*.fmat"))
    assert len(spills) == 1, spills
    r_mem = nmf(fm.conv_R2FM(Xn), k=3, max_iter=15, seed=1, mode="stream")
    np.testing.assert_allclose(r_disk.objective, r_mem.objective,
                               rtol=1e-3)
    np.testing.assert_allclose(fm.as_np(r_disk.W), fm.as_np(r_mem.W),
                               rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# Naive Bayes
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def nb_data(blobs):
    pts, centers = blobs
    labels = np.repeat(np.arange(5), 400).astype(np.float32)
    perm = RNG.permutation(len(pts))
    return pts[perm], labels[perm]


@pytest.mark.parametrize("host", [False, True])
def test_gaussian_nb_matches_numpy(nb_data, host):
    Xn, yn = nb_data
    model = naive_bayes(fm.conv_R2FM(Xn, host=host),
                        fm.conv_R2FM(yn, host=host), 5)
    for j in range(5):
        sel = Xn[yn == j].astype(np.float64)
        np.testing.assert_allclose(model.means[j], sel.mean(0), rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_allclose(model.variances[j], sel.var(0),
                                   rtol=1e-3, atol=1e-3)
    pred = fm.as_np(nb_predict(model, fm.conv_R2FM(Xn))).reshape(-1)
    assert (pred == yn.astype(np.int32)).mean() > 0.95


def test_multinomial_nb(nb_data):
    rng = np.random.default_rng(5)
    k, p, n_per = 3, 12, 500
    probs = rng.dirichlet(np.ones(p) * 0.3, size=k)
    X = np.concatenate([rng.multinomial(40, probs[j], size=n_per)
                        for j in range(k)]).astype(np.int32)
    y = np.repeat(np.arange(k), n_per).astype(np.int32)
    model = naive_bayes(fm.conv_R2FM(X), fm.conv_R2FM(y), k,
                        kind="multinomial")
    counts = np.stack([X[y == j].sum(0) for j in range(k)]) + 1.0
    expected = np.log(counts / counts.sum(1, keepdims=True))
    np.testing.assert_allclose(model.feature_log_prob, expected, rtol=1e-5)
    pred = fm.as_np(nb_predict(model, fm.conv_R2FM(X))).reshape(-1)
    assert (pred == y).mean() > 0.9


def test_kmeans_matches_pallas_kernel(blobs):
    """The fused GenOps iteration and the Pallas kernel agree."""
    import jax.numpy as jnp
    from repro.algorithms.kmeans import kmeans_iteration, _init_centers
    from repro.kernels import ops
    pts, _ = blobs
    X = fm.conv_R2FM(pts)
    C = _init_centers(X, 5, 0)
    newC, counts, wss, _ = kmeans_iteration(X, C)
    lab_k, sums_k, cnt_k, wss_k = ops.kmeans_assign(jnp.asarray(pts),
                                                    jnp.asarray(C),
                                                    block_rows=256)
    np.testing.assert_allclose(np.asarray(cnt_k), counts)
    np.testing.assert_allclose(float(wss_k[0]), wss, rtol=1e-3)
    kernC = np.where(np.asarray(cnt_k)[:, None] > 0,
                     np.asarray(sums_k) / np.maximum(np.asarray(cnt_k)[:, None], 1),
                     C)
    np.testing.assert_allclose(kernC, newC, rtol=1e-3, atol=1e-3)
