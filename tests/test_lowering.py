"""Lowering-layer tests: plan IR segmentation, backend parity, dispatch.

The contract under test (ISSUE 2 acceptance): the pallas backend — running
in interpret mode on this CPU container, Mosaic on TPU — must produce the
same results as the xla backend for the fused summary-statistics, Gram and
k-means/groupby workloads, dispatching through the ENGINE (materialize →
plan IR → lowering → kernels/), not standalone kernel calls; and the plan
cache must key on backend + both partition levels so compile-once/stream-
many still holds per backend.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from helpers_cache import assert_activity, cache_activity
from repro.core import fm
from repro.core import materialize as mz
from repro.core import matrix as matrix_mod
from repro.core.fusion import Plan
from repro.core.lowering import resolve_backend

RNG = np.random.default_rng(7)

DTYPES = [np.float32, "bfloat16", np.int32]


def _tol(dtype):
    if str(dtype) == "bfloat16":
        return dict(rtol=2e-2, atol=2e-2)
    return dict(rtol=1e-4, atol=1e-5)


def _data(n, p, dtype):
    a = RNG.normal(size=(n, p)) * 3.0
    if np.issubdtype(np.dtype(dtype) if dtype != "bfloat16" else np.float32,
                     np.integer):
        return a.astype(np.int32)
    return a.astype(np.float32)  # bf16 cast happens in conv below


def _fmx(a, dtype):
    if dtype == "bfloat16":
        return fm.conv_R2FM(jnp.asarray(a, jnp.bfloat16))
    return fm.conv_R2FM(a.astype(dtype))


def _summary_outs(X):
    return (fm.colSums(X), fm.colSums(fm.abs_(X)), fm.colSums(X ** 2),
            fm.colMins(X), fm.colMaxs(X), fm.agg_col(X, "count_nonzero"))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("mode", ["whole", "stream"])
def test_summary_chain_parity(dtype, mode):
    """Fused apply→agg.col chains: pallas-interpret == xla per backend."""
    a = _data(1000, 5, dtype)
    X = _fmx(a, dtype)
    res = {}
    for backend in ("xla", "pallas"):
        outs = fm.materialize(*_summary_outs(X), mode=mode, backend=backend)
        res[backend] = [fm.as_np(o).reshape(-1) for o in outs]
    for ox, op in zip(res["xla"], res["pallas"]):
        np.testing.assert_allclose(op.astype(np.float64),
                                   ox.astype(np.float64), **_tol(dtype))


@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
@pytest.mark.parametrize("mode", ["whole", "stream"])
def test_gram_parity(dtype, mode):
    a = _data(800, 6, np.float32)
    X = _fmx(a, dtype)
    (gx,) = fm.materialize(fm.crossprod(X), mode=mode, backend="xla")
    (gp,) = fm.materialize(fm.crossprod(X), mode=mode, backend="pallas")
    np.testing.assert_allclose(fm.as_np(gp), fm.as_np(gx), **_tol(dtype))


def test_xty_parity():
    a = _data(600, 5, np.float32)
    b = _data(600, 3, np.float32)
    X, Y = fm.conv_R2FM(a), fm.conv_R2FM(b)
    (cx,) = fm.materialize(fm.crossprod(X, Y), backend="xla")
    (cp,) = fm.materialize(fm.crossprod(X, Y), backend="pallas")
    np.testing.assert_allclose(fm.as_np(cp), fm.as_np(cx), rtol=1e-4)


@pytest.mark.parametrize("mode", ["whole", "stream"])
def test_kmeans_groupby_parity(mode):
    """The Lloyd pattern (distances → which.min → groupby sums/counts +
    objective) through both backends, multi-partition in stream mode."""
    rng = np.random.default_rng(0)
    true_c = rng.normal(size=(4, 6)) * 10          # well-separated clusters
    a = np.concatenate(
        [c + rng.normal(size=(300, 6)) for c in true_c]).astype(np.float32)
    centers = (true_c + rng.normal(size=true_c.shape)).astype(np.float32)
    X = fm.conv_R2FM(a)

    def lloyd(backend):
        D = fm.inner_prod(X, centers.T, "squared_diff", "sum")
        labels = fm.which_min_row(D)
        sums = fm.rowsum(X, labels, 4)
        counts = fm.table_(labels, 4)
        wss = fm.sum_(fm.rowMins(D))
        outs = fm.materialize(sums, counts, wss, labels, mode=mode,
                              backend=backend)
        return [fm.as_np(o) for o in outs]

    sx, cx, wx, lx = lloyd("xla")
    sp, cp, wp, lp = lloyd("pallas")
    np.testing.assert_array_equal(lp, lx)
    np.testing.assert_array_equal(cp, cx)
    np.testing.assert_allclose(sp, sx, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(wp, wx, rtol=1e-4)


@pytest.mark.parametrize("mode", ["whole", "stream"])
def test_weighted_gram_parity(mode):
    """crossprod(X*w, X) (the IRLS step's XᵀWX): pallas wgram == xla."""
    a = _data(900, 5, np.float32)
    wv = np.abs(RNG.normal(size=(900,))).astype(np.float32)
    X = fm.conv_R2FM(a)
    w = fm.conv_R2FM(wv)

    def build():
        Xw = fm.mapply_col(X, w, "mul")
        return fm.crossprod(Xw, X)

    (gx,) = fm.materialize(build(), mode=mode, backend="xla")
    (gp,) = fm.materialize(build(), mode=mode, backend="pallas")
    expected = (a * wv[:, None]).T.astype(np.float64) @ a
    np.testing.assert_allclose(fm.as_np(gp), fm.as_np(gx), rtol=1e-4)
    np.testing.assert_allclose(fm.as_np(gp), expected, rtol=1e-3)


def test_weighted_gram_dispatch_both_orientations():
    """Both crossprod(Xw, X) and crossprod(X, Xw) lower onto wgram (XᵀWX is
    symmetric in which operand carries the diagonal weights)."""
    a = _data(256, 4, np.float32)
    wv = np.abs(RNG.normal(size=(256,))).astype(np.float32)
    X, w = fm.conv_R2FM(a), fm.conv_R2FM(wv)
    for build in (lambda: fm.crossprod(fm.mapply_col(X, w, "mul"), X),
                  lambda: fm.crossprod(X, fm.mapply_col(X, w, "mul"))):
        plan = Plan([build().m])
        kernels = [u.kernel for u in plan.program("pallas").kernel_units]
        assert kernels == ["wgram"], plan.program("pallas").describe()


def test_weighted_gram_not_matched_for_distinct_matrices():
    """Weights applied to a DIFFERENT matrix than the contraction partner
    is XᵀW Y, not XᵀWX — must fall back (xty may still claim nothing here
    because the mapply chain is absorbed)."""
    a = _data(128, 3, np.float32)
    b = _data(128, 4, np.float32)
    wv = np.abs(RNG.normal(size=(128,))).astype(np.float32)
    X, Y, w = fm.conv_R2FM(a), fm.conv_R2FM(b), fm.conv_R2FM(wv)
    plan = Plan([fm.crossprod(fm.mapply_col(X, w, "mul"), Y).m])
    assert all(u.kernel != "wgram"
               for u in plan.program("pallas").kernel_units)
    (gx,) = fm.materialize(
        fm.crossprod(fm.mapply_col(X, w, "mul"), Y), backend="pallas")
    np.testing.assert_allclose(
        fm.as_np(gx), (a * wv[:, None]).T @ b, rtol=1e-3)


def test_int_dtype_parity():
    """Integer apply→agg chains accumulate in i32 inside the kernel
    (acc-dtype parameter), so both backends agree EXACTLY."""
    a = RNG.integers(-50, 50, size=(500, 4)).astype(np.int32)
    X = fm.conv_R2FM(a)
    outs_x = fm.materialize(fm.colSums(X), fm.colMaxs(X), backend="xla")
    outs_p = fm.materialize(fm.colSums(X), fm.colMaxs(X), backend="pallas")
    for ox, op in zip(outs_x, outs_p):
        np.testing.assert_array_equal(fm.as_np(op), fm.as_np(ox))


def test_int_chains_dispatch_to_fused_apply_agg():
    """int sources are now ELIGIBLE for the chain kernel (i32 accumulator),
    closing the ROADMAP fallback item — and stay exact where a float32
    accumulator would round (values past 2²⁴)."""
    a = np.zeros((64, 2), np.int32)
    a[0] = (1 << 24) + 1          # not representable in float32
    a[1:] = 1
    X = fm.conv_R2FM(a)
    outs = (fm.colSums(X), fm.colMaxs(X), fm.colMins(X))
    plan = Plan([o.m for o in outs])
    units = plan.program("pallas").kernel_units
    assert [u.kernel for u in units] == ["fused_apply_agg"]
    assert sorted(c[2] for c in units[0].chains) == ["int32"] * 3
    op = [fm.as_np(o) for o in fm.materialize(*outs, backend="pallas")]
    np.testing.assert_array_equal(op[0].reshape(-1), a.sum(0))  # exact
    np.testing.assert_array_equal(op[1].reshape(-1), a.max(0))
    np.testing.assert_array_equal(op[2].reshape(-1), a.min(0))


def test_cast_chains_dispatch_to_fused_apply_agg():
    """Chains containing lazy cast nodes (paper §III-D) stay in the kernel
    instead of falling back to the generic trace."""
    a = RNG.integers(0, 100, size=(300, 3)).astype(np.int32)
    X = fm.conv_R2FM(a)
    Xf = fm.sapply(X, "cast_float32")
    outs = (fm.colSums(Xf), fm.colSums(Xf ** 2))
    plan = Plan([o.m for o in outs])
    units = plan.program("pallas").kernel_units
    assert [u.kernel for u in units] == ["fused_apply_agg"], \
        plan.program("pallas").describe()
    assert len(units[0].chains) == 2
    op = [fm.as_np(o).reshape(-1)
          for o in fm.materialize(*outs, backend="pallas")]
    np.testing.assert_allclose(op[0], a.sum(0), rtol=1e-6)
    np.testing.assert_allclose(op[1], (a.astype(np.float64) ** 2).sum(0),
                               rtol=1e-5)


def test_mixed_acc_dtypes_share_one_kernel_call():
    """float stats and exact integer counts over one source still fuse into
    ONE kernel read (per-chain accumulator dtypes)."""
    a = _data(400, 3, np.float32)
    X = fm.conv_R2FM(a)
    outs = (fm.colSums(X), fm.agg_col(X, "count_nonzero"))
    plan = Plan([o.m for o in outs])
    units = plan.program("pallas").kernel_units
    assert len(units) == 1
    accs = sorted(c[2] for c in units[0].chains)
    assert accs == ["float32", "int32"]
    sp, cp = fm.materialize(*outs, backend="pallas")
    np.testing.assert_allclose(fm.as_np(sp).reshape(-1), a.sum(0), rtol=1e-4)
    np.testing.assert_array_equal(fm.as_np(cp).reshape(-1), (a != 0).sum(0))


# ---------------------------------------------------------------------------
# Dispatch: the ENGINE must reach the kernels, not just standalone calls
# ---------------------------------------------------------------------------

def test_engine_dispatches_to_kernels():
    a = _data(512, 4, np.float32)
    X = fm.conv_R2FM(a)
    plan = Plan([fm.crossprod(X).m, fm.colSums(fm.abs_(X)).m])
    prog = plan.program("pallas")
    kernels = sorted(u.kernel for u in prog.kernel_units)
    assert kernels == ["fused_apply_agg", "gram"], prog.describe()
    # xla lowering of the same plan has no kernel units
    assert plan.program("xla").kernel_units == []


def test_apply_agg_chains_share_one_source_read():
    """N agg.col chains over one matrix fuse into ONE kernel call."""
    a = _data(512, 4, np.float32)
    X = fm.conv_R2FM(a)
    plan = Plan([o.m for o in _summary_outs(X)])
    units = plan.program("pallas").kernel_units
    assert len(units) == 1
    assert len(units[0].chains) == 6


def test_kmeans_pattern_single_kernel():
    a = _data(512, 4, np.float32)
    X = fm.conv_R2FM(a)
    centers = RNG.normal(size=(3, 4)).astype(np.float32)
    D = fm.inner_prod(X, centers.T, "squared_diff", "sum")
    labels = fm.which_min_row(D)
    plan = Plan([fm.rowsum(X, labels, 3).m, fm.table_(labels, 3).m,
                 fm.sum_(fm.rowMins(D)).m, labels.m])
    units = plan.program("pallas").kernel_units
    assert [u.kernel for u in units] == ["kmeans_assign"], \
        plan.program("pallas").describe()


# ---------------------------------------------------------------------------
# Plan cache: backend + both partition levels in the key
# ---------------------------------------------------------------------------

def test_plan_cache_misses_on_backend_change():
    mz.clear_plan_cache()
    a = _data(4096, 4, np.float32)
    X = fm.conv_R2FM(a)
    with cache_activity() as act:
        fm.materialize(fm.colSums(X), backend="xla")
        fm.materialize(fm.colSums(X), backend="pallas")
        fm.materialize(fm.colSums(X), backend="pallas")
    # backend is part of the key; the second pallas run is a hit
    assert_activity(act, misses=2, hits=1, materialize_calls=3)
    assert len(mz._PLANS) == 2
    mz.clear_plan_cache()


def test_plan_cache_misses_on_vmem_budget_change():
    """The processor-level schedule is the second partition tier of the
    cache key: retuning the VMEM budget must retrace, not reuse."""
    mz.clear_plan_cache()
    old = matrix_mod.VMEM_PARTITION_BYTES
    try:
        a = _data(8192, 4, np.float32)
        X = fm.conv_R2FM(a)
        fm.materialize(fm.colSums(X), backend="pallas")
        assert len(mz._PLANS) == 1
        fm.set_conf(vmem_partition_bytes=64 * 1024)
        (s,) = fm.materialize(fm.colSums(X), backend="pallas")
        assert len(mz._PLANS) == 2
        np.testing.assert_allclose(fm.as_np(s).reshape(-1), a.sum(0),
                                   rtol=1e-4)
    finally:
        matrix_mod.VMEM_PARTITION_BYTES = old
        mz.clear_plan_cache()


def test_compile_once_stream_many_per_backend():
    """k-means-style iteration: new centers (Smalls) reuse one cached plan
    per backend — the compile-once/stream-many contract."""
    mz.clear_plan_cache()
    a = _data(2048, 4, np.float32)
    X = fm.conv_R2FM(a)
    with cache_activity() as act:
        for backend in ("xla", "pallas"):
            for it in range(3):
                centers = RNG.normal(size=(3, 4)).astype(np.float32)
                D = fm.inner_prod(X, centers.T, "squared_diff", "sum")
                labels = fm.which_min_row(D)
                fm.materialize(fm.rowsum(X, labels, 3),
                               fm.table_(labels, 3),
                               fm.sum_(fm.rowMins(D)), labels,
                               backend=backend)
    # one entry per backend, not per iteration
    assert_activity(act, misses=2, hits=4)
    assert len(mz._PLANS) == 2
    mz.clear_plan_cache()


def test_resolve_backend():
    assert resolve_backend("xla") == "xla"
    assert resolve_backend("pallas") == "pallas"
    assert resolve_backend("auto") in ("xla", "pallas")
    with pytest.raises(ValueError):
        resolve_backend("tpu2000")


def test_set_conf_backend_roundtrip():
    conf = fm.set_conf(backend="pallas")
    try:
        assert conf["backend"] == "pallas"
        a = _data(256, 3, np.float32)
        X = fm.conv_R2FM(a)
        (s,) = fm.materialize(fm.colSums(X))  # default now pallas
        np.testing.assert_allclose(fm.as_np(s).reshape(-1), a.sum(0),
                                   rtol=1e-4)
    finally:
        fm.set_conf(backend="auto")
