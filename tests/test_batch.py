"""Batch execution layer tests (ISSUE 7 tentpole).

Contract under test: ``fm.batch(...)`` co-schedules independent plans over
shared physical sources onto ONE streaming drive — per group,
``exec_stats()['streams'] == 1`` while every member still counts its own
logical pass, union bytes are read once (vs. k× serially), results match
the serial execution bit-for-bit on every backend × mode cell, a staging
fault mid-group leaves NO member partially registered, per-request
``fm.collect_stats()`` scopes report their own plan's share, and
consecutive identical partition schedules reuse the resident final
partition (``prefetch_reuse_hits``) — solo, batched, and across iterations
under ``fm.inspect_iterations()``.
"""
import numpy as np
import pytest

from helpers_cache import assert_no_partial_results, flaky_matrix
from repro.core import fm
from repro.core import materialize as mz
from repro.core.dag import toposort
from repro.core.fusion import Plan, coschedule, stream_group_key
from repro import storage

RNG = np.random.default_rng(17)

CELLS = [(backend, mode)
         for backend in ("xla", "pallas")
         for mode in ("whole", "stream", "ooc")]


def _x(n=600, p=5, seed=None):
    rng = RNG if seed is None else np.random.default_rng(seed)
    return (rng.normal(size=(n, p)) * 2 + 0.5).astype(np.float32)


@pytest.fixture(autouse=True)
def _small_partitions():
    """Multi-partition streams, fresh plan cache per test."""
    from repro.core import matrix as matrix_mod
    old = matrix_mod.IO_PARTITION_BYTES
    fm.set_conf(io_partition_bytes=4096)
    mz.clear_plan_cache()
    yield
    matrix_mod.IO_PARTITION_BYTES = old
    mz.clear_plan_cache()


@pytest.fixture()
def data_dir(tmp_path, monkeypatch):
    monkeypatch.setitem(storage.registry._CONF, "data_dir", None)
    fm.set_conf(data_dir=str(tmp_path / "fmdata"))
    return tmp_path / "fmdata"


def _requests_over(X):
    """Three independent requests sharing one source: the doc example."""
    return [fm.colMeans(X), (fm.colSds(X), fm.crossprod(X)), fm.sum_(X)]


def _check_oracle(a, res):
    np.testing.assert_allclose(fm.as_np(res[0]).ravel(), a.mean(0),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(fm.as_np(res[1][0]).ravel(),
                               a.std(0, ddof=1), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(
        fm.as_np(res[1][1]), a.T.astype(np.float64) @ a, rtol=2e-3)
    np.testing.assert_allclose(fm.as_scalar(res[2]), a.sum(),
                               rtol=1e-3)


# ---------------------------------------------------------------------------
# The tentpole: parity + 1 stream × k plans
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,mode", CELLS)
def test_batched_equals_serial(backend, mode):
    a = _x()
    X = fm.conv_R2FM(a, host=(mode == "ooc"))
    res = fm.batch(*_requests_over(X), mode=mode, backend=backend)
    _check_oracle(a, res)
    # Serial reference over the same physical source.  The group streams at
    # the MIN member partition rows, so partial-combine order can differ
    # from a solo run by float32 rounding — tight allclose, not bitwise.
    serial = [fm.materialize(fm.colMeans(X), mode=mode, backend=backend)[0],
              fm.materialize(fm.colSds(X), fm.crossprod(X), mode=mode,
                             backend=backend),
              fm.materialize(fm.sum_(X), mode=mode, backend=backend)[0]]
    np.testing.assert_allclose(fm.as_np(res[0]), fm.as_np(serial[0]),
                               rtol=1e-6)
    np.testing.assert_allclose(fm.as_np(res[1][1]), fm.as_np(serial[1][1]),
                               rtol=1e-6)
    np.testing.assert_allclose(fm.as_np(res[2]), fm.as_np(serial[2]),
                               rtol=1e-6)


@pytest.mark.parametrize("mode", ["stream", "ooc"])
def test_one_stream_k_plans(mode):
    a = _x(1200, 6)
    X = fm.conv_R2FM(a, host=(mode == "ooc"))
    mz.reset_exec_stats()
    res = fm.batch(*_requests_over(X), mode=mode)
    st = mz.exec_stats()
    # Three plans, ONE physical sweep: union bytes == one pass over X.
    assert st["streams"] == 1
    assert st["passes"] == 3
    assert st["pass_bytes_in"] == (X.m.nbytes(),)
    _check_oracle(a, res)


def test_serial_streams_kx():
    """The counter-provable win: the same requests serially stream k×."""
    a = _x(1200, 6)
    X = fm.conv_R2FM(a, host=True)
    mz.reset_exec_stats()
    for req in _requests_over(X):
        outs = req if isinstance(req, tuple) else (req,)
        fm.materialize(*outs, mode="ooc")
    st = mz.exec_stats()
    assert st["streams"] == 3 and st["passes"] == 3


def test_batch_disk_tier_single_scan(data_dir):
    """The acceptance shape: k plans over one shared DISK matrix = one
    scan of the file."""
    a = _x(2000, 4, seed=3)
    X = fm.load_dense_matrix(a, "batch_x")
    assert X.m.on_disk
    mz.reset_exec_stats()
    res = fm.batch(*_requests_over(X))
    st = mz.exec_stats()
    assert st["streams"] == 1 and st["passes"] == 3
    assert st["pass_bytes_in"] == (X.m.nbytes(),)
    _check_oracle(a, res)


def test_subset_source_set_rides_superset_stream():
    """A plan over {X} joins the stream of a plan over {X, Y}."""
    a, b = _x(900, 3, seed=4), _x(900, 3, seed=5)
    X = fm.conv_R2FM(a, host=True)
    Y = fm.conv_R2FM(b, host=True)
    mz.reset_exec_stats()
    s_m, m_m = fm.batch(fm.sum_(X * Y), fm.colMeans(X))
    st = mz.exec_stats()
    assert st["streams"] == 1 and st["passes"] == 2
    np.testing.assert_allclose(fm.as_scalar(s_m), (a * b).sum(), rtol=1e-3)
    np.testing.assert_allclose(fm.as_np(m_m).ravel(), a.mean(0), rtol=1e-4,
                               atol=1e-4)


def test_disjoint_sources_stream_separately():
    a, b = _x(900, 3, seed=6), _x(900, 3, seed=7)
    X = fm.conv_R2FM(a, host=True)
    Y = fm.conv_R2FM(b, host=True)
    mz.reset_exec_stats()
    mx, my = fm.batch(fm.colMeans(X), fm.colMeans(Y))
    st = mz.exec_stats()
    assert st["streams"] == 2 and st["passes"] == 2
    np.testing.assert_allclose(fm.as_np(mx).ravel(), a.mean(0), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(fm.as_np(my).ravel(), b.mean(0), rtol=1e-4,
                               atol=1e-4)


def test_multipass_member_batches_round_zero():
    """scale(X) (two passes) batched with colMeans(X) (one pass): round 0
    groups both pass-0s onto one stream, round 1 runs scale's sweep."""
    a = _x(800, 4, seed=8)
    X = fm.conv_R2FM(a, host=True)
    mz.reset_exec_stats()
    z_m, mu_m = fm.batch(fm.scale(X), fm.colMeans(X))
    st = mz.exec_stats()
    assert st["passes"] == 3          # scale's two + colMeans' one
    assert st["streams"] == 2         # round 0 shared, round 1 solo
    ref = (a - a.mean(0)) / a.std(0, ddof=1)
    np.testing.assert_allclose(fm.as_np(z_m), ref, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(fm.as_np(mu_m).ravel(), a.mean(0),
                               rtol=1e-4, atol=1e-4)


def test_collector_form_and_handles():
    a = _x(500, 3, seed=9)
    X = fm.conv_R2FM(a)
    with fm.batch() as b:
        h1 = b.add(fm.colMeans(X).m)
        h2 = b.add(fm.colSds(X).m, fm.crossprod(X).m)
    np.testing.assert_allclose(
        np.asarray(h1.value.logical_data()).ravel(), a.mean(0),
        rtol=1e-4, atol=1e-4)
    sds, ctp = h2.value
    np.testing.assert_allclose(np.asarray(sds.logical_data()).ravel(),
                               a.std(0, ddof=1), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ctp.logical_data()),
                               a.T.astype(np.float64) @ a, rtol=2e-3)
    with pytest.raises(RuntimeError, match="already executed"):
        b.add(fm.colMeans(X).m)


# ---------------------------------------------------------------------------
# Attribution: per-request scopes see their own share
# ---------------------------------------------------------------------------

def test_per_request_scope_attribution():
    a = _x(1500, 4, seed=10)
    X = fm.conv_R2FM(a, host=True)
    mz.reset_exec_stats()
    b = fm.batch()
    with fm.collect_stats("req0") as sc0:
        h0 = b.add(fm.colMeans(X).m)
    with fm.collect_stats("req1") as sc1:
        h1 = b.add(fm.colSds(X).m, fm.crossprod(X).m)
    b.run()
    for sc in (sc0, sc1):
        s = sc.stats()
        # Each request's scope reports ITS plan: one pass, one stream,
        # its own bytes — not the group totals.
        assert s["passes"] == 1
        assert s["streams"] == 1
        assert s["bytes_streamed"] == X.m.nbytes()
        assert s["pass_bytes_in"] == (X.m.nbytes(),)
        assert s["partition_steps"] >= 1
    # The root scope saw the group: 2 logical passes, 1 physical stream.
    st = mz.exec_stats()
    assert st["passes"] == 2 and st["streams"] == 1
    assert float(np.asarray(h0.value.logical_data()).ravel()[0]) == \
        pytest.approx(a.mean(0)[0], rel=1e-4)
    assert h1.value is not None


# ---------------------------------------------------------------------------
# Fault injection: no partial sinks for ANY member
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prefetch", [False, True])
def test_interrupted_group_leaves_no_member_partial(prefetch):
    a = _x(800, 4, seed=11)
    Xm, store = flaky_matrix(a, 1)
    X = fm.FM(Xm)
    reqs = [fm.colMeans(X), fm.crossprod(X)]
    nodes = [n for r in reqs for n in toposort([r.m.node])]
    with pytest.raises(Exception, match="staging failure"):
        fm.batch(*reqs, prefetch=prefetch)
    assert store.failed
    # NO member of the interrupted group registered anything.
    assert_no_partial_results(*nodes)
    store.heal()
    mu_m, ctp_m = fm.batch(*reqs, prefetch=prefetch)
    np.testing.assert_allclose(fm.as_np(mu_m).ravel(), a.mean(0),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(fm.as_np(ctp_m),
                               a.T.astype(np.float64) @ a, rtol=2e-3)


# ---------------------------------------------------------------------------
# Partition reuse: resident final partition served instead of re-read
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prefetch", [False, True])
def test_intra_plan_partition_reuse(prefetch):
    """PCA's shape — crossprod(X - colMeans(X)): the pass-2 contraction
    streams X under the SAME partition schedule as the pass-1 moments, so
    the final partition must not be re-staged.  (A sweep pass with an
    n-row OUTPUT halves its partition rows and legitimately re-reads.)"""
    a = _x(1000, 4, seed=12)
    X = fm.conv_R2FM(a, host=True)
    C = fm.crossprod(X - fm.colMeans(X))
    plan = Plan([C.m])
    assert plan.n_passes == 2
    assert plan.passes[0].partition_rows == plan.passes[1].partition_rows
    mz.reset_exec_stats()
    (cm,) = fm.materialize(C, mode="ooc", prefetch=prefetch)
    st = mz.exec_stats()
    assert st["prefetch_reuse_hits"] == 1
    c = a - a.mean(0)
    np.testing.assert_allclose(fm.as_np(cm), c.T.astype(np.float64) @ c,
                               rtol=2e-3)


def test_iteration_scope_reuse_across_materializes():
    """Inside fm.inspect_iterations(), iteration i+1's stream starts from
    iteration i's resident final partition; outside, residency is dropped."""
    a = _x(1000, 4, seed=13)
    X = fm.conv_R2FM(a, host=True)
    mz.reset_exec_stats()
    with fm.inspect_iterations():
        for _ in range(3):
            fm.materialize(fm.colMeans(X), mode="ooc", reuse_plans=False)
    st = mz.exec_stats()
    assert st["prefetch_reuse_hits"] == 2    # iterations 2 and 3
    # Residency must not outlive the scope.
    mz.reset_exec_stats()
    fm.materialize(fm.colSds(X), mode="ooc")
    assert mz.exec_stats()["prefetch_reuse_hits"] == 0


def test_iteration_scope_reuse_across_batches():
    a = _x(1000, 4, seed=14)
    X = fm.conv_R2FM(a, host=True)
    mz.reset_exec_stats()
    with fm.inspect_iterations():
        fm.batch(fm.colMeans(X), fm.sum_(X))
        fm.batch(fm.colMeans(X * 2.0), fm.sum_(X * 0.5))
    st = mz.exec_stats()
    assert st["streams"] == 2 and st["passes"] == 4
    assert st["prefetch_reuse_hits"] == 1


# ---------------------------------------------------------------------------
# Co-schedule unit behavior + explain view
# ---------------------------------------------------------------------------

def test_coschedule_groups_by_subset():
    x, y, z = object(), object(), object()
    keys = [(100, frozenset({id(x)})),
            (100, frozenset({id(x), id(y)})),
            (100, frozenset({id(z)})),
            (200, frozenset({id(x)}))]
    groups = coschedule(keys)
    assert sorted(map(sorted, groups)) == [[0, 1], [2], [3]]


def test_stream_group_key_is_physical_identity():
    a = _x(300, 3, seed=15)
    X = fm.conv_R2FM(a, host=True)
    k1 = stream_group_key(Plan([fm.colMeans(X).m]).passes[0])
    k2 = stream_group_key(Plan([fm.colSds(X).m]).passes[0])
    assert k1 == k2


def test_explain_batch_group_view():
    a = _x(400, 3, seed=16)
    X = fm.conv_R2FM(a, host=True)
    out = fm.explain_batch(fm.colMeans(X),
                           (fm.colSds(X), fm.crossprod(X)))
    assert "members=2" in out
    assert "once" in out and "serially" in out
    # Nothing executed, nothing registered.
    assert fm.colMeans(X).is_virtual


def test_batch_trace_has_stream_spans():
    a = _x(600, 3, seed=17)
    X = fm.conv_R2FM(a, host=True)
    with fm.trace():
        fm.batch(fm.colMeans(X), fm.crossprod(X))
    names = [e["name"] for e in fm.trace_events()]
    assert "batch" in names
    assert names.count("stream") == 1


# ---------------------------------------------------------------------------
# Fuzz: random 2–3-plan batches over shared sources == serial oracle
# ---------------------------------------------------------------------------

def _rand_request(rng, X, Y):
    """One random lazy request over the shared sources."""
    base = [X, Y, X + Y, X * 0.5, fm.sqrt(fm.abs_(X) + 1.0)][rng.integers(5)]
    op = rng.integers(4)
    if op == 0:
        return fm.colMeans(base)
    if op == 1:
        return fm.sum_(base)
    if op == 2:
        return fm.crossprod(base)
    return fm.colMaxs(base)


def _oracle(req_fm, X_a, Y_a):
    """Numpy value of a request built by _rand_request."""
    (m,) = fm.materialize(req_fm, mode="ooc")
    return fm.as_np(m)


@pytest.mark.parametrize("seed", range(6))
def test_batch_fuzz_matches_serial(seed):
    rng = np.random.default_rng(100 + seed)
    a = (rng.normal(size=(700, 4)) * 2).astype(np.float32)
    b = (rng.normal(size=(700, 4)) + 1).astype(np.float32)
    X = fm.conv_R2FM(a, host=True)
    Y = fm.conv_R2FM(b, host=True)
    k = int(rng.integers(2, 4))
    reqs = [_rand_request(rng, X, Y) for _ in range(k)]
    batched = fm.batch(*reqs)
    for req, got in zip(reqs, batched):
        want = _oracle(req, a, b)
        np.testing.assert_allclose(fm.as_np(got), want, rtol=2e-3,
                                   atol=1e-4)
