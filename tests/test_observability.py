"""Observability layer: span tracer, scoped metrics, plan explain (ISSUE 6).

What the layer must guarantee:
  * spans nest (children lie inside their parent's interval) and the
    disabled tracer records nothing at near-zero cost,
  * ``fm.collect_stats()`` isolates per-request telemetry even when two
    materializes run CONCURRENTLY on different threads — including the
    counters recorded on the prefetcher's background thread,
  * the acceptance trace: an out-of-core two-pass ``scale(X, save='disk')``
    carries per-pass/per-partition ``stage``/``prefetch_wait``/
    ``device_step``/``combine`` spans, the prefetch thread on its own
    track, and exactly one ``epilogue`` span per pass that schedules one,
  * ``fm.explain`` output is stable (golden) for the two-pass scale plan,
  * prefetch-thread failures surface with partition range + source name,
  * ``exec_stats()`` stays a faithful compatibility view of the registry.
"""
import collections
import json
import re
import threading

import numpy as np
import pytest

from repro import storage
from repro.core import fm
from repro.core import materialize as mz
from repro.core import matrix as matrix_mod
from repro.observability import metrics
from repro.observability.trace import TRACER


@pytest.fixture()
def data_dir(tmp_path, monkeypatch):
    monkeypatch.setitem(storage.registry._CONF, "data_dir", None)
    fm.set_conf(data_dir=str(tmp_path / "fmdata"))
    return tmp_path / "fmdata"


@pytest.fixture()
def small_partitions():
    """Tiny I/O partitions so even small matrices stream multi-partition."""
    old = matrix_mod.IO_PARTITION_BYTES
    fm.set_conf(io_partition_bytes=4096)
    mz.clear_plan_cache()
    yield
    matrix_mod.IO_PARTITION_BYTES = old
    mz.clear_plan_cache()


def _arr(n=800, p=4, seed=0):
    return np.random.default_rng(seed).normal(size=(n, p)).astype(np.float32)


# ---------------------------------------------------------------------------
# Span tracer
# ---------------------------------------------------------------------------

def test_span_nesting_and_containment():
    TRACER.start()
    with TRACER.span("outer", idx=1):
        with TRACER.span("inner"):
            pass
        with TRACER.span("inner"):
            pass
    TRACER.stop()
    evs = TRACER.events()
    # Spans record on exit, so both children precede their parent.
    assert [e["name"] for e in evs] == ["inner", "inner", "outer"]
    outer = evs[-1]
    assert outer["args"] == {"idx": 1}
    for inner in evs[:2]:
        assert inner["ts"] >= outer["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]


def test_disabled_tracer_records_nothing():
    with TRACER.span("x", a=1):
        pass
    TRACER.record("y", 0.0, 1.0)
    assert TRACER.events() == []
    # Disabled spans are one shared null object — no per-span allocation.
    assert TRACER.span("x") is TRACER.span("y")


def test_chrome_trace_export(tmp_path):
    with fm.trace():
        with TRACER.span("work", rows=7):
            pass
    path = tmp_path / "trace.json"
    fm.trace_export(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    complete = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert [e["name"] for e in complete] == ["work"]
    assert complete[0]["dur"] >= 0 and complete[0]["args"] == {"rows": 7}
    assert any(m["name"] == "thread_name" for m in meta)
    assert any(m["name"] == "process_name" for m in meta)


def test_trace_context_manager_resets_by_default():
    with fm.trace():
        with TRACER.span("first"):
            pass
    assert [e["name"] for e in fm.trace_events()] == ["first"]
    with fm.trace():
        pass
    assert fm.trace_events() == []          # reset=True dropped "first"
    assert not TRACER.enabled               # and the tracer is off again


# ---------------------------------------------------------------------------
# Scoped metrics
# ---------------------------------------------------------------------------

def test_collect_stats_isolates_concurrent_materializes(small_partitions):
    """Two threads materialize different matrices at once; each scope must
    see only its own counters — including stage bytes recorded on each
    materialize's own prefetcher thread."""
    a = _arr(n=2048, p=4, seed=1)
    b = _arr(n=4096, p=4, seed=2)
    results = {}
    barrier = threading.Barrier(2)

    def work(tag, arr):
        X = fm.conv_R2FM(arr, host=True)
        G = fm.crossprod(X)
        barrier.wait()
        with fm.collect_stats(tag) as scope:
            fm.materialize(G, mode="stream")
        results[tag] = scope.stats()

    threads = [threading.Thread(target=work, args=("a", a)),
               threading.Thread(target=work, args=("b", b))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    for tag, arr in (("a", a), ("b", b)):
        st = results[tag]
        assert st["materialize_calls"] == 1
        assert st["passes"] == 1
        assert st["pass_bytes_in"] == (arr.nbytes,)
        # Prefetch-thread staging attributed to the right scope.
        assert st["stage_bytes_read"] == arr.nbytes
    # b is twice as many rows as a: twice the partition steps, per scope.
    assert results["a"]["partition_steps"] > 1
    assert results["b"]["partition_steps"] == \
        2 * results["a"]["partition_steps"]


def test_pass_bytes_scoped_per_execution_and_set_on_cache_hit():
    mz.reset_exec_stats()
    mz.clear_plan_cache()
    a = _arr(n=128)
    X = fm.conv_R2FM(a)
    fm.materialize(fm.crossprod(X))
    assert mz.exec_stats()["pass_bytes_in"] == (a.nbytes,)
    # Re-executing the cached plan must still publish its own bytes.
    with fm.collect_stats() as scope:
        fm.materialize(fm.crossprod(X))
    assert scope.stats()["pass_bytes_in"] == (a.nbytes,)
    st = mz.exec_stats()
    assert st["plan_cache_hits"] == 1 and st["plan_cache_misses"] == 1
    assert metrics.stats()["plan_cache_hit_ratio"] == 0.5


def test_exec_stats_compat_view():
    mz.reset_exec_stats()
    mz.clear_plan_cache()
    X = fm.conv_R2FM(_arr(n=200))
    fm.materialize(fm.scale(X))
    st = mz.exec_stats()
    assert st["materialize_calls"] == 1
    assert st["passes"] == 2                     # scale is the two-pass plan
    assert st["epilogue_launches"] >= 1
    assert len(st["pass_bytes_in"]) == 2
    for key in mz.EXEC_COUNTERS:
        assert isinstance(st[key], int), key
    # The registry view carries the derived telemetry too.
    full = metrics.stats()
    assert 0.0 <= full["prefetch_wait_frac"] <= 1.0
    assert full["stream_bandwidth_bytes_s"] >= 0.0


# ---------------------------------------------------------------------------
# Acceptance: out-of-core two-pass scale under the tracer
# ---------------------------------------------------------------------------

def test_ooc_disk_scale_trace(data_dir, small_partitions):
    a = _arr(n=1024, p=4, seed=3)
    X = fm.load_dense_matrix(a, "trace_x")
    Z = fm.scale(X, save="disk")
    mz.reset_exec_stats()
    with fm.trace():
        fm.materialize(Z)
    st = mz.exec_stats()
    evs = fm.trace_events()
    counts = collections.Counter(e["name"] for e in evs)

    assert counts["materialize"] == 1
    assert counts["pass"] == st["passes"] == 2
    assert counts["partition"] == st["partition_steps"] > 2
    for required in ("stage", "prefetch_wait", "device_step", "combine"):
        assert counts[required] > 0, required
    # Exactly one epilogue span per pass that schedules one.
    assert counts["epilogue"] == st["epilogue_launches"] == 1

    # The prefetcher's staging runs on its own track, not the main thread.
    by_name = {}
    for e in evs:
        by_name.setdefault(e["name"], []).append(e)
    main_tid = threading.get_ident()
    stage_tids = {e["tid"] for e in by_name["stage"]}
    assert main_tid not in stage_tids
    assert TRACER.chrome_trace() and any(
        m.get("args", {}).get("name") == "fm-prefetch"
        for m in TRACER.chrome_trace()["traceEvents"] if m["ph"] == "M")

    # Every partition span falls inside some pass span's interval.
    passes = [(p["ts"], p["ts"] + p["dur"]) for p in by_name["pass"]]
    for part in by_name["partition"]:
        lo, hi = part["ts"], part["ts"] + part["dur"]
        assert any(p0 <= lo and hi <= p1 for p0, p1 in passes)
    # And the device_step/combine spans inside some partition span.
    parts = [(p["ts"], p["ts"] + p["dur"]) for p in by_name["partition"]]
    for name in ("device_step", "combine"):
        for e in by_name[name]:
            lo, hi = e["ts"], e["ts"] + e["dur"]
            assert any(p0 <= lo and hi <= p1 for p0, p1 in parts), name


# ---------------------------------------------------------------------------
# Prefetch error context (satellite)
# ---------------------------------------------------------------------------

def test_prefetch_error_carries_partition_and_source():
    class Exploding:
        name = "bad_matrix"

        def block(self, start, stop):
            raise OSError("bad sector")

    pf = storage.PartitionPrefetcher([(0, Exploding())], 8, 64)
    with pytest.raises(storage.PrefetchError,
                       match=r"rows \[0, 8\) of source 'bad_matrix'"):
        for _ in pf:
            pass
    pf.close()


def test_prefetch_error_names_unnamed_source_by_type():
    class Nameless:
        def block(self, start, stop):
            raise ValueError("boom")

    pf = storage.PartitionPrefetcher([(0, Nameless())], 4, 8)
    with pytest.raises(storage.PrefetchError, match=r"source 'Nameless'"):
        for _ in pf:
            pass
    pf.close()


# ---------------------------------------------------------------------------
# fm.explain (golden)
# ---------------------------------------------------------------------------

EXPLAIN_GOLDEN = """\
Plan: passes=2 long_dim=100 backend=xla
  cost: flops=2.700e+03 bytes_in=2.3 KiB bytes_out=1.2 KiB
pass 0: io_partition_rows=16384
  source leaf#N: 100x3 float32 tier=device streamed 1.2 KiB/pass (read once for 3 leaves)
  seg#N [sink_update] root=agg.col[sum] nodes=1 width=3 dtype=float32 flops/row=3.0 block_rows=32768
    -> xla generic trace
  seg#N [sink_update] root=agg.col[sum] nodes=2 width=3 dtype=float32 flops/row=6.0 block_rows=32768
    -> xla generic trace
  seg#N [sink_update] root=agg.col[sum] nodes=1 width=3 dtype=float32 flops/row=3.0 block_rows=32768
    -> xla generic trace
  seg#N [epilogue] root=sapply#N nodes=7 width=3 dtype=float32 flops/row=48.0 block_rows=16384
    -> post-merge epilogue (single launch per pass)
pass 1: io_partition_rows=32768
  bindings (from earlier passes): mapply#N, sapply#N
  source leaf#N: 100x3 float32 tier=device streamed 1.2 KiB/pass
  seg#N [row_local] root=mapply_row#N nodes=2 width=3 dtype=float32 flops/row=15.0 block_rows=16384
    -> xla generic trace"""


def test_explain_golden_two_pass_scale():
    old_io = matrix_mod.IO_PARTITION_BYTES
    old_vmem = matrix_mod.VMEM_PARTITION_BYTES
    fm.set_conf(io_partition_bytes=1 << 20, vmem_partition_bytes=1 << 20)
    try:
        X = fm.conv_R2FM(np.ones((100, 3), np.float32))
        text = fm.explain(fm.scale(X), backend="xla")
    finally:
        matrix_mod.IO_PARTITION_BYTES = old_io
        matrix_mod.VMEM_PARTITION_BYTES = old_vmem
    assert re.sub(r"#\d+", "#N", text) == EXPLAIN_GOLDEN


def test_explain_pallas_dispatch_reasons():
    X = fm.conv_R2FM(_arr(n=256))
    text = fm.explain(fm.crossprod(X), backend="pallas")
    assert "pallas:gram (claimed by " in text
    assert "backend=pallas" in text


def test_explain_nothing_virtual():
    X = fm.conv_R2FM(_arr(n=16))
    assert "already materialized" in fm.explain(X)


def test_plan_explain_method_matches_fm_explain():
    from repro.core.fusion import Plan
    X = fm.conv_R2FM(_arr(n=64))
    Z = fm.scale(X)
    assert Plan([Z.m]).explain(backend="xla") == fm.explain(
        Z, backend="xla")
