"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates a REDUCED config of the same family
and runs one forward/train step on CPU asserting output shapes + no NaNs,
plus the serving contract: prefill+decode at position S must match the full
forward at position S (exactness of every cache type: KV, SSM state, conv
window, cross-KV, shared-block KV).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_for_smoke
from repro.configs.base import SHAPES
from repro.models import build, input_specs, zoo
from repro.models.base import tree_unbox

pytestmark = pytest.mark.slow  # ~90s: full arch sweep forward+train

RNG = np.random.default_rng(0)
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B, S):
    b = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32),
         "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        b["patch_embs"] = jnp.asarray(
            RNG.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            RNG.normal(size=(B, cfg.enc_len, cfg.d_model)), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_shapes(arch):
    cfg = reduced_for_smoke(get_config(arch))
    model = build(cfg)
    params, axes = tree_unbox(model.init(KEY))
    # axes tree mirrors params tree exactly (the sharding contract)
    assert (jax.tree_util.tree_structure(params)
            == jax.tree_util.tree_structure(axes))
    batch = _batch(cfg, 2, 64)
    loss, metrics = jax.jit(model.forward)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # one grad step produces finite, shape-preserving updates
    g = jax.grad(lambda p: model.forward(p, batch)[0])(params)
    for leaf, gleaf in zip(jax.tree_util.tree_leaves(params),
                           jax.tree_util.tree_leaves(g)):
        assert leaf.shape == gleaf.shape
        assert np.isfinite(np.asarray(gleaf, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced_for_smoke(get_config(arch))
    model = build(cfg)
    params, _ = tree_unbox(model.init(KEY))
    B, S = 2, 33
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    maxlen = S + 8 + (cfg.n_patches or 0)
    batch = {k: v for k, v in _batch(cfg, B, S).items() if k != "labels"}
    batch["tokens"] = toks[:, :S]
    batch_full = dict(batch, tokens=toks)

    _, logits_full = jax.jit(
        lambda p, b: model.prefill(p, b, maxlen))(params, batch_full)
    cache, _ = jax.jit(
        lambda p, b: model.prefill(p, b, maxlen))(params, batch)
    _, logits_dec = jax.jit(model.decode)(params, cache, toks[:, S:S + 1])
    a = np.asarray(logits_full, np.float32).reshape(B, -1)
    b = np.asarray(logits_dec, np.float32).reshape(B, -1)
    err = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert err < 2e-2, f"{arch}: decode diverges from forward ({err:.2e})"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    shapes = cfg.shapes()
    if cfg.supports_long_context:
        assert "long_500k" in shapes
    else:
        assert "long_500k" not in shapes
    for name, sh in shapes.items():
        spec = input_specs(cfg, sh)
        assert spec["kind"] in ("train", "prefill", "decode")
        for k, v in spec["batch"].items():
            assert all(d > 0 for d in v.shape), (arch, name, k)
        if spec["kind"] != "decode":
            assert set(spec["axes"]) == set(spec["batch"])


@pytest.mark.parametrize("arch", ["qwen2-72b", "arctic-480b", "mamba2-1.3b",
                                  "zamba2-7b", "whisper-medium"])
def test_full_config_abstract_params(arch):
    """FULL configs exercised via ShapeDtypeStruct only — no allocation."""
    cfg = get_config(arch)
    model = build(cfg)
    shapes, axes = model.abstract_params()
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))
    approx = cfg.n_params()
    assert 0.5 < n / approx < 2.0, (arch, n, approx)


def test_param_counts_sane():
    expected = {"qwen2-72b": 72e9, "granite-8b": 8e9, "llama3.2-3b": 3.2e9,
                "qwen2-0.5b": 0.5e9, "mamba2-1.3b": 1.3e9,
                "arctic-480b": 480e9, "whisper-medium": 0.76e9}
    for arch, target in expected.items():
        cfg = get_config(arch)
        n = cfg.n_params()
        assert 0.5 < n / target < 1.7, (arch, n / 1e9)
