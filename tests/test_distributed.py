"""Distribution-layer tests.

Sharding-policy unit tests run in-process; anything needing multiple
devices runs in a subprocess with its own XLA_FLAGS (the main process must
keep the default 1-device view — see dryrun.py's device-count contract)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.sharding import resolve

pytestmark = pytest.mark.slow  # ~20s: subprocess mesh smoke runs


class _FakeMesh:
    def __init__(self, shape, names):
        import numpy as np
        self.axis_names = names
        self.devices = np.empty(shape)


MESH1 = _FakeMesh((16, 16), ("data", "model"))
MESH2 = _FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_param_fsdp_tp():
    spec = resolve("d_model|d_ff", (8192, 29568), MESH1)
    assert tuple(spec) == ("data", "model")


def test_head_divisibility_fallback():
    # paligemma: 8 q-heads cannot shard 16 ways -> replicate heads
    spec = resolve("d_model|heads", (2048, 8 * 256), MESH1)
    assert tuple(spec) == ("data", "model")  # 2048 divisible both ways
    spec = resolve("batch|seq|act_heads|head_dim", (16, 128, 8, 256), MESH1)
    assert spec[2] is None                   # 8 % 16 != 0 -> replicated


def test_batch_prefers_pod_data():
    spec = resolve("batch|seq", (256, 4096), MESH2)
    assert spec[0] == ("pod", "data")


def test_batch_one_gives_axes_to_kv_seq():
    # long_500k: batch=1 -> kv_seq takes (data, model)
    spec = resolve("batch|kv_seq|kv_heads|head_dim", (1, 524288, 32, 112), MESH1)
    assert spec[0] is None
    assert spec[1] == ("data", "model")


def test_kv_seq_model_when_batch_takes_data():
    spec = resolve("batch|kv_seq|kv_heads|head_dim", (128, 32768, 8, 128), MESH1)
    assert spec[0] == "data"
    assert spec[1] == "model"


def test_no_axis_used_twice():
    spec = resolve("d_ff|vocab", (29568, 152064), MESH1)
    used = [s for s in spec if s]
    assert len(set(used)) == len(used)


def test_scalar_replicated():
    assert tuple(resolve("", (), MESH1)) == ()


_SUBPROCESS_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    import json

    # 1) data-parallel GenOps: sharded whole-mode == host reference
    from repro.core import fm
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(8, model=2)
    rng = np.random.default_rng(0)
    Xn = rng.normal(size=(512, 6)).astype(np.float32)
    X = fm.conv_R2FM(Xn)
    (g, s) = fm.materialize(fm.crossprod(X), fm.colSums(X), mesh=mesh)
    assert np.allclose(fm.as_np(g), Xn.T @ Xn, rtol=1e-3)
    assert np.allclose(fm.as_np(s).ravel(), Xn.sum(0), rtol=1e-3)

    # 2) sharded train step == single-device train step (llama reduced)
    from repro.configs import get_config, reduced_for_smoke
    from repro.models import zoo
    from repro.models.base import tree_unbox
    from repro.distributed import sharding as shd
    from repro.launch.steps import build_train_step
    from repro.optim import adam

    cfg = reduced_for_smoke(get_config("llama3.2-3b"))
    model = zoo.build(cfg)
    params, axes = tree_unbox(model.init(jax.random.PRNGKey(0)))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)), jnp.int32)}
    opt = adam.init(params)
    step = build_train_step(model)

    loss_1dev = jax.jit(step)(params, opt, batch)[2]["loss"]

    with shd.use_mesh(mesh):
        shapes = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        p_sh = shd.tree_shardings(axes, shapes, mesh)
        params_s = jax.tree_util.tree_map(jax.device_put, params, p_sh)
        opt_s = adam.init(params_s)
        b_sh = {k: shd.sharding_for("batch|seq", v.shape, mesh)
                for k, v in batch.items()}
        batch_s = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
        loss_8dev = jax.jit(step)(params_s, opt_s, batch_s)[2]["loss"]

    rel = abs(float(loss_1dev) - float(loss_8dev)) / abs(float(loss_1dev))
    assert rel < 1e-3, (float(loss_1dev), float(loss_8dev))
    print(json.dumps({"ok": True, "loss": float(loss_8dev)}))
""")


def test_multidevice_equivalence():
    """8 fake devices: sharded GenOps + sharded train step match 1-device."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_TEST],
                          capture_output=True, text=True, env=env,
                          timeout=600, cwd=os.path.dirname(
                              os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert '"ok": true' in proc.stdout


_MESH_GRID_TEST = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%d" % {ndev})
    import json, threading, time
    import numpy as np, jax

    from repro.core import fm
    from repro.core import materialize as mz
    from repro.core.matrix import DenseStore, FMMatrix
    from repro.launch.mesh import make_host_mesh
    from repro import storage

    NDEV = {ndev}
    assert len(jax.devices()) == NDEV
    mesh = make_host_mesh(NDEV)

    rng = np.random.default_rng(7)
    A = rng.normal(size=(512, 6)).astype(np.float32)
    fm.set_conf(io_partition_bytes=2048)   # 512x6 f32 -> >= 8 partitions

    def run_cases(X, mode):
        return [
            ("colMeans", fm.as_np(fm.materialize(fm.colMeans(X),
                                                 mode=mode)[0])),
            ("colSds", fm.as_np(fm.materialize(fm.colSds(X),
                                               mode=mode)[0])),
            ("crossprod", fm.as_np(fm.materialize(fm.crossprod(X),
                                                  mode=mode)[0])),
            ("scale", fm.as_np(fm.materialize(fm.scale(X),
                                              mode=mode)[0])),
        ]

    # Single-device baselines (no mesh configured).
    base = {}
    for mode, mk in (("whole", "mem"), ("stream", "mem"), ("ooc", "disk")):
        X = fm.conv_R2FM(A)
        if mk == "disk":
            X = fm.persist(X, tier="disk")
        base[mode] = run_cases(X, mode)

    # Sharded runs: the engine-wide conf mesh (fm.set_conf) for stream/ooc,
    # the explicit materialize(mesh=) argument for whole — both entry
    # points must key the plan cache and shard identically.
    for mode, mk in (("whole", "mem"), ("stream", "mem"), ("ooc", "disk")):
        X = fm.conv_R2FM(A)
        if mk == "disk":
            X = fm.persist(X, tier="disk")
        if mode == "whole":
            got = [(nm, fm.as_np(fm.materialize(getattr(fm, nm)(X)
                                                if nm != "scale"
                                                else fm.scale(X),
                                                mode=mode, mesh=mesh)[0]))
                   for nm, _ in base[mode]]
        else:
            fm.set_conf(mesh=mesh)
            fm.reset_exec_stats()
            got = run_cases(X, mode)
            st = fm.exec_stats()
            assert st["shards"] > 0 and st["shards"] % NDEV == 0, \\
                (mode, st["shards"])
            fm.set_conf(mesh=False)
        for (nm, want), (nm2, have) in zip(base[mode], got):
            assert nm == nm2
            assert np.allclose(want, have, rtol=1e-4, atol=1e-4), \\
                (mode, nm, np.abs(want - have).max())

    # One combine-merge per shard boundary: a solo single-pass stream
    # materialize merges exactly shards-1 times.
    fm.set_conf(mesh=mesh)
    fm.reset_exec_stats()
    X = fm.conv_R2FM(A)
    (g,) = fm.materialize(fm.crossprod(X), mode="stream")
    st = fm.exec_stats()
    assert st["shards"] == NDEV, st
    assert st["shard_merges"] == NDEV - 1, st
    assert len(st["shard_bytes_in"]) == NDEV
    assert sum(st["shard_bytes_in"]) == A.nbytes
    assert np.allclose(fm.as_np(g), A.T @ A, rtol=1e-4, atol=1e-3)

    # Write-through save='disk': every shard's rows land in ONE store.
    D = fm.persist(fm.conv_R2FM(A), tier="disk")
    (S,) = fm.materialize(fm.scale(D, save="disk"), mode="ooc")
    ref = (A - A.mean(0)) / A.std(0, ddof=1)
    assert np.allclose(fm.as_np(S), ref, rtol=1e-3, atol=1e-3)

    # Grouped streams shard too (fm.batch): one stream, NDEV shards.
    fm.reset_exec_stats()
    X = fm.conv_R2FM(A)
    means, (sds, ctp) = fm.batch(fm.colMeans(X),
                                 (fm.colSds(X), fm.crossprod(X)),
                                 mode="stream")
    st = fm.exec_stats()
    assert st["streams"] == 1 and st["shards"] == NDEV, st
    assert np.allclose(fm.as_np(means), A.mean(0), atol=1e-4)
    assert np.allclose(fm.as_np(ctp), A.T @ A, rtol=1e-4, atol=1e-3)

    # Interrupted shard: one shard's staging fails mid-sweep -> the whole
    # materialize fails, NO sinks register, and no prefetcher worker or
    # staged partition outlives the failure.
    class FlakyStore(DenseStore):
        def __init__(self, data, fail_after):
            super().__init__(np.asarray(data))
            self.fail_after = fail_after
            self.reads = 0
            self._lk = threading.Lock()
        def block(self, start, stop):
            with self._lk:
                self.reads += 1
                n = self.reads
            if n > self.fail_after:
                raise RuntimeError("injected shard staging failure")
            return super().block(start, stop)

    n_threads0 = threading.active_count()
    Xf = FMMatrix(A.shape, A.dtype, store=FlakyStore(A, 2), name="flaky")
    G = fm.crossprod(fm.FM(Xf) * 2.0)
    try:
        fm.materialize(G, mode="stream")
        raise SystemExit("expected injected failure")
    except RuntimeError:
        pass
    assert G.m.is_virtual, "partial sink registered"
    assert getattr(G.m, "cached_store", None) is None
    deadline = time.time() + 10
    while time.time() < deadline and (
            storage.live_prefetchers() or
            threading.active_count() > n_threads0):
        time.sleep(0.05)
    assert storage.live_prefetchers() == [], "prefetcher leaked"
    assert storage.staged_leaks() == [], "staged partitions leaked"
    assert threading.active_count() <= n_threads0, "shard thread leaked"

    fm.set_conf(mesh=False)
    print(json.dumps({"ok": True, "ndev": NDEV}))
""")


@pytest.mark.parametrize("ndev", [1, 2, 8])
def test_mesh_parity_grid(ndev):
    """Sharded materialize == single-device across algorithms x modes,
    with exact shard accounting, under 1/2/8 forced host devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_GRID_TEST.replace("{ndev}", str(ndev))],
        capture_output=True, text=True, env=env, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert '"ok": true' in proc.stdout


def test_dryrun_smoke_subprocess():
    """A tiny end-to-end dry-run (reduced arch, 8-device mesh) proving the
    lowering/compile/analysis pipeline works without the 512-device env."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, jax
        from repro.configs import get_config, reduced_for_smoke
        from repro.configs.base import ShapeSpec
        from repro.models import zoo
        from repro.launch.mesh import make_host_mesh
        from repro.launch.steps import lower_cell
        from repro.launch.hlo_analysis import analyze
        import dataclasses as dc
        cfg = dc.replace(reduced_for_smoke(get_config("llama3.2-3b")),
                         grad_accum=2)
        model = zoo.build(cfg)
        mesh = make_host_mesh(8, model=2)
        shape = ShapeSpec("t", 128, 8, "train")
        compiled = lower_cell(model, shape, mesh).compile()
        la = analyze(compiled.as_text())
        assert la["dot_flops"] > 0
        print(json.dumps({"ok": True, "flops": la["dot_flops"]}))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, env=env,
                          timeout=600, cwd=os.path.dirname(
                              os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert '"ok": true' in proc.stdout
