"""Async multi-tenant serving layer tests (ISSUE 8 tentpole).

Contract under test: ``fm.serve()`` / `Engine` accepts lazy-DAG requests
from many threads, holds them in an admission window, and co-schedules
same-source strangers onto ONE streaming drive — per window
``exec_stats()['streams'] == 1`` with every request counting its own
logical pass, total bytes strictly below naive serial execution, correct
per-request ``fm.collect_stats()`` attribution, NO partial sinks when a
member fails mid-group, and mid-stream admission of a late same-group
plan at the next partition boundary (with an exact catch-up of the
missed prefix).  Plus the ISSUE 8 thread-safety audit regressions: plan
cache under concurrent LRU/borrow, lazy data-dir init, lazy program
compile, and concurrent materialize through one borrowed template.
"""
import threading

import numpy as np
import pytest

from helpers_cache import FlakyStore, StagingFault, assert_no_partial_results, \
    flaky_matrix
from repro.core import fm
from repro.core import materialize as mz
from repro.core import batch as batch_mod
from repro.core.fusion import Plan
from repro.core.matrix import DenseStore, FMMatrix
from repro.core.serve import Engine, _Gate
from repro import storage
from repro.observability import metrics
from repro.storage.prefetch import PrefetchError, negotiate_depth

# A staging fault may surface raw (inline staging) or wrapped by the
# prefetch worker, depending on the prefetch heuristic.
FAULTS = (StagingFault, PrefetchError)

RNG = np.random.default_rng(23)


def _x(n=3000, p=6, seed=None):
    rng = RNG if seed is None else np.random.default_rng(seed)
    return (rng.normal(size=(n, p)) * 2 + 0.5).astype(np.float32)


@pytest.fixture(autouse=True)
def _small_partitions():
    """Multi-partition streams, fresh plan cache per test."""
    from repro.core import matrix as matrix_mod
    old = matrix_mod.IO_PARTITION_BYTES
    fm.set_conf(io_partition_bytes=4096)
    mz.clear_plan_cache()
    yield
    matrix_mod.IO_PARTITION_BYTES = old
    mz.clear_plan_cache()


@pytest.fixture()
def data_dir(tmp_path, monkeypatch):
    monkeypatch.setitem(storage.registry._CONF, "data_dir", None)
    fm.set_conf(data_dir=str(tmp_path / "fmdata"))
    return tmp_path / "fmdata"


def _submit_from_threads(eng, requests):
    """Submit each request from its own thread (barrier-released), return
    the handles in request order."""
    barrier = threading.Barrier(len(requests))
    handles = [None] * len(requests)
    errors = []

    def worker(i, outs):
        try:
            barrier.wait(timeout=30)
            handles[i] = eng.submit(*outs)
        except Exception as exc:  # noqa: BLE001 - surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=worker,
                                args=(i, outs if isinstance(outs, tuple)
                                      else (outs,)))
               for i, outs in enumerate(requests)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors, errors
    return handles


class PacedStore(DenseStore):
    """Host store that signals ``started`` after its second partition read
    and then holds the stream until ``release`` — the deterministic hook
    the mid-stream admission tests use to submit a late request while the
    sweep is provably live."""

    def __init__(self, arr, started, release):
        super().__init__(np.asarray(arr))
        self.reads = 0
        self.started = started
        self.release = release

    def block(self, start, stop):
        self.reads += 1
        if self.reads == 2:
            self.started.set()
            self.release.wait(timeout=30)
        return super().block(start, stop)


# ---------------------------------------------------------------------------
# Tentpole: window coalescing, bytes, attribution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["stream", "ooc"])
def test_window_coalesces_concurrent_requests(mode):
    a = _x()
    X = fm.conv_R2FM(a, host=(mode == "ooc"))
    reqs = [fm.colMeans(X), fm.colSums(X), (fm.colSds(X), fm.crossprod(X)),
            fm.sum_(X)]
    mz.reset_exec_stats()
    with fm.serve(window_ms=2000, max_window_requests=len(reqs),
                  mode=mode, midstream_admission=False) as eng:
        handles = _submit_from_threads(eng, reqs)
        res = [h.result(timeout=120) for h in handles]
    st = mz.exec_stats()
    # k concurrent same-source requests: ONE physical sweep, k logical passes.
    assert st["streams"] == 1
    assert st["passes"] == len(reqs)
    assert st["pass_bytes_in"] == (X.m.nbytes(),)
    np.testing.assert_allclose(fm.as_np(res[0]).ravel(), a.mean(0),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(fm.as_np(res[1]).ravel(), a.sum(0),
                               rtol=1e-3)
    np.testing.assert_allclose(fm.as_np(res[2][0]).ravel(),
                               a.std(0, ddof=1), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(fm.as_np(res[2][1]),
                               a.T.astype(np.float64) @ a, rtol=2e-3)
    np.testing.assert_allclose(float(np.asarray(fm.as_np(res[3]))), a.sum(),
                               rtol=1e-3)


def test_served_bytes_strictly_below_serial():
    a = _x(2400, 6)
    X = fm.conv_R2FM(a, host=True)

    def fresh_reqs():
        return [fm.colMeans(X), fm.colSums(X), fm.sum_(X)]

    mz.reset_exec_stats()
    for r in fresh_reqs():
        fm.materialize(r, mode="ooc")
    serial_bytes = metrics.root_counter("bytes_streamed")

    mz.clear_plan_cache()
    mz.reset_exec_stats()
    with fm.serve(window_ms=2000, max_window_requests=3,
                  mode="ooc", midstream_admission=False) as eng:
        for h in _submit_from_threads(eng, fresh_reqs()):
            h.result(timeout=120)
    served_bytes = metrics.root_counter("bytes_streamed")
    assert served_bytes < serial_bytes
    assert served_bytes == X.m.nbytes()  # union read exactly once


def test_multipass_request_in_window():
    """A two-pass plan (scale: moment pass -> sweep pass) served alongside
    single-pass requests resolves correctly across rounds."""
    a = _x(1500, 5)
    X = fm.conv_R2FM(a, host=True)
    with fm.serve(window_ms=2000, max_window_requests=2,
                  midstream_admission=False) as eng:
        handles = _submit_from_threads(
            eng, [fm.scale(X), fm.colMeans(X)])
        scaled, mu = [h.result(timeout=120) for h in handles]
    oracle = (a - a.mean(0)) / a.std(0, ddof=1)
    np.testing.assert_allclose(fm.as_np(scaled), oracle, rtol=1e-3,
                               atol=1e-4)
    np.testing.assert_allclose(fm.as_np(mu).ravel(), a.mean(0), rtol=1e-3,
                               atol=1e-4)


def test_per_request_scope_attribution():
    """Each tenant's fm.collect_stats() scope sees ITS plan's share: one
    stream, its own bytes — not the group's union."""
    a = _x(2000, 4)
    X = fm.conv_R2FM(a, host=True)
    own_bytes = X.m.nbytes()
    eng = Engine(window_ms=2000, max_window_requests=2,
                 midstream_admission=False)
    stats = [None, None]
    barrier = threading.Barrier(2)

    def tenant(i, out):
        with fm.collect_stats(f"tenant{i}") as sc:
            barrier.wait(timeout=30)
            eng.submit(out).result(timeout=120)
        stats[i] = sc.stats()

    threads = [threading.Thread(target=tenant, args=(0, fm.colMeans(X))),
               threading.Thread(target=tenant, args=(1, fm.sum_(X)))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    eng.close()
    for st in stats:
        assert st is not None
        assert st["streams"] == 1
        assert st["passes"] == 1
        assert st["bytes_streamed"] == own_bytes
        assert st["pass_bytes_in"] == (own_bytes,)
        assert st["materialize_calls"] == 1


def test_no_partial_sinks_when_member_fails_midgroup():
    """A staging fault inside one group fails every member of THAT group
    with no partial sinks; an unrelated group in the same window still
    completes.  Healing the store lets a resubmit succeed through the
    same (undamaged) cached template."""
    a = _x(1600, 5)
    F, fstore = flaky_matrix(a, fail_after=3)
    b = _x(1600, 5, seed=7)
    Y = fm.conv_R2FM(b, host=True)

    flaky_reqs = [fm.colMeans(F), fm.sum_(F)]
    with fm.serve(window_ms=2000, max_window_requests=3, mode="ooc",
                  midstream_admission=False) as eng:
        handles = _submit_from_threads(eng, flaky_reqs + [fm.colMeans(Y)])
        for h in handles[:2]:
            with pytest.raises(FAULTS):
                h.result(timeout=120)
        np.testing.assert_allclose(fm.as_np(handles[2].result(120)).ravel(),
                                   b.mean(0), rtol=1e-3, atol=1e-4)
        assert_no_partial_results(*[r.m.node for r in flaky_reqs])

        fstore.heal()
        h = eng.submit(*flaky_reqs)
        r1, r2 = h.result(timeout=120)
        np.testing.assert_allclose(fm.as_np(r1).ravel(), a.mean(0),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(float(np.asarray(fm.as_np(r2))), a.sum(),
                                   rtol=1e-3)


# ---------------------------------------------------------------------------
# Mid-stream admission
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prefetch", [False, True])
def test_midstream_admission_at_partition_boundary(prefetch):
    a = _x(8000, 8)
    started, release = threading.Event(), threading.Event()
    X = FMMatrix(a.shape, a.dtype,
                 store=PacedStore(a, started, release), name="paced")

    mz.reset_exec_stats()
    with fm.serve(window_ms=1, prefetch=prefetch) as eng:
        h1 = eng.submit(fm.colMeans(X))
        assert started.wait(timeout=30), "stream never started"
        # The sweep is live (partition 0 consumed or staged): this request
        # must ride it from the next boundary, not wait for a new window.
        h2 = eng.submit(fm.colSums(X))
        release.set()
        r1 = h1.result(timeout=120)
        r2 = h2.result(timeout=120)
    st = mz.exec_stats()
    assert st["midstream_admits"] == 1
    assert st["streams"] == 1          # no second sweep
    assert st["passes"] == 2
    # Catch-up of the missed prefix is exact: full-precision parity.
    np.testing.assert_allclose(fm.as_np(r1).ravel(), a.mean(0), rtol=1e-3,
                               atol=1e-4)
    np.testing.assert_allclose(fm.as_np(r2).ravel(), a.sum(0), rtol=1e-3)


def test_submit_after_stream_done_uses_new_window():
    a = _x(1200, 4)
    X = fm.conv_R2FM(a, host=True)
    mz.reset_exec_stats()
    with fm.serve(window_ms=1) as eng:
        h1 = eng.submit(fm.colMeans(X))
        h1.result(timeout=120)        # first stream fully done
        h2 = eng.submit(fm.colSums(X))
        h2.result(timeout=120)
    st = mz.exec_stats()
    assert st["midstream_admits"] == 0
    assert st["streams"] == 2


def test_gate_rejects_device_resident_long_outputs():
    """A late plan with a device-target long-dimension output cannot join a
    device-mode sweep (partition-order concatenation would scramble), but a
    sink-only plan can; in ooc mode the output is row-addressed on host and
    both qualify."""
    a = _x(1600, 4)
    Xd = fm.conv_R2FM(a, host=False)   # device tier -> 'stream' mode
    Xh = fm.conv_R2FM(a, host=True)    # host tier -> 'ooc' mode

    def gate_for(out, to_host, rows=None):
        req = batch_mod.BatchRequest([out.m], structured=False)
        assert batch_mod._plan_request(req, "xla", None, True)
        member = batch_mod._member_for(req, 0)
        ps = member.ps
        ids = frozenset(id(m) for _, m in ps.staged_sources(member.sources))
        gate = _Gate(ps.long_dim, rows if rows is not None
                     else ps.partition_rows, ids, to_host=to_host)
        return gate, req

    # rows=1: the sweep granularity never disqualifies, isolating the
    # output-residency check.
    gate, _ = gate_for(fm.colMeans(Xd), to_host=False, rows=1)
    sink_req = batch_mod.BatchRequest([fm.sum_(Xd).m], structured=False)
    assert batch_mod._plan_request(sink_req, "xla", None, True)
    assert gate.accepts(sink_req)
    rowlocal_req = batch_mod.BatchRequest([fm.sqrt(fm.abs_(Xd)).m],
                                          structured=False)
    assert batch_mod._plan_request(rowlocal_req, "xla", None, True)
    assert not gate.accepts(rowlocal_req)   # device-resident long output

    gate_h, _ = gate_for(fm.colMeans(Xh), to_host=True, rows=1)
    rowlocal_h = batch_mod.BatchRequest([fm.sqrt(fm.abs_(Xh)).m],
                                        structured=False)
    assert batch_mod._plan_request(rowlocal_h, "xla", None, True)
    assert gate_h.accepts(rowlocal_h)       # host-addressed: fine

    # A late plan whose partitions are FINER than the live sweep cannot
    # consume the sweep's partitions whole: rejected on granularity.
    gate_coarse, _ = gate_for(fm.colMeans(Xh), to_host=True)
    assert gate_coarse.rows > rowlocal_h.plan.passes[0].partition_rows
    assert not gate_coarse.accepts(rowlocal_h)

    # Multi-pass and foreign-source requests never ride a gate.
    twopass = batch_mod.BatchRequest([fm.scale(Xh).m], structured=False)
    assert batch_mod._plan_request(twopass, "xla", None, True)
    assert not gate_h.accepts(twopass)
    other = batch_mod.BatchRequest(
        [fm.colMeans(fm.conv_R2FM(_x(1600, 4, seed=3), host=True)).m],
        structured=False)
    assert batch_mod._plan_request(other, "xla", None, True)
    assert not gate_h.accepts(other)

    # A closed gate refuses offers; leftovers come back for re-queueing.
    g = _Gate(1600, 1, frozenset(), to_host=True)
    assert g.offer("req", "member")
    assert g.close() == ["req"]
    assert not g.offer("req2", "member2")


def test_midstream_admitted_scope_attribution():
    a = _x(8000, 8)
    started, release = threading.Event(), threading.Event()
    X = FMMatrix(a.shape, a.dtype,
                 store=PacedStore(a, started, release), name="paced")
    with fm.serve(window_ms=1, prefetch=False) as eng:
        h1 = eng.submit(fm.colMeans(X))
        assert started.wait(timeout=30)
        with fm.collect_stats("late") as sc:
            h2 = eng.submit(fm.colSums(X))
            release.set()
            h2.result(timeout=120)
        h1.result(timeout=120)
    st = sc.stats()
    # The late tenant sees a solo-run view: one stream, its full bytes.
    assert st["streams"] == 1
    assert st["passes"] == 1
    assert st["bytes_streamed"] == X.nbytes()


# ---------------------------------------------------------------------------
# Admission control + prefetch depth negotiation
# ---------------------------------------------------------------------------

def test_bandwidth_cap_defers_second_group():
    a = _x(4000, 6)
    b = _x(4000, 6, seed=5)
    started, release = threading.Event(), threading.Event()
    Xa = FMMatrix(a.shape, a.dtype,
                  store=PacedStore(a, started, release), name="paced-a")
    Xb = fm.conv_R2FM(b, host=True)

    mz.reset_exec_stats()
    # Cap of 1 byte: any group defers while another is in flight; the
    # "always admit when idle" rule keeps it deadlock-free.
    with fm.serve(window_ms=2000, max_window_requests=2,
                  max_concurrent_streams=2, max_inflight_bytes=1,
                  midstream_admission=False) as eng:
        handles = _submit_from_threads(
            eng, [fm.colMeans(Xa), fm.colMeans(Xb)])
        assert started.wait(timeout=30)
        # Group A is provably mid-stream; group B must be deferring now or
        # have already recorded its deferral.
        deadline = 30.0
        import time as _time
        t0 = _time.perf_counter()
        while (metrics.root_counter("serve_deferrals") < 1
               and _time.perf_counter() - t0 < deadline):
            _time.sleep(0.01)
        release.set()
        ra, rb = [h.result(timeout=120) for h in handles]
    assert metrics.root_counter("serve_deferrals") >= 1
    assert metrics.root_counter("serve_admission_wait_seconds") > 0
    np.testing.assert_allclose(fm.as_np(ra).ravel(), a.mean(0), rtol=1e-3,
                               atol=1e-4)
    np.testing.assert_allclose(fm.as_np(rb).ravel(), b.mean(0), rtol=1e-3,
                               atol=1e-4)


def test_negotiate_depth_group_aware():
    assert negotiate_depth(1, 1 << 20, base=2) == 2       # solo: unchanged
    assert negotiate_depth(4, 1 << 20, base=2) == 5       # +1 per member
    assert negotiate_depth(32, 1 << 20, base=2) == 8      # hard ceiling
    assert negotiate_depth(4, 1 << 20, base=2,
                           budget_bytes=2 << 20) == 2     # budget clamp
    assert negotiate_depth(4, 8 << 20, base=2,
                           budget_bytes=1 << 20) == 1     # floor at 1


def test_engine_close_drains_pending():
    a = _x(1200, 4)
    X = fm.conv_R2FM(a, host=True)
    eng = fm.serve(window_ms=60_000)   # window far longer than the test
    h = eng.submit(fm.colMeans(X))
    eng.close()                        # must cut the window short + drain
    np.testing.assert_allclose(fm.as_np(h.result(timeout=10)).ravel(),
                               a.mean(0), rtol=1e-3, atol=1e-4)
    with pytest.raises(RuntimeError):
        eng.submit(fm.colSums(X))


def test_physical_passthrough_resolves_immediately():
    a = _x(600, 3)
    X = fm.conv_R2FM(a, host=False)
    with fm.serve(window_ms=60_000) as eng:   # scheduler never needed
        h = eng.submit(X)
        assert h.done()
        np.testing.assert_allclose(fm.as_np(h.result(0)), a, rtol=1e-6)


# ---------------------------------------------------------------------------
# Thread-safety audit regressions (ISSUE 8 satellite)
# ---------------------------------------------------------------------------

def test_concurrent_materialize_through_one_cached_template():
    """N threads repeatedly materialize structurally identical plans over
    their OWN data through one shared plan-cache template.  The borrow
    discipline (_store_results onto=) must keep every result correct —
    the old snapshot/scrub dance corrupted concurrent borrowers."""
    n_threads, iters = 4, 6
    datas = [_x(900, 4, seed=i) for i in range(n_threads)]
    errors = []
    barrier = threading.Barrier(n_threads)

    def worker(i):
        try:
            barrier.wait(timeout=30)
            for _ in range(iters):
                X = fm.conv_R2FM(datas[i], host=True)
                (r,) = fm.materialize(fm.colMeans(X), mode="ooc")
                np.testing.assert_allclose(
                    fm.as_np(r).ravel(), datas[i].mean(0), rtol=1e-3,
                    atol=1e-4)
        except Exception as exc:  # noqa: BLE001
            errors.append((i, exc))

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=180)
    assert not errors, errors


def test_plan_cache_lru_eviction_racing_borrows(monkeypatch):
    """Concurrent materializes churning a 2-entry cache: eviction may drop
    a template another thread is borrowing — results must stay correct
    (borrowers hold their own strong reference)."""
    monkeypatch.setattr(mz, "PLAN_CACHE_LIMIT", 2)
    n_threads, iters = 4, 5
    datas = [_x(700, 3 + i, seed=10 + i) for i in range(n_threads)]
    errors = []
    barrier = threading.Barrier(n_threads)

    def worker(i):
        try:
            barrier.wait(timeout=30)
            for _ in range(iters):
                X = fm.conv_R2FM(datas[i], host=True)
                (r,) = fm.materialize(fm.colSums(X), mode="ooc")
                np.testing.assert_allclose(
                    fm.as_np(r).ravel(), datas[i].sum(0), rtol=1e-3)
        except Exception as exc:  # noqa: BLE001
            errors.append((i, exc))

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=180)
    assert not errors, errors
    assert len(mz._PLANS) <= 2


def test_data_dir_lazy_init_is_threadsafe(monkeypatch):
    monkeypatch.setitem(storage.registry._CONF, "data_dir", None)
    results = []
    barrier = threading.Barrier(8)

    def worker():
        barrier.wait(timeout=30)
        results.append(storage.registry.data_dir())

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert len(results) == 8
    assert len({str(p) for p in results}) == 1  # ONE dir, not eight


def test_program_compile_is_single_and_shared():
    a = _x(1000, 4)
    X = fm.conv_R2FM(a, host=True)
    plan = Plan([fm.colMeans(X).m])
    progs = [None] * 6
    barrier = threading.Barrier(6)

    def worker(i):
        barrier.wait(timeout=30)
        progs[i] = plan.program("xla")

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert all(p is progs[0] and p is not None for p in progs)


def test_mixed_materialize_batch_serve_stress(data_dir):
    """The ISSUE 8 stress shape: N threads mixing fm.materialize, fm.batch
    and Engine.submit against shared NAMED disk matrices, every result
    checked against numpy."""
    a = _x(2000, 5, seed=40)
    b = _x(2000, 5, seed=41)
    A = storage.load_dense_matrix(a, "stress_a")
    B = storage.load_dense_matrix(b, "stress_b")
    eng = Engine(window_ms=10, max_concurrent_streams=2)
    errors = []
    barrier = threading.Barrier(3)

    def check(res, arr, kind):
        got = np.asarray(fm.as_np(res)).ravel()
        want = arr.mean(0) if kind == "mean" else arr.sum(0)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def worker(i):
        try:
            barrier.wait(timeout=30)
            for j in range(4):
                which = (i + j) % 3
                src, arr = (A, a) if (i + j) % 2 == 0 else (B, b)
                if which == 0:
                    (r,) = fm.materialize(fm.colMeans(src))
                    check(r, arr, "mean")
                elif which == 1:
                    r1, r2 = fm.batch(fm.colMeans(src), fm.colSums(src))
                    check(r1, arr, "mean")
                    check(r2, arr, "sum")
                else:
                    h = eng.submit(fm.colSums(src))
                    check(h.result(timeout=120), arr, "sum")
        except Exception as exc:  # noqa: BLE001
            errors.append((i, exc))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    eng.close()
    assert not errors, errors


def test_flaky_group_leaves_no_partials_with_midstream_member():
    """A mid-stream-admitted member's future fails with the group's fault
    and registers nothing."""
    a = _x(8000, 8)
    started, release = threading.Event(), threading.Event()

    class FlakyPaced(FlakyStore):
        def __init__(self, arr):
            super().__init__(arr, fail_after=-1)

        def block(self, start, stop):
            self.reads += 1
            if self.reads == 2:
                started.set()
                release.wait(timeout=30)
            if self.fail_after >= 0 and self.reads > self.fail_after:
                raise StagingFault("injected fault after admission")
            return DenseStore.block(self, start, stop)

    st = FlakyPaced(a)
    X = FMMatrix(a.shape, a.dtype, store=st, name="flaky-paced")
    with fm.serve(window_ms=1, prefetch=False) as eng:
        h1 = eng.submit(fm.colMeans(X))
        assert started.wait(timeout=30)
        late = fm.colSums(X)
        h2 = eng.submit(late)
        st.fail_after = st.reads + 1   # fault a couple partitions later
        release.set()
        with pytest.raises(FAULTS):
            h1.result(timeout=120)
        with pytest.raises(FAULTS):
            h2.result(timeout=120)
    assert_no_partial_results(late.m.node)


# ---------------------------------------------------------------------------
# Submit backpressure (ISSUE 9 satellite): bounded pending queue
# ---------------------------------------------------------------------------

def test_submit_backpressure_rejects_when_saturated():
    """With the queue at max_pending_requests and submit_timeout_s=0, the
    next submit raises EngineSaturated, increments serve_rejections, and
    enqueues nothing — gated on the observed queue depth so the test only
    asserts once saturation is real."""
    from repro.core.serve import EngineSaturated
    a = _x(600, 4)
    X = FMMatrix(a.shape, a.dtype, store=DenseStore(a), name="bp")
    # A huge window holds every submit in the pending queue: depth is
    # deterministic, no scheduler race.
    eng = Engine(window_ms=60_000, max_window_requests=None,
                 max_pending_requests=2, submit_timeout_s=0.0)
    try:
        eng.submit(fm.colMeans(X))
        eng.submit(fm.colSums(X))
        depth = metrics.REGISTRY.root.stats().get("serve_queue_depth", {})
        assert depth.get("max", 0) >= 2, depth  # queue provably full
        with pytest.raises(EngineSaturated):
            eng.submit(fm.colMaxs(X))
        assert eng.stats()["serve_rejections"] == 1
        with eng._cv:
            assert len(eng._pending) == 2  # rejected submit not enqueued
    finally:
        eng.close()


def test_submit_backpressure_blocks_until_window_drains():
    """A blocking submit (submit_timeout_s > 0) waits for the scheduler to
    swap the window out and then succeeds — no rejection counted."""
    a = _x(600, 4)
    X = FMMatrix(a.shape, a.dtype, store=DenseStore(a), name="bp2")
    eng = Engine(window_ms=200, max_pending_requests=1,
                 submit_timeout_s=10.0)
    try:
        h1 = eng.submit(fm.colMeans(X))
        h2 = eng.submit(fm.colSums(X))  # blocks ~200ms for the drain
        assert np.allclose(fm.as_np(h1.result(60)), a.mean(0), atol=1e-4)
        assert np.allclose(fm.as_np(h2.result(60)), a.sum(0), atol=1e-3)
        assert eng.stats().get("serve_rejections", 0) == 0
    finally:
        eng.close()


def test_engine_saturated_reexported():
    assert fm.EngineSaturated is __import__(
        "repro.core.serve", fromlist=["EngineSaturated"]).EngineSaturated


# ---------------------------------------------------------------------------
# Serving under a mesh (ISSUE 9 tentpole): sharded groups, serialized
# admission
# ---------------------------------------------------------------------------

def test_serve_under_mesh_shards_groups_and_serializes_admission():
    """An Engine(mesh=...) drives every group through the sharded runner
    (``shards`` counts the drive) and opens NO mid-stream gates — a late
    compatible request waits for the next window (midstream_admits == 0)
    but still computes correctly.  Runs with however many devices XLA
    exposes (1 locally, 8 under the CI forced-device arm)."""
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    ndata = mesh.devices.shape[0]
    a = _x(4096, 4)
    X = FMMatrix(a.shape, a.dtype, store=DenseStore(a), name="mesh-serve")
    with Engine(window_ms=50, max_window_requests=2, mode="stream",
                mesh=mesh) as eng:
        h1, h2 = _submit_from_threads(
            eng, [fm.colMeans(X), fm.crossprod(X)])
        assert np.allclose(fm.as_np(h1.result(120)), a.mean(0), atol=1e-4)
        assert np.allclose(fm.as_np(h2.result(120)), a.T @ a,
                           rtol=1e-4, atol=1e-2)
        st = mz.exec_stats()
        assert st["shards"] > 0 and st["shards"] % ndata == 0, st
        assert st["midstream_admits"] == 0
        with eng._gates_lock:
            assert eng._gates == []  # no gate ever opens under a mesh
