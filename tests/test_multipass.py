"""Multi-pass planner tests (ISSUE 5 tentpole).

Contract under test: a DAG in which a merged value (sink / epilogue
output) feeds a ROW-LOCAL op — FlashR's ``scale(X)``, ``X - colMeans(X)``,
PCA's covariance-of-the-centered-matrix — schedules as an ordered pass
list (moment pass → sweep pass) compiled under ONE plan-cache entry and
executed by ONE ``fm.materialize`` call: ``exec_stats()['passes'] == 2``,
per-pass ``pass_bytes_in`` observable, parity with numpy on every
backend × mode cell, write-through spill for pass-2 outputs, and no
partially-registered sinks when a pass is interrupted.
"""
import numpy as np
import pytest

from helpers_cache import (assert_activity, assert_no_partial_results,
                           cache_activity, flaky_matrix)
from repro.core import fm
from repro.core import materialize as mz
from repro.core.dag import toposort
from repro.core.fusion import Plan

RNG = np.random.default_rng(7)

CELLS = [(backend, mode)
         for backend in ("xla", "pallas")
         for mode in ("whole", "stream", "ooc")]


def _x(n=600, p=5):
    return (RNG.normal(size=(n, p)) * 2 + 0.5).astype(np.float32)


@pytest.fixture(autouse=True)
def _small_partitions():
    """Make streams multi-partition so pass 2 genuinely re-streams."""
    from repro.core import matrix as matrix_mod
    old = matrix_mod.IO_PARTITION_BYTES
    fm.set_conf(io_partition_bytes=4096)
    mz.clear_plan_cache()
    yield
    matrix_mod.IO_PARTITION_BYTES = old
    mz.clear_plan_cache()


# ---------------------------------------------------------------------------
# The tentpole: scale(X) is ONE materialize with passes == 2
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,mode", CELLS)
def test_scale_one_call_two_passes(backend, mode):
    a = _x()
    X = fm.conv_R2FM(a, host=(mode == "ooc"))
    Z = fm.scale(X)
    assert Z.is_virtual  # nothing computed: the moments are DAG edges
    plan = Plan([Z.m])
    assert plan.n_passes == 2
    # Honest I/O accounting: two streamed reads of one physical matrix.
    assert plan.bytes_in() == 2 * X.m.nbytes()
    mz.reset_exec_stats()
    with cache_activity() as act:
        (Zm,) = fm.materialize(Z, mode=mode, backend=backend)
        st = mz.exec_stats()
    assert_activity(act, materialize_calls=1, misses=1, hits=0,
                    epilogue_launches=1)
    assert st["passes"] == 2
    assert st["pass_bytes_in"] == (X.m.nbytes(), X.m.nbytes())
    ref = (a - a.mean(0)) / a.std(0, ddof=1)
    np.testing.assert_allclose(fm.as_np(Zm), ref, rtol=1e-3, atol=1e-4)
    mz.clear_plan_cache()


@pytest.mark.parametrize("backend,mode", CELLS)
def test_pca_covariance_of_centered_two_passes(backend, mode):
    """The PCA shape: crossprod(X - colMeans(X)) — a pass-2 CONTRACTION
    consuming the pass-1 epilogue, with its own /(n−1) pass-2 epilogue."""
    a = _x(700, 4)
    X = fm.conv_R2FM(a, host=(mode == "ooc"))
    cov = fm.crossprod(X - fm.colMeans(X)) / float(a.shape[0] - 1)
    plan = Plan([cov.m])
    assert plan.n_passes == 2
    assert plan.passes[1].sinks  # the Gram contraction streams in pass 2
    mz.reset_exec_stats()
    (cm,) = fm.materialize(cov, mode=mode, backend=backend)
    st = mz.exec_stats()
    assert st["passes"] == 2
    assert st["epilogue_launches"] == 2  # moments epilogue + /(n−1)
    c = a - a.mean(0)
    ref = c.T.astype(np.float64) @ c / (a.shape[0] - 1)
    np.testing.assert_allclose(fm.as_np(cm), ref, rtol=2e-3, atol=1e-4)
    mz.clear_plan_cache()


def test_sweep_helper_and_sink_binding():
    """fm.sweep with a lazy stat; a SINK value (not an epilogue chain)
    bound directly into the pass-2 row-local op."""
    a = _x(400, 3)
    X = fm.conv_R2FM(a)
    s = fm.sweep(X, 2, fm.colSums(X), "sub")
    plan = Plan([s.m])
    assert plan.n_passes == 2
    (sm,) = fm.materialize(s, mode="stream")
    np.testing.assert_allclose(fm.as_np(sm), a - a.sum(0), rtol=1e-4,
                               atol=1e-3)
    with pytest.raises(ValueError, match="margin"):
        fm.sweep(X, 3, fm.colSums(X))


def test_three_pass_chain():
    """Pass numbers chain: standardizing the CENTERED matrix by its own
    colSds needs moment → center → sd-moment... scheduled automatically."""
    a = _x(500, 4)
    X = fm.conv_R2FM(a)
    Z = X - fm.colMeans(X)              # pass 2 row-local
    W = Z / fm.colSds(Z)                # colSds(Z) sinks stream in pass 2
    plan = Plan([W.m])
    assert plan.n_passes == 3
    mz.reset_exec_stats()
    (wm,) = fm.materialize(W, mode="stream")
    assert mz.exec_stats()["passes"] == 3
    c = a - a.mean(0)
    ref = c / c.std(0, ddof=1)
    np.testing.assert_allclose(fm.as_np(wm), ref, rtol=1e-3, atol=1e-3)


def test_scale_fuses_into_downstream_gram():
    """scale(X) stays lazy and fuses into a downstream Gram — the FlashR
    standardize-then-correlate idiom in one call."""
    a = _x(600, 4)
    X = fm.conv_R2FM(a)
    G = fm.crossprod(fm.scale(X))
    mz.reset_exec_stats()
    (gm,) = fm.materialize(G)
    st = mz.exec_stats()
    assert st["materialize_calls"] == 1 and st["passes"] == 2
    z = (a - a.mean(0)) / a.std(0, ddof=1)
    np.testing.assert_allclose(fm.as_np(gm), z.T.astype(np.float64) @ z,
                               rtol=2e-3, atol=1e-2)


# ---------------------------------------------------------------------------
# Write-through spill of the pass-2 long-dimension output
# ---------------------------------------------------------------------------

def test_scale_save_disk_streams_out_of_core(tmp_path, monkeypatch):
    from repro import storage
    monkeypatch.setitem(storage.registry._CONF, "data_dir", None)
    fm.set_conf(data_dir=str(tmp_path / "fmdata"))
    a = _x(800, 4)
    Xd = fm.load_dense_matrix(a, "mp_spill_x")
    assert Xd.m.on_disk
    Z = fm.scale(Xd, save="disk")
    mz.reset_exec_stats()
    (Zm,) = fm.materialize(Z)
    st = mz.exec_stats()
    assert st["passes"] == 2
    assert st["partition_steps"] > 2     # genuinely streamed, both passes
    assert st["epilogue_host_inputs"] == 0
    assert Zm.m.on_disk                  # disk → disk, never whole in RAM
    ref = (a - a.mean(0)) / a.std(0, ddof=1)
    np.testing.assert_allclose(np.asarray(Zm.m.logical_data()), ref,
                               rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# Plan-cache correctness under the pass-structure key
# ---------------------------------------------------------------------------

def test_cache_no_collision_across_pass_structures():
    """The same sweep computation with a LAZY stat (two passes) vs a
    PHYSICAL stat (one pass) must be two cache entries, and each signature
    must carry its pass structure."""
    a = _x()
    X = fm.conv_R2FM(a)
    mu = a.mean(0).astype(np.float32)
    lazy = fm.mapply_row(X, fm.colMeans(X), "sub")
    phys = fm.mapply_row(X, mu, "sub")
    p_lazy, p_phys = Plan([lazy.m]), Plan([phys.m])
    assert p_lazy.n_passes == 2 and p_phys.n_passes == 1
    assert p_lazy.signature() != p_phys.signature()
    assert "P2" in p_lazy.signature() and "P1" in p_phys.signature()
    with cache_activity() as act:
        (lm,) = fm.materialize(fm.mapply_row(X, fm.colMeans(X), "sub"))
        (pm,) = fm.materialize(fm.mapply_row(X, mu, "sub"))
        # identical structures re-materialize as hits
        fm.materialize(fm.mapply_row(X, fm.colMeans(X), "sub"))
        fm.materialize(fm.mapply_row(X, mu, "sub"))
    assert_activity(act, misses=2, hits=2)
    np.testing.assert_allclose(fm.as_np(lm), a - a.mean(0), rtol=1e-4,
                               atol=1e-3)
    np.testing.assert_allclose(fm.as_np(pm), a - mu, rtol=1e-4, atol=1e-3)


def test_cache_keyed_on_per_pass_partition_schedule():
    """Retuning the I/O partition budget must retrace a multi-pass plan
    (per-pass partition rows are in the cache key), not reuse stale
    tiling."""
    a = _x()
    X = fm.conv_R2FM(a)
    with cache_activity() as act:
        fm.materialize(fm.scale(X), mode="stream")
        fm.set_conf(io_partition_bytes=8192)
        (Zm,) = fm.materialize(fm.scale(X), mode="stream")
    assert_activity(act, misses=2, hits=0)
    ref = (a - a.mean(0)) / a.std(0, ddof=1)
    np.testing.assert_allclose(fm.as_np(Zm), ref, rtol=1e-3, atol=1e-4)


def test_cached_two_pass_plan_reuse():
    """Iteration-style reuse: a structurally identical two-pass DAG built
    twice compiles once and hits on the second materialize."""
    a = _x()
    X = fm.conv_R2FM(a)
    with cache_activity() as act:
        (z1,) = fm.materialize(fm.scale(X), mode="stream")
        (z2,) = fm.materialize(fm.scale(X), mode="stream")
    assert_activity(act, misses=1, hits=1, epilogue_launches=2)
    np.testing.assert_allclose(fm.as_np(z1), fm.as_np(z2), rtol=1e-6)


# ---------------------------------------------------------------------------
# Interrupted passes: no partially-registered results
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fail_after", [1, 0])
def test_interrupted_pass1_leaves_no_partial_sinks(fail_after):
    """A staging failure during PASS 1 must abort the whole materialize
    with NOTHING registered — and a retry (healed store, same cached plan)
    must succeed."""
    a = _x(800, 4)
    Xm, store = flaky_matrix(a, fail_after)
    Z = fm.scale(fm.FM(Xm))
    nodes = toposort([Z.m.node])
    with pytest.raises(Exception, match="staging failure"):
        fm.materialize(Z, prefetch=False)
    assert store.failed
    assert_no_partial_results(*nodes)
    store.heal()
    (Zm,) = fm.materialize(Z, prefetch=False)
    ref = (a - a.mean(0)) / a.std(0, ddof=1)
    np.testing.assert_allclose(fm.as_np(Zm), ref, rtol=1e-3, atol=1e-4)


def test_interrupted_pass2_rolls_back_pass1_sinks():
    """Pass 1 completes, pass 2 dies mid-stream: even the ALREADY-MERGED
    pass-1 sinks must not register (a half-materialized plan would poison
    later cuts reusing them as sources)."""
    a = _x(800, 4)
    n_parts = -(-800 // Plan([fm.scale(fm.conv_R2FM(a)).m])
                .passes[0].partition_rows)
    assert n_parts > 1
    # Survive all of pass 1, die on the second read of pass 2.
    Xm, store = flaky_matrix(a, n_parts + 1)
    Z = fm.scale(fm.FM(Xm))
    nodes = toposort([Z.m.node])
    with pytest.raises(Exception, match="staging failure"):
        fm.materialize(Z, prefetch=False)
    assert store.reads > n_parts          # pass 2 actually started
    assert_no_partial_results(*nodes)
    store.heal()
    (Zm,) = fm.materialize(Z, prefetch=False)
    ref = (a - a.mean(0)) / a.std(0, ddof=1)
    np.testing.assert_allclose(fm.as_np(Zm), ref, rtol=1e-3, atol=1e-4)


def test_interrupted_prefetching_pass_raises_prefetch_error():
    """With the prefetcher ON, the injected fault surfaces as a
    PrefetchError on the consumer side — same no-partial-results
    guarantee, pass-2 prefetcher re-drive included."""
    from repro.storage.prefetch import PrefetchError
    a = _x(800, 4)
    Xm, store = flaky_matrix(a, 1)
    Z = fm.scale(fm.FM(Xm))
    nodes = toposort([Z.m.node])
    with pytest.raises(PrefetchError):
        fm.materialize(Z, prefetch=True)
    assert_no_partial_results(*nodes)


# ---------------------------------------------------------------------------
# Algorithm integration counters
# ---------------------------------------------------------------------------

def test_pca_single_materialize_two_passes():
    from repro.algorithms.pca import pca
    a = _x(700, 5)
    mz.reset_exec_stats()
    r = pca(fm.conv_R2FM(a), k=5)
    st = mz.exec_stats()
    assert st["materialize_calls"] == 1 and st["passes"] == 2
    c = a - a.mean(0)
    ev = np.linalg.eigvalsh(
        c.T.astype(np.float64) @ c / (a.shape[0] - 1))[::-1]
    np.testing.assert_allclose(r.sdev ** 2, ev, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(r.center, a.mean(0), rtol=1e-4, atol=1e-4)


def test_glm_standardize_first_iteration_two_passes():
    from repro.algorithms.glm import glm, glm_predict
    rng = np.random.default_rng(11)
    a = (rng.normal(size=(600, 4)) * 3 + 2).astype(np.float32)
    zs = (a - a.mean(0)) / a.std(0, ddof=1)
    beta_true = rng.normal(size=4)
    pv = 1.0 / (1.0 + np.exp(-(zs.astype(np.float64) @ beta_true)))
    y = (rng.uniform(size=600) < pv).astype(np.float32)
    mz.reset_exec_stats()
    res = glm(fm.conv_R2FM(a), fm.conv_R2FM(y), "logistic",
              standardize=True)
    st = mz.exec_stats()
    # Only iteration 1 pays the moment pass; iterations 2+ are one-pass.
    assert st["passes"] == st["materialize_calls"] + 1
    assert res.center is not None and res.scale is not None
    # Oracle: IRLS on the standardized design.
    Zs = ((a - a.mean(0)) / np.maximum(a.std(0, ddof=1), 1e-12)) \
        .astype(np.float64)
    b = np.zeros(4)
    for _ in range(50):
        eta = Zs @ b
        mu = 1.0 / (1.0 + np.exp(-eta))
        w = mu * (1.0 - mu) + 1e-6
        z = eta + (y - mu) / w
        b = np.linalg.solve(Zs.T @ (Zs * w[:, None]), Zs.T @ (w * z))
    np.testing.assert_allclose(res.beta, b, rtol=1e-3, atol=1e-3)
    pred = fm.as_np(glm_predict(res, fm.conv_R2FM(a))).reshape(-1)
    np.testing.assert_allclose(pred, 1.0 / (1.0 + np.exp(-(Zs @ b))),
                               rtol=1e-2, atol=1e-3)
