"""Per-kernel shape/dtype sweeps vs the ref.py jnp oracles (interpret=True).

Every Pallas kernel must match its pure-jnp oracle across row counts that
exercise padding/masking edges, block sizes, and dtypes.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(3)

ROWS = [8, 100, 256, 1000]
COLS = [1, 7, 12]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", ROWS)
@pytest.mark.parametrize("p", COLS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_summary(n, p, dtype):
    x = jnp.asarray(RNG.normal(size=(n, p)), dtype)
    outs = ops.fused_summary(x, block_rows=64)
    refs = ref.fused_summary_ref(x)
    for o, r in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("n", ROWS)
@pytest.mark.parametrize("p", [4, 12])
@pytest.mark.parametrize("dtype", DTYPES)
def test_gram(n, p, dtype):
    x = jnp.asarray(RNG.normal(size=(n, p)), dtype)
    g = ops.gram(x, block_rows=128)
    np.testing.assert_allclose(np.asarray(g), np.asarray(ref.gram_ref(x)),
                               **_tol(dtype))


@pytest.mark.parametrize("n", [64, 513])
def test_xty(n):
    x = jnp.asarray(RNG.normal(size=(n, 6)), jnp.float32)
    y = jnp.asarray(RNG.normal(size=(n, 3)), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.xty(x, y, block_rows=128)),
                               np.asarray(ref.xty_ref(x, y)), rtol=1e-4)


@pytest.mark.parametrize("n", ROWS)
@pytest.mark.parametrize("p", [4, 12])
@pytest.mark.parametrize("dtype", DTYPES)
def test_wgram(n, p, dtype):
    x = jnp.asarray(RNG.normal(size=(n, p)), dtype)
    w = jnp.asarray(RNG.uniform(size=(n,)), jnp.float32)
    g = ops.wgram(x, w, block_rows=64)
    np.testing.assert_allclose(np.asarray(g), np.asarray(ref.wgram_ref(x, w)),
                               **_tol(dtype))


def test_wgram_unit_weights_equal_gram():
    x = jnp.asarray(RNG.normal(size=(300, 6)), jnp.float32)
    g = ops.wgram(x, jnp.ones((300,), jnp.float32), block_rows=64)
    np.testing.assert_allclose(np.asarray(g), np.asarray(ops.gram(
        x, block_rows=64)), rtol=1e-5)


@pytest.mark.parametrize("n", ROWS)
@pytest.mark.parametrize("k", [2, 5])
@pytest.mark.parametrize("dtype", DTYPES)
def test_kmeans_assign(n, k, dtype):
    x = jnp.asarray(RNG.normal(size=(n, 8)), dtype)
    c = jnp.asarray(RNG.normal(size=(k, 8)), dtype)
    lab, sums, cnts, wss = ops.kmeans_assign(x, c, block_rows=64)
    rl, rs, rc, rw = ref.kmeans_assign_ref(x, c)
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(rl))
    np.testing.assert_allclose(np.asarray(sums), np.asarray(rs), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(cnts), np.asarray(rc))
    np.testing.assert_allclose(np.asarray(wss), np.asarray(rw),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-3)


@pytest.mark.parametrize("s", [32, 100, 160])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_attention(s, causal, dtype):
    bh, d = 2, 16
    q = jnp.asarray(RNG.normal(size=(bh, s, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(bh, s, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(bh, s, d)), dtype)
    o = ops.flash_attention(q, k, v, causal=causal, bq=32, bk=48)
    r = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 2e-3,
                               atol=3e-2 if dtype == jnp.bfloat16 else 2e-3)


def test_flash_attention_cross_lengths():
    q = jnp.asarray(RNG.normal(size=(1, 40, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 100, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 100, 16)), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=False, bq=16, bk=32)
    r = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-3, atol=2e-3)
