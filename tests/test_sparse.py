"""Sparse CSR tier tests (ISSUE 10): format round-trip, factor/one-hot
construction, engine parity over backend × mode × mesh, sparse glm, SpMM
dispatch visibility, the unified ``fm.persist`` surface (+ deprecation
shims), ``fm.conf`` scoping, and ingest failure hygiene.

The contract under test is the paper's Criteo story: a one-hot design
matrix never densifies on its way through the engine — CSR on disk, ELL
slabs in flight, nnz-proportional bytes in the stream accounting — while
every materialized value matches the dense oracle exactly.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro import storage
from repro.core import fm
from repro.core import materialize as mz
from repro.core.matrix import FMMatrix
from repro.core.sparse import (SparseBlock, csr_from_dense, csr_from_ell,
                               ell_from_csr_rows)


@pytest.fixture()
def data_dir(tmp_path, monkeypatch):
    monkeypatch.setitem(storage.registry._CONF, "data_dir", None)
    fm.set_conf(data_dir=str(tmp_path / "fmdata"))
    return tmp_path / "fmdata"


def _one_hot_case(seed=0, n=600, levels=(7, 5, 11)):
    rng = np.random.default_rng(seed)
    codes = [rng.integers(0, lv, n) for lv in levels]
    X = fm.one_hot(*[fm.as_factor(c, lv) for c, lv in zip(codes, levels)])
    dense = np.zeros((n, sum(levels)), np.float32)
    off = np.cumsum([0] + list(levels[:-1]))
    for c, o in zip(codes, off):
        dense[np.arange(n), c + o] = 1.0
    return X, dense


# ---------------------------------------------------------------------------
# Format + block round-trips
# ---------------------------------------------------------------------------

def test_csr_fmat_roundtrip(tmp_path):
    rng = np.random.default_rng(1)
    dense = rng.normal(size=(97, 13)).astype(np.float32)
    dense *= rng.random(dense.shape) < 0.3
    indptr, indices, data = csr_from_dense(dense)
    path = tmp_path / "m.fmat"
    meta = storage.save_csr_matrix(path, indptr, indices, data, ncol=13)
    assert meta["format"] == "csr" and meta["nnz"] == int(indptr[-1])
    st = storage.open_csr(path)
    assert st.sparse and st.shape == (97, 13)
    np.testing.assert_array_equal(st.logical(), dense)
    # Partition reads slice rows exactly, at the matrix-wide kmax.
    blk = st.block(10, 40)
    assert isinstance(blk, SparseBlock) and blk.kmax == st.max_row_nnz
    np.testing.assert_array_equal(blk.todense(), dense[10:40])
    # open_matrix dispatches on the header's format field.
    st2 = storage.open_matrix(path)
    assert isinstance(st2, storage.CsrMmapStore)
    assert storage.peek_format(path) == "csr"
    # The dense reader refuses a CSR file with a pointed error.
    with pytest.raises(ValueError, match="csr"):
        storage.read_header(path)


def test_ell_csr_conversions():
    rng = np.random.default_rng(2)
    dense = rng.normal(size=(31, 9)).astype(np.float32)
    dense *= rng.random(dense.shape) < 0.4
    indptr, indices, data = csr_from_dense(dense)
    kmax = max(1, int(np.diff(indptr).max()))
    blk = ell_from_csr_rows(indptr, indices, data, 0, 31, kmax, 9)
    np.testing.assert_array_equal(blk.todense(), dense)
    ip2, ix2, d2 = csr_from_ell(blk.cols, blk.vals)
    np.testing.assert_array_equal(ip2, indptr)
    np.testing.assert_array_equal(ix2, indices)
    np.testing.assert_array_equal(d2, data)


def test_sparse_nbytes_is_nnz_proportional(data_dir):
    X, dense = _one_hot_case(n=400, levels=(1000, 1000))
    # 2 ones per row among 2000 columns: the sparse tier moves ~2·8 bytes
    # per row, not 2000·4.
    assert X.m.nbytes() < dense.nbytes / 50
    Xd = fm.persist(X, tier="disk", name="wide")
    assert Xd.m.nbytes() < dense.nbytes / 50


# ---------------------------------------------------------------------------
# Factor / one-hot constructors (paper Table III: fm.as.factor)
# ---------------------------------------------------------------------------

def test_as_factor_validation():
    f = fm.as_factor(np.array([0, 2, 1, 2]))
    assert f.num_levels == 3 and len(f) == 4
    with pytest.raises(ValueError, match="negative"):
        fm.as_factor(np.array([0, -1]))
    with pytest.raises(ValueError, match="out of range"):
        fm.as_factor(np.array([0, 5]), num_levels=3)
    with pytest.raises(ValueError, match="integer"):
        fm.as_factor(np.array([0.5, 1.0]))


def test_one_hot_matches_dense_oracle():
    X, dense = _one_hot_case()
    assert X.m.is_sparse
    np.testing.assert_array_equal(fm.as_np(X), dense)
    with pytest.raises(ValueError, match="lengths differ"):
        fm.one_hot(fm.as_factor(np.arange(4)), fm.as_factor(np.arange(5)))


# ---------------------------------------------------------------------------
# Engine parity: backend × mode (× mesh below), both sparse tiers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("mode", ["whole", "stream", "ooc"])
def test_sparse_crossprod_parity(data_dir, backend, mode):
    X, dense = _one_hot_case(seed=3)
    src = fm.persist(X, tier="disk", name="par") if mode == "ooc" else X
    (G,) = fm.materialize(fm.crossprod(src), mode=mode, backend=backend)
    np.testing.assert_allclose(
        fm.as_np(G), dense.T.astype(np.float64) @ dense, rtol=1e-4)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_sparse_rowlocal_and_sinks_parity(data_dir, backend):
    """Generic-trace coverage: row-local chains and sinks densify the ELL
    slab per partition without mutating the shared value cache."""
    X, dense = _one_hot_case(seed=4)
    Z = (X * 3.0 - 1.0)
    (zm, s, m) = fm.materialize(Z, fm.colSums(X), X @ np.full((23, 2), 0.5,
                                                             np.float32),
                                mode="stream", backend=backend)
    np.testing.assert_allclose(fm.as_np(zm), dense * 3.0 - 1.0, rtol=1e-5)
    np.testing.assert_allclose(fm.as_np(s).reshape(-1), dense.sum(0),
                               rtol=1e-5)
    np.testing.assert_allclose(fm.as_np(m), dense @ np.full((23, 2), 0.5),
                               rtol=1e-5)


def test_sparse_glm_ooc_matches_dense_oracle(data_dir):
    """The capstone: logistic regression out-of-core from a CSR .fmat
    equals the dense-engine fit (both float32 IRLS; beta agrees within
    float32 noise) on every backend."""
    from repro.algorithms.glm import glm
    rng = np.random.default_rng(5)
    n = 2500
    X, dense = _one_hot_case(seed=5, n=n, levels=(13, 7, 5))
    true_b = rng.normal(0, 0.7, dense.shape[1])
    p = 1.0 / (1.0 + np.exp(-(dense @ true_b)))
    y = fm.conv_R2FM((rng.random(n) < p).astype(np.float32).reshape(-1, 1))
    oracle = glm(fm.conv_R2FM(dense), y, "logistic", ridge=1e-3,
                 mode="whole", backend="xla")
    Xd = fm.persist(X, tier="disk", name="glm")
    for backend in ("xla", "pallas"):
        r = glm(Xd, y, "logistic", ridge=1e-3, mode="ooc", backend=backend)
        assert np.abs(r.beta - oracle.beta).max() < 1e-2, backend
        assert abs(r.loglik - oracle.loglik) < 1e-3 * abs(oracle.loglik)


def test_sparse_mesh_parity_subprocess(data_dir):
    """Sharded execution over a 4-device host mesh: sparse crossprod in
    whole/stream/ooc matches the dense oracle (the mesh axis of the
    acceptance grid)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np
        from repro.core import fm
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(4)
        rng = np.random.default_rng(3)
        codes = [rng.integers(0, 9, 2000), rng.integers(0, 6, 2000)]
        X = fm.one_hot(*[fm.as_factor(c) for c in codes])
        dense = fm.as_np(X).copy()
        want = dense.T.astype(np.float64) @ dense
        fm.set_conf(io_partition_bytes=4096)
        Xd = fm.persist(X, tier="disk", name="mesh_oh")
        (g,) = fm.materialize(fm.crossprod(X), mode="whole", mesh=mesh)
        np.testing.assert_allclose(fm.as_np(g), want, rtol=1e-3)
        with fm.conf(mesh=mesh):
            for src, mode in ((X, "stream"), (Xd, "ooc")):
                fm.reset_exec_stats()
                (g,) = fm.materialize(fm.crossprod(src), mode=mode)
                np.testing.assert_allclose(fm.as_np(g), want, rtol=1e-3)
                assert fm.exec_stats()["shards"] == 4
        print("SPARSE_MESH_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, env=env,
                          timeout=600, cwd=os.path.dirname(
                              os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SPARSE_MESH_OK" in proc.stdout


# ---------------------------------------------------------------------------
# Dispatch visibility: SpMM claims + decline reasons (fm.explain)
# ---------------------------------------------------------------------------

def test_explain_shows_spmm_claims(data_dir):
    from repro.algorithms.glm import glm_irls_outputs
    X, dense = _one_hot_case(seed=6, n=400)
    y = fm.conv_R2FM(np.ones((400, 1), np.float32))
    text = fm.explain(fm.crossprod(X), backend="pallas")
    assert "pallas:spmm_gram (claimed by match_spmm)" in text
    assert "density=" in text
    beta0 = np.zeros(dense.shape[1])
    b_fm, ll_fm, *_ = glm_irls_outputs(X, y, beta0, "logistic")
    text = fm.explain(b_fm, ll_fm, backend="pallas")
    assert "pallas:spmm_wgram" in text
    assert "pallas:spmm_xty" in text


def test_explain_reports_decline_reasons():
    """Satellite: a fallback segment says WHY — here a (mul,max) semiring
    over a sparse source declines both the spmm and dense matchers."""
    X, _ = _one_hot_case(seed=7, n=200)
    text = fm.explain(fm.inner_prod(X.T, X, "mul", "max"), backend="pallas")
    assert "generic trace (declined:" in text
    assert "spmm covers (mul,sum) only" in text
    # The xla backend has no matchers: its line is unchanged (golden-pinned
    # in test_observability).
    text = fm.explain(fm.inner_prod(X.T, X, "mul", "max"), backend="xla")
    assert "xla generic trace" in text


# ---------------------------------------------------------------------------
# The unified persistence surface (satellite: fm.persist + shims)
# ---------------------------------------------------------------------------

def test_persist_physical_tiers(data_dir):
    A = np.arange(12, dtype=np.float32).reshape(4, 3)
    X = fm.conv_R2FM(A)
    Xh = fm.persist(X, tier="host")
    assert Xh.m.on_host and not Xh.m.on_disk
    Xd = fm.persist(Xh, tier="disk", name="p1")
    assert Xd.m.on_disk
    np.testing.assert_array_equal(fm.as_np(fm.get_dense_matrix("p1")), A)
    with pytest.raises(ValueError, match="unknown tier"):
        fm.persist(X, tier="ssd")


def test_persist_virtual_marks_save(data_dir):
    A = np.arange(20, dtype=np.float32).reshape(5, 4)
    Z = fm.conv_R2FM(A) * 2.0
    out = fm.persist(Z, tier="disk")
    assert out is Z and Z.m.node.save == "disk"
    (Zm,) = fm.materialize(Z)
    assert Zm.m.on_disk
    np.testing.assert_allclose(fm.as_np(Zm), A * 2.0, rtol=1e-6)


def test_persist_sparse_roundtrips_sparse(data_dir):
    X, dense = _one_hot_case(seed=8, n=150)
    Xd = fm.persist(X, tier="disk", name="sp")
    assert isinstance(Xd.m.store, storage.CsrMmapStore)
    Xh = fm.persist(Xd, tier="host")
    assert isinstance(Xh.m.store, storage.SparseEllStore)
    np.testing.assert_array_equal(fm.as_np(Xh), dense)
    # Reopen by name: format dispatch keeps it sparse.
    assert fm.get_dense_matrix("sp").m.is_sparse


def test_deprecated_spellings_warn_and_delegate(data_dir):
    A = np.arange(12, dtype=np.float32).reshape(4, 3)
    with pytest.warns(DeprecationWarning, match="fm.persist"):
        Xd = fm.conv_store(fm.conv_R2FM(A), "disk", name="old1")
    assert Xd.m.on_disk
    Z = fm.conv_R2FM(A) + 1.0
    with pytest.warns(DeprecationWarning, match="fm.persist"):
        fm.set_mate_level(Z, "disk")
    assert Z.m.node.save == "disk"
    # The supported spellings stay warning-free.
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        fm.persist(fm.conv_R2FM(A), tier="host")
        fm.scale(fm.conv_R2FM(A), save="disk")


# ---------------------------------------------------------------------------
# Config surface (satellite: known-knob table + scoped fm.conf)
# ---------------------------------------------------------------------------

def test_set_conf_rejects_unknown_knob_with_hint():
    with pytest.raises(ValueError, match="did you mean 'prefetch'"):
        fm.set_conf(prefetsh=True)
    with pytest.raises(ValueError, match="known knobs"):
        fm.set_conf(not_even_close=1)


def test_conf_scoped_override_restores():
    from repro.core import lowering as lowering_mod
    from repro.core import matrix as matrix_mod
    old_backend = lowering_mod.DEFAULT_BACKEND
    old_bytes = matrix_mod.IO_PARTITION_BYTES
    with fm.conf(backend="pallas", io_partition_bytes=4096) as live:
        assert live["backend"] == "pallas"
        assert matrix_mod.IO_PARTITION_BYTES == 4096
    assert lowering_mod.DEFAULT_BACKEND == old_backend
    assert matrix_mod.IO_PARTITION_BYTES == old_bytes
    # Restores on error too.
    with pytest.raises(RuntimeError):
        with fm.conf(io_partition_bytes=8192):
            raise RuntimeError("boom")
    assert matrix_mod.IO_PARTITION_BYTES == old_bytes
    with pytest.raises(ValueError, match="unknown config knob"):
        with fm.conf(backnd="xla"):
            pass


# ---------------------------------------------------------------------------
# Streaming factor ingest + failure hygiene (satellite: no partial .fmat)
# ---------------------------------------------------------------------------

def test_ingest_factor_csv_roundtrip(data_dir, tmp_path):
    rng = np.random.default_rng(9)
    codes = np.stack([rng.integers(0, 6, 500), rng.integers(0, 4, 500)], 1)
    csv = tmp_path / "f.csv"
    np.savetxt(csv, codes, fmt="%d", delimiter=",")
    X = fm.load_factor_matrix(str(csv), "criteo_mini", num_levels=[6, 4],
                              chunk_rows=64)
    assert X.m.is_sparse and X.shape == (500, 10)
    dense = np.zeros((500, 10), np.float32)
    dense[np.arange(500), codes[:, 0]] = 1.0
    dense[np.arange(500), 6 + codes[:, 1]] = 1.0
    np.testing.assert_array_equal(fm.as_np(X), dense)


def test_ingest_factor_cardinality_overflow(data_dir, tmp_path):
    codes = np.array([[0, 1], [2, 9]])
    csv = tmp_path / "bad.csv"
    np.savetxt(csv, codes, fmt="%d", delimiter=",")
    with pytest.raises(ValueError, match="cardinality overflow"):
        fm.load_factor_matrix(str(csv), "overflow", num_levels=[3, 4])
    dest = storage.registry.matrix_path("overflow")
    assert not dest.exists(), "partial .fmat left behind"
    assert not list(dest.parent.glob("*.tmp")), "sidecar temp left behind"


def test_ingest_csv_malformed_rows_no_partial(data_dir, tmp_path):
    csv = tmp_path / "mal.csv"
    csv.write_text("1.0,2.0\n3.0,not_a_number\n")
    with pytest.raises(ValueError, match="malformed CSV"):
        fm.load_dense_matrix(str(csv), "mal")
    assert not storage.registry.matrix_path("mal").exists()


def test_ingest_csv_ragged_rows_no_partial(data_dir, tmp_path):
    csv = tmp_path / "rag.csv"
    # Chunked so the ragged row is seen AFTER a chunk already wrote.
    rows = ["1.0,2.0"] * 5 + ["1.0,2.0,3.0"]
    csv.write_text("\n".join(rows) + "\n")
    with pytest.raises(ValueError, match="ragged"):
        fm.load_dense_matrix(str(csv), "rag", chunk_rows=2)
    assert not storage.registry.matrix_path("rag").exists()


def test_ingest_binary_dtype_mismatch_no_partial(data_dir, tmp_path):
    raw = tmp_path / "odd.bin"
    raw.write_bytes(b"\x00" * 10)  # not a whole number of 3-col f32 rows
    with pytest.raises(ValueError, match="whole number"):
        fm.load_dense_matrix(str(raw), "oddbin", ncol=3)
    assert not storage.registry.matrix_path("oddbin").exists()


# ---------------------------------------------------------------------------
# Engine bookkeeping: signatures, stream accounting
# ---------------------------------------------------------------------------

def test_sparse_signature_differs_from_dense(data_dir):
    from repro.core.fusion import Plan
    X, dense = _one_hot_case(seed=10, n=100)
    D = fm.conv_R2FM(dense)
    assert (Plan([fm.crossprod(X).m]).signature()
            != Plan([fm.crossprod(D).m]).signature())


def test_sparse_stream_moves_nnz_bytes(data_dir):
    """exec stats over an ooc sparse stream account the CSR/ELL bytes, not
    the dense nrow·ncol·itemsize — the tier's whole point."""
    X, dense = _one_hot_case(seed=11, n=3000, levels=(500, 400))
    Xd = fm.persist(X, tier="disk", name="acct")
    with fm.collect_stats() as sc:
        fm.materialize(fm.crossprod(Xd), mode="ooc")
    moved = sc.stats()["stage_bytes_read"]
    assert 0 < moved < dense.nbytes / 10, (moved, dense.nbytes)
