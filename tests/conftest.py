"""Shared fixtures for the test suite.

Observability state (metrics counters, trace buffers) is process-global by
design; the autouse fixture here resets it around every test so counter
assertions in one test never see another test's activity, and a test that
enables the tracer can never leave it running for the rest of the session.
"""
import pytest

from repro.observability import metrics, trace


def _reset():
    trace.TRACER.stop()
    trace.TRACER.reset()
    metrics.REGISTRY.reset()


@pytest.fixture(autouse=True)
def _reset_observability():
    _reset()
    yield
    _reset()
