"""Shared plan-cache / epilogue instrumentation helpers.

Reused by test_lowering.py, test_epilogue.py and test_parity_fuzz.py: the
engine exposes raw counters (repro.core.materialize.exec_stats), and these
helpers turn them into delta assertions so tests state intent
("this block must MISS once then HIT twice, with one epilogue launch per
materialize") instead of poking at the counter dict.
"""
from __future__ import annotations

import contextlib
import dataclasses

from repro.core import materialize as mz


@dataclasses.dataclass
class CacheActivity:
    """Counter deltas observed across a ``cache_activity()`` block."""

    hits: int = 0
    misses: int = 0
    materialize_calls: int = 0
    epilogue_launches: int = 0
    epilogue_host_inputs: int = 0
    partition_steps: int = 0


@contextlib.contextmanager
def cache_activity():
    """Record plan-cache and epilogue counter deltas over a with-block."""
    before = mz.exec_stats()
    act = CacheActivity()
    try:
        yield act
    finally:
        after = mz.exec_stats()
        act.hits = after["plan_cache_hits"] - before["plan_cache_hits"]
        act.misses = after["plan_cache_misses"] - before["plan_cache_misses"]
        act.materialize_calls = (after["materialize_calls"]
                                 - before["materialize_calls"])
        act.epilogue_launches = (after["epilogue_launches"]
                                 - before["epilogue_launches"])
        act.epilogue_host_inputs = (after["epilogue_host_inputs"]
                                    - before["epilogue_host_inputs"])
        act.partition_steps = (after["partition_steps"]
                               - before["partition_steps"])


def assert_activity(act: CacheActivity, **expected):
    """Assert exact counter deltas, e.g. ``assert_activity(act, misses=1,
    hits=2, epilogue_launches=3)``.  Unmentioned counters are unchecked."""
    for name, want in expected.items():
        got = getattr(act, name)
        assert got == want, (
            f"{name}: expected {want}, got {got} (full activity: {act})")
