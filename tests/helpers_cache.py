"""Shared plan-cache / epilogue instrumentation helpers.

Reused by test_lowering.py, test_epilogue.py and test_parity_fuzz.py: the
engine exposes raw counters (repro.core.materialize.exec_stats), and these
helpers turn them into delta assertions so tests state intent
("this block must MISS once then HIT twice, with one epilogue launch per
materialize") instead of poking at the counter dict.
"""
from __future__ import annotations

import contextlib
import dataclasses

import numpy as np

from repro.core import materialize as mz
from repro.core.matrix import DenseStore, FMMatrix


@dataclasses.dataclass
class CacheActivity:
    """Counter deltas observed across a ``cache_activity()`` block."""

    hits: int = 0
    misses: int = 0
    materialize_calls: int = 0
    epilogue_launches: int = 0
    epilogue_host_inputs: int = 0
    partition_steps: int = 0


@contextlib.contextmanager
def cache_activity():
    """Record plan-cache and epilogue counter deltas over a with-block."""
    before = mz.exec_stats()
    act = CacheActivity()
    try:
        yield act
    finally:
        after = mz.exec_stats()
        act.hits = after["plan_cache_hits"] - before["plan_cache_hits"]
        act.misses = after["plan_cache_misses"] - before["plan_cache_misses"]
        act.materialize_calls = (after["materialize_calls"]
                                 - before["materialize_calls"])
        act.epilogue_launches = (after["epilogue_launches"]
                                 - before["epilogue_launches"])
        act.epilogue_host_inputs = (after["epilogue_host_inputs"]
                                    - before["epilogue_host_inputs"])
        act.partition_steps = (after["partition_steps"]
                               - before["partition_steps"])


def assert_activity(act: CacheActivity, **expected):
    """Assert exact counter deltas, e.g. ``assert_activity(act, misses=1,
    hits=2, epilogue_launches=3)``.  Unmentioned counters are unchecked."""
    for name, want in expected.items():
        got = getattr(act, name)
        assert got == want, (
            f"{name}: expected {want}, got {got} (full activity: {act})")


# ---------------------------------------------------------------------------
# Staging fault injection (multi-pass interruption tests)
# ---------------------------------------------------------------------------

class StagingFault(RuntimeError):
    """The simulated partition-staging failure raised by FlakyStore."""


class FlakyStore(DenseStore):
    """A host-tier DenseStore whose ``block()`` raises `StagingFault` after
    ``fail_after`` successful partition reads — simulates a disk/staging
    error mid-stream.  ``heal()`` turns the fault off so a retry of the
    same plan (same cache entry) can succeed."""

    def __init__(self, data: np.ndarray, fail_after: int):
        super().__init__(np.asarray(data))
        self.fail_after = int(fail_after)
        self.reads = 0
        self.failed = False

    def block(self, start: int, stop: int):
        if self.fail_after >= 0 and self.reads >= self.fail_after:
            self.failed = True
            raise StagingFault(
                f"injected staging failure after {self.reads} reads")
        self.reads += 1
        return super().block(start, stop)

    def heal(self):
        self.fail_after = -1


def flaky_matrix(arr: np.ndarray, fail_after: int):
    """A host-tier FMMatrix whose partition staging fails after
    ``fail_after`` block reads.  Returns ``(matrix, store)`` — call
    ``store.heal()`` to let a retry succeed."""
    arr = np.asarray(arr)
    store = FlakyStore(arr, fail_after)
    return FMMatrix(arr.shape, arr.dtype, store=store, name="flaky"), store


def assert_no_partial_results(*nodes):
    """After an interrupted execution, NO node of the plan may have been
    registered (a partially-registered sink would poison later cuts that
    reuse it as a source)."""
    for n in nodes:
        assert getattr(n, "cached_store", None) is None, (
            f"{n!r} was registered by an interrupted execution")
