"""Randomized cross-backend DAG parity fuzzing (ISSUE 4 satellite).

Random GenOp DAGs — row-local chains, aggregation sinks, POST-SINK
epilogue math, and EPILOGUE→ROW-LOCAL sweeps (the ``sweeprow`` op:
``mapply.row`` of a tall register against a merged vector, which makes the
planner schedule MULTI-PASS programs — moment pass → sweep pass, chains
included) — execute on every backend∈{xla, pallas} × mode∈{mem, stream,
ooc} cell and are checked against a NumPy float64 oracle evaluated
alongside the same program.

The harness is deterministic and shrinking-friendly without external
dependencies (hypothesis is optional in this environment): programs are
generated from ``FUZZ_SEED`` (example i uses seed FUZZ_SEED + i), and on
failure the harness greedily deletes instructions while the failure
reproduces, then reports the MINIMAL failing program as a paste-able repr.

Knobs (used by CI):
  FUZZ_EXAMPLES   number of random programs (default 25; PR fuzz job 200,
                  nightly cron 2000)
  FUZZ_SEED       base seed (default 0; PRs pin it, nightly varies it)
  FUZZ_BATCH      when set (nightly), every program ALSO executes through
                  ``fm.batch`` with its outputs split into 2–3 independent
                  requests over the shared sources — the co-scheduled
                  stream groups must match the same numpy oracle
  FUZZ_SERVE      when set (nightly), every program ALSO executes through
                  a ``fm.serve`` Engine with its outputs split into 2–3
                  requests SUBMITTED FROM CONCURRENT THREADS — the
                  admission window + group runner must match the oracle
  FUZZ_MESH       when set (nightly / the 8-device CI arm), every program
                  ALSO executes SHARDED over a host mesh
                  (``fm.materialize(mesh=make_host_mesh())``) — the
                  per-shard drives + cross-shard combine merges must match
                  the oracle for every cell (under 1 forced device this
                  still exercises the sharded code path with one shard)
  FUZZ_SPARSE     when set (the sparse CI arm), ~a third of the programs
                  run over a SPARSE source: register 0 becomes a
                  sparse-tier matrix (ELL slab for mem/stream, a CSR
                  ``.fmat`` for ooc) whose densified values equal the
                  oracle's input — every cell must match the same numpy
                  dense oracle, driving the SpMM matchers AND the
                  generic-trace densify fallback
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import List, Optional, Tuple

import numpy as np
import pytest

from repro.core import fm
from repro.core import materialize as mz

EXAMPLES = int(os.environ.get("FUZZ_EXAMPLES", "25"))
BASE_SEED = int(os.environ.get("FUZZ_SEED", "0"))
FUZZ_BATCH = os.environ.get("FUZZ_BATCH", "") not in ("", "0")
FUZZ_SERVE = os.environ.get("FUZZ_SERVE", "") not in ("", "0")
FUZZ_MESH = os.environ.get("FUZZ_MESH", "") not in ("", "0")
FUZZ_SPARSE = os.environ.get("FUZZ_SPARSE", "") not in ("", "0")

_HOST_MESH = None


def _host_mesh():
    """The fuzzer's shared host mesh over however many devices XLA exposes
    (1 locally; 8 under the CI --xla_force_host_platform_device_count=8
    arm).  Built once: mesh identity keys the plan cache."""
    global _HOST_MESH
    if _HOST_MESH is None:
        from repro.launch.mesh import make_host_mesh
        _HOST_MESH = make_host_mesh()
    return _HOST_MESH

CELLS = [(backend, mode)
         for backend in ("xla", "pallas")
         for mode in ("mem", "stream", "ooc")]

_SAPPLY = ("abs", "neg", "sq", "sqrt_abs")
_BINOPS = ("add", "sub", "mul", "pmin", "pmax")
_SCALARS = (0.7, -1.5, 2.0, 3.0)
_SINKS = ("colsums", "colmins", "colmaxs", "sumall", "crossprod")

#: Magnitude budget per register (tracked symbolically while generating):
#: keeps i32 accumulators far from overflow and float comparisons
#: well-conditioned.
_EST_CAP = {"f32": 1e5, "i32": 1e5}

#: Which tuple positions of each op are REGISTER references (other int
#: positions are seeds/widths and must never be treated as dependencies).
#: ``sweeprow`` is the epilogue→row-local edge: tall ∘ merged-vector.
_REG_ARGS = {
    "sapply": (1,), "sscalar": (1,), "mapply": (1, 2), "mapply_row": (1,),
    "rowsums": (1,), "cbind": (1, 2), "matmul": (1,), "colsums": (1,),
    "colmins": (1,), "colmaxs": (1,), "sumall": (1,), "crossprod": (1, 2),
    "escalar": (1,), "emap": (1, 2), "esapply": (1,), "esum": (1,),
    "sweeprow": (1, 2),
}


def _reg_args(op) -> list:
    return [op[i] for i in _REG_ARGS[op[0]] if op[i] is not None]


@dataclasses.dataclass
class Program:
    """A straight-line GenOp program.  Register 0 is the input matrix;
    instruction k writes register k+1.  ``outputs`` lists registers to
    materialize together (one fused plan)."""

    seed: int
    n: int
    p: int
    dtype: str                       # 'f32' | 'i32'
    ops: List[Tuple]
    outputs: List[int]
    sparse: bool = False             # register 0 is a sparse-tier source

    def __repr__(self):
        lines = [f"Program(seed={self.seed}, n={self.n}, p={self.p}, "
                 f"dtype={self.dtype!r},"
                 + (f" sparse={self.sparse!r}," if self.sparse else "")]
        lines.append("  ops=[")
        for k, op in enumerate(self.ops):
            lines.append(f"    {op!r},   # -> r{k + 1}")
        lines.append(f"  ], outputs={self.outputs})")
        return "\n".join(lines)


def _vec(seed: int, w: int) -> np.ndarray:
    r = np.random.default_rng(seed)
    return (r.uniform(0.5, 2.0, w) * r.choice([-1.0, 1.0], w)) \
        .astype(np.float32)


def _mat(seed: int, w: int, q: int) -> np.ndarray:
    return np.random.default_rng(seed).uniform(-1.5, 1.5, (w, q)) \
        .astype(np.float32)


def _input(prog: Program) -> np.ndarray:
    r = np.random.default_rng(prog.seed)
    if prog.dtype == "i32":
        x = r.integers(-20, 21, size=(prog.n, prog.p)).astype(np.int32)
    else:
        x = (r.normal(size=(prog.n, prog.p)) * 2).astype(np.float32)
    if prog.sparse:
        # The sparse arm's source: mostly-zero rows whose DENSIFIED values
        # are exactly what the oracle consumes.
        x = x * (r.random(size=x.shape) < 0.35)
    return x


def _sparse_fm(xn: np.ndarray, *, disk: bool):
    """Register 0 of a sparse program: the same values as the oracle's
    dense input, on the sparse tier — an ELL slab (SparseEllStore), or a
    CSR ``.fmat`` reopened through the registry for the ooc cell."""
    from repro import storage
    from repro.core.matrix import FMMatrix
    from repro.core.sparse import csr_from_dense, ell_from_csr_rows
    indptr, indices, data = csr_from_dense(xn)
    kmax = max(1, int(np.diff(indptr).max()) if xn.shape[0] else 1)
    blk = ell_from_csr_rows(indptr, indices, data, 0, xn.shape[0], kmax,
                            xn.shape[1])
    m = FMMatrix(xn.shape, xn.dtype,
                 store=storage.SparseEllStore(blk.cols, blk.vals,
                                              xn.shape[1]))
    if disk:
        m = storage.save_sparse_matrix(m, "fuzz_sparse")
    return fm.FM(m)


# ---------------------------------------------------------------------------
# Generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Reg:
    tag: str        # 'tall' | 'post'
    ncol: int
    est: float      # loose abs-magnitude bound (overflow/conditioning guard)
    nrow: int = 1   # post registers only (talls all share the long dim)


def generate(seed: int) -> Program:
    r = np.random.default_rng(seed)
    n = int(r.choice([48, 64, 96, 130]))
    p = int(r.choice([1, 2, 3, 4]))
    dtype = "i32" if r.random() < 0.25 else "f32"
    # Always consume the draw so program generation is identical with and
    # without the FUZZ_SPARSE arm enabled.
    sparse = (r.random() < 0.35) and FUZZ_SPARSE
    cap = _EST_CAP[dtype]
    regs = [_Reg("tall", p, 25.0)]
    ops: List[Tuple] = []

    def talls():
        return [i for i, g in enumerate(regs) if g.tag == "tall"]

    def posts():
        return [i for i, g in enumerate(regs) if g.tag == "post"]

    def emit(op, reg):
        ops.append(op)
        regs.append(reg)

    n_ops = int(r.integers(3, 10))
    for _ in range(n_ops):
        kind = r.choice(["tall", "tall", "sink", "epi", "epi"])
        if kind == "epi" and not posts():
            kind = "sink"  # seed a sink so epilogue chains can grow on it
        if kind == "tall":
            i = int(r.choice(talls()))
            g = regs[i]
            # sweeprow (mapply.row against a MERGED vector) schedules the
            # consumer one pass later than the vector's pass — the program
            # becomes multi-pass, chains included.
            sweep_js = [j for j in posts()
                        if regs[j].nrow == 1 and regs[j].ncol == g.ncol]
            tall_ops = ["sapply", "sscalar", "mapply", "mapply_row",
                        "rowsums", "cbind", "matmul"]
            if sweep_js:
                tall_ops += ["sweeprow", "sweeprow"]
            choice = r.choice(tall_ops)
            if choice == "sapply":
                f = str(r.choice(_SAPPLY))
                est = g.est * g.est if f == "sq" else g.est
                if est > cap:
                    f, est = "abs", g.est
                emit(("sapply", i, f), _Reg("tall", g.ncol, est))
            elif choice == "sscalar":
                op = str(r.choice(("add", "sub", "mul", "div")))
                c = float(r.choice(_SCALARS))
                if op == "div":
                    c = abs(c) + 0.5
                est = g.est * abs(c) if op == "mul" else g.est + abs(c)
                if est > cap:
                    continue
                emit(("sscalar", i, op, c), _Reg("tall", g.ncol, est))
            elif choice == "mapply":
                js = [j for j in talls() if regs[j].ncol == g.ncol]
                j = int(r.choice(js))
                op = str(r.choice(_BINOPS))
                est = (g.est * regs[j].est if op == "mul"
                       else g.est + regs[j].est)
                if est > cap:
                    continue
                emit(("mapply", i, j, op), _Reg("tall", g.ncol, est))
            elif choice == "mapply_row":
                op = str(r.choice(("add", "sub", "mul", "div")))
                est = g.est * 2 + 2
                if est > cap:
                    continue
                emit(("mapply_row", i, int(r.integers(1 << 20)), op),
                     _Reg("tall", g.ncol, est))
            elif choice == "sweeprow":
                j = int(r.choice(sweep_js))
                op = str(r.choice(("add", "sub", "mul", "pmin", "pmax")))
                est = (g.est * regs[j].est if op == "mul"
                       else g.est + regs[j].est)
                if est > cap:
                    continue
                emit(("sweeprow", i, j, op), _Reg("tall", g.ncol, est))
            elif choice == "rowsums":
                emit(("rowsums", i), _Reg("tall", 1, g.est * g.ncol))
            elif choice == "cbind":
                j = int(r.choice(talls()))
                if g.ncol + regs[j].ncol > 6:
                    continue
                emit(("cbind", i, j),
                     _Reg("tall", g.ncol + regs[j].ncol,
                          max(g.est, regs[j].est)))
            elif choice == "matmul":
                q = int(r.integers(1, 4))
                est = g.est * g.ncol * 1.5
                if est > cap:
                    continue
                emit(("matmul", i, int(r.integers(1 << 20)), q),
                     _Reg("tall", q, est))
        elif kind == "sink":
            i = int(r.choice(talls()))
            g = regs[i]
            choice = str(r.choice(_SINKS))
            if choice == "crossprod":
                js = [None] + talls()
                j = js[int(r.integers(len(js)))]
                jest = g.est if j is None else regs[j].est
                jcol = g.ncol if j is None else regs[j].ncol
                if g.est * jest * n > 5e7:
                    continue
                emit(("crossprod", i, j),
                     _Reg("post", jcol, g.est * jest * n, nrow=g.ncol))
            elif choice == "sumall":
                if g.est * n * g.ncol > 5e7:
                    continue
                emit(("sumall", i), _Reg("post", 1, g.est * n * g.ncol))
            else:
                if choice == "colsums" and g.est * n > 5e7:
                    continue
                emit((choice, i),
                     _Reg("post", g.ncol,
                          g.est * (n if choice == "colsums" else 1)))
        else:  # epilogue math over post values
            if not posts():
                continue
            i = int(r.choice(posts()))
            g = regs[i]
            choice = r.choice(["escalar", "emap", "esapply", "esum"])
            if choice == "escalar":
                op = str(r.choice(("add", "sub", "mul", "div")))
                c = float(r.choice(_SCALARS))
                if op == "div":
                    c = abs(c) + 0.5
                emit(("escalar", i, op, c),
                     _Reg("post", g.ncol, g.est * abs(c) + abs(c),
                          nrow=g.nrow))
            elif choice == "emap":
                js = [j for j in posts() if j != i
                      and regs[j].ncol == g.ncol
                      and regs[j].nrow == g.nrow]
                if not js:
                    continue
                j = int(r.choice(js))
                op = str(r.choice(_BINOPS))
                est = (g.est * regs[j].est if op == "mul"
                       else g.est + regs[j].est)
                if est > 1e10:
                    continue
                emit(("emap", i, j, op),
                     _Reg("post", g.ncol, est, nrow=g.nrow))
            elif choice == "esapply":
                f = str(r.choice(("abs", "neg", "sqrt_abs")))
                emit(("esapply", i, f),
                     _Reg("post", g.ncol, g.est, nrow=g.nrow))
            elif choice == "esum":
                emit(("esum", i), _Reg("post", 1, g.est * g.ncol))

    if not any(regs[k].tag == "post" for k in range(1, len(regs))):
        i = int(r.choice(talls()))
        emit(("colmaxs", i), _Reg("post", regs[i].ncol, regs[i].est))

    consumed = set()
    for op in ops:
        consumed.update(_reg_args(op))
    outputs = [k for k in range(1, len(regs)) if k not in consumed]
    if not outputs:
        outputs = [len(regs) - 1]
    return Program(seed=seed, n=n, p=p, dtype=dtype, ops=ops,
                   outputs=outputs, sparse=sparse)


# ---------------------------------------------------------------------------
# Evaluation: the engine and the numpy oracle interpret the SAME program
# ---------------------------------------------------------------------------

def eval_numpy(prog: Program) -> List[np.ndarray]:
    x = _input(prog).astype(np.float64)
    regs = [x]

    def f1(v, f):
        return {"abs": np.abs, "neg": np.negative, "sq": np.square,
                "sqrt_abs": lambda u: np.sqrt(np.abs(u))}[f](v)

    def f2(a, b, op):
        return {"add": np.add, "sub": np.subtract, "mul": np.multiply,
                "div": np.divide, "pmin": np.minimum,
                "pmax": np.maximum}[op](a, b)

    for op in prog.ops:
        k = op[0]
        if k == "sapply" or k == "esapply":
            regs.append(f1(regs[op[1]], op[2]))
        elif k == "sscalar" or k == "escalar":
            regs.append(f2(regs[op[1]], op[3], op[2]))
        elif k == "mapply" or k == "emap":
            regs.append(f2(regs[op[1]], regs[op[2]], op[3]))
        elif k == "mapply_row":
            v = _vec(op[2], regs[op[1]].shape[1]).astype(np.float64)
            regs.append(f2(regs[op[1]], v.reshape(1, -1), op[3]))
        elif k == "sweeprow":
            regs.append(f2(regs[op[1]], regs[op[2]].reshape(1, -1), op[3]))
        elif k == "rowsums":
            regs.append(regs[op[1]].sum(1, keepdims=True))
        elif k == "cbind":
            regs.append(np.concatenate([regs[op[1]], regs[op[2]]], 1))
        elif k == "matmul":
            m = _mat(op[2], regs[op[1]].shape[1], op[3]).astype(np.float64)
            regs.append(regs[op[1]] @ m)
        elif k == "colsums":
            regs.append(regs[op[1]].sum(0, keepdims=True))
        elif k == "colmins":
            regs.append(regs[op[1]].min(0, keepdims=True))
        elif k == "colmaxs":
            regs.append(regs[op[1]].max(0, keepdims=True))
        elif k == "sumall" or k == "esum":
            regs.append(regs[op[1]].sum().reshape(1, 1))
        elif k == "crossprod":
            a = regs[op[1]]
            b = a if op[2] is None else regs[op[2]]
            regs.append(a.T @ b)
        else:  # pragma: no cover - generator/evaluator mismatch
            raise AssertionError(f"unknown op {k}")
    return [np.asarray(regs[i], np.float64) for i in prog.outputs]


def _lazy_outputs(prog: Program, mode: str) -> list:
    """Build the program's lazy output handles (shared by the fused-serial
    and batched evaluation arms)."""
    xn = _input(prog)
    if prog.sparse:
        X = _sparse_fm(xn, disk=(mode == "ooc"))
    else:
        X = fm.conv_R2FM(xn, host=(mode == "ooc"))
    regs = [X]

    def f1(v, f):
        if f == "sqrt_abs":
            return fm.sqrt(fm.abs_(v))
        return {"abs": fm.abs_, "neg": lambda u: -u,
                "sq": lambda u: u ** 2}[f](v)

    def f2(a, b, op):
        if op == "pmin":
            return fm.pmin(a, b)
        if op == "pmax":
            return fm.pmax(a, b)
        return {"add": lambda u, v: u + v, "sub": lambda u, v: u - v,
                "mul": lambda u, v: u * v,
                "div": lambda u, v: u / v}[op](a, b)

    for op in prog.ops:
        k = op[0]
        if k == "sapply" or k == "esapply":
            regs.append(f1(regs[op[1]], op[2]))
        elif k == "sscalar" or k == "escalar":
            regs.append(f2(regs[op[1]], op[3], op[2]))
        elif k == "mapply" or k == "emap":
            regs.append(f2(regs[op[1]], regs[op[2]], op[3]))
        elif k == "mapply_row":
            v = _vec(op[2], regs[op[1]].ncol)
            regs.append(fm.mapply_row(regs[op[1]], v, op[3]))
        elif k == "sweeprow":
            # LAZY merged vector: the engine must schedule a later pass.
            regs.append(fm.mapply_row(regs[op[1]], regs[op[2]], op[3]))
        elif k == "rowsums":
            regs.append(fm.rowSums(regs[op[1]]))
        elif k == "cbind":
            regs.append(fm.cbind(regs[op[1]], regs[op[2]]))
        elif k == "matmul":
            regs.append(regs[op[1]] @ _mat(op[2], regs[op[1]].ncol, op[3]))
        elif k == "colsums":
            regs.append(fm.colSums(regs[op[1]]))
        elif k == "colmins":
            regs.append(fm.colMins(regs[op[1]]))
        elif k == "colmaxs":
            regs.append(fm.colMaxs(regs[op[1]]))
        elif k == "sumall" or k == "esum":
            regs.append(fm.sum_(regs[op[1]]))
        elif k == "crossprod":
            b = None if op[2] is None else regs[op[2]]
            regs.append(fm.crossprod(regs[op[1]], b))
        else:  # pragma: no cover
            raise AssertionError(f"unknown op {k}")
    return [regs[i] for i in prog.outputs]


def eval_engine(prog: Program, backend: str, mode: str) -> List[np.ndarray]:
    exec_mode = {"mem": "whole", "stream": "stream", "ooc": "ooc"}[mode]
    lazies = _lazy_outputs(prog, mode)
    outs = fm.materialize(*lazies, mode=exec_mode, backend=backend)
    return [np.asarray(fm.as_np(o), np.float64) for o in outs]


def eval_engine_meshed(prog: Program, backend: str,
                       mode: str) -> List[np.ndarray]:
    """The FUZZ_MESH arm: the same program materialized with an explicit
    host mesh — whole mode runs the step SPMD over sharded inputs,
    stream/ooc split the partition sweep into per-device shard drives
    merged through each plan's ``combine``."""
    exec_mode = {"mem": "whole", "stream": "stream", "ooc": "ooc"}[mode]
    lazies = _lazy_outputs(prog, mode)
    outs = fm.materialize(*lazies, mode=exec_mode, backend=backend,
                          mesh=_host_mesh())
    return [np.asarray(fm.as_np(o), np.float64) for o in outs]


def eval_engine_batched(prog: Program, backend: str, mode: str) -> List[np.ndarray]:
    """The FUZZ_BATCH arm: the same program, but its outputs split
    round-robin into 2–3 independent requests over the shared sources and
    executed through ``fm.batch`` — the co-scheduler must fuse the requests'
    streams without changing any value."""
    exec_mode = {"mem": "whole", "stream": "stream", "ooc": "ooc"}[mode]
    lazies = _lazy_outputs(prog, mode)
    k = min(3, len(lazies))
    reqs = [tuple(lazies[j] for j in range(i, len(lazies), k))
            for i in range(k)]
    results = fm.batch(*reqs, mode=exec_mode, backend=backend)
    out: List[Optional[np.ndarray]] = [None] * len(lazies)
    for i, res in enumerate(results):
        vals = res if isinstance(res, list) else [res]
        for j, v in zip(range(i, len(lazies), k), vals):
            out[j] = np.asarray(fm.as_np(v), np.float64)
    return out


def eval_engine_served(prog: Program, backend: str,
                       mode: str) -> List[np.ndarray]:
    """The FUZZ_SERVE arm: the same program through the async serving
    layer — outputs split round-robin into 2–3 requests, each SUBMITTED
    FROM ITS OWN THREAD into one admission window, so the fuzzer drives
    the concurrent plan-construction + window-coalescing path."""
    from repro.core.serve import Engine
    exec_mode = {"mem": "whole", "stream": "stream", "ooc": "ooc"}[mode]
    lazies = _lazy_outputs(prog, mode)
    k = min(3, len(lazies))
    reqs = [tuple(lazies[j] for j in range(i, len(lazies), k))
            for i in range(k)]
    handles: List = [None] * k
    errors: List[BaseException] = []
    barrier = threading.Barrier(k)

    def submit(i):
        try:
            barrier.wait(timeout=30)
            handles[i] = eng.submit(*reqs[i])
        except BaseException as exc:  # noqa: BLE001 - re-raised below
            errors.append(exc)

    with Engine(window_ms=2000, max_window_requests=k, mode=exec_mode,
                backend=backend, midstream_admission=False) as eng:
        threads = [threading.Thread(target=submit, args=(i,))
                   for i in range(k)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        if errors:
            raise errors[0]
        out: List[Optional[np.ndarray]] = [None] * len(lazies)
        for i, h in enumerate(handles):
            res = h.result(timeout=120)
            vals = res if isinstance(res, (list, tuple)) else [res]
            for j, v in zip(range(i, len(lazies), k), vals):
                out[j] = np.asarray(fm.as_np(v), np.float64)
    return out


def check_cell(prog: Program, backend: str, mode: str) -> Optional[str]:
    """Run one grid cell against the oracle; returns an error string (or
    None) instead of raising, so the shrinker can probe cheaply."""
    try:
        refs = eval_numpy(prog)
        arms = [("", eval_engine(prog, backend, mode))]
        if FUZZ_MESH:
            arms.append(("meshed:", eval_engine_meshed(prog, backend, mode)))
        if FUZZ_BATCH:
            arms.append(("batched:", eval_engine_batched(prog, backend, mode)))
        if FUZZ_SERVE:
            arms.append(("served:", eval_engine_served(prog, backend, mode)))
        for label, gots in arms:
            for o, (got, ref) in zip(prog.outputs, zip(gots, refs)):
                scale = max(1.0, float(np.max(np.abs(ref))))
                err = float(np.max(np.abs(got - ref))) / scale
                if not np.isfinite(got).all() and np.isfinite(ref).all():
                    return f"{label}r{o}: non-finite engine result"
                if err > 2e-3:
                    return (f"{label}r{o}: normalized max abs err {err:.2e} "
                            f"(got[0,0]={got.flat[0]!r} "
                            f"ref[0,0]={ref.flat[0]!r})")
        return None
    except AssertionError:
        raise
    except Exception as e:  # engine crash on a valid program IS a failure
        return f"{type(e).__name__}: {e}"


# ---------------------------------------------------------------------------
# Shrinking: greedy instruction deletion, dependency-aware
# ---------------------------------------------------------------------------

def _drop_op(prog: Program, k: int) -> Optional[Program]:
    """Program with instruction k removed (register k+1 dropped), or None
    when a later instruction or the sole output depends on it."""
    victim = k + 1
    for later in prog.ops[k + 1:]:
        if victim in _reg_args(later):
            return None
    outputs = [o for o in prog.outputs if o != victim]
    if not outputs:
        return None

    ops = []
    for idx, op in enumerate(prog.ops):
        if idx == k:
            continue
        op = list(op)
        for pos in _REG_ARGS[op[0]]:
            if op[pos] is not None and op[pos] > victim:
                op[pos] -= 1
        ops.append(tuple(op))
    return dataclasses.replace(
        prog, ops=ops, outputs=[o - 1 if o > victim else o for o in outputs])


def shrink(prog: Program, backend: str, mode: str, budget: int = 150):
    """Greedy delta-debugging: drop instructions while the cell still
    fails.  Deterministic, bounded, dependency-safe."""
    evals = 0
    changed = True
    while changed and evals < budget:
        changed = False
        for k in reversed(range(len(prog.ops))):
            cand = _drop_op(prog, k)
            if cand is None:
                continue
            evals += 1
            if evals >= budget:
                break
            if check_cell(cand, backend, mode) is not None:
                prog = cand
                changed = True
    return prog


# ---------------------------------------------------------------------------
# The fuzz loop
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", autouse=True)
def _fuzz_config():
    from repro.core import matrix as matrix_mod
    old = matrix_mod.IO_PARTITION_BYTES
    fm.set_conf(io_partition_bytes=2048)  # force real multi-partition runs
    mz.clear_plan_cache()
    yield
    matrix_mod.IO_PARTITION_BYTES = old
    mz.clear_plan_cache()


def _report_failure(text: str):
    """Persist the shrunk repro where CI can pick it up as an artifact
    (FUZZ_REPORT env var names the file; see the fuzz jobs in ci.yml)."""
    path = os.environ.get("FUZZ_REPORT")
    if not path:
        return
    with open(path, "a", encoding="utf-8") as fh:
        fh.write(f"FUZZ_SEED base={BASE_SEED} examples={EXAMPLES}\n")
        fh.write(text + "\n\n")


def _run_examples(indices):
    import jax
    failures = []
    for count, i in enumerate(indices):
        prog = generate(BASE_SEED + i)
        for backend, mode in CELLS:
            err = check_cell(prog, backend, mode)
            if err is not None:
                small = shrink(prog, backend, mode)
                failures.append(
                    f"seed={prog.seed} cell=({backend},{mode}): {err}\n"
                    f"minimal failing program:\n{small!r}")
                break
        mz.clear_plan_cache()
        if (count + 1) % 20 == 0:
            jax.clear_caches()  # bound jit-cache growth over long runs
        if failures:
            break
    if failures:
        _report_failure(failures[0])
        pytest.fail(failures[0])


# Split the example budget into a few pytest items so progress is visible
# and a failure reports early without discarding the whole budget.
_CHUNKS = 5
_chunk_ids = list(range(_CHUNKS))


@pytest.mark.parametrize("chunk", _chunk_ids)
def test_random_dag_parity(chunk):
    lo = EXAMPLES * chunk // _CHUNKS
    hi = EXAMPLES * (chunk + 1) // _CHUNKS
    if lo == hi:
        pytest.skip("no examples in this chunk")
    _run_examples(range(lo, hi))


def test_generator_is_deterministic():
    assert repr(generate(BASE_SEED)) == repr(generate(BASE_SEED))


def test_known_epilogue_program_parity():
    """A hand-pinned program exercising the sink→epilogue→epilogue-sink
    shape on every cell (always runs, independent of FUZZ_EXAMPLES)."""
    prog = Program(
        seed=1234, n=96, p=3, dtype="f32",
        ops=[
            ("sapply", 0, "sq"),        # -> r1
            ("colsums", 1),             # -> r2  sink
            ("colsums", 0),             # -> r3  sink
            ("escalar", 3, "div", 2.0),  # -> r4  epilogue
            ("emap", 2, 4, "sub"),      # -> r5  epilogue
            ("esapply", 5, "sqrt_abs"),  # -> r6  epilogue
            ("esum", 6),                # -> r7  epilogue-evaluated sink
        ],
        outputs=[6, 7])
    for backend, mode in CELLS:
        err = check_cell(prog, backend, mode)
        assert err is None, f"cell=({backend},{mode}): {err}"


def test_known_multipass_program_parity():
    """A hand-pinned epilogue→row-local program (the ``scale(X)`` shape:
    sink → epilogue → sweep → sink-over-the-sweep) on every cell — the
    multi-pass planner's fuzz anchor, independent of FUZZ_EXAMPLES."""
    prog = Program(
        seed=4321, n=96, p=3, dtype="f32",
        ops=[
            ("colsums", 0),                # -> r1  pass-1 sink
            ("escalar", 1, "div", 2.0),    # -> r2  pass-1 epilogue
            ("sweeprow", 0, 2, "sub"),     # -> r3  PASS-2 row-local sweep
            ("sapply", 3, "abs"),          # -> r4  pass-2 chain
            ("colmaxs", 4),                # -> r5  pass-2 sink
            ("sweeprow", 0, 1, "pmin"),    # -> r6  sink bound directly
        ],
        outputs=[3, 5, 6])
    for backend, mode in CELLS:
        err = check_cell(prog, backend, mode)
        assert err is None, f"cell=({backend},{mode}): {err}"


def test_known_program_batched_parity():
    """Always-on anchor for the FUZZ_BATCH arm: a hand-pinned multi-output
    multipass program executed through ``fm.batch`` (outputs split into
    independent co-scheduled requests) matches the oracle on every cell,
    independent of the nightly FUZZ_BATCH budget."""
    prog = Program(
        seed=9876, n=96, p=3, dtype="f32",
        ops=[
            ("colsums", 0),                # -> r1  pass-1 sink
            ("escalar", 1, "div", 2.0),    # -> r2  pass-1 epilogue
            ("sweeprow", 0, 2, "sub"),     # -> r3  PASS-2 row-local sweep
            ("sapply", 3, "abs"),          # -> r4  pass-2 chain
            ("colmaxs", 4),                # -> r5  pass-2 sink
            ("sumall", 0),                 # -> r6  independent sink
        ],
        outputs=[3, 5, 6])
    refs = eval_numpy(prog)
    for backend, mode in CELLS:
        gots = eval_engine_batched(prog, backend, mode)
        for o, got, ref in zip(prog.outputs, gots, refs):
            scale = max(1.0, float(np.max(np.abs(ref))))
            err = float(np.max(np.abs(got - ref))) / scale
            assert err <= 2e-3, (
                f"cell=({backend},{mode}) r{o}: batched err {err:.2e}")
        mz.clear_plan_cache()


def test_known_program_served_parity():
    """Always-on anchor for the FUZZ_SERVE arm: a hand-pinned multi-output
    multipass program served through an Engine admission window with its
    requests submitted from concurrent threads matches the oracle on every
    cell, independent of the nightly FUZZ_SERVE budget."""
    prog = Program(
        seed=6789, n=96, p=3, dtype="f32",
        ops=[
            ("colsums", 0),                # -> r1  pass-1 sink
            ("escalar", 1, "div", 2.0),    # -> r2  pass-1 epilogue
            ("sweeprow", 0, 2, "sub"),     # -> r3  PASS-2 row-local sweep
            ("sapply", 3, "abs"),          # -> r4  pass-2 chain
            ("colmaxs", 4),                # -> r5  pass-2 sink
            ("sumall", 0),                 # -> r6  independent sink
        ],
        outputs=[3, 5, 6])
    refs = eval_numpy(prog)
    for backend, mode in CELLS:
        gots = eval_engine_served(prog, backend, mode)
        for o, got, ref in zip(prog.outputs, gots, refs):
            scale = max(1.0, float(np.max(np.abs(ref))))
            err = float(np.max(np.abs(got - ref))) / scale
            assert err <= 2e-3, (
                f"cell=({backend},{mode}) r{o}: served err {err:.2e}")
        mz.clear_plan_cache()


def test_known_program_meshed_parity():
    """Always-on anchor for the FUZZ_MESH arm: a hand-pinned multi-output
    multipass program materialized with an explicit host mesh matches the
    oracle on every cell, independent of the nightly FUZZ_MESH budget
    (1 shard locally; 8 under the CI forced-8-device arm)."""
    prog = Program(
        seed=2468, n=96, p=3, dtype="f32",
        ops=[
            ("colsums", 0),                # -> r1  pass-1 sink
            ("escalar", 1, "div", 2.0),    # -> r2  pass-1 epilogue
            ("sweeprow", 0, 2, "sub"),     # -> r3  PASS-2 row-local sweep
            ("sapply", 3, "abs"),          # -> r4  pass-2 chain
            ("colmaxs", 4),                # -> r5  pass-2 sink
            ("sumall", 0),                 # -> r6  independent sink
        ],
        outputs=[3, 5, 6])
    refs = eval_numpy(prog)
    for backend, mode in CELLS:
        gots = eval_engine_meshed(prog, backend, mode)
        for o, got, ref in zip(prog.outputs, gots, refs):
            scale = max(1.0, float(np.max(np.abs(ref))))
            err = float(np.max(np.abs(got - ref))) / scale
            assert err <= 2e-3, (
                f"cell=({backend},{mode}) r{o}: meshed err {err:.2e}")
        mz.clear_plan_cache()


def test_known_sparse_program_parity():
    """Always-on anchor for the FUZZ_SPARSE arm: a hand-pinned program over
    a sparse-tier source — the SpMM gram claim (crossprod), the gather
    matmul (matmul_small), a sink and a multipass sweep all from ONE CSR/ELL
    register — matches the dense numpy oracle on every cell, independent of
    the FUZZ_SPARSE budget."""
    prog = Program(
        seed=1357, n=130, p=3, dtype="f32", sparse=True,
        ops=[
            ("crossprod", 0, None),        # -> r1  SpMM gram sink
            ("matmul", 0, 42, 2),          # -> r2  sparse gather matmul
            ("colsums", 0),                # -> r3  sink over the sparse leaf
            ("escalar", 3, "div", 2.0),    # -> r4  epilogue
            ("sweeprow", 0, 4, "sub"),     # -> r5  PASS-2 sweep (densify)
            ("sapply", 2, "abs"),          # -> r6  chain off the matmul
        ],
        outputs=[1, 5, 6])
    for backend, mode in CELLS:
        err = check_cell(prog, backend, mode)
        assert err is None, f"cell=({backend},{mode}): {err}"
    mz.clear_plan_cache()


def test_generator_emits_multipass_programs():
    """The generator actually produces epilogue→row-local edges, so the CI
    fuzz budget exercises the multi-pass planner."""
    hits = sum(any(op[0] == "sweeprow" for op in generate(s).ops)
               for s in range(200))
    assert hits >= 10, f"only {hits}/200 programs contained a sweeprow"
