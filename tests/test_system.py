"""End-to-end system tests: the paper's workflow on the full stack.

1. The FlashR user journey: load data on the slow tier, run R-style
   analytics + ML, results match in-memory execution bit-for-bit modulo
   reduction order.
2. The LM framework journey: train a reduced model for a few steps with
   checkpointing, kill, resume, serve — loss goes down, resume is exact.
"""
import numpy as np
import pytest

from repro.core import fm

pytestmark = pytest.mark.slow  # ~25s: end-to-end user journeys


def test_flashr_user_journey():
    rng = np.random.default_rng(0)
    n, p, k = 40_000, 12, 4
    centers = rng.normal(size=(k, p)) * 10
    X_host = np.concatenate(
        [c + rng.normal(size=(n // k, p)) for c in centers]).astype(np.float32)

    # data lives on the SSD-analog tier the whole time
    X = fm.conv_R2FM(X_host, host=True)

    # 1) normalize lazily, 2) stats + correlation in one fused pass
    from repro.algorithms import correlation, kmeans, summary, svd_tall
    s = summary(X)
    assert np.isfinite(s.mean).all() and (s.var > 0).all()

    corr = correlation(X)
    assert np.allclose(np.diag(corr), 1.0, atol=1e-5)

    svd = svd_tall(X, k=4)
    assert (np.diff(svd.s) <= 1e-6).all()  # descending

    res = kmeans(X, k=k, max_iter=20, seed=0)
    d = np.linalg.norm(res.centers[:, None] - centers[None], axis=-1)
    assert (d.min(1) < 1.0).all()

    # identical results from the in-memory tier
    Xd = fm.conv_R2FM(X_host)
    corr2 = correlation(Xd)
    np.testing.assert_allclose(corr, corr2, rtol=1e-4, atol=1e-5)


def test_lm_train_checkpoint_resume(tmp_path):
    from repro.launch import train

    ck = str(tmp_path / "ck")
    losses = train.main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "8",
                         "--batch", "4", "--seq", "64", "--ckpt-dir", ck,
                         "--ckpt-every", "4", "--log-every", "100"])
    assert losses[-1] < losses[0], "loss must decrease"

    resumed = train.main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "10",
                          "--batch", "4", "--seq", "64", "--ckpt-dir", ck,
                          "--resume", "--log-every", "100"])
    assert len(resumed) == 2  # steps 8..9 only: resume picked up step 8


def test_serve_loadgen_journey(tmp_path):
    """The serving journey (ISSUE 8): the load generator's serial-vs-serve
    arms over one named disk matrix — each wave's concurrent same-source
    requests share ONE streaming drive and read strictly fewer bytes."""
    from repro.launch import serve

    fm.set_conf(data_dir=str(tmp_path / "fmdata"))
    serial, served = serve.main([
        "--n", "6000", "--p", "4", "--clients", "3", "--waves", "2",
        "--partition-kib", "16", "--name", "system_serve_x"])
    assert served["streams"] == 2            # one stream per wave
    assert serial["streams"] == 6            # one stream per request
    assert served["bytes_per_request"] * 3 == serial["bytes_per_request"]
    assert served["requests"] == serial["requests"] == 6
