"""Fault-tolerance paths that need real (placeholder) multi-device meshes.

Run in subprocesses so the main pytest process keeps its 1-device view
(dryrun.py device-count contract).

1. Elastic re-mesh: checkpoint written under mesh A (8 devices) restores
   onto mesh B (4 devices, different sharding) bit-exact — the node-failure
   recovery path of runtime/fault_tolerance.py.
2. int8 error-feedback gradient reduction across a `pod` axis inside
   shard_map — the cross-pod DCN compression (optim/compression.py),
   verified unbiased against the exact f32 psum.
"""
import json
import os
import subprocess
import sys
import textwrap
import pytest

pytestmark = pytest.mark.slow  # ~100s: subprocess multi-device trainings

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, cwd=_ROOT, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_elastic_remesh_restore(tmp_path):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import Checkpointer
        from repro.runtime import replan_mesh, rescale_grad_accum

        # "Before failure": 8 devices, (4, 2) mesh, params FSDP+TP sharded.
        from repro.launch.mesh import make_host_mesh
        mesh_a = make_host_mesh(8, model=2)
        w = jnp.arange(64.0 * 32).reshape(64, 32)
        sh_a = NamedSharding(mesh_a, P("data", "model"))
        tree = {{"w": jax.device_put(w, sh_a),
                 "step": jnp.asarray(7, jnp.int32)}}
        ck = Checkpointer(r"{tmp_path}")
        ck.save(7, tree, blocking=True)

        # "After failure": 4 survivors -> replan mesh, restore resharded.
        mesh_b = replan_mesh(4, prefer_model=2)
        assert mesh_b.devices.size == 4
        sh_b = {{"w": NamedSharding(mesh_b, P("data", "model")),
                 "step": NamedSharding(mesh_b, P())}}
        out, step, _ = ck.restore(tree, shardings=sh_b)
        assert step == 7
        assert out["w"].sharding == sh_b["w"]
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
        assert rescale_grad_accum(2, old_data=4, new_data=2) == 4
        print(json.dumps({{"ok": True}}))
    """)
    assert '"ok": true' in _run(code)


def test_int8_crosspod_gradient_reduction():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json, functools
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        try:
            from jax import shard_map
        except ImportError:  # jax < 0.6 keeps it under experimental
            from jax.experimental.shard_map import shard_map
        from repro.optim import compression
        from repro.launch.mesh import mesh_axis_kwargs

        mesh = jax.make_mesh((4,), ("pod",), **mesh_axis_kwargs(1))
        rng = np.random.default_rng(0)
        # per-pod gradients (leading axis = pod shard)
        g_all = jnp.asarray(rng.normal(size=(4, 256)) * 1e-3, jnp.float32)

        def body(g, e):
            grads = {"w": g[0]}
            err = {"w": e[0]}
            reduced, new_err = compression.cross_pod_psum_int8(
                grads, err, axis_name="pod")
            return reduced["w"][None], new_err["w"][None]

        fn = shard_map(body, mesh=mesh,
                       in_specs=(P("pod", None), P("pod", None)),
                       out_specs=(P("pod", None), P("pod", None)))
        err0 = jnp.zeros((4, 256), jnp.bfloat16)

        exact = np.asarray(g_all).sum(0)
        # error feedback: averaged over repeats, quantized reduction -> exact
        total = np.zeros(256)
        err = err0
        for _ in range(30):
            red, err = fn(g_all, err)
            total += np.asarray(red[0])
        np.testing.assert_allclose(total / 30, exact, rtol=0.05, atol=2e-5)
        print(json.dumps({"ok": True}))
    """)
    assert '"ok": true' in _run(code)


def test_preemption_checkpoint_loss_bounded(tmp_path):
    """Preempt mid-training (simulated), resume: at most one step lost."""
    code = textwrap.dedent(f"""
        import json
        from repro.launch import train
        ck = r"{tmp_path}/ck"
        losses = train.main(["--arch", "qwen2-0.5b", "--reduced", "--steps",
                             "6", "--batch", "2", "--seq", "32",
                             "--ckpt-dir", ck, "--ckpt-every", "2",
                             "--log-every", "100"])
        # simulate crash: just restart with --resume for more steps
        more = train.main(["--arch", "qwen2-0.5b", "--reduced", "--steps",
                           "8", "--batch", "2", "--seq", "32",
                           "--ckpt-dir", ck, "--resume",
                           "--log-every", "100"])
        assert len(more) == 2, f"resume should run exactly steps 6..7: {{len(more)}}"
        print(json.dumps({{"ok": True}}))
    """)
    assert '"ok": true' in _run(code)
