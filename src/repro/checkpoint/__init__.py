"""Atomic, async, sharded, elastic checkpointing."""
from . import checkpoint
from .checkpoint import Checkpointer
