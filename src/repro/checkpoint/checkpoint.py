"""Fault-tolerant checkpointing: atomic, async, sharded, elastic.

Design requirements at 1000+-node scale (DESIGN.md §4):

* **Atomicity** — a preemption mid-write must never corrupt the latest
  checkpoint: write to ``step_<n>.tmp/``, fsync, then ``rename`` (the only
  atomic primitive POSIX gives us); readers only ever see complete steps.
* **Async** — serialization happens on a background thread so the train
  loop loses only the device→host transfer time, not the disk write
  (FlashMatrix's write-through-cache philosophy: overlap persistence with
  compute).
* **Sharded** — each host writes only its local shard bytes
  (``jax.Array`` addressable shards); a manifest records the global shape,
  dtype and sharding spec per leaf + a CRC per file.
* **Elastic restore** — ``restore`` takes the *target* sharding tree, so a
  checkpoint saved on mesh A reshards onto mesh B (new pod count, changed
  TP width) at load time: restore-to-host → device_put with the new
  NamedSharding.  This is the re-mesh path runtime/fault_tolerance.py uses
  after a topology change.

Format: one ``.npy``-like raw file per leaf (numpy save), a JSON manifest,
CRC-32 integrity, and a ``latest`` pointer file.  msgpack/zarr would be
drop-in upgrades; the semantics above are the point.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, extra: Optional[dict] = None,
             blocking: bool = False):
        """Snapshot `tree` at `step`.  Device→host copy happens here
        (synchronously, so training can donate the buffers right after);
        disk I/O happens on the background thread unless blocking=True."""
        self.wait()  # at most one in-flight save
        flat, _ = _flatten_with_paths(tree)
        host = [(k, np.asarray(v)) for k, v in flat]  # d2h now

        def write():
            tmp = self.dir / f"step_{step:010d}.tmp"
            final = self.dir / f"step_{step:010d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "extra": extra or {}, "leaves": {}}
            for i, (key, arr) in enumerate(host):
                fname = f"leaf_{i:05d}.npy"
                # bfloat16 has no portable .npy encoding: store the raw u16
                # payload and record the logical dtype in the manifest.
                logical_dtype = str(arr.dtype)
                if logical_dtype == "bfloat16":
                    arr = arr.view(np.uint16)
                with open(tmp / fname, "wb") as f:
                    np.save(f, arr)
                    f.flush()
                crc = zlib.crc32((tmp / fname).read_bytes()) & 0xFFFFFFFF
                manifest["leaves"][key] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": logical_dtype, "crc32": crc,
                }
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)                      # the atomic commit point
            (self.dir / "latest.tmp").write_text(str(step))
            (self.dir / "latest.tmp").rename(self.dir / "latest")
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                if p.is_dir() and not p.name.endswith(".tmp")]

    def latest_step(self) -> Optional[int]:
        ptr = self.dir / "latest"
        if ptr.exists():
            s = int(ptr.read_text())
            if (self.dir / f"step_{s:010d}" / "manifest.json").exists():
                return s
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, template: Any, *, step: Optional[int] = None,
                shardings: Any = None, verify: bool = True):
        """Load into the structure of `template`.

        `shardings`: optional pytree of (Named)Shardings — the ELASTIC path:
        pass the new mesh's shardings and each leaf lands resharded.
        Returns (tree, step, extra)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())

        flat, treedef = _flatten_with_paths(template)
        sh_flat = None
        if shardings is not None:
            sh_list, _ = jax.tree_util.tree_flatten(shardings)
            sh_flat = sh_list
        leaves = []
        for i, (key, tmpl) in enumerate(flat):
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            raw = (d / meta["file"]).read_bytes()
            if verify:
                crc = zlib.crc32(raw) & 0xFFFFFFFF
                if crc != meta["crc32"]:
                    raise IOError(f"CRC mismatch for {key} in step {step}")
            arr = np.load(d / meta["file"])
            if meta["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            if sh_flat is not None:
                arr = jax.device_put(arr, sh_flat[i])
            elif hasattr(tmpl, "dtype"):
                if str(arr.dtype) != str(tmpl.dtype):
                    arr = jax.device_put(jax.numpy.asarray(arr).astype(tmpl.dtype))
                else:
                    arr = jax.device_put(arr)
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, step, manifest.get("extra", {})
