"""K-means (Lloyd's algorithm, paper §IV-A) on GenOps.

One iteration is ONE fused pass over X (O(n·p·k) compute, O(n·p) I/O —
Table IV row 4), exercising every GenOp class at once:

    D      = fm.inner.prod(X, t(C), squared_diff, sum)   # distances (fusable)
    labels = fm.agg.row(D, which.min)                    # assignment (fusable)
    sums   = fm.groupby.row(X, labels, sum)              # sink
    counts = fm.groupby.row(1, labels, count)            # sink
    wss    = fm.agg(min-distance, sum)                   # sink (objective)

The three sinks co-materialize, so the entire Lloyd step streams each
I/O-level partition through distance → argmin → scatter-add while it is
still resident in the fast tier — the paper's two-level fusion, and the
pattern `kernels/kmeans_assign.py` implements as a single Pallas kernel.
"""
from __future__ import annotations

import contextlib
import dataclasses

import numpy as np

from ..core import fm


@dataclasses.dataclass
class KMeansResult:
    centers: np.ndarray
    labels: fm.FM          # n-vector (may live on host for OOC inputs)
    wss: float             # within-cluster sum of squares (objective)
    iters: int


def _init_centers(X: fm.FM, k: int, seed: int) -> np.ndarray:
    """k-means++ on a uniform row subsample (≤16k rows).

    The paper benchmarks Lloyd iterations, so init cost is off the critical
    path; ++-style seeding on the small tier avoids Forgy's merged-cluster
    local optima without adding streaming passes over the big matrix."""
    rng = np.random.default_rng(seed)
    n = X.nrow
    m = min(n, 16384)
    idx = np.sort(rng.choice(n, size=m, replace=False))
    data = X.m.logical_data()
    S = (np.asarray(data)[idx] if isinstance(data, np.ndarray)
         else np.asarray(data[idx])).astype(np.float64)
    centers = [S[rng.integers(m)]]
    d2 = ((S - centers[0]) ** 2).sum(1)
    for _ in range(1, k):
        prob = d2 / max(d2.sum(), 1e-300)
        centers.append(S[rng.choice(m, p=prob)])
        d2 = np.minimum(d2, ((S - centers[-1]) ** 2).sum(1))
    return np.stack(centers).astype(np.float32)


def kmeans_iteration(X: fm.FM, centers: np.ndarray, *, mode: str = "auto",
                     fuse: bool = True):
    """One Lloyd step: returns (new_centers, counts, wss, labels_FM)."""
    k = centers.shape[0]
    D = fm.inner_prod(X, centers.T, "squared_diff", "sum")   # n×k distances
    labels = fm.which_min_row(D)                             # n×1, fusable
    mind = fm.rowMins(D)                                     # n×1, fusable
    sums = fm.rowsum(X, labels, k)                           # k×p sink
    counts = fm.table_(labels, k)                            # k×1 sink
    wss = fm.sum_(mind)                                      # scalar sink
    sums_m, counts_m, wss_m, labels_m = fm.materialize(
        sums, counts, wss, labels, mode=mode, fuse=fuse)
    s = fm.as_np(sums_m)
    c = fm.as_np(counts_m).reshape(-1).astype(np.float64)
    # Empty clusters keep their previous center (mclust/MLlib convention).
    new_centers = np.where(c.reshape(-1, 1) > 0,
                           s / np.maximum(c.reshape(-1, 1), 1.0),
                           centers).astype(np.float32)
    return new_centers, c, float(fm.as_scalar(wss_m)), labels_m


def kmeans(X: fm.FM, k: int = 10, *, max_iter: int = 20, tol: float = 1e-6,
           seed: int = 0, mode: str = "auto", fuse: bool = True,
           inspect: bool = True) -> KMeansResult:
    """``inspect=True`` (default) declares the Lloyd loop to the executor
    (``fm.inspect_iterations``): each iteration is one stream over X, and
    iteration i+1's sweep starts from iteration i's still-resident final
    partition instead of re-reading it (``prefetch_reuse_hits``)."""
    centers = _init_centers(X, k, seed)
    prev_wss = np.inf
    labels = None
    it = 0
    scope = (fm.inspect_iterations() if inspect
             else contextlib.nullcontext())
    with scope:
        for it in range(1, max_iter + 1):
            centers, counts, wss, labels = kmeans_iteration(
                X, centers, mode=mode, fuse=fuse)
            if (np.isfinite(prev_wss)
                    and prev_wss - wss <= tol * max(prev_wss, 1.0)):
                break
            prev_wss = wss
    return KMeansResult(centers=centers, labels=labels, wss=wss, iters=it)
