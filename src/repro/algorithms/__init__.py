"""The paper's evaluation algorithms (§IV-A), written on the R-like GenOps
API — FlashMatrix "executes the R implementations in parallel and out of
core automatically"; these modules are those R programs, line for line where
practical."""
from .summary import summary
from .correlation import correlation
from .svd import svd_tall
from .kmeans import kmeans
from .gmm import gmm

__all__ = ["summary", "correlation", "svd_tall", "kmeans", "gmm"]
