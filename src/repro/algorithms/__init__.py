"""The paper's evaluation algorithms (§IV-A), written on the R-like GenOps
API — FlashMatrix "executes the R implementations in parallel and out of
core automatically"; these modules are those R programs, line for line where
practical."""
from .summary import summary
from .correlation import correlation
from .svd import svd_tall
from .kmeans import kmeans
from .gmm import gmm
from .glm import glm, glm_predict, glm_iteration_plan
from .pca import pca
from .nmf import nmf
from .naive_bayes import naive_bayes, nb_predict

__all__ = ["summary", "correlation", "svd_tall", "kmeans", "gmm",
           "glm", "glm_predict", "glm_iteration_plan", "pca", "nmf",
           "naive_bayes", "nb_predict"]
