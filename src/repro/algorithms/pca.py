"""Principal component analysis (paper §IV-A) on GenOps.

"PCA computes eigenvalues on the Gramian matrix t(X) %*% X" — we center
(and optionally scale) X lazily and compute the covariance Gram of the
*virtual* standardized matrix: Z never exists physically, and the whole
program — moment sinks, epilogue math, sweep and Gram contraction — is ONE
``fm.materialize`` call that the multi-pass planner schedules as
moment pass → sweep+Gram pass (``exec_stats()['passes'] == 2``).

Equivalent FlashR R code:

    Z  <- scale(X, scale = FALSE)          # lazy sweep over colMeans
    ev <- eigen(crossprod(Z) / (n - 1))    # two scheduled passes + small tier
    scores <- Z %*% ev$vectors[, 1:k]      # optional extra pass

Complexity: O(n·p²) compute, O(n·p) I/O per pass (Table IV row 3); two
passes total (moments, Gram) plus an optional scores pass — the same pass
structure the paper reports for its PCA implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core import fm
from .svd import svd_tall


@dataclasses.dataclass
class PCAResult:
    sdev: np.ndarray              # component standard deviations (k,)
    rotation: np.ndarray          # principal axes (p × k)
    center: np.ndarray            # column means used for centering (p,)
    scale: Optional[np.ndarray]   # column sds when scale=True, else None
    scores: Optional[fm.FM]       # n × k projections (optional, any tier)


def pca(X: fm.FM, k: int = 10, *, center: bool = True, scale: bool = False,
        compute_scores: bool = False, mode: str = "auto",
        fuse: bool = True) -> PCAResult:
    """R prcomp(): PCA of a tall (n, p) matrix on any storage tier.

    ``scale=True`` standardizes columns (correlation PCA).  The centered /
    scaled matrix stays virtual end to end: the covariance Gram of the
    centered matrix, the column moments and their epilogue math
    co-materialize in ONE call — the planner streams the moment pass, then
    re-streams X with the moments bound for the sweep+Gram pass.
    """
    n, p = X.shape
    k = min(k, p)
    mu = np.zeros(p, np.float32)
    sd = None
    Z = X
    wants = []
    if center:
        mu_fm = fm.colMeans(X)
        wants.append(mu_fm)
        Z = fm.mapply_row(Z, mu_fm, "sub")
    if scale:
        sd_fm = fm.colSds(X)
        wants.append(sd_fm)
        Z = fm.mapply_row(Z, fm.pmax(sd_fm, 1e-12), "div")
    # ONE materialize: Gram of the (virtual) centered matrix + the moments.
    outs = fm.materialize(fm.crossprod(Z), *wants, mode=mode, fuse=fuse)
    g = fm.as_np(outs[0]).astype(np.float64)
    if center:
        mu = fm.as_np(outs[1]).reshape(-1).astype(np.float32)
    if scale:
        sd = fm.as_np(outs[-1]).reshape(-1).astype(np.float32)
    # Scores reuse the now-physical moments: the optional extra pass stays
    # a single sweep+product stream instead of re-deriving the moments.
    Zp = X
    if center:
        Zp = fm.mapply_row(Zp, mu, "sub")
    if scale:
        Zp = fm.mapply_row(Zp, np.maximum(sd, 1e-12), "div")
    r = svd_tall(Zp, k=k, compute_u=compute_scores, mode=mode, fuse=fuse,
                 gram=g)
    sdev = r.s / np.sqrt(max(n - 1, 1))
    scores = None
    if compute_scores:
        # U·Σ = Z·V: rescale the left singular vectors (already one
        # streaming pass inside svd_tall).
        scores = fm.mapply_row(r.U, r.s.astype(np.float32), "mul")
        (scores,) = fm.materialize(scores, mode=mode, fuse=fuse)
    return PCAResult(sdev=sdev, rotation=r.V, center=mu, scale=sd,
                     scores=scores)
