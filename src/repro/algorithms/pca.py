"""Principal component analysis (paper §IV-A) on GenOps.

"PCA computes eigenvalues on the Gramian matrix t(X) %*% X" — we center
(and optionally scale) X lazily and reuse ``svd_tall``: the standardized
matrix Z never exists physically; its Gram matrix is ONE streaming
contraction sink and the p×p eigendecomposition runs on the small tier.

Equivalent FlashR R code:

    mu <- colMeans(X)                      # moment pass (sink + epilogue)
    Z  <- sweep(X, 2, mu)                  # lazy mapply.row
    ev <- eigen(crossprod(Z) / (n - 1))    # one streaming pass + small tier
    scores <- Z %*% ev$vectors[, 1:k]      # optional second pass

Complexity: O(n·p²) compute, O(n·p) I/O per pass (Table IV row 3); two
passes total (moments, Gram) plus an optional scores pass — the same pass
structure the paper reports for its PCA implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core import fm
from .svd import svd_tall


@dataclasses.dataclass
class PCAResult:
    sdev: np.ndarray              # component standard deviations (k,)
    rotation: np.ndarray          # principal axes (p × k)
    center: np.ndarray            # column means used for centering (p,)
    scale: Optional[np.ndarray]   # column sds when scale=True, else None
    scores: Optional[fm.FM]       # n × k projections (optional, any tier)


def pca(X: fm.FM, k: int = 10, *, center: bool = True, scale: bool = False,
        compute_scores: bool = False, mode: str = "auto",
        fuse: bool = True) -> PCAResult:
    """R prcomp(): PCA of a tall (n, p) matrix on any storage tier.

    ``scale=True`` standardizes columns (correlation PCA).  The centered /
    scaled matrix stays virtual: centering fuses into the Gram pass.
    """
    n, p = X.shape
    k = min(k, p)
    mu = np.zeros(p, np.float32)
    sd = None
    Z = X
    if center or scale:
        # ONE co-materialized moment pass yields both the means and (when
        # scaling) the sds: the colMeans/colSds epilogue chains share the
        # staged read of X and finish in a single post-merge launch.
        wants = []
        if center:
            wants.append(fm.colMeans(X))
        if scale:
            wants.append(fm.colSds(X))
        outs = fm.materialize(*wants, mode=mode, fuse=fuse)
        if center:
            mu = fm.as_np(outs[0]).reshape(-1).astype(np.float32)
        if scale:
            sd = fm.as_np(outs[-1]).reshape(-1).astype(np.float32)
    if center:
        Z = fm.mapply_row(Z, mu, "sub")
    if scale:
        Z = fm.mapply_row(Z, np.maximum(sd, 1e-12), "div")
    r = svd_tall(Z, k=k, compute_u=compute_scores, mode=mode, fuse=fuse)
    sdev = r.s / np.sqrt(max(n - 1, 1))
    scores = None
    if compute_scores:
        # U·Σ = Z·V: rescale the left singular vectors (already one
        # streaming pass inside svd_tall).
        scores = fm.mapply_row(r.U, r.s.astype(np.float32), "mul")
        (scores,) = fm.materialize(scores, mode=mode, fuse=fuse)
    return PCAResult(sdev=sdev, rotation=r.V, center=mu, scale=sd,
                     scores=scores)
