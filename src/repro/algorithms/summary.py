"""Multivariate statistical summary (paper §IV-A).

Column-wise min, max, mean, L1 norm, L2 norm, number of non-zero values and
variance — all eight sinks materialize together in ONE fused pass over the
data matrix, the paper's flagship demonstration of sink co-materialization
(complexity: O(n·p) compute, O(n·p) I/O, Table IV row 1).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import fm


@dataclasses.dataclass
class Summary:
    col_min: np.ndarray
    col_max: np.ndarray
    mean: np.ndarray
    l1: np.ndarray
    l2: np.ndarray
    nnz: np.ndarray
    var: np.ndarray


def summary(X: fm.FM, *, mode: str = "auto", fuse: bool = True) -> Summary:
    n = X.nrow
    mins = fm.colMins(X)
    maxs = fm.colMaxs(X)
    sums = fm.colSums(X)
    l1 = fm.colSums(fm.abs_(X))
    sq = fm.colSums(X ** 2)
    nnz = fm.agg_col(X, "count_nonzero")
    outs = fm.materialize(mins, maxs, sums, l1, sq, nnz, mode=mode, fuse=fuse)
    mn, mx, s, a1, s2, nz = [fm.as_np(o).reshape(-1) for o in outs]
    mean = s / n
    var = (s2 - n * mean ** 2) / (n - 1)
    return Summary(mn, mx, mean, a1, np.sqrt(s2), nz, var)
