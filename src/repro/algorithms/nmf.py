"""Non-negative matrix factorization (paper §IV-A) on GenOps.

Lee–Seung multiplicative updates for X ≈ W·H with a TALL factor W (n × k,
row-aligned with X — it can live on the disk tier and spill there with
``save='disk'``) and a SMALL factor H (k × p, small tier):

    H ← H ⊙ (WᵀX) / (WᵀW·H)        # pass A: two contraction sinks
    W ← W ⊙ (X·Hᵀ) / (W·(H·Hᵀ))    # pass B: row-local, streams W out

Equivalent FlashR R code:

    WtX  <- crossprod(W, X); WtW <- crossprod(W)   # one fused pass
    H    <- H * WtX / (WtW %*% H + eps)
    W    <- W * (X %*% t(H)) / (W %*% (H %*% t(H)) + eps)

Each iteration is exactly TWO streaming passes, each reading X (and W)
once: pass A co-materializes the WᵀX and WᵀW sinks (the paper's
partial-aggregation merge; X is staged once per partition for both thanks
to staging dedupe); pass B is a pure row-local chain whose n×k output
write-throughs to the chosen tier.  The Frobenius objective
‖X−WH‖² = ‖X‖² − 2·tr(HᵀWᵀX) + tr(WᵀW·HHᵀ) falls out of pass A's sinks —
no extra pass.

Complexity per iteration: O(n·p·k) compute, O(n·(p + k)) I/O (Table IV).
"""
from __future__ import annotations

import contextlib
import dataclasses

import numpy as np

from ..core import fm

_EPS = 1e-9


@dataclasses.dataclass
class NMFResult:
    W: fm.FM                  # n × k (device / host / disk tier)
    H: np.ndarray             # k × p (small tier)
    objective: float          # ‖X − WH‖²_F at the last iteration
    objective_trace: list
    iters: int


def nmf(X: fm.FM, k: int = 8, *, max_iter: int = 30, tol: float = 1e-4,
        seed: int = 0, save: str | None = None, mode: str = "auto",
        fuse: bool = True, backend=None, inspect: bool = True) -> NMFResult:
    """Factorize a non-negative tall matrix.  ``save='disk'`` streams the
    tall factor W through the write-through spill path every iteration, so
    neither factor update ever holds an n-row matrix in RAM.

    ``inspect=True`` (default) declares the update loop to the executor
    (``fm.inspect_iterations``): consecutive passes with matching partition
    schedules over X reuse the resident final partition
    (``prefetch_reuse_hits``) instead of re-reading it."""
    n, p = X.shape
    rng = np.random.default_rng(seed)
    # ‖X‖² (for the objective) and the grand mean (for init scale) in one
    # co-materialized setup pass.
    x2_m, xs_m = fm.materialize(fm.sum_(X ** 2), fm.sum_(X), mode=mode,
                                fuse=fuse, backend=backend)
    x_norm2 = float(fm.as_scalar(x2_m))
    x_mean = float(fm.as_scalar(xs_m)) / float(n * p)
    scale = np.sqrt(max(x_mean, _EPS) / k)
    W = fm.conv_R2FM(
        (rng.uniform(size=(n, k)) * scale + _EPS).astype(np.float32),
        host=fm._fm(X).on_host)
    H = (rng.uniform(size=(k, p)) * scale + _EPS).astype(np.float64)

    trace: list[float] = []
    prev = np.inf
    it = 0
    scope = (fm.inspect_iterations() if inspect
             else contextlib.nullcontext())
    with scope:
      for it in range(1, max_iter + 1):
        # Pass A: both contraction sinks in one fused scan of (X, W).
        WtX_m, WtW_m = fm.materialize(fm.crossprod(W, X), fm.crossprod(W),
                                      mode=mode, fuse=fuse, backend=backend)
        WtX = fm.as_np(WtX_m).astype(np.float64)
        WtW = fm.as_np(WtW_m).astype(np.float64)
        H = H * WtX / (WtW @ H + _EPS)

        # Objective from pass A's sinks (no extra pass): uses the H that
        # the W-update below will be driven by.
        obj = float(x_norm2 - 2.0 * np.sum(WtX * H)
                    + np.sum((WtW @ H) * H))
        trace.append(obj)

        # Pass B: row-local multiplicative update of the tall factor;
        # spills write-through when save='disk'.
        Ht = np.ascontiguousarray(H.T, np.float32)          # p × k
        HHt = np.ascontiguousarray((H @ H.T), np.float32)   # k × k
        num = X @ Ht                                        # n × k row-local
        den = W @ HHt + _EPS                                # n × k row-local
        W_new = W * num / den
        if save:
            fm.persist(W_new, tier=save)
        prev_W = W
        (W,) = fm.materialize(W_new, mode=mode, fuse=fuse, backend=backend)
        # Reclaim the previous iteration's spill file (each save='disk'
        # materialization writes a fresh one) — only files THIS fit
        # created; the caller's input X is never touched.
        if save == "disk" and prev_W.m.on_disk:
            prev_W.m.store.path.unlink(missing_ok=True)

        if np.isfinite(prev) and abs(prev - obj) <= tol * max(abs(prev), 1.0):
            break
        prev = obj
    return NMFResult(W=W, H=H, objective=trace[-1], objective_trace=trace,
                     iters=it)
