"""Pair-wise Pearson correlation (paper §IV-A).

    corr(X)_jk = (E[x_j x_k] - μ_j μ_k) / (σ_j σ_k)

The paper notes its implementation "requires an additional pass on the input
matrix to compute column-wise mean values, which results in lower
external-memory performance" (§IV-C).  Because our sinks co-materialize, the
single-pass moment form is the default here: Gram matrix, column sums and
column sums-of-squares all stream in ONE pass (a beyond-paper fix the DAG
makes free).  ``two_pass=True`` reproduces the paper-faithful variant for
the benchmark comparison.

Complexity: O(n·p²) compute, O(n·p) I/O (Table IV row 2).
"""
from __future__ import annotations

import numpy as np

from ..core import fm


def correlation(X: fm.FM, *, mode: str = "auto", fuse: bool = True,
                two_pass: bool = False) -> np.ndarray:
    n = X.nrow
    if two_pass:
        # Paper-faithful: pass 1 for means, pass 2 for the centered Gram.
        (sums,) = fm.materialize(fm.colSums(X), mode=mode, fuse=fuse)
        mu = fm.as_np(sums).reshape(-1) / n
        Zc = fm.mapply_row(X, mu, "sub")
        G = fm.crossprod(Zc)
        (Gm,) = fm.materialize(G, mode=mode, fuse=fuse)
        cov = fm.as_np(Gm) / (n - 1)
        sd = np.sqrt(np.diag(cov))
        return cov / np.outer(sd, sd)

    # Single-pass moment form: one fused scan produces all three sinks.
    G = fm.crossprod(X)
    sums = fm.colSums(X)
    (Gm, sm) = fm.materialize(G, sums, mode=mode, fuse=fuse)
    g = fm.as_np(Gm).astype(np.float64)
    s = fm.as_np(sm).reshape(-1).astype(np.float64)
    mu = s / n
    cov = (g - n * np.outer(mu, mu)) / (n - 1)
    sd = np.sqrt(np.diag(cov))
    return (cov / np.outer(sd, sd)).astype(np.float64)
