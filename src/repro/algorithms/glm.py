"""Generalized linear models via IRLS (paper §IV-A's logistic regression,
generalized to the gaussian/logistic/poisson families) on GenOps.

Every IRLS iteration is ONE fused plan over X: the weighted Gram XᵀWX, the
weighted moment XᵀWz and the log-likelihood sink co-materialize while a
partition is resident in the fast tier, and the p×p Newton solve runs as a
lazy EPILOGUE op in the SAME plan — one launch after the partial merge, on
device, so the whole R expression below executes as a single DAG.  The
weighted-Gram segment (``mapply.col(X, w, mul) → inner.prod(mul, sum)``)
is the pattern the pallas backend lowers onto ``kernels/weighted_gram.py``.

Equivalent FlashR R code (paper Fig. 4 style):

    eta <- X %*% beta
    mu  <- 1 / (1 + exp(-eta))                 # logistic link inverse
    w   <- mu * (1 - mu)
    z   <- eta + (y - mu) / w                  # working response
    XtWX <- crossprod(X * w, X)                # weighted Gram  (sink)
    XtWz <- crossprod(X, w * z)                # weighted moment (sink)
    ll   <- sum(y * eta - log(1 + exp(eta)))   # log-likelihood (sink)
    beta <- solve(XtWX, XtWz)                  # plan epilogue

Complexity per iteration: O(n·p²) compute, O(n·p) I/O — the correlation/SVD
row of Table IV, with the same out-of-core behavior.
"""
from __future__ import annotations

import contextlib
import dataclasses

import numpy as np

from ..core import fm
from ..core.fusion import Plan

FAMILIES = ("gaussian", "logistic", "poisson")

#: Weight floor: keeps the working response finite when mu saturates.
_W_EPS = 1e-6

#: Column-sd floor for feature standardization (constant columns).
_SD_EPS = 1e-12


@dataclasses.dataclass
class GLMResult:
    beta: np.ndarray        # (p,) coefficients (float64)
    family: str
    loglik: float           # final log-likelihood (gaussian: -0.5·RSS)
    loglik_trace: list
    iters: int
    converged: bool
    # Feature standardization (glm(standardize=True)): beta is on the
    # STANDARDIZED scale; glm_predict applies the same sweep.
    center: "np.ndarray | None" = None   # column means, (p,)
    scale: "np.ndarray | None" = None    # column sds (floored), (p,)


def _softplus(eta: fm.FM) -> fm.FM:
    """log(1 + exp(eta)), overflow-safe: max(eta, 0) + log1p(exp(-|eta|))."""
    return fm.pmax(eta, 0.0) + fm.log1p(fm.exp(-fm.abs_(eta)))


def glm_irls_sinks(X: fm.FM, y: fm.FM, beta: np.ndarray, family: str):
    """The three sinks of one IRLS iteration (all lazy; co-materialize for
    one fused pass over X): XᵀWX, XᵀWz, log-likelihood.

    ``beta`` may be a host array OR the previous iteration's device-resident
    epilogue output (the Newton solve result): forwarding the device value
    keeps iteration i's epilogue feeding iteration i+1's pass as a broadcast
    binding with no host roundtrip — and since small operands sign the plan
    by shape/dtype only, the plan cache still hits."""
    if isinstance(beta, np.ndarray) or not hasattr(beta, "reshape"):
        b = np.asarray(beta, np.float32).reshape(-1, 1)
    else:
        b = beta.reshape(-1, 1)
        if str(b.dtype) != "float32":
            b = b.astype(np.float32)
    eta = X @ b                                   # n×1, row-local
    if family == "gaussian":
        # Constant unit weights: IRLS is ordinary least squares, one step.
        # The sink is the RSS at the pre-step coefficients; glm() finishes
        # −RSS(β_new)/2 via the quadratic expansion on the small tier.
        w = y * 0.0 + 1.0
        z = y
        ll = fm.sum_((y - eta) ** 2)
    elif family == "logistic":
        mu = fm.sigmoid(eta)
        w = mu * (1.0 - mu) + _W_EPS
        z = eta + (y - mu) / w
        ll = fm.sum_(y * eta - _softplus(eta))
    elif family == "poisson":
        mu = fm.exp(eta)
        w = mu + _W_EPS
        z = eta + (y - mu) / w
        ll = fm.sum_(y * eta - mu)
    else:
        raise ValueError(f"unknown family {family!r}; have {FAMILIES}")
    Xw = fm.mapply_col(X, w, "mul")               # X ⊙ w, row-local
    XtWX = fm.crossprod(Xw, X)                    # p×p weighted Gram sink
    XtWz = fm.crossprod(X, w * z)                 # p×1 weighted moment sink
    return XtWX, XtWz, ll


def glm_irls_outputs(X: fm.FM, y: fm.FM, beta: np.ndarray, family: str,
                     ridge: float = 0.0):
    """One WHOLE IRLS iteration as a single lazy DAG: the three sinks plus
    ``beta_next = solve(XᵀWX (+ ridge·I), XᵀWz)`` running in the plan
    epilogue — the Newton step materializes in the same fused pass over X.
    Returns (beta_next, ll, XtWX, XtWz) lazy handles."""
    XtWX, XtWz, ll = glm_irls_sinks(X, y, beta, family)
    A = XtWX
    if ridge:
        # The ridge eye matrix is an epilogue-only source: handed whole to
        # the post-merge callable, never streamed.
        A = A + fm.conv_R2FM((ridge * np.eye(X.ncol)).astype(np.float32))
    beta_next = fm.solve(A, XtWz)
    return beta_next, ll, XtWX, XtWz


def glm_iteration_plan(X: fm.FM, y: fm.FM, beta: np.ndarray,
                       family: str) -> Plan:
    """The fusion Plan of one IRLS iteration, INCLUDING the epilogue Newton
    solve — exposes the cost counters (bytes_in vs nbytes(X): the proof
    each iteration streams X once) and the epilogue stage evidence."""
    beta_next, ll, _, _ = glm_irls_outputs(X, y, beta, family)
    return Plan([beta_next.m, ll.m])


def glm(X: fm.FM, y: fm.FM, family: str = "logistic", *, max_iter: int = 25,
        tol: float = 1e-8, ridge: float = 0.0, mode: str = "auto",
        fuse: bool = True, backend=None, standardize: bool = False,
        inspect: bool = True) -> GLMResult:
    """Fit a GLM by iteratively reweighted least squares.

    ``X``: n×p design matrix (any tier — device, host RAM, or disk).
    ``y``: n×1 response, row-aligned with X (0/1 for logistic, counts for
    poisson).  ``ridge`` adds an L2 penalty to the normal equations (also
    the numerical-rescue knob for separable logistic data).

    ``standardize=True`` fits on lazily standardized features: the FIRST
    iteration is a single-materialize TWO-PASS plan — the column moments
    stream in pass 1 and the standardized IRLS sinks + Newton solve in
    pass 2 (``exec_stats()['passes'] == 2``) — and later iterations reuse
    the now-physical moments as one-pass plans.  ``result.beta`` is on the
    standardized scale (``result.center``/``result.scale`` record the
    sweep; ``glm_predict`` applies it).

    ``inspect=True`` (default) declares the IRLS loop to the executor
    (``fm.inspect_iterations``): the converged beta of iteration i feeds
    iteration i+1's pass directly from the device (no host roundtrip), and
    consecutive iterations' streams reuse the resident final partition of
    X instead of re-reading it (``prefetch_reuse_hits``).
    """
    n, p = X.shape
    beta = np.zeros(p, np.float64)
    # The value iteration i+1's sinks bind: starts as the host zeros, then
    # (under inspect) the device-resident epilogue output of iteration i.
    beta_carry: object = beta
    trace: list[float] = []
    prev = -np.inf
    converged = False
    it = 0
    center = scale_v = None
    if standardize:
        # Pure lazy standardization chain: materializes WITH iteration 1.
        mu_fm, sd_fm = fm.colMeans(X), fm.colSds(X)
        Z = fm.mapply_row(fm.mapply_row(X, mu_fm, "sub"),
                          fm.pmax(sd_fm, _SD_EPS), "div")
    else:
        Z = X
    scope = (fm.inspect_iterations() if inspect
             else contextlib.nullcontext())
    with scope:
      for it in range(1, max_iter + 1):
        # The ENTIRE iteration — sinks and the epilogue Newton solve — is
        # one plan: a single streaming pass over X and one epilogue launch
        # (plus the one-off moment pass when standardizing, iteration 1).
        beta_fm, ll_fm, XtWX_fm, XtWz_fm = glm_irls_outputs(
            Z, y, beta_carry, family, ridge)
        moment_wants = ([mu_fm, sd_fm]
                        if standardize and center is None else [])
        if family == "gaussian":
            # Also pull the (unridged) normal-equation sinks: the quadratic
            # RSS expansion below needs them on the small tier.
            beta_m, ll_m, A_m, b_m, *mo = fm.materialize(
                beta_fm, ll_fm, XtWX_fm, XtWz_fm, *moment_wants, mode=mode,
                fuse=fuse, backend=backend)
        else:
            beta_m, ll_m, *mo = fm.materialize(
                beta_fm, ll_fm, *moment_wants, mode=mode, fuse=fuse,
                backend=backend)
        if moment_wants:
            # Rebind the sweep to the physical moments: iterations 2+ are
            # ordinary one-pass plans over X.
            center = fm.as_np(mo[0]).reshape(-1).astype(np.float32)
            scale_v = np.maximum(
                fm.as_np(mo[1]).reshape(-1).astype(np.float32), _SD_EPS)
            Z = fm.mapply_row(fm.mapply_row(X, center, "sub"),
                              scale_v, "div")
        beta = fm.as_np(beta_m).astype(np.float64).reshape(-1)
        # Forward the device value: iteration i's epilogue result becomes
        # iteration i+1's broadcast binding without leaving the device.
        beta_carry = (beta_m.m.logical_data() if inspect else beta)
        if not np.isfinite(beta).all():
            # The on-device epilogue solve cannot raise like the old eager
            # float64 numpy path did — restore the diagnostic here.
            raise np.linalg.LinAlgError(
                f"IRLS normal equations are singular or too ill-conditioned "
                f"for the on-device solve at iteration {it} (family="
                f"{family!r}); add a ridge penalty (glm(..., ridge=...)) or "
                f"drop collinear columns")
        ll = float(fm.as_scalar(ll_m))
        if family == "gaussian":
            # The streamed sink is RSS at the pre-step coefficients — zeros
            # on this single OLS step, so it equals yᵀy.  Finish the
            # quadratic expansion at the new beta on the small tier:
            # RSS(β) = yᵀy − 2βᵀXᵀy + βᵀ(XᵀX)β.
            A0 = fm.as_np(A_m).astype(np.float64)
            bvec = fm.as_np(b_m).astype(np.float64).reshape(-1)
            rss = ll - 2.0 * float(bvec @ beta) + float(beta @ (A0 @ beta))
            trace.append(-0.5 * rss)
            converged = True        # constant weights: one Newton step
            break
        trace.append(ll)
        if np.isfinite(prev) and abs(ll - prev) <= tol * (abs(prev) + 1.0):
            converged = True
            break
        prev = ll
    return GLMResult(beta=beta, family=family, loglik=trace[-1],
                     loglik_trace=trace, iters=it, converged=converged,
                     center=center, scale=scale_v)


def glm_predict(result: GLMResult, X: fm.FM) -> fm.FM:
    """Linear predictor / response on the link scale: one row-local pass
    (lazy — fuses with downstream GenOps).  A standardized fit sweeps X
    with the training moments first (still row-local and lazy)."""
    if result.center is not None:
        X = fm.mapply_row(fm.mapply_row(X, result.center, "sub"),
                          result.scale, "div")
    eta = X @ result.beta.astype(np.float32).reshape(-1, 1)
    if result.family == "logistic":
        return fm.sigmoid(eta)
    if result.family == "poisson":
        return fm.exp(eta)
    return eta
