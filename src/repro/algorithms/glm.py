"""Generalized linear models via IRLS (paper §IV-A's logistic regression,
generalized to the gaussian/logistic/poisson families) on GenOps.

Every IRLS iteration is ONE fused pass over X: the weighted Gram XᵀWX, the
weighted moment XᵀWz and the log-likelihood sink all co-materialize while a
partition is resident in the fast tier.  The weighted-Gram segment
(``mapply.col(X, w, mul) → inner.prod(mul, sum)``) is the pattern the
pallas backend lowers onto ``kernels/weighted_gram.py``.  The p×p Newton
solve runs on the small tier.

Equivalent FlashR R code (paper Fig. 4 style):

    eta <- X %*% beta
    mu  <- 1 / (1 + exp(-eta))                 # logistic link inverse
    w   <- mu * (1 - mu)
    z   <- eta + (y - mu) / w                  # working response
    XtWX <- crossprod(X * w, X)                # weighted Gram  (sink)
    XtWz <- crossprod(X, w * z)                # weighted moment (sink)
    ll   <- sum(y * eta - log(1 + exp(eta)))   # log-likelihood (sink)
    beta <- solve(XtWX, XtWz)                  # small tier

Complexity per iteration: O(n·p²) compute, O(n·p) I/O — the correlation/SVD
row of Table IV, with the same out-of-core behavior.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import fm
from ..core.fusion import Plan

FAMILIES = ("gaussian", "logistic", "poisson")

#: Weight floor: keeps the working response finite when mu saturates.
_W_EPS = 1e-6


@dataclasses.dataclass
class GLMResult:
    beta: np.ndarray        # (p,) coefficients (float64)
    family: str
    loglik: float           # final log-likelihood (gaussian: -0.5·RSS)
    loglik_trace: list
    iters: int
    converged: bool


def _softplus(eta: fm.FM) -> fm.FM:
    """log(1 + exp(eta)), overflow-safe: max(eta, 0) + log1p(exp(-|eta|))."""
    return fm.pmax(eta, 0.0) + fm.log1p(fm.exp(-fm.abs_(eta)))


def glm_irls_sinks(X: fm.FM, y: fm.FM, beta: np.ndarray, family: str):
    """The three sinks of one IRLS iteration (all lazy; co-materialize for
    one fused pass over X): XᵀWX, XᵀWz, log-likelihood."""
    b = np.asarray(beta, np.float32).reshape(-1, 1)
    eta = X @ b                                   # n×1, row-local
    if family == "gaussian":
        # Constant unit weights: IRLS is ordinary least squares, one step.
        # The sink is the residual sum of squares (a sink's value cannot
        # feed further lazy math; glm() finishes −RSS/2 on the small tier).
        w = y * 0.0 + 1.0
        z = y
        ll = fm.sum_((y - eta) ** 2)
    elif family == "logistic":
        mu = fm.sigmoid(eta)
        w = mu * (1.0 - mu) + _W_EPS
        z = eta + (y - mu) / w
        ll = fm.sum_(y * eta - _softplus(eta))
    elif family == "poisson":
        mu = fm.exp(eta)
        w = mu + _W_EPS
        z = eta + (y - mu) / w
        ll = fm.sum_(y * eta - mu)
    else:
        raise ValueError(f"unknown family {family!r}; have {FAMILIES}")
    Xw = fm.mapply_col(X, w, "mul")               # X ⊙ w, row-local
    XtWX = fm.crossprod(Xw, X)                    # p×p weighted Gram sink
    XtWz = fm.crossprod(X, w * z)                 # p×1 weighted moment sink
    return XtWX, XtWz, ll


def glm_iteration_plan(X: fm.FM, y: fm.FM, beta: np.ndarray,
                       family: str) -> Plan:
    """The fusion Plan of one IRLS iteration — exposes the cost counters
    (bytes_in vs nbytes(X): the proof each iteration streams X once)."""
    return Plan([o.m for o in glm_irls_sinks(X, y, beta, family)])


def glm(X: fm.FM, y: fm.FM, family: str = "logistic", *, max_iter: int = 25,
        tol: float = 1e-8, ridge: float = 0.0, mode: str = "auto",
        fuse: bool = True, backend=None) -> GLMResult:
    """Fit a GLM by iteratively reweighted least squares.

    ``X``: n×p design matrix (any tier — device, host RAM, or disk).
    ``y``: n×1 response, row-aligned with X (0/1 for logistic, counts for
    poisson).  ``ridge`` adds an L2 penalty to the normal equations (also
    the numerical-rescue knob for separable logistic data).
    """
    n, p = X.shape
    beta = np.zeros(p, np.float64)
    trace: list[float] = []
    prev = -np.inf
    converged = False
    it = 0
    for it in range(1, max_iter + 1):
        sinks = glm_irls_sinks(X, y, beta, family)
        XtWX_m, XtWz_m, ll_m = fm.materialize(*sinks, mode=mode, fuse=fuse,
                                              backend=backend)
        A = fm.as_np(XtWX_m).astype(np.float64)
        b = fm.as_np(XtWz_m).astype(np.float64).reshape(-1)
        A0 = A
        if ridge:
            A = A + ridge * np.eye(p)
        beta = np.linalg.solve(A, b)
        ll = float(fm.as_scalar(ll_m))
        if family == "gaussian":
            # The streamed sink is RSS at the pre-step coefficients — zeros
            # on this single OLS step, so it equals yᵀy.  Finish the
            # quadratic expansion at the new beta on the small tier:
            # RSS(β) = yᵀy − 2βᵀXᵀy + βᵀ(XᵀX)β.
            rss = ll - 2.0 * float(b @ beta) + float(beta @ (A0 @ beta))
            trace.append(-0.5 * rss)
            converged = True        # constant weights: one Newton step
            break
        trace.append(ll)
        if np.isfinite(prev) and abs(ll - prev) <= tol * (abs(prev) + 1.0):
            converged = True
            break
        prev = ll
    return GLMResult(beta=beta, family=family, loglik=trace[-1],
                     loglik_trace=trace, iters=it, converged=converged)


def glm_predict(result: GLMResult, X: fm.FM) -> fm.FM:
    """Linear predictor / response on the link scale: one row-local pass
    (lazy — fuses with downstream GenOps)."""
    eta = X @ result.beta.astype(np.float32).reshape(-1, 1)
    if result.family == "logistic":
        return fm.sigmoid(eta)
    if result.family == "poisson":
        return fm.exp(eta)
    return eta
