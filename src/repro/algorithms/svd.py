"""Tall-and-skinny SVD (paper §IV-A).

"To compute SVD on an n×p matrix A (n >> p), we first compute Gramian matrix
AᵀA and compute eigenvalues and eigenvectors to derive singular values and
singular vectors of the matrix A."

The Gram matrix is one streaming sink (O(n·p²) compute / O(n·p) I/O); the
p×p eigendecomposition runs on the small tier; the left singular vectors
U = A V Σ⁻¹ are an optional second streaming pass (a fusable tall·small
inner product) that can land on either tier.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core import fm


@dataclasses.dataclass
class SVDResult:
    s: np.ndarray                 # singular values, descending
    V: np.ndarray                 # right singular vectors (p × k)
    U: Optional[fm.FM] = None     # left singular vectors (n × k), optional


def svd_tall(X: fm.FM, k: int = 10, *, compute_u: bool = False,
             mode: str = "auto", fuse: bool = True,
             gram: Optional[np.ndarray] = None) -> SVDResult:
    """``gram`` short-circuits the Gram pass with an already-materialized
    XᵀX (pca co-materializes it with the column moments in one call)."""
    n, p = X.shape
    k = min(k, p)
    if gram is None:
        (G,) = fm.materialize(fm.crossprod(X), mode=mode, fuse=fuse)
        g = fm.as_np(G).astype(np.float64)
    else:
        g = np.asarray(gram, np.float64)
    evals, evecs = np.linalg.eigh(g)          # ascending
    evals = np.maximum(evals[::-1], 0.0)      # descending, clipped
    evecs = evecs[:, ::-1]
    s = np.sqrt(evals[:k])
    V = evecs[:, :k]
    U = None
    if compute_u:
        inv_s = np.where(s > 0, 1.0 / np.maximum(s, 1e-300), 0.0)
        # U = X @ (V Σ⁻¹): row-local tall·small product, streamed/fused.
        W = (V * inv_s.reshape(1, -1)).astype(np.float32)
        U_virtual = fm.inner_prod(X, W)
        (U,) = fm.materialize(U_virtual, mode=mode, fuse=fuse)
    return SVDResult(s=s, V=V, U=U)
