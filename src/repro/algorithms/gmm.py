"""Gaussian Mixture Model via EM (paper §IV-A), full covariance, on GenOps.

Complexity per iteration: O(n·p²·k + p³·k) compute, O(n·p + n·k) I/O
(Table IV row 5) — the most compute-dense of the paper's workloads, which is
why its out-of-core execution tracks in-memory performance the closest
(paper Fig. 8/10).

One EM iteration is ONE fused pass over X.  For each component j:

    Z_j  = X - μ_j                        (mapply.row, fusable)
    Y_j  = Z_j L_j⁻ᵀ                      (inner.prod tall·small, fusable)
    q_j  = rowSums(Y_j²)                  (agg.row, fusable)
    ll_j = logπ_j - ½(p·log2π + logdet_j) - ½q_j
    r_j  = exp(ll_j - logsumexp_j ll_j)   (responsibilities, fusable)

and the sinks — N_j = Σᵢ r_ij, M_j = Xᵀ r_j, S_j = (X ⊙ r_j)ᵀ X and the
total log-likelihood — all co-materialize in that single pass.  The M-step
is small-tier math (k covariance Cholesky factorizations on p×p matrices).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math

import numpy as np

from ..core import fm


@dataclasses.dataclass
class GMMResult:
    weights: np.ndarray     # (k,)
    means: np.ndarray       # (k, p)
    covs: np.ndarray        # (k, p, p)
    loglik: float
    loglik_trace: list
    iters: int


def _chol_factors(covs: np.ndarray):
    """Per-component (L⁻ᵀ, logdet) for the Mahalanobis inner product."""
    k, p, _ = covs.shape
    inv_lt = np.empty_like(covs)
    logdet = np.empty(k)
    for j in range(k):
        L = np.linalg.cholesky(covs[j])
        inv_lt[j] = np.linalg.inv(L).T          # Z @ L^-T has rowSums(·²) = quad form
        logdet[j] = 2.0 * np.log(np.diag(L)).sum()
    return inv_lt, logdet


def gmm_iteration(X: fm.FM, weights, means, covs, *, mode="auto", fuse=True):
    n, p = X.shape
    k = means.shape[0]
    inv_lt, logdet = _chol_factors(covs)
    const = -0.5 * p * math.log(2.0 * math.pi)

    lls = []
    for j in range(k):
        Z = fm.mapply_row(X, means[j].astype(np.float32), "sub")
        Y = fm.inner_prod(Z, inv_lt[j].astype(np.float32))
        q = fm.agg_row(Y ** 2, "sum")
        ll = q * (-0.5) + float(math.log(max(weights[j], 1e-300))
                                + const - 0.5 * logdet[j])
        lls.append(ll)
    LL = fm.cbind(*lls)                       # n×k, fusable
    lse = fm.agg_row(LL, "logsumexp")         # n×1, fusable

    sinks = [fm.sum_(lse)]                    # total log-likelihood
    for j in range(k):
        r_j = fm.exp(lls[j] - lse)            # responsibilities for j, fusable
        Nk = fm.sum_(r_j)
        Mk = fm.crossprod(X, r_j)             # Xᵀ r_j: p×1 sink
        Xw = fm.mapply_col(X, r_j, "mul")
        Sj = fm.crossprod(Xw, X)              # (X⊙r_j)ᵀX: p×p sink
        sinks.extend([Nk, Mk, Sj])

    outs = fm.materialize(*sinks, mode=mode, fuse=fuse)
    loglik = float(fm.as_scalar(outs[0]))

    new_w = np.empty(k)
    new_mu = np.empty((k, p))
    new_cov = np.empty((k, p, p))
    for j in range(k):
        Nk = float(fm.as_scalar(outs[1 + 3 * j]))
        Mk = fm.as_np(outs[2 + 3 * j]).reshape(-1).astype(np.float64)
        Sj = fm.as_np(outs[3 + 3 * j]).astype(np.float64)
        Nk = max(Nk, 1e-8)
        mu = Mk / Nk
        cov = Sj / Nk - np.outer(mu, mu)
        cov = 0.5 * (cov + cov.T) + 1e-6 * np.eye(p)
        new_w[j] = Nk / n
        new_mu[j] = mu
        new_cov[j] = cov
    new_w /= new_w.sum()
    return new_w, new_mu, new_cov, loglik


def gmm(X: fm.FM, k: int = 10, *, max_iter: int = 30, tol: float = 1e-5,
        seed: int = 0, mode: str = "auto", fuse: bool = True,
        inspect: bool = True) -> GMMResult:
    """``inspect=True`` (default) declares the EM loop to the executor
    (``fm.inspect_iterations``): iteration i+1's single fused pass over X
    starts from iteration i's still-resident final partition
    (``prefetch_reuse_hits``) instead of re-reading it."""
    n, p = X.shape
    rng = np.random.default_rng(seed)
    idx = np.sort(rng.choice(n, size=k, replace=False))
    data = fm._fm(X).logical_data()
    means = np.asarray(np.asarray(data)[idx], dtype=np.float64)
    covs = np.tile(np.eye(p), (k, 1, 1))
    weights = np.full(k, 1.0 / k)

    trace = []
    prev = -np.inf
    it = 0
    scope = (fm.inspect_iterations() if inspect
             else contextlib.nullcontext())
    with scope:
        for it in range(1, max_iter + 1):
            weights, means, covs, loglik = gmm_iteration(
                X, weights, means, covs, mode=mode, fuse=fuse)
            trace.append(loglik)
            if loglik - prev <= tol * abs(max(prev, -1e300)) and it > 1:
                break
            prev = loglik
    return GMMResult(weights=weights, means=means, covs=covs,
                     loglik=trace[-1], loglik_trace=trace, iters=it)
