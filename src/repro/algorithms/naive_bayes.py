"""Naive Bayes classifiers (paper §IV-A) on GenOps.

Training is the ``groupby.row`` showcase: every per-class moment is one
grouped sink, and ALL of them co-materialize in ONE streaming pass over X —
labels fuse straight into the scatter-add exactly like k-means.

Gaussian NB (continuous features):

    cnt  <- table(y)                            # per-class counts   (sink)
    s1   <- rowsum(X, y)                        # per-class sums     (sink)
    s2   <- rowsum(X * X, y)                    # per-class sq-sums  (sink)
    mu   <- s1 / cnt;  var <- s2 / cnt - mu^2   # small tier

Multinomial NB (count features, e.g. term counts): per-class feature
totals via rowsum.  Integer GenOp chains over a count matrix (e.g.
``colSums(X)``) lower onto the ``fused_apply_agg`` kernel with an exact
i32 accumulator (the acc-dtype widening; see
core/lowering._match_apply_agg) instead of falling back to the generic
trace.

Prediction is one row-local pass: per-class log-likelihood columns, cbind,
which.max — the same shape as the k-means assignment step, so it fuses and
streams on any tier.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import fm

_VAR_EPS = 1e-9


@dataclasses.dataclass
class NaiveBayesModel:
    kind: str                  # 'gaussian' | 'multinomial'
    class_log_prior: np.ndarray    # (k,)
    # gaussian: per-class means/variances; multinomial: log feature probs.
    means: np.ndarray | None       # (k, p)
    variances: np.ndarray | None   # (k, p)
    feature_log_prob: np.ndarray | None  # (k, p)
    class_count: np.ndarray        # (k,)


def naive_bayes(X: fm.FM, y: fm.FM, num_classes: int, *,
                kind: str = "gaussian", alpha: float = 1.0,
                mode: str = "auto", fuse: bool = True,
                backend=None) -> NaiveBayesModel:
    """Train on an n×p matrix and an n×1 integer label vector (0-based),
    both row-aligned on any storage tier."""
    n, p = X.shape
    k = int(num_classes)
    if kind == "gaussian":
        cnt, s1, s2 = fm.materialize(
            fm.table_(y, k),
            fm.rowsum(X, y, k),
            fm.rowsum(X * X, y, k),
            mode=mode, fuse=fuse, backend=backend)
        c = fm.as_np(cnt).reshape(-1).astype(np.float64)
        safe = np.maximum(c, 1.0).reshape(-1, 1)
        mu = fm.as_np(s1).astype(np.float64) / safe
        var = fm.as_np(s2).astype(np.float64) / safe - mu ** 2
        var = np.maximum(var, _VAR_EPS)
        return NaiveBayesModel(
            kind=kind, class_log_prior=np.log(np.maximum(c, 1e-300) / n),
            means=mu, variances=var, feature_log_prob=None, class_count=c)
    if kind == "multinomial":
        # Per-class feature totals + class counts, one pass.  (Integer
        # apply→agg chains like colSums(X_int) dispatch to the i32
        # fused_apply_agg path — covered by tests/test_lowering.py.)
        cnt, F = fm.materialize(
            fm.table_(y, k),
            fm.rowsum(X, y, k),
            mode=mode, fuse=fuse, backend=backend)
        c = fm.as_np(cnt).reshape(-1).astype(np.float64)
        Fc = fm.as_np(F).astype(np.float64) + alpha
        flp = np.log(Fc) - np.log(Fc.sum(1, keepdims=True))
        return NaiveBayesModel(
            kind=kind, class_log_prior=np.log(np.maximum(c, 1e-300) / n),
            means=None, variances=None, feature_log_prob=flp, class_count=c)
    raise ValueError(f"unknown kind {kind!r}; have gaussian|multinomial")


def nb_score(model: NaiveBayesModel, X: fm.FM) -> fm.FM:
    """Per-class log-likelihood columns (n × k, LAZY row-local chain)."""
    k = model.class_count.shape[0]
    cols = []
    if model.kind == "gaussian":
        for j in range(k):
            mu = model.means[j].astype(np.float32)
            var = model.variances[j].astype(np.float32)
            Z = fm.mapply_row(X, mu, "sub")
            q = fm.rowSums(fm.mapply_row(Z * Z, 2.0 * var, "div"))
            const = float(model.class_log_prior[j]
                          - 0.5 * np.log(2.0 * np.pi * model.variances[j]).sum())
            cols.append(const - q)
    else:
        # scores = X %*% t(log P) + log prior: X (possibly int) casts
        # lazily into the tall·small inner product.
        W = model.feature_log_prob.astype(np.float32).T      # p × k
        return fm.mapply_row(X @ W,
                             model.class_log_prior.astype(np.float32), "add")
    return fm.cbind(*cols)


def nb_predict(model: NaiveBayesModel, X: fm.FM, *, mode: str = "auto",
               fuse: bool = True, backend=None) -> fm.FM:
    """Predicted class labels (n × 1, int32), one fused row-local pass."""
    labels = fm.which_max_row(nb_score(model, X))
    (out,) = fm.materialize(labels, mode=mode, fuse=fuse, backend=backend)
    return out
