"""Naive Bayes classifiers (paper §IV-A) on GenOps.

Training is the ``groupby.row`` showcase: every per-class moment is one
grouped sink, and ALL of them co-materialize in ONE streaming pass over X —
labels fuse straight into the scatter-add exactly like k-means.

Gaussian NB (continuous features):

    cnt  <- table(y)                            # per-class counts   (sink)
    s1   <- rowsum(X, y)                        # per-class sums     (sink)
    s2   <- rowsum(X * X, y)                    # per-class sq-sums  (sink)
    mu   <- s1 / cnt;  var <- s2 / cnt - mu^2   # plan epilogue (lazy)

Multinomial NB (count features, e.g. term counts): per-class feature
totals via rowsum.  Integer GenOp chains over a count matrix (e.g.
``colSums(X)``) lower onto the ``fused_apply_agg`` kernel with an exact
i32 accumulator (the acc-dtype widening; see
core/lowering._match_apply_agg) instead of falling back to the generic
trace.

Prediction is one row-local pass: per-class log-likelihood columns, cbind,
which.max — the same shape as the k-means assignment step, so it fuses and
streams on any tier.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import fm

_VAR_EPS = 1e-9


@dataclasses.dataclass
class NaiveBayesModel:
    kind: str                  # 'gaussian' | 'multinomial'
    class_log_prior: np.ndarray    # (k,)
    # gaussian: per-class means/variances; multinomial: log feature probs.
    means: np.ndarray | None       # (k, p)
    variances: np.ndarray | None   # (k, p)
    feature_log_prob: np.ndarray | None  # (k, p)
    class_count: np.ndarray        # (k,)


def nb_gaussian_outputs(X: fm.FM, y: fm.FM, k: int):
    """The gaussian training DAG as lazy handles: per-class counts plus
    the mu/var EPILOGUE chains over the grouped sinks — mu = s1/cnt and
    var = s2/cnt − mu² evaluate once after the partial merge, inside the
    SAME single-pass plan (the `cnt` recycling lowers onto mapply.col of
    two merged sink values).  Exposed so benchmark iteration plans build
    the exact DAG the algorithm executes."""
    cnt = fm.table_(y, k)
    s1 = fm.rowsum(X, y, k)
    s2 = fm.rowsum(X * X, y, k)
    safe = fm.pmax(fm.sapply(cnt, "cast_float32"), 1.0)
    mu = s1 / safe
    var = fm.pmax(s2 / safe - mu * mu, _VAR_EPS)
    return cnt, mu, var


def naive_bayes(X: fm.FM, y: fm.FM, num_classes: int, *,
                kind: str = "gaussian", alpha: float = 1.0,
                mode: str = "auto", fuse: bool = True,
                backend=None) -> NaiveBayesModel:
    """Train on an n×p matrix and an n×1 integer label vector (0-based),
    both row-aligned on any storage tier."""
    n, p = X.shape
    k = int(num_classes)
    if kind == "gaussian":
        cnt, mu, var = nb_gaussian_outputs(X, y, k)
        cnt_m, mu_m, var_m = fm.materialize(
            cnt, mu, var, mode=mode, fuse=fuse, backend=backend)
        c = fm.as_np(cnt_m).reshape(-1).astype(np.float64)
        return NaiveBayesModel(
            kind=kind, class_log_prior=np.log(np.maximum(c, 1e-300) / n),
            means=fm.as_np(mu_m).astype(np.float64),
            variances=fm.as_np(var_m).astype(np.float64),
            feature_log_prob=None, class_count=c)
    if kind == "multinomial":
        # Per-class feature totals + class counts + smoothed log-probs, one
        # pass: the Laplace smoothing and row normalization are epilogue
        # math over the rowsum sink.  (Integer apply→agg chains like
        # colSums(X_int) dispatch to the i32 fused_apply_agg path — covered
        # by tests/test_lowering.py.)
        cnt = fm.table_(y, k)
        Fc = fm.rowsum(X, y, k) + float(alpha)
        flp = fm.log(Fc) - fm.log(fm.rowSums(Fc))
        cnt_m, flp_m = fm.materialize(
            cnt, flp, mode=mode, fuse=fuse, backend=backend)
        c = fm.as_np(cnt_m).reshape(-1).astype(np.float64)
        return NaiveBayesModel(
            kind=kind, class_log_prior=np.log(np.maximum(c, 1e-300) / n),
            means=None, variances=None,
            feature_log_prob=fm.as_np(flp_m).astype(np.float64),
            class_count=c)
    raise ValueError(f"unknown kind {kind!r}; have gaussian|multinomial")


def nb_score(model: NaiveBayesModel, X: fm.FM) -> fm.FM:
    """Per-class log-likelihood columns (n × k, LAZY row-local chain)."""
    k = model.class_count.shape[0]
    cols = []
    if model.kind == "gaussian":
        for j in range(k):
            mu = model.means[j].astype(np.float32)
            var = model.variances[j].astype(np.float32)
            Z = fm.mapply_row(X, mu, "sub")
            q = fm.rowSums(fm.mapply_row(Z * Z, 2.0 * var, "div"))
            const = float(model.class_log_prior[j]
                          - 0.5 * np.log(2.0 * np.pi * model.variances[j]).sum())
            cols.append(const - q)
    else:
        # scores = X %*% t(log P) + log prior: X (possibly int) casts
        # lazily into the tall·small inner product.
        W = model.feature_log_prob.astype(np.float32).T      # p × k
        return fm.mapply_row(X @ W,
                             model.class_log_prior.astype(np.float32), "add")
    return fm.cbind(*cols)


def nb_predict(model: NaiveBayesModel, X: fm.FM, *, mode: str = "auto",
               fuse: bool = True, backend=None) -> fm.FM:
    """Predicted class labels (n × 1, int32), one fused row-local pass."""
    labels = fm.which_max_row(nb_score(model, X))
    (out,) = fm.materialize(labels, mode=mode, fuse=fuse, backend=backend)
    return out
