"""Double-buffered async partition prefetcher (paper §III-F I/O overlap).

A background thread walks the long dimension, reads each source's
I/O-level partition from its store (a disk read for ``MmapStore``, a RAM
slice for host ``DenseStore``), makes it contiguous and ``device_put``s
it, then parks the staged partition in a bounded queue.  The consumer
(``materialize._execute_stream``) pops partition *i* and computes while
the thread is already staging partition *i+1* — disk I/O, host→device DMA
and compute overlap, which is the mechanism that lets the paper's
out-of-core execution track in-memory performance.

``depth`` bounds how far ahead the thread runs (default 2 = classic
double buffering), which also bounds staged memory to
``depth × partition_bytes`` — the memory-chunk discipline.

Staged device blocks are exclusively owned by the pipeline, so the
consumer may donate them to the fused step (buffer recycling) without a
defensive copy.
"""
from __future__ import annotations

import queue
import threading
import time
import weakref
from typing import Iterator, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..observability import metrics
from ..observability.trace import TRACER

_DONE = object()

#: Hard ceiling on a negotiated prefetch depth: beyond this the queue only
#: adds staged-memory pressure (depth × partition bytes) without hiding any
#: more latency.
MAX_NEGOTIATED_DEPTH = 8


def negotiate_depth(n_members: int, partition_nbytes: int,
                    base: Optional[int] = None,
                    budget_bytes: Optional[int] = None) -> int:
    """Group-aware prefetch depth for a co-scheduled stream (ISSUE 8).

    A solo stream double-buffers (``base``, default the configured
    ``prefetch_depth``); a group of k member plans consumes each staged
    partition k times, so compute per partition is ~k× longer and the
    stager can usefully run further ahead — one extra slot per extra
    member, capped at `MAX_NEGOTIATED_DEPTH` and (when ``budget_bytes``
    is given) at the number of partitions that fit the staging budget —
    the budget clamp may go below ``base``, but never below 1.
    """
    from . import registry
    if base is None:
        base = int(registry.get_conf("prefetch_depth"))
    depth = min(base + max(0, int(n_members) - 1), MAX_NEGOTIATED_DEPTH)
    if budget_bytes and partition_nbytes > 0:
        depth = min(depth, int(budget_bytes) // int(partition_nbytes))
    return max(1, depth)


def stage_block(mat, start: int, stop: int, *, donate: bool = True,
                to_device: bool = True, device=None):
    """Read one I/O-level partition from ``mat`` and stage it for the fused
    step — the single definition of the staging rules, shared by the
    prefetch thread and the synchronous (prefetch-off) path:

    * slow-tier (numpy/memmap) blocks are made contiguous (the actual disk
      read for a memmap slice) and ``device_put`` — dispatch is async, so
      the H2D copy overlaps downstream compute;
    * device-resident blocks are defensively copied when the consumer will
      donate them (donation must not consume the source buffer).

    Emits a ``stage`` span on whichever thread runs it (the prefetch
    worker's own track when pipelined) and feeds the slow-tier read
    bandwidth counters (``stage_bytes_read`` / ``stage_read_seconds``:
    memmap/numpy reads only — device-resident blocks involve no tier read).

    ``device`` pins the staged block to one device of a mesh (the sharded
    partition loop stages each shard's rows onto that shard's device);
    ``None`` keeps the default uncommitted placement.
    """
    t0 = time.perf_counter()
    blk = mat.block(start, stop)
    if type(blk).__name__ == "SparseBlock":
        # Sparse (ELL) partition: a (cols, vals) pytree.  Same rules as the
        # dense branches, applied leaf-wise — host slabs are the slow-tier
        # read (contiguous + async device_put), device slabs are copied
        # only when the consumer will donate them.
        from ..core.sparse import SparseBlock
        if isinstance(blk.vals, np.ndarray):
            cols = np.ascontiguousarray(blk.cols)
            vals = np.ascontiguousarray(blk.vals)
            metrics.inc("stage_bytes_read", cols.nbytes + vals.nbytes)
            metrics.inc("stage_read_seconds", time.perf_counter() - t0)
            if to_device:
                cols = jax.device_put(cols, device)
                vals = jax.device_put(vals, device)
            blk = SparseBlock(cols, vals, blk.ncol)
        elif device is not None:
            blk = SparseBlock(jax.device_put(blk.cols, device),
                              jax.device_put(blk.vals, device), blk.ncol)
        elif donate:
            blk = SparseBlock(jnp.copy(blk.cols), jnp.copy(blk.vals),
                              blk.ncol)
        TRACER.record("stage", t0, time.perf_counter(),
                      {"start": int(start), "stop": int(stop)})
        return blk
    if isinstance(blk, np.ndarray):
        blk = np.ascontiguousarray(blk)
        # The slow-tier read is complete once the block is contiguous in
        # RAM; device_put below is async dispatch, not read time.
        metrics.inc("stage_bytes_read", blk.nbytes)
        metrics.inc("stage_read_seconds", time.perf_counter() - t0)
        if to_device:
            blk = jax.device_put(blk, device)
    elif device is not None:
        # Cross-device copy: commits to the shard's device and leaves the
        # resident source buffer untouched, so donation stays safe.
        blk = jax.device_put(blk, device)
    elif donate:
        blk = jnp.copy(blk)
    TRACER.record("stage", t0, time.perf_counter(),
                  {"start": int(start), "stop": int(stop)})
    return blk


def _source_name(mat) -> str:
    """Best human-readable identity of a staged source, for error context:
    the matrix's registry name, its backing file path, or its type."""
    name = getattr(mat, "name", "")
    if name:
        return str(name)
    store = getattr(mat, "store", None)
    path = getattr(store, "path", None) or getattr(mat, "path", None)
    if path:
        return str(path)
    return type(store or mat).__name__


class PrefetchError(RuntimeError):
    """A staging-thread failure, re-raised on the consumer side."""


#: Every constructed prefetcher, weakly held — leak-audit introspection
#: (ISSUE 9): after a stream ends (normally or via a fault) no entry may
#: have a live worker thread or staged partitions still queued.
_LIVE: "weakref.WeakSet" = weakref.WeakSet()


def live_prefetchers() -> list:
    """Prefetchers whose worker thread is still running — must be empty
    between streams; a non-empty result is a shutdown leak."""
    return [p for p in list(_LIVE) if p.alive]


def staged_leaks() -> list:
    """Closed-or-dead prefetchers still holding staged partitions in their
    queue (device memory pinned past shutdown) — must be empty."""
    leaks = []
    for p in list(_LIVE):
        if not p.alive and p.queued:
            leaks.append(p)
    return leaks


class PartitionPrefetcher:
    """Iterate ``(start, stop, {node_id: staged_block})`` over partitions.

    sources: ``[(node_id, matrix)]`` where each matrix exposes
    ``block(start, stop)`` (FMMatrix or a bare MatrixStore).
    """

    def __init__(self, sources: Sequence[Tuple[int, object]],
                 partition_rows: int, long_dim: int, *, depth: int = 2,
                 donate: bool = True, stage_to_device: bool = True,
                 reuse: Optional[dict] = None, row_start: int = 0,
                 device=None):
        if depth < 1:
            raise ValueError("prefetch depth must be >= 1")
        self.sources = list(sources)
        self.partition_rows = int(partition_rows)
        self.long_dim = int(long_dim)
        # Half-open row range [row_start, long_dim): a sharded partition
        # loop drives one prefetcher per device shard, each over its own
        # range, staged onto that shard's ``device``.
        self.row_start = int(row_start)
        self.device = device
        self.donate = donate
        self.stage_to_device = stage_to_device
        # {node_id: staged block} for the FINAL partition: when the previous
        # pass ran the identical partition schedule, its last resident
        # partition is still on device — serve it instead of re-reading
        # (counted as ``prefetch_reuse_hits``; core/materialize owns the
        # residency bookkeeping and schedule-equality check).
        self.reuse = dict(reuse) if reuse else None
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._closed = False
        # Metrics scopes open on the CONSTRUCTING thread: the worker adopts
        # them so background staging is attributed to the fm.collect_stats()
        # request that spawned this pipeline.
        self._scopes = metrics.current_scopes()
        self._thread = threading.Thread(
            target=self._worker, name="fm-prefetch", daemon=True)
        _LIVE.add(self)
        self._thread.start()

    # -- staging thread --------------------------------------------------------
    def _worker(self):
        with metrics.use_scopes(self._scopes):
            try:
                start = self.row_start
                while start < self.long_dim and not self._stop.is_set():
                    stop = min(start + self.partition_rows, self.long_dim)
                    final = stop >= self.long_dim
                    blocks = {}
                    for nid, mat in self.sources:
                        if final and self.reuse and nid in self.reuse:
                            # Partition-reuse: the identical final partition
                            # is already staged from the previous pass.
                            blocks[nid] = self.reuse[nid]
                            metrics.inc("prefetch_reuse_hits")
                            continue
                        try:
                            blocks[nid] = stage_block(
                                mat, start, stop, donate=self.donate,
                                to_device=self.stage_to_device,
                                device=self.device)
                        except Exception as exc:
                            raise PrefetchError(
                                f"prefetch thread failed staging rows "
                                f"[{start}, {stop}) of source "
                                f"{_source_name(mat)!r}: {exc!r}") from exc
                    metrics.observe("prefetch_queue_depth", self._q.qsize())
                    if not self._put((start, stop, blocks)):
                        return
                    start = stop
                self._put(_DONE)
            except Exception as exc:  # noqa: BLE001 - forwarded to consumer
                self._put(exc)

    def _put(self, item) -> bool:
        """Bounded put that aborts promptly when close() is requested."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    # -- consumer side ---------------------------------------------------------
    def __iter__(self) -> Iterator[tuple]:
        while True:
            t0 = time.perf_counter()
            item = self._q.get()
            t1 = time.perf_counter()
            # Time the compute thread spent blocked on the staging queue:
            # the numerator of prefetch_wait_frac (pipeline-fill included).
            metrics.inc("prefetch_wait_seconds", t1 - t0)
            TRACER.record("prefetch_wait", t0, t1)
            if item is _DONE:
                self._closed = True
                return
            if isinstance(item, PrefetchError):
                # Already carries partition + source context from _worker.
                self._closed = True
                raise item
            if isinstance(item, Exception):
                self._closed = True
                raise PrefetchError(f"prefetch thread failed: {item!r}") from item
            yield item

    def close(self):
        """Stop the staging thread and drop queued partitions.  Idempotent;
        safe to call mid-stream (early consumer exit) or after exhaustion.

        Drain and join must INTERLEAVE: a worker parked in ``_put`` on a
        full queue re-checks ``_stop`` only on its 50 ms timeout, so a
        single drain *before* the join races it — the worker could enqueue
        one more staged partition after the drain and leave device blocks
        pinned in the dead pipeline's queue (the ISSUE 9 shutdown leak).
        """
        self._stop.set()
        deadline = time.monotonic() + 10.0
        while True:
            self._drain()
            self._thread.join(timeout=0.05)
            if not self._thread.is_alive() or time.monotonic() > deadline:
                break
        self._drain()
        self._closed = True

    def _drain(self):
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    @property
    def queued(self) -> int:
        """Staged partitions currently parked in the queue (leak audit)."""
        return self._q.qsize()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
