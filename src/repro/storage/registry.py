"""Named-matrix registry + engine configuration (FlashR's EM workflow).

FlashR keeps external-memory matrices as named files under a configured
data directory (``fm.set.conf``); users reopen them by name with
``fm.get.dense.matrix`` and create them with ``fm.load.dense.matrix`` /
``fm.conv.store(in.mem=FALSE)``.  This module is that surface:

    fm.set_conf(data_dir="/ssd/fm")            # once per deployment
    X = fm.load_dense_matrix("criteo.csv", name="criteo")   # ingest → disk
    X = fm.get_dense_matrix("criteo")          # O(1) reopen, mmap-backed
    Y = fm.conv_store(Z, "disk")               # spill a result by name

The registry is directory-backed (one ``<name>.fmat`` per matrix), so it
is shared between processes and survives restarts; nothing is cached in
RAM beyond the mmap handles.
"""
from __future__ import annotations

import atexit
import contextlib
import difflib
import itertools
import os
import pathlib
import re
import shutil
import tempfile
import threading
from typing import Optional

import numpy as np

from ..core import lowering as lowering_mod
from ..core import matrix as matrix_mod
from ..core.matrix import FMMatrix
from . import format as fmt

_CONF = {
    "data_dir": None,       # pathlib.Path once configured / first used
    "prefetch": True,       # default for ooc execution (overridable per call)
    "prefetch_depth": 2,    # bounded-queue depth (2 = double buffering)
    "direct_io": False,     # best-effort page-cache bypass on partition reads
    "mesh": None,           # default jax Mesh for sharded execution (ISSUE 9)
}

#: Temp dirs the registry itself mkdtemp'd (NEVER a user-supplied
#: data_dir): removed at interpreter exit and by ``cleanup()`` /
#: ``Engine.close(release_storage=True)`` — repeated test/bench runs used
#: to leak one ``fm-data-*`` dir per process (ISSUE 9 satellite).
_OWNED_DIRS: list[pathlib.Path] = []
_ATEXIT_REGISTERED = False

_spill_ids = itertools.count()

#: Guards _CONF mutation — most importantly the lazy ``data_dir()`` init:
#: without it two threads racing the first disk-tier touch (fm.serve
#: workers, concurrent materialize) could each mkdtemp their OWN data dir
#: and then fail to see each other's named matrices (ISSUE 8 audit).
_CONF_LOCK = threading.Lock()


#: The full knob table ``set_conf`` validates against — one entry per
#: accepted keyword, with a one-line meaning (rendered in the
#: unknown-knob error and docs/api.md).
KNOWN_KNOBS = {
    "data_dir": "storage-tier directory for named .fmat matrices",
    "prefetch": "async partition prefetch default for ooc execution",
    "prefetch_depth": "bounded staging-queue depth (2 = double buffering)",
    "io_partition_bytes": "I/O-level partition budget (streaming granule)",
    "vmem_partition_bytes": "processor-level (VMEM tile) partition budget",
    "backend": "lowering backend: 'auto' | 'xla' | 'pallas'",
    "direct_io": "best-effort page-cache bypass on partition reads",
    "mesh": "default jax Mesh for sharded execution (False clears)",
}


def _check_knobs(kw: dict):
    unknown = [k for k in kw if k not in KNOWN_KNOBS]
    if not unknown:
        return
    parts = []
    for k in unknown:
        close = difflib.get_close_matches(k, KNOWN_KNOBS, n=1)
        parts.append(f"{k!r} (did you mean {close[0]!r}?)" if close
                     else repr(k))
    plural = "s" if len(parts) > 1 else ""
    raise ValueError(
        f"unknown config knob{plural} {', '.join(parts)}; known knobs: "
        f"{', '.join(sorted(KNOWN_KNOBS))}")


def set_conf(**kw) -> dict:
    """fm.set.conf: configure the storage tier + execution engine.
    Returns the live config.

    Keywords are validated against `KNOWN_KNOBS` — a typo raises with a
    did-you-mean suggestion instead of being silently dropped.  ``None``
    always means "leave unchanged"; use ``fm.conf(...)`` (the context
    manager) for a scoped override that restores prior values.

    ``io_partition_bytes`` adjusts the I/O-level partition budget engine-
    wide (core.matrix.IO_PARTITION_BYTES) — the knob the out-of-core
    examples/benchmarks turn to make matrices many partitions long.
    ``vmem_partition_bytes`` adjusts the processor-level (second tier)
    budget the plan IR schedules per-segment block rows from.
    ``backend`` picks the lowering backend ('auto' | 'xla' | 'pallas',
    core/lowering.py).  ``direct_io`` enables best-effort page-cache bypass
    (posix_fadvise/madvise DONTNEED) after each disk partition read, so
    benchmarks can measure genuinely cold reads.

    ``mesh`` installs a default jax ``Mesh`` (launch.mesh.make_host_mesh)
    for SHARDED execution: every materialize/batch/serve drive splits its
    partition loop over the mesh's data axis (core/materialize).  Pass
    ``mesh=False`` to clear it (``None`` means "leave unchanged", like
    every other knob here).
    """
    _check_knobs(kw)
    data_dir = kw.get("data_dir")
    prefetch = kw.get("prefetch")
    prefetch_depth = kw.get("prefetch_depth")
    io_partition_bytes = kw.get("io_partition_bytes")
    vmem_partition_bytes = kw.get("vmem_partition_bytes")
    backend = kw.get("backend")
    direct_io = kw.get("direct_io")
    mesh = kw.get("mesh")
    if data_dir is not None:
        p = pathlib.Path(data_dir)
        p.mkdir(parents=True, exist_ok=True)
        with _CONF_LOCK:
            _CONF["data_dir"] = p
    if prefetch is not None:
        _CONF["prefetch"] = bool(prefetch)
    if prefetch_depth is not None:
        if int(prefetch_depth) < 1:
            raise ValueError("prefetch_depth must be >= 1")
        _CONF["prefetch_depth"] = int(prefetch_depth)
    if io_partition_bytes is not None:
        matrix_mod.IO_PARTITION_BYTES = int(io_partition_bytes)
    if vmem_partition_bytes is not None:
        matrix_mod.VMEM_PARTITION_BYTES = int(vmem_partition_bytes)
    if backend is not None:
        if backend != "auto" and backend not in lowering_mod.BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; have "
                f"{sorted(lowering_mod.BACKENDS)} + 'auto'")
        lowering_mod.DEFAULT_BACKEND = backend
    if direct_io is not None:
        _CONF["direct_io"] = bool(direct_io)
    if mesh is not None:
        if mesh is False:
            _CONF["mesh"] = None
        else:
            if not (hasattr(mesh, "axis_names") and hasattr(mesh, "devices")):
                raise TypeError(
                    f"mesh must be a jax Mesh (see launch.mesh."
                    f"make_host_mesh) or False to clear; got {mesh!r}")
            _CONF["mesh"] = mesh
    return dict(_CONF, io_partition_bytes=matrix_mod.IO_PARTITION_BYTES,
                vmem_partition_bytes=matrix_mod.VMEM_PARTITION_BYTES,
                backend=lowering_mod.DEFAULT_BACKEND)


def get_conf(key: str):
    if key == "io_partition_bytes":
        return matrix_mod.IO_PARTITION_BYTES
    if key == "vmem_partition_bytes":
        return matrix_mod.VMEM_PARTITION_BYTES
    if key == "backend":
        return lowering_mod.DEFAULT_BACKEND
    return _CONF[key]


def _restore_conf(snapshot: dict):
    """Put knobs back EXACTLY as snapshotted — bypasses ``set_conf``'s
    "None means leave unchanged" convention so an unset ``data_dir`` or a
    cleared ``mesh`` restores to unset, not to "unchanged"."""
    for k, v in snapshot.items():
        if k == "io_partition_bytes":
            matrix_mod.IO_PARTITION_BYTES = v
        elif k == "vmem_partition_bytes":
            matrix_mod.VMEM_PARTITION_BYTES = v
        elif k == "backend":
            lowering_mod.DEFAULT_BACKEND = v
        else:
            with _CONF_LOCK:
                _CONF[k] = v


@contextlib.contextmanager
def conf(**kw):
    """fm.conf: scoped configuration override.

        with fm.conf(backend='pallas', io_partition_bytes=1 << 20):
            fm.materialize(...)     # runs under the overridden knobs
        # prior values restored here, even on error

    Same knob table and validation as ``set_conf``; yields the LIVE config
    dict.  Replaces the manual save/apply/try/finally-restore dance in
    tests and benchmarks."""
    _check_knobs(kw)
    snapshot = {k: get_conf(k) for k in kw}
    try:
        yield set_conf(**kw)
    finally:
        _restore_conf(snapshot)


def data_dir() -> pathlib.Path:
    """The configured data directory (lazily a fresh temp dir, so the disk
    tier works out of the box in tests and examples).  Thread-safe: the
    lazy init is locked so concurrent first touches agree on ONE dir.
    Lazily-created dirs are registry-OWNED: they are removed at process
    exit (atexit) or by ``cleanup()``; a user-supplied ``data_dir`` is
    never touched."""
    global _ATEXIT_REGISTERED
    with _CONF_LOCK:
        if _CONF["data_dir"] is None:
            d = pathlib.Path(tempfile.mkdtemp(prefix="fm-data-"))
            _CONF["data_dir"] = d
            _OWNED_DIRS.append(d)
            if not _ATEXIT_REGISTERED:
                atexit.register(cleanup)
                _ATEXIT_REGISTERED = True
        return _CONF["data_dir"]


def cleanup() -> list[pathlib.Path]:
    """Remove every ``fm-data-*`` dir the registry itself created and
    forget them.  User-configured directories are never removed.  Returns
    the removed paths.  Runs automatically at interpreter exit; callable
    any time (``Engine.close(release_storage=True)`` routes here)."""
    with _CONF_LOCK:
        owned, _OWNED_DIRS[:] = list(_OWNED_DIRS), []
        for d in owned:
            shutil.rmtree(d, ignore_errors=True)
        if _CONF["data_dir"] in owned:
            _CONF["data_dir"] = None
    return owned


def _sanitize(name: str) -> str:
    clean = re.sub(r"[^A-Za-z0-9._-]+", "_", name).strip("._")
    return clean or "matrix"


def matrix_path(name: str) -> pathlib.Path:
    return data_dir() / f"{_sanitize(name)}.fmat"


def spill_path(name: str = "") -> pathlib.Path:
    """A fresh file for a write-through spill output (save='disk')."""
    return (data_dir() / "spill"
            / f"{_sanitize(name or 'out')}-{next(_spill_ids)}.fmat")


# ---------------------------------------------------------------------------
# The EM-matrix surface
# ---------------------------------------------------------------------------

def save_dense_matrix(mat, name: Optional[str] = None, *,
                      layout: str = "row") -> FMMatrix:
    """Write a matrix (FMMatrix / numpy / jax array) to the data dir under
    ``name`` and return the disk-backed handle."""
    if name is None:
        name = getattr(mat, "name", "") or f"anon-{next(_spill_ids)}"
    path = matrix_path(name)
    fmt.save_matrix(path, mat, layout=layout)
    return get_dense_matrix(name)


def save_sparse_matrix(mat, name: Optional[str] = None) -> FMMatrix:
    """Write a sparse-tier matrix (SparseEllStore / CsrMmapStore backed
    FMMatrix, or any matrix worth storing sparse) to the data dir as a CSR
    ``.fmat`` and return the disk-backed handle (``fm.persist(x,
    tier='disk')`` routes sparse matrices here)."""
    from ..core.sparse import csr_from_dense, csr_from_ell
    from . import sparse as sp
    if name is None:
        name = getattr(mat, "name", "") or f"anon-{next(_spill_ids)}"
    path = matrix_path(name)
    store = getattr(mat, "store", None)
    if isinstance(store, sp.CsrMmapStore):
        triplet = (np.asarray(store._indptr), np.asarray(store._indices),
                   np.asarray(store._data))
    elif isinstance(store, sp.SparseEllStore):
        triplet = csr_from_ell(np.asarray(store.cols),
                               np.asarray(store.vals))
    else:
        triplet = csr_from_dense(np.asarray(mat.logical_data()))
    sp.save_csr_matrix(path, *triplet, ncol=mat.shape[1])
    return get_dense_matrix(name)


def get_dense_matrix(name: str) -> FMMatrix:
    """fm.get.dense.matrix: reopen a named on-disk matrix (O(1), mmap).
    Dispatches on the stored format — a CSR ``.fmat`` reopens as a
    sparse-tier (CsrMmapStore-backed) matrix."""
    path = matrix_path(name)
    if not path.exists():
        raise KeyError(
            f"no on-disk matrix {name!r} under {os.fspath(data_dir())} "
            f"(have: {sorted(list_matrices())})")
    store = fmt.open_matrix(path)
    shape = getattr(store, "shape", None) or store.header.shape
    dtype = getattr(store, "dtype", None) or store.header.dtype
    return FMMatrix(shape, dtype, store=store, name=name)


def load_dense_matrix(src, name: str, *, ncol: Optional[int] = None,
                      dtype=None, delimiter: str = ",",
                      layout: str = "row", **ingest_kw) -> FMMatrix:
    """fm.load.dense.matrix: ingest an external file into the registry.

    ``src`` may be a ``.csv``/``.txt`` text file, a ``.npy`` array, a raw
    binary file (requires ``ncol``), or an in-memory array.  Text/binary
    ingest streams through data.pipeline in bounded chunks (Criteo-scale
    files never fully materialize in RAM).

    ``dtype=None`` keeps the source's own dtype for arrays and ``.npy``
    files, and defaults to float32 for text/raw-binary (whose element type
    is not self-describing).
    """
    from ..data import pipeline as _pipeline  # lazy: data imports are heavy
    dest = matrix_path(name)
    if isinstance(src, (str, os.PathLike)):
        suffix = pathlib.Path(src).suffix.lower()
        if suffix in (".csv", ".txt", ".tsv"):
            _pipeline.ingest_csv(src, dest, dtype=dtype or np.float32,
                                 delimiter=delimiter, layout=layout,
                                 **ingest_kw)
        elif suffix == ".npy":
            arr = np.load(src, mmap_mode="r")
            if dtype is not None:
                arr = np.asarray(arr, dtype=dtype)
            fmt.save_matrix(dest, arr, layout=layout)
        else:
            if ncol is None:
                raise ValueError("raw binary ingest requires ncol=")
            _pipeline.ingest_binary(src, dest, ncol=ncol,
                                    dtype=dtype or np.float32,
                                    layout=layout, **ingest_kw)
    else:
        arr = np.asarray(src) if dtype is None else np.asarray(src, dtype=dtype)
        fmt.save_matrix(dest, arr, layout=layout)
    return get_dense_matrix(name)


def load_factor_matrix(src, name: str, *, num_levels, dtype=np.float32,
                       delimiter: str = ",", **ingest_kw) -> FMMatrix:
    """fm.load.factor.matrix: stream a CSV of integer factor columns into
    the registry as a CSR ``.fmat`` of one-hot rows (the Criteo ingest —
    see data.pipeline.ingest_factor_csv) and reopen it sparse."""
    from ..data import pipeline as _pipeline  # lazy: data imports are heavy
    _pipeline.ingest_factor_csv(src, matrix_path(name),
                                num_levels=num_levels, dtype=dtype,
                                delimiter=delimiter, **ingest_kw)
    return get_dense_matrix(name)


def delete_matrix(name: str):
    path = matrix_path(name)
    if path.exists():
        path.unlink()


def list_matrices() -> list[str]:
    if _CONF["data_dir"] is None:
        return []
    return sorted(p.stem for p in data_dir().glob("*.fmat"))
