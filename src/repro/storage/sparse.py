"""Sparse CSR variant of the on-disk ``.fmat`` format (the Criteo tier).

Same container as ``format.py`` — magic, version, page-aligned JSON header
block — with ``"format": "csr"`` in the header and three body sections
instead of one dense buffer:

    [HEADER_BYTES, ..)     indptr   int64  (nrow + 1)
    [indices_offset, ..)   indices  int32  (nnz)
    [data_offset, ..)      data     dtype  (nnz)

``indptr`` is tiny (8 bytes/row) and maps in O(1); a partition read of
rows [start, stop) is two contiguous range reads (indices + data) located
by the indptr slice — the same "one contiguous range per partition"
property the dense format has, which is what the SSD streaming story
needs.  The header also records ``max_row_nnz``, the matrix-wide widest
row: every partition is expanded to a fixed (rows, max_row_nnz) ELL slab
(core/sparse.SparseBlock) so the executor's jit'd partition step keeps a
static structure across partitions.
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from ..core.matrix import MatrixStore
from ..core.sparse import SparseBlock, ell_from_csr_rows
from .format import HEADER_BYTES, MAGIC, VERSION, PathLike


def _csr_header_bytes(*, nrow: int, ncol: int, dtype, nnz: int,
                      max_row_nnz: int) -> bytes:
    indptr_offset = HEADER_BYTES
    indices_offset = indptr_offset + (nrow + 1) * 8
    data_offset = indices_offset + nnz * 4
    payload = json.dumps({
        "format": "csr", "nrow": int(nrow), "ncol": int(ncol),
        "dtype": np.dtype(dtype).str, "layout": "row",
        "nnz": int(nnz), "max_row_nnz": int(max_row_nnz),
        "indptr_offset": indptr_offset, "indices_offset": indices_offset,
        "data_offset": data_offset,
    }).encode()
    head = (MAGIC + VERSION.to_bytes(4, "little")
            + len(payload).to_bytes(4, "little") + payload)
    if len(head) > HEADER_BYTES:
        raise ValueError("csr header does not fit the reserved block")
    return head + b"\x00" * (HEADER_BYTES - len(head))


def read_csr_meta(path: PathLike) -> dict:
    with open(path, "rb") as f:
        fixed = f.read(16)
        if len(fixed) < 16 or fixed[:8] != MAGIC:
            raise ValueError(f"{path}: not an fmat file (bad magic)")
        json_len = int.from_bytes(fixed[12:16], "little")
        meta = json.loads(f.read(json_len).decode())
    if meta.get("format") != "csr":
        raise ValueError(f"{path}: not a csr fmat file")
    return meta


def save_csr_matrix(path: PathLike, indptr, indices, data, *,
                    ncol: int) -> dict:
    """Write a CSR triplet to ``path``; returns the header meta dict."""
    indptr = np.ascontiguousarray(indptr, np.int64)
    indices = np.ascontiguousarray(indices, np.int32)
    data = np.ascontiguousarray(data)
    nrow = indptr.shape[0] - 1
    nnz = int(indptr[-1])
    if indices.shape[0] != nnz or data.shape[0] != nnz:
        raise ValueError(
            f"CSR sections disagree: indptr says nnz={nnz}, have "
            f"{indices.shape[0]} indices / {data.shape[0]} values")
    if nnz and (indices.min() < 0 or indices.max() >= ncol):
        raise ValueError(f"CSR column index out of range for ncol={ncol}")
    max_row_nnz = int(np.diff(indptr).max()) if nrow else 0
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(_csr_header_bytes(nrow=nrow, ncol=ncol, dtype=data.dtype,
                                  nnz=nnz, max_row_nnz=max_row_nnz))
        f.write(indptr.tobytes())
        f.write(indices.tobytes())
        f.write(data.tobytes())
    return read_csr_meta(path)


def open_csr(path: PathLike) -> "CsrMmapStore":
    return CsrMmapStore(path, read_csr_meta(path))


class CsrMmapStore(MatrixStore):
    """Disk-backed CSR matrix store: ``block()`` returns ELL SparseBlocks."""

    layout = "row"
    sparse = True

    def __init__(self, path, meta: dict):
        self.path = pathlib.Path(path)
        self.meta = meta
        self.shape = (int(meta["nrow"]), int(meta["ncol"]))
        self.dtype = np.dtype(meta["dtype"])
        self.nnz = int(meta["nnz"])
        # kmax floor of 1 keeps the all-zero-matrix ELL slab a valid shape.
        self.max_row_nnz = max(1, int(meta["max_row_nnz"]))
        self._indptr = np.memmap(self.path, dtype=np.int64, mode="r",
                                 offset=int(meta["indptr_offset"]),
                                 shape=(self.shape[0] + 1,))
        self._indices = np.memmap(self.path, dtype=np.int32, mode="r",
                                  offset=int(meta["indices_offset"]),
                                  shape=(self.nnz,))
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r",
                               offset=int(meta["data_offset"]),
                               shape=(self.nnz,))

    # -- MatrixStore protocol -----------------------------------------------
    @property
    def on_host(self) -> bool:
        return True

    @property
    def on_disk(self) -> bool:
        return True

    def block(self, start: int, stop: int) -> SparseBlock:
        return ell_from_csr_rows(self._indptr, self._indices, self._data,
                                 start, stop, self.max_row_nnz,
                                 self.shape[1])

    def logical(self) -> np.ndarray:
        """Densified copy — the small-tier escape hatch (conv_FM2R,
        oracles).  O(nrow·ncol) RAM: fine for tests, not the streaming
        path, which goes through ``block()``."""
        return self.block(0, self.shape[0]).todense()

    def nbytes(self) -> int:
        """Physical bytes on disk (what streaming actually moves) — NOT
        nrow·ncol·itemsize: the whole point of the tier."""
        return ((self.shape[0] + 1) * 8 + self.nnz * 4
                + self.nnz * self.dtype.itemsize)

    def transposed(self) -> "MatrixStore":
        return _SparseTransposed(self)

    def __repr__(self):
        return (f"CsrMmapStore({self.shape[0]}x{self.shape[1]}, "
                f"{self.dtype.name}, nnz={self.nnz}, "
                f"kmax={self.max_row_nnz}, path={str(self.path)!r})")


class SparseEllStore(MatrixStore):
    """In-memory sparse store over an ELL slab (host numpy or device jax)
    — what ``fm.one_hot`` builds for the mem/stream tiers, and the RAM
    analog of ``CsrMmapStore``."""

    layout = "row"
    sparse = True

    def __init__(self, cols, vals, ncol: int, *, nnz: int | None = None):
        self.cols = cols
        self.vals = vals
        self.shape = (int(cols.shape[0]), int(ncol))
        self.dtype = np.dtype(vals.dtype) if isinstance(vals, np.ndarray) \
            else vals.dtype
        self.max_row_nnz = max(1, int(cols.shape[1]))
        if nnz is None:
            nnz = int(np.count_nonzero(np.asarray(vals)))
        self.nnz = int(nnz)

    @property
    def on_host(self) -> bool:
        return isinstance(self.vals, np.ndarray)

    def block(self, start: int, stop: int) -> SparseBlock:
        return SparseBlock(self.cols[start:stop], self.vals[start:stop],
                           self.shape[1])

    def logical(self):
        return self.block(0, self.shape[0]).todense()

    def nbytes(self) -> int:
        return int(self.cols.nbytes) + int(self.vals.nbytes)

    def transposed(self) -> "MatrixStore":
        return _SparseTransposed(self)

    def __repr__(self):
        tier = "host" if self.on_host else "device"
        return (f"SparseEllStore({self.shape[0]}x{self.shape[1]}, "
                f"kmax={self.max_row_nnz}, {tier})")


class _SparseTransposed(MatrixStore):
    """Zero-copy transpose handle over a sparse store.

    ``crossprod(X)`` transposes eagerly (FMMatrix.transpose →
    store.transposed) but the contraction path only ever peels the
    ``transposed_of`` handle back off — the wide orientation is never
    block-read.  So this wrapper exists to satisfy the protocol: shape
    flipped, ``transposed()`` returns the base store, and a partition read
    in the wide orientation (which would be column slicing) is refused.
    """

    layout = "col"
    sparse = False  # wide orientation: never a streaming source

    def __init__(self, base: MatrixStore):
        self.base = base
        self.shape = (base.shape[1], base.shape[0])
        self.dtype = base.dtype

    @property
    def on_host(self) -> bool:
        return self.base.on_host

    @property
    def on_disk(self) -> bool:
        return self.base.on_disk

    def block(self, start: int, stop: int):
        raise NotImplementedError(
            "column-sliced reads of a sparse CSR matrix are not supported; "
            "the transpose is consumed lazily (t(X) %*% Y peels it off)")

    def logical(self):
        return np.asarray(self.base.logical()).T

    def nbytes(self) -> int:
        return self.base.nbytes()

    def transposed(self) -> MatrixStore:
        return self.base
