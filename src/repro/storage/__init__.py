"""Disk-backed storage tier (the paper's SSD layer).

Three pieces, mirroring FlashR's external-memory stack:

  * `format`   — the on-disk single-file matrix format (.fmat): magic +
    shape/dtype/layout header, page-aligned row-contiguous body.
    `save_matrix` / `open_matrix` / `create_matrix`.
  * `store`    — `MmapStore`, the `core.matrix.MatrixStore` backend that
    serves I/O-level partitions straight from the file via np.memmap.
  * `prefetch` — `PartitionPrefetcher`, the double-buffered background
    stager that overlaps disk reads + host→device copies with compute.
  * `registry` — `fm.set.conf`-style data dir + named-matrix surface
    (`load_dense_matrix` / `get_dense_matrix` / `save_dense_matrix`).
"""
from . import format, prefetch, registry, store
from .format import (MatrixHeader, create_matrix, open_matrix, read_header,
                     save_matrix)
from .prefetch import (PartitionPrefetcher, PrefetchError, live_prefetchers,
                       negotiate_depth, stage_block, staged_leaks)
from .registry import (cleanup, get_conf, get_dense_matrix, list_matrices,
                       load_dense_matrix, save_dense_matrix, set_conf,
                       spill_path)
from .store import MmapStore

__all__ = [
    "format", "prefetch", "registry", "store",
    "MatrixHeader", "MmapStore", "PartitionPrefetcher", "PrefetchError",
    "cleanup", "create_matrix", "open_matrix", "read_header", "save_matrix",
    "get_conf", "get_dense_matrix", "list_matrices", "live_prefetchers",
    "load_dense_matrix", "negotiate_depth", "save_dense_matrix", "set_conf",
    "spill_path", "stage_block", "staged_leaks",
]
