"""Disk-backed storage tier (the paper's SSD layer).

Three pieces, mirroring FlashR's external-memory stack:

  * `format`   — the on-disk single-file matrix format (.fmat): magic +
    shape/dtype/layout header, page-aligned row-contiguous body.
    `save_matrix` / `open_matrix` / `create_matrix`.
  * `store`    — `MmapStore`, the `core.matrix.MatrixStore` backend that
    serves I/O-level partitions straight from the file via np.memmap.
  * `prefetch` — `PartitionPrefetcher`, the double-buffered background
    stager that overlaps disk reads + host→device copies with compute.
  * `registry` — `fm.set.conf`-style data dir + named-matrix surface
    (`load_dense_matrix` / `get_dense_matrix` / `save_dense_matrix`).
  * `sparse`   — the CSR variant of the .fmat container (ISSUE 10): row-
    partition-addressable indptr/indices/data sections served as ELL
    SparseBlocks (`CsrMmapStore`), plus the in-RAM `SparseEllStore` tier.
"""
from . import format, prefetch, registry, sparse, store
from .format import (MatrixHeader, create_matrix, open_matrix, peek_format,
                     read_header, save_matrix)
from .prefetch import (PartitionPrefetcher, PrefetchError, live_prefetchers,
                       negotiate_depth, stage_block, staged_leaks)
from .registry import (KNOWN_KNOBS, cleanup, conf, get_conf,
                       get_dense_matrix, list_matrices, load_dense_matrix,
                       load_factor_matrix, save_dense_matrix,
                       save_sparse_matrix, set_conf, spill_path)
from .sparse import (CsrMmapStore, SparseEllStore, open_csr, read_csr_meta,
                     save_csr_matrix)
from .store import MmapStore

__all__ = [
    "format", "prefetch", "registry", "sparse", "store",
    "CsrMmapStore", "KNOWN_KNOBS", "MatrixHeader", "MmapStore",
    "PartitionPrefetcher", "PrefetchError", "SparseEllStore",
    "cleanup", "conf", "create_matrix", "get_conf", "get_dense_matrix",
    "list_matrices", "live_prefetchers", "load_dense_matrix",
    "load_factor_matrix", "negotiate_depth", "open_csr", "open_matrix",
    "peek_format",
    "read_csr_meta", "read_header", "save_csr_matrix", "save_dense_matrix",
    "save_matrix", "save_sparse_matrix", "set_conf", "spill_path",
    "stage_block", "staged_leaks",
]
