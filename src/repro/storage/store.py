"""MmapStore — the disk tier behind the ``MatrixStore`` protocol.

An on-disk matrix (format.py) served through ``np.memmap``: opening is
O(1), ``block()`` touches only the partition's pages, and nothing forces
the whole matrix into RAM.  ``on_host`` is True (partitions must be staged
host→device, like the RAM tier) and ``on_disk`` distinguishes it for the
mode picker and the prefetcher.

Writable stores (``format.create_matrix``) are the spill targets of
``save='disk'`` outputs: the streaming executor calls ``write_rows`` per
partition (write-through), then ``flush``.
"""
from __future__ import annotations

import os
import pathlib
from typing import Optional

import numpy as np

from ..core.matrix import MatrixStore
from .format import MatrixHeader


class MmapStore(MatrixStore):
    """Disk-backed matrix store over a single ``.fmat`` file."""

    def __init__(self, path, header: MatrixHeader, *, mode: str = "r",
                 _mm: Optional[np.memmap] = None, _layout: Optional[str] = None):
        self.path = pathlib.Path(path)
        self.header = header
        self.mode = mode
        self.layout = _layout if _layout is not None else header.layout
        self._fd: Optional[int] = None  # fadvise handle (direct_io mode)
        if _mm is not None:
            self._mm = _mm
        else:
            self._mm = np.memmap(self.path, dtype=header.dtype, mode=mode,
                                 offset=header.body_offset,
                                 shape=header.stored_shape)

    # -- MatrixStore protocol -------------------------------------------------
    @property
    def on_host(self) -> bool:
        return True

    @property
    def on_disk(self) -> bool:
        return True

    def logical(self):
        """The full matrix in logical orientation, as a lazy memmap view —
        pages fault in only where actually read."""
        return self._mm.T if self.layout == "col" else self._mm

    def block(self, start: int, stop: int):
        if self._direct_io():
            # Cache-bypass mode: materialize the partition, then tell the
            # kernel to drop its pages so the next pass re-reads from the
            # device (cold-read benchmarking; fm.set_conf(direct_io=True)).
            if self.layout == "col":
                out = np.ascontiguousarray(self._mm[:, start:stop].T)
            else:
                out = np.array(self._mm[start:stop])
            self.drop_cache(start, stop)
            return out
        if self.layout == "col":
            return self._mm[:, start:stop].T
        return self._mm[start:stop]

    @staticmethod
    def _direct_io() -> bool:
        from . import registry  # deferred: registry imports core at load
        return bool(registry.get_conf("direct_io"))

    def drop_cache(self, start: Optional[int] = None,
                   stop: Optional[int] = None):
        """Best-effort page-cache eviction of logical rows [start, stop)
        (or the whole body) via ``posix_fadvise(DONTNEED)``.

        'col'-layout stores interleave every logical row across the file,
        so a row range degrades to dropping the whole body.  No-op on
        platforms without posix_fadvise (macOS)."""
        fadvise = getattr(os, "posix_fadvise", None)
        if fadvise is None or self._mm is None:  # pragma: no cover
            return
        h = self.header
        itemsize = np.dtype(h.dtype).itemsize
        if start is None or stop is None or self.layout == "col":
            offset, length = h.body_offset, self._mm.size * itemsize
        else:
            row_bytes = self._mm.shape[1] * itemsize
            offset = h.body_offset + start * row_bytes
            length = (stop - start) * row_bytes
        try:
            if self._fd is None:
                self._fd = os.open(self.path, os.O_RDONLY)
            os.posix_fadvise(self._fd, offset, length,
                             os.POSIX_FADV_DONTNEED)
        except OSError:  # pragma: no cover - best effort by design
            pass

    def nbytes(self) -> int:
        return int(self._mm.size) * self._mm.dtype.itemsize

    def transposed(self) -> "MmapStore":
        flipped = "col" if self.layout == "row" else "row"
        return MmapStore(self.path, self.header, mode=self.mode,
                         _mm=self._mm, _layout=flipped)

    # -- write-through spill ---------------------------------------------------
    @property
    def writable(self) -> bool:
        return self.mode in ("r+", "w+")

    def write_rows(self, start: int, arr: np.ndarray):
        """Write logical rows [start, start+len(arr)) — one partition of a
        long-dimension output streaming to disk."""
        if not self.writable:
            raise ValueError(f"{self.path} opened read-only")
        arr = np.asarray(arr)
        if self.layout == "col":
            self._mm[:, start:start + arr.shape[0]] = arr.T
        else:
            self._mm[start:start + arr.shape[0]] = arr

    def flush(self):
        if self.writable and self._mm is not None:
            self._mm.flush()

    def close(self):
        """Flush and drop the mapping (further reads fault).  Idempotent."""
        if self._fd is not None:
            try:
                os.close(self._fd)
            except OSError:  # pragma: no cover
                pass
            self._fd = None
        if self._mm is None:
            return
        self.flush()
        mm = getattr(self._mm, "_mmap", None)
        self._mm = None
        if mm is not None:
            mm.close()

    def __repr__(self):
        h = self.header
        return (f"MmapStore({h.nrow}x{h.ncol}, {np.dtype(h.dtype).name}, "
                f"layout={self.layout!r}, path={os.fspath(self.path)!r})")
