"""On-disk single-file matrix format (the FlashR external-memory matrix).

Layout of a ``.fmat`` file:

    [0, 8)              magic  b"FMATRIX1"
    [8, 12)             u32 little-endian format version (currently 1)
    [12, 16)            u32 little-endian length of the JSON header
    [16, 16+json_len)   JSON header: nrow, ncol, dtype (numpy ``.str``,
                        endianness-explicit), layout ('row'|'col'),
                        body_offset, row_align
    [.., HEADER_BYTES)  zero padding
    [HEADER_BYTES, ..)  body: the stored buffer, C-contiguous — shape
                        (nrow, ncol) for 'row' layout, (ncol, nrow) for
                        'col' (the zero-copy-transpose convention of
                        core.matrix.MatrixStore)

The body starts at a page-aligned offset (HEADER_BYTES = 4096) so
I/O-level partition reads are sector-aligned — the paper's "data well
aligned" requirement for SSD DMA, and what a future O_DIRECT path needs.
Rows inside the body are contiguous, so a partition read of rows
[start, stop) is one contiguous range of the file.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
from typing import Union

import numpy as np

MAGIC = b"FMATRIX1"
VERSION = 1
HEADER_BYTES = 4096

PathLike = Union[str, os.PathLike]


@dataclasses.dataclass(frozen=True)
class MatrixHeader:
    """Parsed header of an on-disk matrix."""

    nrow: int
    ncol: int
    dtype: np.dtype        # element dtype (endianness-explicit on disk)
    layout: str            # 'row' | 'col'
    body_offset: int = HEADER_BYTES
    row_align: int = 8     # core.matrix.ROW_ALIGN at write time

    @property
    def shape(self) -> tuple[int, int]:
        return (self.nrow, self.ncol)

    @property
    def stored_shape(self) -> tuple[int, int]:
        """Shape of the buffer as laid out in the file."""
        if self.layout == "col":
            return (self.ncol, self.nrow)
        return (self.nrow, self.ncol)

    def body_nbytes(self) -> int:
        return self.nrow * self.ncol * self.dtype.itemsize

    def to_bytes(self) -> bytes:
        payload = json.dumps({
            "nrow": self.nrow, "ncol": self.ncol,
            "dtype": np.dtype(self.dtype).str, "layout": self.layout,
            "body_offset": self.body_offset, "row_align": self.row_align,
        }).encode()
        head = (MAGIC + VERSION.to_bytes(4, "little")
                + len(payload).to_bytes(4, "little") + payload)
        if len(head) > self.body_offset:
            raise ValueError("header does not fit the reserved block")
        return head + b"\x00" * (self.body_offset - len(head))


def read_header(path: PathLike) -> MatrixHeader:
    with open(path, "rb") as f:
        fixed = f.read(16)
        if len(fixed) < 16 or fixed[:8] != MAGIC:
            raise ValueError(f"{path}: not an fmat file (bad magic)")
        version = int.from_bytes(fixed[8:12], "little")
        if version > VERSION:
            raise ValueError(f"{path}: fmat version {version} > {VERSION}")
        json_len = int.from_bytes(fixed[12:16], "little")
        meta = json.loads(f.read(json_len).decode())
    if meta.get("format", "dense") != "dense":
        raise ValueError(
            f"{path}: a {meta['format']!r} fmat file, not dense — open it "
            f"through storage.open_matrix (which dispatches on the format) "
            f"or storage.sparse.open_csr")
    if meta["layout"] not in ("row", "col"):
        raise ValueError(f"{path}: bad layout {meta['layout']!r}")
    return MatrixHeader(
        nrow=int(meta["nrow"]), ncol=int(meta["ncol"]),
        dtype=np.dtype(meta["dtype"]), layout=meta["layout"],
        body_offset=int(meta.get("body_offset", HEADER_BYTES)),
        row_align=int(meta.get("row_align", 8)))


def write_header(path: PathLike, header: MatrixHeader):
    """(Re)write the fixed-size header block in place — used by streaming
    ingest that learns the final nrow only after the body is written."""
    with open(path, "r+b") as f:
        f.write(header.to_bytes())


# ---------------------------------------------------------------------------
# Whole-matrix save / open / preallocate
# ---------------------------------------------------------------------------

def save_matrix(path: PathLike, arr, *, layout: str = "row",
                chunk_rows: int = 65536) -> MatrixHeader:
    """Write a matrix (numpy/jax array or physical FMMatrix) to ``path``.

    The body streams out in ``chunk_rows`` slabs so a host-RAM array never
    needs a second full-size copy; 1-D arrays become one-column matrices
    (the engine-wide vector convention).
    """
    if layout not in ("row", "col"):
        raise ValueError(f"bad layout {layout!r}")
    if hasattr(arr, "logical_data"):          # FMMatrix duck-type
        arr = arr.logical_data()
    arr = np.asarray(arr)
    if arr.ndim == 1:
        arr = arr.reshape(-1, 1)
    if arr.ndim != 2:
        raise ValueError(f"expected a matrix, got ndim={arr.ndim}")
    header = MatrixHeader(nrow=arr.shape[0], ncol=arr.shape[1],
                          dtype=np.dtype(arr.dtype), layout=layout)
    stored = arr.T if layout == "col" else arr
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(header.to_bytes())
        for start in range(0, stored.shape[0], chunk_rows):
            f.write(np.ascontiguousarray(stored[start:start + chunk_rows]))
    return header


def peek_format(path: PathLike) -> str:
    """The container variant of an ``.fmat`` file: 'dense' or 'csr'.
    Reads only the header block."""
    with open(path, "rb") as f:
        fixed = f.read(16)
        if len(fixed) < 16 or fixed[:8] != MAGIC:
            raise ValueError(f"{path}: not an fmat file (bad magic)")
        json_len = int.from_bytes(fixed[12:16], "little")
        meta = json.loads(f.read(json_len).decode())
    return meta.get("format", "dense")


def open_matrix(path: PathLike, *, mode: str = "r"):
    """Open an on-disk matrix (no data is read): an ``MmapStore`` for the
    dense format, a ``CsrMmapStore`` for the sparse CSR variant."""
    from .store import MmapStore
    if peek_format(path) == "csr":
        from .sparse import open_csr
        return open_csr(path)
    return MmapStore(path, read_header(path), mode=mode)


def create_matrix(path: PathLike, shape, dtype, *, layout: str = "row"):
    """Preallocate an on-disk matrix and return a *writable* ``MmapStore``
    — the spill target for ``save='disk'`` outputs (write-through)."""
    from .store import MmapStore
    header = MatrixHeader(nrow=int(shape[0]), ncol=int(shape[1]),
                          dtype=np.dtype(dtype), layout=layout)
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(header.to_bytes())
        f.truncate(header.body_offset + header.body_nbytes())
    return MmapStore(path, header, mode="r+")
