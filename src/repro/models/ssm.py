"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

The chunked SSD algorithm is FlashMatrix's two-level partitioning in
disguise (DESIGN.md §3): the sequence splits into chunks (I/O-level
partitions); within a chunk the quadratic "attention-like" term runs on a
VMEM-resident (L, L) tile, and across chunks a tiny (H, P, N) state carries
the recurrence — identity → update → combine, like every GenOps sink.

Shapes (per layer): d_inner = expand·d_model, P = headdim,
H = d_inner / P heads, N = ssm_state, G = ngroups (B/C shared per group).

    in_proj : d_model -> [z (d_inner), x (d_inner), B (G·N), C (G·N), dt (H)]
    conv1d  : depthwise width-4 over the (x, B, C) channels
    SSD     : y_t = Σ_{s≤t} C_tᵀ (∏_{r=s+1..t} a_r) B_s (dt_s x_s)  + D·x_t
    out     : gated RMSNorm(y, z) -> out_proj

Decode is the O(1) recurrence: S ← a·S + dt·(B ⊗ x);  y = C·S + D·x, with a
rolling width-(conv-1) convolution state.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from .base import param

CHUNK = 128  # SSD chunk length (the sequence-tier partition)


def dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_headdim
    return d_in, H, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_ngroups


def init_ssm(cfg, keys) -> dict:
    d = cfg.d_model
    d_in, H, P, N, G = dims(cfg)
    conv_ch = d_in + 2 * G * N
    return {
        "in_proj": param(next(keys), (d, 2 * d_in + 2 * G * N + H),
                         ("d_model", "d_inner")),
        "conv_w": param(next(keys), (cfg.ssm_conv, conv_ch), ("conv", "d_inner"),
                        scale=cfg.ssm_conv ** -0.5),
        "conv_b": param(next(keys), (conv_ch,), ("d_inner",), init="zeros"),
        "A_log": param(next(keys), (H,), ("heads",), init="zeros"),
        "dt_bias": param(next(keys), (H,), ("heads",), init="zeros"),
        "D": param(next(keys), (H,), ("heads",), init="ones"),
        "norm": param(next(keys), (d_in,), ("d_inner",), init="ones"),
        "out_proj": param(next(keys), (d_in, d), ("d_inner", "d_model")),
    }


def _split(cfg, zxbcdt):
    d_in, H, P, N, G = dims(cfg)
    z, x, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + G * N, 2 * d_in + 2 * G * N], axis=-1)
    return z, x, Bc, Cc, dt


def _gated_norm(cfg, w, y, z):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = (yf * yf).mean(-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + cfg.rms_eps)
            * w.astype(jnp.float32)).astype(y.dtype)


def _conv_full(x, w, b):
    """Causal depthwise conv over (B, S, C) with width-K taps (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return jax.nn.silu((out + b[None, None, :]).astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(xh, dt, a_log, Bc, Cc, chunk: int = CHUNK,
                init_state=None):
    """Chunked SSD: lax.scan over sequence chunks.

    xh (B,S,H,P) dt (B,S,H) positive; a = exp(-dt·exp(a_log));
    Bc/Cc (B,S,G,N).  Returns (y (B,S,H,P), final_state (B,H,P,N)).

    One chunk at a time (carry = the (H,P,N) state): the quadratic
    intra-chunk tile (L, L) exists only per step — the two-level
    partitioning discipline; a vectorized-over-chunks version materializes
    (B, nc, H, L, L) score/decay tensors (observed: 61 GiB/device on
    mamba2 train_4k).
    """
    Bsz, S, H, P = xh.shape
    G, N = Bc.shape[2], Bc.shape[3]
    rep = H // G
    nc = S // chunk
    f32 = jnp.float32

    loga = (-dt.astype(f32) * jnp.exp(a_log.astype(f32))[None, None, :])
    xw = xh.astype(f32) * dt[..., None].astype(f32)      # dt-weighted input

    def chunked(t):
        # (B, S, ...) -> (nc, B, L, ...): chunk axis leads for scan
        return t.reshape((Bsz, nc, chunk) + t.shape[2:]).swapaxes(0, 1)

    xs = (chunked(loga), chunked(xw), chunked(Bc.astype(f32)),
          chunked(Cc.astype(f32)))
    s0 = (jnp.zeros((Bsz, H, P, N), f32) if init_state is None
          else init_state.astype(f32))
    ids = jnp.arange(chunk)
    causal = (ids[:, None] >= ids[None, :])[None, None]  # (1,1,L,L)

    def chunk_step(state, inp):
        loga_c, x_c, B_c, C_c = inp                      # (B,L,·)
        cum = jnp.cumsum(loga_c, axis=1)                 # (B,L,H)
        Bh = jnp.repeat(B_c, rep, axis=2)                # (B,L,H,N)
        Ch = jnp.repeat(C_c, rep, axis=2)

        # Intra-chunk (the VMEM-tile term): y_t += C_t·B_s decay(s→t) x_s
        scores = jnp.einsum("blhn,bmhn->bhlm", Ch, Bh)   # (B,H,L,L)
        cum_t = cum.transpose(0, 2, 1)                   # (B,H,L)
        decay = jnp.exp(cum_t[:, :, :, None] - cum_t[:, :, None, :])
        att = jnp.where(causal, scores * decay, 0.0)
        y = jnp.einsum("bhlm,bmhp->blhp", att, x_c)

        # Inter-chunk: y_t += C_t decay(start→t) S_prev
        dec_in = jnp.exp(cum)                            # (B,L,H)
        y = y + jnp.einsum("blhn,bhpn,blh->blhp", Ch, state, dec_in)

        # State update (the sink-combine step)
        dec_end = jnp.exp(cum[:, -1:, :] - cum)          # (B,L,H)
        new_state = state * jnp.exp(cum[:, -1])[:, :, None, None] \
            + jnp.einsum("blhn,blhp,blh->bhpn", Bh, x_c, dec_end)
        return new_state, y

    final, ys = jax.lax.scan(chunk_step, s0, xs)         # ys: (nc,B,L,H,P)
    y = ys.swapaxes(0, 1).reshape(Bsz, S, H, P)
    return y, final


def apply_ssm(cfg, p, x, *, init_state=None):
    """Full-sequence Mamba-2 mixer. x: (B, S, d) -> (B, S, d)."""
    d_in, H, P, N, G = dims(cfg)
    B_, S, _ = x.shape
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xr, Bc, Cc, dt = _split(cfg, zxbcdt)
    conv_in = jnp.concatenate([xr, Bc, Cc], axis=-1)
    conv_out = _conv_full(conv_in, p["conv_w"].astype(x.dtype),
                          p["conv_b"].astype(x.dtype))
    xr, Bc, Cc = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)
    dtp = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))

    pad = (-S) % CHUNK
    if pad:
        padf = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        xr_, dtp_, Bc_, Cc_ = padf(xr), padf(dtp), padf(Bc), padf(Cc)
    else:
        xr_, dtp_, Bc_, Cc_ = xr, dtp, Bc, Cc

    xh = xr_.reshape(B_, -1, H, P)
    y, state = ssd_chunked(xh, dtp_, p["A_log"], xh_bc(Bc_, G, N), xh_bc(Cc_, G, N),
                           init_state=init_state)
    y = y[:, :S]
    y = y + xr.reshape(B_, S, H, P) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B_, S, d_in).astype(x.dtype)
    y = hint(y, "batch|seq|act_inner")
    y = _gated_norm(cfg, p["norm"], y, z)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype)), state


def xh_bc(t, G, N):
    return t.reshape(t.shape[0], t.shape[1], G, N)


# ---------------------------------------------------------------------------
# Decode (O(1) state recurrence)
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg, batch: int, dtype) -> dict:
    d_in, H, P, N, G = dims(cfg)
    conv_ch = d_in + 2 * G * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
    }


SSM_CACHE_AXES = {"conv": "batch|seq|d_inner", "state": "batch|heads|head_dim|state"}


def apply_ssm_decode(cfg, p, x, cache):
    """One-token step. x: (B, 1, d)."""
    d_in, H, P, N, G = dims(cfg)
    B_ = x.shape[0]
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xr, Bc, Cc, dt = _split(cfg, zxbcdt)
    conv_in = jnp.concatenate([xr, Bc, Cc], axis=-1)      # (B,1,C)
    window = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B,K,C)
    w = p["conv_w"].astype(x.dtype)
    conv_out = (window * w[None]).sum(axis=1, keepdims=True) \
        + p["conv_b"].astype(x.dtype)[None, None]
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xr, Bc, Cc = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)

    dtp = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))      # (B,H)
    a = jnp.exp(-dtp * jnp.exp(p["A_log"].astype(jnp.float32)))    # (B,H)
    xh = xr.reshape(B_, H, P).astype(jnp.float32) * dtp[..., None]
    Bh = jnp.repeat(Bc.reshape(B_, G, N), H // G, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cc.reshape(B_, G, N), H // G, axis=1).astype(jnp.float32)

    state = cache["state"] * a[:, :, None, None] \
        + jnp.einsum("bhp,bhn->bhpn", xh, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + xr.reshape(B_, H, P).astype(jnp.float32) \
        * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B_, 1, d_in).astype(x.dtype)
    y = _gated_norm(cfg, p["norm"], y, z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, {"conv": window[:, 1:], "state": state}
