"""Attention-free Mamba-2 LM (mamba2-1.3b family).

Pre-norm residual SSM blocks, scan-over-layers.  Decode is O(1) per token
(rolling conv window + (H, P, N) state), which is why this family runs the
``long_500k`` cell that full-attention architectures must skip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from . import layers as L
from . import ssm as S
from ..distributed import sharding as shd
from .base import axes_of, keygen, stack_layers


def _blk_axes(cfg):
    return axes_of(lambda k: _block_init(cfg, keygen(k)), jax.random.PRNGKey(0))


def _block_init(cfg, keys):
    return {"ln": L.init_norm(cfg, next(keys)), "ssm": S.init_ssm(cfg, keys)}


def init(cfg, key):
    keys = keygen(key)
    return {
        "embed": L.init_embed(cfg, keys),
        "layers": stack_layers([_block_init(cfg, keys)
                                for _ in range(cfg.n_layers)]),
        "final_norm": L.init_norm(cfg, next(keys)),
    }


def _block(cfg, blk, x):
    y, state = S.apply_ssm(cfg, blk["ssm"], L.apply_norm(cfg, blk["ln"], x))
    return x + y, state


def forward(cfg, params, batch):
    tokens = batch["tokens"]
    x = L.embed_tokens(cfg, params["embed"], tokens)
    x = hint(x, "batch|seq|embed")

    body = functools.partial(_block, cfg)
    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    blk_axes = _blk_axes(cfg)
    carry_ax = "batch|act_seq|embed" if cfg.seq_parallel else "batch|seq|embed"

    def step(x, blk):
        x, _ = body(shd.hint_tree(blk, blk_axes), x)
        return shd.hint(x, carry_ax), None

    x, _ = jax.lax.scan(step, x, params["layers"])
    h = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.logits_out(cfg, params["embed"], h)
    loss = L.xent_loss(logits, batch["labels"])
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    del max_len  # state is O(1); max_len irrelevant (the long_500k win)
    one = S.init_ssm_cache(cfg, batch, jnp.dtype(cfg.dtype))
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype), one)
    return {"ssm": stacked, "len": jnp.zeros((), jnp.int32)}


def cache_axes(cfg):
    return {"ssm": {k: "layers|" + v for k, v in S.SSM_CACHE_AXES.items()},
            "len": ""}


def prefill(cfg, params, tokens, max_len: int):
    """Full-sequence scan; emits per-layer final SSM state + conv tail."""
    del max_len
    x = L.embed_tokens(cfg, params["embed"], tokens)
    B, Sq = tokens.shape

    blk_axes = _blk_axes(cfg)

    def step(x, blk):
        blk = shd.hint_tree(blk, blk_axes)
        h = L.apply_norm(cfg, blk["ln"], x)
        y, state = S.apply_ssm(cfg, blk["ssm"], h)
        # rolling conv window: last (K-1) pre-activation conv inputs
        d_in, H, P, N, G = S.dims(cfg)
        zxbcdt = jnp.einsum("bsd,de->bse", h,
                            blk["ssm"]["in_proj"].astype(h.dtype))
        _, xr, Bc, Cc, _ = S._split(cfg, zxbcdt)
        conv_in = jnp.concatenate([xr, Bc, Cc], axis=-1)
        window = conv_in[:, -(cfg.ssm_conv - 1):]
        return x + y, {"conv": window.astype(jnp.dtype(cfg.dtype)),
                       "state": state}

    x, cache = jax.lax.scan(step, x, params["layers"])
    h = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
    logits = L.logits_out(cfg, params["embed"], h)
    return {"ssm": cache, "len": jnp.asarray(Sq, jnp.int32)}, logits


def decode(cfg, params, cache, token):
    x = L.embed_tokens(cfg, params["embed"], token)

    blk_axes = _blk_axes(cfg)

    def step(x, inp):
        blk, c = inp
        blk = shd.hint_tree(blk, blk_axes)
        y, c = S.apply_ssm_decode(cfg, blk["ssm"],
                                  L.apply_norm(cfg, blk["ln"], x), c)
        return x + y, c

    x, new_cache = jax.lax.scan(step, x, (params["layers"], cache["ssm"]))
    h = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.logits_out(cfg, params["embed"], h)
    return {"ssm": new_cache, "len": cache["len"] + 1}, logits
