"""Transformer building blocks: norms, RoPE, GQA attention, GLU MLPs.

Functional style over boxed param trees (models/base.py).  Activation
sharding hints use the logical-axis resolver so the same code lowers on a
laptop (no mesh) and on the 512-chip production mesh.

Attention supports:
  * train/prefill (full-sequence, causal or bidirectional),
  * cross-attention (whisper decoder),
  * single-token decode against a static-length KV cache
    (dynamic_update_slice write + length-masked read — the serve_step path).

GQA is computed grouped (no KV repeat materialization): q reshaped to
(B, kv, group, S, hd) so score/attn einsums contract per KV head.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from .base import Boxed, param

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg, key) -> dict:
    if cfg.norm == "layernorm":
        return {"scale": param(key, (cfg.d_model,), ("embed",), init="ones"),
                "bias": param(key, (cfg.d_model,), ("embed",), init="zeros")}
    return {"scale": param(key, (cfg.d_model,), ("embed",), init="ones")}


def apply_norm(cfg, p, x):
    xf = x.astype(jnp.float32)
    if "bias" in p:
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + cfg.rms_eps)
        out = out * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)


def sinusoidal(positions, d: int):
    """Classic sin/cos absolute encodings: positions (...,S) -> (...,S,d).

    Whisper's learned positions are replaced by sinusoids (stub-friendly:
    no max-length parameter; noted in DESIGN.md as a frontend deviation)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(cfg, keys, *, d_model: Optional[int] = None) -> dict:
    d = d_model or cfg.d_model
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": param(next(keys), (d, nh * hd), ("d_model", "heads")),
        "wk": param(next(keys), (d, nkv * hd), ("d_model", "kv_heads")),
        "wv": param(next(keys), (d, nkv * hd), ("d_model", "kv_heads")),
        "wo": param(next(keys), (nh * hd, d), ("heads", "d_model")),
    }
    if cfg.qkv_bias:
        p["bq"] = param(next(keys), (nh * hd,), ("heads",), init="zeros")
        p["bk"] = param(next(keys), (nkv * hd,), ("kv_heads",), init="zeros")
        p["bv"] = param(next(keys), (nkv * hd,), ("kv_heads",), init="zeros")
    return p


def _proj(x, w, b=None):
    y = jnp.einsum("bsd,df->bsf", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def _qkv(cfg, p, x, xkv=None):
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xkv = x if xkv is None else xkv
    B, S = x.shape[:2]
    T = xkv.shape[1]
    q = _proj(x, p["wq"], p.get("bq")).reshape(B, S, nh, hd)
    k = _proj(xkv, p["wk"], p.get("bk")).reshape(B, T, nkv, hd)
    v = _proj(xkv, p["wv"], p.get("bv")).reshape(B, T, nkv, hd)
    return q, k, v


def _grouped_attention(q, k, v, mask):
    """q: (B,S,nh,hd), k/v: (B,T,nkv,hd), mask broadcastable to (B,1,1,S,T).

    Computed per KV-head group to avoid materializing repeated KV."""
    B, S, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    qg = q.reshape(B, S, nkv, g, hd).transpose(0, 2, 3, 1, 4)  # (B,kv,g,S,hd)
    kg = k.transpose(0, 2, 1, 3)                               # (B,kv,T,hd)
    vg = v.transpose(0, 2, 1, 3)
    # bf16 operands + f32 accumulation (preferred_element_type): the MXU
    # pattern, and it stops XLA-CPU hoisting whole-cache f32 upcasts out of
    # the decode layer scan (observed: +20 GiB on qwen2-72b decode_32k).
    scores = jnp.einsum("bngsd,bntd->bngst", qg, kg,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngst,bntd->bngsd", w.astype(v.dtype), vg)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, nh * hd)


def causal_mask(positions_q, positions_k):
    """(B,S),(B,T) -> (B,1,1,S,T) bool."""
    return (positions_q[:, None, None, :, None]
            >= positions_k[:, None, None, None, :])


# Sequence-parallel flash attention (online softmax, custom VJP).
#
# The (S, T) score matrix exists only as (S_local, bk) tiles: the KV axis is
# scanned (memory control), while the q/sequence axis is SHARDED over the
# `model` mesh axis — matching the seq-parallel residual stream, so q and
# the output never cross devices; only K/V are gathered (bf16, the cheap
# operand).  This replaced a two-level q/kv chunk scan whose per-chunk
# reshapes fought the act_seq sharding (EXPERIMENTS.md §Perf iteration 1:
# 3953 -> ~50 GiB of all-gathers per step on llama3.2-3b train_4k), and it
# also de-replicates attention compute for head counts that don't divide
# the model axis (qwen2-0.5b's 14 heads, paligemma's 8).
#
# The backward is the flash-attention recompute scheme (saved (out, lse)
# only) — O(S·d) residency, no stored probability tiles.
_BLOCKWISE_THRESHOLD = 2048  # S·T above which scores must not materialize


def _group(q):
    B, S, nh, hd = q.shape
    return q.transpose(0, 2, 1, 3), (B, S, nh, hd)


def _flash_fwd_scan(q, k, v, q_pos, k_pos, causal, bk):
    """q: (B,S,nh,hd) seq-sharded; k/v: (B,T,nkv,hd) replicated-on-model.

    Returns grouped out (B,nkv,g,S,hd) f32-normalized in q.dtype + lse."""
    B, S, nh, hd = q.shape
    T, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    f32 = jnp.float32
    bk = min(bk, T)
    pad_k = (-T) % bk
    qg = q.reshape(B, S, nkv, g, hd).transpose(0, 2, 3, 1, 4)  # (B,kv,g,S,hd)
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kpos = jnp.pad(k_pos, ((0, 0), (0, pad_k)), constant_values=-1)
    nk = kp.shape[1] // bk
    kc = kp.reshape(B, nk, bk, nkv, hd).transpose(1, 0, 3, 2, 4)
    vc = vp.reshape(B, nk, bk, nkv, hd).transpose(1, 0, 3, 2, 4)
    kpc = kpos.reshape(B, nk, bk).transpose(1, 0, 2)
    scale = hd ** -0.5

    m0 = hint(jnp.full((B, nkv, g, S), -1e30, f32), "batch|rep|rep|act_seq")
    l0 = hint(jnp.zeros((B, nkv, g, S), f32), "batch|rep|rep|act_seq")
    a0 = hint(jnp.zeros((B, nkv, g, S, hd), f32),
              "batch|rep|rep|act_seq|head_dim")

    def kv_step(carry, kv_in):
        m, l, acc = carry
        kb, vb, kpb = kv_in                                   # (B,kv,bk,hd)
        s = jnp.einsum("bngsd,bntd->bngst", qg.astype(f32),
                       kb.astype(f32)) * scale
        mask = (kpb >= 0)[:, None, None, None, :]
        if causal:
            mask = mask & (q_pos[:, None, None, :, None]
                           >= kpb[:, None, None, None, :])
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bngst,bntd->bngsd", p, vb.astype(f32))
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc, vc, kpc))
    lsafe = jnp.maximum(l, 1e-30)
    out = (acc / lsafe[..., None]).astype(q.dtype)            # (B,kv,g,S,hd)
    lse = m + jnp.log(lsafe)
    return out, lse


def _unflatten_out(out, B, S, nh, hd):
    # (B,kv,g,S,hd) -> (B,S,nh*hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, nh * hd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _blockwise_attention_vjp(causal, bk, q, k, v, q_pos, k_pos):
    out, _ = _flash_fwd_scan(q, k, v, q_pos, k_pos, causal, bk)
    B, S, nh, hd = q.shape
    return _unflatten_out(out, B, S, nh, hd)


def _bw_fwd(causal, bk, q, k, v, q_pos, k_pos):
    out, lse = _flash_fwd_scan(q, k, v, q_pos, k_pos, causal, bk)
    B, S, nh, hd = q.shape
    return (_unflatten_out(out, B, S, nh, hd),
            (q, k, v, q_pos, k_pos, out, lse))


def _bw_bwd(causal, bk, res, dout):
    """Flash backward: recompute (S, bk) probability tiles per kv chunk from
    the saved (out, lse).  dq stays seq-sharded (carry); dk/dv emit per
    chunk."""
    q, k, v, q_pos, k_pos, out_g, lse = res
    B, S, nh, hd = q.shape
    T, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    f32 = jnp.float32
    scale = hd ** -0.5
    bk = min(bk, T)
    pad_k = (-T) % bk
    nk = (T + pad_k) // bk

    dog = dout.reshape(B, S, nkv, g, hd).transpose(0, 2, 3, 1, 4).astype(f32)
    D = (dog * out_g.astype(f32)).sum(-1)                     # (B,kv,g,S)
    qg = q.reshape(B, S, nkv, g, hd).transpose(0, 2, 3, 1, 4).astype(f32)

    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kpos = jnp.pad(k_pos, ((0, 0), (0, pad_k)), constant_values=-1)
    kc = kp.reshape(B, nk, bk, nkv, hd).transpose(1, 0, 3, 2, 4).astype(f32)
    vc = vp.reshape(B, nk, bk, nkv, hd).transpose(1, 0, 3, 2, 4).astype(f32)
    kpc = kpos.reshape(B, nk, bk).transpose(1, 0, 2)

    dq0 = hint(jnp.zeros((B, nkv, g, S, hd), f32),
               "batch|rep|rep|act_seq|head_dim")

    def kv_step(dq, kv_in):
        kb, vb, kpb = kv_in
        s = jnp.einsum("bngsd,bntd->bngst", qg, kb) * scale
        mask = (kpb >= 0)[:, None, None, None, :]
        if causal:
            mask = mask & (q_pos[:, None, None, :, None]
                           >= kpb[:, None, None, None, :])
        p = jnp.where(mask, jnp.exp(s - lse[..., None]), 0.0)
        dvj = jnp.einsum("bngst,bngsd->bntd", p, dog)
        dp = jnp.einsum("bngsd,bntd->bngst", dog, vb)
        ds = p * (dp - D[..., None]) * scale
        dq = dq + jnp.einsum("bngst,bntd->bngsd", ds, kb)
        dkj = jnp.einsum("bngst,bngsd->bntd", ds, qg)
        return dq, (dkj, dvj)

    dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, (kc, vc, kpc))
    dq = dq.transpose(0, 3, 1, 2, 4).reshape(B, S, nh, hd)
    dk = dks.transpose(1, 0, 3, 2, 4).reshape(B, nk * bk, nkv, hd)[:, :T]
    dv = dvs.transpose(1, 0, 3, 2, 4).reshape(B, nk * bk, nkv, hd)[:, :T]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


_blockwise_attention_vjp.defvjp(_bw_fwd, _bw_bwd)


def blockwise_attention(q, k, v, q_pos, k_pos, causal, bk: int = 1024):
    """Public seq-parallel flash attention:
    (B,S,nh,hd)×(B,T,nkv,hd) -> (B,S,nh*hd), O(S·d·bk-tile) memory in fwd
    AND bwd (custom flash-style VJP), q/out seq-sharded, K/V gathered."""
    q = hint(q, "batch|act_seq|rep|head_dim")
    k = hint(k, "batch|rep|rep|head_dim")
    v = hint(v, "batch|rep|rep|head_dim")
    return _blockwise_attention_vjp(causal, bk, q, k, v, q_pos, k_pos)


def apply_attention(cfg, p, x, positions, *, causal=True, use_rope=True,
                    xkv=None, kv_positions=None):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    q, k, v = _qkv(cfg, p, x, xkv)
    kv_positions = positions if kv_positions is None else kv_positions
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    S, T = q.shape[1], k.shape[1]
    if S * T > _BLOCKWISE_THRESHOLD ** 2:
        out = blockwise_attention(q, k, v, positions, kv_positions, causal)
    else:
        q = hint(q, "batch|seq|act_heads|head_dim")
        if causal:
            mask = causal_mask(positions, kv_positions)
        else:
            mask = jnp.ones((1, 1, 1, 1, 1), bool)
        out = _grouped_attention(q, k, v, mask)
    out = jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(out.dtype))
    return hint(out, "batch|act_seq|embed"), (k, v)


def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    nkv, hd = cfg.n_kv_heads, cfg.hd
    shape = (batch, max_len, nkv, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


KV_CACHE_AXES = {"k": "batch|kv_seq|kv_heads|head_dim",
                 "v": "batch|kv_seq|kv_heads|head_dim"}


def apply_attention_decode(cfg, p, x, cache: dict, cur_len, *, use_rope=True):
    """One-token decode: x is (B, 1, d); cache holds (B, T, nkv, hd).

    ``cur_len`` (scalar int32) is the number of valid positions already in
    the cache; the new token writes at index cur_len and attends over
    [0, cur_len].
    """
    B = x.shape[0]
    pos = jnp.full((B, 1), cur_len, jnp.int32)
    q, k_new, v_new = _qkv(cfg, p, x)
    if use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k_new = rope(k_new, pos, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype),
                                            cur_len, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype),
                                            cur_len, axis=1)
    k = hint(k, "batch|kv_seq|kv_heads|head_dim")
    v = hint(v, "batch|kv_seq|kv_heads|head_dim")
    T = k.shape[1]
    kpos = jnp.arange(T, dtype=jnp.int32)[None, :].repeat(B, 0)
    mask = (kpos <= cur_len)[:, None, None, None, :]
    out = _grouped_attention(q, k, v, mask)
    out = jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(out.dtype))
    return out, {"k": k, "v": v}


def apply_cross_attention_decode(cfg, p, x, cross_k, cross_v):
    """Decode-time cross-attention over precomputed encoder KV."""
    B = x.shape[0]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = _proj(x, p["wq"], p.get("bq")).reshape(B, 1, nh, hd)
    mask = jnp.ones((1, 1, 1, 1, 1), bool)
    out = _grouped_attention(q, cross_k, cross_v, mask)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(out.dtype))


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg, keys, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    p = {"wi": param(next(keys), (d, f), ("d_model", "d_ff")),
         "wo": param(next(keys), (f, d), ("d_ff", "d_model"))}
    if cfg.act in ("swiglu", "geglu"):
        p["wg"] = param(next(keys), (d, f), ("d_model", "d_ff"))
    return p


def apply_mlp(cfg, p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    elif cfg.act == "geglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        h = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = hint(h, "batch|seq|act_ff")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------

def init_embed(cfg, keys) -> dict:
    p = {"tok": param(next(keys), (cfg.vocab, cfg.d_model),
                      ("vocab", "d_model"), init="embed")}
    if not cfg.tie_embeddings:
        p["out"] = param(next(keys), (cfg.d_model, cfg.vocab),
                         ("d_model", "vocab"))
    return p


def embed_tokens(cfg, p, tokens):
    emb = p["tok"].astype(_dt(cfg))[tokens]
    if cfg.tie_embeddings:
        emb = emb * (cfg.d_model ** 0.5)  # gemma-style scaled tied embedding
    return hint(emb, "batch|seq|embed")


def logits_out(cfg, p, x):
    if cfg.tie_embeddings:
        w = p["tok"].astype(x.dtype)
        return jnp.einsum("bsd,vd->bsv", x, w)
    return jnp.einsum("bsd,dv->bsv", x, p["out"].astype(x.dtype))


def xent_loss(logits, labels, mask=None):
    """Stable cross-entropy; logits (B,S,V) any float dtype, labels int."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def _dt(cfg):
    return jnp.dtype(cfg.dtype)
