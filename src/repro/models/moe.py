"""Mixture-of-Experts layer: token-choice top-k with capacity, scatter
dispatch and segment-sum combine.

DESIGN.md §1.4: the combine path IS the paper's `fm.groupby.row` — tokens
scatter-add into per-expert buffers keyed by routing labels, the exact
segment-sum core of the GenOps engine.  Dispatch is GShard-style
capacity-bounded (position-in-expert via cumsum; overflow tokens drop and
keep the residual), which keeps every shape static for jit while sharding
cleanly: expert buffers (E, C, d) shard E over `model`, token activations
shard over `data`, and GSPMD turns the scatter/gather pair into the
all-to-all pattern the roofline parser then prices.

Arctic's dense-residual variant runs a dense MLP in parallel and sums.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from .base import param
from .layers import apply_mlp, init_mlp


def init_moe(cfg, keys) -> dict:
    d, f, e = cfg.d_model, cfg.moe_ff, cfg.n_experts
    glu = cfg.act in ("swiglu", "geglu")
    p = {
        "router": param(next(keys), (d, e), ("d_model", "experts")),
        "wi": param(next(keys), (e, d, f), ("experts", "d_model", "d_ff")),
        "wo": param(next(keys), (e, f, d), ("experts", "d_ff", "d_model")),
    }
    if glu:
        p["wg"] = param(next(keys), (e, d, f), ("experts", "d_model", "d_ff"))
    if cfg.dense_residual:
        p["dense"] = init_mlp(cfg, keys, cfg.d_ff)
    return p


def _capacity(cfg, tokens: int) -> int:
    # Small token counts (decode steps, smoke tests) run DROPLESS: capacity
    # covers the worst case, so decode routing is exactly consistent with
    # the full forward pass.  Large counts use GShard capacity bounding.
    if tokens * cfg.top_k <= 4096:
        return max(8, -(-tokens * cfg.top_k // 8) * 8)
    cap = int(cfg.capacity_factor * tokens * cfg.top_k / cfg.n_experts)
    return max(8, -(-cap // 8) * 8)  # round up to sublane multiple


def apply_moe(cfg, p, x):
    """x: (B, S, d) -> (B, S, d).

    Dispatch is *per batch row*: each row owns an (E, C_b, d) expert buffer
    with per-row capacity C_b, so the buffer tensor is (B, E, C_b, d) and
    shards (batch→data, experts→model) — expert FFN matmuls stay local to
    their expert shard (the flat (E, C_global, d) formulation made GSPMD
    replicate the FFN across the model axis: 16× the dot FLOPs, see
    EXPERIMENTS.md §Perf iteration 2).  Combine is the inverse slot-scatter
    (a batched `fm.groupby.row` — DESIGN.md §1.4), which reduces across
    expert shards as a psum of the (B, S, d) output rather than a gather of
    the much larger expert buffers.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(cfg, S)                             # per-row capacity

    # --- route (per row) -----------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)           # (B, S, E)
    weights, sel = jax.lax.top_k(gates, k)            # (B, S, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # --- per-row capacity positions (GShard cumsum) --------------------------
    sel_flat = sel.reshape(B, S * k)
    onehot = jax.nn.one_hot(sel_flat, E, dtype=jnp.int32)       # (B, S*k, E)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.take_along_axis(pos_all, sel_flat[..., None], 2)[..., 0]
    keep = pos < C                                    # (B, S*k)

    tok_idx = (jnp.arange(S * k, dtype=jnp.int32) // k)          # static
    vals = jnp.repeat(x, k, axis=1)                   # (B, S*k, d)
    vals = jnp.where(keep[..., None], vals, 0)
    e_idx = jnp.where(keep, sel_flat, E)              # OOB -> dropped
    p_idx = jnp.where(keep, pos, C)
    w_flat = (weights.reshape(B, S * k) * keep).astype(x.dtype)

    # --- dispatch: per-row scatter into (E, C, d) ----------------------------
    def row_dispatch(v_r, e_r, p_r, w_r):
        buf = jnp.zeros((E, C, d), x.dtype).at[e_r, p_r].add(v_r, mode="drop")
        slot_tok = jnp.full((E, C), S, jnp.int32).at[e_r, p_r].set(
            tok_idx, mode="drop")                     # S = OOB sentinel
        slot_w = jnp.zeros((E, C), x.dtype).at[e_r, p_r].set(w_r, mode="drop")
        return buf, slot_tok, slot_w

    buf, slot_tok, slot_w = jax.vmap(row_dispatch)(vals, e_idx, p_idx, w_flat)
    buf = hint(buf, "batch|experts|capacity|embed")

    # --- expert FFN (E sharded over `model`, B over `data`) ------------------
    h = jnp.einsum("becd,edf->becf", buf, p["wi"].astype(x.dtype))
    if "wg" in p:
        g = jnp.einsum("becd,edf->becf", buf, p["wg"].astype(x.dtype))
        act = (jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu)
        h = act(g.astype(jnp.float32)).astype(x.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    out_buf = jnp.einsum("becf,efd->becd", h, p["wo"].astype(x.dtype))
    out_buf = hint(out_buf, "batch|experts|capacity|embed")

    # --- combine: slot-scatter back to tokens (groupby.row core) -------------
    def row_combine(ob_r, st_r, sw_r):
        upd = (ob_r * sw_r[..., None]).reshape(E * C, d)
        return jnp.zeros((S, d), x.dtype).at[st_r.reshape(E * C)].add(
            upd, mode="drop")

    y = jax.vmap(row_combine)(out_buf, slot_tok, slot_w)
    if "dense" in p:
        y = y + apply_mlp(cfg, p["dense"], x)
    return hint(y, "batch|seq|embed"), _aux_loss(gates.reshape(-1, E),
                                                 sel.reshape(-1, k), E)


def _aux_loss(gates, sel, E):
    """Switch/GShard load-balancing auxiliary loss."""
    me = gates.mean(axis=0)                                   # (E,)
    pe = jax.nn.one_hot(sel[:, 0], E, dtype=jnp.float32).mean(axis=0)
    return E * jnp.sum(me * pe)
