"""LM model stack: layers, MoE, SSD, families, zoo facade."""
from . import base, layers, moe, ssm, transformer, ssm_lm, hybrid, encdec, zoo
from .zoo import Model, build, input_specs

__all__ = ["base", "layers", "moe", "ssm", "transformer", "ssm_lm", "hybrid",
           "encdec", "zoo", "Model", "build", "input_specs"]
