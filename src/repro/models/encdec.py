"""Whisper-style encoder–decoder backbone.

The conv/mel frontend is a STUB per the assignment: `input_specs()` feeds
precomputed frame embeddings (B, enc_len, d_model) directly to the encoder
(bidirectional attention, no RoPE, sinusoidal positions).  The decoder is a
causal LM with per-layer cross-attention into the encoder output.

Serving: ``prefill`` encodes once, caches per-layer cross-K/V and the
decoder self-attention KV; ``decode`` is then encoder-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from . import layers as L
from ..distributed import sharding as shd
from .base import axes_of, keygen, stack_layers


def _enc_axes(cfg):
    return axes_of(lambda k: _enc_block_init(cfg, keygen(k)), jax.random.PRNGKey(0))


def _dec_axes(cfg):
    return axes_of(lambda k: _dec_block_init(cfg, keygen(k)), jax.random.PRNGKey(0))


def _enc_block_init(cfg, keys):
    return {"ln1": L.init_norm(cfg, next(keys)),
            "attn": L.init_attention(cfg, keys),
            "ln2": L.init_norm(cfg, next(keys)),
            "mlp": L.init_mlp(cfg, keys)}


def _dec_block_init(cfg, keys):
    return {"ln1": L.init_norm(cfg, next(keys)),
            "self": L.init_attention(cfg, keys),
            "ln2": L.init_norm(cfg, next(keys)),
            "cross": L.init_attention(cfg, keys),
            "ln3": L.init_norm(cfg, next(keys)),
            "mlp": L.init_mlp(cfg, keys)}


def init(cfg, key):
    keys = keygen(key)
    return {
        "embed": L.init_embed(cfg, keys),
        "enc_layers": stack_layers([_enc_block_init(cfg, keys)
                                    for _ in range(cfg.n_enc_layers)]),
        "enc_norm": L.init_norm(cfg, next(keys)),
        "dec_layers": stack_layers([_dec_block_init(cfg, keys)
                                    for _ in range(cfg.n_layers)]),
        "dec_norm": L.init_norm(cfg, next(keys)),
    }


def encode(cfg, params, frames):
    """frames: (B, enc_len, d_model) precomputed embeddings (stub)."""
    B, T, _ = frames.shape
    pos = jnp.arange(T, dtype=jnp.int32)[None].repeat(B, 0)
    x = frames.astype(jnp.dtype(cfg.dtype)) + \
        L.sinusoidal(pos, cfg.d_model).astype(jnp.dtype(cfg.dtype))
    x = hint(x, "batch|seq|embed")

    def body(cfg, blk, x, pos):
        a, _ = L.apply_attention(cfg, blk["attn"],
                                 L.apply_norm(cfg, blk["ln1"], x), pos,
                                 causal=False, use_rope=False)
        x = x + a
        return x + L.apply_mlp(cfg, blk["mlp"],
                               L.apply_norm(cfg, blk["ln2"], x)), 0.0

    fn = functools.partial(body, cfg)
    if cfg.remat:
        fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)

    blk_axes = _enc_axes(cfg)
    carry_ax = "batch|act_seq|embed" if cfg.seq_parallel else "batch|seq|embed"

    def step(x, blk):
        x, _ = fn(shd.hint_tree(blk, blk_axes), x, pos)
        return shd.hint(x, carry_ax), None

    x, _ = jax.lax.scan(step, x, params["enc_layers"])
    return L.apply_norm(cfg, params["enc_norm"], x)


def _dec_block(cfg, blk, x, pos, enc_out, enc_pos):
    a, kv = L.apply_attention(cfg, blk["self"],
                              L.apply_norm(cfg, blk["ln1"], x), pos,
                              causal=True, use_rope=False)
    x = x + a
    c, cross_kv = L.apply_attention(cfg, blk["cross"],
                                    L.apply_norm(cfg, blk["ln2"], x), pos,
                                    causal=False, use_rope=False,
                                    xkv=enc_out, kv_positions=enc_pos)
    x = x + c
    x = x + L.apply_mlp(cfg, blk["mlp"], L.apply_norm(cfg, blk["ln3"], x))
    return x, kv, cross_kv


def forward(cfg, params, batch):
    """batch: frames (B,enc_len,d), tokens (B,S), labels (B,S)."""
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    pos = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None].repeat(B, 0)
    x = L.embed_tokens(cfg, params["embed"], tokens)
    x = x + L.sinusoidal(pos, cfg.d_model).astype(x.dtype)

    body = functools.partial(_dec_block, cfg)
    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    blk_axes = _dec_axes(cfg)
    carry_ax = "batch|act_seq|embed" if cfg.seq_parallel else "batch|seq|embed"

    def step(x, blk):
        x, _, _ = body(shd.hint_tree(blk, blk_axes), x, pos, enc_out, enc_pos)
        return shd.hint(x, carry_ax), None

    x, _ = jax.lax.scan(step, x, params["dec_layers"])
    h = L.apply_norm(cfg, params["dec_norm"], x)
    logits = L.logits_out(cfg, params["embed"], h)
    loss = L.xent_loss(logits, batch["labels"])
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    kv = jax.tree_util.tree_map(
        lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype),
        L.init_kv_cache(cfg, batch, max_len, dtype))
    cross_shape = (cfg.n_layers, batch, cfg.enc_len, cfg.n_kv_heads, cfg.hd)
    return {"kv": kv,
            "cross_k": jnp.zeros(cross_shape, dtype),
            "cross_v": jnp.zeros(cross_shape, dtype),
            "len": jnp.zeros((), jnp.int32)}


def cache_axes(cfg):
    return {"kv": {k: "layers|" + v for k, v in L.KV_CACHE_AXES.items()},
            "cross_k": "layers|batch|kv_seq|kv_heads|head_dim",
            "cross_v": "layers|batch|kv_seq|kv_heads|head_dim",
            "len": ""}


def prefill(cfg, params, frames, tokens, max_len: int):
    enc_out = encode(cfg, params, frames)
    B, S = tokens.shape
    pos = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None].repeat(B, 0)
    x = L.embed_tokens(cfg, params["embed"], tokens)
    x = x + L.sinusoidal(pos, cfg.d_model).astype(x.dtype)
    dtype = jnp.dtype(cfg.dtype)

    blk_axes = _dec_axes(cfg)

    def step(x, blk):
        blk = shd.hint_tree(blk, blk_axes)
        x, (k, v), (ck, cv) = _dec_block(cfg, blk, x, pos, enc_out, enc_pos)
        pad = max_len - k.shape[1]
        kc = jnp.pad(k.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, ({"k": kc, "v": vc}, ck.astype(dtype), cv.astype(dtype))

    x, (kv, ck, cv) = jax.lax.scan(step, x, params["dec_layers"])
    h = L.apply_norm(cfg, params["dec_norm"], x[:, -1:])
    logits = L.logits_out(cfg, params["embed"], h)
    return {"kv": kv, "cross_k": ck, "cross_v": cv,
            "len": jnp.asarray(S, jnp.int32)}, logits


def decode(cfg, params, cache, token):
    cur = cache["len"]
    x = L.embed_tokens(cfg, params["embed"], token)
    B = token.shape[0]
    pos = jnp.full((B, 1), cur, jnp.int32)
    x = x + L.sinusoidal(pos, cfg.d_model).astype(x.dtype)

    blk_axes = _dec_axes(cfg)

    def step(x, inp):
        blk, kv, ck, cv = inp
        blk = shd.hint_tree(blk, blk_axes)
        h = L.apply_norm(cfg, blk["ln1"], x)
        a, kv = L.apply_attention_decode(cfg, blk["self"], h, kv, cur,
                                         use_rope=False)
        x = x + a
        h = L.apply_norm(cfg, blk["ln2"], x)
        x = x + L.apply_cross_attention_decode(cfg, blk["cross"], h, ck, cv)
        x = x + L.apply_mlp(cfg, blk["mlp"], L.apply_norm(cfg, blk["ln3"], x))
        return x, kv

    x, kv = jax.lax.scan(step, x, (params["dec_layers"], cache["kv"],
                                   cache["cross_k"], cache["cross_v"]))
    h = L.apply_norm(cfg, params["dec_norm"], x)
    logits = L.logits_out(cfg, params["embed"], h)
    return {"kv": kv, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"],
            "len": cur + 1}, logits
