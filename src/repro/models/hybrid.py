"""Zamba2-style hybrid: Mamba-2 backbone + ONE shared attention block.

Layout: every ``cfg.shared_attn_every`` SSM layers, the *same* attention+MLP
block (one weight copy) is applied — Zamba2's parameter-sharing design.
Each application keeps its own KV cache (weights shared, state not).

Scan structure: outer scan over G groups, each group = (inner scan over E
stacked SSM layers) + shared-block application; leftover tail layers scan
separately.  81 = 13×6 + 3 for the production config.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from . import layers as L
from . import ssm as S
from ..distributed import sharding as shd
from .base import axes_of, keygen, stack_layers


def _blk_axes(cfg):
    return axes_of(lambda k: _ssm_block_init(cfg, keygen(k)), jax.random.PRNGKey(0))


def _ssm_block_init(cfg, keys):
    return {"ln": L.init_norm(cfg, next(keys)), "ssm": S.init_ssm(cfg, keys)}


def group_shape(cfg):
    every = cfg.shared_attn_every
    n_groups = cfg.n_layers // every
    tail = cfg.n_layers - n_groups * every
    return n_groups, every, tail


def _stack_or_empty(cfg, keys, n: int):
    """Stack n SSM blocks; n == 0 yields a zero-length stacked tree so the
    tail scan still typechecks (lax.scan over length-0 xs)."""
    if n == 0:
        template = stack_layers([_ssm_block_init(cfg, keys)])
        return jax.tree_util.tree_map(
            lambda b: type(b)(b.value[:0], b.axes), template,
            is_leaf=lambda x: hasattr(x, "axes"))
    return stack_layers([_ssm_block_init(cfg, keys) for _ in range(n)])


def init(cfg, key):
    keys = keygen(key)
    n_groups, every, tail = group_shape(cfg)
    groups = [stack_layers([_ssm_block_init(cfg, keys) for _ in range(every)])
              for _ in range(n_groups)]
    return {
        "embed": L.init_embed(cfg, keys),
        "groups": stack_layers(groups),
        "tail": _stack_or_empty(cfg, keys, tail),
        "shared": {
            "ln1": L.init_norm(cfg, next(keys)),
            "attn": L.init_attention(cfg, keys),
            "ln2": L.init_norm(cfg, next(keys)),
            "mlp": L.init_mlp(cfg, keys),
        },
        "final_norm": L.init_norm(cfg, next(keys)),
    }


def _ssm_block(cfg, blk, x):
    y, state = S.apply_ssm(cfg, blk["ssm"], L.apply_norm(cfg, blk["ln"], x))
    return x + y, state


def _shared_full(cfg, shared, x, positions):
    a, kv = L.apply_attention(cfg, shared["attn"],
                              L.apply_norm(cfg, shared["ln1"], x),
                              positions, causal=True)
    x = x + a
    return x + L.apply_mlp(cfg, shared["mlp"],
                           L.apply_norm(cfg, shared["ln2"], x)), kv


def forward(cfg, params, batch):
    tokens = batch["tokens"]
    x = L.embed_tokens(cfg, params["embed"], tokens)
    B, Sq = tokens.shape
    positions = jnp.arange(Sq, dtype=jnp.int32)[None].repeat(B, 0)
    x = hint(x, "batch|seq|embed")

    ssm_body = functools.partial(_ssm_block, cfg)
    if cfg.remat:
        ssm_body = jax.checkpoint(
            ssm_body, policy=jax.checkpoint_policies.nothing_saveable)

    blk_axes = _blk_axes(cfg)
    carry_ax = "batch|act_seq|embed" if cfg.seq_parallel else "batch|seq|embed"

    def inner(x, blk):
        x, _ = ssm_body(shd.hint_tree(blk, blk_axes), x)
        return shd.hint(x, carry_ax), None

    def outer(x, group):
        x, _ = jax.lax.scan(inner, x, group)
        x, _ = _shared_full(cfg, params["shared"], x, positions)
        return x, None

    x, _ = jax.lax.scan(outer, x, params["groups"])
    x, _ = jax.lax.scan(inner, x, params["tail"])
    h = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.logits_out(cfg, params["embed"], h)
    loss = L.xent_loss(logits, batch["labels"])
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    n_groups, every, tail = group_shape(cfg)
    one = S.init_ssm_cache(cfg, batch, dtype)
    grp = jax.tree_util.tree_map(
        lambda x: jnp.zeros((n_groups, every) + x.shape, x.dtype), one)
    tl = jax.tree_util.tree_map(
        lambda x: jnp.zeros((tail,) + x.shape, x.dtype), one)
    kv = jax.tree_util.tree_map(
        lambda x: jnp.zeros((n_groups,) + x.shape, x.dtype),
        L.init_kv_cache(cfg, batch, max_len, dtype))
    return {"ssm_groups": grp, "ssm_tail": tl, "kv": kv,
            "len": jnp.zeros((), jnp.int32)}


def cache_axes(cfg):
    ssm_ax = {k: "apps|layers|" + v for k, v in S.SSM_CACHE_AXES.items()}
    tail_ax = {k: "layers|" + v for k, v in S.SSM_CACHE_AXES.items()}
    kv_ax = {k: "apps|" + v for k, v in L.KV_CACHE_AXES.items()}
    return {"ssm_groups": ssm_ax, "ssm_tail": tail_ax, "kv": kv_ax, "len": ""}


def prefill(cfg, params, tokens, max_len: int):
    x = L.embed_tokens(cfg, params["embed"], tokens)
    B, Sq = tokens.shape
    positions = jnp.arange(Sq, dtype=jnp.int32)[None].repeat(B, 0)
    dtype = jnp.dtype(cfg.dtype)

    blk_axes = _blk_axes(cfg)

    def inner(x, blk):
        blk = shd.hint_tree(blk, blk_axes)
        h = L.apply_norm(cfg, blk["ln"], x)
        y, state = S.apply_ssm(cfg, blk["ssm"], h)
        zxbcdt = jnp.einsum("bsd,de->bse", h,
                            blk["ssm"]["in_proj"].astype(h.dtype))
        _, xr, Bc, Cc, _ = S._split(cfg, zxbcdt)
        window = jnp.concatenate([xr, Bc, Cc], -1)[:, -(cfg.ssm_conv - 1):]
        return x + y, {"conv": window.astype(dtype), "state": state}

    def outer(x, group):
        x, ssm_c = jax.lax.scan(inner, x, group)
        x, (k, v) = _shared_full(cfg, params["shared"], x, positions)
        pad = max_len - k.shape[1]
        kc = jnp.pad(k.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, (ssm_c, {"k": kc, "v": vc})

    x, (grp_c, kv_c) = jax.lax.scan(outer, x, params["groups"])
    x, tail_c = jax.lax.scan(inner, x, params["tail"])
    h = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
    logits = L.logits_out(cfg, params["embed"], h)
    return {"ssm_groups": grp_c, "ssm_tail": tail_c, "kv": kv_c,
            "len": jnp.asarray(Sq, jnp.int32)}, logits


def decode(cfg, params, cache, token):
    cur = cache["len"]
    x = L.embed_tokens(cfg, params["embed"], token)

    blk_axes = _blk_axes(cfg)

    def inner(x, inp):
        blk, c = inp
        blk = shd.hint_tree(blk, blk_axes)
        y, c = S.apply_ssm_decode(cfg, blk["ssm"],
                                  L.apply_norm(cfg, blk["ln"], x), c)
        return x + y, c

    def outer(x, inp):
        group, ssm_c, kv = inp
        x, ssm_c = jax.lax.scan(inner, x, (group, ssm_c))
        h = L.apply_norm(cfg, params["shared"]["ln1"], x)
        a, kv = L.apply_attention_decode(cfg, params["shared"]["attn"], h,
                                         kv, cur)
        x = x + a
        x = x + L.apply_mlp(cfg, params["shared"]["mlp"],
                            L.apply_norm(cfg, params["shared"]["ln2"], x))
        return x, (ssm_c, kv)

    x, (grp_c, kv_c) = jax.lax.scan(
        outer, x, (params["groups"], cache["ssm_groups"], cache["kv"]))
    x, tail_c = jax.lax.scan(inner, x, (params["tail"], cache["ssm_tail"]))
    h = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.logits_out(cfg, params["embed"], h)
    return {"ssm_groups": grp_c, "ssm_tail": tail_c, "kv": kv_c,
            "len": cur + 1}, logits
