"""Model zoo: one facade over every architecture family (--arch <id>).

``build(cfg)`` dispatches on cfg.family and returns a `Model` whose five
functions share a uniform signature, so the launcher/dryrun treat all ten
assigned architectures identically:

    forward(params, batch)                  -> (loss, metrics)
    prefill(params, batch, max_len)         -> (cache, logits)
    decode(params, cache, token)            -> (cache, logits)
    init_cache(batch, max_len)              -> cache pytree
    input_specs(shape)                      -> abstract batch pytrees + axes

`input_specs` is the dry-run contract: ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, zero allocation).  Modality
frontends are stubs per the assignment — paligemma's 256 image patches and
whisper's 1500 audio frames arrive as precomputed embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from . import encdec, hybrid, ssm_lm, transformer
from .base import eval_shape_boxed


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    init: Callable          # key -> boxed param tree
    forward: Callable       # (params, batch) -> (loss, metrics)
    prefill: Callable       # (params, batch, max_len) -> (cache, logits)
    decode: Callable        # (params, cache, token) -> (cache, logits)
    init_cache: Callable    # (batch, max_len) -> cache
    cache_axes: Callable    # () -> axes pytree matching init_cache

    def abstract_params(self):
        """(ShapeDtypeStruct tree, axes tree) without allocating."""
        return eval_shape_boxed(self.init, jax.random.PRNGKey(0))

    def abstract_cache(self, batch: int, max_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))


def _cast_init(init_fn, dtype):
    def init(key):
        boxed = init_fn(key)
        return jax.tree_util.tree_map(
            lambda b: type(b)(b.value.astype(dtype)
                              if b.value.dtype == jnp.float32 else b.value,
                              b.axes),
            boxed, is_leaf=lambda x: hasattr(x, "axes"))
    return init


def _finish(model: Model) -> Model:
    import dataclasses as dc
    if model.cfg.param_dtype != "float32":
        return dc.replace(model, init=_cast_init(model.init,
                                                 jnp.dtype(model.cfg.param_dtype)))
    return model


def build(cfg: ArchConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer

        def fwd(params, batch):
            return mod.forward(cfg, params, batch)

        def pre(params, batch, max_len):
            return mod.prefill(cfg, params, batch["tokens"], max_len,
                               patch_embs=batch.get("patch_embs"))

        def dec(params, cache, token):
            return mod.decode(cfg, params, cache, token)

        return _finish(Model(cfg, lambda k: mod.init(cfg, k), fwd, pre, dec,
                             lambda b, m: mod.init_cache(cfg, b, m),
                             lambda: mod.cache_axes(cfg)))
    if fam == "ssm":
        mod = ssm_lm
    elif fam == "hybrid":
        mod = hybrid
    elif fam == "encdec":
        mod = encdec
    else:
        raise ValueError(f"unknown family {fam}")

    def fwd(params, batch):
        return mod.forward(cfg, params, batch)

    if fam == "encdec":
        def pre(params, batch, max_len):
            return mod.prefill(cfg, params, batch["frames"], batch["tokens"],
                               max_len)
    else:
        def pre(params, batch, max_len):
            return mod.prefill(cfg, params, batch["tokens"], max_len)

    def dec(params, cache, token):
        return mod.decode(cfg, params, cache, token)

    return _finish(Model(cfg, lambda k: mod.init(cfg, k), fwd, pre, dec,
                         lambda b, m: mod.init_cache(cfg, b, m),
                         lambda: mod.cache_axes(cfg)))


# ---------------------------------------------------------------------------
# Abstract input specs (dry-run contract)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec):
    """ShapeDtypeStruct batch + logical-axes batch for one (arch × shape).

    Returns dict(kind=..., batch=specs, axes=..., token=..., max_len=...).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)

    def tok(shape_):
        return jax.ShapeDtypeStruct(shape_, i32)

    batch, axes = {}, {}
    if cfg.family == "vlm":
        text = S - cfg.n_patches
        batch["tokens"] = tok((B, text))
        batch["labels"] = tok((B, text))
        batch["patch_embs"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), act)
        axes = {"tokens": "batch|seq", "labels": "batch|seq",
                "patch_embs": "batch|seq|embed"}
    elif cfg.family == "encdec":
        batch["tokens"] = tok((B, S))
        batch["labels"] = tok((B, S))
        batch["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_len, cfg.d_model), act)
        axes = {"tokens": "batch|seq", "labels": "batch|seq",
                "frames": "batch|seq|embed"}
    else:
        batch["tokens"] = tok((B, S))
        batch["labels"] = tok((B, S))
        axes = {"tokens": "batch|seq", "labels": "batch|seq"}

    if shape.kind == "train":
        return {"kind": "train", "batch": batch, "axes": axes}
    if shape.kind == "prefill":
        del batch["labels"]
        del axes["labels"]
        return {"kind": "prefill", "batch": batch, "axes": axes,
                "max_len": S}
    # decode: one new token against a seq_len cache
    token = tok((B, 1))
    return {"kind": "decode", "batch": {"token": token},
            "axes": {"token": "batch|seq"}, "max_len": S,
            "cache_batch": B}
