"""Functional-parameter infrastructure for the LM stack.

No flax in this environment, so models are plain functions over explicit
pytrees.  Every parameter leaf is created through ``param(...)`` which
*boxes* the array with its logical sharding axes; ``unbox`` splits a boxed
tree into (arrays, axes) with identical treedefs, so the distribution layer
(distributed/sharding.py) can resolve PartitionSpecs for any architecture
without a hand-maintained parallel table.

Under ``jax.eval_shape`` the same init functions produce ShapeDtypeStruct
leaves — that is how launch/dryrun.py builds abstract parameter trees for
the 512-device lowering without allocating a single byte.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Boxed:
    """A parameter leaf + its logical axis names (one per dim)."""

    value: Any
    axes: tuple


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def param(key, shape, axes, *, dtype=jnp.float32, init: str = "normal",
          scale: Optional[float] = None) -> Boxed:
    """Create a boxed parameter.

    init: 'normal' (trunc-normal fan-in), 'zeros', 'ones', 'embed'.
    """
    assert len(axes) == len(shape), (shape, axes)
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        if scale is None:
            fan_in = shape[0] if len(shape) > 1 else shape[-1]
            if init == "embed":
                fan_in = shape[-1]
            scale = fan_in ** -0.5
        v = (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    return Boxed(v, tuple(axes))


def keygen(key):
    """Infinite key splitter."""
    while True:
        key, sub = jax.random.split(key)
        yield sub


def tree_unbox(tree):
    """(params, axes) with identical treedefs.

    Axes leaves are encoded as '|'-joined strings (e.g. 'd_model|d_ff') so
    the axes tree has exactly one leaf per parameter — a tuple of strings
    would itself flatten under tree_map.
    """
    values = jax.tree_util.tree_map(lambda b: b.value, tree, is_leaf=is_boxed)
    axes = jax.tree_util.tree_map(lambda b: "|".join(b.axes), tree,
                                  is_leaf=is_boxed)
    return values, axes


def stack_layers(per_layer: Sequence):
    """Stack a list of boxed trees along a new leading 'layers' axis —
    the scan-over-layers representation (O(1) HLO size for any depth)."""
    def stack(*leaves):
        vals = jnp.stack([l.value for l in leaves])
        return Boxed(vals, ("layers",) + leaves[0].axes)
    return jax.tree_util.tree_map(stack, *per_layer, is_leaf=is_boxed)


def axes_of(init_fn, *args):
    """Logical-axes tree of an init function's output (abstract, cheap).

    Used by scan bodies to re-assert per-layer parameter sharding via
    distributed.sharding.hint_tree — see that docstring for why."""
    return eval_shape_boxed(init_fn, *args)[1]


def eval_shape_boxed(init_fn, *args):
    """Run an init function abstractly; returns (ShapeDtypeStruct tree, axes).

    Boxes are not pytrees on purpose (leaves must stay opaque to jit), so we
    unbox inside the traced function and reattach axes from a concrete-free
    second structural pass.
    """
    axes_cell = {}

    def run():
        tree = init_fn(*args)
        values, axes = tree_unbox(tree)
        axes_cell["axes"] = axes
        return values

    shapes = jax.eval_shape(run)
    return shapes, axes_cell["axes"]
