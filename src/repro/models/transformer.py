"""Decoder-only LM: dense / MoE / VLM families, scan-over-layers.

All homogeneous layer stacks are `jax.lax.scan` over stacked parameters —
O(1) HLO size regardless of depth (80-layer qwen2-72b compiles as fast as
2 layers), with optional per-layer remat (activation checkpointing).

Three entry points per model (launch/dryrun.py lowers all three):
  * forward(params, batch)            -> (loss, metrics)        train
  * prefill(params, tokens, ...)      -> (cache, last_logits)   serve
  * decode(params, cache, token, len) -> (cache, logits)        serve
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from . import layers as L
from . import moe as M
from ..distributed import sharding as shd
from .base import axes_of, keygen, param, stack_layers


def _blk_axes(cfg):
    return axes_of(lambda k: _block_init(cfg, keygen(k)), jax.random.PRNGKey(0))


def _block_init(cfg, keys):
    blk = {
        "ln1": L.init_norm(cfg, next(keys)),
        "attn": L.init_attention(cfg, keys),
        "ln2": L.init_norm(cfg, next(keys)),
    }
    if cfg.n_experts:
        blk["moe"] = M.init_moe(cfg, keys)
    else:
        blk["mlp"] = L.init_mlp(cfg, keys)
    return blk


def init(cfg, key):
    keys = keygen(key)
    return {
        "embed": L.init_embed(cfg, keys),
        "layers": stack_layers([_block_init(cfg, keys)
                                for _ in range(cfg.n_layers)]),
        "final_norm": L.init_norm(cfg, next(keys)),
    }


# ---------------------------------------------------------------------------
# Train / full forward
# ---------------------------------------------------------------------------

def _block_apply(cfg, blk, x, positions):
    a, _ = L.apply_attention(cfg, blk["attn"], L.apply_norm(cfg, blk["ln1"], x),
                             positions, causal=True)
    x = x + a
    h = L.apply_norm(cfg, blk["ln2"], x)
    if cfg.n_experts:
        m, aux = M.apply_moe(cfg, blk["moe"], h)
    else:
        m, aux = L.apply_mlp(cfg, blk["mlp"], h), 0.0
    return x + m, aux


def _scan_blocks(cfg, stacked, x, positions, block_fn):
    blk_axes = _blk_axes(cfg)
    body = functools.partial(block_fn, cfg)
    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    carry_ax = "batch|act_seq|embed" if cfg.seq_parallel else "batch|seq|embed"

    def step(carry, blk):
        blk = shd.hint_tree(blk, blk_axes)   # keep FSDP gather inside the loop
        x, aux = carry
        x, a = body(blk, x, positions)
        return (shd.hint(x, carry_ax), aux + a), None

    (x, aux), _ = jax.lax.scan(step, (x, 0.0), stacked)
    return x, aux


def hidden_states(cfg, params, tokens, *, patch_embs=None):
    """Token (+ optional stub patch) embedding -> final norm hidden states."""
    x = L.embed_tokens(cfg, params["embed"], tokens)
    if patch_embs is not None:
        x = jnp.concatenate([patch_embs.astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    x = hint(x, "batch|seq|embed")
    x, aux = _scan_blocks(cfg, params["layers"], x, positions, _block_apply)
    return L.apply_norm(cfg, params["final_norm"], x), aux


def forward(cfg, params, batch):
    """Causal-LM loss.  batch: tokens (B,S), labels (B,S) [, patch_embs]."""
    patch = batch.get("patch_embs")
    h, aux = hidden_states(cfg, params, batch["tokens"], patch_embs=patch)
    if patch is not None:
        h = h[:, patch.shape[1]:]          # loss on the text span only (VLM)
    logits = L.logits_out(cfg, params["embed"], h)
    logits = hint(logits, "batch|seq|vocab")
    loss = L.xent_loss(logits, batch["labels"], batch.get("loss_mask"))
    if cfg.n_experts:
        loss = loss + 0.01 * aux / cfg.n_layers
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    one = L.init_kv_cache(cfg, batch, max_len, dtype)
    kv = jax.tree_util.tree_map(
        lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype), one)
    return {"kv": kv, "len": jnp.zeros((), jnp.int32)}


def cache_axes(cfg):
    return {"kv": {k: "layers|" + v for k, v in L.KV_CACHE_AXES.items()},
            "len": ""}


def prefill(cfg, params, tokens, max_len: int, *, patch_embs=None):
    """Run the prompt, return (cache, last-position logits)."""
    x = L.embed_tokens(cfg, params["embed"], tokens)
    if patch_embs is not None:
        x = jnp.concatenate([patch_embs.astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    x = hint(x, "batch|seq|embed")
    dtype = jnp.dtype(cfg.dtype)

    blk_axes = _blk_axes(cfg)
    carry_ax = "batch|act_seq|embed" if cfg.seq_parallel else "batch|seq|embed"

    def step(carry, blk):
        blk = shd.hint_tree(blk, blk_axes)
        x = shd.hint(carry, carry_ax)
        h = L.apply_norm(cfg, blk["ln1"], x)
        a, (k, v) = L.apply_attention(cfg, blk["attn"], h, positions, causal=True)
        x = x + a
        h = L.apply_norm(cfg, blk["ln2"], x)
        if cfg.n_experts:
            m, _ = M.apply_moe(cfg, blk["moe"], h)
        else:
            m = L.apply_mlp(cfg, blk["mlp"], h)
        pad = max_len - k.shape[1]
        kc = jnp.pad(k.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
        kc = hint(kc, "batch|kv_seq|kv_heads|head_dim")
        vc = hint(vc, "batch|kv_seq|kv_heads|head_dim")
        return x + m, {"k": kc, "v": vc}

    x, kv = jax.lax.scan(step, x, params["layers"])
    h = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
    logits = L.logits_out(cfg, params["embed"], h)
    return {"kv": kv, "len": jnp.asarray(S, jnp.int32)}, logits


def decode(cfg, params, cache, token):
    """One decode step.  token: (B, 1) int32."""
    x = L.embed_tokens(cfg, params["embed"], token)
    cur = cache["len"]

    blk_axes = _blk_axes(cfg)

    def step(carry, inp):
        x = carry
        blk, kv = inp
        blk = shd.hint_tree(blk, blk_axes)
        h = L.apply_norm(cfg, blk["ln1"], x)
        a, kv = L.apply_attention_decode(cfg, blk["attn"], h, kv, cur)
        x = x + a
        h = L.apply_norm(cfg, blk["ln2"], x)
        if cfg.n_experts:
            m, _ = M.apply_moe(cfg, blk["moe"], h)
        else:
            m = L.apply_mlp(cfg, blk["mlp"], h)
        return x + m, kv

    x, kv = jax.lax.scan(step, x, (params["layers"], cache["kv"]))
    h = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.logits_out(cfg, params["embed"], h)
    return {"kv": kv, "len": cur + 1}, logits
