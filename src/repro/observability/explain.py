"""Plan explain: a pretty-printer for the fused execution plan.

``fm.explain(x)`` builds the SAME `fusion.Plan` that ``fm.materialize(x)``
would execute — cut, pass schedule, both partition tiers, segment IR —
without running it, and renders the planner's decisions:

  * the pass schedule (how many streaming passes, which merged values bind
    forward into later passes);
  * each pass's sources with their storage tier (device/host/disk),
    staging-group deduplication and streamed bytes;
  * each fused segment with its width/dtype/FLOP metadata and BOTH
    partition tiers (I/O-level ``partition_rows``, processor-level
    ``block_rows`` — the paper's §III-F two-level partitioning);
  * the backend dispatch decision per segment: which pallas kernel matcher
    claimed it, or why it fell back to the generic XLA trace
    (`lowering.dispatch_report`).

The output is stable under node-id renumbering except for the ``#id``
suffixes in node names; golden tests normalize those with ``#\\d+`` → ``#N``.

Imports of ``repro.core`` stay inside the functions: ``core.materialize``
imports ``repro.observability`` at module load, so the package level here
must not import back into core.
"""
from __future__ import annotations


def _tier(mat) -> str:
    if getattr(mat, "on_disk", False):
        return "disk"
    return "host" if getattr(mat, "on_host", False) else "device"


def _mat_label(node, mat) -> str:
    name = getattr(mat, "name", "") or getattr(node, "name", "") or "<anon>"
    return name


def _fmt_bytes(n: float) -> str:
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0 or unit == "TiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024.0
    return f"{n:.1f} TiB"  # pragma: no cover - loop always returns


def explain(*outputs, backend=None) -> str:
    """Render the fused plan ``fm.materialize(*outputs)`` would run.

    Accepts the same operands as ``fm.materialize`` (FM wrappers or raw
    FMMatrix handles); nothing is computed and no plan-cache entry is
    created.  ``backend`` resolves like materialize's (None/'auto' → the
    engine default).
    """
    from ..core.fusion import Plan

    mats = [getattr(x, "m", x) for x in outputs]
    virtuals = [m for m in mats if getattr(m, "is_virtual", False)]
    if not virtuals:
        return "(nothing to plan: every operand is already materialized)"
    return explain_plan(Plan(virtuals), backend=backend)


def explain_plan(plan, backend=None) -> str:
    """Explain an already-built `fusion.Plan` (``Plan.explain`` delegates
    here)."""
    from ..core import dtypes, lowering

    resolved = lowering.resolve_backend(backend)
    lines = [
        f"Plan: passes={plan.n_passes} long_dim={plan.long_dim} "
        f"backend={resolved}"
        + (f" (resolved from {backend or 'auto'!r})"
           if resolved != backend else ""),
        f"  cost: flops={plan.flop_count():.3e} "
        f"bytes_in={_fmt_bytes(plan.bytes_in())} "
        f"bytes_out={_fmt_bytes(plan.bytes_out())}",
    ]
    for ps in plan.passes:
        lines.append(f"pass {ps.idx}: io_partition_rows={ps.partition_rows}")
        if ps.bindings:
            lines.append("  bindings (from earlier passes): "
                         + ", ".join(n.name for n in ps.bindings))
        for nid, mat in ps.staged_sources():
            group = next(g for g in ps.source_groups if g[0].id == nid)
            alias = (f" (read once for {len(group)} leaves)"
                     if len(group) > 1 else "")
            lines.append(
                f"  source {_mat_label(group[0], mat)}: "
                f"{mat.shape[0]}x{mat.shape[1]} "
                f"{dtypes.canon(mat.dtype).name} tier={_tier(mat)} "
                f"streamed {_fmt_bytes(mat.nbytes())}/pass{alias}")
        for node, mat in ps.broadcast_sources:
            lines.append(f"  broadcast {_mat_label(node, mat)}: "
                         f"{mat.shape[0]}x{mat.shape[1]} tier={_tier(mat)} "
                         f"(staged whole)")
        for node, mat in ps.epilogue_sources:
            lines.append(f"  epilogue-source {_mat_label(node, mat)}: "
                         f"{mat.shape[0]}x{mat.shape[1]} tier={_tier(mat)} "
                         f"(epilogue only)")
        report = lowering.dispatch_report(ps, ps.ir, resolved)
        for seg in ps.ir.segments:
            lines.append("  " + seg.describe())
            lines.append(f"    -> {report.get(seg.sid, '?')}")
    return "\n".join(lines)


def explain_batch(request_groups, backend=None) -> str:
    """Render the co-schedule ``fm.batch`` would run over ``request_groups``
    (a list of requests, each a list of FMMatrix outputs): per round, the
    stream groups with their members, shared physical sources and the
    union bytes the group's ONE drive reads — against the sum the same
    requests would read serially.  Nothing is computed and no plan-cache
    entry is created."""
    from ..core import dtypes
    from ..core.fusion import Plan, coschedule, stream_group_key

    plans = []
    for outs in request_groups:
        virtuals = [m for m in outs if getattr(m, "is_virtual", False)]
        if virtuals:
            plans.append(Plan(virtuals))
    if not plans:
        return "(nothing to plan: every request is already materialized)"

    n_rounds = max(p.n_passes for p in plans)
    lines = [f"Batch: requests={len(plans)} rounds={n_rounds}"]
    total_union = total_serial = 0.0
    for r in range(n_rounds):
        live = [(i, p) for i, p in enumerate(plans) if r < p.n_passes]
        keys = [stream_group_key(p.passes[r]) for _, p in live]
        lines.append(f"round {r}:")
        for group in coschedule(keys):
            members = [live[g] for g in group]
            union, seen = [], set()
            for _, p in members:
                for _, mat in p.passes[r].staged_sources():
                    if id(mat) not in seen:
                        seen.add(id(mat))
                        union.append(mat)
            union_b = sum(mat.nbytes() for mat in union)
            serial_b = sum(p.passes[r].bytes_in() for _, p in members)
            total_union += union_b
            total_serial += serial_b
            rows = min(p.passes[r].partition_rows for _, p in members)
            lines.append(
                f"  stream group: members={len(members)} "
                f"io_partition_rows={rows} "
                f"reads {_fmt_bytes(union_b)} once"
                + (f" (vs {_fmt_bytes(serial_b)} serially)"
                   if len(members) > 1 else ""))
            for mat in union:
                lines.append(
                    f"    source {getattr(mat, 'name', '') or '<anon>'}: "
                    f"{mat.shape[0]}x{mat.shape[1]} "
                    f"{dtypes.canon(mat.dtype).name} tier={_tier(mat)}")
            for i, p in members:
                sinks = ", ".join(n.name for n in p.passes[r].sinks) or "-"
                lines.append(f"    member request[{i}] pass {r}: "
                             f"sinks [{sinks}]")
    lines.append(f"total streamed: {_fmt_bytes(total_union)} batched vs "
                 f"{_fmt_bytes(total_serial)} serial")
    return "\n".join(lines)
