"""Observability: span tracing, scoped metrics and plan explain.

The instrumentation substrate the execution engine records into
(core/materialize.py, core/lowering.py, storage/prefetch.py) and the
benchmarks/serving layers read from:

* `trace`   — nested timing spans with Chrome-trace/Perfetto export
  (``fm.trace(...)`` / ``fm.trace_export(path)``);
* `metrics` — thread-safe scoped counters/gauges/histograms behind the
  ``exec_stats()`` compatibility view, plus ``fm.collect_stats()`` for
  per-request isolation;
* `explain` — the fused-plan pretty-printer behind ``fm.explain(x)``.

`trace` and `metrics` are stdlib-only (core imports this package at module
load); `explain` imports core lazily inside its functions.
"""
from . import explain, metrics, trace                       # noqa: F401
from .explain import explain as explain_outputs, explain_plan  # noqa: F401
from .metrics import REGISTRY, Scope                        # noqa: F401
from .trace import TRACER, SpanTracer, span                 # noqa: F401
