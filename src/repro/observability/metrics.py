"""Scoped, thread-safe metrics registry (counters, last-value slots,
histograms) — the engine's structured replacement for ad-hoc module-global
counter dicts.

Two kinds of scope:

  * the **root scope** — process-global, always recording.  The
    compatibility view ``repro.core.materialize.exec_stats()`` reads it,
    so every pre-existing counter assertion keeps working.
  * **collection scopes** — opened with ``fm.collect_stats()`` (a context
    manager yielding the scope).  A scope records only what the *current
    thread* (plus pipeline threads it explicitly spawns, see
    ``current_scopes``/``use_scopes``) does while it is open: two
    concurrent materialize calls in two threads, each inside its own
    ``collect_stats()``, observe only their own execution — the
    per-request isolation an admission-controlling serving layer needs
    (ROADMAP item 2).

Metric kinds:

  * ``inc(name, v)``     — monotonic counter (calls, bytes, seconds);
  * ``put(name, value)`` — last-value slot (the per-pass byte tuple of the
    most recent execution, published atomically at execution end — never
    half-updated by an interleaved materialize);
  * ``observe(name, v)`` — histogram summary (count/total/min/max), e.g.
    prefetch-queue occupancy samples.

``Scope.stats()`` returns a plain dict: counters and values verbatim,
histograms as ``{name: {count, total, min, max, mean}}``, plus derived
rates — ``stream_bandwidth_bytes_s`` (slow-tier staging read bandwidth),
``prefetch_wait_frac`` (fraction of streaming wall time the compute thread
spent blocked on the staging queue) and ``plan_cache_hit_ratio``.

The registry takes one small lock per recording call and nothing else:
cheap enough to stay always-on (the CI bench gate holds it to no
measurable wall-time regression).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterable, Optional

#: Counter pairs that define the derived rates in ``derive()``.
_DERIVED_DOC = {
    "stream_bandwidth_bytes_s": ("stage_bytes_read", "stage_read_seconds"),
    "prefetch_wait_frac": ("prefetch_wait_seconds", "pass_seconds"),
    "plan_cache_hit_ratio": ("plan_cache_hits",
                             "plan_cache_hits + plan_cache_misses"),
}


class _Hist:
    __slots__ = ("count", "total", "min", "max")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float):
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def snapshot(self) -> dict:
        return {"count": self.count, "total": self.total,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "mean": (self.total / self.count) if self.count else 0.0}


class Scope:
    """One collector: counters + last-value slots + histograms."""

    def __init__(self, name: str = ""):
        self.name = name
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._values: dict[str, object] = {}
        self._hists: dict[str, _Hist] = {}

    # -- recording ----------------------------------------------------------
    def inc(self, name: str, v: float = 1.0):
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + v

    def put(self, name: str, value):
        with self._lock:
            self._values[name] = value

    def observe(self, name: str, v: float):
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = _Hist()
            h.observe(v)

    # -- reading ------------------------------------------------------------
    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0.0)

    def value(self, name: str, default=None):
        with self._lock:
            return self._values.get(name, default)

    def stats(self) -> dict:
        """Snapshot: counters/values verbatim, histogram summaries, and the
        derived bandwidth / wait-fraction / cache-hit-ratio rates."""
        with self._lock:
            out: dict = dict(self._counters)
            out.update(self._values)
            for name, h in self._hists.items():
                out[name] = h.snapshot()
        return derive(out)

    def reset(self):
        with self._lock:
            self._counters.clear()
            self._values.clear()
            self._hists.clear()

    def __repr__(self):
        return f"Scope({self.name or 'anon'}, {len(self._counters)} counters)"


def derive(stats: dict) -> dict:
    """Attach the derived rate metrics to a raw stats dict (in place)."""
    read_s = stats.get("stage_read_seconds", 0.0)
    stats["stream_bandwidth_bytes_s"] = (
        stats.get("stage_bytes_read", 0.0) / read_s if read_s > 0 else 0.0)
    loop_s = stats.get("pass_seconds", 0.0)
    stats["prefetch_wait_frac"] = (
        min(stats.get("prefetch_wait_seconds", 0.0) / loop_s, 1.0)
        if loop_s > 0 else 0.0)
    lookups = (stats.get("plan_cache_hits", 0.0)
               + stats.get("plan_cache_misses", 0.0))
    stats["plan_cache_hit_ratio"] = (
        stats.get("plan_cache_hits", 0.0) / lookups if lookups > 0 else 0.0)
    return stats


class MetricsRegistry:
    """The root scope plus a per-thread stack of collection scopes.  Every
    recording call fans out to the root and to the calling thread's open
    scopes, so scoped collection never loses the global view."""

    def __init__(self):
        self.root = Scope("root")
        self._local = threading.local()

    # -- scope plumbing ------------------------------------------------------
    def _stack(self) -> list[Scope]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def scopes(self) -> tuple[Scope, ...]:
        """Every scope the current thread records into (root first)."""
        return (self.root, *self._stack())

    def current_scopes(self) -> tuple[Scope, ...]:
        """The current thread's OPEN collection scopes (no root) — capture
        these before spawning a pipeline thread and re-enter them there
        with ``use_scopes`` so background staging work is attributed to
        the request that spawned it."""
        return tuple(self._stack())

    @contextlib.contextmanager
    def use_scopes(self, scopes: Iterable[Scope]):
        """Adopt another thread's collection scopes for a with-block (the
        prefetcher's worker thread runs its whole loop under this)."""
        st = self._stack()
        saved = list(st)
        st[:] = list(scopes)
        try:
            yield
        finally:
            st[:] = saved

    @contextlib.contextmanager
    def collect(self, name: str = ""):
        """``fm.collect_stats()``: open a fresh scope on this thread; yields
        the `Scope` (read it with ``.stats()`` during or after the block)."""
        scope = Scope(name)
        st = self._stack()
        st.append(scope)
        try:
            yield scope
        finally:
            st.remove(scope)

    # -- recording (fans out to root + open scopes) --------------------------
    def inc(self, name: str, v: float = 1.0):
        for s in self.scopes():
            s.inc(name, v)

    def put(self, name: str, value):
        for s in self.scopes():
            s.put(name, value)

    def observe(self, name: str, v: float):
        for s in self.scopes():
            s.observe(name, v)

    # -- reading / reset -----------------------------------------------------
    def stats(self) -> dict:
        return self.root.stats()

    def reset(self):
        """Reset the ROOT scope (collection scopes are ephemeral — their
        owners hold them)."""
        self.root.reset()


#: The process-wide registry the engine records into.
REGISTRY = MetricsRegistry()

# Module-level shorthands (hot-path call sites use these).
inc = REGISTRY.inc
put = REGISTRY.put
observe = REGISTRY.observe
collect = REGISTRY.collect
current_scopes = REGISTRY.current_scopes
use_scopes = REGISTRY.use_scopes
stats = REGISTRY.stats
reset = REGISTRY.reset


def root_counter(name: str) -> float:
    return REGISTRY.root.counter(name)


def root_value(name: str, default=None):
    return REGISTRY.root.value(name, default)
