"""Span tracer: nested timing spans with Chrome-trace/Perfetto export.

The engine's execution pipeline emits spans

    materialize → pass → partition → {stage, prefetch_wait,
                                      device_step, combine} → epilogue

on the thread that performs each piece of work, so the prefetcher's
background staging thread gets its OWN track and the stage/compute overlap
the paper's §III-F design promises is directly visible in the timeline.

Design constraints (this module is on the per-partition hot path):

  * **near-zero overhead when disabled** — ``span()`` returns a shared
    no-op context manager after a single attribute check; no allocation,
    no lock, no clock read;
  * **thread-safe when enabled** — events append under one lock; each
    event carries its thread id, and thread names are recorded as
    Chrome-trace metadata so Perfetto labels the tracks;
  * **timing fidelity** — span begin/end use ``time.perf_counter`` against
    a fixed epoch; the executor additionally blocks on device values
    inside ``device_step``/``combine`` spans *only while tracing*, so
    disabled runs keep their async dispatch behavior.

Use through the R-like surface:

    with fm.trace():                    # enable + collect
        fm.materialize(...)
    fm.trace_export("run.trace.json")   # chrome://tracing / ui.perfetto.dev

or ``fm.trace(export="run.trace.json")`` to export on scope exit.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Optional


class _NullSpan:
    """Shared do-nothing context manager: the disabled-tracer fast path."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "SpanTracer", name: str, args: dict):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._tracer.record(self._name, self._t0, time.perf_counter(),
                            self._args)
        return False


class SpanTracer:
    """Collect timing spans; export as Chrome-trace JSON.

    One process-wide instance (`TRACER`) is shared by the whole engine;
    ``enabled`` gates collection.  Events survive ``stop()`` so a trace can
    be exported after the traced block exits; ``reset()`` clears them.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._thread_names: dict[int, str] = {}
        self._epoch = time.perf_counter()
        self.enabled = False

    # -- recording ----------------------------------------------------------
    def span(self, name: str, **args):
        """Context manager timing one span.  Near-free when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, args)

    def record(self, name: str, t_start: float, t_end: float,
               args: Optional[dict] = None):
        """Record a completed span from raw ``perf_counter`` timestamps
        (for call sites that measure manually, e.g. the prefetch-queue
        wait, whose args are only known after the wait ends)."""
        if not self.enabled:
            return
        tid = threading.get_ident()
        ev = {
            "name": name,
            "ts": (t_start - self._epoch) * 1e6,   # µs, Chrome-trace unit
            "dur": max((t_end - t_start) * 1e6, 0.0),
            "tid": tid,
        }
        if args:
            ev["args"] = args
        with self._lock:
            if tid not in self._thread_names:
                self._thread_names[tid] = threading.current_thread().name
            self._events.append(ev)

    # -- lifecycle ----------------------------------------------------------
    def start(self):
        self.enabled = True

    def stop(self):
        self.enabled = False

    def reset(self):
        with self._lock:
            self._events.clear()
            self._thread_names.clear()
        self._epoch = time.perf_counter()

    @contextlib.contextmanager
    def recording(self, export: Optional[str] = None, *, reset: bool = True):
        """Enable tracing over a with-block (`fm.trace()`).  ``reset=True``
        starts from an empty buffer; ``export=`` writes the Chrome-trace
        JSON on exit."""
        if reset:
            self.reset()
        self.start()
        try:
            yield self
        finally:
            self.stop()
            if export is not None:
                self.export(export)

    # -- inspection / export -------------------------------------------------
    def events(self) -> list[dict]:
        """Snapshot of collected span events (ts/dur in µs, per-thread)."""
        with self._lock:
            return [dict(ev) for ev in self._events]

    def chrome_trace(self) -> dict:
        """The trace as a Chrome-trace JSON object: complete ('X') events
        plus thread-name metadata, loadable by chrome://tracing and
        ui.perfetto.dev."""
        with self._lock:
            events = [dict(ev) for ev in self._events]
            names = dict(self._thread_names)
        trace_events = [
            {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
             "args": {"name": "repro.fm engine"}},
        ]
        for tid, tname in sorted(names.items()):
            trace_events.append(
                {"ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
                 "args": {"name": tname}})
        for ev in events:
            out = {"ph": "X", "cat": "fm", "pid": 0,
                   "name": ev["name"], "tid": ev["tid"],
                   "ts": round(ev["ts"], 3), "dur": round(ev["dur"], 3)}
            if "args" in ev:
                out["args"] = ev["args"]
            trace_events.append(out)
        return {"traceEvents": trace_events, "displayTimeUnit": "ms"}

    def export(self, path) -> str:
        """Write the Chrome-trace JSON to ``path``; returns the path."""
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(self.chrome_trace(), fh)
            fh.write("\n")
        return str(path)


#: The process-wide tracer every engine layer records into.
TRACER = SpanTracer()


def span(name: str, **args):
    """Module-level shorthand: ``trace.span('pass', idx=0)``."""
    return TRACER.span(name, **args)
