"""Optimizers + schedules + gradient compression."""
from . import adam, schedule, compression
from .adam import AdamConfig

__all__ = ["adam", "schedule", "compression", "AdamConfig"]
