"""AdamW with low-precision moments (pytree-native, sharding-transparent).

Moments default to bfloat16 — at 72B/480B parameters the f32 m/v pair alone
would blow past HBM; bf16 moments halve optimizer memory at negligible
quality cost (the classic large-scale memory trick, paired with the FSDP
parameter sharding from distributed/sharding.py: optimizer state inherits
the parameter PartitionSpecs because the trees are shape-congruent).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "bfloat16"
    grad_clip: float = 1.0


def init(params, cfg: AdamConfig = AdamConfig()):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_axes(param_axes):
    """Moment trees shard exactly like the parameters (ZeRO)."""
    return {"m": param_axes, "v": param_axes, "step": ""}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(grads, state, params, cfg: AdamConfig = AdamConfig(),
           lr_scale=1.0):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd_elem(p, g, m, v, decay):
        g = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if decay:
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step_
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    def upd(p, g, m, v):
        # NOTE: a lax.map-chunked update (one layer slice at a time) was
        # tried for the giant stacked-expert leaves (arctic-480b) to bound
        # Adam's f32 temporaries — it defeated XLA's input/output buffer
        # aliasing and cost MORE (+10.4 GiB) than it saved.  Measured and
        # reverted; see EXPERIMENTS.md §Perf (refuted hypothesis).
        return upd_elem(p, g, m, v, bool(cfg.weight_decay) and p.ndim >= 2)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
