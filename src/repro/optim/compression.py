"""Error-feedback int8 gradient compression for the cross-pod (DCN) hop.

At multi-pod scale the gradient all-reduce crosses the data-center network,
which is an order of magnitude slower than ICI — the same tier relationship
FlashMatrix exploits between DRAM and SSDs.  The mitigation is also the
same: cut bytes moved across the slow tier.  Per-leaf symmetric int8
quantization (per-tensor scale) with an error-feedback residual keeps SGD
unbiased in expectation; the residual is carried in the optimizer state and
added back before the next quantization (1-bit-Adam-style EF scheme).

Used by launch/train.py when `--grad-compression int8` is set: gradients
reduce in int8 across the `pod` axis only (intra-pod reductions stay bf16
over ICI), an 8x/2x byte reduction on the slowest link.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x, *, bits: int = 8):
    """Symmetric per-tensor quantization -> (int8 payload, f32 scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(xf / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def compress_with_feedback(grads, err):
    """(grads + carried error) -> (quantized payloads, new error residual).

    Returns ((q, scale) tree, err') where err' = input − dequant(output).
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, s = quantize(corrected)
        resid = corrected - dequantize(q, s)
        return (q, s), resid.astype(jnp.bfloat16)

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    errs = treedef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(leaves, errs)]
    payload = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return payload, new_err


def cross_pod_psum_int8(grads, err, axis_name: str = "pod"):
    """Reduce gradients across `axis_name` in int8 with error feedback.

    Call inside shard_map with the pod axis in scope.  The quantization
    scale is SHARED across the axis (pmax of local amax, one scalar of
    traffic) *before* quantizing — with per-participant scales the summed
    payloads cannot be dequantized exactly, a bug our multi-device test
    caught.  Sum of int8 payloads fits int32 for <=2^23 participants; the
    local residual (vs the shared scale) carries as bf16 error feedback.
    """
    def reduce_one(g, e):
        corrected = g.astype(jnp.float32) + e.astype(jnp.float32)
        amax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(corrected / scale), -127, 127)
        resid = corrected - q * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return total.astype(jnp.float32) * scale, resid.astype(jnp.bfloat16)

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    errs = treedef.flatten_up_to(err)
    out = [reduce_one(g, e) for g, e in zip(leaves, errs)]
    reduced = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return reduced, new_err
