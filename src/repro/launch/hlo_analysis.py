"""Loop-aware post-SPMD HLO analysis.

XLA-CPU's ``compiled.cost_analysis()`` counts a `while` body ONCE, so for
scan-over-layers programs (all of ours) its flops/bytes are per-layer, not
per-step (verified experimentally: an 8-step scanned matmul reports 1/8 the
flops of its unrolled twin).  Fortunately the HLO text carries
``known_trip_count`` on every scan-derived while, so exact accounting is
reconstructable:

  1. split the module into computations,
  2. per computation: result bytes of every collective op; MXU FLOPs of
     every ``dot`` (2 · prod(result dims) · prod(contracted dims));
  3. propagate multipliers through the call graph — `while` multiplies by
     its trip count, call/fusion/reduce by 1, conditional by max branch.

The dry-run records both the flat (body-once) numbers and these loop-aware
numbers; launch/roofline.py uses the latter.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^{]*)?\{\s*$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE = re.compile(
    r"=\s*(\([^)]*\)|[^\s(]+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_WHILE = re.compile(
    r"\swhile\(.*?body=%?([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
# Operands may carry inline types depending on XLA version:
#   dot(%lhs, %rhs)  or  dot(f32[64,128]{1,0} %lhs, f32[128,64]{1,0} %rhs)
_DOT = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^\s]*\s+dot\("
    r"(?:\w+\[([\d,]*)\][^\s]*\s+)?%([\w\.\-]+),")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DEF = re.compile(r"^\s*%([\w\.\-]+) = (\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CompStats:
    coll: Dict[str, list]            # kind -> [count, bytes]
    dot_flops: float
    whiles: list                     # (body_name, trip)
    calls: list                      # called computation names (mult 1)


def _parse(hlo: str):
    # pass 1: module-wide symbol table (instruction name -> dims) so dot
    # operands (referenced by %name without inline types) resolve.
    symbols: Dict[str, list] = {}
    for line in hlo.splitlines():
        dm = _DEF.match(line)
        if dm and dm.group(2) in _DTYPE_BYTES:
            symbols[dm.group(1)] = [int(d) for d in dm.group(3).split(",") if d]

    comps: Dict[str, CompStats] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        if cur is None or line.startswith("}") is False:
            hm = _COMP_HEADER.match(line)
            if hm:
                name = hm.group(2)
                comps[name] = CompStats({}, 0.0, [], [])
                cur = name
                if hm.group(1):
                    entry = name
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        st = comps[cur]
        cm = _COLLECTIVE.search(line)
        if cm and cm.group(3) != "-done":
            kind = cm.group(2)
            b = _shape_bytes(cm.group(1))
            rec = st.coll.setdefault(kind, [0, 0])
            rec[0] += 1
            rec[1] += b
        wm = _WHILE.search(line)
        if wm:
            tm = _TRIP.search(line)
            st.whiles.append((wm.group(1), int(tm.group(1)) if tm else 1))
            continue
        dm = _DOT.search(line)
        if dm:
            out_n = 1
            for d in dm.group(2).split(","):
                if d:
                    out_n *= int(d)
            if dm.group(3) is not None:  # inline lhs type
                lhs_dims = [int(d) for d in dm.group(3).split(",") if d]
            else:
                lhs_dims = symbols.get(dm.group(4), [])
            km = _CONTRACT.search(line)
            contracted = 1
            if km and lhs_dims:
                for idx in km.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        contracted *= lhs_dims[int(idx)]
            st.dot_flops += 2.0 * out_n * contracted
        for cm2 in _CALLED.finditer(line):
            st.calls.append(cm2.group(1))
        bm = _BRANCHES.search(line)
        if bm:
            for b in bm.group(1).split(","):
                b = b.strip().lstrip("%")
                if b:
                    st.calls.append(b)
    return comps, entry


def analyze(hlo: str) -> dict:
    """Loop-aware totals: dot FLOPs + per-kind collective counts/bytes."""
    comps, entry = _parse(hlo)
    mult: Dict[str, float] = {}

    def add(name: str, m: float, depth=0):
        if name not in comps or depth > 64:
            return
        mult[name] = mult.get(name, 0.0) + m
        st = comps[name]
        for body, trip in st.whiles:
            add(body, m * trip, depth + 1)
        for callee in st.calls:
            add(callee, m, depth + 1)

    if entry is None:
        entry = next(iter(comps))
    add(entry, 1.0)

    flops = 0.0
    coll: Dict[str, dict] = {}
    for name, st in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        flops += st.dot_flops * m
        for kind, (cnt, b) in st.coll.items():
            rec = coll.setdefault(kind, {"count": 0.0, "bytes": 0.0})
            rec["count"] += cnt * m
            rec["bytes"] += b * m
    total = sum(v["bytes"] for v in coll.values())
    return {"dot_flops": flops, "collectives": coll,
            "collective_bytes_total": total,
            "n_computations": len(comps)}
