"""Production mesh builders.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any jax
device query).

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is the
DCN tier — pure data parallelism with (optionally compressed) gradient
reduction, no parameter or activation sharding crosses it.
"""
from __future__ import annotations

import jax


def mesh_axis_kwargs(n_axes: int) -> dict:
    """`jax.make_mesh` kwargs for `n_axes` Auto axes, across jax versions:
    jax < 0.5 has neither `jax.sharding.AxisType` nor the `axis_types`
    parameter (Auto is the only behavior), so pass nothing there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_kwargs(len(axes)))


def make_host_mesh(n: int = 0, *, model: int = 1):
    """Small mesh over the locally-visible devices (tests, examples)."""
    n = n or len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         **mesh_axis_kwargs(2))
