"""Step builders: the jitted train/prefill/decode entry points with their
sharding contracts.

Everything the dry-run lowers and the real launcher executes comes from
here, so the 512-chip lowering and the 1-chip smoke test share one code
path.  ``build_*`` returns (fn, in_shardings, out_shardings, arg_specs)
ready for ``jax.jit(fn, in_shardings=..., out_shardings=...).lower(*specs)``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from ..distributed import sharding as shd
from ..models import zoo
from ..optim import adam


def _tree_shardings(axes_tree, shapes_tree, mesh):
    return jax.tree_util.tree_map(
        lambda ax, s: shd.sharding_for(ax, s.shape, mesh),
        axes_tree, shapes_tree)


def build_train_step(model: zoo.Model, opt_cfg: adam.AdamConfig = adam.AdamConfig()):
    """(params, opt_state, batch) -> (params', opt_state', metrics).

    cfg.grad_accum > 1 splits the global batch into microbatches and scans
    over them, accumulating f32 gradients (param-sharded, ZeRO-style).  This
    bounds peak activation residency — the per-layer checkpoint carries of
    ONE microbatch — which is what lets qwen2-72b/arctic-480b train_4k fit
    a 16 GiB v5e chip.  The accumulation loop also overlaps the microbatch
    boundary with the gradient reduce-scatter XLA schedules per leaf."""
    cfg = model.cfg
    accum = max(1, cfg.grad_accum)

    def loss_fn(p, mb):
        loss, metrics = model.forward(p, mb)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)

            def mb_step(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            # f32 accumulation for f32 masters; bf16 masters (arctic-480b:
            # pure-bf16 training, the only way 480B optimizer state fits one
            # pod) accumulate in bf16.
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32
                                    if p.dtype == jnp.float32 else p.dtype),
                params)
            (grads, loss_sum), _ = jax.lax.scan(mb_step, (g0, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = {"loss": loss}
        new_params, new_opt, opt_metrics = adam.update(
            grads, opt_state, params, opt_cfg)
        metrics = dict(metrics, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def train_specs(model: zoo.Model, shape: ShapeSpec, mesh,
                opt_cfg: adam.AdamConfig = adam.AdamConfig()):
    """Abstract args + shardings for train_step on `mesh`."""
    p_shapes, p_axes = model.abstract_params()
    opt_shapes = jax.eval_shape(lambda p: adam.init(p, opt_cfg), p_shapes)
    opt_axes = adam.opt_state_axes(p_axes)
    spec = zoo.input_specs(model.cfg, shape)
    assert spec["kind"] == "train"

    p_sh = _tree_shardings(p_axes, p_shapes, mesh)
    o_sh = _tree_shardings(opt_axes, opt_shapes, mesh)
    b_sh = _tree_shardings(spec["axes"], spec["batch"], mesh)
    metrics_sh = jax.tree_util.tree_map(
        lambda _: shd.sharding_for("", (), mesh),
        jax.eval_shape(lambda: {"loss": jnp.zeros(()),
                                "grad_norm": jnp.zeros(())}))
    return dict(
        args=(p_shapes, opt_shapes, spec["batch"]),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, metrics_sh),
        donate_argnums=(0, 1),
    )


def build_prefill_step(model: zoo.Model, max_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)
    return prefill_step


def prefill_specs(model: zoo.Model, shape: ShapeSpec, mesh):
    p_shapes, p_axes = model.abstract_params()
    spec = zoo.input_specs(model.cfg, shape)
    assert spec["kind"] == "prefill"
    p_sh = _tree_shardings(p_axes, p_shapes, mesh)
    b_sh = _tree_shardings(spec["axes"], spec["batch"], mesh)

    cache_shapes = model.abstract_cache(shape.global_batch, spec["max_len"])
    cache_sh = _tree_shardings(model.cache_axes(), cache_shapes, mesh)
    B = shape.global_batch
    logits_sh = shd.sharding_for(
        "batch|seq|vocab", (B, 1, model.cfg.vocab), mesh)
    return dict(
        args=(p_shapes, spec["batch"]),
        in_shardings=(p_sh, b_sh),
        out_shardings=(cache_sh, logits_sh),
        max_len=spec["max_len"],
        donate_argnums=(),
    )


def build_decode_step(model: zoo.Model):
    def decode_step(params, cache, token):
        return model.decode(params, cache, token)
    return decode_step


def decode_specs(model: zoo.Model, shape: ShapeSpec, mesh):
    p_shapes, p_axes = model.abstract_params()
    spec = zoo.input_specs(model.cfg, shape)
    assert spec["kind"] == "decode"
    B, max_len = spec["cache_batch"], spec["max_len"]
    cache_shapes = model.abstract_cache(B, max_len)
    cache_sh = _tree_shardings(model.cache_axes(), cache_shapes, mesh)
    p_sh = _tree_shardings(p_axes, p_shapes, mesh)
    tok_sh = shd.sharding_for("batch|seq", (B, 1), mesh)
    logits_sh = shd.sharding_for("batch|seq|vocab", (B, 1, model.cfg.vocab), mesh)
    return dict(
        args=(p_shapes, cache_shapes, spec["batch"]["token"]),
        in_shardings=(p_sh, cache_sh, tok_sh),
        out_shardings=(cache_sh, logits_sh),
        donate_argnums=(1,),
    )


def lower_cell(model: zoo.Model, shape: ShapeSpec, mesh, *,
               serve_dtype: str = "bfloat16"):
    """Lower the right step for (arch, shape) on `mesh`; returns Lowered.

    Serving shapes lower with bf16 parameters (inference deployment mode);
    train keeps f32 masters + bf16 compute.
    """
    import dataclasses as dc
    cfg = model.cfg
    with shd.use_mesh(mesh):
        if shape.kind == "train":
            sp = train_specs(model, shape, mesh)
            fn = build_train_step(model)
            return jax.jit(fn, in_shardings=sp["in_shardings"],
                           out_shardings=sp["out_shardings"],
                           donate_argnums=sp["donate_argnums"]).lower(*sp["args"])
        # serving: bf16 params
        serve_cfg = dc.replace(cfg, param_dtype=serve_dtype)
        smodel = zoo.build(serve_cfg)
        smodel = dc.replace(smodel, init=_bf16_init(smodel))
        if shape.kind == "prefill":
            sp = prefill_specs(smodel, shape, mesh)
            fn = build_prefill_step(smodel, sp["max_len"])
            return jax.jit(fn, in_shardings=sp["in_shardings"],
                           out_shardings=sp["out_shardings"]).lower(*sp["args"])
        import contextlib
        ctx = (shd.serve_mode() if cfg.serve_weights_resident
               else contextlib.nullcontext())
        with ctx:
            sp = decode_specs(smodel, shape, mesh)
            fn = build_decode_step(smodel)
            return jax.jit(fn, in_shardings=sp["in_shardings"],
                           out_shardings=sp["out_shardings"],
                           donate_argnums=sp["donate_argnums"]).lower(*sp["args"])


def _bf16_init(model: zoo.Model):
    """Wrap init so serving parameters materialize in bf16."""
    inner = model.init

    def init(key):
        boxed = inner(key)
        return jax.tree_util.tree_map(
            lambda b: type(b)(b.value.astype(jnp.bfloat16)
                              if b.value.dtype == jnp.float32 else b.value,
                              b.axes),
            boxed, is_leaf=lambda x: hasattr(x, "axes"))
    return init
