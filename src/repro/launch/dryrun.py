import os
# while-loop-invariant-code-motion hoists the per-layer bf16->f32 operand
# converts of XLA-CPU's f32 dot/DUS emulation OUT of the layer scan,
# materializing f32 copies of entire stacked weight/cache tensors
# (+22 GiB/device on qwen2-72b decode_32k).  TPU executes bf16 natively, so
# disabling the pass gives memory_analysis numbers closer to the real
# target.  See EXPERIMENTS.md §Perf iteration 3.
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_disable_hlo_passes=while-loop-invariant-code-motion"
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell:
    lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)
    compiled = lowered.compile()
    memory_analysis / cost_analysis / HLO collective scan

The two XLA_FLAGS lines above MUST run before any other import (jax locks
the device count at first init); 512 placeholder host devices back both the
(16,16) single-pod and the (2,16,16) multi-pod meshes.

Outputs one JSON record per cell into ``results/dryrun/<mesh>/<arch>/<shape>.json``
with: per-device memory stats, HLO FLOPs/bytes, per-collective byte counts,
and lowering wall time.  launch/roofline.py turns these into EXPERIMENTS.md
§Dry-run/§Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--single-pod]
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback


_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|[^\s(]+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in (post-SPMD) HLO.

    The per-device HLO already has partitioned shapes, so summed result
    sizes approximate per-device bytes placed on the interconnect (the
    standard roofline accounting; all-gather results count the gathered
    size, reduce-scatter the scattered size).  Tuple results (multi-operand
    reductions, async -start forms) sum their components; -done ops are
    skipped so async pairs count once.  NOTE: ops inside `while` bodies
    count once per body — the roofline layer multiplies by trip counts
    (scan length) analytically, same as for FLOPs."""
    out = {}
    for m in _LINE_RE.finditer(hlo_text):
        result_ty, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue
        total = 0
        for sm in _SHAPE_RE.finditer(result_ty):
            dtype, dims = sm.group(1), sm.group(2)
            if dtype not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dtype]
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += total
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: pathlib.Path, verbose: bool = True) -> dict:
    import jax
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.models import zoo
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(map(str, mesh.devices.shape))

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "n_devices": mesh.devices.size,
           "status": "ok"}
    t0 = time.time()
    try:
        model = zoo.build(cfg)
        lowered = lower_cell(model, shape, mesh)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        if ma is not None:
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "peak_device_bytes": int(ma.argument_size_in_bytes
                                         + ma.output_size_in_bytes
                                         + ma.temp_size_in_bytes
                                         - ma.alias_size_in_bytes),
            }
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        if ca:
            rec["cost"] = {k: float(v) for k, v in ca.items()
                           if k in ("flops", "bytes accessed", "transcendentals",
                                    "optimal_seconds")}
        hlo = compiled.as_text()
        rec["collectives_flat"] = collective_bytes(hlo)
        from repro.launch.hlo_analysis import analyze
        la = analyze(hlo)
        rec["loop_aware"] = la
        rec["collectives"] = la["collectives"]
        rec["collective_bytes_total"] = int(la["collective_bytes_total"])
        rec["hlo_bytes"] = len(hlo)
    except Exception as e:  # noqa: BLE001 - a failing cell is a bug report
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]

    out_path = out_dir / mesh_name / arch / f"{shape_name}.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    if verbose:
        mem = rec.get("memory", {}).get("peak_device_bytes", 0) / 2**30
        flops = rec.get("cost", {}).get("flops", 0)
        print(f"[{rec['status']:5s}] {mesh_name:10s} {arch:20s} {shape_name:12s}"
              f" lower={rec.get('lower_s', 0):6.1f}s"
              f" compile={rec.get('compile_s', 0):6.1f}s"
              f" mem/dev={mem:6.2f}GiB flops/dev={flops:.3e}"
              f" coll={rec.get('collective_bytes_total', 0)/2**30:7.3f}GiB",
              flush=True)
        if rec["status"] != "ok":
            print("   ", rec["error"], flush=True)
    return rec


def cells_for(arch: str):
    from repro.configs import get_config
    return list(get_config(arch).shapes().keys())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="only the (2,16,16) mesh")
    ap.add_argument("--single-pod", action="store_true",
                    help="only the (16,16) mesh")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args(argv)

    from repro.configs import ARCH_IDS
    out_dir = pathlib.Path(args.out)
    meshes = [False, True]
    if args.multi_pod:
        meshes = [True]
    elif args.single_pod:
        meshes = [False]

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    failures = 0
    for multi in meshes:
        for arch in archs:
            shapes = [args.shape] if args.shape else cells_for(arch)
            for shape in shapes:
                rec = run_cell(arch, shape, multi_pod=multi, out_dir=out_dir)
                failures += rec["status"] != "ok"
    print(f"done; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
