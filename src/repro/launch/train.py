"""Training launcher: the end-to-end driver (deliverable b).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --reduced --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ck --resume

Wires every substrate together: config → model zoo → sharded data pipeline
→ jitted train step (FSDP/TP shardings from the logical-axis policy) →
async atomic checkpoints → preemption guard → straggler monitor.  On this
CPU container it drives reduced configs; on a TPU pod the same file runs
the full ones (the mesh adapts to the visible devices).
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import numpy as np

log = logging.getLogger("repro.train")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced config (smoke/examples)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(message)s")

    from repro.configs import get_config, reduced_for_smoke
    from repro.data.pipeline import DataConfig, DataIterator
    from repro.distributed import sharding as shd
    from repro.checkpoint.checkpoint import Checkpointer
    from repro.models import zoo
    from repro.models.base import tree_unbox
    from repro.optim import adam
    from repro.runtime.fault_tolerance import (PreemptionGuard,
                                               StragglerMonitor)
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import build_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_for_smoke(cfg)
    mesh = make_host_mesh(model=args.model_parallel)
    log.info("arch=%s mesh=%s params(full)=%.2fB", cfg.name,
             dict(zip(mesh.axis_names, mesh.devices.shape)),
             cfg.n_params() / 1e9)

    model = zoo.build(cfg)
    opt_cfg = adam.AdamConfig(lr=args.lr)

    with shd.use_mesh(mesh):
        boxed = model.init(jax.random.PRNGKey(0))
        params, p_axes = tree_unbox(boxed)
        p_sh = shd.tree_shardings(
            p_axes, jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params), mesh)
        params = jax.tree_util.tree_map(jax.device_put, params, p_sh)
        opt_state = adam.init(params, opt_cfg)

        data_cfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                              vocab=cfg.vocab)
        batch_sh = {
            "tokens": shd.sharding_for("batch|seq", (args.batch, args.seq), mesh),
            "labels": shd.sharding_for("batch|seq", (args.batch, args.seq), mesh),
        }
        it = DataIterator(data_cfg, sharding=batch_sh)

        ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
        start_step = 0
        if ckpt and args.resume and ckpt.latest_step() is not None:
            state = {"params": params, "opt": opt_state}
            state, start_step, extra = ckpt.restore(state)
            params, opt_state = state["params"], state["opt"]
            it.load_state_dict(extra.get("data", {"step": start_step}))
            log.info("resumed from step %d", start_step)

        step_fn = jax.jit(build_train_step(model, opt_cfg),
                          donate_argnums=(0, 1))
        guard = PreemptionGuard()
        monitor = StragglerMonitor()

        losses = []
        t_start = time.perf_counter()
        for step in range(start_step, args.steps):
            t0 = time.perf_counter()
            batch = next(it)
            extra = {}
            if cfg.family == "vlm":
                extra["patch_embs"] = jax.device_put(np.zeros(
                    (args.batch, cfg.n_patches, cfg.d_model), np.float32))
            if cfg.family == "encdec":
                extra["frames"] = jax.device_put(np.zeros(
                    (args.batch, cfg.enc_len, cfg.d_model), np.float32))
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 {**batch, **extra})
            loss = float(metrics["loss"])
            losses.append(loss)
            monitor.record(step, time.perf_counter() - t0)

            if step % args.log_every == 0 or step == args.steps - 1:
                log.info("step %5d loss %.4f gnorm %.3f (%.0f ms)", step, loss,
                         float(metrics["grad_norm"]),
                         1e3 * (time.perf_counter() - t0))
            want_ckpt = ckpt and (step + 1) % args.ckpt_every == 0
            if want_ckpt or (ckpt and guard.requested):
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          extra={"data": it.state_dict(), "loss": loss},
                          blocking=guard.requested)
            if guard.requested:
                log.warning("preempted: exiting cleanly at step %d", step + 1)
                break

        if ckpt:
            ckpt.wait()
        dt = time.perf_counter() - t_start
        tokens = (len(losses)) * args.batch * args.seq
        log.info("done: %d steps, %.1f tok/s, loss %.4f -> %.4f",
                 len(losses), tokens / max(dt, 1e-9), losses[0], losses[-1])
        return losses


if __name__ == "__main__":
    main()
