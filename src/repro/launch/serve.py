"""Serving load generator: concurrent tenants over one disk matrix.

    PYTHONPATH=src python -m repro.launch.serve --clients 4 --waves 3

Drives the async serving layer (``fm.serve`` / `core/serve.Engine`) the
way the paper's workflow is actually deployed: many clients concurrently
request independent analytics over the SAME named SSD-resident matrix.
Two arms over identical request traffic:

  serial   every request is its own ``fm.materialize`` — k clients ×
           w waves pay k·w full scans of the source;
  serve    requests go through an Engine admission window — each wave's
           k same-source strangers coalesce onto ONE streaming drive
           (``exec_stats()['streams'] == waves``), so the disk tier is
           read once per wave, not once per request.

Emits one machine-readable ``BENCH {json}`` row per arm: requests/sec,
p50/p99 latency (reported, NOT gated — thread scheduling jitters them),
plus the deterministic engine evidence the CI regression gate compares
exactly — ``streams`` and ``bytes_per_request`` (bytes streamed off the
disk tier divided by requests served).  Window coalescing is what moves
``bytes_per_request``: the serve arm's value is the serial arm's divided
by the number of clients.

The arms run with mid-stream admission disabled and the window held open
for exactly one wave (``max_window_requests=clients`` + a client-side
barrier), so the schedule — and therefore every gated counter — is
deterministic.
"""
from __future__ import annotations

import argparse
import json
import logging
import threading
import time

import numpy as np

log = logging.getLogger("repro.serve")

#: The per-client request mix: client i of a wave submits mix[i % len].
#: All single-pass over the shared source, so every wave forms ONE group.
def _request_mix(fm):
    return (fm.colMeans, fm.colSums, lambda X: fm.colMaxs(X), fm.sum_)


def _percentile(sorted_us, q):
    if not sorted_us:
        return 0.0
    idx = min(len(sorted_us) - 1, int(round(q * (len(sorted_us) - 1))))
    return sorted_us[idx]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=60_000)
    ap.add_argument("--p", type=int, default=8)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--window-ms", type=float, default=2000.0,
                    help="admission window upper bound; each wave closes "
                         "it early via max_window_requests")
    ap.add_argument("--partition-kib", type=int, default=256)
    ap.add_argument("--name", default="serve_loadgen_x")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    from repro.core import fm
    from repro.core import materialize as mz
    from repro.core import matrix as matrix_mod
    from repro.observability import metrics

    old_io = matrix_mod.IO_PARTITION_BYTES
    fm.set_conf(io_partition_bytes=args.partition_kib << 10)
    try:
        rng = np.random.default_rng(0)
        X_np = rng.normal(size=(args.n, args.p)).astype(np.float32)
        X = fm.load_dense_matrix(X_np, args.name)  # the disk tier
        mix = _request_mix(fm)
        k, waves = args.clients, args.waves
        n_requests = k * waves
        records = []

        for arm in ("serial", "serve"):
            mz.clear_plan_cache()
            mz.reset_exec_stats()
            latencies_us = []
            lat_lock = threading.Lock()
            t_arm = time.perf_counter()

            if arm == "serial":
                for _ in range(waves):
                    for i in range(k):
                        t0 = time.perf_counter()
                        fm.materialize(mix[i % len(mix)](X))
                        latencies_us.append(
                            1e6 * (time.perf_counter() - t0))
            else:
                eng = fm.serve(window_ms=args.window_ms,
                               max_window_requests=k,
                               midstream_admission=False)
                try:
                    for _ in range(waves):
                        barrier = threading.Barrier(k)
                        errors = []

                        def client(i):
                            try:
                                out = mix[i % len(mix)](X)
                                barrier.wait(timeout=30)
                                t0 = time.perf_counter()
                                eng.submit(out).result(timeout=300)
                                us = 1e6 * (time.perf_counter() - t0)
                                with lat_lock:
                                    latencies_us.append(us)
                            except Exception as exc:  # noqa: BLE001
                                errors.append(exc)

                        threads = [threading.Thread(target=client, args=(i,))
                                   for i in range(k)]
                        for t in threads:
                            t.start()
                        for t in threads:
                            t.join(timeout=600)
                        if errors:
                            raise errors[0]
                finally:
                    eng.close()

            wall_s = time.perf_counter() - t_arm
            st = mz.exec_stats()
            streamed = int(metrics.root_counter("bytes_streamed"))
            lat = sorted(latencies_us)
            record = {
                "bench": "serve", "workload": "mixed-analytics",
                "arm": arm, "mode": "disk", "backend": "xla",
                "n": args.n, "p": args.p,
                "clients": k, "waves": waves, "requests": n_requests,
                "us_per_call": round(1e6 * wall_s / n_requests, 1),
                "rps": round(n_requests / max(wall_s, 1e-9), 1),
                "us_p50": round(_percentile(lat, 0.50), 1),
                "us_p99": round(_percentile(lat, 0.99), 1),
                # Deterministic engine evidence (CI gates these exactly):
                # serve = one stream per wave; serial = one per request.
                "streams": st["streams"],
                "bytes_per_request": streamed // n_requests,
            }
            print("BENCH " + json.dumps(record, sort_keys=True))
            log.info(
                "%-6s %d requests (%d clients x %d waves): %.1f req/s, "
                "p50 %.1fms p99 %.1fms, streams=%d, %.2f MiB/request",
                arm, n_requests, k, waves, record["rps"],
                record["us_p50"] / 1e3, record["us_p99"] / 1e3,
                record["streams"], record["bytes_per_request"] / 2**20)
            records.append(record)

        serial, served = records
        assert served["streams"] == waves, (
            "window coalescing broken: expected one stream per wave, got "
            f"{served['streams']} for {waves} waves")
        assert served["bytes_per_request"] * n_requests \
            < serial["bytes_per_request"] * n_requests, (
            "serve arm must read strictly fewer bytes than serial")
        log.info("coalescing: %d same-source requests/window -> 1 stream; "
                 "bytes/request %.2f MiB -> %.2f MiB (%.1fx)",
                 k, serial["bytes_per_request"] / 2**20,
                 served["bytes_per_request"] / 2**20,
                 serial["bytes_per_request"]
                 / max(served["bytes_per_request"], 1))
        return records
    finally:
        matrix_mod.IO_PARTITION_BYTES = old_io


def run(argv=None):
    """benchmarks/check_regression.py entry point."""
    return main(argv)


if __name__ == "__main__":
    main()
