"""Serving launcher: batched prefill + decode loop (deliverable b).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --batch 4 --prompt-len 32 --gen 16

Demonstrates the production serving path on any architecture family:
batched prefill fills the KV/SSM caches, then a jitted decode step emits
one token per request per iteration (greedy).  The same step function is
what decode_32k / long_500k lower on the 256/512-chip meshes.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

log = logging.getLogger("repro.serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    from repro.configs import get_config, reduced_for_smoke
    from repro.distributed import sharding as shd
    from repro.models import zoo
    from repro.models.base import tree_unbox
    from repro.launch.mesh import make_host_mesh

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_for_smoke(cfg)
    mesh = make_host_mesh(model=args.model_parallel)
    model = zoo.build(cfg)

    rng = np.random.default_rng(0)
    B, P = args.batch, args.prompt_len
    max_len = P + args.gen + (cfg.n_patches or 0)

    with shd.use_mesh(mesh):
        params, _ = tree_unbox(model.init(jax.random.PRNGKey(0)))
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, P)), jnp.int32)}
        if cfg.family == "vlm":
            batch["patch_embs"] = jnp.zeros((B, cfg.n_patches, cfg.d_model),
                                            jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, cfg.enc_len, cfg.d_model),
                                        jnp.float32)

        t0 = time.perf_counter()
        prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
        cache, logits = prefill(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        log.info("prefill: %d x %d tokens in %.1f ms", B, P, 1e3 * t_prefill)

        decode = jax.jit(model.decode)
        tok = jnp.argmax(logits[:, -1], axis=-1).reshape(B, 1).astype(jnp.int32)
        generated = [tok]
        t0 = time.perf_counter()
        for _ in range(args.gen - 1):
            cache, logits = decode(params, cache, tok)
            tok = jnp.argmax(logits[:, -1], axis=-1).reshape(B, 1).astype(jnp.int32)
            generated.append(tok)
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
        out = np.concatenate([np.asarray(t) for t in generated], axis=1)
        log.info("decode: %d tokens/request, %.2f tok/s/request "
                 "(%.1f ms/step batch=%d)", out.shape[1],
                 (out.shape[1] - 1) / max(dt, 1e-9),
                 1e3 * dt / max(out.shape[1] - 1, 1), B)
        log.info("sample token ids: %s", out[0][:16].tolist())
        return out


if __name__ == "__main__":
    main()
