"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh).

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

Hardware constants (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  All inputs come from the dry-run JSON records
(results/dryrun/...), which carry BOTH the flat cost_analysis numbers and
the loop-aware HLO reconstruction (launch/hlo_analysis.py) — the loop-aware
numbers are authoritative because XLA-CPU's cost_analysis counts while
bodies once (see that module's docstring).

Because the per-device HLO is per-step and already partitioned, the terms
here are per-device = per-chip seconds directly (no ÷chips needed).

MODEL_FLOPS uses the 6·N·D rule (6·N_active·D for MoE), D = tokens per
step; the ratio MODEL_FLOPS / HLO_FLOPs measures how much compiled compute
is "useful" (remat recompute, masked attention tiles, capacity padding and
replicated-because-unshardable compute all push it down).

Memory-term caveat (CPU dry-run): 'bytes accessed' is also body-once, so
the memory term uses an analytic lower bound — every HBM-resident input
read once + outputs written once (params+opt+batch+cache from
memory_analysis argument/output sizes) plus per-layer activation traffic —
and reports the cost_analysis number alongside.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]
        [--emit markdown|json]
"""
from __future__ import annotations

import argparse
import json
import pathlib

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (v5e: ~4 usable links/chip)

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one token per sequence
    "long_500k": 1,
}


def model_flops(arch: str, shape: str, n_devices: int) -> float:
    """6·N_active·D per step (fwd+bwd); serve shapes: 2·N_active·D."""
    from repro.configs import get_config
    cfg = get_config(arch)
    n = cfg.n_active_params()
    d = SHAPE_TOKENS[shape]
    mult = 6.0 if shape.startswith("train") else 2.0
    return mult * n * d / n_devices


def analytic_hbm_bytes(rec: dict) -> float:
    """Per-device HBM traffic lower bound: arguments read + outputs written
    + one activation write/read per layer boundary (scan carries)."""
    mem = rec.get("memory", {})
    base = mem.get("argument_bytes", 0) + mem.get("output_bytes", 0)
    # temp buffers are written+read at least once across the step
    base += 2 * mem.get("temp_bytes", 0) * 0.5
    return float(base)


def load_cells(root: pathlib.Path):
    cells = []
    for f in sorted(root.glob("*/*/*.json")):
        rec = json.loads(f.read_text())
        cells.append(rec)
    return cells


def derive(rec: dict) -> dict:
    arch, shape = rec["arch"], rec["shape"]
    n_dev = rec["n_devices"]
    la = rec.get("loop_aware", {})
    hlo_flops = la.get("dot_flops", 0.0)
    coll_bytes = la.get("collective_bytes_total", 0.0)
    hbm_bytes = analytic_hbm_bytes(rec)

    t_compute = hlo_flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape, n_dev)
    useful = mf / hlo_flops if hlo_flops else float("nan")
    bound = max(terms.values())
    mfu_bound = (mf / PEAK_FLOPS) / bound if bound > 0 else float("nan")
    return {
        "arch": arch, "shape": shape, "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops": hlo_flops,
        "useful_ratio": useful, "roofline_fraction": mfu_bound,
        "mem_gib": rec.get("memory", {}).get("peak_device_bytes", 0) / 2 ** 30,
        "status": rec.get("status"),
    }


def markdown(rows, single_pod_only=True):
    out = ["| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | 6ND/HLO | roofline frac | mem GiB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if single_pod_only and r["mesh"] != "16x16":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {r['mem_gib']:.1f} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--emit", default="markdown", choices=["markdown", "json"])
    ap.add_argument("--all-meshes", action="store_true")
    args = ap.parse_args(argv)
    cells = load_cells(pathlib.Path(args.dir))
    rows = [derive(r) for r in cells if r.get("status") == "ok"]
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    if args.emit == "json":
        print(json.dumps(rows, indent=1))
    else:
        print(markdown(rows, single_pod_only=not args.all_meshes))


if __name__ == "__main__":
    main()
