"""arctic-480b [moe]: dense-MoE hybrid. 35L d_model=7168 56H (GQA kv=8)
d_ff=4864, MoE 128 experts top-2 + dense residual path, vocab=32000
[hf:Snowflake/snowflake-arctic-base].  The dense FFN runs in parallel with
the routed experts and the outputs sum (Arctic's residual design)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab=32000,
    n_experts=128, top_k=2, moe_d_ff=4864, dense_residual=True,
    # 480B optimizer state cannot fit a single pod in f32: pure-bf16
    # training (bf16 masters/moments/grad-accum) is the deployment mode.
    param_dtype="bfloat16", grad_accum=8, serve_weights_resident=False,
)
