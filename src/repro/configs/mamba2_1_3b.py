"""mamba2-1.3b [ssm]: SSD (state-space duality), attention-free.
48L d_model=2048 d_state=128 vocab=50280 [arXiv:2405.21060; unverified].
expand=2 -> d_inner=4096, headdim=64 -> 64 ssm heads, conv width 4."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_conv=4,
    grad_accum=2,
)
