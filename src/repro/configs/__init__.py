"""Assigned architecture configs (--arch <id>) + the paper's own workloads."""
from . import base
from .base import ArchConfig, SHAPES, ShapeSpec, reduced_for_smoke

ARCH_IDS = [
    "paligemma-3b", "llama3.2-3b", "granite-8b", "qwen2-72b", "qwen2-0.5b",
    "arctic-480b", "qwen3-moe-30b-a3b", "mamba2-1.3b", "zamba2-7b",
    "whisper-medium",
]


def get_config(arch_id: str) -> ArchConfig:
    import importlib
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


__all__ = ["base", "ArchConfig", "SHAPES", "ShapeSpec", "reduced_for_smoke",
           "ARCH_IDS", "get_config"]
