"""whisper-medium [audio]: encoder-decoder; conv frontend is a STUB
(`input_specs()` provides precomputed frame embeddings, 1500 frames).
24 enc + 24 dec layers, d_model=1024 16H (MHA) d_ff=4096 vocab=51865
[arXiv:2212.04356; unverified].  LayerNorm + GELU (no GLU), learned
positions; decoder has causal self-attn + cross-attn to the encoder."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=4096, vocab=51865,
    act="gelu", norm="layernorm", enc_len=1500,
)
