"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4, head_dim=128)
expert d_ff=768, 128 experts top-8, vocab=151936 [hf:Qwen/Qwen3-30B-A3B]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936,
    n_experts=128, top_k=8, moe_d_ff=768, rope_theta=1000000.0,
    grad_accum=2,
)
