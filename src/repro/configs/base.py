"""Architecture configuration schema + assigned input shapes.

One ``ArchConfig`` per assigned architecture lives in
``src/repro/configs/<id>.py`` with the exact published numbers; every config
also provides ``reduced()`` — the same family scaled down for CPU smoke
tests (small layers/width, few experts, tiny vocab), per the assignment.

The four assigned input-shape sets are global (LM-family):

    train_4k     seq 4096  × global_batch 256   (train_step)
    prefill_32k  seq 32768 × global_batch 32    (serve_step, prefill)
    decode_32k   seq 32768 × global_batch 128   (serve_step, 1 new token)
    long_500k    seq 524288 × global_batch 1    (sub-quadratic only)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 => d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    act: str = "swiglu"            # swiglu | geglu | gelu
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0              # expert hidden (defaults to d_ff)
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_ngroups: int = 1

    # hybrid (zamba2): one shared attention block applied every N ssm layers
    shared_attn_every: int = 0

    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_len: int = 1500            # fixed audio frame count (stub frontend)

    # vlm (paligemma)
    n_patches: int = 0             # prepended image patch embeddings (stub)

    # distribution/runtime knobs
    dtype: str = "bfloat16"        # compute/activation dtype
    param_dtype: str = "float32"   # master weights
    remat: bool = True             # activation checkpointing per layer
    scan_layers: bool = True
    grad_accum: int = 1            # microbatches per train step
    seq_parallel: bool = True      # shard the residual-stream carry on seq
    # Serve-time weights-resident mode (replicate params over `data`): zero
    # steady-state weight traffic per decoded token.  Off for models whose
    # bf16 weights exceed per-device HBM when sharded on `model` alone
    # (arctic-480b: 960 GB / 16 = 60 GB) — those keep FSDP sharding and pay
    # the per-token gather instead.
    serve_weights_resident: bool = True

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """long_500k runs only for sub-quadratic families (DESIGN.md §3)."""
        return self.family in ("ssm", "hybrid")

    @property
    def moe_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def shapes(self):
        out = {}
        for name, s in SHAPES.items():
            if name == "long_500k" and not self.supports_long_context:
                continue
            out[name] = s
        return out

    def n_params(self) -> float:
        """Approximate parameter count (for 6ND roofline math)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        attn = (d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
                if nh else 0)
        glu = 3 if self.act in ("swiglu", "geglu") else 2
        per_layer = 0.0
        if self.family == "ssm":
            per_layer = _ssm_params(self)
        else:
            per_layer += attn
            if self.n_experts:
                per_layer += self.n_experts * glu * d * self.moe_ff + d * self.n_experts
                if self.dense_residual:
                    per_layer += glu * d * f
            else:
                per_layer += glu * d * f
        total = self.n_layers * per_layer
        if self.family == "hybrid":
            n_apps = self.n_layers // max(1, self.shared_attn_every)
            total = self.n_layers * _ssm_params(self) + (attn + glu * d * f)
            del n_apps  # weights shared: count once
        if self.family == "encdec":
            enc = self.n_enc_layers * (attn + 2 * d * f)
            dec = self.n_layers * (2 * attn + 2 * d * f)
            total = enc + dec
        emb = v * d * (1 if self.tie_embeddings else 2)
        return float(total + emb)

    def n_active_params(self) -> float:
        """Active-per-token params (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.n_params()
        d = self.d_model
        glu = 3 if self.act in ("swiglu", "geglu") else 2
        full_moe = self.n_layers * self.n_experts * glu * d * self.moe_ff
        active_moe = self.n_layers * self.top_k * glu * d * self.moe_ff
        return self.n_params() - full_moe + active_moe


def _ssm_params(cfg: ArchConfig) -> float:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    nheads = d_in // cfg.ssm_headdim
    n = cfg.ssm_state
    proj_in = d * (2 * d_in + 2 * cfg.ssm_ngroups * n + nheads)
    conv = (d_in + 2 * cfg.ssm_ngroups * n) * cfg.ssm_conv
    return proj_in + conv + 3 * nheads + d_in + d_in * d


def reduced_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Shrink a config to CPU-smoke scale, preserving the family structure."""
    return dataclasses.replace(
        cfg,
        n_layers=min(cfg.n_layers, 2),
        n_enc_layers=min(cfg.n_enc_layers, 2) if cfg.n_enc_layers else 0,
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        moe_d_ff=64 if cfg.n_experts else 0,
        vocab=512,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=32 if cfg.ssm_state else 64,
        shared_attn_every=2 if cfg.shared_attn_every else 0,
        enc_len=32 if cfg.family == "encdec" else cfg.enc_len,
        n_patches=8 if cfg.n_patches else 0,
        dtype="float32",
        param_dtype="float32",
        remat=False,
    )
