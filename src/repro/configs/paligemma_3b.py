"""paligemma-3b [vlm]: SigLIP patch-embedding stub + Gemma-2B decoder.

18L d_model=2048 8H (MQA kv=1, head_dim=256) d_ff=16384 vocab=257216
[arXiv:2407.07726; hf].  Image patches enter as 256 precomputed embeddings
(`input_specs()` stub per the assignment); text follows, causal LM loss on
the text span.  Gemma-style: GeGLU, tied embeddings, rms-norm.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216,
    act="geglu", tie_embeddings=True, n_patches=256,
)
