"""Logical-axis sharding policy (DESIGN.md §4).

Every parameter/activation/cache tensor carries logical axis names (encoded
'|'-joined, see models/base.py).  ``resolve`` maps them to a PartitionSpec
for the active mesh with *divisibility-checked greedy assignment*:

* each logical axis has an ordered candidate list of mesh-axis groups;
* a candidate is taken iff every component mesh axis is still unused in
  this tensor's spec and the dim size divides evenly;
* otherwise fall through (ultimately replicate) — this is how paligemma's
  8 heads survive a 16-way model axis (heads replicate, d_ff/vocab still
  shard) and how long_500k's batch=1 hands the `data` axis to the KV
  sequence dimension instead.

Parameter `d_model` dims shard over `data` — FSDP/ZeRO-style — so optimizer
state for the 72B/480B configs fits HBM; gradients inherit the same specs,
which makes XLA emit reduce-scatter + all-gather instead of plain
all-reduce (the ZeRO collective schedule).  The `pod` axis is pure data
parallelism: the only cross-pod (DCN) traffic is the gradient reduction,
optionally int8-compressed (optim/compression.py).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Ordered candidates per logical axis.  Tuples are axis groups (sharded over
# the product).  First fit wins.
RULES: dict[str, list] = {
    # parameters
    "vocab": [("model",)],
    "d_ff": [("model",)],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "experts": [("model",)],
    "d_inner": [("model",)],
    "d_model": [("data",)],          # FSDP axis (params + optimizer state)
    "layers": [],
    "head_dim": [],
    "state": [],
    "conv": [],
    # activations
    "batch": [("pod", "data"), ("data",), ("pod",)],
    "seq": [],
    # Sequence-parallel residual stream (Megatron-SP style): the layer-scan
    # carry — the dominant activation-checkpoint residency, L·B·S·D bytes —
    # shards its sequence dim over `model`; attention/SSD gather it per
    # layer, norms/MLP stay seq-local.  Only used at scan-carry boundaries.
    "act_seq": [("model",)],
    "embed": [],                      # activation d_model: replicated
    "act_ff": [("model",)],
    "act_heads": [("model",)],
    "act_inner": [("model",)],
    "capacity": [],
    # decode caches: prefer giving spare axes to the KV sequence
    "kv_seq": [("data", "model"), ("model",), ("data",)],
    "apps": [],                       # zamba2 shared-block applications
    "rep": [],                        # force-replicated (gathered KV in
                                      # sequence-parallel attention)
    # Engine matrices: the long (streamed-row) dimension of a materialized
    # output shards over the data tier — the sharded partition loop
    # (core/materialize) writes each device's row range; sinks/epilogue
    # values use "rep".  Falls through to replicate when the row count
    # does not divide (resolve's divisibility check).
    "rows": [("pod", "data"), ("data",)],
}

#: Mesh axes that carry the engine's DATA tier: the I/O-level partition
#: loop shards its row ranges over the product of these axes; any other
#: axis (``model``) replicates the sweep.  Shared with
#: ``materialize._long_spec`` so the whole-mode input sharding and the
#: streaming shard runner always agree on the shard count.
DATA_AXES = ("pod", "data", "x", "i")


def mesh_data_axes(mesh: Mesh) -> tuple:
    """The mesh's data-tier axis names, in mesh order (never empty: a mesh
    with no recognized data axis falls back to its first axis)."""
    axes = tuple(a for a in mesh.axis_names if a in DATA_AXES)
    return axes or (mesh.axis_names[0],)


def data_axis_size(mesh: Mesh) -> int:
    """Number of row shards the engine's partition loop splits into."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in mesh_data_axes(mesh):
        n *= int(sizes[a])
    return n


def shard_devices(mesh: Mesh) -> list:
    """One representative device per data shard (index 0 along non-data
    axes), in row-shard order — the devices the sharded partition loop
    drives its per-shard prefetchers and fused steps on."""
    import numpy as np
    names = list(mesh.axis_names)
    devs = np.asarray(mesh.devices, dtype=object)
    data_idx = [names.index(a) for a in mesh_data_axes(mesh)]
    other = [i for i in range(devs.ndim) if i not in data_idx]
    devs = np.transpose(devs, data_idx + other).reshape(
        data_axis_size(mesh), -1)
    return list(devs[:, 0])


def resolve(axes: str, shape, mesh: Mesh) -> P:
    """'batch|seq|embed' + shape -> PartitionSpec for this mesh."""
    names = axes.split("|") if axes else []
    assert len(names) == len(shape), (axes, shape)
    used: set[str] = set()
    spec = []
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    serve = is_serve_mode()
    for name, dim in zip(names, shape):
        placed = None
        rules = RULES.get(name, [])
        if serve and name == "d_model":
            rules = []                 # weights-resident decode (no FSDP)
        for cand in rules:
            cand = tuple(a for a in cand if a in mesh_sizes)
            if not cand or any(a in used for a in cand):
                continue
            prod = 1
            for a in cand:
                prod *= mesh_sizes[a]
            if prod > 1 and dim % prod == 0:
                placed = cand
                used.update(cand)
                break
        spec.append(placed[0] if placed and len(placed) == 1
                    else (placed if placed else None))
    return P(*spec)


def sharding_for(axes: str, shape, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, resolve(axes, shape, mesh))


def tree_shardings(axes_tree, shapes_tree, mesh: Mesh):
    """Map (axes, ShapeDtypeStruct) trees -> NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda ax, s: sharding_for(ax, s.shape, mesh), axes_tree, shapes_tree)


# ---------------------------------------------------------------------------
# Activation hints: a thread-local "current mesh" so model code can annotate
# intermediates without threading the mesh through every call.
# ---------------------------------------------------------------------------

_TLS = threading.local()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = getattr(_TLS, "mesh", None)
    _TLS.mesh = mesh
    try:
        yield
    finally:
        _TLS.mesh = prev


@contextlib.contextmanager
def serve_mode():
    """Serving sharding profile: parameters replicate over `data` instead
    of FSDP-sharding on it.

    Training amortizes the per-layer FSDP all-gather over a whole batch;
    a decode step reads every weight once per TOKEN, so gathering ~9 GB of
    weights per generated token made qwen2-72b decode_32k collective-bound
    by 600x (EXPERIMENTS.md §Perf iteration 3).  Weights-resident decode
    trades the (affordable at inference: no optimizer state) memory for
    zero steady-state parameter traffic."""
    prev = getattr(_TLS, "serve", False)
    _TLS.serve = True
    try:
        yield
    finally:
        _TLS.serve = prev


def is_serve_mode() -> bool:
    return getattr(_TLS, "serve", False)


def current_mesh() -> Optional[Mesh]:
    return getattr(_TLS, "mesh", None)


def hint(x, axes: str):
    """with_sharding_constraint if a mesh is active; identity otherwise."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, sharding_for(axes, x.shape, mesh))


def hint_tree(tree, axes_tree):
    """Constrain every leaf of a pytree to its logical-axes sharding.

    Critical inside scan-over-layers bodies: without a per-slice constraint
    GSPMD may hoist the FSDP all-gather of the *entire stacked* parameter
    tree out of the scan (observed: 245 GiB/device on qwen2-72b).  With it,
    the sliced layer stays data-sharded and the gather happens one layer at
    a time inside the loop — the FSDP schedule."""
    mesh = current_mesh()
    if mesh is None:
        return tree
    return jax.tree_util.tree_map(lambda x, ax: hint(x, ax), tree, axes_tree)
