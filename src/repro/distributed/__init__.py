"""Distribution layer: logical-axis sharding policy + helpers."""
from . import sharding
