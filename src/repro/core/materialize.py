"""Materialization engine (paper §III-F).

Executes a fused `fusion.Plan` in one of three modes:

* ``whole``  — the entire long dimension in one fused XLA computation.  The
  default for in-memory (device-resident) matrices; XLA performs the
  CPU-cache/VMEM-level fusion that the paper implements by hand, and an
  optional device mesh shards the long dimension for data-parallel
  execution (partition-per-device ≙ the paper's partition-per-thread, with
  `psum`-style combines materializing the sinks).
* ``stream`` — explicit I/O-level partition loop on device: the 2-level-
  partitioning demonstrator and the building block of out-of-core.
* ``ooc``    — sources live on a slow tier: host RAM (numpy) or the real
  disk tier (`storage.MmapStore` over the on-disk matrix format).
  Partitions are staged by a double-buffered background prefetcher
  (`storage.PartitionPrefetcher`): the disk read + host→device copy of
  partition i+1 overlaps the compute of partition i (the paper's
  I/O/compute overlap).  The fused step consumes staged blocks with buffer
  donation (the paper's memory-chunk recycling), and long-dimension
  outputs write through to preallocated host buffers or — with
  ``save='disk'`` — stream into a preallocated on-disk matrix (spill).

Sinks accumulate partition partials and merge with the aggregation VUDF's
``combine`` — identical in all three modes, which is exactly why the paper's
out-of-core execution can match in-memory performance once arithmetic
intensity is high enough.
"""
from __future__ import annotations

import contextlib
import threading
import time
import warnings
from collections import OrderedDict
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# Buffer donation is the memory-chunk-recycling analog (DESIGN.md §1); when a
# donated block has no same-shaped output XLA declines it — harmless, and on
# CPU (this container) donation is advisory anyway.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

from . import dtypes, lowering
from .dag import LeafNode, Node, as_node, wrap
from .fusion import Plan
from .matrix import DenseStore, FMMatrix
from ..observability import metrics
from ..observability.trace import TRACER

try:  # NamedSharding is only used when a mesh is passed.
    from jax.sharding import NamedSharding, PartitionSpec as P
except ImportError:  # pragma: no cover
    NamedSharding = None
    P = None


# Compiled-plan cache: structurally identical DAG cuts (k-means iteration
# N+1, GMM E-steps, any steady-state loop) reuse one jitted executable —
# the compile-once/stream-many behavior a production engine needs.  Keyed
# by Plan.signature() plus the mesh's structural identity (axis names +
# shape; NOT id(mesh), which a garbage collector can reissue to a
# different mesh), with LRU eviction at PLAN_CACHE_LIMIT.
_PLANS: "OrderedDict" = OrderedDict()
PLAN_CACHE_LIMIT = 256

# Thread-safety (ISSUE 8 audit) — two locks with distinct jobs:
#
# _PLANS_LOCK guards the cache OrderedDict itself (get / LRU move_to_end /
# insert / evict).  Eviction racing a borrow is safe WITHOUT further
# locking because eviction only drops the cache's reference: a borrower
# holds a strong reference to the template Plan for the whole execution,
# and template nodes are never mutated by executions (results land on the
# requesting plan's own nodes via _store_results(onto=...)).
#
# _DAG_LOCK serializes the two operations that touch LIVE DAG node
# metadata (cached_store / save): plan construction (which classifies
# nodes by that state) and result registration.  Concurrent requests may
# share upstream nodes (fm.serve, threads over one traced graph), so a
# registration must never interleave with another thread's classification
# pass.  Both are cheap relative to execution; execution itself runs
# outside the lock.
_PLANS_LOCK = threading.Lock()
_DAG_LOCK = threading.RLock()

# Execution counters — the observable evidence the benchmarks and tests
# assert on (one fused pass, one epilogue launch, compile-once/stream-many).
# ``epilogue_host_inputs`` counts host (numpy/memmap) buffers that reached
# the epilogue callable: it must stay 0 — merged sinks land on device even
# when the sources are disk-backed.  ``passes`` counts streaming passes
# executed (a two-pass ``scale(X)`` plan adds 2 per materialize); the
# per-pass bytes of the MOST RECENT execution are surfaced as
# ``pass_bytes_in`` so multi-pass I/O is observable.
#
# The counters live in the observability metrics registry (root scope plus
# any ``fm.collect_stats()`` scopes open on the calling thread); this list
# names the compatibility subset ``exec_stats()`` exposes as ints.
#
# ``streams`` counts physical partition sweeps over the sources: for a solo
# materialize it equals ``passes``, but a batched execution (core/batch.py)
# drives ONE stream per co-scheduled group while counting every member's
# logical pass — k plans × 1 stream shows up as passes=k, streams=1.
# ``prefetch_reuse_hits`` counts staged partition blocks served from the
# previous pass's resident final partition instead of a re-read.
#
# ``shards`` counts per-device shard drives under a mesh (ISSUE 9): a
# sharded sweep adds one per non-empty shard range (= the mesh's data-axis
# size whenever the matrix has at least one partition per shard); a whole-
# mode mesh run adds the data-axis size its inputs actually sharded over.
# ``shard_merges`` counts cross-device sink merges through the associative
# ``combine`` path — exactly one per shard boundary (shards − 1 per pass
# with sinks); ``bytes_in`` stays the UNION of rows read (each row is
# staged by exactly one shard), with the per-shard split observable as the
# ``shard_bytes_in`` tuple.
EXEC_COUNTERS = (
    "materialize_calls",
    "plan_cache_hits",
    "plan_cache_misses",
    "partition_steps",
    "passes",
    "streams",
    "shards",
    "shard_merges",
    "midstream_admits",
    "prefetch_reuse_hits",
    "epilogue_launches",
    "epilogue_host_inputs",
)


def exec_stats() -> dict:
    """Snapshot of the engine's execution counters (see EXEC_COUNTERS), plus
    ``pass_bytes_in``: the per-pass streamed bytes of the last execution.

    A compatibility view over the root metrics scope; the full instrument
    set (timings, bandwidth, queue occupancy, derived rates) is
    ``observability.metrics.stats()`` or a ``fm.collect_stats()`` scope."""
    st = {k: int(metrics.root_counter(k)) for k in EXEC_COUNTERS}
    st["pass_bytes_in"] = tuple(metrics.root_value("pass_bytes_in", ()))
    st["shard_bytes_in"] = tuple(metrics.root_value("shard_bytes_in", ()))
    return st


def reset_exec_stats():
    metrics.REGISTRY.reset()


def clear_plan_cache():
    with _PLANS_LOCK:
        _PLANS.clear()


def _mesh_key(mesh):
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(np.shape(mesh.devices)))


def _default_mesh(mesh):
    """Resolve the execution mesh: an explicit ``mesh=`` argument wins,
    else the configured default (``fm.set_conf(mesh=...)``), else None
    (unsharded)."""
    if mesh is not None:
        return mesh
    from ..storage import registry  # deferred: storage depends on core
    return registry.get_conf("mesh")


def materialize(*mats: FMMatrix, mode: str = "auto", fuse: bool = True,
                mesh=None, donate: bool = True, reuse_plans: bool = True,
                prefetch: Optional[bool] = None,
                backend: Optional[str] = None) -> list[FMMatrix]:
    """fm.materialize: force computation of virtual matrices.

    Returns one *physical* FMMatrix per argument (physical args pass
    through).  Multiple arguments materialize together in one fused pass
    over the data (paper: "FlashMatrix can materialize any virtual matrix in
    a DAG and can materialize multiple virtual matrices together").

    ``prefetch`` controls the async partition pipeline in streaming modes:
    None = the storage config default (on for slow-tier sources), False =
    synchronous staging (the ablation the storage benchmark measures).

    ``backend`` picks the lowering backend ('xla' | 'pallas' | 'auto');
    None = the engine default (fm.set_conf(backend=...), 'auto' initially:
    pallas on TPU, xla elsewhere).  See core/lowering.py.
    """
    virtuals = [m for m in mats if m.is_virtual]
    if not virtuals:
        return list(mats)

    metrics.inc("materialize_calls")
    backend = lowering.resolve_backend(backend)
    mesh = _default_mesh(mesh)

    if not fuse:
        with TRACER.span("materialize", backend=backend, fuse=False,
                         outputs=len(virtuals)):
            _materialize_eager([m.node for m in virtuals], mode=mode,
                               backend=backend)
        return [_result_of(m) for m in mats]

    with _DAG_LOCK:
        plan = Plan(virtuals)
        exec_plan = _acquire_exec_plan(plan, backend, mesh, reuse_plans)

    # A cached plan's nodes belong to the FIRST caller's live DAG.  The
    # execution reads schedule/program state from the (possibly borrowed)
    # template but registers results onto THIS call's own nodes
    # (_store_results onto= — the same borrow discipline as fm.batch), so
    # the template is never mutated: its persisted results survive, a
    # retry after a failed execution sees clean state, and concurrent
    # materializes of structurally identical plans (fm.serve workers) can
    # share one cache entry safely.
    with TRACER.span("materialize", backend=backend,
                     passes=plan.n_passes, outputs=len(virtuals),
                     cached=exec_plan is not plan):
        _execute(exec_plan, onto=plan, mode=mode, mesh=mesh, donate=donate,
                 sources=[m for _, m in plan.sources],
                 bc_sources=[m for _, m in plan.broadcast_sources],
                 epi_sources=[m for _, m in plan.epilogue_sources],
                 smalls=plan.small_values(), prefetch=prefetch,
                 backend=backend)
    return [_result_of(m) for m in mats]


def _result_of(m: FMMatrix) -> FMMatrix:
    if not m.is_virtual:
        return m
    store = getattr(m.node, "cached_store", None)
    assert store is not None, f"{m.node} failed to materialize"
    return store


def _acquire_exec_plan(plan: Plan, backend: str, mesh, reuse_plans: bool):
    """Plan-cache lookup shared by ``materialize`` and the batch executor.

    Both partition levels OF EVERY PASS and the backend are part of the
    key: the I/O partition size reads IO_PARTITION_BYTES at plan build and
    the IR's block-row schedule reads VMEM_PARTITION_BYTES, so a
    fm.set_conf change — or a backend switch — must miss the cache rather
    than reuse an executable built for different tiling.  (plan.signature()
    itself embeds the pass structure: node roles carry pass numbers, so
    one-pass and two-pass cuts never collide.)

    Thread-safe: lookup, LRU touch and eviction happen under _PLANS_LOCK
    (see the lock's comment for why eviction racing a borrow is benign).
    """
    if not reuse_plans:
        return plan
    sig = (plan.signature(), plan.pass_key(), backend, _mesh_key(mesh))
    with _PLANS_LOCK:
        cached = _PLANS.get(sig)
        if cached is not None:
            metrics.inc("plan_cache_hits")
            _PLANS.move_to_end(sig)  # LRU touch
            return cached
        metrics.inc("plan_cache_misses")
        _PLANS[sig] = plan
        while len(_PLANS) > PLAN_CACHE_LIMIT:
            _PLANS.popitem(last=False)  # evict least-recently-used
        return plan


# ---------------------------------------------------------------------------
# Iteration inspector: cross-materialize partition residency
# ---------------------------------------------------------------------------

_INSPECT = threading.local()


def inspecting() -> bool:
    """True while an ``iteration_scope`` is open on this thread."""
    return getattr(_INSPECT, "depth", 0) > 0


@contextlib.contextmanager
def iteration_scope():
    """fm.inspect_iterations: declare an iterative driver's loop.

    Inside the scope the executor keeps the LAST staged partition of every
    streaming pass resident across materialize calls, so iteration i+1's
    first pass — whose partition schedule matches iteration i's last pass —
    reuses the already-staged final partition instead of re-reading it
    (``prefetch_reuse_hits``).  The iterative drivers (kmeans / glm IRLS /
    nmf / gmm) open this around their loops; on exit the resident blocks
    are dropped so no device memory outlives the loop.
    """
    _INSPECT.depth = getattr(_INSPECT, "depth", 0) + 1
    try:
        yield
    finally:
        _INSPECT.depth -= 1
        if _INSPECT.depth == 0:
            _INSPECT.residents = None


def _tls_residents():
    return getattr(_INSPECT, "residents", None) if inspecting() else None


def _set_tls_residents(residents):
    if inspecting():
        _INSPECT.residents = residents


class _Resident:
    """The final staged partition of a streaming pass, kept alive so a
    following pass with the SAME partition schedule (rows, long_dim — hence
    the same final row range) can consume it without re-staging.  Blocks
    are keyed by physical-matrix identity; ``mats`` holds strong references
    so an ``id()`` can't be reissued while the entry is live."""

    __slots__ = ("rows", "long_dim", "blocks", "mats")

    def __init__(self, rows: int, long_dim: int, blocks: dict, mats: list):
        self.rows = rows
        self.long_dim = long_dim
        self.blocks = blocks  # {id(mat): staged device block}
        self.mats = mats

    def matches(self, rows: int, long_dim: int) -> bool:
        return self.rows == rows and self.long_dim == long_dim


def _reuse_from(residents, group_pairs, rows: int, long_dim: int):
    """Reusable final-partition blocks for a pass streaming ``group_pairs``
    ([(group_key, mat)]) at ``rows``: {group_key: block} for every source
    whose block is resident under an identical partition schedule.
    Per-source, so a pass that re-streams X alongside a NEW matrix still
    reuses the X block."""
    out = {}
    for entry in residents or ():
        if not entry.matches(rows, long_dim):
            continue
        for key, mat in group_pairs:
            if key not in out and id(mat) in entry.blocks:
                out[key] = entry.blocks[id(mat)]
    return out or None


# ---------------------------------------------------------------------------
# Fused execution
# ---------------------------------------------------------------------------




class _PassExec:
    """Executor state of ONE member pass inside a stream group.

    The group runners (`_run_whole_group` / `_run_stream_group`) drive one
    partition sweep over the UNION of the members' staged sources; while a
    staged partition is resident every member's compiled ``step`` consumes
    it and folds its own sink partials through its own ``combine`` before
    the blocks are evicted — k plans × 1 stream becomes 1 stream × k steps
    (core/batch.py builds multi-member groups; a solo materialize is the
    one-member degenerate case).

    ``out_nodes`` pairs each long-dimension output's TEMPLATE node (the
    plan-cache entry's node, whose id keys the lowered step's outputs) with
    the node whose save flag / name / shape describe where the result goes
    — identical for a solo run, the requesting plan's own node for a batch
    member executing through a borrowed cached template.  ``scopes`` are
    the metrics scopes captured when the request joined the batch; the
    runners adopt them around this member's compute so per-request
    attribution reports the member's OWN share, not the group's.
    """

    __slots__ = ("ps", "prog", "sources", "smalls", "epi_sources",
                 "bindings", "out_nodes", "scopes", "accs", "out_parts",
                 "host_bufs", "disk_stores", "finals", "epi_outs")

    def __init__(self, ps, prog, sources, smalls, epi_sources, bindings, *,
                 out_nodes=None, scopes=()):
        self.ps = ps
        self.prog = prog
        self.sources = sources
        self.smalls = smalls
        self.epi_sources = epi_sources
        self.bindings = bindings
        if out_nodes is None:
            outs = ps.row_local_roots + ps.saves
            out_nodes = list(zip(outs, outs))
        self.out_nodes = out_nodes
        self.scopes = tuple(scopes)
        self.accs = ps.init_accs()
        self.out_parts = {tmpl.id: [] for tmpl, _ in out_nodes}
        self.host_bufs: dict[int, np.ndarray] = {}
        self.disk_stores: dict[int, object] = {}
        self.finals = None
        self.epi_outs = None

    def route_outputs(self, start: int, stop: int, outputs: dict):
        for nid, val in outputs.items():
            if nid in self.disk_stores:
                self.disk_stores[nid].write_rows(start, np.asarray(val))
            elif nid in self.host_bufs:
                self.host_bufs[nid][start:stop] = np.asarray(val)
            else:
                self.out_parts[nid].append(val)


def _member_stack(member: _PassExec):
    """The metrics-scope stack to adopt around this member's compute: the
    executor thread's open scopes plus the scopes captured at request time
    (deduped).  None when nothing extra is captured — record normally."""
    if not member.scopes:
        return None
    cur = metrics.current_scopes()
    extra = [s for s in member.scopes if s not in set(cur)]
    return tuple(cur) + tuple(extra) if extra else None


def _in_stack(stack):
    return metrics.use_scopes(stack) if stack else contextlib.nullcontext()


def _group_staging(members):
    """Union staging plan of a group: one ``(key, mat)`` per distinct
    physical matrix across every member (key = the matrix's identity), and
    per member the canonical-node-id → key map that fans a staged block
    back out to its compiled step."""
    group_pairs: list[tuple[int, object]] = []
    seen: set[int] = set()
    maps: list[dict[int, int]] = []
    for m in members:
        mp = {}
        for nid, mat in m.ps.staged_sources(m.sources):
            if id(mat) not in seen:
                seen.add(id(mat))
                group_pairs.append((id(mat), mat))
            mp[nid] = id(mat)
        maps.append(mp)
    return group_pairs, maps


def _count_member_scopes(member, ambient, stream_scopes: list):
    """One member's request-scope share of a stream: its own plan's pass +
    bytes (what a solo run of that request would have read), recorded on
    every captured scope that is not already ambient on the executor."""
    own = None
    for sc in member.scopes:
        if sc in ambient:
            continue
        if own is None:
            own = member.ps.bytes_in(member.sources)
        sc.inc("passes", 1)
        sc.inc("bytes_streamed", own)
        if sc not in stream_scopes:
            stream_scopes.append(sc)


def _count_stream(members, union_bytes: int):
    """Stream accounting.  Root + the executor's ambient scopes record the
    PHYSICAL sweep — one stream, union bytes read once, one logical pass
    per member (so a batched group shows passes=k, streams=1).  Each
    member's request scopes additionally record the stream and their OWN
    plan's byte share: `fm.collect_stats()` around one request of a batch
    reports that plan's traffic, not the whole group's."""
    metrics.inc("streams")
    metrics.inc("bytes_streamed", union_bytes)
    metrics.inc("passes", len(members))
    ambient = set(metrics.REGISTRY.scopes())
    stream_scopes: list = []
    for m in members:
        _count_member_scopes(m, ambient, stream_scopes)
    for sc in stream_scopes:
        sc.inc("streams", 1)


def _count_admitted(member):
    """Accounting for a mid-stream-admitted member (ISSUE 8): its logical
    pass joins the CURRENT physical sweep — root passes +1 but streams
    unchanged, since no new partition sweep starts.  Root bytes for the
    catch-up prefix are added as those partitions actually stage
    (`_catch_up`); the member's own request scopes see what a solo run
    would have reported (one stream, its full plan bytes)."""
    metrics.inc("passes")
    metrics.inc("midstream_admits")
    ambient = set(metrics.REGISTRY.scopes())
    stream_scopes: list = []
    _count_member_scopes(member, ambient, stream_scopes)
    for sc in stream_scopes:
        sc.inc("streams", 1)


def _member_step(member, blocks, key_map, start, stop, *, donate_blocks,
                 idx):
    """Run one member's step + combine over the staged partition."""
    step = member.prog.step_donated if donate_blocks else member.prog.step
    mblocks = {nid: blocks[key] for nid, key in key_map.items()}
    metrics.inc("partition_steps")
    t0 = time.perf_counter()
    with TRACER.span("device_step", rows=stop - start, member=idx):
        partials, outputs = step(mblocks, member.smalls, member.bindings,
                                 jnp.asarray(start, jnp.int32))
        if TRACER.enabled:  # timing fidelity while tracing only
            jax.block_until_ready((partials, outputs))
    metrics.inc("device_step_seconds", time.perf_counter() - t0)
    # The paper's partial-merge: each partition's sink partials fold into
    # the member's running accumulators with the aggregation VUDFs'
    # ``combine`` (donated: the old acc buffers recycle in place).
    t0 = time.perf_counter()
    with TRACER.span("combine", member=idx):
        member.accs = member.prog.combine(member.accs, partials)
        if TRACER.enabled:
            jax.block_until_ready(member.accs)
    metrics.inc("combine_seconds", time.perf_counter() - t0)
    return outputs


def _replicate(tree, mesh):
    """Commit every jax leaf of ``tree`` replicated across ``mesh`` (empty
    PartitionSpec): merged sink values, epilogue inputs and bindings are
    held by EVERY device, so the epilogue runs replicated and the next
    pass's shard executors find their broadcast values wherever they run."""
    sh = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(x, sh) if isinstance(x, jax.Array) else x,
        tree)


def _finish_members(members, stacks, mesh=None):
    """Finalize + epilogue for every member once the sweep completes.
    Under a mesh the merged accumulators are replicated first (the
    cross-device reduction already happened — `_run_sharded_stream`'s
    shard merges, or GSPMD's all-reduce in whole mode), so finalize and
    the epilogue execute replicated on every device."""
    for m, stack in zip(members, stacks):
        with _in_stack(stack):
            if mesh is not None:
                m.accs = _replicate(m.accs, mesh)
            m.finals = m.ps.finalize_accs(m.accs)
            m.epi_outs = _run_epilogue(m.ps, m.prog, m.finals,
                                       m.epi_sources, m.smalls, m.bindings,
                                       mesh=mesh)
        for nid, buf in m.host_bufs.items():
            m.out_parts[nid] = [buf]
        for st in m.disk_stores.values():
            st.flush()


def _run_whole_group(members, mesh=None):
    """Whole-mode sweep of a group: the union of the members' sources is
    staged once, then every member's step consumes it (offset 0, one
    partition).  Under a mesh, long-aligned inputs are committed sharded
    over the data axis (when the row count divides — `_long_spec`) so XLA
    runs the fused step SPMD with one logical shard per data slot."""
    group_pairs, maps = _group_staging(members)
    long_dim = members[0].ps.long_dim
    spec = n_shards = None
    if mesh is not None:
        spec, n_shards = _long_spec(mesh, long_dim)
        metrics.inc("shards", n_shards)
    blocks = {}
    for key, mat in group_pairs:
        if getattr(mat.store, "sparse", False):
            # Sparse source: stage the whole matrix as one ELL partition
            # (stage_block owns the leaf-wise device_put).  No sharded
            # commit — mesh parity for sparse runs through the sharded
            # stream path, which stages per-shard row ranges instead.
            from ..storage.prefetch import stage_block
            blocks[key] = stage_block(mat, 0, mat.shape[0], donate=False)
            continue
        data = mat.logical_data()
        arr = jnp.asarray(np.asarray(data)) if mat.on_host else data
        if mesh is not None and mat.shape[0] == long_dim:
            arr = jax.device_put(arr, NamedSharding(mesh, spec))
        blocks[key] = arr
    _count_stream(members, sum(mat.nbytes() for _, mat in group_pairs))
    stacks = [_member_stack(m) for m in members]
    with TRACER.span("stream", members=len(members), mode="whole"):
        with TRACER.span("partition", start=0, stop=long_dim):
            for i, (m, mp, stack) in enumerate(zip(members, maps, stacks)):
                with _in_stack(stack):
                    outputs = _member_step(m, blocks, mp, 0, long_dim,
                                           donate_blocks=False, idx=i)
                # Whole mode: every output is one full-height value; save
                # targets are applied later by _store_results.
                for nid, val in outputs.items():
                    m.out_parts[nid].append(val)
    _finish_members(members, stacks, mesh=mesh)
    return None


def _execute(plan: Plan, **kw):
    """`_execute_passes` plus the ISSUE 9 concurrency fix: a failure mid-
    plan (a staging error, an interrupted stream) clears the thread's
    resident-partition capture.  The residents in TLS belong to the
    PREVIOUS materialize's final partition; after a partial run they no
    longer correspond to any upcoming schedule, and leaving them pinned
    holds device memory for the rest of the iteration scope."""
    try:
        return _execute_passes(plan, **kw)
    except BaseException:
        _set_tls_residents(None)
        raise


def _execute_passes(plan: Plan, *, onto: Optional[Plan] = None,
                    mode: str = "auto",
                    mesh=None, donate: bool = True, sources=None, smalls=None,
                    prefetch: Optional[bool] = None,
                    backend: Optional[str] = None,
                    epi_sources=None, bc_sources=None):
    """Run every pass of ``plan`` in order, then register the results.

    ``onto`` is the equal-signature plan results belong to (the caller's
    own trace) when ``plan`` is a borrowed cached template; the template's
    schedules/programs drive execution, the out specs and registration
    target ``onto``'s nodes, and the template is never mutated.  Defaults
    to ``plan`` itself.

    A multi-pass plan (fusion.PassSchedule) carries each pass's finalized
    sinks + epilogue outputs forward as the next pass's ``bindings``
    (broadcast inputs of the compiled step) — the moment-pass → sweep-pass
    schedule executing under one plan-cache entry and one materialize
    call.  Results register only after EVERY pass succeeds, so an
    interrupted pass (a staging error mid-stream) leaves no
    partially-registered sinks behind.

    Streaming passes keep their FINAL staged partition resident whenever
    the next pass — of this plan, or of the next materialize inside an
    ``iteration_scope`` — runs an identical partition schedule over (some
    of) the same physical matrices: the re-drive then starts from the
    resident blocks instead of re-reading them (``prefetch_reuse_hits``).
    """
    own = onto if onto is not None else plan
    if sources is None:
        sources = [m for _, m in own.sources]
    if bc_sources is None:
        bc_sources = [m for _, m in own.broadcast_sources]
    if epi_sources is None:
        epi_sources = [m for _, m in own.epilogue_sources]
    if smalls is None:
        smalls = own.small_values()
    prog = plan.program(lowering.resolve_backend(backend))
    pass_progs = getattr(prog, "passes", None) or [prog]
    mode = _pick_mode_src(sources, mode)
    if mode not in ("whole", "stream", "ooc"):
        raise ValueError(f"unknown mode {mode!r}")

    carried: dict[int, object] = {}
    finals_all: dict[int, object] = {}
    parts_all: dict[int, list] = {}
    epi_all: dict[int, object] = {}
    disk_all: dict[int, object] = {}
    # Per-EXECUTION pass bytes, published atomically to the metrics scopes
    # once every pass has run — never a half-written module global an
    # interleaved materialize can clobber mid-plan.
    pass_bytes: list[int] = []
    residents = _tls_residents()
    src_i = bc_i = epi_i = 0
    for k, (ps, pprog) in enumerate(zip(plan.passes, pass_progs)):
        ns, nb, ne = (len(ps.sources), len(ps.broadcast_sources),
                      len(ps.epilogue_sources))
        ps_src = sources[src_i:src_i + ns]
        ps_bc = bc_sources[bc_i:bc_i + nb]
        ps_epi = epi_sources[epi_i:epi_i + ne]
        src_i, bc_i, epi_i = src_i + ns, bc_i + nb, epi_i + ne
        # Pass bindings: earlier passes' merged values, plus this pass's
        # whole-staged small physical sources.
        bindings = {nid: carried[nid] for nid in ps.binding_ids}
        for nid, mat in ps.broadcast_source_pairs(ps_bc):
            bindings[nid] = _stage_whole(mat)
        out_nodes = None
        if own is not plan:
            own_ps = own.passes[k]
            out_nodes = list(zip(ps.row_local_roots + ps.saves,
                                 own_ps.row_local_roots + own_ps.saves))
        member = _PassExec(ps, pprog, ps_src, smalls, ps_epi, bindings,
                           out_nodes=out_nodes)
        t_pass = time.perf_counter()
        with TRACER.span("pass", idx=ps.idx, mode=mode,
                         partition_rows=ps.partition_rows):
            if mode == "whole":
                _run_whole_group([member], mesh=mesh)
                residents = None
            else:
                # Keep the final staged partition resident when the next
                # streaming pass (this plan's, or — inside an
                # iteration_scope — the next materialize's first) could
                # consume it: same partition rows, shared physical matrix.
                capture = inspecting()
                nxt = plan.passes[k + 1] if k + 1 < len(plan.passes) else None
                if (not capture and nxt is not None
                        and nxt.partition_rows == ps.partition_rows):
                    cur_ids = {id(mat)
                               for _, mat in ps.staged_sources(ps_src)}
                    nxt_src = sources[src_i:src_i + len(nxt.sources)]
                    capture = any(
                        id(mat) in cur_ids
                        for _, mat in nxt.staged_sources(nxt_src))
                entry = _run_stream_group(
                    [member], to_host=(mode == "ooc"), donate=donate,
                    prefetch=prefetch, residents=residents, capture=capture,
                    mesh=mesh)
                residents = [entry] if entry is not None else None
                disk_all.update(member.disk_stores)
        metrics.inc("pass_seconds", time.perf_counter() - t_pass)
        pass_bytes.append(ps.bytes_in(ps_src))
        finals_all.update(member.finals)
        parts_all.update(member.out_parts)
        epi_all.update(member.epi_outs)
        carried.update(member.finals)
        carried.update(member.epi_outs)
    _set_tls_residents(residents)
    metrics.put("pass_bytes_in", tuple(pass_bytes))
    _store_results(plan, finals_all, parts_all, to_host=(mode == "ooc"),
                   disk_stores=disk_all, epilogue_outs=epi_all, onto=own)
    return plan


def _pick_mode_src(sources, mode: str) -> str:
    if mode != "auto":
        return mode
    if any(mat.on_host for mat in sources):
        return "ooc"
    return "whole"


def _stage_whole(mat) -> "jax.Array":
    """Stage a small matrix whole onto the device (broadcast/epilogue
    sources, pass bindings must never leak host buffers into jit)."""
    data = mat.logical_data()
    return jnp.asarray(np.asarray(data)) if mat.on_host else data


def _run_epilogue(ps, prog, sink_finals, epi_sources, smalls, bindings,
                  mesh=None):
    """Invoke the lowered epilogue exactly ONCE after a pass's merge.

    Inputs are the finalized sink values (device arrays out of the jitted
    ``combine``) plus any small physical matrices only the epilogue
    consumes, staged with ``jnp.asarray`` so a disk-backed plan never leaks
    ``np.memmap``/numpy buffers into the compiled callable — the
    ``epilogue_host_inputs`` counter records any violation.

    Under a mesh the epilogue runs REPLICATED: its committed inputs (the
    finalized sinks — already replicated by `_finish_members` — plus the
    epilogue sources and earlier-pass bindings, replicated here) all live
    on every mesh device, so one jit call executes the identical epilogue
    per device with no cross-device traffic.
    """
    if prog.epilogue is None:
        return {}
    epi_vals = {}
    for nid, mat in ps.epilogue_source_pairs(epi_sources):
        epi_vals[nid] = _stage_whole(mat)
    if mesh is not None:
        epi_vals = _replicate(epi_vals, mesh)
        bindings = _replicate(bindings, mesh)
    leaves = jax.tree_util.tree_leaves((sink_finals, epi_vals))
    metrics.inc("epilogue_host_inputs", sum(
        1 for leaf in leaves if isinstance(leaf, np.ndarray)))
    metrics.inc("epilogue_launches")
    t0 = time.perf_counter()
    with TRACER.span("epilogue", idx=ps.idx):
        outs = prog.epilogue(sink_finals, epi_vals, smalls, bindings)
        if TRACER.enabled:
            jax.block_until_ready(outs)
    metrics.inc("epilogue_seconds", time.perf_counter() - t0)
    return outs


def _long_spec(mesh, long_dim: int):
    """(PartitionSpec, shard count) for a whole-mode long-aligned input:
    the row dimension shards across the data tier when it divides evenly
    (``distributed.sharding.resolve``'s divisibility check — the ``rows``
    rule), otherwise replicates with shard count 1.  Model-like axes
    always replicate — GenOps are row-parallel."""
    from ..distributed import sharding as shd
    spec = shd.resolve("rows|rep", (long_dim, 1), mesh)
    n_shards = shd.data_axis_size(mesh) if spec[0] is not None else 1
    return P(spec[0], None), n_shards


def _inline_partitions(src_pairs, rows: int, n: int, donate: bool,
                       reuse=None, row_start: int = 0, device=None):
    """Synchronous partition staging (prefetch-off ablation): same staging
    rules as the prefetch thread (storage.stage_block), but the disk read
    happens on the compute thread; only device_put dispatch overlaps.
    ``reuse`` maps source keys to the previous pass's resident FINAL
    partition blocks — served in place of the last re-read.  ``row_start``
    and ``device`` mirror the prefetcher's shard parameters: one shard's
    half-open range, staged onto that shard's device."""
    from ..storage.prefetch import stage_block
    start = row_start
    while start < n:
        stop = min(start + rows, n)
        blocks = {}
        for nid, mat in src_pairs:
            if stop >= n and reuse and nid in reuse:
                blocks[nid] = reuse[nid]
                metrics.inc("prefetch_reuse_hits")
            else:
                blocks[nid] = stage_block(mat, start, stop, donate=donate,
                                          device=device)
        yield start, stop, blocks
        start = stop


def _alloc_out_targets(member, to_host: bool):
    """Allocate a member's long-dimension output targets before its first
    partition step."""
    from .. import storage  # deferred: storage depends on core.matrix
    for tmpl, spec in member.out_nodes:
        target = spec.save or ("host" if to_host else "device")
        if target == "disk":
            # Write-through spill: the long-dimension output streams
            # into a preallocated on-disk matrix, partition by
            # partition — it never exists whole in RAM.  Works for any
            # pass: scale(X, save='disk') spills the PASS-2 sweep
            # output out-of-core end to end.
            member.disk_stores[tmpl.id] = storage.create_matrix(
                storage.spill_path(spec.name), (spec.nrow, spec.ncol),
                dtypes.np_equiv(spec.dtype))
        elif target == "host":
            member.host_bufs[tmpl.id] = np.empty(
                (spec.nrow, spec.ncol), dtypes.np_equiv(spec.dtype))


def _join_member(member, members, maps, stacks, joined, group_keys,
                 to_host: bool, start: int):
    """Splice a mid-stream-admitted member into a live sweep at a
    partition boundary (ISSUE 8).  The member consumes every partition
    from ``start`` on alongside the group, then `_catch_up` re-drives the
    prefix it missed.  Requirements checked here:

    * its staged sources must be a subset of the group's (it adds
      consumers to already-staged blocks, never new staging);
    * its long-dimension outputs must be row-addressed (host or disk
      targets) — device-resident outputs concatenate in partition order,
      which a late joiner would scramble.  Sink/epilogue-only plans (the
      typical serving analytics shape) always qualify.
    """
    mp = {}
    for nid, mat in member.ps.staged_sources(member.sources):
        if id(mat) not in group_keys:
            raise ValueError(
                "mid-stream admission requires the member's staged sources "
                "to be a subset of the live group's")
        mp[nid] = id(mat)
    if any((spec.save or ("host" if to_host else "device")) == "device"
           for _, spec in member.out_nodes):
        raise ValueError(
            "mid-stream admission cannot take device-resident "
            "long-dimension outputs (order-dependent concatenation)")
    _alloc_out_targets(member, to_host)
    members.append(member)
    maps.append(mp)
    stacks.append(_member_stack(member))
    joined[len(members) - 1] = start
    _count_admitted(member)


def _catch_up(members, maps, stacks, joined, group_pairs, rows: int,
              donate: bool):
    """Re-drive the partition prefix [0, join_start) that mid-stream
    admitted members missed.  Sink combines are order-independent and late
    long-dimension outputs are row-addressed (enforced by `_join_member`),
    so sweeping the prefix after the tail is exact."""
    from ..storage.prefetch import stage_block
    max_join = max(joined.values())
    late_keys = {key for idx in joined for key in maps[idx].values()}
    pairs = [(key, mat) for key, mat in group_pairs if key in late_keys]
    start = 0
    with TRACER.span("catch_up", members=len(joined), upto=max_join):
        while start < max_join:
            stop = min(start + rows, max_join)
            blocks = {key: stage_block(mat, start, stop, donate=donate)
                      for key, mat in pairs}
            metrics.inc("bytes_streamed",
                        sum(int(getattr(b, "nbytes", 0))
                            for b in blocks.values()))
            live = [i for i, j0 in joined.items() if j0 > start]
            with TRACER.span("partition", start=start, stop=stop):
                for pos, i in enumerate(live):
                    m, mp, stack = members[i], maps[i], stacks[i]
                    donate_blocks = donate and pos == len(live) - 1
                    with _in_stack(stack):
                        outputs = _member_step(
                            m, blocks, mp, start, stop,
                            donate_blocks=donate_blocks, idx=i)
                    m.route_outputs(start, stop, outputs)
            start = stop


def _run_stream_group(members, *, to_host: bool, donate: bool = True,
                      prefetch: Optional[bool] = None, residents=None,
                      capture: bool = False, admit=None,
                      depth: Optional[int] = None, mesh=None):
    """Stream ONE co-scheduled group of member passes partition by
    partition: one prefetcher drive over the UNION of the members' staged
    sources, every member's step consuming each staged partition while it
    is resident (1 stream × k steps).  A solo materialize pass is the
    one-member case and behaves exactly like the classic per-plan stream.

    ``residents`` holds the previous pass's resident final partition(s);
    blocks whose partition schedule matches are fed to the prefetcher as
    ``reuse`` so the last partition is not re-staged.  With ``capture``
    the sweep's OWN final partition is returned as a `_Resident` (its
    blocks are excluded from donation) for the next pass to consume.

    ``admit`` is the mid-stream admission hook (fm.serve): called at every
    partition boundary with ``(start, stop)``, it may return new
    `_PassExec` members that join the live sweep from this partition on
    (`_join_member`); after the main sweep they catch up on the prefix
    they missed (`_catch_up`).  ``depth`` overrides the prefetch queue
    depth; None negotiates a group-aware depth
    (`storage.negotiate_depth`).

    ``mesh`` routes the sweep to the SHARDED runner — one prefetcher drive
    per device shard (`_run_sharded_stream`) — unless a live-admission
    gate is active: mid-stream admission splices a member into ONE
    sequential sweep at a partition boundary, and a sharded sweep has no
    single boundary order to splice into, so gated streams run unsharded
    (fm.serve instead serializes admission under a mesh — late requests
    wait for the next window; see Engine._run_group).
    """
    from .. import storage  # deferred: storage depends on core.matrix

    if mesh is not None and admit is None:
        return _run_sharded_stream(members, mesh, to_host=to_host,
                                   donate=donate, prefetch=prefetch,
                                   depth=depth)

    n = members[0].ps.long_dim
    # Partition schedules in one group are power-of-two row counts over the
    # same long dimension: the min is a common partitioning for all members.
    rows = min(m.ps.partition_rows for m in members)
    group_pairs, maps = _group_staging(members)
    _count_stream(members, sum(mat.nbytes() for _, mat in group_pairs))

    for m in members:
        _alloc_out_targets(m, to_host)

    reuse_map = _reuse_from(residents, group_pairs, rows, n)
    group_keys = {key for key, _ in group_pairs}
    joined: dict[int, int] = {}  # member index -> partition start it joined at
    stacks = [_member_stack(m) for m in members]
    captured = None
    if prefetch is None:
        # Default on for slow-tier sources; a single-partition stream has
        # nothing to overlap, so skip the thread.
        prefetch = (storage.get_conf("prefetch") and n > rows
                    and any(mat.on_host for _, mat in group_pairs))
    # Nothing may come between pipeline construction and the try below:
    # the finally's close() is what guarantees an interrupted stream never
    # leaves the worker thread alive or staged partitions pinned.
    if prefetch:
        if depth is None:
            # Group-aware depth: k members consume each staged partition,
            # so the stager can usefully run further ahead (ISSUE 8).
            part_nbytes = rows * sum(
                mat.nbytes() // max(1, mat.shape[0])
                for _, mat in group_pairs)
            depth = storage.negotiate_depth(len(members), part_nbytes)
        parts = storage.PartitionPrefetcher(
            group_pairs, rows, n, donate=donate, depth=depth,
            reuse=reuse_map)
    else:
        parts = _inline_partitions(group_pairs, rows, n, donate,
                                   reuse=reuse_map)
    try:
        with TRACER.span("stream", members=len(members), rows=rows,
                         reused=len(reuse_map or ())):
            for start, stop, blocks in parts:
                if admit is not None:
                    for new_member in admit(start, stop):
                        _join_member(new_member, members, maps, stacks,
                                     joined, group_keys, to_host, start)
                is_final = stop >= n
                # The final partition's blocks survive the step when they
                # are being captured for the next pass, or when they CAME
                # from a resident entry that may be consulted again.
                pin_final = is_final and (capture or reuse_map is not None)
                with TRACER.span("partition", start=start, stop=stop):
                    for i, (m, mp, stack) in enumerate(
                            zip(members, maps, stacks)):
                        # Staged blocks are donated only by the LAST
                        # member's step — earlier members share them.
                        donate_blocks = (donate and i == len(members) - 1
                                         and not pin_final)
                        with _in_stack(stack):
                            outputs = _member_step(
                                m, blocks, mp, start, stop,
                                donate_blocks=donate_blocks, idx=i)
                        m.route_outputs(start, stop, outputs)
                    if capture and is_final:
                        captured = _Resident(
                            rows, n,
                            {key: blocks[key] for key, _ in group_pairs},
                            [mat for _, mat in group_pairs])
    finally:
        if hasattr(parts, "close"):
            parts.close()

    if joined:
        _catch_up(members, maps, stacks, joined, group_pairs, rows, donate)
    _finish_members(members, stacks)
    return captured


def _to_device(tree, dev):
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(x, dev) if isinstance(x, jax.Array) else x,
        tree)


def _run_sharded_stream(members, mesh, *, to_host: bool, donate: bool = True,
                        prefetch: Optional[bool] = None,
                        depth: Optional[int] = None):
    """Shard a group's partition sweep across the mesh's data axis
    (ISSUE 9 tentpole — the paper's partition-per-thread NUMA mapping,
    §III-D, as partition-range-per-device):

    * the long dimension splits into contiguous partition-aligned row
      ranges (`fusion.shard_ranges`), one per data shard;
    * each shard runs its OWN prefetcher drive + per-device executor over
      its range (the disk tier serves arbitrary ``block(start, stop)``),
      staging blocks onto its device — shard workers are plain threads, so
      N shards stream and compute concurrently;
    * sink partials merge across shards through the SAME associative
      ``combine`` the partition loop uses, pairwise (a tree all-reduce):
      exactly one merge per shard boundary (``shard_merges``);
    * the merged sinks replicate across the mesh and the epilogue runs
      replicated (`_finish_members(mesh=...)`).

    Row-addressed targets (ooc host buffers, ``save='disk'`` spill stores)
    are SHARED by the shard clones — ranges are disjoint, so concurrent
    row writes never overlap and a spill streams every shard's rows into
    one on-disk matrix.  Device-resident long outputs gather to the first
    shard's device in shard order, then re-commit sharded over the mesh
    when the row count divides (`LoweredProgram.shard_specs`, resolved
    through ``distributed.sharding.resolve``).

    One failed shard fails the whole sweep (every drive is joined, the
    first error re-raised AFTER all prefetchers shut down), so callers
    never register partial sinks.  Capture/residency reuse is disabled
    under a mesh: the resident-final-partition optimization assumes one
    sequential sweep.  ``bytes_in`` accounting stays the union — each row
    is staged by exactly one shard — with the per-shard byte split
    published as ``shard_bytes_in``.
    """
    import concurrent.futures as cf

    from .. import storage  # deferred: storage depends on core.matrix
    from ..distributed import sharding as shd
    from .fusion import shard_ranges

    n = members[0].ps.long_dim
    rows = min(m.ps.partition_rows for m in members)
    group_pairs, maps = _group_staging(members)
    _count_stream(members, sum(mat.nbytes() for _, mat in group_pairs))
    for m in members:
        _alloc_out_targets(m, to_host)

    devices = shd.shard_devices(mesh)
    ranges = shard_ranges(n, rows, len(devices))
    shards = [(si, lo, hi, dev)
              for si, ((lo, hi), dev) in enumerate(zip(ranges, devices))
              if hi > lo]
    metrics.inc("shards", len(shards))
    row_bytes = sum(mat.nbytes() // max(1, mat.shape[0])
                    for _, mat in group_pairs)
    metrics.put("shard_bytes_in",
                tuple(row_bytes * (hi - lo) for _, lo, hi, _d in shards))

    if prefetch is None:
        prefetch = (storage.get_conf("prefetch") and n > rows
                    and any(mat.on_host for _, mat in group_pairs))
    if prefetch and depth is None:
        depth = storage.negotiate_depth(len(members), rows * row_bytes)

    # Per-shard executor clones: the SAME compiled per-pass program run as
    # per-device executors, one row range each.  Bindings (earlier passes'
    # merged values) and device-resident smalls REPLICATE — each clone
    # gets a copy committed to its shard's device, so the jitted step
    # never sees inputs committed to two different devices.
    clones_by_shard = []
    for _si, _lo, _hi, dev in shards:
        clones = []
        for m in members:
            bindings = _to_device(m.bindings, dev)
            smalls = _to_device(m.smalls, dev)
            sm = _PassExec(m.ps, m.prog, m.sources, smalls, m.epi_sources,
                           bindings, out_nodes=m.out_nodes, scopes=m.scopes)
            sm.host_bufs = m.host_bufs
            sm.disk_stores = m.disk_stores
            clones.append(sm)
        clones_by_shard.append(clones)

    # Metrics scopes are thread-local: capture the calling thread's full
    # stack (ambient + each member's request scopes) here and re-enter it
    # on the shard worker threads, so per-request attribution and the
    # prefetcher's scope adoption keep working off the caller.
    ambient = metrics.current_scopes()
    amb_set = set(ambient)
    stacks = [tuple(ambient)
              + tuple(s for s in m.scopes if s not in amb_set)
              for m in members]

    def drive(shard_idx: int):
        si, lo, hi, dev = shards[shard_idx]
        clones = clones_by_shard[shard_idx]
        with metrics.use_scopes(ambient):
            if prefetch:
                parts = storage.PartitionPrefetcher(
                    group_pairs, rows, hi, row_start=lo, donate=donate,
                    depth=depth, device=dev)
            else:
                parts = _inline_partitions(group_pairs, rows, hi, donate,
                                           row_start=lo, device=dev)
            try:
                with TRACER.span("shard", idx=si, start=lo, stop=hi):
                    for start, stop, blocks in parts:
                        with TRACER.span("partition", start=start,
                                         stop=stop, shard=si):
                            for i, (sm, mp) in enumerate(zip(clones, maps)):
                                donate_blocks = (donate
                                                 and i == len(clones) - 1)
                                with metrics.use_scopes(stacks[i]):
                                    outputs = _member_step(
                                        sm, blocks, mp, start, stop,
                                        donate_blocks=donate_blocks, idx=i)
                                sm.route_outputs(start, stop, outputs)
            finally:
                if hasattr(parts, "close"):
                    parts.close()

    with TRACER.span("stream", members=len(members), rows=rows,
                     shards=len(shards)):
        if len(shards) == 1:
            drive(0)
        else:
            with cf.ThreadPoolExecutor(
                    max_workers=len(shards),
                    thread_name_prefix="fm-shard") as pool:
                futures = [pool.submit(drive, i)
                           for i in range(len(shards))]
                errors = [f.exception() for f in futures]
            for exc in errors:
                if exc is not None:
                    raise exc

    dev0 = shards[0][3]
    for mi, m in enumerate(members):
        if m.ps.sinks:
            entries = [(clones_by_shard[s][mi].accs, shards[s][3])
                       for s in range(len(shards))]
            while len(entries) > 1:
                nxt = []
                for j in range(0, len(entries) - 1, 2):
                    (a, dev_a), (b, _dev_b) = entries[j], entries[j + 1]
                    with TRACER.span("shard_combine", member=mi):
                        a = m.prog.combine(a, _to_device(b, dev_a))
                    metrics.inc("shard_merges")
                    nxt.append((a, dev_a))
                if len(entries) % 2:
                    nxt.append(entries[-1])
                entries = nxt
            m.accs = entries[0][0]
        for tmpl, _spec in m.out_nodes:
            nid = tmpl.id
            if nid in m.host_bufs or nid in m.disk_stores:
                continue  # row-addressed shared targets: already written
            for s in range(len(shards)):
                m.out_parts[nid].extend(
                    _to_device(p, dev0)
                    for p in clones_by_shard[s][mi].out_parts[nid])

    _finish_members(members, [_member_stack(m) for m in members], mesh=mesh)
    _apply_output_specs(members, mesh)
    return None


def _apply_output_specs(members, mesh):
    """Re-commit device-resident long-dimension outputs by their resolved
    specs: shard the rows over the mesh when they divide (the ``rows``
    rule), so a sharded materialize hands downstream consumers an already
    data-sharded result."""
    for m in members:
        specs = m.prog.shard_specs(mesh)
        for tmpl, _spec in m.out_nodes:
            nid = tmpl.id
            parts = m.out_parts.get(nid)
            if not parts or isinstance(parts[0], np.ndarray):
                continue
            spec = specs.get(nid)
            if spec is None or not len(spec) or spec[0] is None:
                continue
            data = parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)
            m.out_parts[nid] = [
                jax.device_put(data, NamedSharding(mesh, spec))]


def _store_results(plan: Plan, sink_finals, out_parts, *, to_host: bool,
                   disk_stores=None, epilogue_outs=None, onto: Plan = None):
    """Register the execution's values as each result node's cached store.

    ``onto`` is an equal-signature plan to register results ON: a request
    executing through a borrowed cached template (solo materialize, batch
    member, serve member alike) reads values keyed by the TEMPLATE's node
    ids but registers them on its own plan's nodes (positionally aligned —
    same signature, same deterministic node order), so the template's
    nodes are never mutated.  Defaults to ``plan`` itself.

    Runs under _DAG_LOCK: registration flips nodes to physical, and must
    not interleave with another thread's plan construction over a shared
    subgraph (ISSUE 8 audit)."""
    onto = onto if onto is not None else plan
    with _DAG_LOCK:
        _store_results_locked(plan, onto, sink_finals, out_parts,
                              to_host=to_host, disk_stores=disk_stores,
                              epilogue_outs=epilogue_outs)


def _store_results_locked(plan, onto, sink_finals, out_parts, *, to_host,
                          disk_stores, epilogue_outs):
    for node, dst in zip(plan.sinks, onto.sinks):
        arr = sink_finals[node.id]
        dst.cached_store = FMMatrix(
            dst.shape, dst.dtype, store=DenseStore(arr), name=dst.name)
    if epilogue_outs:
        # Epilogue results are small post-merge values: like sinks they stay
        # on device in every mode, unless an explicit save flag retargets
        # them (out_parts routes them through the ordinary target logic).
        out_parts = dict(out_parts)
        for node in plan.epilogue_roots:
            out_parts[node.id] = [epilogue_outs[node.id]]
    epi_ids = {n.id for n in plan.epilogue_roots}
    tmpl_outs = plan.row_local_roots + plan.saves + plan.epilogue_roots
    own_outs = onto.row_local_roots + onto.saves + onto.epilogue_roots
    for node, dst in zip(tmpl_outs, own_outs):
        if disk_stores and node.id in disk_stores:
            dst.cached_store = FMMatrix(
                dst.shape, dst.dtype, store=disk_stores[node.id],
                name=dst.name)
            dst.save = None
            continue
        parts = out_parts[node.id]
        if len(parts) == 1:
            data = parts[0]
        else:
            data = jnp.concatenate(parts, axis=0)
        target = dst.save or (
            "host" if to_host and node.id not in epi_ids else None)
        if target == "disk":
            # whole-mode save='disk': spill the materialized output in one go.
            from .. import storage
            store = storage.create_matrix(
                storage.spill_path(dst.name), dst.shape,
                dtypes.np_equiv(dst.dtype))
            store.write_rows(0, np.asarray(data))
            store.flush()
            dst.cached_store = FMMatrix(
                dst.shape, dst.dtype, store=store, name=dst.name)
            dst.save = None
            continue
        if target == "host" and not isinstance(data, np.ndarray):
            data = np.asarray(data)
        dst.cached_store = FMMatrix(
            dst.shape, dst.dtype, store=DenseStore(data), name=dst.name)
        dst.save = None


# ---------------------------------------------------------------------------
# Eager (unfused) execution — the ablation baseline
# ---------------------------------------------------------------------------

def _materialize_eager(nodes: Sequence[Node], *, mode: str = "auto",
                       backend: Optional[str] = None):
    """Materialize every DAG node separately, writing each intermediate out
    in full before the next operation reads it back.

    This is the behaviour the paper ascribes to frameworks without operation
    fusion ("MLlib materializes operations such as aggregation separately"),
    and the `fuse=False` arm of benchmarks/fusion_ablation.py.  Out-of-core,
    every intermediate roundtrips the host tier (mem-fuse off); in memory,
    every intermediate lands in HBM (cache-fuse off).
    """
    order = Plan._cut_toposort(list(nodes))
    temp: list[Node] = []
    ooc = any(isinstance(n, LeafNode) and n.mat.on_host for n in order)
    for n in order:
        with _DAG_LOCK:
            if Plan._is_source(n):
                continue
            sub = Plan([wrap(n)])
            if ooc and not n.is_sink:
                n.save = "host"  # roundtrip the slow tier, as an unfused engine must
        sub_mode = mode
        if mode == "auto":
            sub_mode = "ooc" if ooc else "whole"
        _execute(sub, mode=sub_mode, backend=backend)
        temp.append(n)
    return temp
