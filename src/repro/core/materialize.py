"""Materialization engine (paper §III-F).

Executes a fused `fusion.Plan` in one of three modes:

* ``whole``  — the entire long dimension in one fused XLA computation.  The
  default for in-memory (device-resident) matrices; XLA performs the
  CPU-cache/VMEM-level fusion that the paper implements by hand, and an
  optional device mesh shards the long dimension for data-parallel
  execution (partition-per-device ≙ the paper's partition-per-thread, with
  `psum`-style combines materializing the sinks).
* ``stream`` — explicit I/O-level partition loop on device: the 2-level-
  partitioning demonstrator and the building block of out-of-core.
* ``ooc``    — sources live on a slow tier: host RAM (numpy) or the real
  disk tier (`storage.MmapStore` over the on-disk matrix format).
  Partitions are staged by a double-buffered background prefetcher
  (`storage.PartitionPrefetcher`): the disk read + host→device copy of
  partition i+1 overlaps the compute of partition i (the paper's
  I/O/compute overlap).  The fused step consumes staged blocks with buffer
  donation (the paper's memory-chunk recycling), and long-dimension
  outputs write through to preallocated host buffers or — with
  ``save='disk'`` — stream into a preallocated on-disk matrix (spill).

Sinks accumulate partition partials and merge with the aggregation VUDF's
``combine`` — identical in all three modes, which is exactly why the paper's
out-of-core execution can match in-memory performance once arithmetic
intensity is high enough.
"""
from __future__ import annotations

import time
import warnings
from collections import OrderedDict
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# Buffer donation is the memory-chunk-recycling analog (DESIGN.md §1); when a
# donated block has no same-shaped output XLA declines it — harmless, and on
# CPU (this container) donation is advisory anyway.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

from . import dtypes, lowering
from .dag import LeafNode, Node, as_node, wrap
from .fusion import Plan
from .matrix import DenseStore, FMMatrix
from ..observability import metrics
from ..observability.trace import TRACER

try:  # NamedSharding is only used when a mesh is passed.
    from jax.sharding import NamedSharding, PartitionSpec as P
except ImportError:  # pragma: no cover
    NamedSharding = None
    P = None


# Compiled-plan cache: structurally identical DAG cuts (k-means iteration
# N+1, GMM E-steps, any steady-state loop) reuse one jitted executable —
# the compile-once/stream-many behavior a production engine needs.  Keyed
# by Plan.signature() plus the mesh's structural identity (axis names +
# shape; NOT id(mesh), which a garbage collector can reissue to a
# different mesh), with LRU eviction at PLAN_CACHE_LIMIT.
_PLANS: "OrderedDict" = OrderedDict()
PLAN_CACHE_LIMIT = 256

# Execution counters — the observable evidence the benchmarks and tests
# assert on (one fused pass, one epilogue launch, compile-once/stream-many).
# ``epilogue_host_inputs`` counts host (numpy/memmap) buffers that reached
# the epilogue callable: it must stay 0 — merged sinks land on device even
# when the sources are disk-backed.  ``passes`` counts streaming passes
# executed (a two-pass ``scale(X)`` plan adds 2 per materialize); the
# per-pass bytes of the MOST RECENT execution are surfaced as
# ``pass_bytes_in`` so multi-pass I/O is observable.
#
# The counters live in the observability metrics registry (root scope plus
# any ``fm.collect_stats()`` scopes open on the calling thread); this list
# names the compatibility subset ``exec_stats()`` exposes as ints.
EXEC_COUNTERS = (
    "materialize_calls",
    "plan_cache_hits",
    "plan_cache_misses",
    "partition_steps",
    "passes",
    "epilogue_launches",
    "epilogue_host_inputs",
)


def exec_stats() -> dict:
    """Snapshot of the engine's execution counters (see EXEC_COUNTERS), plus
    ``pass_bytes_in``: the per-pass streamed bytes of the last execution.

    A compatibility view over the root metrics scope; the full instrument
    set (timings, bandwidth, queue occupancy, derived rates) is
    ``observability.metrics.stats()`` or a ``fm.collect_stats()`` scope."""
    st = {k: int(metrics.root_counter(k)) for k in EXEC_COUNTERS}
    st["pass_bytes_in"] = tuple(metrics.root_value("pass_bytes_in", ()))
    return st


def reset_exec_stats():
    metrics.REGISTRY.reset()


def clear_plan_cache():
    _PLANS.clear()


def _mesh_key(mesh):
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(np.shape(mesh.devices)))


def materialize(*mats: FMMatrix, mode: str = "auto", fuse: bool = True,
                mesh=None, donate: bool = True, reuse_plans: bool = True,
                prefetch: Optional[bool] = None,
                backend: Optional[str] = None) -> list[FMMatrix]:
    """fm.materialize: force computation of virtual matrices.

    Returns one *physical* FMMatrix per argument (physical args pass
    through).  Multiple arguments materialize together in one fused pass
    over the data (paper: "FlashMatrix can materialize any virtual matrix in
    a DAG and can materialize multiple virtual matrices together").

    ``prefetch`` controls the async partition pipeline in streaming modes:
    None = the storage config default (on for slow-tier sources), False =
    synchronous staging (the ablation the storage benchmark measures).

    ``backend`` picks the lowering backend ('xla' | 'pallas' | 'auto');
    None = the engine default (fm.set_conf(backend=...), 'auto' initially:
    pallas on TPU, xla elsewhere).  See core/lowering.py.
    """
    virtuals = [m for m in mats if m.is_virtual]
    if not virtuals:
        return list(mats)

    metrics.inc("materialize_calls")
    backend = lowering.resolve_backend(backend)

    if not fuse:
        with TRACER.span("materialize", backend=backend, fuse=False,
                         outputs=len(virtuals)):
            _materialize_eager([m.node for m in virtuals], mode=mode,
                               backend=backend)
        return [_result_of(m) for m in mats]

    plan = Plan(virtuals)
    exec_plan = plan
    if reuse_plans:
        # Both partition levels OF EVERY PASS and the backend are part of
        # the key: the I/O partition size reads IO_PARTITION_BYTES at plan
        # build and the IR's block-row schedule reads VMEM_PARTITION_BYTES,
        # so a fm.set_conf change — or a backend switch — must miss the
        # cache rather than reuse an executable built for different tiling.
        # (plan.signature() itself embeds the pass structure: node roles
        # carry pass numbers, so one-pass and two-pass cuts never collide.)
        sig = (plan.signature(), plan.pass_key(), backend, _mesh_key(mesh))
        cached = _PLANS.get(sig)
        if cached is not None:
            metrics.inc("plan_cache_hits")
            _PLANS.move_to_end(sig)  # LRU touch
            exec_plan = cached
        else:
            metrics.inc("plan_cache_misses")
            _PLANS[sig] = plan
            while len(_PLANS) > PLAN_CACHE_LIMIT:
                _PLANS.popitem(last=False)  # evict least-recently-used

    # A cached plan's nodes belong to the FIRST caller's live DAG: its
    # persisted results (set_mate_level cut points used by that DAG's other
    # virtual matrices) must survive us borrowing the plan.  Snapshot them,
    # scrub for execution (stale cached_store would flip _is_source() on a
    # retrace — e.g. the same signature executing whole after ooc — and
    # silently skip those nodes; _store_results also zeroed save flags, and
    # the signature guarantees the new plan's flags match construction
    # time), execute, copy the results onto the new plan's nodes, then
    # restore the template exactly as we found it.
    # A cached plan built over the SAME node objects (a retry after a
    # failed execution left the entry behind) needs no borrowing dance:
    # results land on the right nodes directly, and snapshot-restore would
    # clobber them with the pre-failure (empty) state.
    borrowed = exec_plan is not plan and any(
        a is not b for a, b in zip(exec_plan.result_nodes(),
                                   plan.result_nodes()))
    snapshot = None
    if borrowed:
        snapshot = [(n, n.cached_store, n.save)
                    for n in exec_plan.result_nodes()]
        for (n, _, _), new_n in zip(snapshot, plan.result_nodes()):
            n.cached_store = None
            n.save = new_n.save
    try:
        with TRACER.span("materialize", backend=backend,
                         passes=plan.n_passes, outputs=len(virtuals),
                         cached=exec_plan is not plan):
            _execute(exec_plan, mode=mode, mesh=mesh, donate=donate,
                     sources=[m for _, m in plan.sources],
                     bc_sources=[m for _, m in plan.broadcast_sources],
                     epi_sources=[m for _, m in plan.epilogue_sources],
                     smalls=plan.small_values(), prefetch=prefetch,
                     backend=backend)
        if borrowed:
            for old_n, new_n in zip(exec_plan.result_nodes(),
                                    plan.result_nodes()):
                new_n.cached_store = old_n.cached_store
                new_n.save = None
    finally:
        if snapshot is not None:
            for n, cs, sv in snapshot:
                n.cached_store = cs
                n.save = sv
    return [_result_of(m) for m in mats]


def _result_of(m: FMMatrix) -> FMMatrix:
    if not m.is_virtual:
        return m
    store = getattr(m.node, "cached_store", None)
    assert store is not None, f"{m.node} failed to materialize"
    return store


# ---------------------------------------------------------------------------
# Fused execution
# ---------------------------------------------------------------------------




def _execute(plan: Plan, *, mode: str = "auto", mesh=None, donate: bool = True,
             sources=None, smalls=None, prefetch: Optional[bool] = None,
             backend: Optional[str] = None, epi_sources=None,
             bc_sources=None):
    """Run every pass of ``plan`` in order, then register the results.

    A multi-pass plan (fusion.PassSchedule) carries each pass's finalized
    sinks + epilogue outputs forward as the next pass's ``bindings``
    (broadcast inputs of the compiled step) — the moment-pass → sweep-pass
    schedule executing under one plan-cache entry and one materialize
    call.  Results register only after EVERY pass succeeds, so an
    interrupted pass (a staging error mid-stream) leaves no
    partially-registered sinks behind.
    """
    if sources is None:
        sources = [m for _, m in plan.sources]
    if bc_sources is None:
        bc_sources = [m for _, m in plan.broadcast_sources]
    if epi_sources is None:
        epi_sources = [m for _, m in plan.epilogue_sources]
    if smalls is None:
        smalls = plan.small_values()
    prog = plan.program(lowering.resolve_backend(backend))
    pass_progs = getattr(prog, "passes", None) or [prog]
    mode = _pick_mode_src(sources, mode)
    if mode not in ("whole", "stream", "ooc"):
        raise ValueError(f"unknown mode {mode!r}")

    carried: dict[int, object] = {}
    finals_all: dict[int, object] = {}
    parts_all: dict[int, list] = {}
    epi_all: dict[int, object] = {}
    disk_all: dict[int, object] = {}
    # Per-EXECUTION pass bytes, published atomically to the metrics scopes
    # once every pass has run — never a half-written module global an
    # interleaved materialize can clobber mid-plan.
    pass_bytes: list[int] = []
    src_i = bc_i = epi_i = 0
    for ps, pprog in zip(plan.passes, pass_progs):
        ns, nb, ne = (len(ps.sources), len(ps.broadcast_sources),
                      len(ps.epilogue_sources))
        ps_src = sources[src_i:src_i + ns]
        ps_bc = bc_sources[bc_i:bc_i + nb]
        ps_epi = epi_sources[epi_i:epi_i + ne]
        src_i, bc_i, epi_i = src_i + ns, bc_i + nb, epi_i + ne
        # Pass bindings: earlier passes' merged values, plus this pass's
        # whole-staged small physical sources.
        bindings = {nid: carried[nid] for nid in ps.binding_ids}
        for nid, mat in ps.broadcast_source_pairs(ps_bc):
            bindings[nid] = _stage_whole(mat)
        t_pass = time.perf_counter()
        with TRACER.span("pass", idx=ps.idx, mode=mode,
                         partition_rows=ps.partition_rows):
            if mode == "whole":
                finals, out_parts, epi_outs = _execute_whole_pass(
                    ps, pprog, mesh, ps_src, smalls, ps_epi, bindings)
            else:
                finals, out_parts, epi_outs, dstores = _execute_stream_pass(
                    ps, pprog, ps_src, smalls, ps_epi, bindings,
                    to_host=(mode == "ooc"), donate=donate,
                    prefetch=prefetch)
                disk_all.update(dstores)
        metrics.inc("pass_seconds", time.perf_counter() - t_pass)
        metrics.inc("passes")
        pb = ps.bytes_in(ps_src)
        pass_bytes.append(pb)
        metrics.inc("bytes_streamed", pb)
        finals_all.update(finals)
        parts_all.update(out_parts)
        epi_all.update(epi_outs)
        carried.update(finals)
        carried.update(epi_outs)
    metrics.put("pass_bytes_in", tuple(pass_bytes))
    _store_results(plan, finals_all, parts_all, to_host=(mode == "ooc"),
                   disk_stores=disk_all, epilogue_outs=epi_all)
    return plan


def _pick_mode_src(sources, mode: str) -> str:
    if mode != "auto":
        return mode
    if any(mat.on_host for mat in sources):
        return "ooc"
    return "whole"


def _stage_whole(mat) -> "jax.Array":
    """Stage a small matrix whole onto the device (broadcast/epilogue
    sources, pass bindings must never leak host buffers into jit)."""
    data = mat.logical_data()
    return jnp.asarray(np.asarray(data)) if mat.on_host else data


def _execute_whole_pass(ps, prog, mesh, sources, smalls, epi_sources,
                        bindings):
    # One staged array per physical matrix; leaves aliasing it share the
    # buffer through the pass's source_aliases (see LoweredProgram._step).
    blocks = {}
    for nid, mat in ps.staged_sources(sources):
        data = mat.logical_data()
        arr = jnp.asarray(np.asarray(data)) if mat.on_host else data
        if mesh is not None and mat.shape[0] == ps.long_dim:
            arr = jax.device_put(arr, NamedSharding(mesh, _long_spec(mesh)))
        blocks[nid] = arr
    offset = jnp.zeros((), jnp.int32)
    metrics.inc("partition_steps")
    with TRACER.span("partition", start=0, stop=ps.long_dim):
        t0 = time.perf_counter()
        with TRACER.span("device_step", rows=ps.long_dim):
            partials, outputs = prog.step(blocks, smalls, bindings, offset)
            if TRACER.enabled:  # timing fidelity; async dispatch otherwise
                jax.block_until_ready((partials, outputs))
        metrics.inc("device_step_seconds", time.perf_counter() - t0)
        t0 = time.perf_counter()
        with TRACER.span("combine"):
            accs = prog.combine(ps.init_accs(), partials)
            if TRACER.enabled:
                jax.block_until_ready(accs)
        metrics.inc("combine_seconds", time.perf_counter() - t0)
    finals = ps.finalize_accs(accs)
    epi_outs = _run_epilogue(ps, prog, finals, epi_sources, smalls, bindings)
    return finals, {nid: [v] for nid, v in outputs.items()}, epi_outs


def _run_epilogue(ps, prog, sink_finals, epi_sources, smalls, bindings):
    """Invoke the lowered epilogue exactly ONCE after a pass's merge.

    Inputs are the finalized sink values (device arrays out of the jitted
    ``combine``) plus any small physical matrices only the epilogue
    consumes, staged with ``jnp.asarray`` so a disk-backed plan never leaks
    ``np.memmap``/numpy buffers into the compiled callable — the
    ``epilogue_host_inputs`` counter records any violation.
    """
    if prog.epilogue is None:
        return {}
    epi_vals = {}
    for nid, mat in ps.epilogue_source_pairs(epi_sources):
        epi_vals[nid] = _stage_whole(mat)
    leaves = jax.tree_util.tree_leaves((sink_finals, epi_vals))
    metrics.inc("epilogue_host_inputs", sum(
        1 for leaf in leaves if isinstance(leaf, np.ndarray)))
    metrics.inc("epilogue_launches")
    t0 = time.perf_counter()
    with TRACER.span("epilogue", idx=ps.idx):
        outs = prog.epilogue(sink_finals, epi_vals, smalls, bindings)
        if TRACER.enabled:
            jax.block_until_ready(outs)
    metrics.inc("epilogue_seconds", time.perf_counter() - t0)
    return outs


def _long_spec(mesh):
    """Shard the long dimension across every data-like mesh axis; model-like
    axes (if any) replicate — GenOps are row-parallel (DESIGN.md §1.3)."""
    data_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data", "x", "i"))
    if not data_axes:
        data_axes = (mesh.axis_names[0],)
    return P(data_axes, None)


def _inline_partitions(src_pairs, rows: int, n: int, donate: bool):
    """Synchronous partition staging (prefetch-off ablation): same staging
    rules as the prefetch thread (storage.stage_block), but the disk read
    happens on the compute thread; only device_put dispatch overlaps."""
    from ..storage.prefetch import stage_block
    start = 0
    while start < n:
        stop = min(start + rows, n)
        yield start, stop, {
            nid: stage_block(mat, start, stop, donate=donate)
            for nid, mat in src_pairs}
        start = stop


def _execute_stream_pass(ps, prog, sources, smalls, epi_sources, bindings, *,
                         to_host: bool, donate: bool = True,
                         prefetch: Optional[bool] = None):
    """Stream ONE pass of a plan partition-by-partition.  Each pass
    re-drives its own prefetcher over its own staged sources (a pass-2
    sweep re-reads the long-dimension matrices pass 1 already streamed)."""
    from .. import storage  # deferred: storage depends on core.matrix

    rows = ps.partition_rows
    n = ps.long_dim
    accs = ps.init_accs()
    out_parts: dict[int, list] = {x.id: [] for x in ps.row_local_roots + ps.saves}
    host_bufs: dict[int, np.ndarray] = {}
    disk_stores: dict[int, "storage.MmapStore"] = {}

    for x in ps.row_local_roots + ps.saves:
        target = x.save or ("host" if to_host else "device")
        if target == "disk":
            # Write-through spill: the long-dimension output streams into a
            # preallocated on-disk matrix, partition by partition — it never
            # exists whole in RAM.  Works for any pass: scale(X, save='disk')
            # spills the PASS-2 sweep output out-of-core end to end.
            disk_stores[x.id] = storage.create_matrix(
                storage.spill_path(x.name), (x.nrow, x.ncol),
                dtypes.np_equiv(x.dtype))
        elif target == "host":
            host_bufs[x.id] = np.empty((x.nrow, x.ncol), dtypes.np_equiv(x.dtype))

    # Deduped staging: one disk/RAM read + device_put per PHYSICAL matrix
    # per partition, however many leaves reference it (ROADMAP open item).
    src_pairs = ps.staged_sources(sources)
    if prefetch is None:
        # Default on for slow-tier sources; a single-partition stream has
        # nothing to overlap, so skip the thread.
        prefetch = (storage.get_conf("prefetch") and n > rows
                    and any(mat.on_host for mat in sources))
    if prefetch:
        parts = storage.PartitionPrefetcher(
            src_pairs, rows, n, donate=donate,
            depth=storage.get_conf("prefetch_depth"))
    else:
        parts = _inline_partitions(src_pairs, rows, n, donate)

    step = prog.step_donated if donate else prog.step
    try:
        for start, stop, blocks in parts:
            metrics.inc("partition_steps")
            with TRACER.span("partition", start=start, stop=stop):
                t0 = time.perf_counter()
                with TRACER.span("device_step", rows=stop - start):
                    partials, outputs = step(blocks, smalls, bindings,
                                             jnp.asarray(start, jnp.int32))
                    if TRACER.enabled:  # timing fidelity while tracing only
                        jax.block_until_ready((partials, outputs))
                metrics.inc("device_step_seconds", time.perf_counter() - t0)
                # The paper's partial-merge: each partition's sink partials
                # fold into the running accumulators with the aggregation
                # VUDFs' ``combine`` (donated: the old acc buffers recycle
                # in place).
                t0 = time.perf_counter()
                with TRACER.span("combine"):
                    accs = prog.combine(accs, partials)
                    if TRACER.enabled:
                        jax.block_until_ready(accs)
                metrics.inc("combine_seconds", time.perf_counter() - t0)
                for nid, val in outputs.items():
                    if nid in disk_stores:
                        disk_stores[nid].write_rows(start, np.asarray(val))
                    elif nid in host_bufs:
                        host_bufs[nid][start:stop] = np.asarray(val)
                    else:
                        out_parts[nid].append(val)
    finally:
        if hasattr(parts, "close"):
            parts.close()

    finals = ps.finalize_accs(accs)
    epi_outs = _run_epilogue(ps, prog, finals, epi_sources, smalls, bindings)
    for nid, buf in host_bufs.items():
        out_parts[nid] = [buf]
    for st in disk_stores.values():
        st.flush()
    return finals, out_parts, epi_outs, disk_stores


def _store_results(plan: Plan, sink_finals, out_parts, *, to_host: bool,
                   disk_stores=None, epilogue_outs=None):
    for node in plan.sinks:
        arr = sink_finals[node.id]
        node.cached_store = FMMatrix(
            node.shape, node.dtype, store=DenseStore(arr), name=node.name)
    if epilogue_outs:
        # Epilogue results are small post-merge values: like sinks they stay
        # on device in every mode, unless an explicit save flag retargets
        # them (out_parts routes them through the ordinary target logic).
        out_parts = dict(out_parts)
        for node in plan.epilogue_roots:
            out_parts[node.id] = [epilogue_outs[node.id]]
    epi_ids = {n.id for n in plan.epilogue_roots}
    for node in plan.row_local_roots + plan.saves + plan.epilogue_roots:
        if disk_stores and node.id in disk_stores:
            node.cached_store = FMMatrix(
                node.shape, node.dtype, store=disk_stores[node.id],
                name=node.name)
            node.save = None
            continue
        parts = out_parts[node.id]
        if len(parts) == 1:
            data = parts[0]
        else:
            data = jnp.concatenate(parts, axis=0)
        target = node.save or (
            "host" if to_host and node.id not in epi_ids else None)
        if target == "disk":
            # whole-mode save='disk': spill the materialized output in one go.
            from .. import storage
            store = storage.create_matrix(
                storage.spill_path(node.name), node.shape,
                dtypes.np_equiv(node.dtype))
            store.write_rows(0, np.asarray(data))
            store.flush()
            node.cached_store = FMMatrix(
                node.shape, node.dtype, store=store, name=node.name)
            node.save = None
            continue
        if target == "host" and not isinstance(data, np.ndarray):
            data = np.asarray(data)
        node.cached_store = FMMatrix(
            node.shape, node.dtype, store=DenseStore(data), name=node.name)
        node.save = None


# ---------------------------------------------------------------------------
# Eager (unfused) execution — the ablation baseline
# ---------------------------------------------------------------------------

def _materialize_eager(nodes: Sequence[Node], *, mode: str = "auto",
                       backend: Optional[str] = None):
    """Materialize every DAG node separately, writing each intermediate out
    in full before the next operation reads it back.

    This is the behaviour the paper ascribes to frameworks without operation
    fusion ("MLlib materializes operations such as aggregation separately"),
    and the `fuse=False` arm of benchmarks/fusion_ablation.py.  Out-of-core,
    every intermediate roundtrips the host tier (mem-fuse off); in memory,
    every intermediate lands in HBM (cache-fuse off).
    """
    order = Plan._cut_toposort(list(nodes))
    temp: list[Node] = []
    ooc = any(isinstance(n, LeafNode) and n.mat.on_host for n in order)
    for n in order:
        if Plan._is_source(n):
            continue
        sub = Plan([wrap(n)])
        sub_mode = mode
        if mode == "auto":
            sub_mode = "ooc" if ooc else "whole"
        if ooc and not n.is_sink:
            n.save = "host"  # roundtrip the slow tier, as an unfused engine must
        _execute(sub, mode=sub_mode, backend=backend)
        temp.append(n)
    return temp
