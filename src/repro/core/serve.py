"""Async multi-tenant serving layer (ISSUE 8).

FlashR's deployment story is one engine serving many users' R programs
over shared SSD-resident matrices; PR 7's ``fm.batch`` built the
co-scheduling primitive (k plans × 1 stream) but callers must assemble a
batch by hand.  `Engine` closes the loop for CONCURRENT callers:

  1. ``submit(*outputs)`` (any thread) plans the request immediately —
     its own `fusion.Plan`, plan-cache template, metrics scopes — and
     returns a `RequestHandle` future;
  2. requests wait in a short **admission window**; when it closes they
     are co-scheduled by `fusion.stream_group_key` exactly like a batch —
     strangers whose plans stream the same named matrix share ONE
     partition sweep (``exec_stats()['streams'] == 1`` per window);
  3. each group runs on a worker pool bounded by
     ``max_concurrent_streams`` AND by an **in-flight streamed-bytes
     cap** derived from the measured disk-tier bandwidth
     (``stream_bandwidth_bytes_s`` telemetry, PR 6) — admission control
     that keeps k streams from thrashing one SSD;
  4. a late request whose plan matches a LIVE group (same long dim,
     subset sources, row-addressed outputs) is **admitted mid-stream** at
     the next partition boundary instead of waiting for the next window:
     it rides the remaining partitions with the group, then the runner
     re-drives only the prefix it missed (`materialize._catch_up`).

Groups drive `materialize._run_stream_group` with a group-aware
negotiated prefetch depth (`storage.negotiate_depth`).  Per-request
futures resolve only after every pass of that request succeeded; a
failing group fails its members' futures and registers no partial sinks
(the fm.batch no-partial-results contract).  ``fm.collect_stats()``
scopes open at submit time are carried with the request, so each tenant
sees their OWN plan's passes/bytes, not the group's.
"""
from __future__ import annotations

import concurrent.futures
import threading
import time
from typing import Optional

from . import batch as batch_mod
from . import lowering
from . import materialize as mz
from .fusion import coschedule
from .matrix import FMMatrix
from ..observability import metrics
from ..observability.trace import TRACER

#: Floor of the 'auto' in-flight-bytes cap: even a slow measured tier
#: admits at least this much concurrently, so tiny test matrices never
#: serialize spuriously.
MIN_INFLIGHT_BYTES = 32 << 20


class EngineSaturated(RuntimeError):
    """`Engine.submit` backpressure (ISSUE 9): the pending queue held
    ``max_pending_requests`` for the whole ``submit_timeout_s`` wait.  The
    request was NOT enqueued; retry later or raise the cap."""


class ServeRequest(batch_mod.BatchRequest):
    """One submitted request: a BatchRequest plus its future + timing."""

    def __init__(self, outputs, *, structured: bool):
        super().__init__(outputs, structured=structured)
        self.future: "concurrent.futures.Future" = concurrent.futures.Future()
        self.t_submit = time.perf_counter()
        self.failed = False


class RequestHandle:
    """The caller's side of a submitted request."""

    def __init__(self, req: ServeRequest):
        self._req = req

    def result(self, timeout: Optional[float] = None):
        """Block until the request's results are registered; returns one
        physical FMMatrix (or a list, mirroring a multi-output submit).
        Raises whatever failed the request's group."""
        return self._req.future.result(timeout)

    def done(self) -> bool:
        return self._req.future.done()

    def exception(self, timeout: Optional[float] = None):
        return self._req.future.exception(timeout)


class _Gate:
    """Mid-stream admission point of one LIVE streaming group.

    ``offer`` (submit thread) parks a compatible late request; ``take``
    (the group's executor, at each partition boundary via the
    `_run_stream_group` ``admit`` hook) splices the parked members into
    the sweep.  ``close`` returns requests offered too late to be taken —
    the engine re-queues them for the next window."""

    def __init__(self, long_dim: int, rows: int, source_ids: frozenset,
                 to_host: bool):
        self.long_dim = long_dim
        self.rows = rows
        self.source_ids = source_ids
        self.to_host = to_host
        self._lock = threading.Lock()
        self._pending: list = []    # [(req, member)] offered, not yet taken
        self.admitted: list = []    # [(req, member)] riding the sweep
        self._closed = False

    def accepts(self, req: ServeRequest) -> bool:
        """Static compatibility: single-pass, same long dimension, staged
        sources a subset of the group's, partition rows no finer than the
        group's sweep, and long-dimension outputs row-addressed (host or
        disk) — the same constraints `materialize._join_member` enforces."""
        if req.n_passes != 1:
            return False
        ps = req.plan.passes[0]
        if ps.long_dim != self.long_dim or ps.partition_rows < self.rows:
            return False
        srcs = [m for _, m in req.plan.sources]
        if not {id(m) for _, m in ps.staged_sources(srcs)} <= self.source_ids:
            return False
        outs = ps.row_local_roots + ps.saves
        default = "host" if self.to_host else "device"
        return all((n.save or default) != "device" for n in outs)

    def offer(self, req: ServeRequest, member) -> bool:
        with self._lock:
            if self._closed:
                return False
            self._pending.append((req, member))
            return True

    def take(self, start: int, stop: int) -> list:
        with self._lock:
            taken, self._pending = self._pending, []
            self.admitted.extend(taken)
            return [member for _, member in taken]

    def close(self) -> list:
        """Seal the gate; returns requests offered but never taken."""
        with self._lock:
            self._closed = True
            leftover, self._pending = self._pending, []
            return [req for req, _ in leftover]


class Engine:
    """fm.serve / fm.Engine: the admission-controlled request scheduler.

    Parameters
    ----------
    window_ms : float
        Admission window: how long the scheduler holds the first request
        of a window open for same-source company (default 5 ms).
    max_window_requests : int or None
        Close the window early once this many requests are pending —
        deterministic batching for load generators and tests.
    max_concurrent_streams : int
        Worker pool size: how many co-scheduled groups may stream at once.
    max_inflight_bytes : int, None or 'auto'
        Admission control on the disk tier: a group whose union staged
        bytes would push the in-flight total past the cap waits
        (``serve_deferrals`` / ``serve_admission_wait_seconds``).  'auto'
        derives the cap from measured ``stream_bandwidth_bytes_s``
        telemetry (≈ ``bandwidth_window_s`` seconds of disk work,
        ≥ MIN_INFLIGHT_BYTES); None disables the cap.  At least one group
        is always admitted, so the cap can never deadlock.
    max_pending_requests : int or None
        Submitter backpressure (ISSUE 9): the pending queue is bounded.
        A ``submit()`` that finds the queue full blocks up to
        ``submit_timeout_s`` for the scheduler to drain a window, then
        raises `EngineSaturated` (``serve_rejections`` counter).  None
        (default) keeps the queue unbounded — the pre-ISSUE-9 behavior,
        where a burst of submitters could grow the queue without limit.
    submit_timeout_s : float
        How long a blocked ``submit()`` waits for queue space before
        rejecting (default 0: reject immediately when full).
    midstream_admission : bool
        Allow late same-group plans to join a live sweep at the next
        partition boundary (default True).  Under a ``mesh`` admission is
        SERIALIZED — a sharded sweep has no single partition-boundary
        order to splice into, so late requests wait for the next window
        (see `_run_group`).
    mode / backend / donate / prefetch / reuse_plans / mesh
        Per-group execution knobs, following ``fm.materialize``
        (``mesh=None`` adopts the configured ``fm.set_conf(mesh=...)``
        at submit time).
    prefetch_depth : int or None
        Override the group-aware negotiated prefetch depth.
    """

    def __init__(self, *, window_ms: float = 5.0,
                 max_window_requests: Optional[int] = None,
                 max_concurrent_streams: int = 2,
                 max_inflight_bytes="auto",
                 bandwidth_window_s: float = 0.25,
                 max_pending_requests: Optional[int] = None,
                 submit_timeout_s: float = 0.0,
                 midstream_admission: bool = True,
                 mode: str = "auto", backend: Optional[str] = None,
                 donate: bool = True, prefetch: Optional[bool] = None,
                 prefetch_depth: Optional[int] = None,
                 reuse_plans: bool = True, mesh=None):
        self.window_s = max(float(window_ms), 0.0) / 1e3
        self.max_window_requests = (int(max_window_requests)
                                    if max_window_requests else None)
        self.max_inflight_bytes = max_inflight_bytes
        self.bandwidth_window_s = float(bandwidth_window_s)
        self.max_pending_requests = (int(max_pending_requests)
                                     if max_pending_requests else None)
        self.submit_timeout_s = max(float(submit_timeout_s), 0.0)
        self.midstream_admission = bool(midstream_admission)
        self.mesh = mesh
        self.mode = mode
        self.backend = lowering.resolve_backend(backend)
        self.donate = donate
        self.prefetch = prefetch
        self.prefetch_depth = prefetch_depth
        self.reuse_plans = reuse_plans

        self._cv = threading.Condition()
        self._pending: list[ServeRequest] = []
        self._closed = False
        self._gates: list[_Gate] = []
        self._gates_lock = threading.Lock()
        self._bw_cv = threading.Condition()
        self._inflight_bytes = 0
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=max(1, int(max_concurrent_streams)),
            thread_name_prefix="fm-serve")
        self._scheduler = threading.Thread(
            target=self._schedule_loop, name="fm-serve-scheduler",
            daemon=True)
        self._scheduler.start()

    # -- submission ----------------------------------------------------------
    def submit(self, *outputs) -> RequestHandle:
        """Submit one request (what would otherwise be one
        ``fm.materialize(*outputs)`` call) from any thread; returns a
        future-like `RequestHandle`.  The request's plan is built here, on
        the caller's thread, under the caller's open ``fm.collect_stats()``
        scopes."""
        if self._closed:
            raise RuntimeError("engine is closed")
        mats = [getattr(x, "m", x) for x in outputs]
        for m in mats:
            if not isinstance(m, FMMatrix):
                raise TypeError(f"submit() takes lazy matrices, got {m!r}")
        req = ServeRequest(mats, structured=len(mats) != 1)
        metrics.inc("serve_requests")
        mesh = mz._default_mesh(self.mesh)
        if not batch_mod._plan_request(req, self.backend, mesh,
                                       self.reuse_plans):
            # Pure pass-through: every output is already physical.
            req.future.set_result(
                req.results() if req.structured else req.results()[0])
            return RequestHandle(req)
        if (mesh is None and self.midstream_admission
                and self._try_midstream(req)):
            return RequestHandle(req)
        with self._cv:
            if self._closed:
                raise RuntimeError("engine is closed")
            # Submitter backpressure (ISSUE 9): an unbounded pending list
            # let a submit storm outrun the scheduler without limit.  Wait
            # for a window to drain up to submit_timeout_s, then reject.
            if self.max_pending_requests is not None:
                deadline = time.perf_counter() + self.submit_timeout_s
                while (len(self._pending) >= self.max_pending_requests
                       and not self._closed):
                    left = deadline - time.perf_counter()
                    if left <= 0:
                        metrics.inc("serve_rejections")
                        raise EngineSaturated(
                            f"pending queue full "
                            f"({self.max_pending_requests} requests) for "
                            f"{self.submit_timeout_s:g}s")
                    self._cv.wait(timeout=left)
                if self._closed:
                    raise RuntimeError("engine is closed")
            self._pending.append(req)
            metrics.observe("serve_queue_depth", len(self._pending))
            self._cv.notify_all()
        return RequestHandle(req)

    def _try_midstream(self, req: ServeRequest) -> bool:
        """Offer ``req`` to a live compatible gate; True when parked."""
        with self._gates_lock:
            gate = next((g for g in self._gates if g.accepts(req)), None)
            if gate is None:
                return False
            member = batch_mod._member_for(req, 0)
            return gate.offer(req, member)

    # -- scheduler thread ----------------------------------------------------
    def _schedule_loop(self):
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if not self._pending and self._closed:
                    return
                # Admission window: hold the first request open for
                # same-source company, close early on max_window_requests.
                deadline = time.perf_counter() + self.window_s
                while not self._closed:
                    if (self.max_window_requests is not None
                            and len(self._pending) >= self.max_window_requests):
                        break
                    left = deadline - time.perf_counter()
                    if left <= 0:
                        break
                    self._cv.wait(timeout=left)
                window, self._pending = self._pending, []
                # Wake submitters blocked on max_pending_requests: the
                # queue just drained.
                self._cv.notify_all()
            try:
                self._run_window(window)
            except Exception as exc:  # noqa: BLE001 - fail the window, not the loop
                for req in window:
                    req.failed = True
                    if not req.future.done():
                        req.future.set_exception(exc)

    def _run_window(self, window: list):
        metrics.inc("serve_windows")
        metrics.observe("serve_window_requests", len(window))
        active = [req for req in window if not req.failed]
        n_rounds = max((req.n_passes for req in active), default=0)
        stream_bytes: list[int] = []
        with TRACER.span("serve_window", requests=len(active),
                         rounds=n_rounds):
            for r in range(n_rounds):
                live = [req for req in active
                        if not req.failed and r < req.n_passes]
                if not live:
                    break
                keys = [batch_mod.pass_group_key(req, r) for req in live]
                futs = []
                for group in coschedule(keys):
                    reqs = [live[i] for i in group]
                    futs.append(self._pool.submit(
                        self._run_group, reqs, r, stream_bytes))
                for f in futs:
                    exc = f.exception()
                    if exc is not None:  # _run_group failed outside its guard
                        for req in live:
                            if not req.future.done():
                                req.failed = True
                                req.future.set_exception(exc)
        # Root + ambient scopes see the PHYSICAL traffic: one entry per
        # stream group driven in this window.
        metrics.put("pass_bytes_in", tuple(stream_bytes))
        for req in active:
            if req.failed or req.future.done():
                continue
            self._finish_request(req)

    # -- group execution (worker pool) ---------------------------------------
    def _run_group(self, reqs: list, r: int, stream_bytes: list):
        members = [batch_mod._member_for(req, r) for req in reqs]
        union, seen = [], set()
        for m in members:
            for _, mat in m.ps.staged_sources(m.sources):
                if id(mat) not in seen:
                    seen.add(id(mat))
                    union.append(mat)
        union_bytes = sum(mat.nbytes() for mat in union)
        stream_bytes.append(union_bytes)
        group_mode = mz._pick_mode_src(union, self.mode)
        if group_mode not in ("whole", "stream", "ooc"):
            raise ValueError(f"unknown mode {group_mode!r}")

        gate = None
        mesh = mz._default_mesh(self.mesh)
        self._acquire_bandwidth(union_bytes)
        try:
            with TRACER.span("serve_group", members=len(members), round=r,
                             mode=group_mode):
                if group_mode == "whole":
                    mz._run_whole_group(members, mesh=mesh)
                else:
                    # Mid-stream admission is serialized under a mesh: the
                    # gate splices a late member into ONE sequential sweep
                    # at a partition boundary, but a sharded sweep has N
                    # concurrent boundary orders.  No gate opens, so late
                    # requests queue for the next window instead
                    # (test_serve: midstream_admits == 0 under mesh).
                    admit = None
                    if (mesh is None and self.midstream_admission
                            and r == 0):
                        gate = self._open_gate(members, group_mode)
                        admit = gate.take
                    mz._run_stream_group(
                        members, to_host=(group_mode == "ooc"),
                        donate=self.donate, prefetch=self.prefetch,
                        capture=False, admit=admit,
                        depth=self.prefetch_depth, mesh=mesh)
            admitted = gate.admitted if gate is not None else []
            pairs = list(zip(members, reqs)) + [(m, req)
                                                for req, m in admitted]
            for m, req in pairs:
                if group_mode == "ooc":
                    req.to_host = True
                req.pass_bytes.append(m.ps.bytes_in(m.sources))
                req.finals.update(m.finals)
                req.parts.update(m.out_parts)
                req.epi.update(m.epi_outs)
                req.disk.update(m.disk_stores)
                req.carried.update(m.finals)
                req.carried.update(m.epi_outs)
            # Mid-admitted requests are single-pass: resolve them now.
            for req, _ in admitted:
                self._finish_request(req)
        except Exception as exc:  # noqa: BLE001 - fail the group's members only
            admitted = gate.admitted if gate is not None else []
            for req in list(reqs) + [rq for rq, _ in admitted]:
                req.failed = True
                if not req.future.done():
                    req.future.set_exception(exc)
        finally:
            if gate is not None:
                self._close_gate(gate)
            self._release_bandwidth(union_bytes)

    def _finish_request(self, req: ServeRequest):
        """Register the request's results onto its own plan and resolve
        its future (the batch `_store_results(onto=)` discipline)."""
        try:
            ambient = set(metrics.REGISTRY.scopes())
            for sc in req.scopes:
                if sc not in ambient:
                    sc.put("pass_bytes_in", tuple(req.pass_bytes))
            mz._store_results(req.exec_plan, req.finals, req.parts,
                              to_host=req.to_host, disk_stores=req.disk,
                              epilogue_outs=req.epi, onto=req.plan)
            res = req.results()
            metrics.observe("serve_request_seconds",
                            time.perf_counter() - req.t_submit)
            req.future.set_result(res if req.structured else res[0])
        except Exception as exc:  # noqa: BLE001
            req.failed = True
            if not req.future.done():
                req.future.set_exception(exc)

    # -- mid-stream gates ----------------------------------------------------
    def _open_gate(self, members, group_mode: str) -> _Gate:
        source_ids = frozenset(
            id(mat) for m in members
            for _, mat in m.ps.staged_sources(m.sources))
        gate = _Gate(members[0].ps.long_dim,
                     min(m.ps.partition_rows for m in members),
                     source_ids, to_host=(group_mode == "ooc"))
        with self._gates_lock:
            self._gates.append(gate)
        return gate

    def _close_gate(self, gate: _Gate):
        with self._gates_lock:
            if gate in self._gates:
                self._gates.remove(gate)
        leftover = gate.close()
        if not leftover:
            return
        # Offered after the sweep's last boundary: back to the queue for
        # the next window (never dropped, never half-admitted).
        with self._cv:
            self._pending.extend(leftover)
            self._cv.notify_all()

    # -- bandwidth admission control -----------------------------------------
    def _current_cap(self) -> Optional[int]:
        cap = self.max_inflight_bytes
        if cap is None:
            return None
        if cap == "auto":
            root = metrics.REGISTRY.root
            read_s = root.counter("stage_read_seconds")
            if read_s <= 0:
                return None  # no telemetry yet: first groups calibrate
            bw = root.counter("stage_bytes_read") / read_s
            return max(int(bw * self.bandwidth_window_s),
                       MIN_INFLIGHT_BYTES)
        return int(cap)

    def _acquire_bandwidth(self, nbytes: int):
        with self._bw_cv:
            cap = self._current_cap()
            if (cap is not None and self._inflight_bytes > 0
                    and self._inflight_bytes + nbytes > cap):
                metrics.inc("serve_deferrals")
                t0 = time.perf_counter()
                with TRACER.span("admission_wait", nbytes=nbytes, cap=cap):
                    # A group is always admitted once the tier is idle, so
                    # a cap smaller than one group cannot deadlock.
                    while self._inflight_bytes > 0:
                        cap = self._current_cap()
                        if cap is None or \
                                self._inflight_bytes + nbytes <= cap:
                            break
                        self._bw_cv.wait(timeout=0.05)
                metrics.inc("serve_admission_wait_seconds",
                            time.perf_counter() - t0)
            self._inflight_bytes += nbytes
            metrics.observe("serve_inflight_bytes", self._inflight_bytes)

    def _release_bandwidth(self, nbytes: int):
        with self._bw_cv:
            self._inflight_bytes -= nbytes
            self._bw_cv.notify_all()

    # -- lifecycle -----------------------------------------------------------
    def stats(self) -> dict:
        """Root-scope serving metrics: serve_* counters/histograms plus
        ``midstream_admits``."""
        st = metrics.REGISTRY.root.stats()
        out = {k: v for k, v in st.items() if k.startswith("serve_")}
        out["midstream_admits"] = int(st.get("midstream_admits", 0))
        return out

    def close(self, release_storage: bool = False):
        """Drain every pending request, stop the scheduler, shut the pool
        down.  Idempotent; the context-manager exit calls it.
        ``release_storage=True`` additionally removes every registry-OWNED
        lazily-created data dir (`storage.registry.cleanup`) — never a
        user-configured ``data_dir``."""
        with self._cv:
            if self._closed:
                self._cv.notify_all()
            self._closed = True
            self._cv.notify_all()
        self._scheduler.join(timeout=60.0)
        self._pool.shutdown(wait=True)
        if release_storage:
            from ..storage import registry
            registry.cleanup()

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False


def serve(**kw) -> Engine:
    """fm.serve: start an `Engine` (see its docstring for the knobs).

        with fm.serve(window_ms=5) as eng:
            h1 = eng.submit(fm.colMeans(X))   # any thread
            h2 = eng.submit(fm.crossprod(X))  # same window, same stream
            mu, G = h1.result(), h2.result()
    """
    return Engine(**kw)
