"""FlashMatrix/FlashR core: GenOps, lazy DAG, fusion, streaming materialization.

Public surface:
  * `repro.core.fm` — the R-like namespace (paper's programming interface)
  * `repro.core.genops` — raw GenOps (paper Table I)
  * `repro.core.vudf` — VUDF registry (extend with register_*)
  * `repro.core.matrix` — FMMatrix handles + partition policy
"""
from . import (dtypes, vudf, matrix, dag, genops, plan_ir, lowering, fusion,
               materialize)
from . import rlike as fm
from .matrix import FMMatrix

__all__ = ["dtypes", "vudf", "matrix", "dag", "genops", "plan_ir",
           "lowering", "fusion", "materialize", "fm", "FMMatrix"]
