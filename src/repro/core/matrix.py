"""Dense-matrix handles and the two-level partitioning model.

Paper §III-B: dense matrices are the main data type; a matrix is *physical*
(in memory / on SSD) or *virtual* (a sequence of computation).  Tall-and-
skinny (TAS) matrices are the optimized case; wide matrices are viewed as
transposed TAS.  Two-level horizontal partitioning:

* **I/O-level partitions** — rows-per-partition is a power of two; each
  partition is contiguous in the slow tier and is the streaming/DMA unit
  (megabytes).  Our analog: the chunk granule of the out-of-core executor
  and the per-device shard granule under `shard_map`.
* **CPU-level partitions** — fits L1/L2 so a fused operation chain stays in
  cache.  Our analog: the Pallas BlockSpec VMEM tile (multiples of (8,128)).

``FMMatrix`` is an immutable handle.  Physical storage lives behind the
``MatrixStore`` protocol: ``DenseStore`` (jax array on device, or numpy array
in host RAM) or ``storage.MmapStore`` (the real SSD tier — an on-disk matrix
file served through ``np.memmap``, see repro/storage/).
Virtual matrices point at a DAG node (core/dag.py) and are materialized by
core/materialize.py.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import dtypes

# ---------------------------------------------------------------------------
# Partition-size policy
# ---------------------------------------------------------------------------

# Default I/O-level partition budget: bytes of the *fused group's* working
# set per partition.  64 MiB mirrors the paper's memory-chunk size; the
# fusion planner divides this by the number of live matrices in the group.
IO_PARTITION_BYTES = 64 * 1024 * 1024

# CPU-level partition budget: should fit comfortably in L1/L2 (paper) or a
# VMEM tile (TPU).  Used by the Pallas kernels' BlockSpec defaults.
CPU_PARTITION_BYTES = 128 * 1024

# Processor-level (second tier) partition budget for the execution engine's
# per-segment schedule: the VMEM working-set analog of the paper's CPU-cache
# partition (§III-F).  Settable via ``fm.set_conf(vmem_partition_bytes=...)``;
# read at plan-IR build, so it is part of the plan-cache key (the schedule).
VMEM_PARTITION_BYTES = 4 * 1024 * 1024

# TPU lane/sublane alignment: row counts that are multiples of 8 and column
# tiles that are multiples of 128 vectorize cleanly (paper's "number of rows
# in an I/O-level partition is always 2^i ... data well aligned ... to help
# CPU vectorization").
ROW_ALIGN = 8


def _pow2_rows(ncol: int, dtype, n_live: int, budget_bytes: int) -> int:
    """Largest power of two rows such that ``n_live`` arrays of that many
    rows fit the byte budget (paper: partitions are always 2^i rows)."""
    row_bytes = max(1, ncol) * dtypes.nbytes(dtype) * max(1, n_live)
    rows = max(ROW_ALIGN, budget_bytes // max(1, row_bytes))
    return 1 << (int(rows).bit_length() - 1)


def io_partition_rows(ncol: int, dtype, n_live: int = 1,
                      budget_bytes: Optional[int] = None) -> int:
    """Rows per I/O-level partition: the largest power of two such that
    ``n_live`` matrices of that many rows fit the partition budget.

    ``budget_bytes=None`` reads the module-level ``IO_PARTITION_BYTES`` at
    call time, so ``fm.set_conf(io_partition_bytes=...)`` takes effect on
    every subsequently built plan."""
    if budget_bytes is None:
        budget_bytes = IO_PARTITION_BYTES
    return _pow2_rows(ncol, dtype, n_live, budget_bytes)


def proc_partition_rows(ncol: int, dtype, n_live: int = 1,
                        budget_bytes: Optional[int] = None) -> int:
    """Rows per processor-level (VMEM-tile) partition for a fused segment:
    the same 2^i rule as the I/O level, one tier down (paper §III-F's
    second partitioning level).

    ``budget_bytes=None`` reads ``VMEM_PARTITION_BYTES`` at call time so
    ``fm.set_conf(vmem_partition_bytes=...)`` reschedules later plans."""
    if budget_bytes is None:
        budget_bytes = VMEM_PARTITION_BYTES
    return _pow2_rows(ncol, dtype, n_live, budget_bytes)


def cpu_partition_rows(ncol: int, dtype,
                       budget_bytes: int = CPU_PARTITION_BYTES) -> int:
    """Rows per CPU-level (VMEM-tile) partition.

    Paper: "FlashMatrix determines the number of rows in a CPU-level
    partition based on the number of columns in a matrix."
    """
    ncol = max(1, ncol)
    rows = max(ROW_ALIGN, budget_bytes // (ncol * dtypes.nbytes(dtype)))
    return (rows // ROW_ALIGN) * ROW_ALIGN


# ---------------------------------------------------------------------------
# Storage
# ---------------------------------------------------------------------------

class MatrixStore(abc.ABC):
    """Store protocol: the physical backing of a materialized matrix.

    ``FMMatrix`` is backend-agnostic — any tier (device HBM, host RAM, SSD)
    plugs in by implementing this interface.  The logical shape of the
    matrix is always (nrow, ncol); ``layout`` records the physical majority
    (paper supports both and avoids copies on transpose by flipping the
    tag).  A 'col'-layout store holds the transposed buffer, shape
    (ncol, nrow).

    Implementations: ``DenseStore`` (device / host-RAM tiers, below) and
    ``repro.storage.MmapStore`` (the disk tier).
    """

    layout: str = "row"  # 'row' | 'col'

    @property
    @abc.abstractmethod
    def on_host(self) -> bool:
        """True when partitions must be staged host→device by the executor
        (the out-of-core tiers: host RAM and disk)."""

    @property
    def on_disk(self) -> bool:
        return False

    @abc.abstractmethod
    def logical(self):
        """Return data in logical (nrow, ncol) orientation (may transpose)."""

    @abc.abstractmethod
    def block(self, start: int, stop: int):
        """Logical rows [start, stop) — the I/O-level partition read.
        Must touch only that partition's bytes, never the whole buffer."""

    @abc.abstractmethod
    def nbytes(self) -> int:
        """Physical size of the backing buffer in bytes."""

    @abc.abstractmethod
    def transposed(self) -> "MatrixStore":
        """A store over the same buffer with the layout tag flipped
        (the zero-copy transpose)."""


@dataclasses.dataclass
class DenseStore(MatrixStore):
    """In-memory backing: ``data`` is a jax Array (device tier) or numpy
    ndarray (host-RAM tier — paged in chunk-by-chunk by the streaming
    executor).  For a 'col'-layout matrix ``data`` holds the transposed
    buffer, i.e. shape (ncol, nrow)."""

    data: Any
    layout: str = "row"  # 'row' | 'col'

    @property
    def on_host(self) -> bool:
        return isinstance(self.data, np.ndarray)

    def logical(self):
        return self.data.T if self.layout == "col" else self.data

    def block(self, start: int, stop: int):
        # Slice the stored buffer and transpose only the block — a col-layout
        # store must never transpose the entire buffer per partition read.
        if self.layout == "col":
            return self.data[:, start:stop].T
        return self.data[start:stop]

    def nbytes(self) -> int:
        return int(self.data.nbytes)

    def transposed(self) -> "DenseStore":
        return DenseStore(self.data, "col" if self.layout == "row" else "row")


class FMMatrix:
    """Immutable matrix handle (paper: all FlashMatrix matrices are immutable).

    Exactly one of ``store`` / ``node`` is set:
      * store: MatrixStore       — physical matrix (any tier)
      * node:  dag.Node          — virtual matrix (lazy computation)
    """

    __slots__ = ("shape", "dtype", "store", "node", "name", "_transposed_of")

    def __init__(self, shape, dtype, *, store: Optional[MatrixStore] = None,
                 node=None, name: str = ""):
        assert (store is None) != (node is None), "exactly one backing"
        self.shape = (int(shape[0]), int(shape[1]))
        self.dtype = dtypes.canon(dtype)
        self.store = store
        self.node = node
        self.name = name
        self._transposed_of: Optional[FMMatrix] = None

    # -- basic properties ---------------------------------------------------
    @property
    def nrow(self) -> int:
        return self.shape[0]

    @property
    def ncol(self) -> int:
        return self.shape[1]

    @property
    def is_virtual(self) -> bool:
        return self.node is not None

    @property
    def is_tall(self) -> bool:
        return self.nrow >= self.ncol

    @property
    def long_dim(self) -> int:
        """Size of the long dimension (paper: the dimension with larger size)."""
        return max(self.shape)

    @property
    def long_axis(self) -> int:
        return 0 if self.is_tall else 1

    @property
    def on_host(self) -> bool:
        return self.store is not None and self.store.on_host

    @property
    def on_disk(self) -> bool:
        return self.store is not None and self.store.on_disk

    @property
    def is_sparse(self) -> bool:
        """True for a physical matrix on the sparse (CSR/ELL) tier."""
        return self.store is not None and getattr(self.store, "sparse", False)

    def nbytes(self) -> int:
        """Bytes the streaming executor actually moves for this matrix.

        Physical matrices ask the store — on the sparse tier that is the
        nnz-proportional section size, not nrow·ncol·itemsize (dense
        stores report exactly the dense formula, so this is a pure
        delegation, not a behavior change)."""
        if self.store is not None:
            return int(self.store.nbytes())
        return self.nrow * self.ncol * dtypes.nbytes(self.dtype)

    # -- construction helpers -------------------------------------------------
    @staticmethod
    def from_array(arr, *, layout: str = "row", name: str = "") -> "FMMatrix":
        """Wrap a jax/numpy array (1-D arrays become one-column matrices,
        mirroring the paper's 'a vector is stored as a one-column dense
        matrix')."""
        if hasattr(arr, "ndim") and arr.ndim == 1:
            arr = arr.reshape(-1, 1)
        if isinstance(arr, np.ndarray):
            data = np.asarray(arr, dtype=dtypes.np_equiv(arr.dtype))
        else:
            data = jnp.asarray(arr)
        shape = data.shape
        if layout == "col":
            data = data.T  # store transposed buffer
        return FMMatrix(shape, arr.dtype, store=DenseStore(data, layout), name=name)

    def transpose(self) -> "FMMatrix":
        """Lazy transpose: no data movement, flip layout tag (paper §III-B1:
        'we avoid data copy for common matrix operations such as matrix
        transpose')."""
        if self.store is not None:
            out = FMMatrix((self.ncol, self.nrow), self.dtype,
                           store=self.store.transposed(),
                           name=f"t({self.name})" if self.name else "")
        else:
            # Virtual transpose handle: consumers (inner_prod) peel it off.
            out = FMMatrix((self.ncol, self.nrow), self.dtype, node=self.node,
                           name=f"t({self.name})" if self.name else "")
        out._transposed_of = self
        return out

    @property
    def transposed_of(self) -> Optional["FMMatrix"]:
        return self._transposed_of

    # -- data access ----------------------------------------------------------
    def logical_data(self):
        """Materialized data in logical row-major orientation.

        Only valid on physical matrices; virtual matrices must go through
        core.materialize first.
        """
        if self.store is None:
            raise ValueError(
                f"matrix {self.name or '<anon>'} is virtual; call "
                "fm.materialize() first")
        return self.store.logical()

    def block(self, start: int, stop: int):
        """Slice ROWS [start, stop) of a *physical* matrix in logical
        orientation — the I/O-level partition read (rows are the streaming
        axis throughout the engine; see dag.long_dim_of).  Delegates to the
        store so only the partition's bytes are touched."""
        if self.store is None:
            raise ValueError(
                f"matrix {self.name or '<anon>'} is virtual; call "
                "fm.materialize() first")
        return self.store.block(start, stop)

    def __repr__(self):
        kind = ("virtual" if self.is_virtual
                else "disk" if self.on_disk
                else "host" if self.on_host else "device")
        return (f"FMMatrix({self.nrow}x{self.ncol}, {self.dtype.name}, {kind}"
                + (f", name={self.name!r}" if self.name else "") + ")")


# ---------------------------------------------------------------------------
# Construction utilities (paper Table II)
# ---------------------------------------------------------------------------

def rep_int(value, n: int, dtype=jnp.float32) -> FMMatrix:
    """fm.rep.int: vector with a repeated value."""
    return FMMatrix.from_array(jnp.full((n,), value, dtypes.canon(dtype)))


def seq_int(n: int, dtype=jnp.int64) -> FMMatrix:
    """fm.seq.int: 0..n-1 sequence vector."""
    return FMMatrix.from_array(jnp.arange(n, dtype=dtypes.canon(dtype)))


def runif_matrix(nrow: int, ncol: int, *, key=None, dtype=jnp.float32,
                 minval=0.0, maxval=1.0, host: bool = False) -> FMMatrix:
    """fm.runif.matrix: uniform random matrix.  host=True places it on the
    out-of-core tier (numpy), the SSD stand-in."""
    key = key if key is not None else jax.random.PRNGKey(0)
    dt = dtypes.canon(dtype)
    x = jax.random.uniform(key, (nrow, ncol), dt, minval, maxval)
    if host:
        return FMMatrix.from_array(np.asarray(x))
    return FMMatrix.from_array(x)


def rnorm_matrix(nrow: int, ncol: int, *, key=None, dtype=jnp.float32,
                 mean=0.0, sd=1.0, host: bool = False) -> FMMatrix:
    """fm.rnorm.matrix: normal random matrix."""
    key = key if key is not None else jax.random.PRNGKey(0)
    dt = dtypes.canon(dtype)
    x = jax.random.normal(key, (nrow, ncol), dt) * sd + mean
    if host:
        return FMMatrix.from_array(np.asarray(x))
    return FMMatrix.from_array(x)


def conv_R2FM(arr, *, host: bool = False) -> FMMatrix:
    """fm.conv.R2FM: wrap an external (numpy) array."""
    if host:
        return FMMatrix.from_array(np.asarray(arr))
    return FMMatrix.from_array(jnp.asarray(arr))


def conv_FM2R(mat: FMMatrix) -> np.ndarray:
    """fm.conv.FM2R: to a host numpy array (materializes virtuals)."""
    if mat.is_virtual:
        from . import materialize as _mat
        mat = _mat.materialize(mat)[0]
    return np.asarray(mat.logical_data())


def conv_store(mat: FMMatrix, where: str, *, name: str = "") -> FMMatrix:
    """fm.conv.store: move a physical matrix between tiers
    ('device' = HBM analog, 'host' = RAM tier, 'disk' = the real SSD tier —
    FlashR's ``fm.conv.store(in.mem=FALSE)``).

    ``where='disk'`` writes the matrix into the configured data directory
    (``storage.registry.set_conf``) under ``name`` (or the matrix's own
    name) and returns a handle backed by ``MmapStore``."""
    if where == "disk":
        from ..storage import registry as _registry  # lazy: avoid cycle
        if getattr(mat.store, "sparse", False):
            return _registry.save_sparse_matrix(mat, name or mat.name or None)
        return _registry.save_dense_matrix(mat, name or mat.name or None)
    if getattr(mat.store, "sparse", False) and where in ("host", "device"):
        # Tier moves keep the sparse representation: only cols/vals migrate.
        from ..storage.sparse import SparseEllStore  # lazy: avoid cycle
        blk = mat.store.block(0, mat.nrow)
        conv = (np.asarray if where == "host"
                else (lambda a: jnp.asarray(np.asarray(a))))
        store = SparseEllStore(conv(blk.cols), conv(blk.vals), mat.ncol,
                               nnz=getattr(mat.store, "nnz", None))
        return FMMatrix(mat.shape, mat.dtype, store=store, name=mat.name)
    data = mat.logical_data()
    if where == "host":
        return FMMatrix.from_array(np.asarray(data), name=mat.name)
    if where == "device":
        return FMMatrix.from_array(jnp.asarray(np.asarray(data)), name=mat.name)
    raise ValueError(f"unknown store {where!r}")


def conv_layout(mat: FMMatrix, layout: str) -> FMMatrix:
    """fm.conv.layout: physically convert row/col majority."""
    data = mat.logical_data()
    if layout == mat.store.layout:
        return mat
    if isinstance(data, np.ndarray):
        buf = np.ascontiguousarray(data.T) if layout == "col" else np.ascontiguousarray(data)
    else:
        buf = data.T if layout == "col" else data
    return FMMatrix(mat.shape, mat.dtype, store=DenseStore(buf, layout), name=mat.name)


def rbind(*mats: FMMatrix) -> FMMatrix:
    """fm.rbind: stack physical matrices by rows."""
    datas = [m.logical_data() for m in mats]
    if any(isinstance(d, np.ndarray) for d in datas):
        return FMMatrix.from_array(np.concatenate([np.asarray(d) for d in datas], 0))
    return FMMatrix.from_array(jnp.concatenate(datas, 0))


def cbind_physical(*mats: FMMatrix) -> FMMatrix:
    """fm.cbind on physical matrices (virtual cbind lives in the DAG)."""
    datas = [m.logical_data() for m in mats]
    if any(isinstance(d, np.ndarray) for d in datas):
        return FMMatrix.from_array(np.concatenate([np.asarray(d) for d in datas], 1))
    return FMMatrix.from_array(jnp.concatenate(datas, 1))
