"""Vectorized user-defined functions (VUDFs).

The paper (§III-D) attacks per-element function-call overhead by passing
*vectors* of elements to user-defined functions and selecting among multiple
"forms" (vector-vector, vector-scalar, scalar-vector, aggregate/combine) per
GenOp and data layout.  Under JAX the tracing compiler inlines the element
function into the fused kernel, which is the limiting case of the same idea
(call overhead amortized over the entire block rather than 128 elements).

We nonetheless keep an explicit VUDF *registry* because the fusion optimizer
(core/fusion.py) needs operator identity and algebraic metadata:

* ``flops``-per-element for the roofline/complexity counters,
* dtype rules (R-style promotion; comparisons produce bool; division
  promotes to floating),
* for aggregation VUDFs: the ``identity`` element and a separate ``combine``
  so partition-partial results merge exactly like the paper's
  "merge the partial aggregation results" step, and
* whether a binary op is commutative (lets the optimizer canonicalize
  scalar-operand sides, i.e. pick between bVUDF2/bVUDF3 forms).

Every VUDF body is a pure ``jnp`` function over arrays of any shape — the
three binary forms of the paper (vec∘vec, vec∘scalar, scalar∘vec) are
subsumed by broadcasting, and the form bookkeeping survives as the
``OperandKind`` tags the DAG keeps per argument.

Users extend the framework by registering new VUDFs (`register_unary`,
`register_binary`, `register_agg`) exactly as in the paper — except the
implementation language is jnp instead of C++, so the same definition runs
in-memory, out-of-core, and inside Pallas kernel bodies.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from . import dtypes


# --------------------------------------------------------------------------
# VUDF descriptors
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UnaryVUDF:
    """uVUDF: vector -> vector of the same length."""

    name: str
    fn: Callable  # jnp array -> jnp array
    flops: float = 1.0
    # dtype rule: None => same as input; "float" => to_floating(input);
    # "bool" => bool; a concrete dtype string => that dtype.
    dtype_rule: Optional[str] = None

    def out_dtype(self, in_dtype) -> jnp.dtype:
        return _apply_rule(self.dtype_rule, dtypes.canon(in_dtype))

    def __call__(self, x):
        return self.fn(x)


@dataclasses.dataclass(frozen=True)
class BinaryVUDF:
    """bVUDF: the three forms (vv, vs, sv) realized through broadcasting."""

    name: str
    fn: Callable  # (a, b) -> out, broadcasting
    flops: float = 1.0
    dtype_rule: Optional[str] = None
    commutative: bool = False

    def out_dtype(self, a_dtype, b_dtype) -> jnp.dtype:
        return _apply_rule(self.dtype_rule, dtypes.promote(a_dtype, b_dtype))

    def __call__(self, a, b):
        return self.fn(a, b)


@dataclasses.dataclass(frozen=True)
class AggVUDF:
    """Aggregation VUDF = (aggregate, combine) pair with an identity.

    ``aggregate`` reduces a block along an axis (aVUDF1: block->scalar /
    row / col partials).  ``combine`` merges two partial results of equal
    shape (aVUDF2).  ``finalize`` post-processes the merged partial (used by
    e.g. mean = sum/count packaged at the rlike level, and by argmin/argmax
    which carry (value, index) pairs through the reduction).

    For simple algebra (sum/min/max/...) the accumulator is a plain array.
    For indexed reductions the accumulator is a tuple pytree; ``aggregate``
    receives the *global offset* of the block along the reduced axis so
    indices are absolute, mirroring how the paper's aggregation VUDFs thread
    state through partitions.
    """

    name: str
    aggregate: Callable  # (block, axis, offset) -> partial
    combine: Callable    # (partial, partial) -> partial
    identity: Callable   # (shape, dtype) -> partial pytree
    finalize: Callable = staticmethod(lambda acc: acc)
    flops: float = 1.0
    dtype_rule: Optional[str] = None

    def out_dtype(self, in_dtype) -> jnp.dtype:
        return _apply_rule(self.dtype_rule, dtypes.canon(in_dtype))


def _apply_rule(rule: Optional[str], base: jnp.dtype) -> jnp.dtype:
    if rule is None:
        return base
    if rule == "float":
        return dtypes.to_floating(base)
    if rule == "bool":
        return jnp.dtype(jnp.bool_)
    if rule == "index":
        return jnp.dtype(jnp.int32)
    return dtypes.canon(rule)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

UNARY: dict[str, UnaryVUDF] = {}
BINARY: dict[str, BinaryVUDF] = {}
AGG: dict[str, AggVUDF] = {}


def register_unary(name: str, fn, *, flops: float = 1.0, dtype_rule=None) -> UnaryVUDF:
    u = UnaryVUDF(name, fn, flops, dtype_rule)
    UNARY[name] = u
    return u


def register_binary(name: str, fn, *, flops: float = 1.0, dtype_rule=None,
                    commutative: bool = False) -> BinaryVUDF:
    b = BinaryVUDF(name, fn, flops, dtype_rule, commutative)
    BINARY[name] = b
    return b


def register_agg(name: str, aggregate, combine, identity, *, finalize=None,
                 flops: float = 1.0, dtype_rule=None) -> AggVUDF:
    a = AggVUDF(name, aggregate, combine, identity,
                finalize or (lambda acc: acc), flops, dtype_rule)
    AGG[name] = a
    return a


def unary(name: str) -> UnaryVUDF:
    return UNARY[name]


def binary(name: str) -> BinaryVUDF:
    return BINARY[name]


def agg(name: str) -> AggVUDF:
    return AGG[name]


# --------------------------------------------------------------------------
# Built-in unary VUDFs (paper Table III element-wise rows + casts)
# --------------------------------------------------------------------------

register_unary("neg", lambda x: -x)
register_unary("abs", jnp.abs)
register_unary("sq", lambda x: x * x)
register_unary("sqrt", jnp.sqrt, dtype_rule="float")
register_unary("exp", jnp.exp, flops=8, dtype_rule="float")
register_unary("log", jnp.log, flops=8, dtype_rule="float")
register_unary("log1p", jnp.log1p, flops=8, dtype_rule="float")
register_unary("floor", jnp.floor)
register_unary("ceil", jnp.ceil)
register_unary("round", jnp.round)
register_unary("sign", jnp.sign)
register_unary("not", jnp.logical_not, dtype_rule="bool")
register_unary("isna", jnp.isnan, dtype_rule="bool")
register_unary("sigmoid", lambda x: 1.0 / (1.0 + jnp.exp(-x)), flops=10, dtype_rule="float")
register_unary("identity", lambda x: x, flops=0)

# Lazy-cast family (inserted by the DAG builder on dtype mismatch).
for _dt in ("bool", "int8", "int16", "int32", "int64", "bfloat16", "float32", "float64"):
    register_unary(
        f"cast_{_dt}",
        (lambda dt: (lambda x: x.astype(dt)))(_dt),
        flops=0,
        dtype_rule=_dt,
    )


# --------------------------------------------------------------------------
# Built-in binary VUDFs
# --------------------------------------------------------------------------

register_binary("add", jnp.add, commutative=True)
register_binary("sub", jnp.subtract)
register_binary("mul", jnp.multiply, commutative=True)
register_binary("div", jnp.divide, dtype_rule="float", flops=4)
register_binary("pow", jnp.power, dtype_rule="float", flops=12)
register_binary("mod", jnp.mod, flops=4)
register_binary("pmin", jnp.minimum, commutative=True)
register_binary("pmax", jnp.maximum, commutative=True)
register_binary("eq", lambda a, b: a == b, dtype_rule="bool", commutative=True)
register_binary("neq", lambda a, b: a != b, dtype_rule="bool", commutative=True)
register_binary("lt", lambda a, b: a < b, dtype_rule="bool")
register_binary("le", lambda a, b: a <= b, dtype_rule="bool")
register_binary("gt", lambda a, b: a > b, dtype_rule="bool")
register_binary("ge", lambda a, b: a >= b, dtype_rule="bool")
register_binary("and", jnp.logical_and, dtype_rule="bool", commutative=True)
register_binary("or", jnp.logical_or, dtype_rule="bool", commutative=True)
# The paper's missing-value workhorse (Fig. 5): ifelse0(x, mask) keeps x where
# ``mask`` is False and writes 0 where True.
register_binary("ifelse0", lambda x, m: jnp.where(m, jnp.zeros((), x.dtype), x))
register_binary("squared_diff", lambda a, b: (a - b) * (a - b), flops=2,
                commutative=True, dtype_rule=None)
register_binary("absdiff", lambda a, b: jnp.abs(a - b), flops=2, commutative=True)
register_binary("hamming", lambda a, b: (a != b).astype(jnp.float32), flops=1,
                commutative=True, dtype_rule="float32")


# --------------------------------------------------------------------------
# Built-in aggregation VUDFs
# --------------------------------------------------------------------------

def _sum_identity(shape, dtype):
    return jnp.zeros(shape, dtype)


def _agg_simple(reduce_fn):
    def aggregate(block, axis, offset):
        del offset
        return reduce_fn(block, axis=axis)
    return aggregate


register_agg(
    "sum",
    _agg_simple(jnp.sum),
    jnp.add,
    _sum_identity,
)

register_agg(
    "prod",
    _agg_simple(jnp.prod),
    jnp.multiply,
    lambda shape, dtype: jnp.ones(shape, dtype),
)

register_agg(
    "min",
    _agg_simple(jnp.min),
    jnp.minimum,
    lambda shape, dtype: jnp.full(shape, _type_max(dtype), dtype),
)

register_agg(
    "max",
    _agg_simple(jnp.max),
    jnp.maximum,
    lambda shape, dtype: jnp.full(shape, _type_min(dtype), dtype),
)

register_agg(
    "any",
    _agg_simple(jnp.any),
    jnp.logical_or,
    lambda shape, dtype: jnp.zeros(shape, jnp.bool_),
    dtype_rule="bool",
)

register_agg(
    "all",
    _agg_simple(jnp.all),
    jnp.logical_and,
    lambda shape, dtype: jnp.ones(shape, jnp.bool_),
    dtype_rule="bool",
)

# count: aggregate != combine (paper: "For some aggregation such as count,
# aggregate and combine are different.")
register_agg(
    "count",
    lambda block, axis, offset: jnp.sum(jnp.ones_like(block, dtypes.canon("int64")), axis=axis),
    jnp.add,
    lambda shape, dtype: jnp.zeros(shape, dtypes.canon("int64")),
    dtype_rule="int64",
)

register_agg(
    "count_nonzero",
    lambda block, axis, offset: jnp.sum((block != 0).astype(dtypes.canon("int64")), axis=axis),
    jnp.add,
    lambda shape, dtype: jnp.zeros(shape, dtypes.canon("int64")),
    dtype_rule="int64",
)


# Indexed reductions: the accumulator is a (value, index) pair pytree.  The
# block offset makes indices global, so out-of-core partitions compose.
def _argmin_aggregate(block, axis, offset):
    idx = jnp.argmin(block, axis=axis).astype(jnp.int32) + offset
    val = jnp.min(block, axis=axis)
    return (val, idx)


def _argmin_combine(a, b):
    av, ai = a
    bv, bi = b
    take_b = bv < av
    return (jnp.where(take_b, bv, av), jnp.where(take_b, bi, ai))


def _argmin_identity(shape, dtype):
    return (jnp.full(shape, _type_max(dtype), dtype),
            jnp.zeros(shape, jnp.int32))


register_agg(
    "which.min",
    _argmin_aggregate,
    _argmin_combine,
    _argmin_identity,
    finalize=lambda acc: acc[1],
    dtype_rule="index",
)


def _argmax_aggregate(block, axis, offset):
    idx = jnp.argmax(block, axis=axis).astype(jnp.int32) + offset
    val = jnp.max(block, axis=axis)
    return (val, idx)


def _argmax_combine(a, b):
    av, ai = a
    bv, bi = b
    take_b = bv > av
    return (jnp.where(take_b, bv, av), jnp.where(take_b, bi, ai))


register_agg(
    "which.max",
    _argmax_aggregate,
    _argmax_combine,
    lambda shape, dtype: (jnp.full(shape, _type_min(dtype), dtype),
                          jnp.zeros(shape, jnp.int32)),
    finalize=lambda acc: acc[1],
    dtype_rule="index",
)


# Numerically-stable streaming logsumexp: accumulator is (running_max,
# running_sum_scaled).  Needed by GMM's E-step over partitions.
def _lse_aggregate(block, axis, offset):
    del offset
    m = jnp.max(block, axis=axis)
    s = jnp.sum(jnp.exp(block - jnp.expand_dims(m, axis)), axis=axis)
    return (m, s)


def _lse_combine(a, b):
    am, asum = a
    bm, bsum = b
    m = jnp.maximum(am, bm)
    return (m, asum * jnp.exp(am - m) + bsum * jnp.exp(bm - m))


register_agg(
    "logsumexp",
    _lse_aggregate,
    _lse_combine,
    lambda shape, dtype: (jnp.full(shape, -jnp.inf, dtype), jnp.zeros(shape, dtype)),
    finalize=lambda acc: acc[0] + jnp.log(acc[1]),
    flops=10,
    dtype_rule="float",
)


def _type_max(dtype):
    dt = dtypes.canon(dtype)
    if dt.kind == "f":
        return np.inf
    if dt.kind == "b":
        return True
    return np.iinfo(dt.name).max


def _type_min(dtype):
    dt = dtypes.canon(dtype)
    if dt.kind == "f":
        return -np.inf
    if dt.kind == "b":
        return False
    return np.iinfo(dt.name).min
