"""Fusion optimizer: turn a DAG cut into a single partition-streaming program.

Paper §III-E/F: FlashMatrix "evaluates expressions lazily and fuses
operations aggressively in a single parallel execution job", materializing
multiple sinks together and streaming one partition through the *entire*
fused chain before touching the next partition ("After materializing a
CPU-level partition, the thread passes the partition to the subsequent
operation in the DAG, instead of materializing the next CPU-level partition
in the same matrix").

`Plan` compiles the induced subgraph of the requested outputs into

    step(accs, source_blocks, offset) -> (accs', row_local_outputs)

which the materializer invokes once per I/O-level partition (stream mode /
out-of-core) or once for the whole matrix (whole mode — XLA then performs
the cache-level fusion the paper implements by hand).  Because ``step`` is a
single traced function, every intermediate virtual matrix lives only as a
value inside one XLA computation: the analog of never writing intermediates
to SSD/DRAM.

The plan cuts the DAG at nodes that were previously persisted
(`fm.set.mate.level` → ``node.cached_store``), mirroring the paper's
materialization of non-sink matrices reused across iterations.

The plan also exposes the cost counters (FLOPs, bytes in/out) that feed
benchmarks/complexity.py and the roofline analysis.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from . import dtypes
from .dag import (LeafNode, Node, SinkNode, Small, as_node, long_dim_of)
from .matrix import FMMatrix, io_partition_rows


class Plan:
    """A fused execution plan over one DAG cut."""

    def __init__(self, outputs: Sequence[FMMatrix], *, fuse: bool = True):
        self.requested = [as_node(o) for o in outputs]
        self.fuse = fuse

        self.order = self._cut_toposort(list(self.requested))
        self.sinks: list[SinkNode] = [n for n in self.order if n.is_sink]
        self.row_local_roots: list[Node] = [
            n for n in self.requested
            if not n.is_sink and not self._is_source(n)]
        # Nodes flagged fm.set.mate.level persist during this execution
        # (paper's write-through materialization of non-sink matrices).
        self.saves: list[Node] = [
            n for n in self.order
            if n.save is not None and not n.is_sink and not self._is_source(n)
            and n not in self.row_local_roots]

        # Sources = physical leaves + previously-persisted cut points.
        self.sources: list[tuple[Node, FMMatrix]] = []
        for n in self.order:
            if isinstance(n, LeafNode):
                self.sources.append((n, n.mat))
            elif getattr(n, "cached_store", None) is not None:
                self.sources.append((n, n.cached_store))

        self.long_dim = long_dim_of(self.order)
        for node, mat in self.sources:
            if mat.shape[0] != self.long_dim and max(mat.shape) != 1:
                raise ValueError(
                    f"source {node.name} shape {mat.shape} rows are not "
                    f"aligned with the streaming dimension {self.long_dim}")

        # I/O-level partition size: budget divided by the number of live
        # long-aligned matrices in the fused group (paper §III-F chooses "a
        # relatively small partition size to balance the overhead of
        # accessing a partition, skew and memory consumption").
        n_live = max(1, len(self.sources) + len(self.row_local_roots) + len(self.saves))
        widths = [1]
        for node, mat in self.sources:
            widths.append(mat.ncol)
        for n in self.order:
            if not self._is_source(n) and not n.is_sink:
                widths.append(n.ncol)
        widest_dtype = max((n.dtype for n in self.order), key=dtypes.rank)
        self.partition_rows = io_partition_rows(max(widths), widest_dtype, n_live)

        # Small (broadcast) operands are runtime ARGUMENTS of the compiled
        # step, not baked constants — that is what lets a structurally
        # identical plan (k-means iteration N+1 with new centers) reuse the
        # compiled executable instead of retracing (see materialize._PLANS).
        self.smalls: list[Small] = []
        self._small_pos: dict[int, int] = {}
        for n in self.order:
            if self._is_source(n):
                continue  # cut points: parents live outside this plan
            for p in n.parents:
                if isinstance(p, Small) and id(p) not in self._small_pos:
                    self._small_pos[id(p)] = len(self.smalls)
                    self.smalls.append(p)

        self._jit_step = jax.jit(self._step)
        self._jit_step_donated = jax.jit(self._step, donate_argnums=(0, 1))
        self._jit_combine = jax.jit(self._combine)

    def signature(self) -> str:
        """Structural identity: two DAG cuts with the same signature can
        share one compiled plan (the compile-once/stream-many contract)."""
        import numpy as _np
        parts = [f"L{self.long_dim}"]
        pos = {n.id: i for i, n in enumerate(self.order)}
        for n in self.order:
            ps = []
            # sources are cut points: their parents are outside this plan
            parents = [] if self._is_source(n) else n.parents
            for p in parents:
                if isinstance(p, Small):
                    v = p.value
                    shape = getattr(v, "shape", ())
                    dt = getattr(v, "dtype", type(v).__name__)
                    ps.append(f"S{shape}:{dt}")
                else:
                    ps.append(f"N{pos[p.id]}")
            fn_info = getattr(n, "fn_info", None)
            fname = ""
            if fn_info:
                for key in ("vudf", "mul", "add"):
                    if key in fn_info:
                        fname += f":{fn_info[key].name}"
                if "num_groups" in fn_info:
                    fname += f":g{fn_info['num_groups']}"
            extra = ""
            for attr in ("agg", "mul", "add"):
                v = getattr(n, attr, None)
                if v is not None:
                    extra += f":{v.name}"
            ng = getattr(n, "num_groups", "")
            role = "q" if self._is_source(n) else ("s" if n.is_sink else "m")
            sv = n.save or ""
            parts.append(f"{role}|{n.kind}|{n.shape}|{n.dtype.name}|{fname}"
                         f"|{extra}|{ng}|{sv}|{','.join(ps)}")
        return ";".join(parts)

    def result_nodes(self):
        """Deterministic result slots (sinks + requested + saves)."""
        return list(self.sinks) + self.row_local_roots + self.saves

    def small_values(self):
        return [jnp.asarray(s.value) if hasattr(s.value, "shape")
                else s.value for s in self.smalls]

    # -- DAG walking -----------------------------------------------------------
    @staticmethod
    def _is_source(n: Node) -> bool:
        return isinstance(n, LeafNode) or getattr(n, "cached_store", None) is not None

    @classmethod
    def _cut_toposort(cls, roots):
        """toposort that cuts at nodes previously persisted via save flags."""
        seen, order = {}, []

        def visit(n: Node):
            if n.id in seen:
                return
            seen[n.id] = n
            if not cls._is_source(n) or isinstance(n, LeafNode):
                if getattr(n, "cached_store", None) is None:
                    for p in n.parent_nodes():
                        visit(p)
            order.append(n)

        for r in roots:
            visit(r)
        return order

    # -- traced step -----------------------------------------------------------
    def _step(self, accs, source_blocks, smalls, offset):
        """One partition through the whole fused DAG.

        ``source_blocks``: dict node-id -> partition array for every source.
        ``smalls``: runtime values for broadcast operands, positionally
        aligned with self.smalls.  ``offset``: global index of the
        partition's first row (makes indexed aggregations like which.min
        absolute across partitions).
        """
        values = dict(source_blocks)
        outputs = {}
        for n in self.order:
            if self._is_source(n):
                continue
            blocks = []
            for p in n.parents:
                blocks.append(smalls[self._small_pos[id(p)]]
                              if isinstance(p, Small) else values[p.id])
            if n.is_sink:
                accs = dict(accs)
                accs[n.id] = n.block_update(accs[n.id], blocks, offset)
            else:
                values[n.id] = n.block_eval(blocks, offset)
        for n in self.row_local_roots + self.saves:
            outputs[n.id] = values[n.id]
        return accs, outputs

    def _combine(self, a, b):
        by_id = self.sinks_by_id
        return {nid: by_id[nid].combine(a[nid], b[nid]) for nid in a}

    @property
    def sinks_by_id(self):
        return {n.id: n for n in self.sinks}

    def init_accs(self):
        return {n.id: n.identity() for n in self.sinks}

    def finalize_accs(self, accs):
        return {n.id: n.finalize(accs[n.id]) for n in self.sinks}

    # -- cost counters (feed complexity + roofline reports) -----------------------
    def flop_count(self) -> float:
        return float(sum(n.flops_per_row() * self.long_dim
                         for n in self.order if not self._is_source(n)))

    def bytes_in(self) -> int:
        return int(sum(mat.nbytes() for _, mat in self.sources))

    def bytes_out(self) -> int:
        total = 0
        for n in self.row_local_roots + self.saves + list(self.sinks):
            total += n.nrow * n.ncol * dtypes.nbytes(n.dtype)
        return int(total)

    def describe(self) -> str:
        lines = [f"Plan(long_dim={self.long_dim}, partition_rows={self.partition_rows},"
                 f" fuse={self.fuse})"]
        for n in self.order:
            role = ("source" if self._is_source(n)
                    else "sink" if n.is_sink else "fused")
            lines.append(f"  [{role:6s}] {n!r}")
        lines.append(f"  flops={self.flop_count():.3e} bytes_in={self.bytes_in():.3e}"
                     f" bytes_out={self.bytes_out():.3e}")
        return "\n".join(lines)
