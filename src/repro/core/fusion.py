"""Fusion optimizer: turn a DAG cut into a schedule of partition-streaming
passes.

Paper §III-E/F: FlashMatrix "evaluates expressions lazily and fuses
operations aggressively in a single parallel execution job", materializing
multiple sinks together and streaming one partition through the *entire*
fused chain before touching the next partition ("After materializing a
CPU-level partition, the thread passes the partition to the subsequent
operation in the DAG, instead of materializing the next CPU-level partition
in the same matrix").

`Plan` owns the *analysis* half of the engine: it cuts the DAG at persisted
nodes, toposorts the induced subgraph, and schedules it as an ordered list
of **passes** (`PassSchedule`).  Most programs are one pass; a program in
which a merged value feeds a row-local op — FlashR's ``scale(X)``, where the
``colMeans`` epilogue sweeps back over X — schedules as two: pass 1 streams
the sources and merges the moment sinks + epilogue, pass 2 re-streams the
long-dimension sources with the pass-1 results bound as broadcast smalls.
Pass numbers chain, so a moment-of-a-sweep program becomes three passes, and
so on.  The whole schedule compiles into ONE multi-program executable under
ONE plan-cache entry and runs in ONE ``fm.materialize`` call.

Each `PassSchedule` classifies its sources/sinks/outputs and picks the
I/O-level partition rows.  The executable halves live one layer down:
`plan_ir.compile_ir` groups each pass into typed fused segments with
per-segment processor-level tiles (the paper's second partition level), and
a `lowering` backend turns those segments into the ``step``/``combine``
programs the materializer streams partitions through.  Because ``step`` is
a single traced function, every intermediate virtual matrix lives only as a
value inside one computation: the analog of never writing intermediates to
SSD/DRAM.

The plan cuts the DAG at nodes that were previously persisted
(`fm.set.mate.level` → ``node.cached_store``), mirroring the paper's
materialization of non-sink matrices reused across iterations.

The plan also exposes the cost counters (FLOPs, bytes in/out) that feed
benchmarks/complexity.py and the roofline analysis.  ``bytes_in`` sums the
streamed reads of every pass, so a two-pass plan over one matrix honestly
reports two passes over its bytes.
"""
from __future__ import annotations

import threading
from typing import Sequence

import jax.numpy as jnp

from . import dtypes, plan_ir
from .dag import (LeafNode, Node, SinkNode, Small, as_node, long_dim_of,
                  schedule_passes)
from .matrix import FMMatrix, io_partition_rows
from .sparse import effective_ncol


def shard_ranges(long_dim: int, partition_rows: int,
                 n_shards: int) -> list[tuple[int, int]]:
    """Contiguous, partition-aligned half-open row ranges splitting
    ``[0, long_dim)`` into ``n_shards`` shards (ISSUE 9).

    Every boundary lands on a multiple of ``partition_rows`` so each shard
    streams WHOLE I/O-level partitions — the disk tier's ``block(start,
    stop)`` granule — and partitions are spread as evenly as possible
    (leading shards take the remainder).  When there are fewer partitions
    than shards, trailing ranges are empty ``(start, start)``: those
    shards idle and the shards counter reflects only the driven ones.
    """
    n_shards = max(1, int(n_shards))
    n_parts = max(1, -(-int(long_dim) // max(1, int(partition_rows))))
    base, extra = divmod(n_parts, n_shards)
    ranges, part = [], 0
    for s in range(n_shards):
        take = base + (1 if s < extra else 0)
        lo = min(part * partition_rows, long_dim)
        part += take
        hi = min(part * partition_rows, long_dim)
        ranges.append((int(lo), int(hi)))
    return ranges


def _conf_data_shards() -> int:
    """Data-axis size of the CONFIGURED mesh (fm.set_conf(mesh=...)), 1
    when unsharded.  Deferred imports: fusion is imported by the storage
    layer, so reaching back into it must happen at call time — same
    precedent as io_partition_rows reading IO_PARTITION_BYTES at
    plan-build time."""
    from ..storage import registry
    mesh = registry.get_conf("mesh")
    if mesh is None:
        return 1
    from ..distributed.sharding import data_axis_size
    return data_axis_size(mesh)


class PassSchedule:
    """One streaming pass of a plan: its own cut classification, staging
    groups, partition size and segment IR.

    A pass evaluates ``loop`` nodes (row-local chains and sinks) in the
    partition loop and ``epi`` nodes once after the merge.  Values produced
    by EARLIER passes that this pass consumes are its ``bindings``: merged
    sink/epilogue results handed to the compiled step as broadcast
    arguments (never streamed, never donated).  Physical sources split
    three ways:

    * ``sources``            — long-aligned matrices streamed partition by
      partition (the pass re-drives the prefetcher over them);
    * ``broadcast_sources``  — small physicals (a (1, p) moment vector cut
      point) consumed by row-local ops: staged whole, fed like bindings;
    * ``epilogue_sources``   — consumed only by epilogue math (a ridge eye):
      handed whole to the epilogue callable.
    """

    def __init__(self, plan: "Plan", idx: int):
        self.idx = idx
        self.long_dim = plan.long_dim
        self.smalls = plan.smalls
        self._small_pos = plan._small_pos
        roles, passno = plan.roles, plan.passno
        is_src = plan._is_source

        execu = [n for n in plan.order
                 if not is_src(n) and passno[n.id] == idx]
        self.epilogue_nodes: list[Node] = [
            n for n in execu if roles[n.id] == "epi"]
        self.epilogue_ids: set[int] = {n.id for n in self.epilogue_nodes}
        self.sinks: list[SinkNode] = [
            n for n in execu if roles[n.id] == "loop" and n.is_sink]
        self.row_local_roots: list[Node] = [
            n for n in plan.requested
            if not is_src(n) and not n.is_sink
            and roles[n.id] == "loop" and passno[n.id] == idx]
        self.saves: list[Node] = [
            n for n in execu
            if n.save is not None and roles[n.id] == "loop"
            and not n.is_sink and n not in self.row_local_roots]
        # Epilogue result slots: requested or save-flagged epilogue nodes.
        seen_roots: set[int] = set()
        self.epilogue_roots: list[Node] = []
        for n in list(plan.requested) + [m for m in plan.order
                                         if m.save is not None]:
            if (not is_src(n) and n.id in self.epilogue_ids
                    and n.id not in seen_roots):
                seen_roots.add(n.id)
                self.epilogue_roots.append(n)
        # Epilogue values a LATER pass consumes but nobody requested; the
        # lowered epilogue returns them alongside the roots so the executor
        # can bind them forward.  Filled in by Plan after every pass exists.
        self.epilogue_carries: list[Node] = []

        # Loop nodes this pass must evaluate: the backward closure of its
        # roots through streaming (row-local, non-sink) parents.  A chain
        # shared with an earlier pass is re-evaluated here — recomputing a
        # row-local chain is exactly one extra fused read, whereas carrying
        # it across passes would mean materializing a long intermediate.
        needed: dict[int, Node] = {}

        def pull(n: Node):
            if n.id in needed:
                return
            needed[n.id] = n
            for p in n.parents:
                if isinstance(p, Small) or not isinstance(p, Node) \
                        or is_src(p):
                    continue
                if roles[p.id] == "loop" and not p.is_sink:
                    pull(p)

        for n in self.sinks + self.row_local_roots + self.saves:
            pull(n)
        evaluated = set(needed) | self.epilogue_ids

        # Sources = physical leaves + previously-persisted cut points that
        # some evaluated node consumes.
        consumers: dict[int, list[Node]] = {}
        for n in plan.order:
            if n.id not in evaluated:
                continue
            for p in n.parents:
                if isinstance(p, Node) and is_src(p):
                    consumers.setdefault(p.id, []).append(n)
        self.order: list[Node] = [
            n for n in plan.order
            if n.id in evaluated or (is_src(n) and n.id in consumers)]

        # Bindings: merged values (sinks / epilogue outputs) produced by an
        # earlier pass and consumed here.
        self.bindings: list[Node] = []
        bind_seen: set[int] = set()
        for n in self.order:
            if is_src(n) or n.id not in evaluated:
                continue
            for p in n.parents:
                if (isinstance(p, Small) or not isinstance(p, Node)
                        or is_src(p) or p.id in evaluated
                        or p.id in bind_seen):
                    continue
                bind_seen.add(p.id)
                self.bindings.append(p)
        self.binding_ids: set[int] = bind_seen

        self.sources: list[tuple[Node, FMMatrix]] = []
        self.broadcast_sources: list[tuple[Node, FMMatrix]] = []
        self.epilogue_sources: list[tuple[Node, FMMatrix]] = []
        for n in self.order:
            if isinstance(n, LeafNode):
                mat = n.mat
            elif getattr(n, "cached_store", None) is not None:
                mat = n.cached_store
            else:
                continue
            cons = consumers.get(n.id, [])
            long_aligned = (mat.shape[0] == self.long_dim
                            and max(mat.shape) > 1)
            if cons and all(c.id in self.epilogue_ids for c in cons):
                # e.g. the ridge eye matrix of a regularized solve: handed
                # whole to the epilogue callable, never streamed.
                self.epilogue_sources.append((n, mat))
            elif long_aligned:
                if any(c.id in self.epilogue_ids for c in cons):
                    raise ValueError(
                        f"source {n.name} is consumed by both the partition "
                        f"loop and the plan epilogue; materialize the "
                        f"epilogue expression separately")
                self.sources.append((n, mat))
            else:
                # Small physical (a (1, p) cut-point vector): broadcast
                # whole.  Only row-local consumers may broadcast it — a sink
                # would re-reduce it once per partition.
                for c in cons:
                    if c.id in self.epilogue_ids:
                        continue
                    if c.is_sink or c.nrow != self.long_dim:
                        raise ValueError(
                            f"source {n.name} shape {mat.shape} rows are "
                            f"not aligned with the streaming dimension "
                            f"{self.long_dim}")
                self.broadcast_sources.append((n, mat))
        self._epi_src_ids = {n.id for n, _ in self.epilogue_sources}

        # Staging groups: every GenOp call wraps its own LeafNode, so a DAG
        # referencing one physical matrix through k leaves (crossprod(X) +
        # colSums(X), the IRLS weighted-gram pair, ...) would read each
        # partition k times in stream/ooc modes.  Group source nodes by the
        # identity of their physical matrix; the executor stages one block
        # per group and the lowered program fans it out to every alias.
        self.source_groups: list[list[Node]] = []
        self.source_aliases: dict[int, int] = {}
        by_mat: dict[int, int] = {}
        for node, mat in self.sources:
            gi = by_mat.get(id(mat))
            if gi is None:
                by_mat[id(mat)] = len(self.source_groups)
                self.source_groups.append([node])
            else:
                self.source_groups[gi].append(node)
        for group in self.source_groups:
            for node in group:
                self.source_aliases[node.id] = group[0].id

        # I/O-level partition size: budget divided by the number of live
        # long-aligned matrices in this pass (paper §III-F chooses "a
        # relatively small partition size to balance the overhead of
        # accessing a partition, skew and memory consumption").
        n_live = max(1, len(self.sources) + len(self.row_local_roots)
                     + len(self.saves))
        widths = [1]
        for node, mat in self.sources:
            # Sparse sources budget at what actually streams (2·kmax
            # scalars per row), not the logical ncol — a one-hot matrix
            # with 2^20 columns would otherwise shrink the I/O partition
            # to single-digit rows.
            widths.append(effective_ncol(mat))
        for n in self.order:
            if (not is_src(n) and not n.is_sink
                    and n.id not in self.epilogue_ids):
                widths.append(n.ncol)
        # An already-materialized request leaves the pass empty (pure
        # cache-hit read-back): default the dtype so the schedule stays
        # well-formed.
        widest_dtype = max((n.dtype for n in self.order), key=dtypes.rank,
                           default=dtypes.canon(jnp.float32))
        self.partition_rows = io_partition_rows(
            max(widths), widest_dtype, n_live)

        # Per-shard row ranges for sharded execution (ISSUE 9): the I/O
        # partition loop splits over the configured mesh's data axis,
        # contiguous and partition-aligned.  Part of ``Plan.pass_key`` so a
        # mesh change (or a long_dim that packs into fewer partitions than
        # shards) re-plans instead of reusing a stale schedule — this is
        # what makes the cache's mesh keying real.
        self.shard_ranges = shard_ranges(
            self.long_dim, self.partition_rows, _conf_data_shards())

        # Segment IR + processor-level tile schedule (paper §III-F level 2).
        self.ir = plan_ir.compile_ir(self)

    def staged_sources(self, sources=None) -> list[tuple[int, FMMatrix]]:
        """One ``(canonical_node_id, matrix)`` pair per staging group — the
        unit the executor reads/stages per partition.  ``sources`` may
        override the matrices positionally (a borrowed cached plan executes
        with the new caller's data)."""
        if sources is None:
            sources = [m for _, m in self.sources]
        id_to_mat = {node.id: mat
                     for (node, _), mat in zip(self.sources, sources)}
        return [(group[0].id, id_to_mat[group[0].id])
                for group in self.source_groups]

    def broadcast_source_pairs(self, mats=None) -> list[tuple[int, FMMatrix]]:
        if mats is None:
            mats = [m for _, m in self.broadcast_sources]
        return [(node.id, mat)
                for (node, _), mat in zip(self.broadcast_sources, mats)]

    def epilogue_source_pairs(self, mats=None) -> list[tuple[int, FMMatrix]]:
        """``(node_id, matrix)`` per epilogue-only source.  ``mats`` may
        override the matrices positionally (borrowed cached plans execute
        with the new caller's data, exactly like staged_sources)."""
        if mats is None:
            mats = [m for _, m in self.epilogue_sources]
        return [(node.id, mat)
                for (node, _), mat in zip(self.epilogue_sources, mats)]

    # -- sink accumulators -----------------------------------------------------
    def init_accs(self):
        return {n.id: n.identity() for n in self.sinks}

    def finalize_accs(self, accs):
        return {n.id: n.finalize(accs[n.id]) for n in self.sinks}

    def bytes_in(self, sources=None) -> int:
        """Bytes streamed by THIS pass: one read per staging group — a
        matrix referenced through several leaves is staged once (see
        source_groups), so it counts once per pass."""
        return int(sum(mat.nbytes()
                       for _, mat in self.staged_sources(sources)))

    def describe(self) -> str:
        lines = [f"pass {self.idx}: partition_rows={self.partition_rows} "
                 f"bindings={[n.name for n in self.bindings]}"]
        for n in self.order:
            role = ("source" if isinstance(n, LeafNode)
                    or getattr(n, "cached_store", None) is not None
                    else "epilog" if n.id in self.epilogue_ids
                    else "sink" if n.is_sink else "fused")
            lines.append(f"  [{role:6s}] {n!r}")
        lines.extend("  " + line for line in self.ir.describe().splitlines())
        return "\n".join(lines)


class Plan:
    """A fused execution plan over one DAG cut: an ordered pass schedule."""

    def __init__(self, outputs: Sequence[FMMatrix], *, fuse: bool = True):
        self.requested = [as_node(o) for o in outputs]
        self.fuse = fuse

        self.order = self._cut_toposort(list(self.requested))
        self.long_dim = long_dim_of(self.order)

        # Multi-pass classification (paper §III-E generalized; see
        # dag.schedule_passes): every executable node gets a role
        # ('loop' | 'epi') and a pass number.  A merged value feeding a
        # row-local op pushes the consumer one pass later instead of
        # raising — the moment-pass → sweep-pass schedule.
        self.roles, self.passno = schedule_passes(
            self.order, is_source=self._is_source, long_dim=self.long_dim)
        self.n_passes = 1 + max(self.passno.values(), default=0)

        # Small (broadcast) operands are runtime ARGUMENTS of the compiled
        # steps, not baked constants — that is what lets a structurally
        # identical plan (k-means iteration N+1 with new centers) reuse the
        # compiled executable instead of retracing (see materialize._PLANS).
        # The registry is global to the plan; every pass indexes into it.
        self.smalls: list[Small] = []
        self._small_pos: dict[int, int] = {}
        for n in self.order:
            if self._is_source(n):
                continue  # cut points: parents live outside this plan
            for p in n.parents:
                if isinstance(p, Small) and id(p) not in self._small_pos:
                    self._small_pos[id(p)] = len(self.smalls)
                    self.smalls.append(p)

        self.passes: list[PassSchedule] = [
            PassSchedule(self, k) for k in range(self.n_passes)]

        # Unrequested epilogue values consumed by later passes must still
        # come out of the lowered epilogue so the executor can bind them.
        for k, ps in enumerate(self.passes):
            later: set[int] = set()
            for nxt in self.passes[k + 1:]:
                later |= nxt.binding_ids
            roots = {n.id for n in ps.epilogue_roots}
            ps.epilogue_carries = [n for n in ps.epilogue_nodes
                                   if n.id in later and n.id not in roots]

        # Aggregated views (single-pass plans look exactly like before).
        self.sinks = [n for ps in self.passes for n in ps.sinks]
        self.row_local_roots = [n for ps in self.passes
                                for n in ps.row_local_roots]
        self.saves = [n for ps in self.passes for n in ps.saves]
        self.epilogue_nodes = [n for ps in self.passes
                               for n in ps.epilogue_nodes]
        self.epilogue_ids = set().union(
            *[ps.epilogue_ids for ps in self.passes]) \
            if self.passes else set()
        self.epilogue_roots = [n for ps in self.passes
                               for n in ps.epilogue_roots]
        self.sources = [sm for ps in self.passes for sm in ps.sources]
        self.broadcast_sources = [sm for ps in self.passes
                                  for sm in ps.broadcast_sources]
        self.epilogue_sources = [sm for ps in self.passes
                                 for sm in ps.epilogue_sources]
        self.source_groups = [g for ps in self.passes
                              for g in ps.source_groups]
        self.source_aliases = {}
        for ps in self.passes:
            self.source_aliases.update(ps.source_aliases)

        self.partition_rows = self.passes[0].partition_rows
        self.ir = self.passes[0].ir
        self._programs: dict[str, "object"] = {}
        # Cached plans are borrowed by concurrent callers (materialize,
        # fm.batch, fm.serve workers); the lazy compile below must not
        # race itself or torn-publish a half-built MultiPassProgram.
        self._prog_lock = threading.Lock()

    def program(self, backend: str):
        """The lowered executable for ``backend``: a `LoweredProgram` for a
        one-pass plan, a `MultiPassProgram` otherwise (core/lowering.py).
        Thread-safe: first caller compiles, concurrent callers wait."""
        prog = self._programs.get(backend)
        if prog is None:
            with self._prog_lock:
                prog = self._programs.get(backend)
                if prog is None:
                    from . import lowering  # deferred: lowering pulls in kernels
                    compiled = [lowering.lower(ps, ps.ir, backend)
                                for ps in self.passes]
                    prog = (compiled[0] if len(compiled) == 1
                            else lowering.MultiPassProgram(compiled))
                    self._programs[backend] = prog
        return prog

    def staged_sources(self) -> list[tuple[int, FMMatrix]]:
        """One pair per distinct PHYSICAL matrix across every pass — the
        denominator of ``passes_over_sources`` (bytes_in counts each pass's
        read, so a two-pass plan over one matrix reports 2.0)."""
        seen: set[int] = set()
        out = []
        for ps in self.passes:
            for nid, mat in ps.staged_sources():
                if id(mat) not in seen:
                    seen.add(id(mat))
                    out.append((nid, mat))
        return out

    def pass_key(self) -> tuple:
        """Per-pass partition schedule: both partition levels of every pass
        plus its per-shard row ranges (ISSUE 9 — the mesh keying made
        real: a mesh change re-plans), the non-structural half of the
        plan-cache key."""
        return tuple((ps.partition_rows, tuple(ps.shard_ranges),
                      ps.ir.schedule_key())
                     for ps in self.passes)

    def signature(self) -> str:
        """Structural identity: two DAG cuts with the same signature can
        share one compiled plan (the compile-once/stream-many contract).
        Node roles carry their PASS NUMBER, and sources carry their
        per-pass staging-group / broadcast / epilogue tags, so two cuts
        with different pass structure can never collide."""
        parts = [f"L{self.long_dim}", f"P{self.n_passes}"]
        pos = {n.id: i for i, n in enumerate(self.order)}
        # Requested-ness AND request order are structural: the compiled
        # epilogue returns exactly the requested roots, and result slots
        # align positionally — a plan materializing an interior epilogue
        # node must not share a template with one that doesn't.
        req_pos: dict[int, int] = {}
        for i, n in enumerate(self.requested):
            req_pos.setdefault(n.id, i)
        src_tag: dict[int, list[str]] = {}
        for k, ps in enumerate(self.passes):
            for gi, group in enumerate(ps.source_groups):
                for node in group:
                    src_tag.setdefault(node.id, []).append(f"s{k}.{gi}")
            for node, _ in ps.broadcast_sources:
                src_tag.setdefault(node.id, []).append(f"b{k}")
            for node, _ in ps.epilogue_sources:
                src_tag.setdefault(node.id, []).append(f"E{k}")
        for n in self.order:
            ps_ = []
            # sources are cut points: their parents are outside this plan
            parents = [] if self._is_source(n) else n.parents
            for p in parents:
                if isinstance(p, Small):
                    v = p.value
                    shape = getattr(v, "shape", ())
                    dt = getattr(v, "dtype", type(v).__name__)
                    ps_.append(f"S{shape}:{dt}")
                else:
                    ps_.append(f"N{pos[p.id]}")
            fn_info = getattr(n, "fn_info", None)
            fname = ""
            if fn_info:
                for key in ("vudf", "mul", "add"):
                    if key in fn_info:
                        fname += f":{fn_info[key].name}"
                if "num_groups" in fn_info:
                    fname += f":g{fn_info['num_groups']}"
            extra = ""
            for attr in ("agg", "mul", "add"):
                v = getattr(n, attr, None)
                if v is not None:
                    extra += f":{v.name}"
            ng = getattr(n, "num_groups", "")
            # Role + pass number are part of the cache key: the SAME
            # structural node must not collide between a loop evaluation
            # and an epilogue one, nor between passes.
            if self._is_source(n):
                role = "q" + "+".join(src_tag.get(n.id, []))
                mat = n.mat if isinstance(n, LeafNode) \
                    else getattr(n, "cached_store", None)
                store = getattr(mat, "store", None)
                if getattr(store, "sparse", False):
                    # Sparse sources stage a (cols, vals) ELL pytree whose
                    # structure depends on kmax: a dense cut with the same
                    # shapes must not share the compiled step.
                    role += f"~csr:{store.max_row_nnz}"
            elif self.roles[n.id] == "epi":
                role = f"e{self.passno[n.id]}"
            elif n.is_sink:
                role = f"s{self.passno[n.id]}"
            else:
                role = f"m{self.passno[n.id]}"
            sv = n.save or ""
            rq = f"r{req_pos[n.id]}" if n.id in req_pos else ""
            parts.append(f"{role}|{n.kind}|{n.shape}|{n.dtype.name}|{fname}"
                         f"|{extra}|{ng}|{sv}|{rq}|{','.join(ps_)}")
        return ";".join(parts)

    def result_nodes(self):
        """Deterministic result slots (sinks + requested + saves +
        epilogue outputs, in pass order)."""
        return (list(self.sinks) + self.row_local_roots + self.saves
                + self.epilogue_roots)

    def small_values(self):
        return [jnp.asarray(s.value) if hasattr(s.value, "shape")
                else s.value for s in self.smalls]

    # -- DAG walking -----------------------------------------------------------
    @staticmethod
    def _is_source(n: Node) -> bool:
        return isinstance(n, LeafNode) or getattr(n, "cached_store", None) is not None

    @classmethod
    def _cut_toposort(cls, roots):
        """toposort that cuts at nodes previously persisted via save flags."""
        seen, order = {}, []

        def visit(n: Node):
            if n.id in seen:
                return
            seen[n.id] = n
            if not cls._is_source(n) or isinstance(n, LeafNode):
                if getattr(n, "cached_store", None) is None:
                    for p in n.parent_nodes():
                        visit(p)
            order.append(n)

        for r in roots:
            visit(r)
        return order

    # -- cost counters (feed complexity + roofline reports) -----------------------
    def flop_count(self) -> float:
        # Epilogue nodes run ONCE after each pass's merge, not once per row —
        # their O(p²)-ish cost is noise next to the streamed loop, so they
        # are excluded rather than multiplied by the long dimension.  A
        # row-local chain re-evaluated by a later pass counts once per pass
        # it actually runs in.
        total = 0.0
        for ps in self.passes:
            for n in ps.order:
                if (not self._is_source(n)
                        and n.id not in ps.epilogue_ids):
                    total += n.flops_per_row() * self.long_dim
        return float(total)

    def bytes_in(self) -> int:
        """Bytes actually read across ALL passes: one read per staging
        group per pass — a two-pass plan over one matrix counts it twice
        (that is the honest I/O the schedule performs)."""
        return int(sum(ps.bytes_in() for ps in self.passes))

    def bytes_out(self) -> int:
        total = 0
        for n in (self.row_local_roots + self.saves + list(self.sinks)
                  + self.epilogue_roots):
            total += n.nrow * n.ncol * dtypes.nbytes(n.dtype)
        return int(total)

    def explain(self, backend: str | None = None) -> str:
        """Render the planner's decisions for humans (``fm.explain``): the
        pass schedule, each source's storage tier and streamed bytes, both
        partition levels, and the per-segment backend dispatch — see
        observability/explain.py.  Unlike ``describe()`` (a raw node dump),
        this is the user-facing inspection surface."""
        from ..observability.explain import explain_plan
        return explain_plan(self, backend=backend)

    def describe(self) -> str:
        lines = [f"Plan(long_dim={self.long_dim}, passes={self.n_passes},"
                 f" fuse={self.fuse})"]
        for ps in self.passes:
            lines.extend("  " + line for line in ps.describe().splitlines())
        lines.append(f"  flops={self.flop_count():.3e} bytes_in={self.bytes_in():.3e}"
                     f" bytes_out={self.bytes_out():.3e}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Cross-plan co-scheduling (the batch layer, core/batch.py)
# ---------------------------------------------------------------------------

def stream_group_key(ps: PassSchedule, sources=None) -> tuple:
    """The co-schedule signature of one pass: its long dimension plus the
    IDENTITY set of the physical matrices it streams.  Two passes with
    compatible keys (equal, or one source set a subset of the other, same
    long dimension) can share a single streaming drive — the staged
    partition serves every member's step before eviction.  Partition row
    counts need not match: they are powers of two under the same I/O
    budget, so the group runs at the smallest member's rows and every
    member's schedule divides it evenly."""
    return (ps.long_dim,
            frozenset(id(m) for _, m in ps.staged_sources(sources)))


def coschedule(keys) -> list[list[int]]:
    """Group member passes (given their `stream_group_key`s) onto shared
    streaming drives.  Returns groups of member indices, input order
    preserved inside each group.

    Equal keys co-schedule directly; a member whose source set is a strict
    SUBSET of an existing group's rides that group's stream for free (its
    matrices are staged there anyway).  A pass that streams nothing (pure
    broadcast/epilogue work) gets its own group — there is no drive to
    share.  Supersets are seeded first so subsets always find their
    carrier."""
    keys = list(keys)
    order = sorted(range(len(keys)), key=lambda i: -len(keys[i][1]))
    groups: list[list[int]] = []
    group_keys: list[tuple] = []
    for i in order:
        long_dim, mats = keys[i]
        placed = False
        if mats:
            for gi, (g_long, g_mats) in enumerate(group_keys):
                if g_long == long_dim and mats <= g_mats:
                    groups[gi].append(i)
                    placed = True
                    break
        if not placed:
            groups.append([i])
            group_keys.append((long_dim, mats))
    for g in groups:
        g.sort()
    return groups
