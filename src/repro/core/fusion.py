"""Fusion optimizer: turn a DAG cut into a single partition-streaming program.

Paper §III-E/F: FlashMatrix "evaluates expressions lazily and fuses
operations aggressively in a single parallel execution job", materializing
multiple sinks together and streaming one partition through the *entire*
fused chain before touching the next partition ("After materializing a
CPU-level partition, the thread passes the partition to the subsequent
operation in the DAG, instead of materializing the next CPU-level partition
in the same matrix").

`Plan` owns the *analysis* half of the engine: it cuts the DAG at persisted
nodes, toposorts the induced subgraph, classifies sources/sinks/outputs and
schedules the I/O-level partition size.  The executable halves live one
layer down: `plan_ir.compile_ir` groups the cut into typed fused segments
with per-segment processor-level tiles (the paper's second partition
level), and a `lowering` backend turns those segments into the
``step``/``combine`` program the materializer streams partitions through.
Because ``step`` is a single traced function, every intermediate virtual
matrix lives only as a value inside one computation: the analog of never
writing intermediates to SSD/DRAM.

The plan cuts the DAG at nodes that were previously persisted
(`fm.set.mate.level` → ``node.cached_store``), mirroring the paper's
materialization of non-sink matrices reused across iterations.

The plan also exposes the cost counters (FLOPs, bytes in/out) that feed
benchmarks/complexity.py and the roofline analysis.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from . import dtypes, plan_ir
from .dag import (LeafNode, Node, SinkNode, Small, as_node, long_dim_of,
                  post_sink_ids)
from .matrix import FMMatrix, io_partition_rows


class Plan:
    """A fused execution plan over one DAG cut."""

    def __init__(self, outputs: Sequence[FMMatrix], *, fuse: bool = True):
        self.requested = [as_node(o) for o in outputs]
        self.fuse = fuse

        self.order = self._cut_toposort(list(self.requested))

        # EPILOGUE classification (paper §III-E's post-aggregation math):
        # a node downstream of a sink inside this cut — colSums(X)/n,
        # sqrt(ss/n − mean²), solve(XᵀWX, XᵀWz) — cannot run in the
        # partition loop because its operands only exist after the partial
        # merge.  Those nodes form the plan's epilogue: the lowered program
        # evaluates them exactly once, on device, after the combine
        # (LoweredProgram.epilogue).  A sink whose operands are themselves
        # post-sink (e.g. sum(colMeans(X))) is evaluated there too.
        self.epilogue_ids: set[int] = post_sink_ids(
            self.order, is_source=self._is_source)
        self.epilogue_nodes: list[Node] = [
            n for n in self.order if n.id in self.epilogue_ids]

        # NOTE: a previously-persisted sink reused as a cut SOURCE must not
        # re-register as a sink here — the executor would re-initialize it
        # to its identity and clobber the persisted value with zeros (only
        # reachable since sink-consumers became plannable).
        self.sinks: list[SinkNode] = [
            n for n in self.order
            if n.is_sink and not self._is_source(n)
            and n.id not in self.epilogue_ids]
        self.row_local_roots: list[Node] = [
            n for n in self.requested
            if not n.is_sink and not self._is_source(n)
            and n.id not in self.epilogue_ids]
        # Nodes flagged fm.set.mate.level persist during this execution
        # (paper's write-through materialization of non-sink matrices).
        self.saves: list[Node] = [
            n for n in self.order
            if n.save is not None and not n.is_sink and not self._is_source(n)
            and n not in self.row_local_roots
            and n.id not in self.epilogue_ids]
        # Epilogue result slots: requested or save-flagged epilogue nodes.
        seen_roots: set[int] = set()
        self.epilogue_roots: list[Node] = []
        for n in list(self.requested) + [m for m in self.order
                                         if m.save is not None]:
            if n.id in self.epilogue_ids and n.id not in seen_roots:
                seen_roots.add(n.id)
                self.epilogue_roots.append(n)

        # Sources = physical leaves + previously-persisted cut points.  A
        # source consumed ONLY by epilogue nodes (e.g. the ridge eye matrix
        # of a regularized solve) is not streamed: it is handed whole to the
        # epilogue callable.
        consumers: dict[int, list[Node]] = {}
        for n in self.order:
            if self._is_source(n):
                continue
            for p in n.parents:
                if isinstance(p, Node):
                    consumers.setdefault(p.id, []).append(n)
        self.sources: list[tuple[Node, FMMatrix]] = []
        self.epilogue_sources: list[tuple[Node, FMMatrix]] = []
        for n in self.order:
            if isinstance(n, LeafNode):
                mat = n.mat
            elif getattr(n, "cached_store", None) is not None:
                mat = n.cached_store
            else:
                continue
            cons = consumers.get(n.id, [])
            if cons and all(c.id in self.epilogue_ids for c in cons):
                self.epilogue_sources.append((n, mat))
            elif any(c.id in self.epilogue_ids for c in cons):
                raise ValueError(
                    f"source {n.name} is consumed by both the partition "
                    f"loop and the plan epilogue; materialize the epilogue "
                    f"expression separately")
            else:
                self.sources.append((n, mat))
        self._epi_src_ids = {n.id for n, _ in self.epilogue_sources}

        # Epilogue operands must exist after the merge: loop sinks, other
        # epilogue values, small epilogue-only sources, or broadcast Smalls.
        # A streaming intermediate (row-local chain) would need a second
        # pass over the data — reject it with a actionable message.
        for n in self.epilogue_nodes:
            for p in n.parents:
                if isinstance(p, Small) or self._is_source(p):
                    continue
                if p.is_sink or p.id in self.epilogue_ids:
                    continue
                raise ValueError(
                    f"epilogue op {n.name} consumes the streaming "
                    f"intermediate {p.name}: post-sink lazy math may only "
                    f"touch aggregation results, small operands or other "
                    f"epilogue values inside one DAG — materialize "
                    f"{p.name} first (it needs its own pass)")

        # Staging groups: every GenOp call wraps its own LeafNode, so a DAG
        # referencing one physical matrix through k leaves (crossprod(X) +
        # colSums(X), the IRLS weighted-gram pair, ...) would read each
        # partition k times in stream/ooc modes.  Group source nodes by the
        # identity of their physical matrix; the executor stages one block
        # per group and the lowered program fans it out to every alias.
        self.source_groups: list[list[Node]] = []
        self.source_aliases: dict[int, int] = {}
        by_mat: dict[int, int] = {}
        for node, mat in self.sources:
            gi = by_mat.get(id(mat))
            if gi is None:
                by_mat[id(mat)] = len(self.source_groups)
                self.source_groups.append([node])
            else:
                self.source_groups[gi].append(node)
        for group in self.source_groups:
            for node in group:
                self.source_aliases[node.id] = group[0].id

        self.long_dim = long_dim_of(self.order)
        for node, mat in self.sources:
            if mat.shape[0] != self.long_dim and max(mat.shape) != 1:
                raise ValueError(
                    f"source {node.name} shape {mat.shape} rows are not "
                    f"aligned with the streaming dimension {self.long_dim}")

        # I/O-level partition size: budget divided by the number of live
        # long-aligned matrices in the fused group (paper §III-F chooses "a
        # relatively small partition size to balance the overhead of
        # accessing a partition, skew and memory consumption").
        n_live = max(1, len(self.sources) + len(self.row_local_roots) + len(self.saves))
        widths = [1]
        for node, mat in self.sources:
            widths.append(mat.ncol)
        for n in self.order:
            if (not self._is_source(n) and not n.is_sink
                    and n.id not in self.epilogue_ids):
                widths.append(n.ncol)
        widest_dtype = max((n.dtype for n in self.order), key=dtypes.rank)
        self.partition_rows = io_partition_rows(max(widths), widest_dtype, n_live)

        # Small (broadcast) operands are runtime ARGUMENTS of the compiled
        # step, not baked constants — that is what lets a structurally
        # identical plan (k-means iteration N+1 with new centers) reuse the
        # compiled executable instead of retracing (see materialize._PLANS).
        self.smalls: list[Small] = []
        self._small_pos: dict[int, int] = {}
        for n in self.order:
            if self._is_source(n):
                continue  # cut points: parents live outside this plan
            for p in n.parents:
                if isinstance(p, Small) and id(p) not in self._small_pos:
                    self._small_pos[id(p)] = len(self.smalls)
                    self.smalls.append(p)

        # Segment IR + processor-level tile schedule (paper §III-F level 2);
        # lowered programs are built lazily per backend and cached here.
        self.ir = plan_ir.compile_ir(self)
        self._programs: dict[str, "object"] = {}

    def program(self, backend: str):
        """The lowered executable for ``backend`` (see core/lowering.py)."""
        prog = self._programs.get(backend)
        if prog is None:
            from . import lowering  # deferred: lowering pulls in kernels
            prog = lowering.lower(self, self.ir, backend)
            self._programs[backend] = prog
        return prog

    def staged_sources(self, sources=None) -> list[tuple[int, FMMatrix]]:
        """One ``(canonical_node_id, matrix)`` pair per staging group — the
        unit the executor reads/stages per partition.  ``sources`` may
        override the matrices positionally (a borrowed cached plan executes
        with the new caller's data)."""
        if sources is None:
            sources = [m for _, m in self.sources]
        id_to_mat = {node.id: mat
                     for (node, _), mat in zip(self.sources, sources)}
        return [(group[0].id, id_to_mat[group[0].id])
                for group in self.source_groups]

    def signature(self) -> str:
        """Structural identity: two DAG cuts with the same signature can
        share one compiled plan (the compile-once/stream-many contract)."""
        parts = [f"L{self.long_dim}"]
        pos = {n.id: i for i, n in enumerate(self.order)}
        group_of = {n.id: gi for gi, group in enumerate(self.source_groups)
                    for n in group}
        for n in self.order:
            ps = []
            # sources are cut points: their parents are outside this plan
            parents = [] if self._is_source(n) else n.parents
            for p in parents:
                if isinstance(p, Small):
                    v = p.value
                    shape = getattr(v, "shape", ())
                    dt = getattr(v, "dtype", type(v).__name__)
                    ps.append(f"S{shape}:{dt}")
                else:
                    ps.append(f"N{pos[p.id]}")
            fn_info = getattr(n, "fn_info", None)
            fname = ""
            if fn_info:
                for key in ("vudf", "mul", "add"):
                    if key in fn_info:
                        fname += f":{fn_info[key].name}"
                if "num_groups" in fn_info:
                    fname += f":g{fn_info['num_groups']}"
            extra = ""
            for attr in ("agg", "mul", "add"):
                v = getattr(n, attr, None)
                if v is not None:
                    extra += f":{v.name}"
            ng = getattr(n, "num_groups", "")
            # Role is part of the cache key: the SAME structural node must
            # not collide between a loop evaluation and an epilogue one
            # (e.g. a requested sink vs that sink feeding post-sink math).
            if self._is_source(n):
                role = "E" if n.id in self._epi_src_ids else "q"
            elif n.id in self.epilogue_ids:
                role = "e"
            elif n.is_sink:
                role = "s"
            else:
                role = "m"
            sv = n.save or ""
            # Staging-group index: two cuts that alias their sources
            # differently (one matrix read through two leaves vs two distinct
            # matrices) must not share a compiled executable.
            grp = f"g{group_of[n.id]}" if n.id in group_of else ""
            parts.append(f"{role}|{n.kind}|{n.shape}|{n.dtype.name}|{fname}"
                         f"|{extra}|{ng}|{sv}|{grp}|{','.join(ps)}")
        return ";".join(parts)

    def result_nodes(self):
        """Deterministic result slots (sinks + requested + saves +
        epilogue outputs)."""
        return (list(self.sinks) + self.row_local_roots + self.saves
                + self.epilogue_roots)

    def epilogue_source_pairs(self, mats=None) -> list[tuple[int, FMMatrix]]:
        """``(node_id, matrix)`` per epilogue-only source.  ``mats`` may
        override the matrices positionally (borrowed cached plans execute
        with the new caller's data, exactly like staged_sources)."""
        if mats is None:
            mats = [m for _, m in self.epilogue_sources]
        return [(node.id, mat)
                for (node, _), mat in zip(self.epilogue_sources, mats)]

    def small_values(self):
        return [jnp.asarray(s.value) if hasattr(s.value, "shape")
                else s.value for s in self.smalls]

    # -- DAG walking -----------------------------------------------------------
    @staticmethod
    def _is_source(n: Node) -> bool:
        return isinstance(n, LeafNode) or getattr(n, "cached_store", None) is not None

    @classmethod
    def _cut_toposort(cls, roots):
        """toposort that cuts at nodes previously persisted via save flags."""
        seen, order = {}, []

        def visit(n: Node):
            if n.id in seen:
                return
            seen[n.id] = n
            if not cls._is_source(n) or isinstance(n, LeafNode):
                if getattr(n, "cached_store", None) is None:
                    for p in n.parent_nodes():
                        visit(p)
            order.append(n)

        for r in roots:
            visit(r)
        return order

    # -- sink accumulators -----------------------------------------------------
    def init_accs(self):
        return {n.id: n.identity() for n in self.sinks}

    def finalize_accs(self, accs):
        return {n.id: n.finalize(accs[n.id]) for n in self.sinks}

    # -- cost counters (feed complexity + roofline reports) -----------------------
    def flop_count(self) -> float:
        # Epilogue nodes run ONCE after the merge, not once per row — their
        # O(p²)-ish cost is noise next to the streamed loop, so they are
        # excluded rather than multiplied by the long dimension.
        return float(sum(n.flops_per_row() * self.long_dim
                         for n in self.order
                         if not self._is_source(n)
                         and n.id not in self.epilogue_ids))

    def bytes_in(self) -> int:
        """Bytes actually read per pass: one read per STAGING GROUP — a
        matrix referenced through several leaves is staged once (see
        source_groups), so it counts once."""
        return int(sum(mat.nbytes() for _, mat in self.staged_sources()))

    def bytes_out(self) -> int:
        total = 0
        for n in (self.row_local_roots + self.saves + list(self.sinks)
                  + self.epilogue_roots):
            total += n.nrow * n.ncol * dtypes.nbytes(n.dtype)
        return int(total)

    def describe(self) -> str:
        lines = [f"Plan(long_dim={self.long_dim}, partition_rows={self.partition_rows},"
                 f" fuse={self.fuse})"]
        for n in self.order:
            role = ("source" if self._is_source(n)
                    else "epilog" if n.id in self.epilogue_ids
                    else "sink" if n.is_sink else "fused")
            lines.append(f"  [{role:6s}] {n!r}")
        lines.extend("  " + line for line in self.ir.describe().splitlines())
        lines.append(f"  flops={self.flop_count():.3e} bytes_in={self.bytes_in():.3e}"
                     f" bytes_out={self.bytes_out():.3e}")
        return "\n".join(lines)
