"""Cross-materialize stream fusion: the batch execution layer (ISSUE 7).

FlashR's core economy is minimizing SSD traffic per unit of compute; a
solo ``fm.materialize`` already fuses one plan into minimal passes, but
INDEPENDENT plans over the same named matrix each pay their own full scan.
``fm.batch`` promotes the pass scheduler from per-plan to per-trace:

  1. every request's lazy outputs build their own `fusion.Plan` (own
     plan-cache entry, own sinks/epilogue — nothing about a plan changes);
  2. round r collects pass r of every unfinished plan and co-schedules the
     passes by `fusion.stream_group_key` — shared physical sources, same
     long dimension (a pass whose source set is a subset of another's
     rides that group's stream for free);
  3. each group runs as ONE streaming drive (`materialize._run_stream_group`
     over a `lowering.GroupProgram` composition): while a staged partition
     is resident, every member plan's ``step`` consumes it and folds its
     partials through its own ``combine`` before eviction — k plans ×
     1 stream becomes 1 stream × k steps (``exec_stats()['streams']``).

Results register only after EVERY round of EVERY member succeeds: an
interrupted group (a staging fault mid-stream) leaves no partially
registered sinks behind for ANY member.  Per-request metrics scopes are
captured when the request joins the batch, so ``fm.collect_stats()``
around one request reports that plan's own pass/byte share rather than
the whole group's.

Consecutive rounds with identical partition schedules reuse the
prefetcher's resident final partition (``prefetch_reuse_hits``), and
inside ``materialize.iteration_scope`` the residency carries across
batches/materializes — the iteration-inspector path the iterative
drivers (kmeans / glm IRLS / nmf / gmm) use.
"""
from __future__ import annotations

import time
from typing import Optional

from . import lowering
from . import materialize as mz
from .fusion import Plan, coschedule, stream_group_key
from .matrix import FMMatrix
from ..observability import metrics
from ..observability.trace import TRACER


class BatchRequest:
    """One member of a batch: the lazy outputs of what would otherwise be
    its own ``fm.materialize(*outputs)`` call, plus the metrics scopes
    open when it was added (per-request attribution)."""

    def __init__(self, outputs, *, structured: bool):
        self.outputs = list(outputs)
        self.structured = structured  # result mirrors a tuple/list request
        self.scopes = metrics.current_scopes()
        # Execution state (filled by execute_batch).
        self.plan: Optional[Plan] = None
        self.exec_plan: Optional[Plan] = None
        self.pass_progs = None
        self.carried: dict[int, object] = {}
        self.finals: dict[int, object] = {}
        self.parts: dict[int, list] = {}
        self.epi: dict[int, object] = {}
        self.disk: dict[int, object] = {}
        self.pass_bytes: list[int] = []
        self.to_host = False

    @property
    def n_passes(self) -> int:
        return len(self.plan.passes) if self.plan is not None else 0

    def results(self) -> list[FMMatrix]:
        return [mz._result_of(m) for m in self.outputs]


def _request_stack(req: BatchRequest):
    """Executor-thread scopes + the request's captured scopes, deduped —
    the stack request-level counters (materialize_calls, cache hits,
    pass_bytes_in) record under."""
    cur = metrics.current_scopes()
    extra = [s for s in req.scopes if s not in set(cur)]
    return tuple(cur) + tuple(extra)


def _plan_request(req: BatchRequest, backend: str, mesh,
                  reuse_plans: bool) -> bool:
    """Build ``req``'s own Plan + acquire its (possibly cached) template.
    Returns False for a pure pass-through request (no virtual outputs).
    Thread-safe: plan construction classifies live DAG node state, so it
    runs under materialize's _DAG_LOCK (fm.serve plans on many caller
    threads concurrently)."""
    virtuals = [m for m in req.outputs if m.is_virtual]
    if not virtuals:
        return False
    with metrics.use_scopes(_request_stack(req)):
        metrics.inc("materialize_calls")
        with mz._DAG_LOCK:
            req.plan = Plan(virtuals)
            req.exec_plan = mz._acquire_exec_plan(
                req.plan, backend, mesh, reuse_plans)
    prog = req.exec_plan.program(backend)
    req.pass_progs = getattr(prog, "passes", None) or [prog]
    return True


def pass_group_key(req: BatchRequest, r: int) -> tuple:
    """The co-schedule key of request ``req``'s pass ``r`` — its
    `fusion.stream_group_key` over the request's OWN source matrices."""
    own_ps = req.plan.passes[r]
    src_off = sum(len(p.sources) for p in req.plan.passes[:r])
    srcs = [m for _, m in req.plan.sources][
        src_off:src_off + len(own_ps.sources)]
    return stream_group_key(own_ps, srcs)


def _member_for(req: BatchRequest, r: int):
    """Build the `_PassExec` for request ``req``'s pass ``r``: template
    PassSchedule/program (the possibly-borrowed cached plan) driven with
    the request's OWN matrices, save specs and carried bindings."""
    own, tmpl = req.plan, req.exec_plan
    own_ps, exec_ps = own.passes[r], tmpl.passes[r]
    src_off = sum(len(p.sources) for p in own.passes[:r])
    bc_off = sum(len(p.broadcast_sources) for p in own.passes[:r])
    epi_off = sum(len(p.epilogue_sources) for p in own.passes[:r])
    sources = [m for _, m in own.sources][
        src_off:src_off + len(own_ps.sources)]
    bc = [m for _, m in own.broadcast_sources][
        bc_off:bc_off + len(own_ps.broadcast_sources)]
    epi = [m for _, m in own.epilogue_sources][
        epi_off:epi_off + len(own_ps.epilogue_sources)]
    bindings = {nid: req.carried[nid] for nid in exec_ps.binding_ids}
    for nid, mat in exec_ps.broadcast_source_pairs(bc):
        bindings[nid] = mz._stage_whole(mat)
    out_nodes = list(zip(exec_ps.row_local_roots + exec_ps.saves,
                         own_ps.row_local_roots + own_ps.saves))
    return mz._PassExec(exec_ps, req.pass_progs[r], sources,
                        own.small_values(), epi, bindings,
                        out_nodes=out_nodes, scopes=req.scopes)


def plan_rounds(requests, *, backend: Optional[str] = None,
                reuse_plans: bool = True, mesh=None):
    """Prepare every request's plan and the per-round co-schedule.

    Returns ``(active_requests, rounds)`` where each round is a list of
    groups and each group a list of (request, pass index) pairs — the
    deterministic schedule both `execute_batch` and ``fm.explain_batch``
    read.  Requests whose outputs are all physical come back with
    ``plan is None`` (pure pass-through)."""
    backend = lowering.resolve_backend(backend)
    active = [req for req in requests
              if _plan_request(req, backend, mesh, reuse_plans)]

    rounds = []
    n_rounds = max((req.n_passes for req in active), default=0)
    for r in range(n_rounds):
        live = [req for req in active if r < req.n_passes]
        keys = [pass_group_key(req, r) for req in live]
        rounds.append([[(live[i], r) for i in group]
                       for group in coschedule(keys)])
    return active, rounds


def execute_batch(requests, *, mode: str = "auto",
                  backend: Optional[str] = None, donate: bool = True,
                  prefetch: Optional[bool] = None, reuse_plans: bool = True,
                  mesh=None):
    """Execute every request, one streaming drive per co-scheduled group.

    Returns the requests' result lists (physical FMMatrix per output).
    ``mode`` follows ``fm.materialize`` ('auto' picks per group from the
    union of that group's sources).  ``mesh`` (default: the configured
    ``fm.set_conf(mesh=...)``) shards every group's partition sweep over
    the mesh's data axis exactly like a solo materialize — grouped streams
    shard too, each member's partials merging through its own ``combine``
    across the shard boundaries.  A failure mid-batch clears the thread's
    resident-partition capture (ISSUE 9): stale residents from a previous
    round must not stay pinned for the rest of the iteration scope."""
    try:
        return _execute_batch(requests, mode=mode, backend=backend,
                              donate=donate, prefetch=prefetch,
                              reuse_plans=reuse_plans, mesh=mesh)
    except BaseException:
        mz._set_tls_residents(None)
        raise


def _execute_batch(requests, *, mode, backend, donate, prefetch,
                   reuse_plans, mesh):
    backend = lowering.resolve_backend(backend)
    mesh = mz._default_mesh(mesh)
    active, rounds = plan_rounds(requests, backend=backend,
                                 reuse_plans=reuse_plans, mesh=mesh)
    residents = mz._tls_residents()
    stream_bytes: list[int] = []
    with TRACER.span("batch", requests=len(active), rounds=len(rounds)):
        for r, groups in enumerate(rounds):
            next_residents = []
            for group in groups:
                members = [_member_for(req, rr) for req, rr in group]
                union = []
                seen = set()
                for m in members:
                    for _, mat in m.ps.staged_sources(m.sources):
                        if id(mat) not in seen:
                            seen.add(id(mat))
                            union.append(mat)
                stream_bytes.append(sum(mat.nbytes() for mat in union))
                group_mode = mz._pick_mode_src(union, mode)
                if group_mode not in ("whole", "stream", "ooc"):
                    raise ValueError(f"unknown mode {group_mode!r}")
                # The composition object: the group's schedule is what is
                # "compiled" here — members keep their own executables.
                gprog = lowering.GroupProgram(
                    [(m.ps, m.prog) for m in members])
                t_pass = time.perf_counter()
                if group_mode == "whole":
                    mz._run_whole_group(members, mesh=mesh)
                else:
                    capture = mz.inspecting() or r + 1 < len(rounds)
                    entry = mz._run_stream_group(
                        members, to_host=(group_mode == "ooc"),
                        donate=donate, prefetch=prefetch,
                        residents=residents, capture=capture, mesh=mesh)
                    if entry is not None:
                        next_residents.append(entry)
                metrics.inc("pass_seconds", time.perf_counter() - t_pass)
                del gprog
                for m, (req, _) in zip(members, group):
                    if group_mode == "ooc":
                        req.to_host = True
                    req.pass_bytes.append(m.ps.bytes_in(m.sources))
                    req.finals.update(m.finals)
                    req.parts.update(m.out_parts)
                    req.epi.update(m.epi_outs)
                    req.disk.update(m.disk_stores)
                    req.carried.update(m.finals)
                    req.carried.update(m.epi_outs)
            residents = next_residents or None
    mz._set_tls_residents(residents)

    # Root + the executor's ambient scopes see the PHYSICAL traffic: one
    # entry per stream group with that group's union bytes.  Each request's
    # own scopes see their plan's per-pass bytes, matching what a solo
    # materialize of that request would have reported.
    metrics.put("pass_bytes_in", tuple(stream_bytes))
    ambient = set(metrics.REGISTRY.scopes())
    for req in active:
        for sc in req.scopes:
            if sc not in ambient:
                sc.put("pass_bytes_in", tuple(req.pass_bytes))

    # Every round of every member succeeded: register results.  Values are
    # keyed by the TEMPLATE plan's node ids but land on each request's own
    # nodes (onto=), so borrowed cache templates are never mutated — two
    # requests borrowing the same template cannot clobber each other.
    for req in active:
        mz._store_results(req.exec_plan, req.finals, req.parts,
                          to_host=req.to_host, disk_stores=req.disk,
                          epilogue_outs=req.epi, onto=req.plan)
    return [req.results() for req in requests]


class Batch:
    """Collector form of ``fm.batch``: queue requests, run them together.

        with fm.batch() as b:
            h1 = b.add(fm.colMeans(X))
            h2 = b.add(fm.colSds(X), fm.crossprod(X))
        h1.value, h2.value

    ``add`` captures the thread's open ``fm.collect_stats()`` scopes with
    the request; ``run`` (or context exit) executes every queued request
    in co-scheduled groups."""

    def __init__(self, *, mode: str = "auto", backend: Optional[str] = None,
                 donate: bool = True, prefetch: Optional[bool] = None,
                 reuse_plans: bool = True, mesh=None):
        self._kw = dict(mode=mode, backend=backend, donate=donate,
                        prefetch=prefetch, reuse_plans=reuse_plans,
                        mesh=mesh)
        self.requests: list[BatchRequest] = []
        self._ran = False

    def add(self, *outputs) -> "BatchHandle":
        if self._ran:
            raise RuntimeError("batch already executed")
        structured = len(outputs) != 1
        req = BatchRequest(outputs, structured=structured)
        self.requests.append(req)
        return BatchHandle(req)

    def run(self) -> list:
        if self._ran:
            raise RuntimeError("batch already executed")
        self._ran = True
        results = execute_batch(self.requests, **self._kw)
        return [res if req.structured else res[0]
                for req, res in zip(self.requests, results)]

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None and not self._ran:
            self.run()
        return False


class BatchHandle:
    """A queued request's result slot (``Batch.add``)."""

    def __init__(self, req: BatchRequest):
        self._req = req

    @property
    def value(self):
        res = self._req.results()
        return res if self._req.structured else res[0]
