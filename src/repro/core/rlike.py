"""R-base-like matrix API reimplemented on GenOps (paper Table III).

The paper's whole point: users write ordinary R matrix code and the engine
runs it parallel + out-of-core.  `FM` wraps an FMMatrix handle with R's
operator vocabulary; every method lowers to a GenOp, so an arbitrary chain
of these calls builds one lazy DAG that `fm.materialize` fuses.

    >>> X = fm.runif_matrix(1_000_000, 16)
    >>> Z = (X - colMeans(X)) / colSds(X)      # standardize (lazy GenOps)
    >>> G = crossprod(Z)                       # Gram sink
    >>> (G,) = fm.materialize(G)               # ONE call, two scheduled passes

(colMeans/colSds are pure lazy chains — a colSums sink plus post-sink
epilogue math evaluated once after the partition-loop merge; recycling
them across X is a lazy sweep too, so the whole standardize-then-Gram
program is ONE DAG that the multi-pass planner runs as moment pass →
sweep+Gram pass inside a single materialize.)

All functions accept and return `FM`.  `conv_FM2R` drops to numpy.
"""
from __future__ import annotations

import math
import warnings
from typing import Optional

import jax.numpy as jnp
import numpy as np

from . import genops, materialize as mat_mod, matrix as matrix_mod
from .dag import as_node
from .matrix import FMMatrix


class FM:
    """R-flavoured wrapper around an FMMatrix handle (virtual or physical)."""

    __slots__ = ("m",)

    def __init__(self, m: FMMatrix):
        self.m = m

    # -- shape ---------------------------------------------------------------
    @property
    def shape(self):
        return self.m.shape

    @property
    def nrow(self):
        return self.m.nrow

    @property
    def ncol(self):
        return self.m.ncol

    @property
    def dtype(self):
        return self.m.dtype

    @property
    def is_virtual(self):
        return self.m.is_virtual

    def __repr__(self):
        return f"FM({self.m!r})"

    # -- element-wise binary (auto row/col recycling like R sweep) -----------
    def _bin(self, other, op):
        if isinstance(other, FM):
            if other.shape == self.shape:
                return FM(genops.mapply(self.m, other.m, op))
            return self._recycle(other, op)
        return FM(genops.mapply(self.m, other, op))

    def _rbin(self, other, op):
        # scalar/array `other` on the left.
        return FM(genops.mapply(other, self.m, op))

    def _recycle(self, other: "FM", op):
        """R-style recycling of a vector across a matrix: a length-ncol
        vector applies per row (mapply.row); length-nrow per column
        (mapply.col).

        A VIRTUAL length-ncol vector (``X - colMeans(X)``) stays lazy: the
        sweep becomes a DAG edge and the multi-pass planner schedules
        moment pass → sweep pass automatically — one materialize, two
        streaming passes.  Physical vectors broadcast eagerly as before.

        Ambiguity rule: when the matrix is square (nrow == ncol), a
        length-n vector pairs with the ROW INDEX (mapply.col) — R stores
        matrices column-major, so recycling walks down each column.
        """
        n = max(other.shape)
        if min(other.shape) != 1:
            raise ValueError(
                f"recycling needs a vector (an n×1 or 1×n matrix); got "
                f"shape {other.shape} against {self.shape} — for "
                f"elementwise matrix∘matrix the shapes must match exactly")
        if n == self.ncol and n != self.nrow:
            vec = other.m if other.m.is_virtual else _vec_data(other.m)
            return FM(genops.mapply_row(self.m, vec, op))
        if n == self.nrow:
            # Includes the square-matrix case: R's column-major recycling
            # pairs vector element i with row i.
            return FM(genops.mapply_col(self.m, other.m, op))
        raise ValueError(
            f"cannot recycle a length-{n} vector across a "
            f"{self.nrow}×{self.ncol} matrix: R recycling needs length "
            f"{self.nrow} (pairs with each row index, mapply.col) or "
            f"{self.ncol} (pairs with each column index, mapply.row)")

    def __add__(self, o):
        return self._bin(o, "add")

    def __radd__(self, o):
        return self._rbin(o, "add")

    def __sub__(self, o):
        return self._bin(o, "sub")

    def __rsub__(self, o):
        return self._rbin(o, "sub")

    def __mul__(self, o):
        return self._bin(o, "mul")

    def __rmul__(self, o):
        return self._rbin(o, "mul")

    def __truediv__(self, o):
        return self._bin(o, "div")

    def __rtruediv__(self, o):
        return self._rbin(o, "div")

    def __pow__(self, o):
        if isinstance(o, (int, float)) and o == 2:
            return FM(genops.sapply(self.m, "sq"))
        return self._bin(o, "pow")

    def __neg__(self):
        return FM(genops.sapply(self.m, "neg"))

    def __eq__(self, o):  # noqa: A003 - R semantics, not identity
        return self._bin(o, "eq")

    def __ne__(self, o):
        return self._bin(o, "neq")

    def __lt__(self, o):
        return self._bin(o, "lt")

    def __le__(self, o):
        return self._bin(o, "le")

    def __gt__(self, o):
        return self._bin(o, "gt")

    def __ge__(self, o):
        return self._bin(o, "ge")

    def __hash__(self):
        return id(self)

    # -- matmul ---------------------------------------------------------------
    def __matmul__(self, o):
        """%*%: matrix multiplication with the (mul, sum) semiring — the
        paper dispatches floating-point cases to BLAS; ours go to the MXU."""
        rhs = o.m if isinstance(o, FM) else o
        return FM(genops.inner_prod(self.m, rhs, "mul", "sum"))

    # -- transforms -------------------------------------------------------------
    def t(self) -> "FM":
        return FM(self.m.transpose())

    @property
    def T(self) -> "FM":
        return self.t()


def _vec_data(m: FMMatrix):
    if m.is_virtual:
        (m,) = mat_mod.materialize(m)
    return jnp.asarray(np.asarray(m.logical_data())).reshape(-1)


# ---------------------------------------------------------------------------
# Free functions (R vocabulary)
# ---------------------------------------------------------------------------

def _fm(x) -> FMMatrix:
    return x.m if isinstance(x, FM) else x


def sapply(x, f) -> FM:
    return FM(genops.sapply(_fm(x), f))


def mapply(a, b, f) -> FM:
    return FM(genops.mapply(_fm(a), _fm(b) if isinstance(b, FM) else b, f))


def mapply_row(a, vec, f) -> FM:
    return FM(genops.mapply_row(_fm(a), _fm(vec) if isinstance(vec, FM) else vec, f))


def mapply_col(a, vec, f) -> FM:
    return FM(genops.mapply_col(_fm(a), _fm(vec) if isinstance(vec, FM) else vec, f))


def inner_prod(a, b, f1="mul", f2="sum") -> FM:
    return FM(genops.inner_prod(_fm(a), _fm(b) if isinstance(b, FM) else b, f1, f2))


def agg(x, f) -> FM:
    return FM(genops.agg(_fm(x), f))


def agg_row(x, f) -> FM:
    return FM(genops.agg_row(_fm(x), f))


def agg_col(x, f) -> FM:
    return FM(genops.agg_col(_fm(x), f))


def groupby_row(x, labels, f, num_groups: int) -> FM:
    return FM(genops.groupby_row(_fm(x), _fm(labels) if isinstance(labels, FM)
                                 else labels, f, num_groups))


def groupby_col(x, labels, f, num_groups: int) -> FM:
    return FM(genops.groupby_col(_fm(x), labels, f, num_groups))


def cbind(*xs) -> FM:
    return FM(genops.cbind(*[_fm(x) for x in xs]))


# element-wise sugar
def sqrt(x) -> FM:
    return sapply(x, "sqrt")


def exp(x) -> FM:
    return sapply(x, "exp")


def log(x) -> FM:
    return sapply(x, "log")


def log1p(x) -> FM:
    return sapply(x, "log1p")


def sigmoid(x) -> FM:
    """1 / (1 + exp(-x)) — the logistic link inverse (GLM/IRLS)."""
    return sapply(x, "sigmoid")


def abs_(x) -> FM:
    return sapply(x, "abs")


def pmin(a, b) -> FM:
    return mapply(a, b, "pmin")


def pmax(a, b) -> FM:
    return mapply(a, b, "pmax")


def ifelse0(x, mask) -> FM:
    return mapply(x, mask, "ifelse0")


def is_na(x) -> FM:
    return sapply(x, "isna")


# aggregates (R names)
def sum_(x) -> FM:
    return agg(x, "sum")


def rowSums(x) -> FM:
    return agg_row(x, "sum")


def colSums(x) -> FM:
    return agg_col(x, "sum")


def rowMins(x) -> FM:
    return agg_row(x, "min")


def colMins(x) -> FM:
    return agg_col(x, "min")


def rowMaxs(x) -> FM:
    return agg_row(x, "max")


def colMaxs(x) -> FM:
    return agg_col(x, "max")


def which_min_row(x) -> FM:
    """R's max.col(-X) / apply(X, 1, which.min), zero-based."""
    return agg_row(x, "which.min")


def which_max_row(x) -> FM:
    return agg_row(x, "which.max")


def any_(x) -> FM:
    return agg(x, "any")


def all_(x) -> FM:
    return agg(x, "all")


def colMeans(x) -> FM:
    """R colMeans — a pure lazy chain: the colSums sink divided by n in the
    plan EPILOGUE (post-sink lazy math, evaluated once after the
    partition-loop merge), so colMeans fuses into whatever pass
    materializes it.  Recycling across the matrix (``X - colMeans(X)``)
    stays lazy too: the planner schedules the sweep one pass after the
    moment pass, all inside one materialize."""
    return colSums(x) / float(_fm(x).nrow)


def rowMeans(x) -> FM:
    """R rowMeans — row-local and LAZY (keeps the long dimension), unlike
    the sink-backed colMeans."""
    return rowSums(x) / float(_fm(x).ncol)


def colSds(x) -> FM:
    """Column standard deviations (matrixStats::colSds), fully lazy: the
    colSums and colSums(x²) sinks co-materialize in ONE streaming pass and
    sqrt((Σx² − (Σx)²/n)/(n−1)) runs as an epilogue chain in the same
    plan — nothing computes until the result is materialized."""
    n = float(_fm(x).nrow)
    s, s2 = colSums(x), colSums(x ** 2)
    var = (s2 - s * s / n) / (n - 1.0)
    return sqrt(pmax(var, 0.0))


def mean_(x) -> FM:
    """R mean(): grand mean over all elements — a lazy epilogue scalar
    (1×1); use ``fm.as_scalar`` for a python float."""
    m = _fm(x)
    return agg(x, "sum") / float(m.nrow * m.ncol)


def sweep(x, margin: int, stat, fun: str = "sub") -> FM:
    """R sweep(): apply ``fun`` between X and a summary statistic vector.

    ``margin=2`` pairs ``stat`` with each column index (``mapply.row``);
    ``margin=1`` with each row index (``mapply.col``).  ``stat`` may be a
    LAZY vector (``sweep(X, 2, colMeans(X))``): the whole expression stays
    one DAG and the multi-pass planner schedules the moment pass and the
    sweep pass inside a single materialize."""
    if margin == 2:
        return mapply_row(x, stat, fun)
    if margin == 1:
        return mapply_col(x, stat, fun)
    raise ValueError(f"sweep margin must be 1 (rows) or 2 (columns), "
                     f"got {margin!r}")


def scale(x, center=True, scale=True, save: Optional[str] = None) -> FM:
    """R scale(): center/standardize columns — a PURE LAZY chain.

    Nothing computes here: the moment sinks (colSums, colSums(x²)), their
    epilogue math and the sweeps are one DAG, and ``fm.materialize``
    schedules it as moment pass → sweep pass automatically (TWO streaming
    passes over X, one plan-cache entry, ``exec_stats()['passes'] == 2``).
    The standardized matrix also fuses into a downstream Gram or IRLS pass
    — FlashR's ``scale(as.double(...))`` ingestion idiom.  ``save='disk'``
    write-through-spills the swept output into an on-disk matrix during
    pass 2, so ``scale(X, save='disk')`` streams out-of-core end to end.
    Constant columns follow R: division yields non-finite values rather
    than being silently clamped."""
    z = x if isinstance(x, FM) else FM(x)
    if center:
        z = mapply_row(z, colMeans(x), "sub")
    if scale:
        z = mapply_row(z, colSds(x), "div")
    if save is not None and z.m.is_virtual:
        persist(z, tier=save)
    return z


def crossprod(x, y: Optional[FM] = None) -> FM:
    """R crossprod: t(x) %*% y (y defaults to x) — the Gram sink."""
    y = x if y is None else y
    return FM(genops.inner_prod(_fm(x).transpose(), _fm(y), "mul", "sum"))


def diag(x) -> FM:
    """R diag(): the diagonal of a (small, materialized) matrix as a
    vector, or a diagonal matrix from a vector.  Small-tier math — the
    operand is materialized if virtual."""
    arr = conv_FM2R(x) if isinstance(x, FM) else np.asarray(x)
    if arr.ndim == 2 and min(arr.shape) == 1:
        arr = arr.reshape(-1)
    if arr.ndim <= 1:
        return conv_R2FM(np.diag(arr.reshape(-1)))
    return conv_R2FM(np.diag(arr).copy())


def solve(a, b=None) -> FM:
    """R solve(): a⁻¹ (b=None) or the solution of a x = b.

    With a VIRTUAL operand (the XᵀWX / XᵀWz sinks of an IRLS step) this is
    a LAZY GenOp evaluated in the plan epilogue: the Newton solve joins the
    same fused pass as the sinks it consumes, one launch after the merge.
    Like all on-device linear algebra it does NOT raise on singular
    systems — non-finite values propagate into the result (check with
    ``np.isfinite``; ``glm`` does).  Physical operands keep the eager
    small-tier path (numpy, float64, raises ``LinAlgError``)."""
    a_virtual = isinstance(a, FM) and a.is_virtual
    b_virtual = isinstance(b, FM) and b.is_virtual
    if a_virtual or b_virtual:
        return FM(genops.solve(_fm(a), _fm(b) if isinstance(b, FM) else b))
    A = np.asarray(conv_FM2R(a) if isinstance(a, FM) else a, np.float64)
    if b is None:
        return conv_R2FM(np.linalg.inv(A))
    B = np.asarray(conv_FM2R(b) if isinstance(b, FM) else b, np.float64)
    if B.ndim <= 1:
        B = B.reshape(-1, 1)   # R: a bare vector is a one-column RHS
    return conv_R2FM(np.linalg.solve(A, B))


def rowsum(x, groups, num_groups: int) -> FM:
    """R rowsum: sum rows by group label."""
    return groupby_row(x, groups, "sum", num_groups)


def table_(groups, num_groups: int) -> FM:
    """R table() over integer labels: per-group counts."""
    g = _fm(groups)
    return FM(genops.groupby_row(g, g, "count", num_groups))


# -- construction / conversion ------------------------------------------------
def runif_matrix(nrow, ncol, **kw) -> FM:
    return FM(matrix_mod.runif_matrix(nrow, ncol, **kw))


def rnorm_matrix(nrow, ncol, **kw) -> FM:
    return FM(matrix_mod.rnorm_matrix(nrow, ncol, **kw))


def rep_int(value, n, **kw) -> FM:
    return FM(matrix_mod.rep_int(value, n, **kw))


def seq_int(n, **kw) -> FM:
    return FM(matrix_mod.seq_int(n, **kw))


def conv_R2FM(arr, host: bool = False) -> FM:
    return FM(matrix_mod.conv_R2FM(arr, host=host))


def conv_FM2R(x) -> np.ndarray:
    return matrix_mod.conv_FM2R(_fm(x))


class Factor:
    """A factor vector (paper Table III ``fm.as.factor``): integer codes
    in ``[0, num_levels)`` plus the level count — what ``fm.one_hot``
    consumes to build the sparse design-matrix columns."""

    __slots__ = ("codes", "num_levels")

    def __init__(self, codes: np.ndarray, num_levels: int):
        self.codes = codes
        self.num_levels = int(num_levels)

    def __len__(self):
        return int(self.codes.shape[0])

    def __repr__(self):
        return f"Factor(n={len(self)}, num_levels={self.num_levels})"


def as_factor(x, num_levels: Optional[int] = None) -> Factor:
    """fm.as.factor: integer labels → a factor vector.

    ``x`` is an FM, FMMatrix or array of integer-valued labels (one
    column); ``num_levels`` defaults to ``max(code) + 1``.  Codes must be
    in ``[0, num_levels)`` — the hashed-categorical convention of the
    Criteo workload, where each of the 26 hash columns becomes a factor."""
    if isinstance(x, Factor):
        return x if num_levels is None else Factor(x.codes, num_levels)
    arr = np.asarray(conv_FM2R(x) if isinstance(x, (FM, FMMatrix)) else x)
    codes = arr.reshape(-1)
    if not np.issubdtype(codes.dtype, np.integer):
        rounded = np.rint(codes)
        if not np.array_equal(rounded, codes):
            raise ValueError(
                "as_factor needs integer-valued labels; got non-integer "
                "values (bin or hash continuous features first)")
        codes = rounded
    codes = codes.astype(np.int64)
    if codes.size and codes.min() < 0:
        raise ValueError("as_factor: negative label codes")
    if num_levels is None:
        num_levels = int(codes.max()) + 1 if codes.size else 1
    elif codes.size and codes.max() >= num_levels:
        raise ValueError(
            f"as_factor: label code {int(codes.max())} out of range for "
            f"num_levels={num_levels}")
    return Factor(codes, num_levels)


def one_hot(*factors, dtype=np.float32, host: bool = True) -> FM:
    """One-hot encode factor(s) into ONE sparse matrix (the ELL tier).

    Each argument is a ``Factor`` (from ``fm.as_factor``) or raw integer
    labels; k factors cbind with running column offsets, so every row has
    exactly k ones — the Criteo design matrix (26 factor columns → a CSR
    row of 26 ones among ~2^20 columns) without ever densifying.
    ``host=False`` places the slab on device.  Persist with
    ``fm.persist(X, tier='disk')`` to write the CSR ``.fmat``."""
    if not factors:
        raise ValueError("one_hot needs at least one factor")
    fs = [as_factor(f) for f in factors]
    n = len(fs[0])
    if any(len(f) != n for f in fs):
        raise ValueError(
            f"one_hot: factor lengths differ ({[len(f) for f in fs]})")
    ncol, offset = 0, []
    for f in fs:
        offset.append(ncol)
        ncol += f.num_levels
    cols = np.stack([f.codes + off for f, off in zip(fs, offset)],
                    axis=1).astype(np.int32)
    vals = np.ones(cols.shape, np.dtype(dtype))
    from ..storage.sparse import SparseEllStore  # lazy: avoid cycle
    if not host:
        cols, vals = jnp.asarray(cols), jnp.asarray(vals)
    store = SparseEllStore(cols, vals, ncol, nnz=n * len(fs))
    return FM(FMMatrix((n, ncol), vals.dtype, store=store))


def persist(x, tier: str = "device", *, name: Optional[str] = None) -> FM:
    """fm.persist: the ONE entry point for keeping a matrix on a tier.

    ``tier`` is 'device' (HBM analog), 'host' (RAM), or 'disk' (the SSD
    tier — FlashR's ``in.mem=FALSE``).  Dense and sparse matrices both
    route here; a sparse matrix persists in its sparse representation
    (ELL slab in RAM, CSR ``.fmat`` on disk) — it is never densified.

      * VIRTUAL ``x``: marks the lazy result so the NEXT materialization
        keeps it on ``tier`` — ``tier='disk'`` write-through-spills the
        streaming output (no extra pass), subsuming the old
        ``materialize(..., save='disk')`` / ``set_mate_level`` spellings.
      * PHYSICAL ``x``: moves the data now — ``tier='disk'`` writes it
        into the configured data directory under ``name`` (or the
        matrix's own name) and returns the reopened mmap-backed handle,
        subsuming the old ``conv_store`` spelling.

    Returns an FM either way (the same lazy handle for virtuals, the new
    tier's handle for physicals)."""
    if tier not in ("device", "host", "disk"):
        raise ValueError(
            f"unknown tier {tier!r}: expected 'device', 'host' or 'disk'")
    m = _fm(x)
    if m.is_virtual:
        genops.set_mate_level(m, tier)
        if name:
            m.name = name
        return x if isinstance(x, FM) else FM(m)
    return FM(matrix_mod.conv_store(m, tier, name=name or ""))


def conv_store(x, where: str, *, name: str = "") -> FM:
    """Deprecated spelling of ``fm.persist(x, tier=where, name=...)``."""
    warnings.warn(
        "fm.conv_store(x, where, name=...) is deprecated; use "
        "fm.persist(x, tier=..., name=...)", DeprecationWarning,
        stacklevel=2)
    return persist(x, tier=where, name=name or None)


# -- the disk tier / EM-matrix registry (repro/storage/) ----------------------
def set_conf(**kw) -> dict:
    """fm.set.conf: data_dir / prefetch / prefetch_depth /
    io_partition_bytes / vmem_partition_bytes / backend / direct_io /
    mesh (a jax Mesh from launch.mesh.make_host_mesh — installs sharded
    execution engine-wide; ``mesh=False`` clears it).  Unknown knobs
    raise with a did-you-mean hint (``storage.registry.KNOWN_KNOBS`` is
    the authoritative table); for a scoped override use ``fm.conf``."""
    from ..storage import registry
    return registry.set_conf(**kw)


def conf(**kw):
    """fm.conf: scoped configuration override (a context manager).

        with fm.conf(backend='pallas', prefetch=False):
            fm.materialize(G)          # runs under the override
        # prior values restored here, even on error

    Validates knob names exactly like ``fm.set_conf`` and snapshots the
    prior values on entry — replacing the manual save/restore dance in
    tests and benchmarks."""
    from ..storage import registry
    return registry.conf(**kw)


def get_dense_matrix(name: str) -> FM:
    """fm.get.dense.matrix: reopen a named on-disk matrix (mmap-backed)."""
    from ..storage import registry
    return FM(registry.get_dense_matrix(name))


def load_dense_matrix(src, name: str, **kw) -> FM:
    """fm.load.dense.matrix: ingest CSV/binary/npy/array → on-disk matrix."""
    from ..storage import registry
    return FM(registry.load_dense_matrix(src, name, **kw))


def load_factor_matrix(src, name: str, *, num_levels, **kw) -> FM:
    """fm.load.factor.matrix: stream a CSV of integer factor columns into
    a CSR on-disk matrix of one-hot rows (the Criteo design matrix) and
    reopen it on the sparse tier."""
    from ..storage import registry
    return FM(registry.load_factor_matrix(src, name, num_levels=num_levels,
                                          **kw))


def save_dense_matrix(x, name: Optional[str] = None, **kw) -> FM:
    """Write a physical matrix into the registry; returns the disk handle."""
    from ..storage import registry
    m = _fm(x)
    if getattr(m, "is_virtual", False):
        (m,) = mat_mod.materialize(m)
    return FM(registry.save_dense_matrix(m, name, **kw))


def conv_layout(x, layout: str) -> FM:
    return FM(matrix_mod.conv_layout(_fm(x), layout))


def set_mate_level(x, level: str) -> FM:
    """Deprecated spelling of ``fm.persist(x, tier=level)``."""
    warnings.warn(
        "fm.set_mate_level(x, level) is deprecated; use "
        "fm.persist(x, tier=...)", DeprecationWarning, stacklevel=2)
    return persist(x, tier=level)


def materialize(*xs, **kw) -> list[FM]:
    """fm.materialize: fused evaluation of every argument in one pass."""
    mats = mat_mod.materialize(*[_fm(x) for x in xs], **kw)
    return [FM(m) for m in mats]


def batch(*request_groups, **kw):
    """fm.batch: cross-materialize stream fusion (core/batch.py).

    Each argument is one request — a lazy matrix, or a tuple/list of lazy
    matrices that would otherwise be one ``fm.materialize(...)`` call.
    Every request keeps its own plan, but requests whose passes stream the
    same physical sources are co-scheduled onto ONE partition sweep: k
    plans × 1 stream (``fm.exec_stats()['streams']``).

        means, (sds, ctp) = fm.batch(fm.colMeans(X),
                                     (fm.colSds(X), fm.crossprod(X)))

    With no arguments, returns a collector to queue requests explicitly:

        with fm.batch() as b:
            h = b.add(fm.colMeans(X))
        h.value

    Keywords (``mode``, ``backend``, ``donate``, ``prefetch``,
    ``reuse_plans``, ``mesh``) follow ``fm.materialize``; ``mode='auto'``
    picks per group from the union of that group's sources."""
    from . import batch as batch_mod
    b = batch_mod.Batch(**kw)
    if not request_groups:
        return b
    handles = []
    for grp in request_groups:
        outs = grp if isinstance(grp, (tuple, list)) else (grp,)
        handles.append(b.add(*[_fm(x) for x in outs]))
    b.run()
    results = []
    for grp, h in zip(request_groups, handles):
        v = h.value
        results.append([FM(m) for m in v] if isinstance(v, list) else FM(v))
    return results


def serve(**kw):
    """fm.serve: start an async multi-tenant serving `Engine`
    (core/serve.py) — concurrent threads ``submit()`` lazy requests, a
    short admission window groups strangers' plans by shared sources, and
    each group streams its matrices ONCE for all members (k requests ×
    1 stream), with bandwidth admission control and mid-stream admission
    of late same-group plans.

        with fm.serve(window_ms=5) as eng:
            h1 = eng.submit(fm.colMeans(X))   # any thread
            h2 = eng.submit(fm.crossprod(X))  # same window, same stream
            mu, G = h1.result(), h2.result()

    Keywords are `Engine`'s (window_ms, max_window_requests,
    max_concurrent_streams, max_inflight_bytes, max_pending_requests,
    submit_timeout_s, midstream_admission, mode, backend, donate,
    prefetch, prefetch_depth, reuse_plans, mesh)."""
    from . import serve as serve_mod
    return serve_mod.Engine(**kw)


def __getattr__(name):
    # fm.Engine without importing the serving layer at fm import time.
    if name in ("Engine", "EngineSaturated"):
        from . import serve as serve_mod
        return getattr(serve_mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def inspect_iterations():
    """fm.inspect_iterations: declare an iterative driver's loop so the
    executor keeps each streaming pass's final staged partition resident
    across materialize/batch calls — iteration i+1's first pass over the
    same partition schedule starts from the resident blocks instead of
    re-reading them (``prefetch_reuse_hits``).  The iterative drivers
    (kmeans / glm IRLS / nmf / gmm) open this around their loops."""
    return mat_mod.iteration_scope()


def as_scalar(x) -> float:
    (r,) = materialize(x) if _fm(x).is_virtual else (x,)
    return float(np.asarray(_fm(r).logical_data()).reshape(()))


def as_np(x) -> np.ndarray:
    return conv_FM2R(x)


# -- observability (repro/observability/) --------------------------------------

def trace(export: Optional[str] = None, *, reset: bool = True):
    """fm.trace: enable span tracing over a with-block.

        with fm.trace():
            fm.materialize(G)
        fm.trace_export("run.trace.json")   # chrome://tracing / Perfetto

    ``export=`` writes the Chrome-trace JSON on scope exit; ``reset=False``
    appends to the already-collected events instead of starting fresh.
    The prefetcher's staging thread records onto its own track, so
    stage/compute overlap is visible in the timeline."""
    from ..observability.trace import TRACER
    return TRACER.recording(export, reset=reset)


def trace_export(path) -> str:
    """fm.trace.export: write collected spans as Chrome-trace JSON."""
    from ..observability.trace import TRACER
    return TRACER.export(path)


def trace_events() -> list:
    """Collected span events (dicts with name/ts/dur/tid), for programmatic
    inspection without round-tripping the JSON export."""
    from ..observability.trace import TRACER
    return TRACER.events()


def collect_stats(name: str = ""):
    """fm.collect.stats: a metrics scope isolating THIS thread's engine
    activity (its materialize calls, plus the prefetch pipelines they
    spawn).  Yields the scope; read it with ``.stats()``:

        with fm.collect_stats() as sc:
            fm.materialize(G)
        sc.stats()["stream_bandwidth_bytes_s"]

    Scopes are per-thread, so concurrent requests each see only their own
    execution — the per-request accounting a serving layer needs."""
    from ..observability import metrics
    return metrics.collect(name)


def exec_stats() -> dict:
    """fm.exec.stats: the engine's execution counters (compatibility view
    over the metrics registry's root scope)."""
    return mat_mod.exec_stats()


def reset_exec_stats():
    mat_mod.reset_exec_stats()


def explain(*xs, backend: Optional[str] = None) -> str:
    """fm.explain: render the fused plan ``fm.materialize(*xs)`` would run
    — pass schedule, source tiers, both partition levels, per-segment
    backend dispatch — without executing anything."""
    from ..observability.explain import explain as _explain
    return _explain(*[_fm(x) for x in xs], backend=backend)


def explain_batch(*request_groups, backend: Optional[str] = None) -> str:
    """fm.explain_batch: render the co-schedule ``fm.batch(*requests)``
    would run — per round, the stream groups with their member plans,
    shared sources and the union bytes one drive reads — without executing
    anything.  Arguments mirror ``fm.batch``: each one is a lazy matrix or
    a tuple/list of them forming one request."""
    from ..observability.explain import explain_batch as _explain_batch
    groups = [grp if isinstance(grp, (tuple, list)) else (grp,)
              for grp in request_groups]
    return _explain_batch([[_fm(x) for x in g] for g in groups],
                          backend=backend)
