"""Element-type lattice and promotion rules.

FlashMatrix supports a small set of primitive element types and performs
*lazy* type casts (paper §III-D: "If a GenOp gets two matrices with different
element types, it first casts the element type of one matrix to match the
other. Type casting operations are implemented with fm.sapply and are
performed lazily.").

We mirror that: a total order (lattice) over the supported dtypes, a
``promote`` rule, and a ``cast`` VUDF factory used by the DAG builder to
insert lazy sapply-cast nodes.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# The promotion lattice, weakest to strongest.  Mirrors R's logical <
# integer < double ordering, extended with the narrower machine types the
# paper supports for storage efficiency.
_LATTICE = (
    jnp.dtype(jnp.bool_),
    jnp.dtype(jnp.int8),
    jnp.dtype(jnp.int16),
    jnp.dtype(jnp.int32),
    jnp.dtype(jnp.int64),
    jnp.dtype(jnp.bfloat16),
    jnp.dtype(jnp.float32),
    jnp.dtype(jnp.float64),
)

_RANK = {dt: i for i, dt in enumerate(_LATTICE)}

SUPPORTED = frozenset(_LATTICE)


def _x64() -> bool:
    import jax
    return bool(jax.config.jax_enable_x64)


def canon(dtype) -> jnp.dtype:
    """Canonicalize a user-supplied dtype to a supported lattice member.

    When JAX runs with x64 disabled (the default), 64-bit members degrade to
    their 32-bit counterparts so accumulator identities stay representable.
    """
    dt = jnp.dtype(dtype)
    if not _x64():
        if dt == jnp.dtype("int64"):
            dt = jnp.dtype(jnp.int32)
        elif dt == jnp.dtype("float64"):
            dt = jnp.dtype(jnp.float32)
    if dt in _RANK:
        return dt
    # Map unsupported widths onto the nearest supported member.
    if dt.kind == "f":
        return jnp.dtype(jnp.float32) if dt.itemsize <= 4 else jnp.dtype(jnp.float64)
    if dt.kind in ("i", "u"):
        return jnp.dtype(jnp.int32) if dt.itemsize <= 4 else jnp.dtype(jnp.int64)
    if dt.kind == "b":
        return jnp.dtype(jnp.bool_)
    raise TypeError(f"unsupported element type: {dtype!r}")


def rank(dtype) -> int:
    return _RANK[canon(dtype)]


def promote(a, b) -> jnp.dtype:
    """Binary promotion: the stronger of the two lattice members."""
    ca, cb = canon(a), canon(b)
    return ca if _RANK[ca] >= _RANK[cb] else cb


def is_floating(dtype) -> bool:
    return canon(dtype).kind == "f"


def to_floating(dtype) -> jnp.dtype:
    """The dtype arithmetic means (e.g. division) promotes to."""
    dt = canon(dtype)
    if dt.kind == "f":
        return dt
    return jnp.dtype(jnp.float64) if dt == jnp.dtype(jnp.int64) else jnp.dtype(jnp.float32)


def nbytes(dtype) -> int:
    return canon(dtype).itemsize


def np_equiv(dtype) -> np.dtype:
    """numpy equivalent for host-side (out-of-core) staging buffers."""
    dt = canon(dtype)
    if dt == jnp.dtype(jnp.bfloat16):
        # numpy has no bfloat16; stage as float32 and cast on device.
        return np.dtype(np.float32)
    return np.dtype(dt.name)
