"""Pluggable lowering backends: plan IR → executable partition programs.

Third layer of the execution engine (after `fusion.Plan` cut/schedule and
`plan_ir` segment compilation).  A backend lowers the IR's segments into a
`LoweredProgram` whose contract the materializer consumes:

    partials, row_local_outputs = program.step(source_blocks, smalls, offset)
    accs = program.combine(accs, partials)       # the paper's partial merge

``step`` pushes ONE I/O-level partition through the whole fused cut and
returns each sink's *partial* for that partition; ``combine`` merges
partials into the running accumulators with the aggregation VUDFs'
``combine`` — exactly the paper's "each thread computes partial aggregation
results independently … in the end, FlashMatrix merges the partial
aggregation results" (§III-F), with partitions standing in for threads.

Backends:

* ``xla``    — every segment is traced node-by-node through the generic
  ``block_eval`` / ``block_update`` rules and XLA performs the cache-level
  fusion (the engine's previous behavior).
* ``pallas`` — eligible segments lower onto the hand-written kernels in
  `repro/kernels/` (the VMEM-tier analog of the paper's CPU-cache fusion):
  inner-product contractions → `gram`/`xty`, apply→agg.col chains sharing a
  source → one `fused_apply_agg` call, and the k-means Lloyd pattern
  (distances → which.min → groupby) → `kmeans_assign`.  Segments with no
  kernel match fall back to the generic trace, and on non-TPU backends the
  kernels run in interpret mode so the same lowering path is exercised in
  tests.

Backend selection: ``fm.set_conf(backend=...)`` ('auto' | 'xla' | 'pallas')
or the ``backend=`` argument of ``fm.materialize``; 'auto' picks pallas on
TPU and xla elsewhere.  The backend name and the IR's two-level partition
schedule are both part of the plan-cache key, so switching backends or
retuning either partition level retraces instead of reusing a stale
executable.

Registering a new kernel lowering = appending a matcher to
``PallasBackend.MATCHERS``: a callable ``(plan, ir, claimed) -> list[unit]``
that inspects unclaimed segments, marks the ones it consumes in ``claimed``
and returns execution units (objects with ``run(values, partials, smalls,
offset)``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import dtypes
from .dag import (AggFullNode, GroupByRowNode, InnerProdContractNode,
                  MapNode, Node, Small)
from .sparse import SparseBlock

# ---------------------------------------------------------------------------
# Backend registry + selection
# ---------------------------------------------------------------------------

BACKENDS: dict[str, "Backend"] = {}

#: Engine-wide default, settable via fm.set_conf(backend=...).
DEFAULT_BACKEND = "auto"


def register_backend(name: str, backend: "Backend"):
    BACKENDS[name] = backend
    return backend


def resolve_backend(name: str | None = None) -> str:
    """'auto' (or None) → pallas on TPU, xla elsewhere."""
    name = name or DEFAULT_BACKEND
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if name not in BACKENDS:
        raise ValueError(
            f"unknown backend {name!r}; have {sorted(BACKENDS)} + 'auto'")
    return name


def lower(plan, ir, backend: str) -> "LoweredProgram":
    return BACKENDS[backend].lower(plan, ir)


# ---------------------------------------------------------------------------
# The lowered program
# ---------------------------------------------------------------------------

class LoweredProgram:
    """An executable lowering of ONE PASS of a plan: per-partition ``step``,
    the sink-partial ``combine`` merge, and — when the pass has post-sink
    lazy math — an ``epilogue`` callable the executor invokes exactly ONCE
    after the merge: ``epilogue(merged_sinks, epilogue_sources, smalls,
    bindings) → outputs`` (the engine's fourth stage).

    ``bindings`` is the multi-pass contract (fusion.PassSchedule): merged
    values produced by EARLIER passes of the same plan — the pass-1 moment
    vector a pass-2 ``scale(X)`` sweep consumes — handed to ``step`` and
    ``epilogue`` as broadcast arguments keyed by node id.  They are normal
    runtime inputs of the jitted callables (never baked constants, never
    donated: every partition of the pass reads them)."""

    def __init__(self, plan, ir, backend: str, units):
        self.plan = plan
        self.ir = ir
        self.backend = backend
        self.units = units
        self._sinks_by_id = {n.id: n for n in plan.sinks}
        self.step = jax.jit(self._step)
        # Buffer donation = the paper's memory-chunk recycling: staged
        # partition blocks are dead after the step consumes them, and the
        # previous accumulators are dead after the merge.
        self.step_donated = jax.jit(self._step, donate_argnums=(0,))
        self.combine = jax.jit(self._combine, donate_argnums=(0,))
        self.epilogue = (jax.jit(self._epilogue)
                         if plan.epilogue_nodes else None)

    @property
    def kernel_units(self):
        """The units lowered onto hand-written kernels (empty under xla)."""
        return [u for u in self.units if getattr(u, "kernel", None)]

    def describe(self) -> str:
        lines = [f"LoweredProgram(backend={self.backend}, "
                 f"units={len(self.units)})"]
        lines += ["  " + u.describe() for u in self.units]
        if self.epilogue is not None:
            lines.append(f"  epilogue nodes={len(self.plan.epilogue_nodes)} "
                         f"outs={[n.name for n in self.plan.epilogue_roots]}")
        return "\n".join(lines)

    def shard_specs(self, mesh) -> dict:
        """PartitionSpec per result node of this pass under a data-sharded
        mesh (ISSUE 9), resolved through the shared divisibility-checked
        policy (``distributed.sharding.resolve``): long-dim outputs shard
        their row dimension over the data tier (``rows`` — falls back to
        replicate when the row count does not divide), merged sinks and
        epilogue values replicate (``rep`` — every device holds the full
        reduction, which is what lets the epilogue run replicated)."""
        from ..distributed import sharding as shd
        specs = {}
        for n in self.plan.row_local_roots + self.plan.saves:
            specs[n.id] = shd.resolve("rows|rep", (n.nrow, n.ncol), mesh)
        for n in list(self.plan.sinks) + list(self.plan.epilogue_roots):
            specs[n.id] = shd.resolve("rep|rep", (n.nrow, n.ncol), mesh)
        return specs

    def _step(self, source_blocks, smalls, bindings, offset):
        """One I/O-level partition through the fused cut of this pass.

        Returns (sink_partials, row_local_outputs) for this partition;
        partials start from each sink's identity so ``combine`` can merge
        them into accumulators of the same structure.

        ``source_blocks`` holds ONE staged block per physical matrix
        (keyed by the staging group's canonical node id); every aliasing
        source node sees the same traced value, so a matrix referenced
        through k leaves is read and transferred once per partition.
        ``bindings`` holds the pass's broadcast inputs keyed by node id:
        earlier-pass merged values plus whole-staged small physical sources
        (fusion.PassSchedule.broadcast_sources).
        """
        values = {nid: source_blocks[canon]
                  for nid, canon in self.plan.source_aliases.items()}
        values.update(bindings)
        partials = {n.id: n.identity() for n in self.plan.sinks}
        for unit in self.units:
            unit.run(values, partials, smalls, offset)
        outputs = {n.id: values[n.id]
                   for n in self.plan.row_local_roots + self.plan.saves}
        return partials, outputs

    def _combine(self, accs, partials):
        return {nid: self._sinks_by_id[nid].combine(accs[nid], partials[nid])
                for nid in accs}

    def _epilogue(self, sink_finals, epi_sources, smalls, bindings):
        """The pass's post-sink lazy math (paper §III-E: expressions like
        ``colSums(X) / n`` fuse into the same execution job), evaluated on
        the FINALIZED sink values — one on-device launch per pass, cached
        with the rest of the plan.

        ``sink_finals``: {sink node id: finalized value} out of the merge;
        ``epi_sources``: {leaf id: whole array} for small physical operands
        only the epilogue consumes (e.g. a ridge eye matrix);
        ``bindings``: earlier-pass merged values (multi-pass plans).  A
        sink-kind node appearing here (``sum(colMeans(X))``) contracts an
        already-merged small value, so it runs its identity→update→finalize
        quartet once with offset 0.

        Returns the pass's epilogue ROOTS (requested/saved results) plus
        its CARRIES — unrequested epilogue values a later pass consumes.
        """
        values = dict(bindings)
        values.update(epi_sources)
        values.update(sink_finals)
        zero = jnp.zeros((), jnp.int32)
        for n in self.plan.epilogue_nodes:
            blocks = [smalls[self.plan._small_pos[id(p)]]
                      if isinstance(p, Small) else values[p.id]
                      for p in n.parents]
            if n.is_sink:
                acc = n.block_update(n.identity(), blocks, zero)
                values[n.id] = n.finalize(acc)
            else:
                values[n.id] = n.block_eval(blocks, zero)
        outs = {n.id: values[n.id] for n in self.plan.epilogue_roots}
        for n in getattr(self.plan, "epilogue_carries", []):
            outs[n.id] = values[n.id]
        return outs


class MultiPassProgram:
    """The compiled executable of a multi-pass plan: one `LoweredProgram`
    per pass, run in order by the executor under ONE plan-cache entry.
    Pass k+1's ``bindings`` are fed from pass k's finalized sinks and
    epilogue outputs (core/materialize.py carries them forward)."""

    def __init__(self, passes):
        self.passes = list(passes)
        self.backend = self.passes[0].backend if self.passes else "?"

    @property
    def kernel_units(self):
        return [u for p in self.passes for u in p.kernel_units]

    @property
    def epilogue(self):
        """Truthy when any pass has post-merge math (observability only —
        the executor always goes through the per-pass programs)."""
        return next((p.epilogue for p in self.passes
                     if p.epilogue is not None), None)

    def describe(self) -> str:
        lines = [f"MultiPassProgram(passes={len(self.passes)})"]
        for k, p in enumerate(self.passes):
            lines.append(f" pass {k}:")
            lines.extend("  " + line for line in p.describe().splitlines())
        return "\n".join(lines)

    def shard_specs(self, mesh) -> dict:
        """Union of every pass's per-node output specs (node ids are unique
        across the plan) — the sharded executor runs the SAME per-pass
        programs as per-device executors, one row range each, and places
        results by these specs."""
        specs = {}
        for p in self.passes:
            specs.update(p.shard_specs(mesh))
        return specs


class GroupProgram:
    """The co-scheduled executable of ONE stream group (core/batch.py): k
    member passes driven over a single shared partition stream.

    The members keep their own compiled ``step``/``combine``/``epilogue``
    (plan-cache identity, donation rules and sink merge are per member);
    what the group composes is the SCHEDULE — while a staged partition is
    resident, every member's step consumes it before eviction, so k plans
    × 1 stream executes as 1 stream × k steps.  ``members`` holds
    ``(PassSchedule, LoweredProgram)`` pairs in execution order; the
    runner is ``materialize._run_stream_group``.
    """

    def __init__(self, members):
        self.members = list(members)

    @property
    def kernel_units(self):
        return [u for _, prog in self.members for u in prog.kernel_units]

    @property
    def partition_rows(self) -> int:
        """The group's common partitioning: the smallest member's rows (all
        are powers of two under one I/O budget, so every member's schedule
        divides it)."""
        return min(ps.partition_rows for ps, _ in self.members)

    def describe(self) -> str:
        lines = [f"GroupProgram(members={len(self.members)}, "
                 f"partition_rows={self.partition_rows})"]
        for i, (ps, prog) in enumerate(self.members):
            lines.append(f" member {i} (pass {ps.idx}, "
                         f"rows={ps.partition_rows}):")
            lines.extend("  " + line
                         for line in prog.describe().splitlines())
        return "\n".join(lines)


class Backend:
    name = "?"

    def lower(self, plan, ir) -> LoweredProgram:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Execution units
# ---------------------------------------------------------------------------

class GenericUnit:
    """Trace a segment node-by-node through the dag eval rules (the xla
    path, and the fallback for segments no kernel matcher claims)."""

    kernel = None

    def __init__(self, plan, segment):
        self.plan = plan
        self.segment = segment
        self.nodes = segment.nodes

    def describe(self) -> str:
        return (f"generic seg#{self.segment.sid} [{self.segment.kind}] "
                f"root={self.segment.root.name}")

    def run(self, values, partials, smalls, offset):
        # Sparse (ELL) partition blocks densify lazily into a LOCAL cache:
        # only node rules with no sparse path see the dense form, and the
        # shared ``values`` dict keeps the SparseBlock so a kernel unit
        # consuming the same staged leaf still gets nnz-proportional input.
        # matmul_small keeps its sparse gather path (dag._inner_prod_block
        # handles a SparseBlock left operand directly).
        dense: dict[int, object] = {}

        def block_of(n, pos, p):
            v = values[p.id]
            if isinstance(v, SparseBlock) and not (
                    n.kind == "matmul_small" and pos == 0):
                if p.id not in dense:
                    dense[p.id] = v.todense()
                v = dense[p.id]
            return v

        for n in self.nodes:
            blocks = [smalls[self.plan._small_pos[id(p)]]
                      if isinstance(p, Small) else block_of(n, i, p)
                      for i, p in enumerate(n.parents)]
            if n.is_sink:
                partials[n.id] = n.block_update(partials[n.id], blocks, offset)
            else:
                values[n.id] = n.block_eval(blocks, offset)


class _KernelUnit:
    """Base for units lowered onto a Pallas kernel.  ``interpret=None``
    defers to kernels.common.default_interpret(): Mosaic on TPU,
    interpreter elsewhere — the same call sites run in both worlds."""

    def __init__(self, kernel: str, block_rows: int):
        self.kernel = kernel
        self.block_rows = int(block_rows)

    @staticmethod
    def _merge(partials, node, part):
        partials[node.id] = node.combine(
            partials[node.id], part.astype(partials[node.id].dtype))


class ContractionUnit(_KernelUnit):
    """InnerProdContractNode (mul, sum) → kernels.gram / kernels.xty."""

    def __init__(self, node: InnerProdContractNode, block_rows: int):
        left, right = node.parents
        # crossprod(X) wraps one physical matrix in two LeafNodes: detect
        # the shared backing so it lowers to gram (one read) rather than xty.
        same = left is right or (
            getattr(left, "mat", None) is not None
            and left.mat is getattr(right, "mat", None))
        super().__init__("gram" if same else "xty", block_rows)
        self.node = node
        self.left_id, self.right_id = left.id, right.id

    def describe(self) -> str:
        return f"pallas:{self.kernel} root={self.node.name}"

    def run(self, values, partials, smalls, offset):
        from ..kernels import gram as gram_mod
        x = values[self.left_id]
        if self.kernel == "gram":
            part = gram_mod.gram(x, block_rows=min(self.block_rows, x.shape[0]))
        else:
            part = gram_mod.xty(x, values[self.right_id],
                                block_rows=min(self.block_rows, x.shape[0]))
        self._merge(partials, self.node, part)


class WeightedGramUnit(_KernelUnit):
    """The IRLS weighted-Gram pattern — ``mapply.col(X, w, mul)`` feeding an
    (mul, sum) contraction of the same X — → one kernels.wgram call: the
    reweighted rows never exist outside the VMEM tile."""

    def __init__(self, node: InnerProdContractNode, x_id: int, w_id: int,
                 block_rows: int):
        super().__init__("wgram", block_rows)
        self.node = node
        self.x_id = x_id
        self.w_id = w_id

    def describe(self) -> str:
        return f"pallas:{self.kernel} root={self.node.name}"

    def run(self, values, partials, smalls, offset):
        from ..kernels import weighted_gram as wg
        x = values[self.x_id]
        w = values[self.w_id]
        part = wg.wgram(x, w, block_rows=min(self.block_rows, x.shape[0]))
        self._merge(partials, self.node, part)


class ApplyAggUnit(_KernelUnit):
    """N apply→agg.col chains over one source → one fused_apply_agg call
    (the paper's sink co-materialization: X is read once for all stats)."""

    def __init__(self, source_id: int, chains, sinks, block_rows: int):
        super().__init__("fused_apply_agg", block_rows)
        self.source_id = source_id
        self.chains = tuple(chains)
        self.sinks = list(sinks)

    def describe(self) -> str:
        return (f"pallas:{self.kernel} chains={len(self.chains)} "
                f"sinks={[s.name for s in self.sinks]}")

    def run(self, values, partials, smalls, offset):
        from ..kernels import fused_apply_agg as faa
        x = values[self.source_id]
        parts = faa.fused_apply_agg(
            x, self.chains, block_rows=min(self.block_rows, x.shape[0]))
        for node, part in zip(self.sinks, parts):
            self._merge(partials, node, part.reshape(node.identity().shape))


class KMeansUnit(_KernelUnit):
    """The Lloyd-step pattern → one kernels.kmeans_assign call per
    partition: distances, argmin, groupby sums/counts and the objective all
    from one VMEM-resident read of X."""

    def __init__(self, *, x_id: int, centers_pos: int, labels: Node,
                 sums: Node, counts: Node | None, wss: Node | None,
                 block_rows: int):
        super().__init__("kmeans_assign", block_rows)
        self.x_id = x_id
        self.centers_pos = centers_pos
        self.labels, self.sums, self.counts, self.wss = (
            labels, sums, counts, wss)

    def describe(self) -> str:
        outs = [self.labels.name, self.sums.name]
        outs += [n.name for n in (self.counts, self.wss) if n is not None]
        return f"pallas:{self.kernel} outs={outs}"

    def run(self, values, partials, smalls, offset):
        from ..kernels import kmeans_assign as ka
        x = values[self.x_id]
        centers = smalls[self.centers_pos].T  # matmul_small stores (p, k)
        lab, sums, cnts, wss = ka.kmeans_assign(
            x, centers, block_rows=min(self.block_rows, x.shape[0]))
        values[self.labels.id] = lab.reshape(-1, 1)
        self._merge(partials, self.sums, sums)
        if self.counts is not None:
            self._merge(partials, self.counts, cnts.reshape(-1, 1))
        if self.wss is not None:
            self._merge(partials, self.wss, wss.reshape(()))


class SpmmUnit(_KernelUnit):
    """A sparse-ELL contraction lowered onto the kernels.spmm family: the
    staged SparseBlock flows straight into the kernel (nnz-proportional HBM
    traffic — the paper's one-hot/Criteo tier), scatter-densified to a VMEM
    tile inside the kernel only.  ``prefix`` holds an absorbed row-local
    chain computing the dense right operand (the IRLS ``w·z`` feeding
    XᵀWz), evaluated generically before the kernel call; it never touches
    the sparse leaf (the matcher declines otherwise)."""

    def __init__(self, kernel: str, node: InnerProdContractNode, *, plan,
                 seg, x_id: int, y_id: int | None = None,
                 w_id: int | None = None, absorb=()):
        super().__init__(kernel, seg.block_rows)
        self.plan = plan
        self.node = node
        self.x_id = x_id
        self.y_id = y_id
        self.w_id = w_id
        # ``absorb``: segment nodes the KERNEL computes itself (the wgram
        # reweighting mapply) — they must not be evaluated generically,
        # since they read the sparse leaf.
        self.prefix = tuple(n for n in seg.nodes
                            if n is not node and n not in absorb)

    def describe(self) -> str:
        return f"pallas:{self.kernel} root={self.node.name}"

    def run(self, values, partials, smalls, offset):
        from ..kernels import spmm
        for n in self.prefix:
            blocks = [smalls[self.plan._small_pos[id(p)]]
                      if isinstance(p, Small) else values[p.id]
                      for p in n.parents]
            values[n.id] = n.block_eval(blocks, offset)
        x = values[self.x_id]
        if not isinstance(x, SparseBlock):
            # Densified between tracing and execution (a tier move): the
            # plan cache keys on the source's sparse signature, so this is
            # a defensive fallback, not a hot path.
            part = self._dense_part(x, values)
        elif self.kernel == "spmm_gram":
            part = spmm.spmm_gram(x.cols, x.vals, ncol=x.ncol)
        elif self.kernel == "spmm_xty":
            y = values[self.y_id]
            part = spmm.spmm_xty(x.cols, x.vals, y, ncol=x.ncol)
        else:
            w = values[self.w_id]
            part = spmm.spmm_wgram(x.cols, x.vals, w, ncol=x.ncol)
        self._merge(partials, self.node, part)

    def _dense_part(self, x, values):
        x = x.astype(jnp.float32)
        if self.kernel == "spmm_gram":
            return x.T @ x
        if self.kernel == "spmm_xty":
            return x.T @ values[self.y_id].astype(jnp.float32)
        w = values[self.w_id].astype(jnp.float32).reshape(-1, 1)
        return (x * w).T @ x


# ---------------------------------------------------------------------------
# xla backend
# ---------------------------------------------------------------------------

class XlaBackend(Backend):
    """Generic traced lowering: XLA performs the cache-level fusion."""

    name = "xla"

    def lower(self, plan, ir) -> LoweredProgram:
        # The epilogue segment is not a partition unit: LoweredProgram
        # compiles it into the separate post-merge callable.
        units = [GenericUnit(plan, seg) for seg in ir.segments
                 if seg.kind != "epilogue"]
        return LoweredProgram(plan, ir, self.name, units)


# ---------------------------------------------------------------------------
# pallas backend
# ---------------------------------------------------------------------------

def _f32_acc(node) -> bool:
    return dtypes.canon(node.acc_dtype) == jnp.dtype(jnp.float32)


def _sparse_leaf(p) -> bool:
    """True when an operand is a leaf over a sparse-tier store — its staged
    partition block arrives as a SparseBlock, which the dense kernels must
    never consume."""
    mat = getattr(p, "mat", None)
    return (mat is not None
            and getattr(getattr(mat, "store", None), "sparse", False))


def _decline(reasons, seg, msg: str):
    """Record why a matcher passed on a segment it inspected (ISSUE 10):
    ``dispatch_report`` replays the matchers with a ``reasons`` dict and
    renders these next to the generic-trace fallback, so sparse-vs-dense
    dispatch decisions are auditable in ``fm.explain``.  ``lower()`` calls
    the matchers without the dict — declining stays free on the hot path."""
    if reasons is not None:
        reasons.setdefault(seg.sid, []).append(msg)


def _source_key(node: Node):
    """Identity of the data a node's partition block carries.  Distinct
    LeafNodes over one physical matrix (each GenOp call wraps its own leaf)
    must compare equal so their chains fuse into one kernel read."""
    mat = getattr(node, "mat", None)
    if mat is not None:
        return ("leaf", id(mat))
    return ("node", node.id)


def _same_source(a: Node, b: Node) -> bool:
    return a is b or _source_key(a) == _source_key(b)


def _is_pure_unary_chain(seg):
    """segment = [sapply*, sink]: returns the unary-name tuple source→sink,
    or None when the absorbed chain is not a linear unary pipeline."""
    names = []
    expect = seg.nodes[-1].parents[0]  # the sink's operand, walking upward
    for n in reversed(seg.nodes[:-1]):
        if n is not expect or n.kind != "sapply":
            return None
        names.append(n.fn_info["vudf"].name)
        expect = n.parents[0]
    if isinstance(expect, Small):
        return None
    return tuple(reversed(names))


def _match_spmm(plan, ir, claimed, reasons=None):
    """Sparse-ELL contraction → kernels.spmm — runs BEFORE the dense
    contraction matchers so a SparseBlock operand is never fed to the dense
    gram/xty/wgram kernels.  Three shapes:

    * ``crossprod(Xs)``         — len-1 segment, both operands one sparse
      leaf → ``spmm_gram``;
    * ``crossprod(Xs * w, Xs)`` — the absorbed ``mapply_col`` reweighting
      of the contraction's own sparse source → ``spmm_wgram`` (the sparse
      IRLS XᵀWX hot spot);
    * ``crossprod(Xs, Y)``      — sparse left against a dense right; the
      segment may have absorbed a row-local prefix computing Y (IRLS
      XᵀWz's ``w·z``), which the unit evaluates generically first.
    """
    units = {}
    for seg in ir.segments:
        if seg.sid in claimed or seg.kind != "contraction":
            continue
        node = seg.root
        if not isinstance(node, InnerProdContractNode):
            continue
        left, right = node.parents
        l_sp, r_sp = _sparse_leaf(left), _sparse_leaf(right)
        if not (l_sp or r_sp):
            continue
        if node.mul.name != "mul" or node.add.name != "sum":
            _decline(reasons, seg,
                     f"sparse operand under a ({node.mul.name},"
                     f"{node.add.name}) semiring: spmm covers (mul,sum) "
                     "only")
            continue
        if not _f32_acc(node):
            _decline(reasons, seg, "sparse operand with 64-bit "
                     "accumulation: spmm kernels accumulate in f32")
            continue
        if len(seg.nodes) == 1:
            if l_sp and r_sp and _same_source(left, right):
                claimed.add(seg.sid)
                units[seg.sid] = SpmmUnit("spmm_gram", node, plan=plan,
                                          seg=seg, x_id=left.id)
                continue
            if l_sp and r_sp:
                _decline(reasons, seg, "two distinct sparse operands: "
                         "spmm expects one sparse source")
                continue
            if l_sp and not isinstance(right, Small) \
                    and dtypes.is_floating(right.dtype):
                claimed.add(seg.sid)
                units[seg.sid] = SpmmUnit("spmm_xty", node, plan=plan,
                                          seg=seg, x_id=left.id,
                                          y_id=right.id)
                continue
            _decline(reasons, seg,
                     "sparse right operand: spmm computes sparseᵀ·dense "
                     "(put the sparse matrix on the left)" if r_sp else
                     "sparse left against a non-float right operand")
            continue
        # Multi-node segment: the wgram shape, or an absorbed dense prefix
        # computing the right operand of an xty.
        if len(seg.nodes) == 2:
            m = seg.nodes[0]
            other = right if left is m else left if right is m else None
            if (isinstance(m, MapNode) and m.kind == "mapply_col"
                    and m.fn_info["vudf"].name == "mul"
                    and other is not None
                    and not isinstance(other, Small)):
                xx, ww = m.parents
                if (_sparse_leaf(xx) and not _sparse_leaf(ww)
                        and not isinstance(ww, Small)
                        and _same_source(xx, other)
                        and dtypes.is_floating(ww.dtype)):
                    claimed.add(seg.sid)
                    units[seg.sid] = SpmmUnit("spmm_wgram", node, plan=plan,
                                              seg=seg, x_id=xx.id,
                                              w_id=ww.id, absorb=(m,))
                    continue
        if l_sp and not isinstance(right, Small):
            prefix = [n for n in seg.nodes if n is not node]
            if any(_sparse_leaf(p) for n in prefix for p in n.parents):
                _decline(reasons, seg, "absorbed prefix reads the sparse "
                         "source: spmm feeds the leaf to the kernel "
                         "unseen")
                continue
            claimed.add(seg.sid)
            units[seg.sid] = SpmmUnit("spmm_xty", node, plan=plan, seg=seg,
                                      x_id=left.id, y_id=right.id)
            continue
        _decline(reasons, seg, "sparse contraction shape not covered by "
                 "spmm (gram / xty / weighted-gram)")
    return units


def _match_contractions(plan, ir, claimed, reasons=None):
    from ..kernels import common as kcommon  # noqa: F401  (import check)
    units = {}
    for seg in ir.segments:
        if seg.sid in claimed or seg.kind != "contraction":
            continue
        node = seg.root
        if len(seg.nodes) != 1 or not isinstance(node, InnerProdContractNode):
            continue
        if any(_sparse_leaf(p) for p in node.parents):
            _decline(reasons, seg, "sparse operand: dense gram/xty "
                     "kernels read dense tiles")
            continue
        if node.mul.name != "mul" or node.add.name != "sum":
            _decline(reasons, seg,
                     f"({node.mul.name},{node.add.name}) semiring: "
                     "gram/xty cover (mul,sum) only")
            continue
        if not _f32_acc(node):
            continue  # f64 accumulation: the generic trace keeps full precision
        if any(isinstance(p, Small) for p in node.parents):
            _decline(reasons, seg, "small broadcast operand: nothing to "
                     "stream through the contraction kernel")
            continue
        if not all(dtypes.is_floating(p.dtype) for p in node.parents):
            _decline(reasons, seg, "non-float operand: gram/xty are "
                     "MXU (floating) kernels")
            continue
        claimed.add(seg.sid)
        units[seg.sid] = ContractionUnit(node, seg.block_rows)
    return units


def _match_weighted_gram(plan, ir, claimed, reasons=None):
    """crossprod(X * w, X) — a contraction segment that absorbed exactly one
    ``mapply_col(·, ·, mul)`` reweighting of the contraction's own source —
    → kernels.wgram.  XᵀWX is symmetric in which operand carries the
    weights, so both orientations match."""
    units = {}
    for seg in ir.segments:
        if seg.sid in claimed or seg.kind != "contraction":
            continue
        if len(seg.nodes) != 2:
            continue
        m, node = seg.nodes
        if not isinstance(node, InnerProdContractNode) or \
                not isinstance(m, MapNode) or m.kind != "mapply_col":
            continue
        if any(_sparse_leaf(p) for p in node.parents + m.parents):
            _decline(reasons, seg, "sparse operand: the dense wgram "
                     "kernel reads dense tiles")
            continue
        if node.mul.name != "mul" or node.add.name != "sum":
            continue
        if m.fn_info["vudf"].name != "mul":
            _decline(reasons, seg, "absorbed mapply_col is not a mul "
                     "reweighting: not the XᵀWX shape")
            continue
        if not _f32_acc(node):
            continue
        left, right = node.parents
        other = right if left is m else left if right is m else None
        if other is None or isinstance(other, Small):
            continue
        xx, ww = m.parents
        if isinstance(xx, Small) or isinstance(ww, Small):
            continue
        if not _same_source(xx, other):
            _decline(reasons, seg, "reweighted matrix differs from the "
                     "contraction's other operand: not XᵀWX")
            continue  # weights against a different matrix: not XᵀWX
        if not all(dtypes.is_floating(p.dtype) for p in (xx, ww, other)):
            continue
        claimed.add(seg.sid)
        units[seg.sid] = WeightedGramUnit(node, xx.id, ww.id, seg.block_rows)
    return units


def _chain_acc_dtype(node) -> str | None:
    """Kernel accumulator dtype for an agg.col sink, or None if ineligible.

    Float accumulation runs in f32 (f64 keeps the generic trace's full
    precision); integer accumulation runs in i32 — EXACT for integer
    sums/counts, unlike the old f32-only kernel, which is what makes int
    apply→agg chains eligible (ROADMAP item)."""
    acc = dtypes.canon(node.acc_dtype)
    if acc == jnp.dtype(jnp.float32):
        return "float32"
    if acc.kind == "i":
        return "int32"
    return None


def _chain_source_ok(source) -> bool:
    """int64/f64 stay on the generic trace (no TPU-native 64-bit); bool
    sources have no meaningful sum/min/max algebra in the kernel."""
    dt = dtypes.canon(source.dtype)
    return dt.kind in ("i", "f") and dt.itemsize <= 4


def _match_apply_agg(plan, ir, claimed, reasons=None):
    _AGG_MAP = {"sum": "sum", "min": "min", "max": "max",
                "count": "count", "count_nonzero": "count_nonzero"}
    from ..kernels.fused_apply_agg import CHAIN_UNARIES
    # Group eligible chains by their shared source so N statistics become
    # one kernel call (one read of X).  Chains carry a per-chain accumulator
    # dtype, so float stats and exact integer counts share the call.
    by_source: dict[int, list] = {}
    for seg in ir.segments:
        if seg.sid in claimed or seg.kind != "sink_update":
            continue
        node = seg.root
        if node.kind != "agg_col" or node.agg.name not in _AGG_MAP:
            continue
        acc = _chain_acc_dtype(node)
        if acc is None:
            continue
        unaries = _is_pure_unary_chain(seg)
        if unaries is None or any(u not in CHAIN_UNARIES for u in unaries):
            continue
        source = seg.nodes[0].parents[0]
        if isinstance(source, Small) or not _chain_source_ok(source):
            continue
        if _sparse_leaf(source):
            _decline(reasons, seg, "sparse source: fused_apply_agg "
                     "streams dense tiles (implicit zeros participate "
                     "in the reduction via the generic trace)")
            continue
        by_source.setdefault(_source_key(source), []).append(
            (seg, source.id, (unaries, _AGG_MAP[node.agg.name], acc)))
    units = {}
    for entries in by_source.values():
        segs = [seg for seg, _, _ in entries]
        chains = tuple(chain for _, _, chain in entries)
        for seg in segs:
            claimed.add(seg.sid)
        units[segs[0].sid] = ApplyAggUnit(
            entries[0][1], chains, [seg.root for seg in segs],
            min(seg.block_rows for seg in segs))
    return units


def _single_node_seg(ir, node, kind=None):
    for seg in ir.segments:
        if seg.root is node and len(seg.nodes) == 1:
            if kind is None or seg.kind == kind:
                return seg
    return None


def _match_kmeans(plan, ir, claimed, reasons=None):
    """distances (squared_diff,sum) → which.min labels → groupby sums
    [+ counts, + wss] → kernels.kmeans_assign."""
    units = {}
    value_roots = {n.id for n in plan.row_local_roots + plan.saves}
    for seg in ir.segments:
        if seg.sid in claimed or seg.kind != "row_local":
            continue
        labels = seg.root
        if (len(seg.nodes) != 1 or not isinstance(labels, MapNode)
                or labels.kind != "agg_row"
                or labels.fn_info["vudf"].name != "which.min"):
            continue
        d = labels.parents[0]
        if (isinstance(d, Small) or not isinstance(d, MapNode)
                or d.kind != "matmul_small"
                or d.fn_info["mul"].name != "squared_diff"
                or d.fn_info["add"].name != "sum"
                or d.id in value_roots):
            continue
        x = d.parents[0]
        centers = d.parents[1]
        if (isinstance(x, Small) or not isinstance(centers, Small)
                or not dtypes.is_floating(x.dtype)
                or dtypes.canon(x.dtype) == jnp.dtype(jnp.float64)):
            continue
        if _sparse_leaf(x):
            _decline(reasons, seg, "sparse source: kmeans_assign reads "
                     "dense tiles")
            continue
        d_seg = _single_node_seg(ir, d)
        if d_seg is None or d_seg.sid in claimed:
            continue

        # Consumers of d: labels (+ optionally rowMins feeding the wss sink).
        d_consumers = ir.consumers.get(d.id, [])
        mind = None
        ok = True
        for c in d_consumers:
            if c is labels:
                continue
            if (isinstance(c, MapNode) and c.kind == "agg_row"
                    and c.fn_info["vudf"].name == "min" and mind is None
                    and c.id not in value_roots):
                mind = c
            else:
                ok = False
        if not ok:
            continue

        # Consumers of labels: the groupby sums sink (+ optionally counts).
        lab_consumers = ir.consumers.get(labels.id, [])
        sums = counts = None
        for c in lab_consumers:
            if (isinstance(c, GroupByRowNode) and c.agg.name == "sum"
                    and _same_source(c.parents[0], x)
                    and c.parents[1] is labels
                    and _f32_acc(c) and sums is None):
                sums = c
            elif (isinstance(c, GroupByRowNode) and c.agg.name == "count"
                  and c.parents[0] is labels and c.parents[1] is labels
                  and counts is None):
                counts = c
            else:
                ok = False
        if not ok or sums is None:
            continue
        sums_seg = _single_node_seg(ir, sums, "sink_update")
        counts_seg = (_single_node_seg(ir, counts, "sink_update")
                      if counts is not None else None)
        if sums_seg is None or (counts is not None and counts_seg is None):
            continue
        if counts is not None and sums.num_groups != counts.num_groups:
            continue

        # wss: AggFullNode(sum) exclusively over mind, absorbed in one seg.
        wss = wss_seg = None
        if mind is not None:
            mind_consumers = ir.consumers.get(mind.id, [])
            if (len(mind_consumers) == 1
                    and isinstance(mind_consumers[0], AggFullNode)
                    and mind_consumers[0].agg.name == "sum"
                    and _f32_acc(mind_consumers[0])):
                wss = mind_consumers[0]
                for s in ir.segments:
                    if s.root is wss and [n.id for n in s.nodes] == \
                            [mind.id, wss.id]:
                        wss_seg = s
            if wss is None or wss_seg is None or wss_seg.sid in claimed:
                continue  # mind exists but doesn't fold into the kernel

        group = [seg, d_seg, sums_seg] + \
            [s for s in (counts_seg, wss_seg) if s is not None]
        if any(s.sid in claimed for s in group):
            continue
        for s in group:
            claimed.add(s.sid)
        units[min(s.sid for s in group)] = KMeansUnit(
            x_id=x.id, centers_pos=plan._small_pos[id(centers)],
            labels=labels, sums=sums, counts=counts, wss=wss,
            block_rows=d_seg.block_rows)
    return units


def dispatch_report(plan, ir, backend: str) -> dict[int, str]:
    """Per-segment dispatch decision, for ``fm.explain``: replay the
    backend's matcher pipeline over a pass's IR (claiming but not lowering)
    and say which kernel claimed each segment — or why it falls back to the
    generic trace.  ``plan`` is the per-pass schedule the segments belong
    to (fusion.PassSchedule, or a one-pass Plan)."""
    backend = resolve_backend(backend)
    report: dict[int, str] = {}
    claimed: set[int] = set()
    reasons: dict[int, list] = {}
    if backend == "pallas":
        for matcher in PallasBackend.MATCHERS:
            before = set(claimed)
            placed = matcher(plan, ir, claimed, reasons=reasons)
            kernels = sorted({u.kernel for u in placed.values()})
            mname = matcher.__name__.lstrip("_")
            for sid, unit in placed.items():
                report[sid] = f"pallas:{unit.kernel} (claimed by {mname})"
            for sid in claimed - before:
                if sid not in placed:
                    # A member of a multi-segment kernel unit (the k-means
                    # group, sibling apply→agg chains folded into one call).
                    report[sid] = (f"fused into pallas:{'/'.join(kernels)} "
                                   f"(claimed by {mname})")
    for seg in ir.segments:
        if seg.sid in report:
            continue
        if seg.kind == "epilogue":
            report[seg.sid] = "post-merge epilogue (single launch per pass)"
        elif backend != "pallas":
            report[seg.sid] = "xla generic trace"
        elif dtypes.canon(seg.dtype).itemsize >= 8:
            report[seg.sid] = ("generic trace (64-bit dtype: kernels keep "
                               "full precision on the XLA path)")
        elif seg.sid in reasons:
            # ISSUE 10: say WHY every matcher that inspected the segment
            # passed on it — auditable sparse-vs-dense dispatch.
            why = "; ".join(dict.fromkeys(reasons[seg.sid]))
            report[seg.sid] = f"generic trace (declined: {why})"
        else:
            report[seg.sid] = "generic trace (no kernel pattern matched)"
    return report


class PallasBackend(Backend):
    """Lower eligible segments onto the Pallas kernels; generic fallback
    for the rest.  Matchers run in order and claim segments by sid."""

    name = "pallas"
    # _match_spmm runs before the dense contraction matchers: a sparse
    # segment either lowers onto the spmm kernels or records why not —
    # the dense kernels never see a SparseBlock operand.
    MATCHERS = [_match_kmeans, _match_spmm, _match_weighted_gram,
                _match_contractions, _match_apply_agg]

    def lower(self, plan, ir) -> LoweredProgram:
        claimed: set[int] = set()
        placed: dict[int, object] = {}
        for matcher in self.MATCHERS:
            placed.update(matcher(plan, ir, claimed))
        units = []
        for seg in ir.segments:
            if seg.kind == "epilogue":
                continue  # post-merge math: LoweredProgram.epilogue, once
            if seg.sid in placed:
                units.append(placed[seg.sid])
            elif seg.sid not in claimed:
                units.append(GenericUnit(plan, seg))
        return LoweredProgram(plan, ir, self.name, units)


register_backend("xla", XlaBackend())
register_backend("pallas", PallasBackend())
