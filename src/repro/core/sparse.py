"""Sparse row blocks (the CSR tier's in-flight representation).

FlashR's flagship workload — logistic regression over the one-hot Criteo
set (``fm.as.factor`` on 26 hash columns) — is sparse: a row of the design
matrix has 26 ones among ~2^20 columns.  Storing it dense is 5 orders of
magnitude of wasted SSD bandwidth, and the paper's whole premise is that
these workloads are I/O bound.

The disk format is CSR (storage/sparse.py: indptr/indices/data sections,
row-partition addressable).  What flows through the engine per partition
is this module's ``SparseBlock``: a fixed-width ELL slab —

    cols  int32  (rows, kmax)     column index of each stored element
    vals  dtype  (rows, kmax)     the element values
    ncol  static                  the logical column count

padded per row with (col=0, val=0) entries, which are NEUTRAL for every
implicit-zero GenOp (sum-product contraction, colsum scatter, gather
matmul).  ELL rather than raggedy CSR because the executor jit-compiles
one partition step and reuses it for every partition: a fixed (rows, kmax)
structure keeps the trace static, with ``kmax`` = the matrix-wide maximum
row population so every partition shares one shape.

``SparseBlock`` is a registered jax pytree, so it rides the existing
staging machinery (device_put, donation, sharding-free mesh streams)
without the executor special-casing anything but the math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import dtypes


@jax.tree_util.register_pytree_node_class
class SparseBlock:
    """One I/O-level partition of a sparse matrix in ELL layout."""

    __slots__ = ("cols", "vals", "ncol")

    def __init__(self, cols, vals, ncol: int):
        self.cols = cols
        self.vals = vals
        self.ncol = int(ncol)

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.cols, self.vals), self.ncol

    @classmethod
    def tree_unflatten(cls, ncol, leaves):
        return cls(leaves[0], leaves[1], ncol)

    # -- array-ish surface (what the executor's bookkeeping touches) --------
    @property
    def shape(self) -> tuple:
        return (int(self.cols.shape[0]), self.ncol)

    @property
    def kmax(self) -> int:
        return int(self.cols.shape[1])

    @property
    def dtype(self):
        return dtypes.canon(self.vals.dtype)

    @property
    def nbytes(self) -> int:
        return int(self.cols.nbytes) + int(self.vals.nbytes)

    @property
    def ndim(self) -> int:
        return 2

    def __repr__(self):
        return (f"SparseBlock({self.shape[0]}x{self.ncol}, "
                f"kmax={self.kmax}, {self.dtype.name})")

    # -- densify (the generic-trace fallback's choke point) -----------------
    def todense(self):
        """Expand to a dense (rows, ncol) array.

        Padding entries are (col=0, val=0): scatter-ADD is safe because a
        zero value contributes nothing wherever it lands.  numpy in → numpy
        out (host tier); jax in → jax out (traceable inside a jit step).
        """
        rows, kmax = self.cols.shape
        if isinstance(self.vals, np.ndarray):
            out = np.zeros((rows, self.ncol), self.vals.dtype)
            r = np.repeat(np.arange(rows), kmax)
            np.add.at(out, (r, np.asarray(self.cols).reshape(-1)),
                      np.asarray(self.vals).reshape(-1))
            return out
        r = jax.lax.broadcasted_iota(jnp.int32, (rows, kmax), 0)
        out = jnp.zeros((rows, self.ncol), self.vals.dtype)
        return out.at[r, self.cols].add(self.vals)

    def matmul_small(self, small, out_dtype=None):
        """X @ B for a small dense B (ncol, q) WITHOUT densifying X: a
        per-element gather of B's rows followed by a kmax-reduction —
        out[i, j] = Σ_k vals[i, k] · B[cols[i, k], j].  nnz-proportional
        work, the sparse fast path of ``matmul_small`` (eta = X @ beta)."""
        acc = jnp.float32 if self.vals.dtype == jnp.bfloat16 else self.vals.dtype
        gathered = jnp.take(small, self.cols, axis=0)        # (rows, kmax, q)
        out = (self.vals[:, :, None].astype(acc)
               * gathered.astype(acc)).sum(axis=1)
        return out.astype(out_dtype) if out_dtype is not None else out


def is_sparse(x) -> bool:
    return isinstance(x, SparseBlock)


def is_sparse_mat(mat) -> bool:
    """True for a physical FMMatrix whose store serves SparseBlocks."""
    store = getattr(mat, "store", None)
    return bool(store is not None and getattr(store, "sparse", False))


def effective_ncol(mat) -> int:
    """The streaming width the partition planner should budget for.

    A sparse source moves 2·kmax scalars per row (cols + vals), not ncol —
    budgeting the one-hot Criteo matrix at ncol = 2^20 would shrink I/O
    partitions to single-digit rows.  Dense matrices budget at ncol."""
    store = getattr(mat, "store", None)
    if store is not None and getattr(store, "sparse", False):
        return max(1, 2 * int(store.max_row_nnz))
    return mat.ncol


# ---------------------------------------------------------------------------
# Construction helpers (host-side numpy: ingest / stores / oracles)
# ---------------------------------------------------------------------------

def ell_from_csr_rows(indptr, indices, data, start: int, stop: int,
                      kmax: int, ncol: int) -> SparseBlock:
    """Slice CSR rows [start, stop) into an ELL SparseBlock (numpy)."""
    rows = stop - start
    rs, re = int(indptr[start]), int(indptr[stop])
    counts = np.diff(indptr[start:stop + 1]).astype(np.int64)
    cols = np.zeros((rows, kmax), np.int32)
    vals = np.zeros((rows, kmax), data.dtype)
    if re > rs:
        row_of = np.repeat(np.arange(rows), counts)
        pos = np.arange(re - rs) - np.repeat(indptr[start:stop] - rs, counts)
        cols[row_of, pos] = indices[rs:re]
        vals[row_of, pos] = data[rs:re]
    return SparseBlock(cols, vals, ncol)


def csr_from_dense(arr):
    """Dense (n, p) numpy array → (indptr, indices, data) CSR triplet."""
    arr = np.asarray(arr)
    mask = arr != 0
    counts = mask.sum(axis=1)
    indptr = np.zeros(arr.shape[0] + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    r, c = np.nonzero(mask)
    return indptr, c.astype(np.int32), np.ascontiguousarray(arr[r, c])


def csr_from_ell(cols, vals):
    """ELL slab → CSR triplet, dropping the (col=0, val=0) padding.
    Boolean masking walks row-major, so entries stay grouped by row in
    within-row ELL order."""
    cols = np.asarray(cols)
    vals = np.asarray(vals)
    mask = vals != 0
    counts = mask.sum(axis=1)
    indptr = np.zeros(cols.shape[0] + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, cols[mask].astype(np.int32), np.ascontiguousarray(
        vals[mask])
