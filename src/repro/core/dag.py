"""Expression DAG for lazy evaluation (paper §III-E).

A lazily evaluated GenOp outputs a *virtual matrix* capturing the
computation and references to its input matrices.  The DAG has two node
classes, exactly as the paper's Fig. 5 distinguishes:

* **row-local nodes** ("the first type ... generates matrices with the same
  long dimension size as the input matrices") — sapply/mapply/mapply.row/
  mapply.col/agg.row-on-tall/cbind/inner-product-with-a-small-matrix.
  These fuse: partition *i* of the output needs only partitions *i* of the
  parents, so an entire chain streams through the fast tier one partition at
  a time.
* **sink nodes** ("the second type ... generates matrices with different
  long dimension sizes") — agg/agg.col-on-tall/groupby.row/inner-product
  contracting the long dimension.  Sinks produce per-partition *partials*
  merged with the aggregation VUDF's ``combine`` (paper §III-F: "each thread
  computes partial aggregation results independently ... in the end,
  FlashMatrix merges the partial aggregation results").

Classification is by actual long-dimension algebra, not by operator name:
``fm.agg.row`` on a tall matrix keeps the long dimension (an n-vector), so
it is row-local and fusable; ``fm.agg.col`` on the same matrix contracts the
long dimension and is a sink.

All virtual matrices in one DAG share the same long dimension (paper
§III-E), which `fusion.Plan` validates.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import dtypes, vudf as vudf_mod
from .matrix import FMMatrix, DenseStore
from .sparse import SparseBlock

_ids = itertools.count()

#: Ops that only ever run in the plan EPILOGUE (post-sink small-tier math):
#: they are classified post-sink even when their operands are physical, so
#: e.g. ``solve`` is never streamed through the partition loop.
EPILOGUE_ONLY_KINDS = frozenset({"solve"})


# ---------------------------------------------------------------------------
# Operands
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Small:
    """A broadcast operand: a scalar or a small physical array that is
    replicated to every partition (the paper's computation-node "immutable
    computation state, such as scalar variables and small matrices")."""

    value: Any  # python scalar or jnp array

    @property
    def dtype(self):
        if hasattr(self.value, "dtype"):
            return dtypes.canon(self.value.dtype)
        if isinstance(self.value, bool):
            return jnp.dtype(jnp.bool_)
        if isinstance(self.value, int):
            return jnp.dtype(jnp.int64)
        return jnp.dtype(jnp.float32)


Operand = Union["Node", Small]


# ---------------------------------------------------------------------------
# Nodes
# ---------------------------------------------------------------------------

class Node:
    """Base DAG node.  ``shape`` is the logical (nrow, ncol) of the output;
    row-local nodes always have nrow == the DAG's long dimension."""

    kind: str = "?"

    def __init__(self, shape, dtype, parents: Sequence[Operand], name=""):
        self.id = next(_ids)
        self.shape = (int(shape[0]), int(shape[1]))
        self.dtype = dtypes.canon(dtype)
        self.parents = list(parents)
        self.name = name or f"{self.kind}#{self.id}"
        # Materialization control (paper: fm.set.mate.level / write-through
        # cache).  None = stay virtual; 'device' | 'host' | 'disk' = persist
        # the materialized partitions during the next DAG execution ('disk'
        # streams them into an on-disk matrix — write-through spill).
        self.save: Optional[str] = None

    # Row-local nodes implement block_eval; sinks implement the
    # identity/update/combine/finalize quartet.
    @property
    def is_sink(self) -> bool:
        return False

    @property
    def nrow(self):
        return self.shape[0]

    @property
    def ncol(self):
        return self.shape[1]

    def parent_nodes(self):
        return [p for p in self.parents if isinstance(p, Node)]

    def flops_per_row(self) -> float:
        """FLOPs per long-dim element — feeds the complexity/roofline counters."""
        return 0.0

    def __repr__(self):
        return f"<{self.kind} {self.name} {self.shape} {self.dtype.name}>"


class LeafNode(Node):
    kind = "leaf"

    def __init__(self, mat: FMMatrix):
        super().__init__(mat.shape, mat.dtype, [], name=mat.name or None)
        self.mat = mat

    def block_eval(self, blocks, offset):
        raise AssertionError("leaves are sliced by the executor, not evaluated")


class MapNode(Node):
    """Row-local computation node.  ``op`` dispatches the eval rule."""

    def __init__(self, op: str, shape, dtype, parents, fn_info, name=""):
        self.kind = op
        super().__init__(shape, dtype, parents, name)
        self.fn_info = fn_info  # op-specific payload (VUDFs, axes, ...)

    def flops_per_row(self) -> float:
        info = self.fn_info
        op = self.kind
        if op in ("sapply", "mapply", "mapply_row", "mapply_col"):
            return info["vudf"].flops * self.ncol
        if op == "agg_row":
            return info["vudf"].flops * self.parents[0].shape[1]
        if op == "matmul_small":
            k = self.parents[0].shape[1]
            return 2.0 * k * self.ncol  # f1+f2 per (col, k)
        if op == "groupby_col":
            return self.parents[0].shape[1]
        return 0.0

    # -- evaluation ----------------------------------------------------------
    def block_eval(self, blocks, offset):
        """blocks: list of per-parent partition arrays (Small operands appear
        as their raw values).  offset: global row offset of this partition."""
        op = self.kind
        info = self.fn_info
        if op == "sapply":
            return info["vudf"].fn(blocks[0])
        if op == "mapply":
            return info["vudf"].fn(blocks[0], blocks[1])
        if op == "mapply_row":
            # CC_ij = f(AA_ij, B_j): vector indexed by column -> broadcast row.
            v = blocks[1]
            v = v.reshape(1, -1)
            return info["vudf"].fn(blocks[0], v)
        if op == "mapply_col":
            # CC_ij = f(AA_ij, B_i): vector indexed by row -> partitioned
            # alongside the matrix (a one-column long operand).
            v = blocks[1]
            v = v.reshape(-1, 1)
            return info["vudf"].fn(blocks[0], v)
        if op == "agg_row":
            agg = info["vudf"]
            part = agg.aggregate(blocks[0], 1, 0)
            out = agg.finalize(part)
            return out.reshape(-1, 1)
        if op == "cbind":
            cols = [b if b.ndim == 2 else b.reshape(-1, 1) for b in blocks]
            return jnp.concatenate(cols, axis=1)
        if op == "matmul_small":
            return _inner_prod_block(blocks[0], blocks[1],
                                     info["mul"], info["add"], self.dtype)
        if op == "groupby_col":
            # CC_{i,k} = agg over columns j with labels[j]==k; row-local.
            agg_name = info["vudf"].name
            labels = blocks[1].reshape(-1).astype(jnp.int32)
            k = info["num_groups"]
            onehot = jax.nn.one_hot(labels, k, dtype=blocks[0].dtype)
            if agg_name in ("sum", "count", "count_nonzero"):
                base = blocks[0]
                if agg_name == "count":
                    base = jnp.ones_like(base)
                elif agg_name == "count_nonzero":
                    base = (base != 0).astype(base.dtype)
                return base @ onehot
            raise NotImplementedError(
                f"groupby_col with agg {agg_name!r}; supported: sum/count")
        if op == "solve":
            # Epilogue-only op (EPILOGUE_ONLY_KINDS): a·x = b on the merged
            # sink values.  One same-precision iterative-refinement step
            # recovers most of the accuracy the old eager float64 small-tier
            # path had; the system is p×p so the extra solve is free.
            a = blocks[0].astype(self.dtype)
            b = blocks[1]
            if b.ndim == 1:
                b = b.reshape(-1, 1)
            elif b.shape[0] != a.shape[0]:
                b = b.reshape(a.shape[0], -1)  # (1, n) vector sink → column
            b = b.astype(self.dtype)
            x = jnp.linalg.solve(a, b)
            r = b - a @ x
            return x + jnp.linalg.solve(a, r)
        raise AssertionError(f"unknown map op {op}")


def _inner_prod_block(a_blk, b_small, mul: vudf_mod.BinaryVUDF,
                      add: vudf_mod.AggVUDF, out_dtype):
    """inner.prod(tall, small): t = f1(A_ik, B_kj); C_ij = f2-reduce_k t.

    Paper §III-C: for the (mul, sum) semiring on floating types use BLAS —
    our analog is the MXU via jnp.matmul.  General semirings evaluate f1 on a
    broadcast (rows, k, ncol_out) tile; k and ncol_out are small by
    definition of this GenOp so the tile stays cache/VMEM-resident.

    A sparse (ELL) left operand with the (mul, sum) semiring takes the
    gather path — out[i,j] = Σ_k vals[i,k]·B[cols[i,k], j] — so ``X @ beta``
    over a one-hot matrix does nnz-proportional work; other semirings
    densify the block first (implicit zeros participate in e.g. a min
    reduction, so the dense evaluation is the correct semantics).
    """
    if isinstance(a_blk, SparseBlock):
        if mul.name == "mul" and add.name == "sum":
            return a_blk.matmul_small(b_small, out_dtype)
        a_blk = a_blk.todense()
    if mul.name == "mul" and add.name == "sum" and dtypes.is_floating(out_dtype):
        return jnp.matmul(a_blk, b_small).astype(out_dtype)
    t = mul.fn(a_blk[:, :, None], b_small[None, :, :])
    part = add.aggregate(t, 1, 0)
    return add.finalize(part).astype(out_dtype)


class SinkNode(Node):
    """Long-dimension-contracting node: evaluated as identity → per-partition
    update → pairwise combine → finalize."""

    @property
    def is_sink(self) -> bool:
        return True

    def identity(self):
        raise NotImplementedError

    def block_update(self, acc, blocks, offset):
        raise NotImplementedError

    def combine(self, a, b):
        raise NotImplementedError

    def finalize(self, acc):
        return acc


class AggFullNode(SinkNode):
    kind = "agg"

    def __init__(self, parent: Node, agg: vudf_mod.AggVUDF):
        out_dt = agg.out_dtype(parent.dtype)
        super().__init__((1, 1), out_dt, [parent], name=f"agg[{agg.name}]")
        self.agg = agg
        self.acc_dtype = _acc_dtype(agg, parent.dtype)

    def flops_per_row(self) -> float:
        return self.agg.flops * self.parents[0].shape[1]

    def identity(self):
        return self.agg.identity((), self.acc_dtype)

    def block_update(self, acc, blocks, offset):
        part = self.agg.aggregate(blocks[0], None, offset)
        return self.agg.combine(acc, part)

    def combine(self, a, b):
        return self.agg.combine(a, b)

    def finalize(self, acc):
        out = self.agg.finalize(acc)
        return jnp.asarray(out).reshape(1, 1)


class AggColNode(SinkNode):
    """Per-column aggregation over the long (row) dimension: C_j."""

    kind = "agg_col"

    def __init__(self, parent: Node, agg: vudf_mod.AggVUDF):
        out_dt = agg.out_dtype(parent.dtype)
        super().__init__((1, parent.ncol), out_dt, [parent],
                         name=f"agg.col[{agg.name}]")
        self.agg = agg
        self.acc_dtype = _acc_dtype(agg, parent.dtype)

    def flops_per_row(self) -> float:
        return self.agg.flops * self.parents[0].shape[1]

    def identity(self):
        return self.agg.identity((self.ncol,), self.acc_dtype)

    def block_update(self, acc, blocks, offset):
        part = self.agg.aggregate(blocks[0], 0, offset)
        return self.agg.combine(acc, part)

    def combine(self, a, b):
        return self.agg.combine(a, b)

    def finalize(self, acc):
        return self.agg.finalize(acc).reshape(1, -1)


class GroupByRowNode(SinkNode):
    """fm.groupby.row: CC_{k,j} = agg over rows i with labels[i]==k.

    The clustering/classification workhorse (paper §III-C) — and, in the LM
    stack, the combine path of MoE expert dispatch (DESIGN.md §1.4).
    """

    kind = "groupby_row"

    _AT_OPS = {"sum": "add", "count": "add", "count_nonzero": "add",
               "min": "min", "max": "max"}

    def __init__(self, parent: Node, labels: Node, agg: vudf_mod.AggVUDF,
                 num_groups: int):
        if agg.name not in self._AT_OPS:
            raise NotImplementedError(
                f"groupby.row supports {sorted(self._AT_OPS)} aggregation, "
                f"got {agg.name!r}")
        out_dt = agg.out_dtype(parent.dtype)
        super().__init__((num_groups, parent.ncol), out_dt, [parent, labels],
                         name=f"groupby.row[{agg.name}]")
        self.agg = agg
        self.num_groups = num_groups
        self.acc_dtype = _acc_dtype(agg, parent.dtype)

    def flops_per_row(self) -> float:
        return self.parents[0].shape[1]

    def identity(self):
        return self.agg.identity((self.num_groups, self.ncol), self.acc_dtype)

    def block_update(self, acc, blocks, offset):
        vals, labels = blocks[0], blocks[1].reshape(-1).astype(jnp.int32)
        if self.agg.name == "count":
            vals = jnp.ones_like(vals, self.acc_dtype)
        elif self.agg.name == "count_nonzero":
            vals = (vals != 0).astype(self.acc_dtype)
        else:
            vals = vals.astype(self.acc_dtype)
        ref = acc.at[labels]
        part = getattr(ref, self._AT_OPS[self.agg.name])(
            vals, mode="drop", unique_indices=False)
        return part

    def combine(self, a, b):
        return self.agg.combine(a, b)

    def finalize(self, acc):
        return self.agg.finalize(acc)


class InnerProdContractNode(SinkNode):
    """inner.prod contracting the long dimension: C = f2-reduce_i f1(tA_i, B_i).

    This is ``fm.inner.prod(wide, tall)`` with the wide matrix expressed as
    the lazy transpose of a long-aligned operand (the common R form
    ``t(X) %*% Y``, e.g. Gram matrices for correlation/SVD and t(R) %*% X in
    the GMM M-step).  Per partition: partial = f2-reduce over the partition's
    rows; partials combine with f2 — the exact paper decomposition, and the
    pattern the `kernels/gram.py` Pallas kernel implements on TPU.
    """

    kind = "inner_prod"

    def __init__(self, left: Node, right: Node, mul: vudf_mod.BinaryVUDF,
                 add: vudf_mod.AggVUDF):
        out_dt = add.out_dtype(mul.out_dtype(left.dtype, right.dtype))
        super().__init__((left.ncol, right.ncol), out_dt, [left, right],
                         name=f"inner[{mul.name},{add.name}]")
        self.mul, self.add = mul, add
        self.acc_dtype = _acc_dtype(add, mul.out_dtype(left.dtype, right.dtype))

    def flops_per_row(self) -> float:
        return 2.0 * self.shape[0] * self.shape[1]

    def identity(self):
        return self.add.identity(self.shape, self.acc_dtype)

    def block_update(self, acc, blocks, offset):
        a_blk, b_blk = blocks  # both (rows, p) row-aligned
        if (self.mul.name == "mul" and self.add.name == "sum"
                and dtypes.is_floating(self.acc_dtype)):
            part = jnp.matmul(a_blk.T.astype(self.acc_dtype),
                              b_blk.astype(self.acc_dtype))
            return self.add.combine(acc, part)
        t = self.mul.fn(a_blk[:, :, None], b_blk[:, None, :])
        part = self.add.aggregate(t, 0, offset)
        return self.add.combine(acc, part)

    def combine(self, a, b):
        return self.add.combine(a, b)

    def finalize(self, acc):
        return self.add.finalize(acc).astype(self.dtype)


def _acc_dtype(agg: vudf_mod.AggVUDF, in_dtype):
    """Accumulator dtype: widen low-precision floats so long streaming
    reductions keep precision (bf16 inputs accumulate in f32) — the TPU
    analog of the paper accumulating in registers wider than the data."""
    out = agg.out_dtype(in_dtype)
    if out == jnp.dtype(jnp.bfloat16):
        return jnp.dtype(jnp.float32)
    return out


# ---------------------------------------------------------------------------
# Graph utilities
# ---------------------------------------------------------------------------

def as_node(mat_or_node) -> Node:
    if isinstance(mat_or_node, Node):
        return mat_or_node
    if isinstance(mat_or_node, FMMatrix):
        if mat_or_node.is_virtual:
            return mat_or_node.node
        return LeafNode(mat_or_node)
    raise TypeError(type(mat_or_node))


def wrap(node: Node, name: str = "") -> FMMatrix:
    """Wrap a node as a virtual FMMatrix handle."""
    return FMMatrix(node.shape, node.dtype, node=node, name=name or node.name)


def toposort(roots: Sequence[Node]) -> list[Node]:
    seen: dict[int, Node] = {}
    order: list[Node] = []

    def visit(n: Node):
        if n.id in seen:
            return
        seen[n.id] = n
        for p in n.parent_nodes():
            visit(p)
        order.append(n)

    for r in roots:
        visit(r)
    return order


def schedule_passes(order: Sequence[Node], is_source, long_dim: int):
    """Multi-pass schedule of a DAG cut (paper §III-E/F generalized).

    Classifies every executable node in ``order`` (topological) as

    * ``'loop'`` — streams through the partition loop: row-local nodes and
      long-dimension-contracting sinks; or
    * ``'epi'``  — post-sink *epilogue* math evaluated once after a pass's
      partial merge (``colSums(X)/n``, ``solve(XᵀWX, XᵀWz)``, and sinks
      whose operands are themselves merged values),

    and assigns each a **pass number**.  A loop node that consumes a merged
    value (a sink or epilogue result) cannot run in the pass that produces
    it — its operand only exists after that pass's merge — so it is
    scheduled one pass later, with the merged value bound as a broadcast
    small (the FlashR ``scale(X)`` shape: pass 1 streams the moment sinks +
    epilogue, pass 2 re-streams X with the moments bound).  Pass numbers
    chain transitively, so moment-of-a-moment programs schedule as three
    passes, and so on.

    Returns ``(roles, passno)`` dicts keyed by node id.  Raises for the one
    genuinely unschedulable shape: an epilogue-only op (``solve``) over a
    streaming *intermediate*, whose value would have to be materialized.
    """
    roles: dict[int, str] = {}
    passno: dict[int, int] = {}
    for n in order:
        if is_source(n):
            continue
        has_stream = False
        stream_pass = 0
        merged_pass = -1
        for p in n.parents:
            if isinstance(p, Small):
                continue
            if is_source(p):
                if p.shape[0] == long_dim and max(p.shape) > 1:
                    has_stream = True
                continue
            if roles[p.id] == "loop" and not p.is_sink:
                has_stream = True
                stream_pass = max(stream_pass, passno[p.id])
            else:  # merged value: a sink or an epilogue node
                merged_pass = max(merged_pass, passno[p.id])
        if n.kind in EPILOGUE_ONLY_KINDS:
            for p in n.parents:
                if (isinstance(p, Node) and not is_source(p)
                        and roles[p.id] == "loop" and not p.is_sink):
                    raise ValueError(
                        f"epilogue op {n.name} consumes the streaming "
                        f"intermediate {p.name}: {n.kind} may only touch "
                        f"aggregation results, small operands or other "
                        f"epilogue values inside one DAG — materialize "
                        f"{p.name} first (it needs its own pass)")
            roles[n.id] = "epi"
            passno[n.id] = max(merged_pass, 0)
        elif not has_stream and merged_pass >= 0:
            # Small post-merge math: runs in the owning pass's epilogue.
            roles[n.id] = "epi"
            passno[n.id] = merged_pass
        else:
            roles[n.id] = "loop"
            passno[n.id] = max(stream_pass, merged_pass + 1)
    return roles, passno


def post_sink_ids(order: Sequence[Node], is_source=None) -> set:
    """Ids of nodes DOWNSTREAM of a sink within ``order`` — the plan's
    *epilogue* set (paper §III-E post-aggregation math like
    ``colSums(X) / n``).  Such a node's operands only exist after the
    partition-loop partial merge, so it cannot run inside the loop; the
    engine evaluates the whole set once, after the merge
    (fusion.Plan → lowering.LoweredProgram.epilogue).

    ``is_source`` marks cut points (previously persisted nodes count as
    sources, not sinks); epilogue-only ops (``solve``) are always post-sink.
    """
    src = is_source or (lambda n: isinstance(n, LeafNode)
                        or getattr(n, "cached_store", None) is not None)
    post: set = set()
    for n in order:
        if src(n):
            continue
        if n.kind in EPILOGUE_ONLY_KINDS or any(
                isinstance(p, Node) and not src(p)
                and (p.is_sink or p.id in post)
                for p in n.parents):
            post.add(n.id)
    return post


def long_dim_of(roots: Sequence[Node]) -> int:
    """All matrices in a DAG share one streaming dimension (paper §III-E).

    The partition axis is uniformly ROWS (shape[0]) — wide matrices are
    simply short streams (the paper handles them as transposed-tall groups;
    our lazy transpose feeds `inner_prod` the tall orientation, so by the
    time a node is in a DAG its rows are the stream)."""
    # Cut-aware walk: a previously-persisted node (cached_store) is a
    # SOURCE of this cut — its upstream DAG belongs to other plans and must
    # not constrain this plan's streaming dimension.
    seen: set = set()
    order: list[Node] = []

    def visit(n: Node):
        if n.id in seen:
            return
        seen.add(n.id)
        if getattr(n, "cached_store", None) is None:
            for p in n.parent_nodes():
                visit(p)
        order.append(n)

    for r in roots:
        visit(r)
    post = post_sink_ids(order)
    consumers: dict = {}
    for n in order:
        for p in n.parent_nodes():
            consumers.setdefault(p.id, []).append(n)
    dims = set()
    for n in order:
        if n.id in post:
            continue  # epilogue math is small-tier: exempt from streaming
        if (isinstance(n, LeafNode)
                or getattr(n, "cached_store", None) is not None):
            cons = consumers.get(n.id, [])
            if cons and all(c.id in post for c in cons):
                continue  # epilogue-only operand (e.g. a ridge eye matrix)
            if max(n.shape) > 1:
                dims.add(n.shape[0])
        elif not n.is_sink:
            dims.add(n.shape[0])
        else:
            for p in n.parent_nodes():
                if not p.is_sink and p.id not in post:
                    dims.add(p.shape[0])
    dims.discard(1)
    if len(dims) > 1:
        raise ValueError(
            f"all matrices in one DAG must share the streaming (row) "
            f"dimension; got {sorted(dims)}")
    return dims.pop() if dims else 1
