"""Plan IR: the DAG cut compiled into typed fused *segments* (paper §III-E/F).

`fusion.Plan` owns the cut, the toposort and the I/O-level partition size;
this module is the middle layer between that cut and the pluggable lowering
backends (core/lowering.py).  It groups the cut's executable nodes into
segments — the unit a backend lowers as a whole:

* ``row_local``   — a chain of row-local nodes ending at a node whose value
  must exist as an array per partition (a requested output, a save, or an
  intermediate shared by several downstream segments);
* ``sink_update`` — an aggregation sink (agg/agg.col/groupby.row) plus the
  row-local chain it exclusively consumes: the classic apply→aggregate
  fusion the paper streams through the CPU cache;
* ``contraction`` — an inner-product sink contracting the long dimension
  (Gram/XᵀY): the MXU-bound pattern;
* ``epilogue``    — the plan's post-sink lazy math (``colSums(X)/n``,
  ``solve(XᵀWX, XᵀWz)``): one segment holding every node downstream of a
  sink, compiled into the lowered program's third callable and evaluated
  exactly ONCE after the partition-loop merge (never per partition).

Each segment carries width/dtype/FLOP metadata and a **processor-level
block-row count** — the second tier of the paper's two-level partitioning
(§III-F).  The I/O-level partition (fusion.Plan.partition_rows) is the
streaming/DMA granule; the segment's ``block_rows`` is the VMEM/cache tile a
Pallas lowering sweeps inside one partition.  Both levels are part of the
compiled-plan cache key (core/materialize.py).
"""
from __future__ import annotations

import dataclasses
from typing import List

from . import dtypes
from .dag import LeafNode, Node, Small
from .matrix import proc_partition_rows
from .sparse import effective_ncol, is_sparse_mat


def _is_source(n: Node) -> bool:
    return isinstance(n, LeafNode) or getattr(n, "cached_store", None) is not None


@dataclasses.dataclass
class Segment:
    """One fused lowering unit of the plan."""

    sid: int
    kind: str   # 'row_local' | 'sink_update' | 'contraction' | 'epilogue'
    nodes: List[Node]         # topological order; nodes[-1] is the root
    root: Node
    width: int                # widest live row (elements) inside the segment
    dtype: object             # widest dtype touched by the segment
    flops_per_row: float
    n_live: int               # live arrays per row while the segment runs
    block_rows: int = 0       # processor-level (VMEM/cache) tile rows
    # nnz / (nrow·ncol) of the sparsest sparse-tier source feeding the
    # segment; 1.0 when every input is dense.  Lowering matchers use it to
    # pick SpMM kernels; explain renders it so sparse-vs-dense dispatch is
    # auditable.
    density: float = 1.0

    def describe(self) -> str:
        base = (f"seg#{self.sid} [{self.kind}] root={self.root.name} "
                f"nodes={len(self.nodes)} width={self.width} "
                f"dtype={dtypes.canon(self.dtype).name} "
                f"flops/row={self.flops_per_row:.1f} "
                f"block_rows={self.block_rows}")
        if self.density < 1.0:
            base += f" density={self.density:.2e}"
        return base


@dataclasses.dataclass
class PlanIR:
    """Segments of one DAG cut, in a valid execution order."""

    segments: List[Segment]
    long_dim: int
    # node id -> executable consumer nodes (the grouping relation; lowering
    # matchers reuse it to check a claimed intermediate has no other users).
    consumers: dict = dataclasses.field(default_factory=dict)

    def schedule_key(self) -> tuple:
        """The processor-level half of the plan-cache key: the per-segment
        block-row schedule (the I/O level is Plan.partition_rows)."""
        return tuple((s.kind, s.block_rows) for s in self.segments)

    def describe(self) -> str:
        lines = [f"PlanIR(long_dim={self.long_dim}, "
                 f"segments={len(self.segments)})"]
        lines += ["  " + s.describe() for s in self.segments]
        return "\n".join(lines)


def compile_ir(plan) -> PlanIR:
    """Compile a fusion.Plan's cut into segments and schedule their
    processor-level tiles.

    Grouping rule: a row-local node joins the segment of its consumers when
    *all* of its consumers live in one segment (so its value never needs to
    exist outside that segment); requested outputs, saves, and shared
    intermediates root their own ``row_local`` segments; every sink roots a
    ``sink_update`` / ``contraction`` segment.
    """
    epilogue_ids = getattr(plan, "epilogue_ids", set())
    exec_nodes = [n for n in plan.order
                  if not _is_source(n) and n.id not in epilogue_ids]
    pos = {n.id: i for i, n in enumerate(plan.order)}
    value_roots = {n.id for n in plan.row_local_roots + plan.saves}

    consumers: dict[int, list[Node]] = {n.id: [] for n in exec_nodes}
    for n in exec_nodes:
        seen_parents: set[int] = set()
        for p in n.parents:
            if isinstance(p, Small) or _is_source(p) or p.id in seen_parents:
                continue  # one entry per consumer (groupby uses labels twice)
            if p.id not in consumers:
                # A pass BINDING: a merged value produced by an earlier
                # pass of the same plan — an external input of this pass,
                # like a source (see fusion.PassSchedule.bindings).
                continue
            seen_parents.add(p.id)
            consumers[p.id].append(n)

    seg_of: dict[int, int] = {}
    members: dict[int, list[Node]] = {}
    roots: dict[int, Node] = {}
    kinds: dict[int, str] = {}
    next_sid = 0

    def new_segment(n: Node, kind: str) -> int:
        nonlocal next_sid
        sid = next_sid
        next_sid += 1
        seg_of[n.id] = sid
        members[sid] = [n]
        roots[sid] = n
        kinds[sid] = kind
        return sid

    for n in reversed(exec_nodes):
        if n.is_sink:
            kind = "contraction" if n.kind == "inner_prod" else "sink_update"
            new_segment(n, kind)
        elif n.id in value_roots:
            new_segment(n, "row_local")
        else:
            owner = {seg_of[c.id] for c in consumers[n.id]}
            if len(owner) == 1:
                sid = owner.pop()
                seg_of[n.id] = sid
                members[sid].append(n)
            else:
                # shared intermediate (or dead node): its value crosses
                # segment boundaries, so it roots a row_local segment.
                new_segment(n, "row_local")

    segments = []
    for sid in sorted(roots, key=lambda s: pos[roots[s].id]):
        nodes = sorted(members[sid], key=lambda n: pos[n.id])
        segments.append(_with_metadata(
            Segment(sid=len(segments), kind=kinds[sid], nodes=nodes,
                    root=roots[sid], width=1, dtype=roots[sid].dtype,
                    flops_per_row=0.0, n_live=1)))
    epi_nodes = getattr(plan, "epilogue_nodes", [])
    if epi_nodes:
        # All post-sink math is ONE segment: it executes once, after the
        # merge, so there is nothing to tile or interleave — kernels never
        # claim it (matchers filter on the loop kinds).
        segments.append(_with_metadata(
            Segment(sid=len(segments), kind="epilogue",
                    nodes=list(epi_nodes), root=epi_nodes[-1], width=1,
                    dtype=epi_nodes[-1].dtype, flops_per_row=0.0,
                    n_live=1)))
    return PlanIR(segments=segments, long_dim=plan.long_dim,
                  consumers=consumers)


def _with_metadata(seg: Segment) -> Segment:
    """Fill width/dtype/flops and schedule the processor-level tile."""
    inside = {n.id for n in seg.nodes}
    widths = [1]
    ext_inputs: set[int] = set()
    widest = seg.root.dtype
    flops = 0.0
    density = 1.0
    for n in seg.nodes:
        flops += n.flops_per_row()
        if dtypes.rank(n.dtype) > dtypes.rank(widest):
            widest = n.dtype
        if not n.is_sink:
            widths.append(n.ncol)
        for p in n.parents:
            if isinstance(p, Small):
                continue
            if dtypes.rank(p.dtype) > dtypes.rank(widest):
                widest = p.dtype
            if isinstance(p, LeafNode) and is_sparse_mat(p.mat):
                # A sparse source streams 2·kmax scalars per row, not ncol
                # — budget the tile on what actually moves.
                widths.append(effective_ncol(p.mat))
                nnz = getattr(p.mat.store, "nnz", None)
                if nnz is not None and p.mat.nrow * p.mat.ncol:
                    density = min(density,
                                  nnz / float(p.mat.nrow * p.mat.ncol))
            else:
                widths.append(p.ncol)
            if p.id not in inside:
                ext_inputs.add(p.id)
    seg.width = max(widths)
    seg.density = density
    seg.dtype = dtypes.canon(widest)
    seg.flops_per_row = flops
    # Live rows while the segment streams: every external input partition
    # plus one output/partial slot (paper §III-F working-set rule).
    seg.n_live = max(1, len(ext_inputs)) + 1
    seg.block_rows = proc_partition_rows(seg.width, seg.dtype, seg.n_live)
    return seg
