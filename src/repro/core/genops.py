"""The Generalized Matrix Operators (GenOps) — paper Table I.

    CC = fm.inner.prod(AA, BB, f1, f2)
    CC = fm.sapply(AA, f)
    CC = fm.mapply(AA, BB, f)
    CC = fm.mapply.row(AA, B, f)   # CC_ij = f(AA_ij, B_j)
    CC = fm.mapply.col(AA, B, f)   # CC_ij = f(AA_ij, B_i)
    c  = fm.agg(AA, f)
    C  = fm.agg.row(AA, f)
    C  = fm.agg.col(AA, f)
    CC = fm.groupby.row(AA, B, f)
    CC = fm.groupby.col(AA, B, f)

Every GenOp is lazy: it returns a *virtual* FMMatrix wrapping a DAG node
(paper §III-E "FlashMatrix allows lazy evaluation on all GenOps").  Nothing
computes until `fm.materialize` (core/materialize.py) walks the DAG.

Dtype mismatches insert lazy `sapply` cast nodes (paper §III-D), and scalar
operands take the bVUDF2/bVUDF3 broadcast forms automatically.
"""
from __future__ import annotations

from typing import Union

import jax.numpy as jnp
import numpy as np

from . import dtypes, vudf as vudf_mod
from .dag import (AggColNode, AggFullNode, GroupByRowNode,
                  InnerProdContractNode, LeafNode, MapNode, Node, Small,
                  as_node, wrap)
from .matrix import FMMatrix

MatLike = Union[FMMatrix, Node]


def _u(f) -> vudf_mod.UnaryVUDF:
    return vudf_mod.unary(f) if isinstance(f, str) else f


def _b(f) -> vudf_mod.BinaryVUDF:
    return vudf_mod.binary(f) if isinstance(f, str) else f


def _a(f) -> vudf_mod.AggVUDF:
    return vudf_mod.agg(f) if isinstance(f, str) else f


def _cast(node: Node, to_dtype) -> Node:
    if node.dtype == dtypes.canon(to_dtype):
        return node
    cv = vudf_mod.unary(f"cast_{dtypes.canon(to_dtype).name}")
    return MapNode("sapply", node.shape, to_dtype, [node], {"vudf": cv},
                   name=f"cast({node.name})")


def _promote2(x: Node, y: Node):
    dt = dtypes.promote(x.dtype, y.dtype)
    return _cast(x, dt), _cast(y, dt), dt


def _is_scalar(v) -> bool:
    return isinstance(v, (int, float, bool, np.number)) or (
        hasattr(v, "shape") and getattr(v, "shape", None) == ())


def _small_array(v):
    """Coerce a small operand (R vector / small matrix) to a jnp array."""
    if isinstance(v, FMMatrix):
        return jnp.asarray(v.logical_data())
    return jnp.asarray(v)


# ---------------------------------------------------------------------------
# apply family
# ---------------------------------------------------------------------------

def sapply(mat: MatLike, f) -> FMMatrix:
    """Element-wise unary: CC_ij = f(AA_ij)."""
    f = _u(f)
    x = as_node(mat)
    node = MapNode("sapply", x.shape, f.out_dtype(x.dtype), [x], {"vudf": f})
    return wrap(node)


def mapply(a: MatLike, b, f) -> FMMatrix:
    """Element-wise binary: CC_ij = f(AA_ij, BB_ij).

    A scalar operand on either side selects the bVUDF2/bVUDF3 form; for
    commutative VUDFs the optimizer may canonicalize the scalar to the right.
    """
    f = _b(f)
    if _is_scalar(b):
        x = as_node(a)
        sdt = Small(b).dtype
        dt = f.out_dtype(x.dtype, sdt)
        x = _cast(x, dtypes.promote(x.dtype, sdt))
        node = MapNode("mapply", x.shape, dt, [x, Small(b)], {"vudf": f})
        return wrap(node)
    if _is_scalar(a):
        y = as_node(b)
        sdt = Small(a).dtype
        dt = f.out_dtype(sdt, y.dtype)
        y = _cast(y, dtypes.promote(sdt, y.dtype))
        flip = vudf_mod.BinaryVUDF(f"{f.name}.sv", lambda u, v, _f=f.fn: _f(v, u),
                                   f.flops, f.dtype_rule, f.commutative)
        node = MapNode("mapply", y.shape, dt, [y, Small(a)], {"vudf": flip})
        return wrap(node)
    x, y = as_node(a), as_node(b)
    if x.shape != y.shape:
        raise ValueError(f"mapply shape mismatch: {x.shape} vs {y.shape}")
    x, y, _ = _promote2(x, y)
    node = MapNode("mapply", x.shape, f.out_dtype(x.dtype, y.dtype), [x, y],
                   {"vudf": f})
    return wrap(node)


def mapply_row(a: MatLike, vec, f) -> FMMatrix:
    """CC_ij = f(AA_ij, B_j): the vector pairs with each *row* (length ncol).

    ncol is small for TAS matrices, so the vector is broadcast state.  A
    VIRTUAL vector — ``colMeans(X)`` feeding ``X - colMeans(X)`` — stays a
    lazy DAG parent: the fusion planner schedules the sweep one pass after
    the pass that merges the vector, binding it as a broadcast small
    (the multi-pass ``scale(X)`` schedule).  Physical vectors keep the
    eager broadcast-Small form."""
    f = _b(f)
    x = as_node(a)
    if isinstance(vec, Node) or (isinstance(vec, FMMatrix) and vec.is_virtual):
        v = as_node(vec)
        if min(v.shape) != 1 or max(v.shape) != x.ncol:
            raise ValueError(
                f"mapply.row vector shape {v.shape} does not broadcast "
                f"across ncol {x.ncol}")
        xx, vv, dt = _promote2(x, v)
        node = MapNode("mapply_row", x.shape, f.out_dtype(dt, dt), [xx, vv],
                       {"vudf": f})
        return wrap(node)
    v = _small_array(vec).reshape(-1)
    if v.shape[0] != x.ncol:
        raise ValueError(f"mapply.row vector length {v.shape[0]} != ncol {x.ncol}")
    dt = dtypes.promote(x.dtype, v.dtype)
    x = _cast(x, dt)
    v = v.astype(dt)
    node = MapNode("mapply_row", x.shape, f.out_dtype(dt, dt), [x, Small(v)],
                   {"vudf": f})
    return wrap(node)


def mapply_col(a: MatLike, vec, f) -> FMMatrix:
    """CC_ij = f(AA_ij, B_i): the vector pairs with each *column* (length
    nrow == long dim), so it is partitioned alongside the matrix and may
    itself be virtual — this is what lets k-means fuse `labels` straight
    into `groupby` without materializing them."""
    f = _b(f)
    x = as_node(a)
    if isinstance(vec, (FMMatrix, Node)):
        v = as_node(vec)
        if max(v.shape) != x.nrow:
            raise ValueError(
                f"mapply.col vector length {max(v.shape)} != nrow {x.nrow}")
        xx, vv, dt = _promote2(x, v)
        node = MapNode("mapply_col", x.shape, f.out_dtype(dt, dt), [xx, vv],
                       {"vudf": f})
        return wrap(node)
    v = _small_array(vec).reshape(-1)
    if v.shape[0] != x.nrow:
        raise ValueError(f"mapply.col vector length {v.shape[0]} != nrow {x.nrow}")
    leaf = LeafNode(FMMatrix.from_array(v))
    return mapply_col(a, wrap(leaf), f)


def cbind(*mats: MatLike) -> FMMatrix:
    """Virtual column-bind of long-aligned matrices (row-local, fusable)."""
    nodes = [as_node(m) for m in mats]
    n = nodes[0].nrow
    if any(x.nrow != n for x in nodes):
        raise ValueError("cbind: row-count mismatch")
    dt = nodes[0].dtype
    for x in nodes[1:]:
        dt = dtypes.promote(dt, x.dtype)
    nodes = [_cast(x, dt) for x in nodes]
    ncol = sum(x.ncol for x in nodes)
    node = MapNode("cbind", (n, ncol), dt, nodes, {})
    return wrap(node)


# ---------------------------------------------------------------------------
# aggregation family
# ---------------------------------------------------------------------------

def agg(mat: MatLike, f) -> FMMatrix:
    """c = f-reduce over all elements (sink)."""
    f = _a(f)
    return wrap(AggFullNode(as_node(mat), f))


def agg_row(mat: MatLike, f) -> FMMatrix:
    """C_i = f-reduce over row i.  On a tall matrix this keeps the long
    dimension: row-local, fusable.  (Wide matrices: transpose first — the
    rlike layer does this automatically.)"""
    f = _a(f)
    x = as_node(mat)
    acc_needs_offset = f.name in ("which.min", "which.max")
    del acc_needs_offset  # row-reductions run over the short axis: offset 0.
    node = MapNode("agg_row", (x.nrow, 1), f.out_dtype(x.dtype), [x],
                   {"vudf": f}, name=f"agg.row[{f.name}]")
    return wrap(node)


def agg_col(mat: MatLike, f) -> FMMatrix:
    """C_j = f-reduce over column j: contracts the long dim of a tall matrix
    (sink)."""
    f = _a(f)
    return wrap(AggColNode(as_node(mat), f))


# ---------------------------------------------------------------------------
# groupby family
# ---------------------------------------------------------------------------

def groupby_row(mat: MatLike, labels: MatLike, f, num_groups: int) -> FMMatrix:
    """CC_{k,j} = f-reduce over rows i with labels_i == k (sink).

    `labels` is long-aligned and may be virtual (fuses with upstream
    computation, e.g. which.min output in k-means)."""
    f = _a(f)
    x = as_node(mat)
    lab = as_node(labels) if isinstance(labels, (FMMatrix, Node)) else \
        LeafNode(FMMatrix.from_array(_small_array(labels).reshape(-1)))
    return wrap(GroupByRowNode(x, lab, f, int(num_groups)))


def groupby_col(mat: MatLike, labels, f, num_groups: int) -> FMMatrix:
    """CC_{i,k} = f-reduce over columns j with labels_j == k (row-local)."""
    f = _a(f)
    x = as_node(mat)
    lab = _small_array(labels).reshape(-1)
    if lab.shape[0] != x.ncol:
        raise ValueError("groupby.col labels must have length ncol")
    node = MapNode("groupby_col", (x.nrow, int(num_groups)),
                   f.out_dtype(x.dtype), [x, Small(lab)],
                   {"vudf": f, "num_groups": int(num_groups)})
    return wrap(node)


# ---------------------------------------------------------------------------
# inner product
# ---------------------------------------------------------------------------

def inner_prod(a: MatLike, b, f1="mul", f2="sum") -> FMMatrix:
    """Generalized matrix multiplication: t = f1(A_ik, B_kj); C_ij = f2_k t.

    Two optimized cases (paper §III-C):
      * tall (n×p) · small (p×q)  -> tall (n×q): row-local, fusable;
      * wide (p×n) · tall (n×q)   -> small (p×q): contracts the long dim
        (sink).  The wide operand must be the lazy transpose ``t(X)`` of a
        long-aligned matrix — the R idiom ``t(X) %*% Y`` — or a small
        physical matrix.
    """
    f1, f2 = _b(f1), _a(f2)

    a_is_fm = isinstance(a, (FMMatrix, Node))
    a_t = a.transposed_of if isinstance(a, FMMatrix) else None

    if a_is_fm and a_t is not None:
        # t(X) %*% Y: contract the streaming (row) dimension -> sink.
        # (X may be tall OR wide — rows are the stream either way.)
        left = as_node(a_t)
        if isinstance(b, (FMMatrix, Node)):
            right = as_node(b)
        else:
            right = LeafNode(FMMatrix.from_array(_small_array(b)))
        if left.nrow != right.nrow:
            raise ValueError(
                f"inner.prod contraction mismatch: {left.shape} x {right.shape}")
        lft, rgt, _ = _promote2(left, right)
        return wrap(InnerProdContractNode(lft, rgt, f1, f2))

    # tall · small: row-local.
    x = as_node(a)
    b_arr = _small_array(b)
    if b_arr.ndim == 1:
        b_arr = b_arr.reshape(-1, 1)
    if x.ncol != b_arr.shape[0]:
        raise ValueError(f"inner.prod shape mismatch: {x.shape} x {b_arr.shape}")
    dt = dtypes.promote(x.dtype, b_arr.dtype)
    out_dt = f2.out_dtype(f1.out_dtype(dt, dt))
    x = _cast(x, dt)
    node = MapNode("matmul_small", (x.nrow, b_arr.shape[1]), out_dt,
                   [x, Small(b_arr.astype(dt))],
                   {"mul": f1, "add": f2}, name=f"inner[{f1.name},{f2.name}]")
    return wrap(node)


# ---------------------------------------------------------------------------
# epilogue-only linear algebra
# ---------------------------------------------------------------------------

def solve(a: MatLike, b=None) -> FMMatrix:
    """Lazy R ``solve()``: a⁻¹ (b=None) or the solution x of a·x = b.

    The operands are small (p×p / p×q) — typically aggregation sinks like
    the IRLS XᵀWX / XᵀWz pair — so the node is an *epilogue* op
    (dag.EPILOGUE_ONLY_KINDS): the engine evaluates it exactly once after
    the partition-loop merge, on device, inside the same fused plan as the
    sinks it consumes (core/fusion.py epilogue stage).
    """
    x = as_node(a)
    if x.nrow != x.ncol:
        raise ValueError(f"solve needs a square matrix, got {x.shape}")
    if b is None:
        rhs: "Operand" = Small(jnp.eye(x.nrow, dtype=jnp.float32))
        rhs_ncol, rhs_dt = x.nrow, jnp.dtype(jnp.float32)
    elif isinstance(b, (FMMatrix, Node)):
        bn = as_node(b)
        if bn.nrow == x.nrow:
            rhs, rhs_ncol, rhs_dt = bn, bn.ncol, bn.dtype
        elif bn.nrow == 1 and bn.ncol == x.nrow:
            # R: a bare length-n vector is a one-column RHS; accept the
            # (1, n) sink orientation (agg.col outputs) the same way.
            rhs, rhs_ncol, rhs_dt = bn, 1, bn.dtype
        else:
            raise ValueError(
                f"solve shape mismatch: {x.shape} vs {bn.shape}")
    else:
        arr = _small_array(b)
        if arr.ndim == 1 or arr.shape[0] != x.nrow:
            arr = arr.reshape(x.nrow, -1)
        rhs = Small(arr)
        rhs_ncol, rhs_dt = arr.shape[1], arr.dtype
    dt = dtypes.to_floating(dtypes.promote(x.dtype, rhs_dt))
    node = MapNode("solve", (x.nrow, rhs_ncol), dt, [x, rhs], {},
                   name="solve")
    return wrap(node)


# ---------------------------------------------------------------------------
# materialization control (paper Table II, Control rows)
# ---------------------------------------------------------------------------

def set_mate_level(mat: FMMatrix, level: str) -> FMMatrix:
    """fm.set.mate.level: ask the next materialization to persist this
    virtual matrix ('device' = HBM tier, 'host' = RAM tier, 'disk' = spill
    the output write-through into an on-disk matrix, repro/storage/)."""
    if not mat.is_virtual:
        return mat
    if level not in ("device", "host", "disk"):
        raise ValueError(f"bad materialization level {level!r}")
    mat.node.save = level
    return mat
