"""Data pipeline substrate."""
from . import pipeline
from .pipeline import (DataConfig, DataIterator, TokenSource, ingest_binary,
                       ingest_csv)
