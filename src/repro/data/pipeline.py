"""Sharded training-data pipeline with out-of-core streaming.

The data path is where FlashMatrix's out-of-core design lands in an LM
framework: token shards live on the slow tier (disk/host memory = the SSD
analog), are memory-mapped, sliced into I/O-level chunks, staged
host→device asynchronously, and handed to the train step — double-buffered
so step N's compute overlaps step N+1's staging (the paper's I/O/compute
overlap; `jax.device_put` dispatch is async).

Determinism + fault tolerance: the iterator state is a single (epoch, step)
cursor; `state_dict()`/`load_state_dict()` round-trips through checkpoints
so a preempted job resumes exactly where it left off (runtime contract with
checkpoint/checkpoint.py).

For this repo's experiments the corpus is synthetic (seeded ziphian token
draws); `TokenSource` also reads real `.npy`/raw-u16 token shards if paths
are provided.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Iterator, Optional, Sequence

import jax
import numpy as np


@dataclasses.dataclass
class DataConfig:
    seq_len: int = 1024
    global_batch: int = 8
    vocab: int = 32000
    seed: int = 0
    shards: Optional[Sequence[str]] = None   # token files; None => synthetic
    synthetic_tokens: int = 1 << 22          # per synthetic "shard"


class TokenSource:
    """A flat token stream on the slow tier."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.shards:
            self._arrays = [np.load(p, mmap_mode="r") if str(p).endswith(".npy")
                            else np.memmap(p, dtype=np.uint16, mode="r")
                            for p in cfg.shards]
        else:
            rng = np.random.default_rng(cfg.seed)
            # Zipf-ish synthetic corpus: realistic token frequency skew.
            ranks = rng.zipf(1.3, size=cfg.synthetic_tokens)
            self._arrays = [np.minimum(ranks, cfg.vocab - 1).astype(np.int32)]
        self.total = sum(a.shape[0] for a in self._arrays)

    def window(self, start: int, length: int) -> np.ndarray:
        """Contiguous token window with wraparound (one I/O-level read)."""
        start = start % self.total
        out = np.empty(length, np.int32)
        filled = 0
        offset = start
        for a in self._arrays * 2:  # wraps at most once
            if filled == length:
                break
            n = a.shape[0]
            lo = offset % self.total
            # locate shard-local offset
            acc = 0
            for arr in self._arrays:
                if lo < acc + arr.shape[0]:
                    local = lo - acc
                    take = min(length - filled, arr.shape[0] - local)
                    out[filled:filled + take] = arr[local:local + take]
                    filled += take
                    offset += take
                    break
                acc += arr.shape[0]
        return out


class DataIterator:
    """Deterministic, resumable, device-prefetching batch iterator."""

    def __init__(self, cfg: DataConfig, *, sharding=None,
                 process_index: int = 0, process_count: int = 1):
        self.cfg = cfg
        self.source = TokenSource(cfg)
        self.step = 0
        self.sharding = sharding
        self.process_index = process_index
        self.process_count = process_count
        self._staged = None  # double buffer (the prefetch depth-1 queue)

    # -- fault-tolerance contract -------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, state: dict):
        self.step = int(state["step"])

    # -- batch construction ----------------------------------------------------
    def _host_batch(self, step: int) -> dict:
        cfg = self.cfg
        per_proc = cfg.global_batch // self.process_count
        span = cfg.seq_len + 1
        base = (step * cfg.global_batch + self.process_index * per_proc) * span
        toks = np.stack([
            self.source.window(base + i * span, span) for i in range(per_proc)])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def _stage(self, batch_np: dict):
        """Host → device, async; sharded if a sharding was provided."""
        if self.sharding is not None:
            return {k: jax.device_put(v, self.sharding[k])
                    for k, v in batch_np.items()}
        return {k: jax.device_put(v) for k, v in batch_np.items()}

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        if self._staged is None:
            self._staged = self._stage(self._host_batch(self.step))
        out = self._staged
        self.step += 1
        # prefetch the next batch while the caller computes on `out`
        self._staged = self._stage(self._host_batch(self.step))
        return out
