"""Sharded training-data pipeline with out-of-core streaming.

The data path is where FlashMatrix's out-of-core design lands in an LM
framework: token shards live on the slow tier (disk/host memory = the SSD
analog), are memory-mapped, sliced into I/O-level chunks, staged
host→device asynchronously, and handed to the train step — double-buffered
so step N's compute overlaps step N+1's staging (the paper's I/O/compute
overlap; `jax.device_put` dispatch is async).

Determinism + fault tolerance: the iterator state is a single (epoch, step)
cursor; `state_dict()`/`load_state_dict()` round-trips through checkpoints
so a preempted job resumes exactly where it left off (runtime contract with
checkpoint/checkpoint.py).

For this repo's experiments the corpus is synthetic (seeded ziphian token
draws); `TokenSource` also reads real `.npy`/raw-u16 token shards if paths
are provided.

The matrix side of the data path is `ingest_csv` / `ingest_binary` /
`ingest_factor_csv`: the FlashR `fm.load.dense.matrix` workflow
(Criteo-style — a multi-GB text or raw-binary table streamed into the
on-disk matrix format of repro/storage/format.py in bounded chunks, never
fully resident in RAM).  `ingest_factor_csv` is the sparse arm: integer
factor columns stream straight into the CSR ``.fmat`` variant as one-hot
rows (k ones per row among Σ num_levels columns) without ever forming the
dense design matrix.  Every ingest path removes its partial output file
on failure — a malformed row, a dtype mismatch or a factor-cardinality
overflow raises a clear error and leaves NO truncated ``.fmat`` behind.
"""
from __future__ import annotations

import contextlib
import dataclasses
import pathlib
from typing import Iterator, Optional, Sequence, Union

import jax
import numpy as np


@dataclasses.dataclass
class DataConfig:
    seq_len: int = 1024
    global_batch: int = 8
    vocab: int = 32000
    seed: int = 0
    shards: Optional[Sequence[str]] = None   # token files; None => synthetic
    synthetic_tokens: int = 1 << 22          # per synthetic "shard"


class TokenSource:
    """A flat token stream on the slow tier."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.shards:
            self._arrays = [np.load(p, mmap_mode="r") if str(p).endswith(".npy")
                            else np.memmap(p, dtype=np.uint16, mode="r")
                            for p in cfg.shards]
        else:
            rng = np.random.default_rng(cfg.seed)
            # Zipf-ish synthetic corpus: realistic token frequency skew.
            ranks = rng.zipf(1.3, size=cfg.synthetic_tokens)
            self._arrays = [np.minimum(ranks, cfg.vocab - 1).astype(np.int32)]
        self.total = sum(a.shape[0] for a in self._arrays)

    def window(self, start: int, length: int) -> np.ndarray:
        """Contiguous token window with wraparound (one I/O-level read)."""
        start = start % self.total
        out = np.empty(length, np.int32)
        filled = 0
        offset = start
        for a in self._arrays * 2:  # wraps at most once
            if filled == length:
                break
            n = a.shape[0]
            lo = offset % self.total
            # locate shard-local offset
            acc = 0
            for arr in self._arrays:
                if lo < acc + arr.shape[0]:
                    local = lo - acc
                    take = min(length - filled, arr.shape[0] - local)
                    out[filled:filled + take] = arr[local:local + take]
                    filled += take
                    offset += take
                    break
                acc += arr.shape[0]
        return out


# ---------------------------------------------------------------------------
# Matrix ingestion: external files → the on-disk matrix format
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def _no_partial_output(*paths):
    """Remove the named output files if the wrapped ingest fails — a bad
    source must never leave a truncated ``.fmat`` that a later
    ``get_dense_matrix`` would happily mmap."""
    try:
        yield
    except BaseException:
        for p in paths:
            pathlib.Path(p).unlink(missing_ok=True)
        raise


def ingest_csv(src, dest, *, dtype=np.float32, delimiter: str = ",",
               skip_header: int = 0, chunk_rows: int = 65536,
               layout: str = "row") -> "storage_format.MatrixHeader":
    """Stream a numeric CSV/TSV into an on-disk matrix (.fmat).

    One pass, bounded memory: ``chunk_rows`` lines are parsed and appended
    at a time, and the header (which records the final row count) is
    rewritten in place at the end — so Criteo-scale tables ingest without a
    row-counting pre-pass or a full in-RAM copy.
    """
    from ..storage import format as storage_format

    if layout == "col":
        raise NotImplementedError(
            "streaming CSV ingest writes row layout; use fm.conv_layout "
            "afterwards for col-major")
    dest = pathlib.Path(dest)
    dest.parent.mkdir(parents=True, exist_ok=True)
    dtype = np.dtype(dtype)
    ncol = None
    nrow = 0
    with _no_partial_output(dest):
        with open(src, "r") as fin, open(dest, "wb") as fout:
            for _ in range(skip_header):
                fin.readline()
            # Reserve the header block; final shape is known only at EOF.
            fout.write(b"\x00" * storage_format.HEADER_BYTES)
            while True:
                lines = []
                for line in fin:
                    if line.strip():
                        lines.append(line)
                    if len(lines) >= chunk_rows:
                        break
                if not lines:
                    break
                try:
                    chunk = np.loadtxt(lines, dtype=dtype,
                                       delimiter=delimiter, ndmin=2)
                except ValueError as e:
                    raise ValueError(
                        f"{src}: malformed CSV in rows "
                        f"[{nrow}, {nrow + len(lines)}): {e}") from None
                if ncol is None:
                    ncol = chunk.shape[1]
                elif chunk.shape[1] != ncol:
                    raise ValueError(
                        f"{src}: ragged CSV — row {nrow} has "
                        f"{chunk.shape[1]} columns, expected {ncol}")
                fout.write(np.ascontiguousarray(chunk))
                nrow += chunk.shape[0]
        if ncol is None:
            raise ValueError(f"{src}: no data rows")
        header = storage_format.MatrixHeader(nrow=nrow, ncol=ncol,
                                             dtype=dtype, layout="row")
        storage_format.write_header(dest, header)
    return header


def ingest_binary(src, dest, *, ncol: int, dtype=np.float32,
                  chunk_rows: int = 65536,
                  layout: str = "row") -> "storage_format.MatrixHeader":
    """Stream a raw row-major binary file (the FlashR
    ``fm.load.dense.matrix`` input: Criteo's preprocessed binaries) into an
    on-disk matrix.  Row count is derived from the file size."""
    from ..storage import format as storage_format

    src = pathlib.Path(src)
    dtype = np.dtype(dtype)
    row_bytes = ncol * dtype.itemsize
    total = src.stat().st_size
    if total % row_bytes:
        raise ValueError(
            f"{src}: size {total} is not a whole number of {ncol}-column "
            f"{dtype.name} rows")
    nrow = total // row_bytes
    if layout != "row":
        raise NotImplementedError("binary ingest writes row layout")
    header = storage_format.MatrixHeader(nrow=nrow, ncol=ncol, dtype=dtype,
                                         layout="row")
    dest = pathlib.Path(dest)
    dest.parent.mkdir(parents=True, exist_ok=True)
    with _no_partial_output(dest):
        with open(src, "rb") as fin, open(dest, "wb") as fout:
            fout.write(header.to_bytes())
            while True:
                buf = fin.read(chunk_rows * row_bytes)
                if not buf:
                    break
                fout.write(buf)
    return header


def ingest_factor_csv(src, dest, *, num_levels: Union[int, Sequence[int]],
                      dtype=np.float32, delimiter: str = ",",
                      skip_header: int = 0,
                      chunk_rows: int = 65536) -> dict:
    """Stream a CSV of integer factor columns into a CSR ``.fmat``
    (the Criteo ingest: k hashed-categorical columns → one-hot rows of
    exactly k ones among Σ ``num_levels`` columns) — one pass, bounded
    memory, never forming the dense design matrix.

    ``num_levels`` is the per-column level count (an int applies to every
    column).  Codes must be integers in ``[0, num_levels[j])``; a
    malformed row, a non-integer value or a cardinality overflow raises a
    clear error and removes the partial output.  Returns the CSR header
    meta dict.

    Layout note: the CSR sections are sequential (indptr | indices |
    data), and the section offsets depend on nnz = k·nrow, known only at
    EOF — so column indices stream to a sidecar temp file and the final
    ``.fmat`` is assembled from it in bounded chunks.  With a constant k
    per row, indptr is just ``arange(nrow+1)·k`` and data is all ones;
    neither needs a temp file.
    """
    from ..storage import sparse as storage_sparse

    dest = pathlib.Path(dest)
    dest.parent.mkdir(parents=True, exist_ok=True)
    tmp = dest.with_suffix(dest.suffix + ".indices.tmp")
    levels = None      # per-column level counts, resolved on first chunk
    offsets = None     # running column offsets of each factor column
    nrow = 0
    with _no_partial_output(dest, tmp):
        with open(src, "r") as fin, open(tmp, "wb") as ftmp:
            for _ in range(skip_header):
                fin.readline()
            while True:
                lines = []
                for line in fin:
                    if line.strip():
                        lines.append(line)
                    if len(lines) >= chunk_rows:
                        break
                if not lines:
                    break
                try:
                    chunk = np.loadtxt(lines, dtype=np.int64,
                                       delimiter=delimiter, ndmin=2)
                except ValueError as e:
                    raise ValueError(
                        f"{src}: malformed factor CSV in rows "
                        f"[{nrow}, {nrow + len(lines)}): {e} (factor "
                        f"columns must be integer codes)") from None
                if levels is None:
                    k = chunk.shape[1]
                    levels = ([int(num_levels)] * k
                              if np.isscalar(num_levels)
                              else [int(v) for v in num_levels])
                    if len(levels) != k:
                        raise ValueError(
                            f"{src}: {k} factor columns but "
                            f"{len(levels)} num_levels entries")
                    offsets = np.cumsum([0] + levels[:-1], dtype=np.int64)
                elif chunk.shape[1] != len(levels):
                    raise ValueError(
                        f"{src}: ragged CSV — row {nrow} has "
                        f"{chunk.shape[1]} columns, expected {len(levels)}")
                if chunk.size and chunk.min() < 0:
                    raise ValueError(
                        f"{src}: negative factor code in rows "
                        f"[{nrow}, {nrow + chunk.shape[0]})")
                over = chunk.max(axis=0) - np.asarray(levels)
                if (over >= 0).any():
                    j = int(np.argmax(over))
                    raise ValueError(
                        f"{src}: factor cardinality overflow — column {j} "
                        f"has code {int(chunk[:, j].max())} but "
                        f"num_levels[{j}]={levels[j]} (codes must be in "
                        f"[0, num_levels))")
                ftmp.write(np.ascontiguousarray(
                    (chunk + offsets).astype(np.int32)))
                nrow += chunk.shape[0]
        if levels is None:
            raise ValueError(f"{src}: no data rows")
        # Assemble the .fmat: header | indptr | indices (from tmp) | ones.
        k = len(levels)
        ncol = int(sum(levels))
        nnz = nrow * k
        dtype = np.dtype(dtype)
        with open(dest, "wb") as fout:
            fout.write(storage_sparse._csr_header_bytes(
                nrow=nrow, ncol=ncol, dtype=dtype, nnz=nnz, max_row_nnz=k))
            indptr_chunk = 1 << 20
            for start in range(0, nrow + 1, indptr_chunk):
                stop = min(start + indptr_chunk, nrow + 1)
                fout.write(np.arange(start, stop, dtype=np.int64) * k)
            with open(tmp, "rb") as ftmp:
                while True:
                    buf = ftmp.read(chunk_rows * k * 4)
                    if not buf:
                        break
                    fout.write(buf)
            ones = np.ones(min(nnz, chunk_rows * k), dtype)
            written = 0
            while written < nnz:
                n = min(nnz - written, ones.shape[0])
                fout.write(ones[:n])
                written += n
    tmp.unlink(missing_ok=True)
    return storage_sparse.read_csr_meta(dest)


class DataIterator:
    """Deterministic, resumable, device-prefetching batch iterator."""

    def __init__(self, cfg: DataConfig, *, sharding=None,
                 process_index: int = 0, process_count: int = 1):
        self.cfg = cfg
        self.source = TokenSource(cfg)
        self.step = 0
        self.sharding = sharding
        self.process_index = process_index
        self.process_count = process_count
        self._staged = None  # double buffer (the prefetch depth-1 queue)

    # -- fault-tolerance contract -------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, state: dict):
        self.step = int(state["step"])

    # -- batch construction ----------------------------------------------------
    def _host_batch(self, step: int) -> dict:
        cfg = self.cfg
        per_proc = cfg.global_batch // self.process_count
        span = cfg.seq_len + 1
        base = (step * cfg.global_batch + self.process_index * per_proc) * span
        toks = np.stack([
            self.source.window(base + i * span, span) for i in range(per_proc)])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def _stage(self, batch_np: dict):
        """Host → device, async; sharded if a sharding was provided."""
        if self.sharding is not None:
            return {k: jax.device_put(v, self.sharding[k])
                    for k, v in batch_np.items()}
        return {k: jax.device_put(v) for k, v in batch_np.items()}

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        if self._staged is None:
            self._staged = self._stage(self._host_batch(self.step))
        out = self._staged
        self.step += 1
        # prefetch the next batch while the caller computes on `out`
        self._staged = self._stage(self._host_batch(self.step))
        return out
