"""Fault-tolerance runtime."""
from . import fault_tolerance
from .fault_tolerance import (PreemptionGuard, StragglerMonitor, StepTimer,
                              replan_mesh, rescale_grad_accum)
