"""Fault-tolerance runtime: preemption, elastic re-mesh, stragglers.

At 1000+ nodes the mean time between node failures is shorter than a long
training run, so the framework assumes failure is routine:

**Preemption / crash** — `PreemptionGuard` installs SIGTERM/SIGINT handlers
(cloud preemption notices) that request a final synchronous checkpoint at
the next step boundary; combined with checkpoint/checkpoint.py's atomic
saves, the job loses at most one step plus the async-save lag.

**Elastic re-mesh** — `replan_mesh(n_devices)` picks the largest valid
(data, model) factorization for the surviving device count; the checkpoint
restores with the *new* shardings (see Checkpointer.restore), so training
continues at reduced width instead of waiting for repair.  Batch size is
held constant by rescaling grad_accum (same global batch, more
microbatches per device).

**Stragglers** — a `StragglerMonitor` tracks per-step wall times; steps
slower than `threshold × median` are logged with the step payload so the
scheduler can blocklist the slow host. In synchronous SPMD the mitigation
is re-mesh without the slow host (same path as failure) — plus the
data-loader prefetch (data/pipeline.py) and async checkpointing already
remove the two most common self-inflicted stalls.
"""
from __future__ import annotations

import logging
import signal
import statistics
import time
from typing import Optional

log = logging.getLogger("repro.runtime")


class PreemptionGuard:
    """SIGTERM/SIGINT → request checkpoint-and-exit at next step boundary."""

    def __init__(self):
        self.requested = False
        self._orig = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._orig[sig] = signal.signal(sig, self._handler)
            except ValueError:           # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        log.warning("preemption signal %s: checkpoint at next boundary", signum)
        self.requested = True

    def restore_handlers(self):
        for sig, h in self._orig.items():
            signal.signal(sig, h)


def replan_mesh(n_devices: int, *, prefer_model: int = 16):
    """Largest (data, model) grid for the surviving device count.

    Keeps the model axis at `prefer_model` when divisible (parameter shards
    stay valid), otherwise falls back to the largest power-of-two divisor —
    the elastic-scaling policy after losing hosts."""
    import jax

    from ..launch.mesh import mesh_axis_kwargs
    model = prefer_model
    while model > 1 and n_devices % model:
        model //= 2
    data = n_devices // model
    return jax.make_mesh((data, model), ("data", "model"),
                         **mesh_axis_kwargs(2))


def rescale_grad_accum(cfg_accum: int, old_data: int, new_data: int) -> int:
    """Hold the global batch constant across a re-mesh: fewer data shards
    => proportionally more microbatches."""
    return max(1, int(round(cfg_accum * old_data / max(1, new_data))))


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, window: int = 50):
        self.threshold = threshold
        self.window = window
        self.times: list = []
        self.flagged: list = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step was a straggler."""
        self.times.append(seconds)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) >= 10:
            med = statistics.median(self.times)
            if seconds > self.threshold * med:
                self.flagged.append((step, seconds, med))
                log.warning("straggler: step %d took %.2fs (median %.2fs)",
                            step, seconds, med)
                return True
        return False


class StepTimer:
    def __init__(self, monitor: Optional[StragglerMonitor] = None):
        self.monitor = monitor or StragglerMonitor()
        self._t0 = None
        self.step = 0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0
        self.monitor.record(self.step, dt)
        self.step += 1
        return False
